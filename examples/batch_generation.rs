//! Many-query batch data generation through the coordinator (Fig B.4
//! regime), served by the multi-mesh continuous-batching server: one
//! `BatchServer` instance holds a registry of mesh topologies (here a 2D
//! triangle mesh and a 3D tet mesh), callers tag each request with its
//! `mesh_id`, and every drained same-mesh group costs ONE batched assembly
//! + one lockstep CG.
//!
//! ```text
//! cargo run --release --example batch_generation -- --n 12 --count 64
//! ```

use tensor_galerkin::coordinator::{BatchServer, SolveRequest, VarCoeffRequest};
use tensor_galerkin::mesh::structured::{unit_cube_tet, unit_square_tri};
use tensor_galerkin::solver::SolverConfig;
use tensor_galerkin::util::cli::Args;
use tensor_galerkin::util::rng::Rng;
use tensor_galerkin::util::timer::time_it;

const MESH_2D: u64 = 1;
const MESH_3D: u64 = 2;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let n = args.get_usize("n", 12);
    let count = args.get_usize("count", 64);

    let tri = unit_square_tri(2 * n);
    let tet = unit_cube_tet(n);
    let (n2, n3) = (tri.n_nodes(), tet.n_nodes());
    println!(
        "== multi-mesh batch generation: {n2}-node tri + {n3}-node tet, {count} samples each =="
    );
    // Registry capped at 8 resident mesh states (plenty for two meshes —
    // the cap matters for servers cycling through many topologies).
    let server = BatchServer::start_multi(
        vec![(MESH_2D, tri), (MESH_3D, tet)],
        SolverConfig::default(),
        32,
        8,
    );

    // Interleaved mesh-tagged requests: the server groups them by mesh key
    // when draining, so both topologies are still served batched.
    let mut rng = Rng::new(7);
    let mut fixed = Vec::with_capacity(2 * count);
    for id in 0..count {
        fixed.push(SolveRequest::on_mesh(
            2 * id as u64,
            MESH_2D,
            (0..n2).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
        ));
        fixed.push(SolveRequest::on_mesh(
            2 * id as u64 + 1,
            MESH_3D,
            (0..n3).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
        ));
    }
    let (out, secs) = time_it(|| {
        server
            .solve_all_each(fixed)
            .into_iter()
            .collect::<anyhow::Result<Vec<_>>>()
            .unwrap()
    });
    let total_iters: usize = out.iter().map(|r| r.iterations).sum();
    println!(
        "fixed-operator: {} samples in {:.3}s ({:.1} samples/s, {} CG iterations total)",
        out.len(),
        secs,
        out.len() as f64 / secs,
        total_iters
    );
    let worst = out.iter().map(|r| r.rel_residual).fold(0.0f64, f64::max);
    println!("worst relative residual: {worst:.2e}");
    anyhow::ensure!(worst < 1e-8, "a solve missed tolerance");

    // A varcoeff burst on the 3D mesh: every sample is its own operator,
    // all assembled through one shared-topology Map-Reduce.
    let vreqs: Vec<VarCoeffRequest> = (0..count)
        .map(|id| {
            VarCoeffRequest::on_mesh(
                id as u64,
                MESH_3D,
                (0..n3).map(|_| rng.uniform_in(0.5, 2.0)).collect(),
                (0..n3).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
            )
        })
        .collect();
    let (vout, vsecs) = time_it(|| {
        server
            .solve_all_varcoeff_each(vreqs)
            .into_iter()
            .collect::<anyhow::Result<Vec<_>>>()
            .unwrap()
    });
    println!(
        "varcoeff: {} samples in {:.3}s ({:.1} samples/s)",
        vout.len(),
        vsecs,
        vout.len() as f64 / vsecs
    );

    let stats = server.stats().expect("worker alive");
    println!(
        "server: {} batched dispatches, {} scalar, {} failed, {} mesh states built",
        stats.batched_solves, stats.scalar_solves, stats.failed_requests, stats.meshes_built
    );
    Ok(())
}
