//! Many-query batch data generation through the coordinator (Fig B.4
//! regime): a fixed Poisson operator served by the BatchServer, generating
//! an (f, u) dataset with amortized setup.
//!
//! ```text
//! cargo run --release --example batch_generation -- --n 12 --count 64
//! ```

use tensor_galerkin::coordinator::{BatchServer, SolveRequest};
use tensor_galerkin::mesh::structured::unit_cube_tet;
use tensor_galerkin::solver::SolverConfig;
use tensor_galerkin::util::cli::Args;
use tensor_galerkin::util::rng::Rng;
use tensor_galerkin::util::timer::time_it;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let n = args.get_usize("n", 12);
    let count = args.get_usize("count", 64);

    let mesh = unit_cube_tet(n);
    println!("== batch generation: {} nodes, {count} samples ==", mesh.n_nodes());
    let n_nodes = mesh.n_nodes();
    let server = BatchServer::start(mesh, SolverConfig::default(), 32);

    let mut rng = Rng::new(7);
    let reqs: Vec<SolveRequest> = (0..count)
        .map(|id| SolveRequest {
            id: id as u64,
            f_nodal: (0..n_nodes).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
        })
        .collect();
    let (out, secs) = time_it(|| server.solve_all(reqs).unwrap());
    let total_iters: usize = out.iter().map(|r| r.iterations).sum();
    println!(
        "{} samples in {:.3}s ({:.1} samples/s, {} CG iterations total)",
        out.len(),
        secs,
        out.len() as f64 / secs,
        total_iters
    );
    let worst = out.iter().map(|r| r.rel_residual).fold(0.0f64, f64::max);
    println!("worst relative residual: {worst:.2e}");
    anyhow::ensure!(worst < 1e-8, "a solve missed tolerance");
    Ok(())
}
