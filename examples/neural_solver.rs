//! End-to-end driver (deliverable (b)/E2E): physics-informed training of
//! the TensorPILS neural solver on the checkerboard Poisson problem for a
//! few hundred steps, logging the loss curve, then evaluating against a
//! fine-mesh FEM reference — all three layers composed (Pallas-kernel
//! artifacts → JAX loss graph → Rust optimizer/PJRT runtime).
//!
//! ```text
//! make artifacts && cargo run --release --example neural_solver -- --adam 800 --lbfgs 40
//! ```

use tensor_galerkin::experiments::table1;
use tensor_galerkin::runtime::Runtime;
use tensor_galerkin::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let adam = args.get_usize("adam", 600);
    let lbfgs = args.get_usize("lbfgs", 30);
    let kfreq = args.get_usize("kfreq", 2);

    let rt = Runtime::new()?;
    println!("== TensorPILS end-to-end training (K={kfreq}, {adam} Adam + {lbfgs} L-BFGS) ==");
    let methods = vec!["pils".to_string()];
    let results = table1::run_with(&rt, &methods, &[kfreq], adam, lbfgs, 1e-3, 0, true)?;
    let r = &results[0];
    println!(
        "\nfinal: rel L2 {:.2}% | loss {:.3e} | Adam {:.1} it/s | L-BFGS {:.1} it/s",
        r.rel_l2_pct, r.final_loss, r.adam_its, r.lbfgs_its
    );
    println!("loss curve + fields: target/experiments.jsonl, target/fields/");
    anyhow::ensure!(
        r.rel_l2_pct < 25.0,
        "training did not reach a useful solution ({:.1}%)",
        r.rel_l2_pct
    );
    Ok(())
}
