//! Physics-informed operator learning demo: train the AGN on the wave
//! equation with the TensorGalerkin Galerkin-residual loss (data-free),
//! then compare ID/OOD rollouts against the FEM reference integrator.
//!
//! ```text
//! make artifacts && cargo run --release --example operator_learning -- --epochs 60
//! ```

use tensor_galerkin::oplearn::{dataset, driver, PdeKind, PdeSetup};
use tensor_galerkin::runtime::Runtime;
use tensor_galerkin::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let epochs = args.get_usize("epochs", 40);
    let samples = args.get_usize("samples", 4);

    let rt = Runtime::new()?;
    let setup = PdeSetup::new(&rt, PdeKind::Wave)?;
    println!(
        "== wave operator learning: {} nodes, rollout T={}, {} train ICs, {} epochs ==",
        setup.mesh.n_nodes(),
        setup.rollout_t,
        samples,
        epochs
    );
    let train = dataset::sample_ics(&setup.mesh, samples, 1000);
    let test = dataset::sample_ics(&setup.mesh, 2, 9000);

    let params = driver::train_pils(&rt, &setup, &train, epochs, 2e-3, 0)?;
    for (i, ic) in test.iter().enumerate() {
        let reference = setup.reference_trajectory(ic, 2 * setup.rollout_t);
        let pred = driver::rollout(&rt, &setup, &params, ic)?;
        let (id, ood) = driver::id_ood_errors(&pred, &reference, setup.rollout_t);
        println!("test IC {i}: rel L2  ID {id:.3}  OOD {ood:.3}");
        if i == 0 {
            let rmse = driver::per_step_rmse(&pred, &reference);
            println!(
                "per-step RMSE: step1 {:.2e} … mid {:.2e} … final {:.2e}",
                rmse[1],
                rmse[rmse.len() / 2],
                rmse.last().unwrap()
            );
            tensor_galerkin::mesh::io::write_vtk(
                "target/fields/wave_pred_final.vtk",
                &setup.mesh,
                &[("pred", &pred[setup.rollout_t]), ("fem", &reference[setup.rollout_t])],
                &[],
            )?;
        }
    }
    println!("snapshot written to target/fields/wave_pred_final.vtk");
    Ok(())
}
