//! Quickstart: assemble and solve a Poisson problem with TensorMesh.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Solves −Δu = 2π²·sin(πx)sin(πy) on the unit square (zero Dirichlet BCs)
//! via the TensorGalerkin Map-Reduce assembly + BiCGSTAB, checks the error
//! against the analytic solution, and writes a VTK field.

use tensor_galerkin::analysis::mms;
use tensor_galerkin::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
use tensor_galerkin::bc::DirichletBc;
use tensor_galerkin::mesh::structured::{jitter, unit_square_tri};
use tensor_galerkin::solver::{Method, SolverConfig};
use tensor_galerkin::tensormesh::{self, Problem};

fn main() -> anyhow::Result<()> {
    // 1. Mesh: a jittered (unstructured-geometry) triangulation.
    let mut mesh = unit_square_tri(48);
    jitter(&mut mesh, 0.2, 42);
    println!("mesh: {} nodes, {} cells", mesh.n_nodes(), mesh.n_cells());

    // 2. Variational problem: a(u,v) = ∫∇u·∇v, ℓ(v) = ∫ f v.
    let probe = AssemblyContext::new(&mesh, 1);
    let mut problem = Problem::scalar();
    problem.bilinear.push(BilinearForm::Diffusion {
        rho: Coefficient::Const(1.0),
    });
    problem.linear.push(LinearForm::Source {
        f: probe.coeff_fn(mms::sine2d_f),
    });
    problem.dirichlet = DirichletBc::homogeneous(mesh.boundary_nodes());

    // 3. Solve (Map-Reduce assembly + BiCGSTAB/Jacobi @ 1e-10).
    let sol = tensormesh::solve(&mesh, &problem, Method::BiCgStab, &SolverConfig::default())?;
    println!(
        "solved: {} iterations, relative residual {:.2e}",
        sol.stats.iterations, sol.rel_residual
    );
    for (stage, secs) in sol.timings.laps() {
        println!("  {stage:<10} {:.1} ms", secs * 1e3);
    }

    // 4. Verify against the manufactured solution.
    let exact: Vec<f64> = (0..mesh.n_nodes()).map(|i| mms::sine2d_u(mesh.point(i))).collect();
    let err = tensor_galerkin::util::rel_l2(&sol.u, &exact);
    println!("relative L2 error vs analytic: {err:.2e}");
    anyhow::ensure!(err < 5e-3, "unexpectedly large error");

    // 5. Dump the field for ParaView.
    tensor_galerkin::mesh::io::write_vtk(
        "target/fields/quickstart.vtk",
        &mesh,
        &[("u", &sol.u), ("exact", &exact)],
        &[],
    )?;
    println!("field written to target/fields/quickstart.vtk");
    Ok(())
}
