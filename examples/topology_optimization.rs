//! TensorOpt demo: compliance minimization of the 2D cantilever beam
//! (SIMP + MMA through the differentiable TensorGalerkin pipeline),
//! dumping the density evolution (Fig 5 / B.20).
//!
//! ```text
//! cargo run --release --example topology_optimization -- --iters 51
//! ```

use tensor_galerkin::mesh::structured::rect_quad;
use tensor_galerkin::opt::topopt::{run_topopt, TopOptConfig};
use tensor_galerkin::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let cfg = TopOptConfig {
        iters: args.get_usize("iters", 51),
        optimizer: args.get_str("optimizer", "mma"),
        ..TopOptConfig::default()
    };
    println!(
        "== TensorOpt: {}×{} cantilever, SIMP p={}, {} iterations ({}) ==",
        cfg.simp.nx, cfg.simp.ny, cfg.simp.penal, cfg.iters, cfg.optimizer
    );
    let result = run_topopt(&cfg)?;
    println!(
        "setup {:.2}s, loop {:.2}s ({} total BiCGSTAB iterations)",
        result.setup_s, result.loop_s, result.total_solver_iters
    );
    println!(
        "compliance: {:.4} → {:.4}  ({:.1}% reduction)",
        result.compliance_history[0],
        result.final_compliance(),
        100.0 * (1.0 - result.final_compliance() / result.compliance_history[0])
    );
    let mean: f64 = result.rho.iter().sum::<f64>() / result.rho.len() as f64;
    println!("volume fraction: {mean:.3} (target {})", cfg.vol_frac);

    let mesh = rect_quad(cfg.simp.nx, cfg.simp.ny, cfg.simp.lx, cfg.simp.ly);
    for (it, rho) in &result.snapshots {
        tensor_galerkin::mesh::io::write_vtk(
            format!("target/fields/cantilever_iter{it:03}.vtk"),
            &mesh,
            &[],
            &[("rho", rho)],
        )?;
    }
    println!("density evolution written to target/fields/cantilever_iter*.vtk");

    // ASCII rendering of the final design (Fig 5d).
    println!("\nfinal design (█ = solid):");
    for j in (0..cfg.simp.ny).rev().step_by(2) {
        let mut line = String::new();
        for i in 0..cfg.simp.nx {
            let r = result.rho[j * cfg.simp.nx + i];
            line.push(if r > 0.7 {
                '█'
            } else if r > 0.3 {
                '▒'
            } else {
                ' '
            });
        }
        println!("{line}");
    }
    Ok(())
}
