"""AOT lowering: JAX/Pallas → HLO text artifacts + manifest.json.

Python runs ONCE (`make artifacts`); the Rust coordinator loads the HLO
text through the PJRT C API and never touches Python again.

Interchange is HLO *text*, not serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).

Dynamic meshes vs static AOT shapes: every kernel is lowered at a ladder of
element-count BUCKETS; the Rust Map stage pads element batches with
degenerate (zero-volume) elements up to the next bucket — zero contribution
by construction (tested in test_kernels.py and rust/tests/).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels import local_assembly as ker

#: Element-count buckets for the Map-stage artifacts.
BUCKETS = [256, 2048, 16384, 131072]

#: 3D isotropic elasticity at E=1, ν=0.3 (paper §B.1.1).
LAM_3D = 0.3 / (1.3 * 0.4)  # ν E /((1+ν)(1−2ν)) = 0.576923
MU_3D = 1.0 / (2.0 * 1.3)  # E /(2(1+ν))        = 0.384615


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def kernel_specs(buckets):
    """(name, fn, arg_specs, meta) for every Map-stage artifact."""
    specs = []
    for e in buckets:
        specs += [
            (
                f"poisson2d_local_E{e}",
                lambda c, r: (ker.poisson2d(c, r),),
                [("coords", f32(e, 3, 2)), ("rho", f32(e, 3))],
                {"kind": "poisson2d_local", "bucket": e, "k": 3, "dim": 2, "kl": 3},
            ),
            (
                f"poisson3d_local_E{e}",
                lambda c, r: (ker.poisson3d(c, r),),
                [("coords", f32(e, 4, 3)), ("rho", f32(e, 4))],
                {"kind": "poisson3d_local", "bucket": e, "k": 4, "dim": 3, "kl": 4},
            ),
            (
                f"load2d_local_E{e}",
                lambda c, f: (ker.load2d(c, f),),
                [("coords", f32(e, 3, 2)), ("f", f32(e, 3))],
                {"kind": "load2d_local", "bucket": e, "k": 3, "dim": 2, "kl": 3},
            ),
            (
                f"load3d_local_E{e}",
                lambda c, f: (ker.load3d(c, f),),
                [("coords", f32(e, 4, 3)), ("f", f32(e, 4))],
                {"kind": "load3d_local", "bucket": e, "k": 4, "dim": 3, "kl": 4},
            ),
            (
                f"mass2d_local_E{e}",
                lambda c, r: (ker.mass2d(c, r),),
                [("coords", f32(e, 3, 2)), ("rho", f32(e, 3))],
                {"kind": "mass2d_local", "bucket": e, "k": 3, "dim": 2, "kl": 3},
            ),
            (
                f"mass3d_local_E{e}",
                lambda c, r: (ker.mass3d(c, r),),
                [("coords", f32(e, 4, 3)), ("rho", f32(e, 4))],
                {"kind": "mass3d_local", "bucket": e, "k": 4, "dim": 3, "kl": 4},
            ),
            (
                f"elasticity3d_local_E{e}",
                lambda c, m: (ker.elasticity3d(c, m, LAM_3D, MU_3D),),
                [("coords", f32(e, 4, 3)), ("emod", f32(e, 4))],
                {
                    "kind": "elasticity3d_local",
                    "bucket": e,
                    "k": 4,
                    "dim": 3,
                    "kl": 12,
                    "lambda": LAM_3D,
                    "mu": MU_3D,
                },
            ),
            (
                f"elasticity2d_q4_local_E{e}",
                lambda c, m: (ker.elasticity2d_q4(c, m, LAM_3D, MU_3D),),
                [("coords", f32(e, 4, 2)), ("emod", f32(e, 4))],
                {
                    "kind": "elasticity2d_q4_local",
                    "bucket": e,
                    "k": 4,
                    "dim": 2,
                    "kl": 8,
                    "lambda": LAM_3D,
                    "mu": MU_3D,
                },
            ),
        ]
    return specs


def lower_one(name, fn, args, meta, out_dir):
    arg_structs = [spec for (_, spec) in args]
    lowered = jax.jit(fn).lower(*arg_structs)
    text = to_hlo_text(lowered)
    path = out_dir / f"{name}.hlo.txt"
    path.write_text(text)
    entry = {
        "file": path.name,
        "inputs": [
            {"name": n, "shape": list(s.shape), "dtype": str(s.dtype)} for (n, s) in args
        ],
        "outputs": [
            {"shape": list(s.shape), "dtype": str(s.dtype)}
            for s in jax.eval_shape(fn, *arg_structs)
        ],
        **meta,
    }
    return entry


def build_kernel_artifacts(out_dir: pathlib.Path, buckets) -> dict:
    manifest = {}
    for name, fn, args, meta in kernel_specs(buckets):
        manifest[name] = lower_one(name, fn, args, meta, out_dir)
        print(f"  lowered {name}", file=sys.stderr)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--only",
        default="all",
        choices=["all", "kernels", "models", "oplearn"],
        help="subset of artifacts to build",
    )
    ap.add_argument(
        "--buckets",
        default=",".join(str(b) for b in BUCKETS),
        help="comma-separated element buckets",
    )
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    buckets = [int(b) for b in args.buckets.split(",") if b]

    manifest_path = out_dir / "manifest.json"
    manifest = {}
    if manifest_path.exists():
        manifest = json.loads(manifest_path.read_text())

    artifacts = manifest.get("artifacts", {})
    if args.only in ("all", "kernels"):
        artifacts.update(build_kernel_artifacts(out_dir, buckets))
    if args.only in ("all", "models"):
        from . import models_aot

        artifacts.update(models_aot.build_model_artifacts(out_dir))
    if args.only in ("all", "oplearn"):
        from . import oplearn_aot

        artifacts.update(oplearn_aot.build_oplearn_artifacts(out_dir))

    manifest = {"buckets": buckets, "artifacts": artifacts}
    manifest_path.write_text(json.dumps(manifest, indent=1, sort_keys=True))
    print(f"wrote {len(artifacts)} artifacts + manifest to {out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
