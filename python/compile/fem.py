"""Build-time FEM tables shared by the Pallas kernels, the jnp oracle and
the AOT lowering: reference-element gradients and quadrature rules.

These mirror `rust/src/fem/{reference,quadrature}.rs` exactly (same
reference cells, same rules); pytest cross-checks the invariants and the
Rust integration tests check the executed artifacts against the native Map
stage, closing the loop.
"""

from __future__ import annotations

import numpy as np

# --- Reference P1 gradients (constant over the simplex) -------------------

#: ∇φ̂ on the reference triangle {x,y≥0, x+y≤1}, shape (3, 2).
GRAD_TRI = np.array([[-1.0, -1.0], [1.0, 0.0], [0.0, 1.0]])

#: ∇φ̂ on the reference tetrahedron, shape (4, 3).
GRAD_TET = np.array(
    [[-1.0, -1.0, -1.0], [1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]]
)

# --- Quadrature (weights sum to the reference measure) ---------------------

#: Degree-2 rule on the reference triangle: 3 points, weights 1/6.
TRI_QPOINTS = np.array([[1 / 6, 1 / 6], [2 / 3, 1 / 6], [1 / 6, 2 / 3]])
TRI_QWEIGHTS = np.full(3, 1 / 6)

#: Degree-2 rule on the reference tetrahedron: 4 points, weights 1/24.
_a = (5.0 - np.sqrt(5.0)) / 20.0
_b = (5.0 + 3.0 * np.sqrt(5.0)) / 20.0
TET_QPOINTS = np.array(
    [[_b, _a, _a], [_a, _b, _a], [_a, _a, _b], [_a, _a, _a]]
)
TET_QWEIGHTS = np.full(4, 1 / 24)

#: 2×2 Gauss rule on [0,1]² (Q4 elements).
_g = 0.5 - 0.5 / np.sqrt(3.0)
QUAD_QPOINTS = np.array(
    [[_g, _g], [1 - _g, _g], [_g, 1 - _g], [1 - _g, 1 - _g]]
)
QUAD_QWEIGHTS = np.full(4, 0.25)


def p1_basis_tri(points: np.ndarray) -> np.ndarray:
    """P1 triangle basis values at reference points, shape (Q, 3)."""
    x, y = points[:, 0], points[:, 1]
    return np.stack([1.0 - x - y, x, y], axis=1)


def p1_basis_tet(points: np.ndarray) -> np.ndarray:
    """P1 tetrahedron basis values at reference points, shape (Q, 4)."""
    x, y, z = points[:, 0], points[:, 1], points[:, 2]
    return np.stack([1.0 - x - y - z, x, y, z], axis=1)


def q1_basis(points: np.ndarray) -> np.ndarray:
    """Q1 quadrilateral basis values at reference points, shape (Q, 4).

    CCW node ordering (0,0),(1,0),(1,1),(0,1) — matches Rust's Q1Quad.
    """
    x, y = points[:, 0], points[:, 1]
    return np.stack([(1 - x) * (1 - y), x * (1 - y), x * y, (1 - x) * y], axis=1)


def q1_grads(points: np.ndarray) -> np.ndarray:
    """Q1 basis gradients at reference points, shape (Q, 4, 2)."""
    x, y = points[:, 0], points[:, 1]
    gx = np.stack([-(1 - y), (1 - y), y, -y], axis=1)
    gy = np.stack([-(1 - x), -x, x, (1 - x)], axis=1)
    return np.stack([gx, gy], axis=2)


def element_tables(kind: str):
    """Return (ref_grads_or_none, qpoints, qweights, basis_vals, k, d).

    `kind` ∈ {tri, tet, quad}. For simplices ref grads are constant (k, d);
    for quads they vary per quadrature point (Q, 4, 2).
    """
    if kind == "tri":
        return GRAD_TRI, TRI_QPOINTS, TRI_QWEIGHTS, p1_basis_tri(TRI_QPOINTS), 3, 2
    if kind == "tet":
        return GRAD_TET, TET_QPOINTS, TET_QWEIGHTS, p1_basis_tet(TET_QPOINTS), 4, 3
    if kind == "quad":
        return (
            q1_grads(QUAD_QPOINTS),
            QUAD_QPOINTS,
            QUAD_QWEIGHTS,
            q1_basis(QUAD_QPOINTS),
            4,
            2,
        )
    raise ValueError(f"unknown element kind {kind!r}")
