"""Layer-1 Pallas kernels: the Batch-Map stage of TensorGalerkin.

Each kernel computes a block of local element matrices/vectors entirely in
VMEM-resident tiles: the grid runs over blocks of the *element* axis (the
TPU analogue of the paper's CUDA batched-einsum decomposition — see
DESIGN.md §3 Hardware adaptation), and each grid step performs the full
quadrature contraction of Eq. (7) for its block with small dense ops.

Implementation notes:

* `interpret=True` everywhere — the CPU PJRT plugin cannot execute Mosaic
  custom-calls, so kernels are lowered through the interpreter to plain
  HLO. This preserves the *structure* under test (O(1) graph nodes, block
  schedule); real-TPU performance is estimated in DESIGN.md §Perf.
* Pallas kernel bodies may not capture constant *arrays*; all reference
  tables (quadrature weights, basis values, reference gradients) enter as
  Python scalars unrolled at trace time — `Q, k ≤ 4`, so the unrolled
  contraction is still one fused kernel.
* All kernels are f32 on the artifact path; the Rust native Map stage is
  f64 and the two are cross-checked in `rust/tests/`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .. import fem

#: Elements per grid step. VMEM estimate per block (f32 words):
#: coords BE·k·d + coeff BE·Q + out BE·kl² ≲ 128·(12+4+144) ≈ 82 KiB for the
#: heaviest (elasticity3d) kernel — comfortably under a TPU core's ~16 MiB.
DEFAULT_BLOCK = 128


# --- In-kernel geometry helpers (no captured constant arrays!) --------------


def _tri_geometry(x):
    """P1 triangle geometry for a coords block (BE,3,2).

    Returns (g, adet): physical gradients as a list of 3 tensors (BE,2),
    and |det J| (BE,). Uses G₀ = −(J⁻ᵀe₁ + J⁻ᵀe₂), G₁, G₂ = rows of J⁻¹.
    """
    e1 = x[:, 1, :] - x[:, 0, :]
    e2 = x[:, 2, :] - x[:, 0, :]
    det = e1[:, 0] * e2[:, 1] - e1[:, 1] * e2[:, 0]
    adet = jnp.abs(det)
    bad = adet < 1e-30
    invd = jnp.where(bad, 0.0, 1.0 / jnp.where(bad, 1.0, det))
    # J = [e1 | e2] columns; rows of J⁻¹ (= reciprocal basis):
    r1 = jnp.stack([e2[:, 1], -e2[:, 0]], axis=-1) * invd[:, None]
    r2 = jnp.stack([-e1[:, 1], e1[:, 0]], axis=-1) * invd[:, None]
    g = [-(r1 + r2), r1, r2]
    return g, adet


def _tet_geometry(x):
    """P1 tetrahedron geometry for (BE,4,3): list of 4 gradients + |det|."""
    e1 = x[:, 1, :] - x[:, 0, :]
    e2 = x[:, 2, :] - x[:, 0, :]
    e3 = x[:, 3, :] - x[:, 0, :]

    def cross(a, b):
        return jnp.stack(
            [
                a[:, 1] * b[:, 2] - a[:, 2] * b[:, 1],
                a[:, 2] * b[:, 0] - a[:, 0] * b[:, 2],
                a[:, 0] * b[:, 1] - a[:, 1] * b[:, 0],
            ],
            axis=-1,
        )

    c23 = cross(e2, e3)
    det = jnp.sum(e1 * c23, axis=-1)
    adet = jnp.abs(det)
    bad = adet < 1e-30
    invd = jnp.where(bad, 0.0, 1.0 / jnp.where(bad, 1.0, det))
    r1 = c23 * invd[:, None]
    r2 = cross(e3, e1) * invd[:, None]
    r3 = cross(e1, e2) * invd[:, None]
    g = [-(r1 + r2 + r3), r1, r2, r3]
    return g, adet


def _stack_local(rows, k):
    """Stack k lists of k (BE,) tensors into (BE,k,k)."""
    return jnp.stack([jnp.stack(r, axis=-1) for r in rows], axis=-2)


def _stiffness_body(geometry, weights, coords_ref, rho_ref, out_ref):
    """Poisson stiffness: K_ab = (Σq ŵq ρq)·|det|·G_a·G_b."""
    g, adet = geometry(coords_ref[...])
    rho = rho_ref[...]
    c = adet * sum(float(w) * rho[:, q] for q, w in enumerate(weights))
    k = len(g)
    rows = [[c * jnp.sum(g[a] * g[b], axis=-1) for b in range(k)] for a in range(k)]
    out_ref[...] = _stack_local(rows, k)


def _load_body(geometry, basis, weights, coords_ref, f_ref, out_ref):
    """Load: F_a = |det| Σq ŵq f_q φ̂_a(q). basis is a (Q,k) numpy table."""
    _, adet = geometry(coords_ref[...])
    f = f_ref[...]
    k = basis.shape[1]
    cols = []
    for a in range(k):
        acc = sum(float(weights[q]) * float(basis[q, a]) * f[:, q] for q in range(len(weights)))
        cols.append(adet * acc)
    out_ref[...] = jnp.stack(cols, axis=-1)


def _mass_body(geometry, basis, weights, coords_ref, rho_ref, out_ref):
    """Mass: M_ab = |det| Σq ŵq ρq φ̂_a φ̂_b."""
    _, adet = geometry(coords_ref[...])
    rho = rho_ref[...]
    k = basis.shape[1]
    nq = len(weights)
    rows = []
    for a in range(k):
        row = []
        for b in range(k):
            acc = sum(
                float(weights[q]) * float(basis[q, a]) * float(basis[q, b]) * rho[:, q]
                for q in range(nq)
            )
            row.append(adet * acc)
        rows.append(row)
    out_ref[...] = _stack_local(rows, k)


def _elasticity_simplex_body(geometry, weights, lam, mu, d, coords_ref, emod_ref, out_ref):
    """Vector P1 simplex elasticity:
    K[(a,i),(b,j)] = scale · (λ G_ai G_bj + μ (G_aj G_bi + δ_ij G_a·G_b)).
    """
    g, adet = geometry(coords_ref[...])
    emod = emod_ref[...]
    scale = adet * sum(float(w) * emod[:, q] for q, w in enumerate(weights))
    k = len(g)
    rows = []
    for a in range(k):
        for i in range(d):
            row = []
            for b in range(k):
                dots = jnp.sum(g[a] * g[b], axis=-1)
                for j in range(d):
                    v = lam * g[a][:, i] * g[b][:, j] + mu * g[a][:, j] * g[b][:, i]
                    if i == j:
                        v = v + mu * dots
                    row.append(scale * v)
            rows.append(row)
    out_ref[...] = _stack_local(rows, k * d)


def _elasticity_q4_body(lam, mu, grads_tab, weights, coords_ref, emod_ref, out_ref):
    """Q4 plane elasticity with 2×2 Gauss; Jacobian varies per q.

    `grads_tab` is the (Q,4,2) numpy table of reference gradients, unrolled
    to scalars at trace time.
    """
    x = coords_ref[...]
    emod = emod_ref[...]
    nq = len(weights)
    acc = None
    for q in range(nq):
        # J[r,c] = Σ_a x[:,a,r]·ĝ[q,a,c] with scalar ĝ entries.
        j = [[None, None], [None, None]]
        for r in range(2):
            for c in range(2):
                j[r][c] = sum(float(grads_tab[q, a, c]) * x[:, a, r] for a in range(4))
        det = j[0][0] * j[1][1] - j[0][1] * j[1][0]
        adet = jnp.abs(det)
        bad = adet < 1e-30
        invd = jnp.where(bad, 0.0, 1.0 / jnp.where(bad, 1.0, det))
        # rows of J⁻¹: [[ j11, -j01], [-j10, j00]]·invd
        jinv = [
            [j[1][1] * invd, -j[0][1] * invd],
            [-j[1][0] * invd, j[0][0] * invd],
        ]
        # G[a,r] = Σ_c ĝ[q,a,c]·J⁻¹[c][r]
        g = []
        for a in range(4):
            g.append(
                [
                    sum(float(grads_tab[q, a, c]) * jinv[c][r] for c in range(2))
                    for r in range(2)
                ]
            )
        scale = adet * emod[:, q] * float(weights[q])
        rows = []
        for a in range(4):
            for i in range(2):
                row = []
                for b in range(4):
                    dots = g[a][0] * g[b][0] + g[a][1] * g[b][1]
                    for jj in range(2):
                        v = lam * g[a][i] * g[b][jj] + mu * g[a][jj] * g[b][i]
                        if i == jj:
                            v = v + mu * dots
                        row.append(scale * v)
                rows.append(row)
        kq = _stack_local(rows, 8)
        acc = kq if acc is None else acc + kq
    out_ref[...] = acc


# --- pallas_call wrappers ---------------------------------------------------


def _call(body, coords, coeff, k, d, out_local, block):
    """Grid over element blocks; all operands tiled on the element axis.

    `out_local` is the trailing local size: 0 → vector output (E, k),
    else matrix output (E, out_local, out_local).
    """
    e = coords.shape[0]
    assert e % block == 0, f"element count {e} not divisible by block {block}"
    q = coeff.shape[1]
    if out_local:
        out_shape = (e, out_local, out_local)
        out_spec = pl.BlockSpec((block, out_local, out_local), lambda i: (i, 0, 0))
    else:
        out_shape = (e, k)
        out_spec = pl.BlockSpec((block, k), lambda i: (i, 0))
    return pl.pallas_call(
        body,
        grid=(e // block,),
        in_specs=[
            pl.BlockSpec((block, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, q), lambda i: (i, 0)),
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, coords.dtype),
        interpret=True,
    )(coords, coeff)


def poisson2d(coords, rho, block=DEFAULT_BLOCK):
    """coords (E,3,2), rho (E,3) → K_local (E,3,3)."""
    body = functools.partial(_stiffness_body, _tri_geometry, fem.TRI_QWEIGHTS)
    return _call(body, coords, rho, 3, 2, 3, block)


def poisson3d(coords, rho, block=DEFAULT_BLOCK):
    """coords (E,4,3), rho (E,4) → K_local (E,4,4)."""
    body = functools.partial(_stiffness_body, _tet_geometry, fem.TET_QWEIGHTS)
    return _call(body, coords, rho, 4, 3, 4, block)


def load2d(coords, f, block=DEFAULT_BLOCK):
    """coords (E,3,2), f (E,3) → F_local (E,3)."""
    body = functools.partial(
        _load_body, _tri_geometry, fem.p1_basis_tri(fem.TRI_QPOINTS), fem.TRI_QWEIGHTS
    )
    return _call(body, coords, f, 3, 2, 0, block)


def load3d(coords, f, block=DEFAULT_BLOCK):
    """coords (E,4,3), f (E,4) → F_local (E,4)."""
    body = functools.partial(
        _load_body, _tet_geometry, fem.p1_basis_tet(fem.TET_QPOINTS), fem.TET_QWEIGHTS
    )
    return _call(body, coords, f, 4, 3, 0, block)


def mass2d(coords, rho, block=DEFAULT_BLOCK):
    """coords (E,3,2), rho (E,3) → M_local (E,3,3)."""
    body = functools.partial(
        _mass_body, _tri_geometry, fem.p1_basis_tri(fem.TRI_QPOINTS), fem.TRI_QWEIGHTS
    )
    return _call(body, coords, rho, 3, 2, 3, block)


def mass3d(coords, rho, block=DEFAULT_BLOCK):
    """coords (E,4,3), rho (E,4) → M_local (E,4,4)."""
    body = functools.partial(
        _mass_body, _tet_geometry, fem.p1_basis_tet(fem.TET_QPOINTS), fem.TET_QWEIGHTS
    )
    return _call(body, coords, rho, 4, 3, 4, block)


def elasticity3d(coords, emod, lam, mu, block=DEFAULT_BLOCK):
    """coords (E,4,3), emod (E,4) → K_local (E,12,12). λ, μ static."""
    body = functools.partial(
        _elasticity_simplex_body, _tet_geometry, fem.TET_QWEIGHTS, float(lam), float(mu), 3
    )
    return _call(body, coords, emod, 4, 3, 12, block)


def elasticity2d_q4(coords, emod, lam, mu, block=DEFAULT_BLOCK):
    """coords (E,4,2), emod (E,4) → K_local (E,8,8). λ, μ static."""
    body = functools.partial(
        _elasticity_q4_body,
        float(lam),
        float(mu),
        np.asarray(fem.q1_grads(fem.QUAD_QPOINTS)),
        fem.QUAD_QWEIGHTS,
    )
    return _call(body, coords, emod, 4, 2, 8, block)
