"""Pure-jnp correctness oracle for the Batch-Map kernels.

Implements Eq. (7)/(A.12) as literal batched einsum contractions with no
Pallas, no tiling and no cleverness — the ground truth the Pallas kernels
(and, transitively, the PJRT artifacts executed from Rust) are validated
against in pytest.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .. import fem


def det2(j):
    """Batched 2×2 determinant (…, 2, 2) → (…)."""
    return j[..., 0, 0] * j[..., 1, 1] - j[..., 0, 1] * j[..., 1, 0]


def inv2(j, det):
    """Closed-form batched 2×2 inverse (no LAPACK custom-calls — the
    xla_extension 0.5.1 runtime rejects typed-FFI custom-call HLO)."""
    invd = 1.0 / det
    row0 = jnp.stack([j[..., 1, 1], -j[..., 0, 1]], axis=-1)
    row1 = jnp.stack([-j[..., 1, 0], j[..., 0, 0]], axis=-1)
    return jnp.stack([row0, row1], axis=-2) * invd[..., None, None]


def det3(j):
    """Batched 3×3 determinant."""
    return (
        j[..., 0, 0] * (j[..., 1, 1] * j[..., 2, 2] - j[..., 1, 2] * j[..., 2, 1])
        - j[..., 0, 1] * (j[..., 1, 0] * j[..., 2, 2] - j[..., 1, 2] * j[..., 2, 0])
        + j[..., 0, 2] * (j[..., 1, 0] * j[..., 2, 1] - j[..., 1, 1] * j[..., 2, 0])
    )


def inv3(j, det):
    """Closed-form batched 3×3 inverse via the adjugate."""
    c = lambda a, b, p, q: j[..., a, p] * j[..., b, q] - j[..., a, q] * j[..., b, p]
    adj = jnp.stack(
        [
            jnp.stack([c(1, 2, 1, 2), -c(0, 2, 1, 2), c(0, 1, 1, 2)], axis=-1),
            jnp.stack([-c(1, 2, 0, 2), c(0, 2, 0, 2), -c(0, 1, 0, 2)], axis=-1),
            jnp.stack([c(1, 2, 0, 1), -c(0, 2, 0, 1), c(0, 1, 0, 1)], axis=-1),
        ],
        axis=-2,
    )
    return adj / det[..., None, None]


def _batched_det_inv(jac):
    """Dispatch closed-form det/inv on the trailing square dimension."""
    d = jac.shape[-1]
    if d == 2:
        det = det2(jac)
        return det, inv2(jac, jnp.where(jnp.abs(det) < 1e-30, 1.0, det))
    if d == 3:
        det = det3(jac)
        return det, inv3(jac, jnp.where(jnp.abs(det) < 1e-30, 1.0, det))
    raise ValueError(f"unsupported dimension {d}")


def _simplex_geometry(coords, grad_ref):
    """Batched P1 simplex geometry.

    coords: (E, k, d); grad_ref: (k, d) constant reference gradients.
    Returns (G, adet) with G (E, k, d) physical gradients, adet (E,) |det J|.
    """
    grad_ref = jnp.asarray(grad_ref, coords.dtype)
    # J[e, r, c] = Σ_a coords[e, a, r] · grad_ref[a, c]
    jac = jnp.einsum("ear,ac->erc", coords, grad_ref)
    det, inv = _batched_det_inv(jac)
    adet = jnp.abs(det)
    # G_a = J^{-T} ĝ_a  ⇔  G[e,a,r] = Σ_c inv[e,c,r] ĝ[a,c]
    g = jnp.einsum("ecr,ac->ear", inv, grad_ref)
    g = jnp.where(adet[:, None, None] < 1e-30, 0.0, g)
    return g, adet


def local_stiffness_simplex(coords, rho_q, grad_ref, weights):
    """Local Poisson stiffness (Eq. A.12): K_eab = Σ_q ŵ_q ρ_eq |detJ| G_a·G_b.

    coords (E,k,d), rho_q (E,Q) → (E,k,k).
    """
    g, adet = _simplex_geometry(coords, grad_ref)
    w = jnp.asarray(weights, coords.dtype)
    c = adet * jnp.einsum("eq,q->e", rho_q, w)  # (E,)
    return c[:, None, None] * jnp.einsum("ead,ebd->eab", g, g)


def local_load_simplex(coords, f_q, basis, weights):
    """Local load vector (Eq. A.12): F_ea = Σ_q ŵ_q f_eq |detJ| φ̂_a(x̂_q)."""
    grad_ref = fem.GRAD_TRI if coords.shape[1] == 3 else fem.GRAD_TET
    _, adet = _simplex_geometry(coords, grad_ref)
    w = jnp.asarray(weights, coords.dtype)
    phi = jnp.asarray(basis, coords.dtype)  # (Q, k)
    return adet[:, None] * jnp.einsum("eq,q,qa->ea", f_q, w, phi)


def local_mass_simplex(coords, rho_q, basis, weights):
    """Local mass matrix: M_eab = Σ_q ŵ_q ρ_eq |detJ| φ̂_a φ̂_b."""
    grad_ref = fem.GRAD_TRI if coords.shape[1] == 3 else fem.GRAD_TET
    _, adet = _simplex_geometry(coords, grad_ref)
    w = jnp.asarray(weights, coords.dtype)
    phi = jnp.asarray(basis, coords.dtype)
    return adet[:, None, None] * jnp.einsum("eq,q,qa,qb->eab", rho_q, w, phi, phi)


def local_elasticity_simplex(coords, emod_q, lam, mu, grad_ref, weights):
    """Local isotropic elasticity stiffness, vector P1 on simplices.

    K[(a,i),(b,j)] = scale · (λ G_ai G_bj + μ (G_aj G_bi + δ_ij G_a·G_b))
    with scale = Σ_q ŵ_q E_eq |detJ|. Returns (E, k·d, k·d).
    """
    g, adet = _simplex_geometry(coords, grad_ref)
    e, k, d = g.shape
    w = jnp.asarray(weights, coords.dtype)
    scale = adet * jnp.einsum("eq,q->e", emod_q, w)
    t_lam = lam * jnp.einsum("eai,ebj->eaibj", g, g)
    t_mu1 = mu * jnp.einsum("eaj,ebi->eaibj", g, g)
    dots = jnp.einsum("ead,ebd->eab", g, g)
    eye = jnp.eye(d, dtype=coords.dtype)
    t_mu2 = mu * jnp.einsum("eab,ij->eaibj", dots, eye)
    full = (t_lam + t_mu1 + t_mu2) * scale[:, None, None, None, None]
    return full.reshape(e, k * d, k * d)


def local_elasticity_q4(coords, emod_q, lam, mu):
    """Local Q4 elasticity stiffness with 2×2 Gauss (non-constant Jacobian).

    coords (E,4,2), emod_q (E,4) → (E,8,8).
    """
    grads = jnp.asarray(fem.q1_grads(fem.QUAD_QPOINTS), coords.dtype)  # (Q,4,2)
    w = jnp.asarray(fem.QUAD_QWEIGHTS, coords.dtype)
    # J[e,q,r,c] = Σ_a coords[e,a,r] grads[q,a,c]
    jac = jnp.einsum("ear,qac->eqrc", coords, grads)
    det, inv = _batched_det_inv(jac)
    adet = jnp.abs(det)
    # G[e,q,a,r] = Σ_c inv[e,q,c,r] grads[q,a,c]
    g = jnp.einsum("eqcr,qac->eqar", inv, grads)
    scale = adet * emod_q * w[None, :]  # (E,Q)
    t_lam = lam * jnp.einsum("eqai,eqbj->eqaibj", g, g)
    t_mu1 = mu * jnp.einsum("eqaj,eqbi->eqaibj", g, g)
    dots = jnp.einsum("eqad,eqbd->eqab", g, g)
    eye = jnp.eye(2, dtype=coords.dtype)
    t_mu2 = mu * jnp.einsum("eqab,ij->eqaibj", dots, eye)
    full = jnp.einsum("eqaibj,eq->eaibj", t_lam + t_mu1 + t_mu2, scale)
    ne = coords.shape[0]
    return full.reshape(ne, 8, 8)


# --- Convenience wrappers matching the artifact signatures -----------------


def poisson2d(coords, rho_q):
    return local_stiffness_simplex(coords, rho_q, fem.GRAD_TRI, fem.TRI_QWEIGHTS)


def poisson3d(coords, rho_q):
    return local_stiffness_simplex(coords, rho_q, fem.GRAD_TET, fem.TET_QWEIGHTS)


def load2d(coords, f_q):
    return local_load_simplex(coords, f_q, fem.p1_basis_tri(fem.TRI_QPOINTS), fem.TRI_QWEIGHTS)


def load3d(coords, f_q):
    return local_load_simplex(coords, f_q, fem.p1_basis_tet(fem.TET_QPOINTS), fem.TET_QWEIGHTS)


def mass2d(coords, rho_q):
    return local_mass_simplex(coords, rho_q, fem.p1_basis_tri(fem.TRI_QPOINTS), fem.TRI_QWEIGHTS)


def mass3d(coords, rho_q):
    return local_mass_simplex(coords, rho_q, fem.p1_basis_tet(fem.TET_QPOINTS), fem.TET_QWEIGHTS)


def elasticity3d(coords, emod_q, lam, mu):
    return local_elasticity_simplex(coords, emod_q, lam, mu, fem.GRAD_TET, fem.TET_QWEIGHTS)


def elasticity2d_q4(coords, emod_q, lam, mu):
    return local_elasticity_q4(coords, emod_q, lam, mu)


def random_valid_simplices(rng: np.random.Generator, n: int, k: int, d: int, dtype=np.float32):
    """Random non-degenerate simplices: identity simplex + bounded jitter."""
    base = np.zeros((k, d))
    base[1:] = np.eye(d)[: k - 1] if k - 1 <= d else None
    coords = base[None, :, :] + 0.15 * rng.standard_normal((n, k, d))
    shift = 2.0 * rng.standard_normal((n, 1, d))
    return (coords + shift).astype(dtype)
