"""Layer-2 physics-informed objectives (Table 1 / Fig 4 / §B.2).

Four neural PDE solver paradigms on the shared SIREN backbone:

* `pinn_loss`      — strong form, second-order AD (two Hessian passes),
* `vpinn_loss`     — variational residual against P1 test functions,
                     first-order AD for ∇u_θ,
* `deep_ritz_loss` — energy functional with deterministic element
                     quadrature, first-order AD,
* `pils_loss`      — TensorPILS: the network predicts nodal Galerkin
                     coefficients; the residual `‖K U − F‖²` uses analytic
                     shape-function derivatives (the pre-assembled sparse K),
                     *zero* spatial autodiff.

All functions are pure and trace-time-differentiable: AOT lowering bakes
`jax.value_and_grad(loss)` into a single O(1)-node HLO program per step —
the structural reproduction of the paper's O(1)-graph property.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import models
from .kernels import ref

LAMBDA_BC = 100.0


def checkerboard(x, kfreq):
    """f_K(x,y) = (−1)^{⌊Kx⌋+⌊Ky⌋} (Eq. B.10); `kfreq` may be traced."""
    ix = jnp.floor(kfreq * x[..., 0])
    iy = jnp.floor(kfreq * x[..., 1])
    return 1.0 - 2.0 * jnp.mod(ix + iy, 2.0)


# --- Strong-form PINN ---------------------------------------------------------


def pinn_loss(flat, coords, mask, kfreq, layers, w0=30.0, lam_bc=LAMBDA_BC):
    """Mean squared strong residual (Δu + f)² on interior nodes + boundary
    penalty. Requires two AD passes (Hessian trace) per point."""

    def u_scalar(p):
        return models.siren_apply(flat, p[None, :], layers, w0)[0, 0]

    lap = jax.vmap(lambda p: jnp.trace(jax.hessian(u_scalar)(p)))(coords)
    u = jax.vmap(u_scalar)(coords)
    f = checkerboard(coords, kfreq)
    pde = (lap + f) ** 2  # −Δu = f ⇒ residual Δu + f
    interior = jnp.sum(mask * pde) / jnp.sum(mask)
    boundary = jnp.sum((1.0 - mask) * u**2) / jnp.maximum(jnp.sum(1.0 - mask), 1.0)
    return interior + lam_bc * boundary


# --- Variational PINN ---------------------------------------------------------


def _element_quadrature(cell_coords):
    """Physical quad points / weights for P1 triangles (deg-2 rule).

    Returns (qpts (E,Q,2), wdet (E,Q), G (E,3,2))."""
    from . import fem

    g, adet = ref._simplex_geometry(cell_coords, fem.GRAD_TRI)
    qref = jnp.asarray(fem.TRI_QPOINTS, cell_coords.dtype)  # (Q,2)
    phi = jnp.asarray(fem.p1_basis_tri(fem.TRI_QPOINTS), cell_coords.dtype)  # (Q,3)
    qpts = jnp.einsum("qa,ead->eqd", phi, cell_coords)
    w = jnp.asarray(fem.TRI_QWEIGHTS, cell_coords.dtype)
    wdet = adet[:, None] * w[None, :]
    del qref
    return qpts, wdet, g, phi


def vpinn_loss(flat, cell_coords, cells, mask, kfreq, layers, w0=30.0, lam_bc=LAMBDA_BC):
    """Variational residual R_i = ∫∇u_θ·∇φ_i − ∫f φ_i, tested against every
    P1 hat function; first-order AD for ∇u_θ at quadrature points."""
    n = mask.shape[0]
    qpts, wdet, g, phi = _element_quadrature(cell_coords)
    e, q, _ = qpts.shape

    def u_scalar(p):
        return models.siren_apply(flat, p[None, :], layers, w0)[0, 0]

    grad_u = jax.vmap(jax.grad(u_scalar))(qpts.reshape(-1, 2)).reshape(e, q, 2)
    f = checkerboard(qpts, kfreq)  # (E,Q)
    # r_ea = Σ_q wdet (∇u·G_a − f φ_qa)
    r_local = jnp.einsum("eq,eqd,ead->ea", wdet, grad_u, g) - jnp.einsum(
        "eq,eq,qa->ea", wdet, f, phi
    )
    r = jax.ops.segment_sum(r_local.reshape(-1), cells.reshape(-1), num_segments=n)
    return jnp.sum((mask * r) ** 2) / jnp.sum(mask)


def vpinn_loss_with_bc(flat, cell_coords, cells, node_coords, mask, kfreq, layers, w0=30.0):
    base = vpinn_loss(flat, cell_coords, cells, mask, kfreq, layers, w0)
    u = models.siren_apply(flat, node_coords, layers, w0)[:, 0]
    nb = jnp.maximum(jnp.sum(1.0 - mask), 1.0)
    return base + LAMBDA_BC * jnp.sum((1.0 - mask) * u**2) / nb


# --- Deep Ritz ----------------------------------------------------------------


def deep_ritz_loss(flat, cell_coords, node_coords, mask, kfreq, layers, w0=30.0, lam_bc=LAMBDA_BC):
    """Energy J(u) = ∫ ½|∇u|² − f u with deterministic Gauss quadrature on
    elements + boundary penalty."""
    qpts, wdet, _, _ = _element_quadrature(cell_coords)
    e, q, _ = qpts.shape

    def u_scalar(p):
        return models.siren_apply(flat, p[None, :], layers, w0)[0, 0]

    flatq = qpts.reshape(-1, 2)
    grad_u = jax.vmap(jax.grad(u_scalar))(flatq).reshape(e, q, 2)
    u_q = jax.vmap(u_scalar)(flatq).reshape(e, q)
    f = checkerboard(qpts, kfreq)
    energy = jnp.sum(wdet * (0.5 * jnp.sum(grad_u**2, axis=-1) - f * u_q))
    u_nodes = models.siren_apply(flat, node_coords, layers, w0)[:, 0]
    nb = jnp.maximum(jnp.sum(1.0 - mask), 1.0)
    return energy + lam_bc * jnp.sum((1.0 - mask) * u_nodes**2) / nb


# --- TensorPILS ----------------------------------------------------------------


def spmv(kvals, rows, cols, u, n):
    """Deterministic sparse K·u via gather + segment-sum (the O(1)-graph
    SpMM-shaped reduce inside the loss)."""
    return jax.ops.segment_sum(kvals * u[cols], rows, num_segments=n)


def pils_loss(flat, node_coords, mask, kvals, rows, cols, fvec, layers, w0=30.0):
    """TensorPILS discrete residual ‖K U − F‖² with hard Dirichlet BCs:
    U is masked to zero on the boundary and residual rows are restricted to
    free DoFs. No spatial AD anywhere — K and F carry all the geometry."""
    n = node_coords.shape[0]
    u = models.siren_apply(flat, node_coords, layers, w0)[:, 0] * mask
    r = (spmv(kvals, rows, cols, u, n) - fvec) * mask
    return jnp.sum(r * r) / jnp.sum(mask)


# --- Data-driven / finite-difference baselines (Fig 4) --------------------------


def supervised_loss(flat, node_coords, u_ref, layers, w0=30.0):
    """Plain MSE against a reference field."""
    u = models.siren_apply(flat, node_coords, layers, w0)[:, 0]
    return jnp.mean((u - u_ref) ** 2)


def fd_loss(flat, node_coords, grid_n, kfreq, layers, w0=30.0, lam_bc=LAMBDA_BC):
    """5-point finite-difference residual on a regular (grid_n+1)² grid —
    the stencil baseline in Fig 4 (only applicable to Cartesian grids)."""
    m = grid_n + 1
    h = 1.0 / grid_n
    u = models.siren_apply(flat, node_coords, layers, w0)[:, 0].reshape(m, m)
    lap = (
        u[:-2, 1:-1] + u[2:, 1:-1] + u[1:-1, :-2] + u[1:-1, 2:] - 4.0 * u[1:-1, 1:-1]
    ) / (h * h)
    f = checkerboard(node_coords, kfreq).reshape(m, m)[1:-1, 1:-1]
    interior = jnp.mean((lap + f) ** 2)
    edge = (
        jnp.sum(u[0, :] ** 2)
        + jnp.sum(u[-1, :] ** 2)
        + jnp.sum(u[1:-1, 0] ** 2)
        + jnp.sum(u[1:-1, -1] ** 2)
    ) / (4.0 * grid_n)
    return interior + lam_bc * edge
