"""Python mirror of the Rust mesh generators.

The model/operator-learning artifacts bake mesh *shapes* (node counts,
element counts, CSR nnz) at lowering time, so python must generate the
exact same topology as `rust/src/mesh/structured.rs` — same node ordering
(row-major `j·(nx+1)+i`), same alternating-diagonal split, same L-shape
filtering, same circle mapping. `python/tests/test_meshes.py` checks the
invariants; the Rust integration tests validate shape agreement through the
manifest.
"""

from __future__ import annotations

import numpy as np


def rect_tri(nx: int, ny: int, lx: float = 1.0, ly: float = 1.0):
    """Triangulated rectangle — mirrors `structured::rect_tri`."""
    xs = np.linspace(0.0, lx, nx + 1)
    ys = np.linspace(0.0, ly, ny + 1)
    pts = np.stack(np.meshgrid(xs, ys, indexing="xy"), axis=-1).reshape(-1, 2)

    def nid(i, j):
        return j * (nx + 1) + i

    cells = []
    for j in range(ny):
        for i in range(nx):
            a, b = nid(i, j), nid(i + 1, j)
            c, d = nid(i + 1, j + 1), nid(i, j + 1)
            if (i + j) % 2 == 0:
                cells += [[a, b, c], [a, c, d]]
            else:
                cells += [[a, b, d], [b, c, d]]
    return pts.astype(np.float64), np.array(cells, dtype=np.int64)


def unit_square_tri(n: int):
    return rect_tri(n, n, 1.0, 1.0)


def boundary_nodes(points: np.ndarray, cells: np.ndarray) -> np.ndarray:
    """Nodes on boundary edges (edges incident to exactly one cell)."""
    from collections import Counter

    edges = Counter()
    for tri in cells:
        for a, b in ((0, 1), (1, 2), (2, 0)):
            key = tuple(sorted((int(tri[a]), int(tri[b]))))
            edges[key] += 1
    nodes = set()
    for (a, b), count in edges.items():
        if count == 1:
            nodes.add(a)
            nodes.add(b)
    return np.array(sorted(nodes), dtype=np.int64)


def lshape_tri(n: int):
    """L-shape [0,1]² \\ (0.5,1]² — mirrors `structured::lshape_tri`
    including the remove-unused-nodes compaction order."""
    pts, cells = unit_square_tri(n)
    keep = []
    for tri in cells:
        c = pts[tri].mean(axis=0)
        if not (c[0] > 0.5 and c[1] > 0.5):
            keep.append(tri)
    cells = np.array(keep, dtype=np.int64)
    used = np.zeros(len(pts), dtype=bool)
    used[cells.reshape(-1)] = True
    remap = -np.ones(len(pts), dtype=np.int64)
    remap[used] = np.arange(used.sum())
    return pts[used], remap[cells]


def circle_tri(n: int, cx: float = 0.5, cy: float = 0.5, r: float = 0.5):
    """Disk via the elliptical square→disk map — mirrors `curved::circle_tri`."""
    pts, cells = unit_square_tri(n)
    x = 2.0 * pts[:, 0] - 1.0
    y = 2.0 * pts[:, 1] - 1.0
    u = x * np.sqrt(1.0 - 0.5 * y * y)
    v = y * np.sqrt(1.0 - 0.5 * x * x)
    mapped = np.stack([cx + r * u, cy + r * v], axis=1)
    return mapped, cells


def csr_pattern(n_nodes: int, cells: np.ndarray):
    """Symbolic CSR pattern of the Galerkin matrix (sorted unique columns
    per row) — mirrors `Routing::build`'s pattern. Returns (rows, cols) COO
    arrays sorted row-major, suitable for jnp segment_sum."""
    adj = [set() for _ in range(n_nodes)]
    for tri in cells:
        for a in tri:
            for b in tri:
                adj[int(a)].add(int(b))
    rows, cols = [], []
    for i in range(n_nodes):
        for j in sorted(adj[i]):
            rows.append(i)
            cols.append(j)
    return np.array(rows, dtype=np.int32), np.array(cols, dtype=np.int32)
