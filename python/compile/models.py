"""Layer-2 neural models in pure JAX (SIREN, MLP, GraphSAGE-style GNN,
DeepONet) with flat-parameter-vector calling conventions.

All models take a single flat f32 parameter vector so Rust optimizers
(Adam / L-BFGS / MMA live in `rust/src/pils/`) can treat the AOT artifact
as a black-box `params → (loss, grad)` function. `param_spec` functions
return the static layout used to unflatten inside the traced function.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

# --- Flat parameter utilities ------------------------------------------------


def spec_size(spec) -> int:
    return int(sum(int(np.prod(shape)) for shape in spec))


def unflatten(flat, spec):
    """Split a flat vector into arrays with the shapes listed in `spec`."""
    out = []
    off = 0
    for shape in spec:
        n = int(np.prod(shape))
        out.append(flat[off : off + n].reshape(shape))
        off += n
    return out


# --- SIREN (Sitzmann et al. 2020) --------------------------------------------


def siren_spec(layers):
    """Parameter spec for a SIREN MLP with the given layer widths."""
    spec = []
    for din, dout in zip(layers[:-1], layers[1:]):
        spec.append((din, dout))
        spec.append((dout,))
    return spec


def siren_init(rng: np.random.Generator, layers, w0: float = 30.0) -> np.ndarray:
    """Flat f32 init following the SIREN scheme: first layer U(−1/d, 1/d),
    later layers U(−√(6/d)/w0, √(6/d)/w0)."""
    flats = []
    for li, (din, dout) in enumerate(zip(layers[:-1], layers[1:])):
        if li == 0:
            bound = 1.0 / din
        else:
            bound = np.sqrt(6.0 / din) / w0
        w = rng.uniform(-bound, bound, (din, dout))
        b = rng.uniform(-bound, bound, (dout,))
        flats += [w.reshape(-1), b]
    return np.concatenate(flats).astype(np.float32)


def siren_apply(flat, x, layers, w0: float = 30.0):
    """SIREN forward: x (..., din) → (..., dout). Sine activations with the
    ω0 frequency on every hidden layer (Eq. B.11-13)."""
    params = unflatten(flat, siren_spec(layers))
    h = x
    n_layers = len(layers) - 1
    for li in range(n_layers):
        w, b = params[2 * li], params[2 * li + 1]
        h = h @ w + b
        if li < n_layers - 1:
            h = jnp.sin(w0 * h)
    return h


# --- Plain MLP (tanh) — PI-DeepONet branch/trunk -----------------------------


def mlp_spec(layers):
    return siren_spec(layers)


def mlp_init(rng: np.random.Generator, layers) -> np.ndarray:
    """Glorot-uniform init."""
    flats = []
    for din, dout in zip(layers[:-1], layers[1:]):
        bound = np.sqrt(6.0 / (din + dout))
        flats += [rng.uniform(-bound, bound, (din, dout)).reshape(-1), np.zeros(dout)]
    return np.concatenate(flats).astype(np.float32)


def mlp_apply(flat, x, layers):
    params = unflatten(flat, mlp_spec(layers))
    h = x
    n_layers = len(layers) - 1
    for li in range(n_layers):
        w, b = params[2 * li], params[2 * li + 1]
        h = h @ w + b
        if li < n_layers - 1:
            h = jnp.tanh(h)
    return h


# --- AGN: encoder / GraphSAGE processor / decoder (§B.3.2) --------------------


def agn_spec(in_dim, hidden, out_dim, n_mp, kfreq):
    """Spec: frequency-enhanced encoder MLP, `n_mp` GraphSAGE layers
    (self + neighbor weights), decoder MLP."""
    enc_in = (in_dim) * (1 + 2 * kfreq) + 2  # features ⊕ sin/cos ladder ⊕ xy
    spec = []
    spec += [(enc_in, hidden), (hidden,)]
    for _ in range(n_mp):
        spec += [(hidden, hidden), (hidden, hidden), (hidden,)]  # W_self, W_neigh, b
    spec += [(hidden, hidden), (hidden,), (hidden, out_dim), (out_dim,)]
    return spec


def agn_init(rng: np.random.Generator, in_dim, hidden, out_dim, n_mp, kfreq) -> np.ndarray:
    flats = []
    for shape in agn_spec(in_dim, hidden, out_dim, n_mp, kfreq):
        if len(shape) == 2:
            bound = np.sqrt(6.0 / (shape[0] + shape[1]))
            flats.append(rng.uniform(-bound, bound, shape).reshape(-1))
        else:
            flats.append(np.zeros(shape))
    return np.concatenate(flats).astype(np.float32)


def frequency_features(x, kfreq):
    """Eq. (B.20): X ⊕ sin(X/K)…sin(KX) ⊕ cos ladder."""
    feats = [x]
    for k in range(1, kfreq + 1):
        feats += [jnp.sin(k * x), jnp.cos(k * x)]
    return jnp.concatenate(feats, axis=-1)


def agn_apply(flat, node_feats, coords, edge_src, edge_dst, deg_inv, cfg):
    """AGN forward.

    node_feats (N, in_dim) — the window of previous states;
    coords (N, 2); edge_src/edge_dst (Eg,) int32 directed edges;
    deg_inv (N,) 1/in-degree. Returns (N, out_dim) bundled updates.
    """
    in_dim, hidden, out_dim, n_mp, kfreq = (
        cfg["in_dim"],
        cfg["hidden"],
        cfg["out_dim"],
        cfg["n_mp"],
        cfg["kfreq"],
    )
    params = unflatten(flat, agn_spec(in_dim, hidden, out_dim, n_mp, kfreq))
    i = 0

    def take(n):
        nonlocal i
        out = params[i : i + n]
        i += n
        return out

    (w_enc, b_enc) = take(2)
    h = jnp.concatenate([frequency_features(node_feats, kfreq), coords], axis=-1)
    h = jnp.tanh(h @ w_enc + b_enc)
    n = h.shape[0]
    for _ in range(n_mp):
        (w_self, w_neigh, b) = take(3)
        gathered = h[edge_src]  # (Eg, hidden)
        agg = jax.ops.segment_sum(gathered, edge_dst, num_segments=n) * deg_inv[:, None]
        h = jnp.tanh(h @ w_self + agg @ w_neigh + b)
    (w_d1, b_d1, w_d2, b_d2) = take(4)
    h = jnp.tanh(h @ w_d1 + b_d1)
    return h @ w_d2 + b_d2


# --- DeepONet ------------------------------------------------------------------


def deeponet_spec(n_sensors, coord_dim, hidden, n_layers, latent):
    branch_layers = [n_sensors] + [hidden] * (n_layers - 1) + [latent]
    trunk_layers = [coord_dim] + [hidden] * (n_layers - 1) + [latent]
    return mlp_spec(branch_layers) + mlp_spec(trunk_layers) + [(1,)]


def deeponet_init(rng, n_sensors, coord_dim, hidden, n_layers, latent):
    branch_layers = [n_sensors] + [hidden] * (n_layers - 1) + [latent]
    trunk_layers = [coord_dim] + [hidden] * (n_layers - 1) + [latent]
    return np.concatenate(
        [mlp_init(rng, branch_layers), mlp_init(rng, trunk_layers), np.zeros(1, np.float32)]
    ).astype(np.float32)


def deeponet_apply(flat, sensors, coords, cfg):
    """u(y) = Σ_l branch_l(sensors)·trunk_l(y) + bias.

    sensors (n_sensors,) — IC samples; coords (M, coord_dim) — query points.
    """
    n_sensors, coord_dim, hidden, n_layers, latent = (
        cfg["n_sensors"],
        cfg["coord_dim"],
        cfg["hidden"],
        cfg["n_layers"],
        cfg["latent"],
    )
    branch_layers = [n_sensors] + [hidden] * (n_layers - 1) + [latent]
    trunk_layers = [coord_dim] + [hidden] * (n_layers - 1) + [latent]
    nb = spec_size(mlp_spec(branch_layers))
    nt = spec_size(mlp_spec(trunk_layers))
    b_flat, t_flat, bias = flat[:nb], flat[nb : nb + nt], flat[nb + nt]
    branch = mlp_apply(b_flat, sensors[None, :], branch_layers)[0]  # (latent,)
    trunk = mlp_apply(t_flat, coords, trunk_layers)  # (M, latent)
    return trunk @ branch + bias
