"""AOT lowering of the neural-solver artifacts (Table 1, Fig 4, Fig B.12).

Every artifact is a single fused HLO program `params (+ static mesh data as
runtime inputs) → (loss, grad)` — AD happens at *trace* time, so the
runtime graph has O(1) nodes per optimizer step regardless of mesh size or
network depth, which is exactly the property Table 1 / Fig 4 measure.

Shapes baked at lowering: mesh node/element counts and the Galerkin CSR
nnz, all mirrored from the Rust generators via `meshes.py`.
"""

from __future__ import annotations

import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from . import losses, meshes, models

#: SIREN backbone shared by all Table-1 methods (§B.2.2): 4 hidden × 64.
LAYERS = [2, 64, 64, 64, 64, 1]
W0 = 30.0

#: Table-1 mesh: structured unit square (paper: 3,017-node unstructured
#: mesh; scaled for the 1-core CPU testbed — all methods share it).
TABLE1_N = 32

#: Fig-4 DoF sweep grids ((n+1)² DoFs each).
FIG4_SIZES = [8, 16, 32, 64]

#: Eval bucket for `siren_eval` (points padded to this count).
EVAL_M = 4096


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _lower(out_dir, name, fn, args, meta):
    from .aot import to_hlo_text

    arg_structs = [s for (_, s) in args]
    lowered = jax.jit(fn).lower(*arg_structs)
    (out_dir / f"{name}.hlo.txt").write_text(to_hlo_text(lowered))
    print(f"  lowered {name}", flush=True)
    return {
        "file": f"{name}.hlo.txt",
        "inputs": [
            {"name": n, "shape": list(s.shape), "dtype": str(s.dtype)} for (n, s) in args
        ],
        "outputs": [
            {"shape": list(s.shape), "dtype": str(s.dtype)}
            for s in jax.tree.leaves(jax.eval_shape(fn, *arg_structs))
        ],
        **meta,
    }


def _mesh_tables(n):
    pts, cells = meshes.unit_square_tri(n)
    bnodes = meshes.boundary_nodes(pts, cells)
    mask = np.ones(len(pts), np.float32)
    mask[bnodes] = 0.0
    rows, cols = meshes.csr_pattern(len(pts), cells)
    return pts, cells, mask, rows, cols


def build_model_artifacts(out_dir: pathlib.Path) -> dict:
    artifacts = {}
    p = models.spec_size(models.siren_spec(LAYERS))

    # --- Initial parameter blobs (4 seeds) ---------------------------------
    for seed in range(4):
        rng = np.random.default_rng(seed)
        flat = models.siren_init(rng, LAYERS, W0)
        fname = f"siren_init_s{seed}.bin"
        (out_dir / fname).write_bytes(flat.tobytes())
        artifacts[f"siren_init_s{seed}"] = {
            "file": fname,
            "inputs": [],
            "outputs": [],
            "kind": "siren_init",
            "param_count": p,
            "seed": seed,
        }

    # --- Table 1: loss_and_grad per method ---------------------------------
    pts, cells, mask, rows, cols = _mesh_tables(TABLE1_N)
    n = len(pts)
    e = len(cells)
    nnz = len(rows)
    mesh_meta = {"mesh_n": TABLE1_N, "n_nodes": n, "n_elems": e, "nnz": nnz, "param_count": p}

    def pinn_lg(params, coords, msk, kfreq):
        return jax.value_and_grad(
            lambda q: losses.pinn_loss(q, coords, msk, kfreq, LAYERS, W0)
        )(params)

    artifacts["table1_pinn"] = _lower(
        out_dir,
        "table1_pinn",
        pinn_lg,
        [("params", f32(p)), ("coords", f32(n, 2)), ("mask", f32(n)), ("kfreq", f32())],
        {"kind": "table1_loss_grad", "method": "pinn", **mesh_meta},
    )

    def vpinn_lg(params, cell_coords, cell_idx, node_coords, msk, kfreq):
        return jax.value_and_grad(
            lambda q: losses.vpinn_loss_with_bc(
                q, cell_coords, cell_idx, node_coords, msk, kfreq, LAYERS, W0
            )
        )(params)

    artifacts["table1_vpinn"] = _lower(
        out_dir,
        "table1_vpinn",
        vpinn_lg,
        [
            ("params", f32(p)),
            ("cell_coords", f32(e, 3, 2)),
            ("cells", i32(e, 3)),
            ("node_coords", f32(n, 2)),
            ("mask", f32(n)),
            ("kfreq", f32()),
        ],
        {"kind": "table1_loss_grad", "method": "vpinn", **mesh_meta},
    )

    def ritz_lg(params, cell_coords, node_coords, msk, kfreq):
        return jax.value_and_grad(
            lambda q: losses.deep_ritz_loss(q, cell_coords, node_coords, msk, kfreq, LAYERS, W0)
        )(params)

    artifacts["table1_deepritz"] = _lower(
        out_dir,
        "table1_deepritz",
        ritz_lg,
        [
            ("params", f32(p)),
            ("cell_coords", f32(e, 3, 2)),
            ("node_coords", f32(n, 2)),
            ("mask", f32(n)),
            ("kfreq", f32()),
        ],
        {"kind": "table1_loss_grad", "method": "deepritz", **mesh_meta},
    )

    def pils_lg(params, node_coords, msk, kvals, r_idx, c_idx, fvec):
        return jax.value_and_grad(
            lambda q: losses.pils_loss(q, node_coords, msk, kvals, r_idx, c_idx, fvec, LAYERS, W0)
        )(params)

    artifacts["table1_pils"] = _lower(
        out_dir,
        "table1_pils",
        pils_lg,
        [
            ("params", f32(p)),
            ("node_coords", f32(n, 2)),
            ("mask", f32(n)),
            ("kvals", f32(nnz)),
            ("rows", i32(nnz)),
            ("cols", i32(nnz)),
            ("fvec", f32(n)),
        ],
        {"kind": "table1_loss_grad", "method": "pils", **mesh_meta},
    )

    # --- SIREN forward evaluation (error metrics, field dumps) --------------
    def eval_fn(params, points):
        return (models.siren_apply(params, points, LAYERS, W0)[:, 0],)

    artifacts["siren_eval"] = _lower(
        out_dir,
        "siren_eval",
        eval_fn,
        [("params", f32(p)), ("points", f32(EVAL_M, 2))],
        {"kind": "siren_eval", "bucket": EVAL_M, "param_count": p},
    )

    # --- Fig 4 / B.12: loss-eval cost vs DoF --------------------------------
    for gn in FIG4_SIZES:
        pts_g, cells_g, mask_g, rows_g, cols_g = _mesh_tables(gn)
        ng, eg, nnzg = len(pts_g), len(cells_g), len(rows_g)
        meta = {"mesh_n": gn, "n_nodes": ng, "n_elems": eg, "nnz": nnzg, "param_count": p}

        def pinn_fwd(params, coords, msk, kfreq):
            return (losses.pinn_loss(params, coords, msk, kfreq, LAYERS, W0),)

        def pinn_grad(params, coords, msk, kfreq):
            return jax.value_and_grad(
                lambda q: losses.pinn_loss(q, coords, msk, kfreq, LAYERS, W0)
            )(params)

        for tag, fn in [("fwd", pinn_fwd), ("grad", pinn_grad)]:
            artifacts[f"fig4_pinn_{tag}_n{gn}"] = _lower(
                out_dir,
                f"fig4_pinn_{tag}_n{gn}",
                fn,
                [("params", f32(p)), ("coords", f32(ng, 2)), ("mask", f32(ng)), ("kfreq", f32())],
                {"kind": f"fig4_pinn_{tag}", **meta},
            )

        def pils_fwd(params, node_coords, msk, kvals, r_idx, c_idx, fvec):
            return (
                losses.pils_loss(params, node_coords, msk, kvals, r_idx, c_idx, fvec, LAYERS, W0),
            )

        def pils_grad(params, node_coords, msk, kvals, r_idx, c_idx, fvec):
            return jax.value_and_grad(
                lambda q: losses.pils_loss(
                    q, node_coords, msk, kvals, r_idx, c_idx, fvec, LAYERS, W0
                )
            )(params)

        pils_args = [
            ("params", f32(p)),
            ("node_coords", f32(ng, 2)),
            ("mask", f32(ng)),
            ("kvals", f32(nnzg)),
            ("rows", i32(nnzg)),
            ("cols", i32(nnzg)),
            ("fvec", f32(ng)),
        ]
        for tag, fn in [("fwd", pils_fwd), ("grad", pils_grad)]:
            artifacts[f"fig4_pils_{tag}_n{gn}"] = _lower(
                out_dir, f"fig4_pils_{tag}_n{gn}", fn, pils_args, {"kind": f"fig4_pils_{tag}", **meta}
            )

        def sup_fwd(params, node_coords, u_ref):
            return (losses.supervised_loss(params, node_coords, u_ref, LAYERS, W0),)

        def sup_grad(params, node_coords, u_ref):
            return jax.value_and_grad(
                lambda q: losses.supervised_loss(q, node_coords, u_ref, LAYERS, W0)
            )(params)

        sup_args = [("params", f32(p)), ("node_coords", f32(ng, 2)), ("u_ref", f32(ng))]
        for tag, fn in [("fwd", sup_fwd), ("grad", sup_grad)]:
            artifacts[f"fig4_supervised_{tag}_n{gn}"] = _lower(
                out_dir,
                f"fig4_supervised_{tag}_n{gn}",
                fn,
                sup_args,
                {"kind": f"fig4_supervised_{tag}", **meta},
            )

        def fd_fwd(params, node_coords, kfreq, _gn=gn):
            return (losses.fd_loss(params, node_coords, _gn, kfreq, LAYERS, W0),)

        artifacts[f"fig4_fd_fwd_n{gn}"] = _lower(
            out_dir,
            f"fig4_fd_fwd_n{gn}",
            fd_fwd,
            [("params", f32(p)), ("node_coords", f32(ng, 2)), ("kfreq", f32())],
            {"kind": "fig4_fd_fwd", **meta},
        )

    return artifacts
