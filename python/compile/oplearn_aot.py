"""AOT artifacts for physics-informed operator learning (Table 2, §B.3).

Three paradigms over the same AGN backbone (wave on a circle, Allen-Cahn
on an L-shape):

* TensorPILS   — Galerkin-residual training (Eqs. B.17 / B.19): rollout the
  AGN inside `lax.scan`, assemble the per-step discrete residual with the
  pre-assembled sparse `M`, `K` (and, for AC, the nonlinear reaction load
  via element quadrature) — no spatial autodiff anywhere.
* Data-driven  — same AGN, MSE against the FEM reference trajectory.
* PI-DeepONet  — branch(IC) ⊗ trunk(x,y,t) with a strong-form AD residual.

The rollout length (ID segment) and mesh sizes are scaled for the 1-core
CPU testbed; ID/OOD evaluation uses `*_rollout` artifacts with twice the
training horizon (first half = ID, second half = OOD), matching §B.3.3.
"""

from __future__ import annotations

import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from . import losses, meshes, models

# --- Configuration (shapes baked at lowering, mirrored by Rust) -------------

WAVE_N = 12  #: circle mesh resolution (2·N² elements)
AC_N = 12  #: L-shape resolution
ROLLOUT_T = 24  #: training horizon (ID); eval horizon = 2·ROLLOUT_T
WAVE_DT = 5e-3  # scaled CFL (paper: 5e-4 with 200 steps; same physical horizon)
WAVE_C2 = 4.0 * 4.0  # c = 4 (Eq. B.14 setup)
AC_DT = 2e-3
AC_A2 = 1e-2
AC_EPS2 = 1.0

AGN_CFG = {"in_dim": 2, "hidden": 32, "out_dim": 1, "n_mp": 3, "kfreq": 4}

DON_CFG = {"coord_dim": 3, "hidden": 64, "n_layers": 4, "latent": 32}


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def element_edges(cells: np.ndarray) -> np.ndarray:
    """Directed edges of the element graph (§B.3.2: nodes of each element
    fully connected), deduplicated, sorted — shape (Eg, 2)."""
    pairs = set()
    for tri in cells:
        for a in tri:
            for b in tri:
                if a != b:
                    pairs.add((int(a), int(b)))
    return np.array(sorted(pairs), dtype=np.int32)


def _mesh_pack(kind: str):
    if kind == "wave":
        pts, cells = meshes.circle_tri(WAVE_N, 0.5, 0.5, 0.5)
    else:
        pts, cells = meshes.lshape_tri(AC_N)
    bnodes = meshes.boundary_nodes(pts, cells)
    mask = np.ones(len(pts), np.float32)
    mask[bnodes] = 0.0
    rows, cols = meshes.csr_pattern(len(pts), cells)
    edges = element_edges(cells)
    deg = np.zeros(len(pts), np.float64)
    for _, dst in edges:
        deg[dst] += 1.0
    deg_inv = (1.0 / np.maximum(deg, 1.0)).astype(np.float32)
    return pts, cells, mask, rows, cols, edges, deg_inv


def agn_step_factory(scheme):
    """One AGN update: window (N,2) of [U^{k-1}, U^k] → U^{k+1} (masked).

    `scheme` fixes the integration inductive bias: "central" (hyperbolic:
    2U^k − U^{k-1} + δ, the Eq. B.16 extrapolation) or "euler" (parabolic:
    U^k + δ). The network predicts the correction δ in both cases.
    """

    def step(params, window, coords, edge_src, edge_dst, deg_inv, mask):
        delta = models.agn_apply(params, window, coords, edge_src, edge_dst, deg_inv, AGN_CFG)[
            :, 0
        ]
        if scheme == "central":
            u_next = 2.0 * window[:, 1] - window[:, 0] + delta
        else:
            u_next = window[:, 1] + delta
        return u_next * mask

    return step


def rollout(params, u0, steps, coords, edge_src, edge_dst, deg_inv, mask, n, scheme):
    """Autoregressive rollout from (U⁰, U¹=U⁰): returns (steps+1, N)."""
    step = agn_step_factory(scheme)

    def body(carry, _):
        prev, curr = carry
        nxt = step(params, jnp.stack([prev, curr], axis=1), coords, edge_src, edge_dst, deg_inv, mask)
        return (curr, nxt), nxt

    (_, _), traj = jax.lax.scan(body, (u0, u0), None, length=steps)
    return jnp.concatenate([u0[None, :], traj], axis=0)


def wave_residual_loss(params, u0, coords, edge_src, edge_dst, deg_inv, mask, mvals, kvals, rows, cols, n):
    """Σ_k ‖M(U^{k+2}−2U^{k+1}+U^k)/Δt² + c²K U^{k+1}‖² (Eq. B.17)."""
    traj = rollout(params, u0, ROLLOUT_T, coords, edge_src, edge_dst, deg_inv, mask, n, "central")

    def spmv(vals, u):
        return losses.spmv(vals, rows, cols, u, n)

    # Residual rescaled by Δt² (same minimizer, gradients O(1)); the
    # recurrence alone leaves the initial velocity free, so the v⁰ = 0
    # condition enters as an explicit ‖U¹−U⁰‖² term (§B.3.3 zero-velocity
    # start).
    r_sum = 0.0
    dt2 = WAVE_DT * WAVE_DT
    for k in range(ROLLOUT_T - 1):
        acc = spmv(mvals, traj[k + 2] - 2.0 * traj[k + 1] + traj[k])
        acc = acc + dt2 * WAVE_C2 * spmv(kvals, traj[k + 1])
        r_sum = r_sum + jnp.sum((acc * mask) ** 2)
    v0_pen = jnp.sum(((traj[1] - traj[0]) * mask) ** 2)
    return r_sum / (ROLLOUT_T - 1) + v0_pen


def ac_reaction_load(u, cell_coords, cells, basis, weights, n):
    """F(U)_i = ∫ −ε² u(u²−1) φ_i via element quadrature + segment-sum."""
    from .kernels import ref
    from . import fem

    g, adet = ref._simplex_geometry(cell_coords, fem.GRAD_TRI)
    del g
    phi = jnp.asarray(basis, cell_coords.dtype)  # (Q,k)
    w = jnp.asarray(weights, cell_coords.dtype)
    u_cells = u[cells]  # (E,3)
    u_q = jnp.einsum("qa,ea->eq", phi, u_cells)
    f_q = -AC_EPS2 * u_q * (u_q * u_q - 1.0)
    f_local = adet[:, None] * jnp.einsum("eq,q,qa->ea", f_q, w, phi)
    return jax.ops.segment_sum(f_local.reshape(-1), cells.reshape(-1), num_segments=n)


def ac_residual_loss(
    params, u0, coords, edge_src, edge_dst, deg_inv, mask, mvals, kvals, rows, cols, cell_coords, cells, n
):
    """Σ_k ‖M(U^{k+1}−U^k)/Δt + a²K U^{k+1} − F(U^{k+1})‖² (Eq. B.19)."""
    from . import fem

    traj = rollout(params, u0, ROLLOUT_T, coords, edge_src, edge_dst, deg_inv, mask, n, "euler")
    basis = fem.p1_basis_tri(fem.TRI_QPOINTS)

    def spmv(vals, u):
        return losses.spmv(vals, rows, cols, u, n)

    # Residual rescaled by Δt (same minimizer, better conditioning).
    r_sum = 0.0
    for k in range(ROLLOUT_T):
        unew = traj[k + 1]
        acc = spmv(mvals, unew - traj[k]) + AC_DT * AC_A2 * spmv(kvals, unew)
        acc = acc - AC_DT * ac_reaction_load(unew, cell_coords, cells, basis, fem.TRI_QWEIGHTS, n)
        r_sum = r_sum + jnp.sum((acc * mask) ** 2)
    return r_sum / ROLLOUT_T


def datadriven_loss(params, u0, traj_ref, coords, edge_src, edge_dst, deg_inv, mask, n, scheme):
    """MSE against the FEM trajectory (Eq. B.21)."""
    traj = rollout(params, u0, ROLLOUT_T, coords, edge_src, edge_dst, deg_inv, mask, n, scheme)
    return jnp.mean((traj - traj_ref) ** 2)


# --- PI-DeepONet --------------------------------------------------------------


def deeponet_cfg(n_sensors):
    return {"n_sensors": n_sensors, **DON_CFG}


def pideeponet_wave_loss(params, sensors, colloc, ic_pts, ic_vals, bc_pts, n_sensors):
    """Strong-form residual ∂tt u − c²Δu at collocation (x,y,t) + IC + BC
    penalties (Eq. B.23), all via AD."""
    cfg = deeponet_cfg(n_sensors)

    def u_scalar(xyt):
        return models.deeponet_apply(params, sensors, xyt[None, :], cfg)[0]

    def residual(xyt):
        h = jax.hessian(u_scalar)(xyt)
        return h[2, 2] - WAVE_C2 * (h[0, 0] + h[1, 1])

    r = jax.vmap(residual)(colloc)
    u_ic = jax.vmap(u_scalar)(ic_pts)
    du_ic = jax.vmap(lambda p: jax.grad(u_scalar)(p)[2])(ic_pts)
    u_bc = jax.vmap(u_scalar)(bc_pts)
    return (
        jnp.mean(r**2)
        + 100.0 * jnp.mean((u_ic - ic_vals) ** 2)
        + 100.0 * jnp.mean(du_ic**2)
        + 100.0 * jnp.mean(u_bc**2)
    )


def build_oplearn_artifacts(out_dir: pathlib.Path) -> dict:
    from .aot import to_hlo_text

    artifacts = {}

    def lower(name, fn, args, meta):
        arg_structs = [s for (_, s) in args]
        lowered = jax.jit(fn).lower(*arg_structs)
        (out_dir / f"{name}.hlo.txt").write_text(to_hlo_text(lowered))
        print(f"  lowered {name}", flush=True)
        artifacts[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"name": nm, "shape": list(s.shape), "dtype": str(s.dtype)} for (nm, s) in args
            ],
            "outputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)}
                for s in jax.tree.leaves(jax.eval_shape(fn, *arg_structs))
            ],
            **meta,
        }

    for kind in ["wave", "ac"]:
        pts, cells, mask, rows, cols, edges, deg_inv = _mesh_pack(kind)
        n, e, nnz, eg = len(pts), len(cells), len(rows), len(edges)
        p = models.spec_size(
            models.agn_spec(
                AGN_CFG["in_dim"], AGN_CFG["hidden"], AGN_CFG["out_dim"], AGN_CFG["n_mp"], AGN_CFG["kfreq"]
            )
        )
        meta = {
            "mesh_n": WAVE_N if kind == "wave" else AC_N,
            "n_nodes": n,
            "n_elems": e,
            "nnz": nnz,
            "n_edges": eg,
            "rollout_t": ROLLOUT_T,
            "param_count": p,
            "dt": WAVE_DT if kind == "wave" else AC_DT,
        }

        # Init blobs (2 seeds).
        for seed in range(2):
            rng = np.random.default_rng(100 + seed)
            flat = models.agn_init(
                rng, AGN_CFG["in_dim"], AGN_CFG["hidden"], AGN_CFG["out_dim"], AGN_CFG["n_mp"], AGN_CFG["kfreq"]
            )
            fname = f"agn_init_{kind}_s{seed}.bin"
            (out_dir / fname).write_bytes(flat.tobytes())
            artifacts[f"agn_init_{kind}_s{seed}"] = {
                "file": fname,
                "inputs": [],
                "outputs": [],
                "kind": "agn_init",
                "param_count": p,
                "seed": seed,
            }

        common = [
            ("params", f32(p)),
            ("u0", f32(n)),
            ("coords", f32(n, 2)),
            ("edge_src", i32(eg)),
            ("edge_dst", i32(eg)),
            ("deg_inv", f32(n)),
            ("mask", f32(n)),
        ]
        sparse_args = [
            ("mvals", f32(nnz)),
            ("kvals", f32(nnz)),
            ("rows", i32(nnz)),
            ("cols", i32(nnz)),
        ]

        if kind == "wave":

            def wave_lg(params, u0, coords, es, ed, di, msk, mv, kv, r_, c_):
                return jax.value_and_grad(
                    lambda q: wave_residual_loss(q, u0, coords, es, ed, di, msk, mv, kv, r_, c_, n)
                )(params)

            lower("oplearn_wave_pils", wave_lg, common + sparse_args, {"kind": "oplearn_loss", "pde": "wave", "method": "pils", **meta})
        else:
            cell_args = [("cell_coords", f32(e, 3, 2)), ("cells", i32(e, 3))]

            def ac_lg(params, u0, coords, es, ed, di, msk, mv, kv, r_, c_, cc, ci):
                return jax.value_and_grad(
                    lambda q: ac_residual_loss(q, u0, coords, es, ed, di, msk, mv, kv, r_, c_, cc, ci, n)
                )(params)

            lower("oplearn_ac_pils", ac_lg, common + sparse_args + cell_args, {"kind": "oplearn_loss", "pde": "ac", "method": "pils", **meta})

        scheme = "central" if kind == "wave" else "euler"

        def dd_lg(params, u0, traj_ref, coords, es, ed, di, msk, _s=scheme):
            return jax.value_and_grad(
                lambda q: datadriven_loss(q, u0, traj_ref, coords, es, ed, di, msk, n, _s)
            )(params)

        dd_args = [
            ("params", f32(p)),
            ("u0", f32(n)),
            ("traj_ref", f32(ROLLOUT_T + 1, n)),
            ("coords", f32(n, 2)),
            ("edge_src", i32(eg)),
            ("edge_dst", i32(eg)),
            ("deg_inv", f32(n)),
            ("mask", f32(n)),
        ]
        lower(f"oplearn_{kind}_datadriven", dd_lg, dd_args, {"kind": "oplearn_loss", "pde": kind, "method": "datadriven", **meta})

        # Rollout artifact at 2× horizon for ID/OOD eval.
        def roll2(params, u0, coords, es, ed, di, msk, _s=scheme):
            return (rollout(params, u0, 2 * ROLLOUT_T, coords, es, ed, di, msk, n, _s),)

        lower(f"oplearn_{kind}_rollout", roll2, common, {"kind": "oplearn_rollout", "pde": kind, **meta})

    # --- PI-DeepONet (wave only, per Table 2's worst-case story) -----------
    pts, cells, mask, *_ = _mesh_pack("wave")
    n = len(pts)
    t_max = 2 * ROLLOUT_T * WAVE_DT
    m_col = 512
    m_ic = n
    m_bc = 128
    pdon = models.spec_size(
        models.deeponet_spec(n, DON_CFG["coord_dim"], DON_CFG["hidden"], DON_CFG["n_layers"], DON_CFG["latent"])
    )
    rng = np.random.default_rng(7)
    flat = models.deeponet_init(rng, n, DON_CFG["coord_dim"], DON_CFG["hidden"], DON_CFG["n_layers"], DON_CFG["latent"])
    (out_dir / "deeponet_init_wave.bin").write_bytes(flat.tobytes())
    artifacts["deeponet_init_wave"] = {
        "file": "deeponet_init_wave.bin",
        "inputs": [],
        "outputs": [],
        "kind": "deeponet_init",
        "param_count": pdon,
    }

    def don_lg(params, sensors, colloc, ic_pts, ic_vals, bc_pts):
        return jax.value_and_grad(
            lambda q: pideeponet_wave_loss(q, sensors, colloc, ic_pts, ic_vals, bc_pts, n)
        )(params)

    lower(
        "oplearn_wave_pideeponet",
        don_lg,
        [
            ("params", f32(pdon)),
            ("sensors", f32(n)),
            ("colloc", f32(m_col, 3)),
            ("ic_pts", f32(m_ic, 3)),
            ("ic_vals", f32(m_ic)),
            ("bc_pts", f32(m_bc, 3)),
        ],
        {
            "kind": "oplearn_loss",
            "pde": "wave",
            "method": "pideeponet",
            "param_count": pdon,
            "n_nodes": n,
            "m_col": m_col,
            "m_bc": m_bc,
            "t_max": t_max,
            "rollout_t": ROLLOUT_T,
            "dt": WAVE_DT,
        },
    )

    def don_eval(params, sensors, query):
        cfg = deeponet_cfg(n)
        return (models.deeponet_apply(params, sensors, query, cfg),)

    lower(
        "oplearn_wave_pideeponet_eval",
        don_eval,
        [("params", f32(pdon)), ("sensors", f32(n)), ("query", f32(n, 3))],
        {"kind": "oplearn_eval", "pde": "wave", "method": "pideeponet", "param_count": pdon, "n_nodes": n},
    )

    return artifacts
