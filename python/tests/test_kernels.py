"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps element counts / geometry jitter / dtypes; every kernel
must match `ref.py` to float tolerance. This is the CORE correctness signal
for the Map stage (the Rust integration tests then validate the PJRT
round-trip against the Rust native implementation).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import local_assembly as ker
from compile.kernels import ref


def rng(seed):
    return np.random.default_rng(seed)


def assert_close(a, b, rtol=2e-5, atol=2e-6):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


BLOCK = 16  # small block → several grid steps even for small E


@settings(max_examples=15, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_poisson2d_matches_ref(blocks, seed):
    n = blocks * BLOCK
    r = rng(seed)
    coords = ref.random_valid_simplices(r, n, 3, 2)
    rho = r.uniform(0.5, 2.0, (n, 3)).astype(np.float32)
    out = ker.poisson2d(coords, rho, block=BLOCK)
    expect = ref.poisson2d(coords, rho)
    assert_close(out, expect)


@settings(max_examples=15, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_poisson3d_matches_ref(blocks, seed):
    n = blocks * BLOCK
    r = rng(seed)
    coords = ref.random_valid_simplices(r, n, 4, 3)
    rho = r.uniform(0.5, 2.0, (n, 4)).astype(np.float32)
    out = ker.poisson3d(coords, rho, block=BLOCK)
    expect = ref.poisson3d(coords, rho)
    assert_close(out, expect)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31))
def test_loads_match_ref(seed):
    r = rng(seed)
    n = 2 * BLOCK
    c2 = ref.random_valid_simplices(r, n, 3, 2)
    f2 = r.standard_normal((n, 3)).astype(np.float32)
    assert_close(ker.load2d(c2, f2, block=BLOCK), ref.load2d(c2, f2))
    c3 = ref.random_valid_simplices(r, n, 4, 3)
    f3 = r.standard_normal((n, 4)).astype(np.float32)
    assert_close(ker.load3d(c3, f3, block=BLOCK), ref.load3d(c3, f3))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31))
def test_masses_match_ref(seed):
    r = rng(seed)
    n = 2 * BLOCK
    c2 = ref.random_valid_simplices(r, n, 3, 2)
    rho2 = r.uniform(0.5, 2.0, (n, 3)).astype(np.float32)
    assert_close(ker.mass2d(c2, rho2, block=BLOCK), ref.mass2d(c2, rho2))
    c3 = ref.random_valid_simplices(r, n, 4, 3)
    rho3 = r.uniform(0.5, 2.0, (n, 4)).astype(np.float32)
    assert_close(ker.mass3d(c3, rho3, block=BLOCK), ref.mass3d(c3, rho3))


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31),
    lam=st.floats(min_value=0.1, max_value=2.0),
    mu=st.floats(min_value=0.1, max_value=2.0),
)
def test_elasticity3d_matches_ref(seed, lam, mu):
    r = rng(seed)
    n = 2 * BLOCK
    coords = ref.random_valid_simplices(r, n, 4, 3)
    emod = r.uniform(0.5, 2.0, (n, 4)).astype(np.float32)
    out = ker.elasticity3d(coords, emod, lam, mu, block=BLOCK)
    expect = ref.elasticity3d(coords, emod, lam, mu)
    assert_close(out, expect, rtol=5e-5, atol=5e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31))
def test_elasticity_q4_matches_ref(seed):
    r = rng(seed)
    n = 2 * BLOCK
    # Valid quads: unit squares + small jitter, CCW ordering.
    base = np.array([[0, 0], [1, 0], [1, 1], [0, 1]], dtype=np.float64)
    coords = base[None] + 0.1 * r.standard_normal((n, 4, 2))
    coords = coords.astype(np.float32)
    emod = r.uniform(0.5, 2.0, (n, 4)).astype(np.float32)
    lam, mu = 0.577, 0.385
    out = ker.elasticity2d_q4(coords, emod, lam, mu, block=BLOCK)
    expect = ref.elasticity2d_q4(coords, emod, lam, mu)
    assert_close(out, expect, rtol=5e-5, atol=5e-6)


def test_degenerate_padding_elements_contribute_zero():
    """Bucket padding: zero-volume elements must produce exactly zero."""
    n = BLOCK
    r = rng(0)
    coords = ref.random_valid_simplices(r, n, 3, 2)
    coords[n // 2 :] = coords[n // 2 :, :1, :]  # collapse to a point
    rho = np.ones((n, 3), np.float32)
    out = np.asarray(ker.poisson2d(coords, rho, block=BLOCK))
    assert np.all(out[n // 2 :] == 0.0)
    f_out = np.asarray(ker.load2d(coords, rho, block=BLOCK))
    assert np.all(f_out[n // 2 :] == 0.0)


def test_stiffness_rows_sum_to_zero():
    """∇(Σφ)=0 ⇒ local stiffness row sums vanish (both layers agree)."""
    r = rng(3)
    coords = ref.random_valid_simplices(r, BLOCK, 4, 3)
    rho = np.ones((BLOCK, 4), np.float32)
    out = np.asarray(ker.poisson3d(coords, rho, block=BLOCK))
    np.testing.assert_allclose(out.sum(axis=2), 0.0, atol=1e-4)


def test_mass_total_equals_volume():
    """Σ_ab M_ab = |e| for ρ=1 (partition of unity, both axes)."""
    r = rng(4)
    coords = ref.random_valid_simplices(r, BLOCK, 3, 2)
    rho = np.ones((BLOCK, 3), np.float32)
    out = np.asarray(ker.mass2d(coords, rho, block=BLOCK))
    # Triangle area from the cross product.
    e1 = coords[:, 1] - coords[:, 0]
    e2 = coords[:, 2] - coords[:, 0]
    area = 0.5 * np.abs(e1[:, 0] * e2[:, 1] - e1[:, 1] * e2[:, 0])
    np.testing.assert_allclose(out.sum(axis=(1, 2)), area, rtol=1e-5)


def test_float64_path():
    """Kernels are dtype-generic (x64 used by build-time validation)."""
    import jax

    with jax.experimental.enable_x64():
        r = rng(9)
        coords = ref.random_valid_simplices(r, BLOCK, 3, 2, dtype=np.float64)
        rho = np.ones((BLOCK, 3), np.float64)
        out = ker.poisson2d(coords, rho, block=BLOCK)
        expect = ref.poisson2d(coords, rho)
        assert out.dtype == jnp.float64
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-12)


def test_block_size_must_divide():
    r = rng(1)
    coords = ref.random_valid_simplices(r, BLOCK + 1, 3, 2)
    rho = np.ones((BLOCK + 1, 3), np.float32)
    with pytest.raises(AssertionError):
        ker.poisson2d(coords, rho, block=BLOCK)
