"""Python mesh mirrors: invariants + shape agreement with the generators'
contracts (the Rust side asserts the same counts through the manifest)."""

import numpy as np

from compile import meshes


def tri_area(pts, tri):
    a, b, c = pts[tri[0]], pts[tri[1]], pts[tri[2]]
    return 0.5 * ((b[0] - a[0]) * (c[1] - a[1]) - (c[0] - a[0]) * (b[1] - a[1]))


def test_unit_square_counts_and_orientation():
    pts, cells = meshes.unit_square_tri(4)
    assert len(pts) == 25
    assert len(cells) == 32
    areas = [tri_area(pts, t) for t in cells]
    assert all(a > 0 for a in areas)
    assert abs(sum(areas) - 1.0) < 1e-12


def test_boundary_nodes_square():
    pts, cells = meshes.unit_square_tri(4)
    b = meshes.boundary_nodes(pts, cells)
    assert len(b) == 16
    for i in b:
        x, y = pts[i]
        assert min(x, y, 1 - x, 1 - y) < 1e-12


def test_lshape_area_and_compaction():
    pts, cells = meshes.lshape_tri(8)
    areas = [tri_area(pts, t) for t in cells]
    assert abs(sum(areas) - 0.75) < 1e-12
    assert cells.max() == len(pts) - 1  # compacted indices


def test_circle_inside_radius():
    pts, cells = meshes.circle_tri(12, 0.5, 0.5, 0.5)
    r = np.sqrt((pts[:, 0] - 0.5) ** 2 + (pts[:, 1] - 0.5) ** 2)
    assert r.max() <= 0.5 + 1e-9
    areas = [tri_area(pts, t) for t in cells]
    assert all(a > 0 for a in areas)


def test_csr_pattern_is_symmetric_with_diagonal():
    pts, cells = meshes.unit_square_tri(3)
    rows, cols = meshes.csr_pattern(len(pts), cells)
    pairs = set(zip(rows.tolist(), cols.tolist()))
    for i, j in list(pairs):
        assert (j, i) in pairs
    for i in range(len(pts)):
        assert (i, i) in pairs
    # Row-major sorted.
    order = np.lexsort((cols, rows))
    assert np.all(order == np.arange(len(rows)))
