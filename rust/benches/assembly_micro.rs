//! Microbenchmarks of the assembly pipeline stages (the §Perf tool):
//! Batch-Map (native), Sparse-Reduce (routing), scatter-add baseline,
//! routing construction, SpMV — per problem size — plus the batched
//! multi-instance path (S coefficient instances through one shared-topology
//! Map-Reduce vs S sequential assemblies) and the fused-vs-two-stage
//! comparison (tile engine vs materialized `S×E×kl²` intermediate, scalar
//! and S=16 batched). The fused speedup on the largest 2D batched
//! diffusion case is written to `BENCH_assembly.json` at the repo root so
//! the assembly-path perf trajectory is tracked across PRs. Used to locate
//! the hot path before and after each optimization iteration.

use tensor_galerkin::assembly::routing::Routing;
use tensor_galerkin::assembly::{scatter, AssemblyContext, BilinearForm, Coefficient};
use tensor_galerkin::fem::dofmap::DofMap;
use tensor_galerkin::mesh::structured::{unit_cube_tet, unit_square_tri};
use tensor_galerkin::util::bench::Bench;
use tensor_galerkin::util::cli::Args;
use tensor_galerkin::util::rng::Rng;

fn main() {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let sizes_2d = args.get_usize_list("sizes2d", &[32, 64, 128]);
    let sizes_3d = args.get_usize_list("sizes3d", &[8, 16, 24]);
    let s_batch = args.get_usize("batch", 16);
    let mut bench = Bench::new("assembly_micro");

    for &n in &sizes_2d {
        let mesh = unit_square_tri(n);
        let ctx = AssemblyContext::new(&mesh, 1);
        let form = BilinearForm::Diffusion { rho: Coefficient::Const(1.0) };
        let ne = mesh.n_cells() as f64;
        bench.bench(&format!("2d/map/e{}", mesh.n_cells()), &[("n_elems", ne)], || {
            ctx.map_matrix(&form)
        });
        let local = ctx.map_matrix(&form);
        let mut data = vec![0.0; ctx.routing.nnz()];
        bench.bench(&format!("2d/reduce/e{}", mesh.n_cells()), &[("n_elems", ne)], || {
            ctx.routing.reduce_matrix_into(&local, &mut data);
            data[0]
        });
        bench.bench(&format!("2d/scatter_add/e{}", mesh.n_cells()), &[("n_elems", ne)], || {
            scatter::assemble_matrix(&mesh, &ctx.dofmap, &form, &ctx.tab, &ctx.geo)
        });
        bench.bench(&format!("2d/routing_build/e{}", mesh.n_cells()), &[("n_elems", ne)], || {
            Routing::build(&DofMap::scalar(&mesh))
        });
        let k = ctx.assemble_matrix(&form);
        let x = vec![1.0; k.ncols];
        let mut y = vec![0.0; k.nrows];
        bench.bench(&format!("2d/spmv/n{}", k.nrows), &[("n_dofs", k.nrows as f64)], || {
            k.spmv(&x, &mut y);
            y[0]
        });

        // --- Batched multi-instance assembly (the Fig B.4 regime): S
        // random coefficient instances on this fixed topology, one
        // shared-topology Map-Reduce vs S sequential assemble_matrix calls.
        let nq = ctx.quad.len();
        let mut rng = Rng::new(99);
        let coeffs: Vec<Coefficient> = (0..s_batch)
            .map(|_| {
                let vals: Vec<f64> = (0..mesh.n_cells() * nq)
                    .map(|_| rng.uniform_in(0.5, 2.0))
                    .collect();
                Coefficient::Quad(vals)
            })
            .collect();
        let forms: Vec<BilinearForm> = coeffs
            .iter()
            .map(|c| BilinearForm::Diffusion { rho: c.clone() })
            .collect();
        let meta = [("n_elems", ne), ("batch", s_batch as f64)];
        bench.bench(
            &format!("2d/assemble_seq_s{s_batch}/e{}", mesh.n_cells()),
            &meta,
            || {
                let mut checksum = 0.0;
                for f in &forms {
                    checksum += ctx.assemble_matrix(f).data[0];
                }
                checksum
            },
        );
        let plan = ctx.batched(&forms[0]).expect("P1 triangles are separable");
        bench.bench(
            &format!("2d/assemble_batched_s{s_batch}/e{}", mesh.n_cells()),
            &meta,
            || plan.assemble(&coeffs).data[0],
        );
        // Plan construction included (cold batched path) + generic fused path.
        bench.bench(
            &format!("2d/assemble_batched_cold_s{s_batch}/e{}", mesh.n_cells()),
            &meta,
            || ctx.batched(&forms[0]).unwrap().assemble(&coeffs).data[0],
        );
        bench.bench(
            &format!("2d/assemble_batched_generic_s{s_batch}/e{}", mesh.n_cells()),
            &meta,
            || ctx.assemble_matrix_batch(&forms).data[0],
        );

        // --- Fused tile engine vs the two-stage pipeline, scalar and
        // batched, on identical inputs and preallocated outputs for BOTH
        // arms: the two-stage side still materializes the local tensor
        // (that intermediate is what it is), but reduces into the same
        // preallocated value buffer the fused side fills, so the
        // comparison isolates the Map+Reduce execution itself rather than
        // output/pattern allocation.
        let mut kdata = vec![0.0; ctx.routing.nnz()];
        bench.bench(&format!("2d/fused_scalar/e{}", mesh.n_cells()), &[("n_elems", ne)], || {
            ctx.assemble_matrix_into(&form, &mut kdata);
            kdata[0]
        });
        bench.bench(
            &format!("2d/two_stage_scalar/e{}", mesh.n_cells()),
            &[("n_elems", ne)],
            || {
                let local = ctx.map_matrix(&form);
                ctx.routing.reduce_matrix_into(&local, &mut kdata);
                kdata[0]
            },
        );
        let mut batch_data = vec![0.0; s_batch * ctx.routing.nnz()];
        bench.bench(&format!("2d/fused_s{s_batch}/e{}", mesh.n_cells()), &meta, || {
            ctx.assemble_matrix_batch_into(&forms, &mut batch_data);
            batch_data[0]
        });
        bench.bench(&format!("2d/two_stage_s{s_batch}/e{}", mesh.n_cells()), &meta, || {
            let local = ctx.map_matrix_batch(&forms);
            ctx.routing.reduce_matrix_batch_into(&local, s_batch, &mut batch_data);
            batch_data[0]
        });
    }

    for &n in &sizes_3d {
        let mesh = unit_cube_tet(n);
        let ctx = AssemblyContext::new(&mesh, 1);
        let form = BilinearForm::Diffusion { rho: Coefficient::Const(1.0) };
        let ne = mesh.n_cells() as f64;
        bench.bench(&format!("3d/map/e{}", mesh.n_cells()), &[("n_elems", ne)], || {
            ctx.map_matrix(&form)
        });
        let local = ctx.map_matrix(&form);
        let mut data = vec![0.0; ctx.routing.nnz()];
        bench.bench(&format!("3d/reduce/e{}", mesh.n_cells()), &[("n_elems", ne)], || {
            ctx.routing.reduce_matrix_into(&local, &mut data);
            data[0]
        });
        bench.bench(&format!("3d/scatter_add/e{}", mesh.n_cells()), &[("n_elems", ne)], || {
            scatter::assemble_matrix(&mesh, &ctx.dofmap, &form, &ctx.tab, &ctx.geo)
        });
    }

    // Acceptance summary: batched-vs-sequential and fused-vs-two-stage
    // speedups per 2D size.
    let find = |name: String| bench.results().iter().find(|m| m.name == name).map(|m| m.median_s);
    for &n in &sizes_2d {
        let e = 2 * n * n;
        let seq = find(format!("2d/assemble_seq_s{s_batch}/e{e}"));
        let bat = find(format!("2d/assemble_batched_s{s_batch}/e{e}"));
        let cold = find(format!("2d/assemble_batched_cold_s{s_batch}/e{e}"));
        if let (Some(s), Some(b)) = (seq, bat) {
            println!(
                "2d/e{e}: batched S={s_batch} is {:.2}x sequential (warm plan), {:.2}x (cold plan)",
                s / b.max(1e-12),
                cold.map(|c| s / c.max(1e-12)).unwrap_or(f64::NAN),
            );
        }
        let two = find(format!("2d/two_stage_s{s_batch}/e{e}"));
        let fus = find(format!("2d/fused_s{s_batch}/e{e}"));
        if let (Some(t), Some(f)) = (two, fus) {
            println!("2d/e{e}: fused S={s_batch} is {:.2}x two-stage", t / f.max(1e-12));
        }
    }
    // Perf-trajectory record: fused vs two-stage on the largest 2D S-batch
    // (the workload where the S×E×kl² intermediate traffic dominates).
    if let Some(&n) = sizes_2d.last() {
        let e = 2 * n * n;
        if let Some(speedup) = bench.write_speedup_json(
            "BENCH_assembly.json",
            &format!("2d/two_stage_s{s_batch}/e{e}"),
            &format!("2d/fused_s{s_batch}/e{e}"),
            &[("n_elems", e as f64), ("batch", s_batch as f64)],
        ) {
            println!(
                "assembly S={s_batch}: fused tile engine is {speedup:.2}x two-stage \
                 (record: BENCH_assembly.json at the repo root)"
            );
        }
    }
    bench.finish();
}
