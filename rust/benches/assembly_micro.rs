//! Microbenchmarks of the assembly pipeline stages (the §Perf tool):
//! Batch-Map (native), Sparse-Reduce (routing), scatter-add baseline,
//! routing construction, SpMV — per problem size. Used to locate the hot
//! path before and after each optimization iteration.

use tensor_galerkin::assembly::routing::Routing;
use tensor_galerkin::assembly::{scatter, AssemblyContext, BilinearForm, Coefficient};
use tensor_galerkin::fem::dofmap::DofMap;
use tensor_galerkin::mesh::structured::{unit_cube_tet, unit_square_tri};
use tensor_galerkin::util::bench::Bench;
use tensor_galerkin::util::cli::Args;

fn main() {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let sizes_2d = args.get_usize_list("sizes2d", &[32, 64, 128]);
    let sizes_3d = args.get_usize_list("sizes3d", &[8, 16, 24]);
    let mut bench = Bench::new("assembly_micro");

    for &n in &sizes_2d {
        let mesh = unit_square_tri(n);
        let ctx = AssemblyContext::new(&mesh, 1);
        let form = BilinearForm::Diffusion { rho: Coefficient::Const(1.0) };
        let ne = mesh.n_cells() as f64;
        bench.bench(&format!("2d/map/e{}", mesh.n_cells()), &[("n_elems", ne)], || {
            ctx.map_matrix(&form)
        });
        let local = ctx.map_matrix(&form);
        let mut data = vec![0.0; ctx.routing.nnz()];
        bench.bench(&format!("2d/reduce/e{}", mesh.n_cells()), &[("n_elems", ne)], || {
            ctx.routing.reduce_matrix_into(&local, &mut data);
            data[0]
        });
        bench.bench(&format!("2d/scatter_add/e{}", mesh.n_cells()), &[("n_elems", ne)], || {
            scatter::assemble_matrix(&mesh, &ctx.dofmap, &form, &ctx.tab, &ctx.geo)
        });
        bench.bench(&format!("2d/routing_build/e{}", mesh.n_cells()), &[("n_elems", ne)], || {
            Routing::build(&DofMap::scalar(&mesh))
        });
        let k = ctx.assemble_matrix(&form);
        let x = vec![1.0; k.ncols];
        let mut y = vec![0.0; k.nrows];
        bench.bench(&format!("2d/spmv/n{}", k.nrows), &[("n_dofs", k.nrows as f64)], || {
            k.spmv(&x, &mut y);
            y[0]
        });
    }

    for &n in &sizes_3d {
        let mesh = unit_cube_tet(n);
        let ctx = AssemblyContext::new(&mesh, 1);
        let form = BilinearForm::Diffusion { rho: Coefficient::Const(1.0) };
        let ne = mesh.n_cells() as f64;
        bench.bench(&format!("3d/map/e{}", mesh.n_cells()), &[("n_elems", ne)], || {
            ctx.map_matrix(&form)
        });
        let local = ctx.map_matrix(&form);
        let mut data = vec![0.0; ctx.routing.nnz()];
        bench.bench(&format!("3d/reduce/e{}", mesh.n_cells()), &[("n_elems", ne)], || {
            ctx.routing.reduce_matrix_into(&local, &mut data);
            data[0]
        });
        bench.bench(&format!("3d/scatter_add/e{}", mesh.n_cells()), &[("n_elems", ne)], || {
            scatter::assemble_matrix(&mesh, &ctx.dofmap, &form, &ctx.tab, &ctx.geo)
        });
    }
    bench.finish();
}
