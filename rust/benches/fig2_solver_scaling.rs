//! Bench: Fig 2 (a,b) — assemble+solve scaling with DoFs on 3D Poisson and
//! 3D elasticity, across assembly strategies (scatter-add baseline,
//! TensorGalerkin native, PJRT-artifact Map, recompile-per-solve) — plus
//! two solve-path comparisons:
//!
//! * **Looped vs blocked** (PR 2): S=16 varcoeff instances solved by one
//!   batched condensation + lockstep `cg_batch` vs S looped condense+`cg`
//!   pipelines, written to `BENCH_solver.json`.
//! * **Jacobi-PCG vs AMG-PCG** (PR 5): the fig2 Poisson family at two mesh
//!   sizes, preconditioner SETUP time (Jacobi diagonal extraction / AMG
//!   hierarchy construction) recorded separately from the ITERATION phase
//!   so neither record is polluted by one-time setup, with per-method
//!   iteration counts at both sizes and the large-size end-to-end solve
//!   speedup written to `BENCH_precond.json`.
//!
//! `cargo bench --bench fig2_solver_scaling [-- --sizes 4,8,12,16
//!   --batch 16 --batch-n 10 --precond-sizes 10,20]`

use tensor_galerkin::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
use tensor_galerkin::bc::{condense, condense_batch, DirichletBc};
use tensor_galerkin::experiments::fig2;
use tensor_galerkin::mesh::structured::unit_cube_tet;
use tensor_galerkin::runtime::Runtime;
use tensor_galerkin::solver::{
    cg, cg_batch, AmgConfig, AmgHierarchy, AmgPrecond, JacobiPrecond, SolverConfig,
};
use tensor_galerkin::sparse::Csr;
use tensor_galerkin::util::bench::Bench;
use tensor_galerkin::util::cli::Args;
use tensor_galerkin::util::rng::Rng;

/// Condensed 3D Poisson system of the fig2 family at structured size `n`.
fn poisson3d_condensed(n: usize) -> (Csr, Vec<f64>) {
    let mesh = unit_cube_tet(n);
    let ctx = AssemblyContext::new(&mesh, 1);
    let k = ctx.assemble_matrix(&BilinearForm::Diffusion { rho: Coefficient::Const(1.0) });
    let f = ctx.assemble_vector(&LinearForm::Source { f: Coefficient::Const(1.0) });
    let sys = condense(&k, &f, &DirichletBc::homogeneous(mesh.boundary_nodes()));
    (sys.k, sys.rhs)
}

fn main() {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let sizes = args.get_usize_list("sizes", &[4, 8, 12, 16]);
    let s_batch = args.get_usize("batch", 16);
    let batch_n = args.get_usize("batch-n", 10);
    let precond_sizes = args.get_usize_list("precond-sizes", &[10, 20]);
    let runtime = Runtime::new().ok();
    if runtime.is_none() {
        eprintln!("(artifacts missing: pjrt/recompile variants skipped)");
    }
    let mut bench = Bench::new("fig2_solver_scaling");
    for problem in ["poisson3d", "elasticity3d"] {
        for &n in &sizes {
            let pts = fig2::scale_point(problem, n, runtime.as_ref()).expect("scale point");
            for p in pts {
                bench.record(
                    &format!("{problem}/{}/assemble/dofs{}", p.variant, p.n_dofs),
                    &[("n_dofs", p.n_dofs as f64), ("n_elems", p.n_elems as f64)],
                    p.assemble_s,
                );
                if p.solve_s > 0.0 {
                    bench.record(
                        &format!("{problem}/{}/solve/dofs{}", p.variant, p.n_dofs),
                        &[("n_dofs", p.n_dofs as f64), ("rel_res", p.rel_residual)],
                        p.solve_s,
                    );
                }
            }
        }
    }

    // --- Looped vs blocked solve: S varcoeff Poisson instances on one 3D
    // topology. Both sides share the already-assembled CsrBatch, so the
    // comparison isolates condensation + CG (the phase PR 2 blocked).
    let mesh = unit_cube_tet(batch_n);
    let ctx = AssemblyContext::new(&mesh, 1);
    let n = ctx.n_dofs();
    let mut rng = Rng::new(4242);
    let forms: Vec<BilinearForm> = (0..s_batch)
        .map(|_| {
            let rho: Vec<f64> = (0..mesh.n_nodes()).map(|_| rng.uniform_in(0.5, 2.0)).collect();
            BilinearForm::Diffusion { rho: ctx.coeff_nodal(&rho) }
        })
        .collect();
    let kbatch = ctx.assemble_matrix_batch(&forms);
    let lforms: Vec<LinearForm> = (0..s_batch)
        .map(|_| {
            let f: Vec<f64> = (0..mesh.n_nodes()).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            LinearForm::Source { f: ctx.coeff_nodal(&f) }
        })
        .collect();
    let fbatch = ctx.assemble_vector_batch(&lforms);
    let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
    let cfg = SolverConfig::default();
    let meta = [("n_dofs", n as f64), ("batch", s_batch as f64)];

    // Looped baseline mirrors the pre-PR production loop exactly: one
    // pattern materialization, values copied per instance, scalar
    // condense + Jacobi CG per instance.
    let looped_name = format!("poisson3d/solve_looped_s{s_batch}/dofs{n}");
    let mut k_looped = ctx.pattern_matrix();
    bench.bench(&looped_name, &meta, || {
        let mut total_iters = 0usize;
        for s in 0..s_batch {
            k_looped.data.copy_from_slice(kbatch.values(s));
            let sys = condense(&k_looped, &fbatch[s * n..(s + 1) * n], &bc);
            let pc = JacobiPrecond::new(&sys.k);
            let (_, stats) = cg(&sys.k, &sys.rhs, &pc, &cfg);
            total_iters += stats.iterations;
        }
        total_iters
    });
    let blocked_name = format!("poisson3d/solve_blocked_s{s_batch}/dofs{n}");
    bench.bench(&blocked_name, &meta, || {
        let red = condense_batch(&kbatch, &fbatch, &bc);
        let (_, stats) = cg_batch(&red.k, &red.rhs, &cfg);
        stats.iter().map(|st| st.iterations).sum::<usize>()
    });

    if let Some(speedup) =
        bench.write_speedup_json("BENCH_solver.json", &looped_name, &blocked_name, &meta)
    {
        println!(
            "solve S={s_batch}: blocked condense+cg_batch is {speedup:.2}x looped condense+cg \
             (record: BENCH_solver.json at the repo root)"
        );
    }

    // --- Jacobi-PCG vs AMG-PCG on the fig2 Poisson family. Preconditioner
    // SETUP is benchmarked separately from the ITERATION phase: the solve
    // records time only PCG against a prebuilt preconditioner, so the
    // BENCH_precond.json speedup reflects per-solve cost — the regime of
    // every repeated-solve consumer, where the hierarchy is refilled, not
    // rebuilt. Setup has its own records for the one-shot picture.
    let mut precond_meta: Vec<(String, f64)> = Vec::new();
    // The BENCH_precond.json record compares the LARGEST problem (by DoF
    // count, not argument order — `--precond-sizes 16,8` must still pick
    // the 16³ mesh).
    let mut largest: Option<(usize, String, String)> = None;
    for &pn in &precond_sizes {
        let (a, b) = poisson3d_condensed(pn);
        let nd = a.nrows;
        let size_meta = [("n_dofs", nd as f64)];
        bench.bench(&format!("precond_setup/jacobi/dofs{nd}"), &size_meta, || {
            JacobiPrecond::new(&a)
        });
        bench.bench(&format!("precond_setup/amg/dofs{nd}"), &size_meta, || {
            AmgHierarchy::build(&a, AmgConfig::default())
        });
        let jac = JacobiPrecond::new(&a);
        let h = AmgHierarchy::build(&a, AmgConfig::default());
        let (_, st_jac) = cg(&a, &b, &jac, &cfg);
        let amg_pc = AmgPrecond::new(&h);
        let (_, st_amg) = cg(&a, &b, &amg_pc, &cfg);
        println!(
            "precond dofs={nd}: jacobi {} iters, amg {} iters ({} levels, opc {:.2})",
            st_jac.iterations,
            st_amg.iterations,
            h.n_levels(),
            h.operator_complexity()
        );
        let jac_name = format!("poisson3d/solve_jacobi_pcg/dofs{nd}");
        let amg_name = format!("poisson3d/solve_amg_pcg/dofs{nd}");
        bench.bench(&jac_name, &[("n_dofs", nd as f64), ("iters", st_jac.iterations as f64)], || {
            cg(&a, &b, &jac, &cfg).1.iterations
        });
        bench.bench(&amg_name, &[("n_dofs", nd as f64), ("iters", st_amg.iterations as f64)], || {
            cg(&a, &b, &amg_pc, &cfg).1.iterations
        });
        precond_meta.push((format!("dofs_{pn}"), nd as f64));
        precond_meta.push((format!("iters_jacobi_{pn}"), st_jac.iterations as f64));
        precond_meta.push((format!("iters_amg_{pn}"), st_amg.iterations as f64));
        if largest.as_ref().map_or(true, |(best, _, _)| nd > *best) {
            largest = Some((nd, jac_name, amg_name));
        }
    }
    if let Some((_, jac_name, amg_name)) = largest {
        let meta_refs: Vec<(&str, f64)> =
            precond_meta.iter().map(|(k, v)| (k.as_str(), *v)).collect();
        if let Some(speedup) =
            bench.write_speedup_json("BENCH_precond.json", &jac_name, &amg_name, &meta_refs)
        {
            println!(
                "precond: AMG-PCG is {speedup:.2}x Jacobi-PCG at the largest size \
                 (record: BENCH_precond.json at the repo root)"
            );
        }
    }
    bench.finish();
}
