//! Bench: Fig 2 (a,b) — assemble+solve scaling with DoFs on 3D Poisson and
//! 3D elasticity, across assembly strategies (scatter-add baseline,
//! TensorGalerkin native, PJRT-artifact Map, recompile-per-solve).
//!
//! `cargo bench --bench fig2_solver_scaling [-- --sizes 4,8,12,16]`

use tensor_galerkin::experiments::fig2;
use tensor_galerkin::runtime::Runtime;
use tensor_galerkin::util::bench::Bench;
use tensor_galerkin::util::cli::Args;

fn main() {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let sizes = args.get_usize_list("sizes", &[4, 8, 12, 16]);
    let runtime = Runtime::new().ok();
    if runtime.is_none() {
        eprintln!("(artifacts missing: pjrt/recompile variants skipped)");
    }
    let mut bench = Bench::new("fig2_solver_scaling");
    for problem in ["poisson3d", "elasticity3d"] {
        for &n in &sizes {
            let pts = fig2::scale_point(problem, n, runtime.as_ref()).expect("scale point");
            for p in pts {
                bench.record(
                    &format!("{problem}/{}/assemble/dofs{}", p.variant, p.n_dofs),
                    &[("n_dofs", p.n_dofs as f64), ("n_elems", p.n_elems as f64)],
                    p.assemble_s,
                );
                if p.solve_s > 0.0 {
                    bench.record(
                        &format!("{problem}/{}/solve/dofs{}", p.variant, p.n_dofs),
                        &[("n_dofs", p.n_dofs as f64), ("rel_res", p.rel_residual)],
                        p.solve_s,
                    );
                }
            }
        }
    }
    bench.finish();
}
