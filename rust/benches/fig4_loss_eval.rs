//! Bench: Fig 4 / Fig B.12 — wall-clock of one loss evaluation (forward,
//! and forward+backward) vs DoF for the supervised / FD / PINN / TensorPILS
//! objectives, all through the AOT artifacts on the PJRT CPU client.
//!
//! The paper's claim under test: PINN cost blows up with DoF count while
//! TensorPILS tracks the supervised/FD baselines.

use tensor_galerkin::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
use tensor_galerkin::mesh::structured::unit_square_tri;
use tensor_galerkin::pils::trainer::{ArtifactLoss, LossFn, Operand};
use tensor_galerkin::runtime::Runtime;
use tensor_galerkin::util::bench::Bench;

fn main() {
    let Ok(rt) = Runtime::new() else {
        eprintln!("fig4_loss_eval: artifacts missing (run `make artifacts`); skipping");
        return;
    };
    let mut bench = Bench::new("fig4_loss_eval");
    let sizes: Vec<usize> = rt
        .manifest
        .artifacts
        .values()
        .filter(|a| a.kind == "fig4_pinn_fwd")
        .map(|a| a.meta["mesh_n"] as usize)
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .collect();

    for &n in &sizes {
        let mesh = unit_square_tri(n);
        let dofs = mesh.n_nodes();
        let coords = mesh.points.clone();
        let mut mask = vec![1.0f64; dofs];
        for b in mesh.boundary_nodes() {
            mask[b] = 0.0;
        }
        let ctx = AssemblyContext::new(&mesh, 1);
        let kmat = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        let mut rows_idx = Vec::with_capacity(kmat.nnz());
        for r in 0..kmat.nrows {
            for _ in kmat.indptr[r]..kmat.indptr[r + 1] {
                rows_idx.push(r);
            }
        }
        let fvec = ctx.assemble_vector(&LinearForm::Source {
            f: ctx.coeff_fn(|p| tensor_galerkin::analysis::mms::checkerboard(4, p)),
        });
        let u_ref = vec![0.0f64; dofs];
        let params = tensor_galerkin::pils::siren::load_init(&rt, 0).expect("init");

        let kf = Operand::F32(vec![4.0f32]);
        let cases: Vec<(String, Vec<Operand>)> = vec![
            (
                format!("fig4_pinn_fwd_n{n}"),
                vec![Operand::from_f64(&coords), Operand::from_f64(&mask), kf.clone()],
            ),
            (
                format!("fig4_pinn_grad_n{n}"),
                vec![Operand::from_f64(&coords), Operand::from_f64(&mask), kf.clone()],
            ),
            (
                format!("fig4_pils_fwd_n{n}"),
                vec![
                    Operand::from_f64(&coords),
                    Operand::from_f64(&mask),
                    Operand::from_f64(&kmat.data),
                    Operand::from_usize(&rows_idx),
                    Operand::from_usize(&kmat.indices),
                    Operand::from_f64(&fvec),
                ],
            ),
            (
                format!("fig4_pils_grad_n{n}"),
                vec![
                    Operand::from_f64(&coords),
                    Operand::from_f64(&mask),
                    Operand::from_f64(&kmat.data),
                    Operand::from_usize(&rows_idx),
                    Operand::from_usize(&kmat.indices),
                    Operand::from_f64(&fvec),
                ],
            ),
            (
                format!("fig4_supervised_fwd_n{n}"),
                vec![Operand::from_f64(&coords), Operand::from_f64(&u_ref)],
            ),
            (
                format!("fig4_supervised_grad_n{n}"),
                vec![Operand::from_f64(&coords), Operand::from_f64(&u_ref)],
            ),
            (
                format!("fig4_fd_fwd_n{n}"),
                vec![Operand::from_f64(&coords), kf.clone()],
            ),
        ];
        for (name, fixed) in cases {
            if rt.manifest.get(&name).is_err() {
                continue;
            }
            // fwd-only artifacts return (loss,), grad return (loss, grad):
            // both run through execute; use ArtifactLoss for grad ones and
            // raw execute for fwd ones.
            if name.contains("_grad_") {
                let mut loss = ArtifactLoss::new(&rt, &name, fixed);
                let _ = loss.eval(&params).expect("warmup");
                bench.bench(&name, &[("dofs", dofs as f64)], || {
                    loss.eval(&params).unwrap().0
                });
            } else {
                let p32: Vec<f32> = params.iter().map(|&x| x as f32).collect();
                let owned = fixed;
                let run = || {
                    let mut inputs = vec![tensor_galerkin::runtime::exec::Operand::F32(&p32)];
                    for op in &owned {
                        inputs.push(match op {
                            Operand::F32(v) => tensor_galerkin::runtime::exec::Operand::F32(v),
                            Operand::I32(v) => tensor_galerkin::runtime::exec::Operand::I32(v),
                        });
                    }
                    rt.execute(&name, &inputs).unwrap()[0][0]
                };
                let _ = run(); // compile+warm
                bench.bench(&name, &[("dofs", dofs as f64)], run);
            }
        }
    }
    bench.finish();
}
