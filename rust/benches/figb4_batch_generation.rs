//! Bench: Fig B.4 — batched data generation (fixed 3D Poisson operator,
//! varying RHS) vs the naive per-sample pipeline, plus the multi-instance
//! regime where every sample carries its own coefficient field and all S
//! operators are assembled by one shared-topology Map-Reduce, plus the
//! *served* regime: the same burst pushed through the continuous-batching
//! [`BatchServer`] (one batched dispatch) vs a sequential client
//! (request-by-request over the same server). The served comparison is the
//! coordinator's perf trajectory, recorded to `BENCH_coordinator.json` at
//! the repo root.

use std::collections::VecDeque;
use std::sync::mpsc::TryRecvError;
use std::time::{Duration, Instant};

use tensor_galerkin::coordinator::batcher::{solve_unbatched, BatchSolver};
use tensor_galerkin::coordinator::{
    BatchServer, ShardConfig, SolveError, SolveRequest, SolveResponse, VarCoeffRequest,
};
use tensor_galerkin::mesh::structured::unit_cube_tet;
use tensor_galerkin::solver::SolverConfig;
use tensor_galerkin::util::bench::Bench;
use tensor_galerkin::util::cli::Args;
use tensor_galerkin::util::rng::Rng;

fn main() {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let n = args.get_usize("n", 12);
    let batches = args.get_usize_list("batches", &[1, 4, 16, 64]);
    let s_varcoeff = args.get_usize("varcoeff", 16);
    let s_served = args.get_usize("served", 32);
    let mesh = unit_cube_tet(n);
    let cfg = SolverConfig {
        rel_tol: 1e-8,
        ..SolverConfig::default()
    };
    let mut rng = Rng::new(42);
    let mut bench = Bench::new("figb4_batch_generation");
    let solver = BatchSolver::new(&mesh, cfg);
    for &b in &batches {
        let reqs: Vec<SolveRequest> = (0..b)
            .map(|id| {
                SolveRequest::new(
                    id as u64,
                    (0..mesh.n_nodes()).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
                )
            })
            .collect();
        bench.bench(
            &format!("batched/b{b}"),
            &[("batch", b as f64), ("n_dofs", mesh.n_nodes() as f64)],
            || solver.solve_batch(&reqs).unwrap().len(),
        );
        let naive_n = b.min(4);
        bench.bench(
            &format!("naive/b{naive_n}"),
            &[("batch", naive_n as f64)],
            || solve_unbatched(&mesh, &reqs[..naive_n], cfg).unwrap().len(),
        );
    }

    // --- Multi-instance batch: per-sample coefficient fields, S operators
    // sharing one symbolic pattern (CsrBatch) vs S scalar assembly+solve
    // pipelines over the same requests.
    let vreqs: Vec<VarCoeffRequest> = (0..s_varcoeff)
        .map(|id| {
            VarCoeffRequest::new(
                id as u64,
                (0..mesh.n_nodes()).map(|_| rng.uniform_in(0.5, 2.0)).collect(),
                (0..mesh.n_nodes()).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
            )
        })
        .collect();
    bench.bench(
        &format!("varcoeff_batched/b{s_varcoeff}"),
        &[("batch", s_varcoeff as f64), ("n_dofs", mesh.n_nodes() as f64)],
        || solver.solve_varcoeff_batch(&vreqs).unwrap().len(),
    );
    bench.bench(
        &format!("varcoeff_sequential/b{s_varcoeff}"),
        &[("batch", s_varcoeff as f64), ("n_dofs", mesh.n_nodes() as f64)],
        || solver.solve_varcoeff_sequential(&vreqs).unwrap().len(),
    );

    // --- Served throughput: the same burst through the continuous-batching
    // server. Burst submission lands the whole group in one drain cycle →
    // ONE batched assembly + one lockstep CG; the baseline is a sequential
    // client that waits for each response before submitting the next
    // (request-by-request serving, what the pre-PR-4 worker did for every
    // drained batch).
    let server = BatchServer::start(mesh.clone(), cfg, s_served);
    let sreqs: Vec<SolveRequest> = (0..s_served)
        .map(|id| {
            SolveRequest::new(
                id as u64,
                (0..mesh.n_nodes()).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
            )
        })
        .collect();
    // Warm the lazy per-mesh state so both arms measure steady-state serving.
    server
        .submit(sreqs[0].clone())
        .recv()
        .expect("server alive")
        .expect("warmup solve");
    bench.bench(
        &format!("served_burst/b{s_served}"),
        &[("batch", s_served as f64), ("n_dofs", mesh.n_nodes() as f64)],
        || {
            let out = server.solve_all(sreqs.clone()).unwrap();
            out.len()
        },
    );
    bench.bench(
        &format!("served_sequential/b{s_served}"),
        &[("batch", s_served as f64), ("n_dofs", mesh.n_nodes() as f64)],
        || {
            sreqs
                .iter()
                .map(|r| server.submit(r.clone()).recv().unwrap().unwrap())
                .count()
        },
    );
    // --- Sharded serving arms: the same closed-loop burst regime over
    // num_shards = 1/2/4 with stealing on, four meshes whose ids spread
    // over every shard at s=4 under the stable-hash routing. Throughput
    // scaling and per-request p50/p99 ride in the BENCH_coordinator.json
    // meta below (closed-loop here, open-loop sustained load further down).
    let shard_counts = args.get_usize_list("shards", &[1, 2, 4]);
    let sh_n = args.get_usize("shard_n", (n / 2).max(4));
    let sharded_mesh = unit_cube_tet(sh_n);
    const SHARD_MESH_IDS: [u64; 4] = [6, 1, 2, 0];
    let mut sharded_servers: Vec<(usize, BatchServer)> = Vec::new();
    let mut sharded_meta: Vec<(String, f64)> = Vec::new();
    for &s in &shard_counts {
        let sh_server = BatchServer::start_sharded(
            SHARD_MESH_IDS.iter().map(|&id| (id, sharded_mesh.clone())).collect(),
            cfg,
            s_served,
            0,
            ShardConfig { num_shards: s, steal: true },
        );
        // Warm every per-mesh state so the arms measure steady-state serving.
        for &id in &SHARD_MESH_IDS {
            let f = (0..sharded_mesh.n_nodes()).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            sh_server
                .submit(SolveRequest::on_mesh(8000 + id, id, f))
                .recv()
                .expect("sharded server alive")
                .expect("sharded warmup solve");
        }
        let sh_burst: Vec<SolveRequest> = (0..4 * s_served)
            .map(|i| {
                SolveRequest::on_mesh(
                    i as u64,
                    SHARD_MESH_IDS[i % 4],
                    (0..sharded_mesh.n_nodes()).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
                )
            })
            .collect();
        bench.bench(
            &format!("sharded_burst/s{s}"),
            &[
                ("shards", s as f64),
                ("batch", sh_burst.len() as f64),
                ("n_dofs", sharded_mesh.n_nodes() as f64),
            ],
            || sh_server.solve_all(sh_burst.clone()).unwrap().len(),
        );
        // One timed pass for absolute throughput plus a closed-loop
        // per-request latency distribution.
        let t0 = Instant::now();
        let mut sh_lat: Vec<f64> = Vec::with_capacity(sh_burst.len());
        for rx in sh_server.submit_many(sh_burst.clone()) {
            rx.recv().expect("sharded server alive").expect("sharded latency probe");
            sh_lat.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let reqps = sh_lat.len() as f64 / t0.elapsed().as_secs_f64().max(1e-9);
        sh_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let spct = |p: f64| sh_lat[((sh_lat.len() - 1) as f64 * p).round() as usize];
        println!(
            "sharded s={s}: {reqps:.0} req/s closed-loop, p50 {:.2} ms, p99 {:.2} ms",
            spct(0.5),
            spct(0.99)
        );
        sharded_meta.push((format!("sharded_s{s}_reqps"), reqps));
        sharded_meta.push((format!("sharded_s{s}_p50_ms"), spct(0.5)));
        sharded_meta.push((format!("sharded_s{s}_p99_ms"), spct(0.99)));
        sharded_servers.push((s, sh_server));
    }
    bench.finish();

    // --- Serving SLO smoke: per-request latency distribution under the
    // burst regime (submit-to-reply, so the tail is the full drain time),
    // plus deadline-expiry and admission-rejection probes. The percentiles
    // and robustness counters ride along in the BENCH_coordinator.json
    // meta so the serving trajectory tracks tail latency across PRs.
    let mut lat_ms: Vec<f64> = Vec::with_capacity(2 * s_served);
    for _ in 0..2 {
        let t0 = Instant::now();
        for rx in server.submit_many(sreqs.clone()) {
            rx.recv().expect("server alive").expect("latency probe solve");
            lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        }
    }
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lat_ms[((lat_ms.len() - 1) as f64 * p).round() as usize];
    let (lat_p50, lat_p99) = (pct(0.5), pct(0.99));
    println!(
        "served latency over {} requests: p50 {lat_p50:.2} ms, p99 {lat_p99:.2} ms",
        lat_ms.len()
    );
    // Deadline expiry: already-passed deadlines are answered Expired at
    // dispatch without solving.
    let expired_probe: Vec<SolveRequest> = (0..4)
        .map(|id| {
            SolveRequest::new(
                9000 + id,
                (0..mesh.n_nodes()).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
            )
            .with_deadline(Instant::now())
        })
        .collect();
    for rx in server.submit_many(expired_probe) {
        let _ = rx.recv().expect("server alive");
    }
    // Admission rejection: a burst larger than the queue bound is refused
    // synchronously. The bound is lifted again afterwards.
    server.set_max_queue(2);
    let overload_probe: Vec<SolveRequest> = (0..8)
        .map(|id| {
            SolveRequest::new(
                9100 + id,
                (0..mesh.n_nodes()).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
            )
        })
        .collect();
    for rx in server.submit_many(overload_probe) {
        let _ = rx.recv().expect("server alive");
    }
    server.set_max_queue(0);

    let stats = server.stats().expect("worker alive");
    println!(
        "server dispatches: {} batched, {} scalar, {} failed ({} expired, {} rejected)",
        stats.batched_solves,
        stats.scalar_solves,
        stats.failed_requests,
        stats.expired_requests,
        stats.rejected_requests
    );

    // --- Open-loop sustained load: fixed-rate arrivals on a deterministic
    // schedule (request i is due at t0 + i/rate, independent of responses).
    // The closed-loop arms above can never observe queueing collapse —
    // the client waits, so offered load adapts to capacity; an open-loop
    // client keeps offering, so a saturated server must shed or expire.
    // Every request carries a deadline and the admission queue is bounded;
    // responses are classified served (latency sample, drained without
    // blocking the schedule), shed (Overloaded/Unhealthy — never queued)
    // or expired. Loss counters and the served-latency distribution ride
    // in the BENCH_coordinator.json meta.
    let n_open = args.get_usize("open", 96);
    let rate_hz = args.get_usize("rate", 400);
    let open_deadline_ms = args.get_usize("open_deadline_ms", 250);
    fn classify(res: &anyhow::Result<SolveResponse>) -> (u64, u64, u64, u64) {
        match res {
            Ok(_) => (1, 0, 0, 0),
            Err(e) => match e.downcast_ref::<SolveError>() {
                Some(SolveError::Overloaded { .. } | SolveError::Unhealthy { .. }) => (0, 1, 0, 0),
                Some(SolveError::Expired { .. }) => (0, 0, 1, 0),
                _ => (0, 0, 0, 1),
            },
        }
    }
    server.set_max_queue(4 * s_served);
    let period = Duration::from_secs_f64(1.0 / rate_hz.max(1) as f64);
    let deadline = Duration::from_millis(open_deadline_ms as u64);
    let mut inflight = VecDeque::new();
    let mut open_lat: Vec<f64> = Vec::with_capacity(n_open);
    let (mut shed, mut expired, mut lost) = (0u64, 0u64, 0u64);
    let t0 = Instant::now();
    for i in 0..n_open {
        let due = t0 + period * i as u32;
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        let sent = Instant::now();
        let rx = server.submit(
            SolveRequest::new(
                9500 + i as u64,
                (0..mesh.n_nodes()).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
            )
            .with_deadline(sent + deadline),
        );
        inflight.push_back((sent, rx));
        // Drain whatever already answered; never block the arrival schedule.
        while let Some((sent, rx)) = inflight.pop_front() {
            match rx.try_recv() {
                Ok(res) => {
                    let (ok, s, e, l) = classify(&res);
                    if ok == 1 {
                        open_lat.push(sent.elapsed().as_secs_f64() * 1e3);
                    }
                    shed += s;
                    expired += e;
                    lost += l;
                }
                Err(TryRecvError::Empty) => {
                    inflight.push_front((sent, rx));
                    break;
                }
                Err(TryRecvError::Disconnected) => lost += 1,
            }
        }
    }
    for (sent, rx) in inflight {
        match rx.recv() {
            Ok(res) => {
                let (ok, s, e, l) = classify(&res);
                if ok == 1 {
                    open_lat.push(sent.elapsed().as_secs_f64() * 1e3);
                }
                shed += s;
                expired += e;
                lost += l;
            }
            Err(_) => lost += 1,
        }
    }
    server.set_max_queue(0);
    open_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let opct = |p: f64| {
        if open_lat.is_empty() {
            0.0
        } else {
            open_lat[((open_lat.len() - 1) as f64 * p).round() as usize]
        }
    };
    let (open_p50, open_p99) = (opct(0.5), opct(0.99));
    println!(
        "open-loop {n_open} req @ {rate_hz} Hz (deadline {open_deadline_ms} ms): \
         {} served (p50 {open_p50:.2} ms, p99 {open_p99:.2} ms), \
         {shed} shed, {expired} expired, {lost} lost",
        open_lat.len()
    );

    // --- Open-loop sustained load over the sharded servers: the same
    // fixed-rate deterministic schedule, arrivals round-robin over the
    // four meshes so every shard sees traffic. Records served p50/p99 and
    // loss counters per shard count.
    for (s, sh_server) in sharded_servers {
        sh_server.set_max_queue(4 * s_served);
        let mut inflight = VecDeque::new();
        let mut olat: Vec<f64> = Vec::with_capacity(n_open);
        let (mut oshed, mut oexpired, mut olost) = (0u64, 0u64, 0u64);
        let t0 = Instant::now();
        for i in 0..n_open {
            let due = t0 + period * i as u32;
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
            let sent = Instant::now();
            let rx = sh_server.submit(
                SolveRequest::on_mesh(
                    9800 + i as u64,
                    SHARD_MESH_IDS[i % 4],
                    (0..sharded_mesh.n_nodes()).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
                )
                .with_deadline(sent + deadline),
            );
            inflight.push_back((sent, rx));
            while let Some((sent, rx)) = inflight.pop_front() {
                match rx.try_recv() {
                    Ok(res) => {
                        let (ok, sh, e, l) = classify(&res);
                        if ok == 1 {
                            olat.push(sent.elapsed().as_secs_f64() * 1e3);
                        }
                        oshed += sh;
                        oexpired += e;
                        olost += l;
                    }
                    Err(TryRecvError::Empty) => {
                        inflight.push_front((sent, rx));
                        break;
                    }
                    Err(TryRecvError::Disconnected) => olost += 1,
                }
            }
        }
        for (sent, rx) in inflight {
            match rx.recv() {
                Ok(res) => {
                    let (ok, sh, e, l) = classify(&res);
                    if ok == 1 {
                        olat.push(sent.elapsed().as_secs_f64() * 1e3);
                    }
                    oshed += sh;
                    oexpired += e;
                    olost += l;
                }
                Err(_) => olost += 1,
            }
        }
        olat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let sopct = |p: f64| {
            if olat.is_empty() {
                0.0
            } else {
                olat[((olat.len() - 1) as f64 * p).round() as usize]
            }
        };
        println!(
            "sharded open-loop s={s}: {} served (p50 {:.2} ms, p99 {:.2} ms), \
             {oshed} shed, {oexpired} expired, {olost} lost",
            olat.len(),
            sopct(0.5),
            sopct(0.99)
        );
        sharded_meta.push((format!("sharded_open_s{s}_p50_ms"), sopct(0.5)));
        sharded_meta.push((format!("sharded_open_s{s}_p99_ms"), sopct(0.99)));
        sharded_meta.push((format!("sharded_open_s{s}_shed"), oshed as f64));
        sharded_meta.push((format!("sharded_open_s{s}_expired"), oexpired as f64));
    }

    // --- Crash-tolerance arm (fault-inject builds only): a supervised
    // 4-shard server takes the closed-loop burst while SHARD_PANIC kills
    // one shard worker mid-run. The supervisor respawns the worker and
    // requeues the salvaged slice, so every request is still answered;
    // served p50/p99 through the crash plus the requeued/lost/respawn
    // counters ride in the BENCH_coordinator.json meta.
    #[cfg(feature = "fault-inject")]
    {
        use tensor_galerkin::coordinator::SupervisionConfig;
        use tensor_galerkin::util::faults::{self, Fault};
        let crash_server = BatchServer::start_sharded(
            SHARD_MESH_IDS.iter().map(|&id| (id, sharded_mesh.clone())).collect(),
            cfg,
            s_served,
            0,
            ShardConfig { num_shards: 4, steal: false },
        );
        crash_server.set_supervision_config(SupervisionConfig::supervised());
        for &id in &SHARD_MESH_IDS {
            let f = (0..sharded_mesh.n_nodes()).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            crash_server
                .submit(SolveRequest::on_mesh(9900 + id, id, f))
                .recv()
                .expect("crash-arm server alive")
                .expect("crash-arm warmup solve");
        }
        let crash_burst: Vec<SolveRequest> = (0..4 * s_served)
            .map(|i| {
                SolveRequest::on_mesh(
                    10_000 + i as u64,
                    SHARD_MESH_IDS[i % 4],
                    (0..sharded_mesh.n_nodes()).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
                )
            })
            .collect();
        let victim = crash_server.shard_of(SHARD_MESH_IDS[0]);
        faults::reset();
        faults::arm(faults::SHARD_PANIC, Fault::always().on_lanes(&[victim]).hits(1));
        let t0 = Instant::now();
        let mut clat: Vec<f64> = Vec::with_capacity(4 * s_served);
        let mut clost = 0u64;
        for rx in crash_server.submit_many(crash_burst) {
            match rx.recv().expect("supervised server answers every request") {
                Ok(_) => clat.push(t0.elapsed().as_secs_f64() * 1e3),
                Err(_) => clost += 1,
            }
        }
        faults::reset();
        clat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let cpct = |p: f64| {
            if clat.is_empty() {
                0.0
            } else {
                clat[((clat.len() - 1) as f64 * p).round() as usize]
            }
        };
        let cstats = crash_server.stats().expect("respawned workers answer stats");
        println!(
            "crash arm (shard {victim} killed mid-run): {} served (p50 {:.2} ms, p99 {:.2} ms), \
             {} requeued, {clost} lost, {} respawns",
            clat.len(),
            cpct(0.5),
            cpct(0.99),
            cstats.requeued_requests,
            cstats.worker_respawns
        );
        sharded_meta.push(("crash_served_p50_ms".to_string(), cpct(0.5)));
        sharded_meta.push(("crash_served_p99_ms".to_string(), cpct(0.99)));
        sharded_meta.push(("crash_requeued".to_string(), cstats.requeued_requests as f64));
        sharded_meta.push(("crash_lost".to_string(), cstats.lost_requests as f64));
        sharded_meta.push(("crash_respawns".to_string(), cstats.worker_respawns as f64));
    }

    let mut meta: Vec<(String, f64)> = vec![
        ("batch".to_string(), s_served as f64),
        ("n_dofs".to_string(), mesh.n_nodes() as f64),
        ("latency_p50_ms".to_string(), lat_p50),
        ("latency_p99_ms".to_string(), lat_p99),
        ("expired_requests".to_string(), stats.expired_requests as f64),
        ("rejected_requests".to_string(), stats.rejected_requests as f64),
        ("openloop_requests".to_string(), n_open as f64),
        ("openloop_rate_hz".to_string(), rate_hz as f64),
        ("openloop_p50_ms".to_string(), open_p50),
        ("openloop_p99_ms".to_string(), open_p99),
        ("openloop_shed".to_string(), shed as f64),
        ("openloop_expired".to_string(), expired as f64),
    ];
    meta.extend(sharded_meta);
    let meta_refs: Vec<(&str, f64)> = meta.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    if let Some(speedup) = bench.write_speedup_json(
        "BENCH_coordinator.json",
        &format!("served_sequential/b{s_served}"),
        &format!("served_burst/b{s_served}"),
        &meta_refs,
    ) {
        println!("served burst vs sequential client speedup: {speedup:.2}×");
    }
}
