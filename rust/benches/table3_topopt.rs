//! Bench: Table 3 — SIMP cantilever timing, TensorOpt vs the
//! rebuild-per-iteration archetype.

use tensor_galerkin::opt::topopt::{run_topopt, TopOptConfig};
use tensor_galerkin::util::bench::Bench;
use tensor_galerkin::util::cli::Args;

fn main() {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let iters = args.get_usize("iters", 51);
    let mut bench = Bench::new("table3_topopt");
    let mut cfg = TopOptConfig {
        iters,
        ..TopOptConfig::default()
    };
    let ours = run_topopt(&cfg).expect("topopt");
    bench.record("tensoropt/setup", &[("iters", iters as f64)], ours.setup_s);
    bench.record("tensoropt/loop", &[("iters", iters as f64)], ours.loop_s);
    cfg.rebuild_setup_each_iter = true;
    let base = run_topopt(&cfg).expect("baseline");
    bench.record("rebuild_baseline/setup", &[], base.setup_s);
    bench.record("rebuild_baseline/loop", &[], base.loop_s);
    println!(
        "final compliance: ours {:.4} vs baseline {:.4}",
        ours.final_compliance(),
        base.final_compliance()
    );
    bench.finish();
}
