//! Bench: Table B.3 — mixed Dirichlet+Neumann+Robin assembly+solve on the
//! circle and boomerang domains (TensorMesh Map-Reduce vs the scatter-add
//! archetype). Timing is end-to-end through the experiment driver.

use tensor_galerkin::experiments::tableb3;
use tensor_galerkin::util::bench::Bench;
use tensor_galerkin::util::cli::Args;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw);
    let mut bench = Bench::new("tableb3_mixed_bc");
    // The driver prints the table and appends experiment records; wrap the
    // whole run so the bench log carries the end-to-end number as well.
    bench.bench("mixed_bc_full_run", &[], || tableb3::run(&args).expect("tableb3"));
    bench.finish();
}
