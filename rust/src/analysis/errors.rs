//! Error norms over FEM fields.
//!
//! `rel_l2_nodal` is the discrete vector norm used in the paper's tables;
//! `l2_norm_field` integrates `(u_h − u)²` with quadrature — the continuous
//! `L²(Ω)` norm used for convergence studies.

use crate::assembly::AssemblyContext;

/// Relative discrete l2 error `‖u−v‖₂/‖v‖₂` on nodal vectors.
pub fn rel_l2_nodal(u: &[f64], v: &[f64]) -> f64 {
    crate::util::rel_l2(u, v)
}

/// Continuous `L²(Ω)` norm of the P1 interpolant of nodal field `u` minus a
/// reference function `exact(x)`, via the context's quadrature.
pub fn l2_error_vs_exact(
    ctx: &AssemblyContext,
    u: &[f64],
    exact: impl Fn(&[f64]) -> f64,
) -> f64 {
    let geo = &ctx.geo;
    let tab = &ctx.tab;
    let mesh = &ctx.mesh;

    let mut acc = 0.0;
    for e in 0..mesh.n_cells() {
        let cell = mesh.cell(e);
        for q in 0..geo.q {
            let w = geo.detj[e * geo.q + q] * tab.weights[q];
            let mut uh = 0.0;
            for (a, &v) in cell.iter().enumerate() {
                uh += u[v] * tab.val(q, a);
            }
            let d = uh - exact(geo.qpoint(e, q));
            acc += w * d * d;
        }
    }
    acc.sqrt()
}

/// `L²(Ω)` norm of a nodal field (through its P1 interpolant).
pub fn l2_norm_field(ctx: &AssemblyContext, u: &[f64]) -> f64 {
    l2_error_vs_exact(ctx, u, |_| 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::structured::unit_square_tri;

    #[test]
    fn l2_norm_of_constant_field() {
        let m = unit_square_tri(4);
        let ctx = AssemblyContext::new(&m, 1);
        let u = vec![2.0; m.n_nodes()];
        // ‖2‖_{L²([0,1]²)} = 2.
        assert!((l2_norm_field(&ctx, &u) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn l2_error_zero_for_exact_interpolant() {
        let m = unit_square_tri(4);
        let ctx = AssemblyContext::new(&m, 1);
        let u: Vec<f64> = (0..m.n_nodes())
            .map(|i| 1.0 + 3.0 * m.point(i)[0] - m.point(i)[1])
            .collect();
        let err = l2_error_vs_exact(&ctx, &u, |p| 1.0 + 3.0 * p[0] - p[1]);
        assert!(err < 1e-13);
    }
}
