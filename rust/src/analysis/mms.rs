//! Manufactured solutions used across tests and experiments.

/// `u = sin(πx)sin(πy)`, `−Δu = 2π² sin(πx)sin(πy)` on `[0,1]²`.
pub fn sine2d_u(p: &[f64]) -> f64 {
    let pi = std::f64::consts::PI;
    (pi * p[0]).sin() * (pi * p[1]).sin()
}

/// Forcing for [`sine2d_u`].
pub fn sine2d_f(p: &[f64]) -> f64 {
    let pi = std::f64::consts::PI;
    2.0 * pi * pi * sine2d_u(p)
}

/// `u = sin(πx)sin(πy)sin(πz)` on `[0,1]³`.
pub fn sine3d_u(p: &[f64]) -> f64 {
    let pi = std::f64::consts::PI;
    (pi * p[0]).sin() * (pi * p[1]).sin() * (pi * p[2]).sin()
}

/// Forcing for [`sine3d_u`].
pub fn sine3d_f(p: &[f64]) -> f64 {
    let pi = std::f64::consts::PI;
    3.0 * pi * pi * sine3d_u(p)
}

/// Checkerboard forcing `f_K(x,y) = (−1)^{⌊Kx⌋+⌊Ky⌋}` (Eq. B.10) — the
/// Table 1 benchmark. Discontinuous, multi-scale as `K` grows.
pub fn checkerboard(k: usize, p: &[f64]) -> f64 {
    let ix = (k as f64 * p[0]).floor() as i64;
    let iy = (k as f64 * p[1]).floor() as i64;
    if (ix + iy) % 2 == 0 {
        1.0
    } else {
        -1.0
    }
}

/// Multi-frequency sine expansion initial condition of Eq. (B.15):
/// `u0 = (π/K²) Σ_ij a_ij (i²+j²)^{-r} sin(πix)sin(πjy)` with
/// `a ~ U[-1,1]` from the given RNG.
pub fn sine_expansion_ic(
    kmax: usize,
    r: f64,
    rng: &mut crate::util::rng::Rng,
) -> impl Fn(&[f64]) -> f64 {
    let pi = std::f64::consts::PI;
    let mut coeffs = Vec::with_capacity(kmax * kmax);
    for _ in 0..kmax * kmax {
        coeffs.push(rng.uniform_in(-1.0, 1.0));
    }
    move |p: &[f64]| {
        let mut s = 0.0;
        for i in 1..=kmax {
            for j in 1..=kmax {
                let a = coeffs[(i - 1) * kmax + (j - 1)];
                let decay = ((i * i + j * j) as f64).powf(-r);
                s += a * decay * (pi * i as f64 * p[0]).sin() * (pi * j as f64 * p[1]).sin();
            }
        }
        pi / (kmax * kmax) as f64 * s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkerboard_alternates() {
        assert_eq!(checkerboard(2, &[0.1, 0.1]), 1.0);
        assert_eq!(checkerboard(2, &[0.6, 0.1]), -1.0);
        assert_eq!(checkerboard(2, &[0.6, 0.6]), 1.0);
        assert_eq!(checkerboard(8, &[0.0, 0.1374]), -1.0);
    }

    #[test]
    fn ic_vanishes_on_unit_square_boundary() {
        let mut rng = crate::util::rng::Rng::new(1);
        let ic = sine_expansion_ic(6, 0.5, &mut rng);
        for t in [0.0, 0.25, 0.7, 1.0] {
            assert!(ic(&[0.0, t]).abs() < 1e-12);
            assert!(ic(&[1.0, t]).abs() < 1e-12);
            assert!(ic(&[t, 0.0]).abs() < 1e-12);
            assert!(ic(&[t, 1.0]).abs() < 1e-12);
        }
        assert!(ic(&[0.4, 0.6]).abs() > 0.0);
    }
}
