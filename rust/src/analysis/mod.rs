//! Error analysis and manufactured solutions.

pub mod errors;
pub mod mms;

pub use errors::{l2_norm_field, rel_l2_nodal};
