//! Weak-form descriptions and coefficient fields.
//!
//! A [`BilinearForm`] (resp. [`LinearForm`]) describes the physics `ℱ` of
//! Eq. (7); the Map stage contracts it against batched geometry. Spatially
//! varying inputs `ρ` enter as [`Coefficient`]s evaluated at physical
//! quadrature points — precisely the paper's batched tensor
//! `𝒞 ∈ R^{E×Q×…}`.

use crate::fem::geometry::ElementGeometry;
use crate::fem::reference::Tabulation;

/// One nodal-to-quadrature interpolation `Σ_a u[g_e(a)] φ̂_a(x̂_q)` — the
/// single source of this kernel's arithmetic order.
/// [`Coefficient::from_nodal`], the separable plan's nodal collapse
/// (`BatchedAssembly::element_scalars_nodal_into`) and the Allen-Cahn
/// reaction path all call it, so their documented bitwise-equality
/// contracts hold by construction instead of by copy discipline.
#[inline]
pub(crate) fn interp_nodal(u: &[f64], dofs: &[usize], tab: &Tabulation, q: usize) -> f64 {
    let mut s = 0.0;
    for (a, &d) in dofs.iter().enumerate() {
        s += u[d] * tab.val(q, a);
    }
    s
}

/// A scalar coefficient field.
#[derive(Clone, Debug)]
pub enum Coefficient {
    /// Constant in space.
    Const(f64),
    /// Values at physical quadrature points, `E × Q` row-major
    /// (the batched coefficient tensor `𝒞_eq`).
    Quad(Vec<f64>),
}

impl Coefficient {
    /// Evaluate a spatial function at the batched quadrature points.
    pub fn from_fn(geo: &ElementGeometry, f: impl Fn(&[f64]) -> f64) -> Coefficient {
        let mut vals = Vec::with_capacity(geo.n_elems * geo.q);
        for e in 0..geo.n_elems {
            for q in 0..geo.q {
                vals.push(f(geo.qpoint(e, q)));
            }
        }
        Coefficient::Quad(vals)
    }

    /// Interpolate a nodal field `u` (one value per global scalar DoF of
    /// `entries`, `E × k` local map) to quadrature points:
    /// `u_eq = Σ_a u[g_e(a)] φ̂_a(x̂_q)` — TensorPILS's analytic
    /// "shape-function interpolation" with zero autodiff.
    pub fn from_nodal(u: &[f64], entries: &[usize], tab: &Tabulation) -> Coefficient {
        let k = tab.k;
        assert_eq!(entries.len() % k, 0);
        let n_elems = entries.len() / k;
        let mut vals = Vec::with_capacity(n_elems * tab.q);
        for e in 0..n_elems {
            let dofs = &entries[e * k..(e + 1) * k];
            for q in 0..tab.q {
                vals.push(interp_nodal(u, dofs, tab, q));
            }
        }
        Coefficient::Quad(vals)
    }

    /// Value at element `e`, quadrature point `q`.
    #[inline]
    pub fn at(&self, e: usize, q: usize, nq: usize) -> f64 {
        match self {
            Coefficient::Const(c) => *c,
            Coefficient::Quad(v) => v[e * nq + q],
        }
    }

    /// Apply `f` pointwise (for nonlinear reaction terms like
    /// `-ε²u(u²-1)` in Allen-Cahn).
    pub fn map(self, f: impl Fn(f64) -> f64) -> Coefficient {
        match self {
            Coefficient::Const(c) => Coefficient::Const(f(c)),
            Coefficient::Quad(v) => Coefficient::Quad(v.into_iter().map(f).collect()),
        }
    }
}

/// Bilinear forms `a(u, v)` supported by the Map stage.
#[derive(Clone, Debug)]
pub enum BilinearForm {
    /// `∫ ρ ∇u·∇v` — scalar diffusion/stiffness (Poisson, wave, AC).
    Diffusion { rho: Coefficient },
    /// `∫ ρ u v` — scalar mass (time-dependent problems).
    Mass { rho: Coefficient },
    /// `∫ λ (div u)(div v) + 2μ ε(u):ε(v)` — isotropic linear elasticity.
    /// Vector-valued with `ncomp = dim`; `e_mod` scales the whole tensor
    /// per element (SIMP density interpolation uses `Quad` here).
    Elasticity {
        lambda: f64,
        mu: f64,
        e_mod: Coefficient,
    },
    /// `∫_Γ α u v` — Robin boundary mass (assembled over facets).
    FacetMass { alpha: Coefficient },
}

impl BilinearForm {
    /// Vector components of the trial/test space.
    pub fn ncomp(&self, dim: usize) -> usize {
        match self {
            BilinearForm::Elasticity { .. } => dim,
            _ => 1,
        }
    }

    /// Does this form integrate over boundary facets rather than cells?
    pub fn is_facet(&self) -> bool {
        matches!(self, BilinearForm::FacetMass { .. })
    }
}

/// Linear functionals `ℓ(v)`.
#[derive(Clone, Debug)]
pub enum LinearForm {
    /// `∫ f v` — scalar source.
    Source { f: Coefficient },
    /// `∫ f·v` — constant vector body force (elasticity).
    VectorSource { f: Vec<f64> },
    /// `∫_Γ g v` — Neumann flux (or the Robin inhomogeneity αg).
    FacetFlux { g: Coefficient },
    /// `∫_Γ t·v` — vector surface traction (topology optimization load).
    FacetTraction { t: Vec<f64> },
}

impl LinearForm {
    pub fn ncomp(&self, dim: usize) -> usize {
        match self {
            LinearForm::VectorSource { .. } | LinearForm::FacetTraction { .. } => dim,
            _ => 1,
        }
    }

    pub fn is_facet(&self) -> bool {
        matches!(self, LinearForm::FacetFlux { .. } | LinearForm::FacetTraction { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fem::quadrature::tri_deg2;
    use crate::fem::reference::RefElement;
    use crate::fem::geometry;
    use crate::mesh::structured::unit_square_tri;

    #[test]
    fn coefficient_from_fn_matches_points() {
        let m = unit_square_tri(2);
        let quad = tri_deg2();
        let tab = RefElement::P1Tri.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let c = Coefficient::from_fn(&geo, |p| p[0] + 10.0 * p[1]);
        for e in 0..geo.n_elems {
            for q in 0..geo.q {
                let p = geo.qpoint(e, q);
                assert!((c.at(e, q, geo.q) - (p[0] + 10.0 * p[1])).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn nodal_interpolation_reproduces_linears() {
        // P1 interpolation of a linear function is exact at quad points.
        let m = unit_square_tri(3);
        let quad = tri_deg2();
        let tab = RefElement::P1Tri.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let u: Vec<f64> = (0..m.n_nodes())
            .map(|i| 2.0 * m.point(i)[0] - 3.0 * m.point(i)[1] + 0.5)
            .collect();
        let c = Coefficient::from_nodal(&u, &m.cells, &tab);
        for e in 0..geo.n_elems {
            for q in 0..geo.q {
                let p = geo.qpoint(e, q);
                let expect = 2.0 * p[0] - 3.0 * p[1] + 0.5;
                assert!((c.at(e, q, geo.q) - expect).abs() < 1e-13);
            }
        }
    }

    #[test]
    fn coefficient_map_applies_nonlinearity() {
        let c = Coefficient::Quad(vec![1.0, 2.0, -1.0]).map(|u| u * (u * u - 1.0));
        match c {
            Coefficient::Quad(v) => assert_eq!(v, vec![0.0, 6.0, 0.0]),
            _ => unreachable!(),
        }
    }
}
