//! The fused Map–Reduce assembly engine: zero-materialization tiles.
//!
//! The two-stage pipeline ([`super::local`] then [`super::routing`])
//! materializes the full local tensor `K_local ∈ R^{E×kl×kl}` between the
//! stages, so repeated assembly is bound by `O(E·kl²)` intermediate
//! write+read memory traffic rather than FLOPs. [`FusedPlan`] removes that
//! intermediate entirely: elements are partitioned into cache-sized tiles,
//! each tile is Mapped into a small scratch buffer (L1/L2-resident, reused
//! for every tile) and immediately Reduced through per-tile restrictions of
//! the routing gather lists. The full `E·kl²` tensor never exists. The Map
//! itself dispatches on the form once per *tile*, not once per element:
//! `local::fill_{matrix,vector}_tile` hoist the form `match` and run a
//! monomorphized per-form kernel over the tile's contiguous element range.
//!
//! # Determinism / bitwise-parity argument
//!
//! [`super::Routing`] accumulates every global target (a CSR nonzero or a
//! global DoF) by summing its flat local sources in ascending order. The
//! fused engine preserves exactly that order:
//!
//! * **Interior targets** — targets whose sources all come from one tile
//!   (tiles are contiguous element ranges and gather lists are sorted, so
//!   "first and last source in the same tile" is sufficient) — are gathered
//!   in-tile, reading the same sources in the same ascending order from the
//!   tile scratch. Each interior target is owned by exactly one tile, so
//!   parallel tiles write disjoint outputs with no atomics.
//! * **Boundary targets** — targets whose gather list spans ≥ 2 tiles —
//!   are *not* summed per-tile (per-tile partials would change the
//!   floating-point association). Instead each tile copies the boundary
//!   sources it owns into a persistent *halo* buffer (laid out in ascending
//!   global source order, so per-tile halo ranges are contiguous and
//!   disjoint), and a short fix-up pass then accumulates every boundary
//!   target from the halo in ascending source order — the identical
//!   sequential sum the two-stage Reduce performs.
//!
//! Both passes partition their outputs disjointly and the tile/chunk split
//! depends only on the cached thread count and problem size, never on OS
//! scheduling — so results are **bitwise identical** to the two-stage path
//! at any thread count (the same argument as `Routing` vs scatter-add
//! atomics, extended to tiling).
//!
//! # Workspaces
//!
//! All transient state (tile scratch, matrix/vector halos, per-element
//! scalar buffers of the separable plan) lives in an [`AssemblyWorkspace`]
//! that grows to a high-water mark and is then reused: repeated assembly —
//! scalar or the fused `S×E` batched variant — performs **zero heap
//! allocation** in steady state. [`super::AssemblyContext`] owns one behind
//! a mutex and routes every assembly call through it.

use crate::fem::geometry::ElementGeometry;
use crate::fem::reference::Tabulation;
use crate::util::threadpool::{self, SyncPtr};

use super::forms::{BilinearForm, LinearForm};
use super::local;
use super::routing::Routing;

/// Target tile-scratch footprint in `f64`s (256 KiB): big enough to
/// amortize per-tile bookkeeping, small enough to stay L2-resident while
/// the in-tile gather re-reads it randomly.
const TILE_BUDGET_F64: usize = 32 * 1024;

/// Reusable assembly scratch. Buffers only ever grow (to the workload's
/// high-water mark), so steady-state reuse is allocation-free.
#[derive(Debug, Default)]
pub struct AssemblyWorkspace {
    /// Per-task tile Map buffers, `n_tasks × tile_len` (matrix or vector).
    scratch: Vec<f64>,
    /// Cross-tile matrix sources, `S × n_halo`, ascending source order.
    halo: Vec<f64>,
    /// Cross-tile vector sources, `S × n_vhalo`.
    vhalo: Vec<f64>,
    /// Fused `S × E` per-element scalars (separable plans, SIMP moduli).
    pub scalars: Vec<f64>,
}

impl AssemblyWorkspace {
    pub fn new() -> AssemblyWorkspace {
        AssemblyWorkspace::default()
    }

    /// Grow-only slice of `buf` — the reuse primitive for every workspace
    /// buffer (never shrinks, so repeat calls allocate nothing).
    pub fn grown(buf: &mut Vec<f64>, len: usize) -> &mut [f64] {
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        &mut buf[..len]
    }
}

/// Per-tile restriction of a routing side (matrix targets or vector
/// targets): which targets each tile fully owns, and where the cross-tile
/// sources live in the halo buffer.
#[derive(Clone, Debug)]
struct TiledSide {
    /// `n_tiles + 1` — ranges into `int_targets`.
    int_tile_ptr: Vec<usize>,
    /// Targets fully owned by a tile, grouped by tile.
    int_targets: Vec<u32>,
    /// `n_tiles + 1` — per-tile contiguous ranges of the halo buffer.
    halo_tile_ptr: Vec<usize>,
    /// Tile-local flat source position of each halo slot.
    halo_local: Vec<u32>,
    /// Targets whose gather lists span tiles.
    bnd_targets: Vec<u32>,
    /// `bnd_targets.len() + 1` — ranges into `bnd_src`.
    bnd_ptr: Vec<usize>,
    /// Halo positions of each boundary target's sources (ascending, i.e.
    /// the exact summation order of the two-stage Reduce).
    bnd_src: Vec<u32>,
}

impl TiledSide {
    /// Partition one routing side. `ptr`/`src` are the gather lists,
    /// `tile_flat` the number of flat source slots per tile.
    fn build(
        ptr: &[usize],
        src: &[u32],
        n_targets: usize,
        n_tiles: usize,
        tile_flat: usize,
    ) -> TiledSide {
        let mut tile_targets: Vec<Vec<u32>> = vec![Vec::new(); n_tiles];
        let mut bnd_targets = Vec::new();
        for p in 0..n_targets {
            let lo = ptr[p];
            let hi = ptr[p + 1];
            if lo == hi {
                // Sourceless target (cannot occur for matrices; guards
                // hypothetical isolated DoFs): gather trivially in tile 0.
                tile_targets[0].push(p as u32);
                continue;
            }
            let t_first = src[lo] as usize / tile_flat;
            let t_last = src[hi - 1] as usize / tile_flat;
            if t_first == t_last {
                tile_targets[t_first].push(p as u32);
            } else {
                bnd_targets.push(p as u32);
            }
        }
        let mut int_tile_ptr = Vec::with_capacity(n_tiles + 1);
        int_tile_ptr.push(0);
        let mut int_targets = Vec::new();
        for list in &tile_targets {
            int_targets.extend_from_slice(list);
            int_tile_ptr.push(int_targets.len());
        }
        // Halo layout: all boundary sources in ascending global flat order
        // (each flat source is routed exactly once, so this is a bijection).
        let mut halo_global: Vec<u32> = Vec::new();
        for &p in &bnd_targets {
            halo_global.extend_from_slice(&src[ptr[p as usize]..ptr[p as usize + 1]]);
        }
        halo_global.sort_unstable();
        let mut bnd_ptr = Vec::with_capacity(bnd_targets.len() + 1);
        bnd_ptr.push(0);
        let mut bnd_src = Vec::with_capacity(halo_global.len());
        for &p in &bnd_targets {
            for &s in &src[ptr[p as usize]..ptr[p as usize + 1]] {
                let h = halo_global.binary_search(&s).expect("boundary source in halo");
                bnd_src.push(h as u32);
            }
            bnd_ptr.push(bnd_src.len());
        }
        let mut halo_tile_ptr = Vec::with_capacity(n_tiles + 1);
        halo_tile_ptr.push(0);
        for t in 0..n_tiles {
            let end = (t + 1) * tile_flat;
            let hi = halo_global.partition_point(|&s| (s as usize) < end);
            halo_tile_ptr.push(hi);
        }
        let halo_local: Vec<u32> = halo_global
            .iter()
            .map(|&s| (s as usize % tile_flat) as u32)
            .collect();
        TiledSide {
            int_tile_ptr,
            int_targets,
            halo_tile_ptr,
            halo_local,
            bnd_targets,
            bnd_ptr,
            bnd_src,
        }
    }

    fn halo_len(&self) -> usize {
        self.halo_local.len()
    }
}

/// Precomputed tiling of a [`Routing`]: element tiles plus the per-tile
/// target/halo restrictions for the matrix and vector sides. Built once per
/// topology (alongside the routing), reused for every assembly.
#[derive(Clone, Debug)]
pub struct FusedPlan {
    /// Elements per tile.
    pub tile: usize,
    pub n_tiles: usize,
    n_elems: usize,
    n_local: usize,
    mat: TiledSide,
    vec: TiledSide,
}

impl FusedPlan {
    /// Build with the default cache-sized tile.
    pub fn build(routing: &Routing, n_elems: usize) -> FusedPlan {
        let kl2 = routing.n_local * routing.n_local;
        let tile = (TILE_BUDGET_F64 / kl2.max(1)).max(16).min(n_elems.max(1));
        FusedPlan::with_tile(routing, n_elems, tile)
    }

    /// Build with an explicit tile size (tests force small tiles so the
    /// cross-tile fix-up path is exercised on small meshes).
    pub fn with_tile(routing: &Routing, n_elems: usize, tile: usize) -> FusedPlan {
        assert!(tile > 0, "tile must be positive");
        let kl = routing.n_local;
        let n_tiles = n_elems.div_ceil(tile).max(1);
        let mat = TiledSide::build(
            &routing.mat_ptr,
            &routing.mat_src,
            routing.nnz(),
            n_tiles,
            tile * kl * kl,
        );
        let vec = TiledSide::build(
            &routing.vec_ptr,
            &routing.vec_src,
            routing.n_dofs,
            n_tiles,
            tile * kl,
        );
        FusedPlan {
            tile,
            n_tiles,
            n_elems,
            n_local: kl,
            mat,
            vec,
        }
    }

    /// Number of cross-tile matrix sources (halo slots) — the only
    /// intermediate the fused path keeps, `O(tile surface)` not `O(E·kl²)`.
    pub fn halo_len(&self) -> usize {
        self.mat.halo_len()
    }

    /// Fused Map+Reduce for `S` bilinear forms into `S × nnz` instance-major
    /// values. Bitwise identical to `local_matrices_batch` followed by
    /// `Routing::reduce_matrix_batch_into` at any thread count; allocates
    /// nothing beyond the (grow-once) workspace.
    #[allow(clippy::too_many_arguments)]
    pub fn assemble_matrix_batch_into(
        &self,
        routing: &Routing,
        forms: &[BilinearForm],
        geo: &ElementGeometry,
        tab: &Tabulation,
        dim: usize,
        ws: &mut AssemblyWorkspace,
        data: &mut [f64],
    ) {
        assert!(!forms.is_empty(), "empty form batch");
        let ncomp = forms[0].ncomp(dim);
        for f in forms {
            assert_eq!(f.ncomp(dim), ncomp, "mixed ncomp in form batch");
        }
        let kl = tab.k * ncomp;
        assert_eq!(kl, self.n_local, "form kl does not match the plan");
        let s_n = forms.len();
        let nnz = routing.nnz();
        assert_eq!(data.len(), s_n * nnz, "output must be S × nnz");
        if self.n_elems == 0 {
            data.fill(0.0);
            return;
        }
        let const_grad = local::is_const_grad(tab);
        let tile_len = self.tile * kl * kl;
        let side = &self.mat;
        // Tile-level Map: the per-form dispatch happens once per tile
        // (`fill_matrix_tile` hoists the `match` out of the element loop
        // and runs a monomorphized kernel over the tile).
        self.run_tiles(
            s_n,
            tile_len,
            side,
            ws,
            nnz,
            data,
            |s, e0, buf| {
                local::fill_matrix_tile(
                    &forms[s],
                    const_grad,
                    e0,
                    kl * kl,
                    buf,
                    geo,
                    tab,
                    dim,
                    ncomp,
                )
            },
            |p| (routing.mat_ptr[p], routing.mat_ptr[p + 1]),
            &routing.mat_src,
        );
    }

    /// Fused Map+Reduce for `S` linear forms into `S × n_dofs` instance-
    /// major global vectors (bitwise identical to the two-stage path).
    #[allow(clippy::too_many_arguments)]
    pub fn assemble_vector_batch_into(
        &self,
        routing: &Routing,
        forms: &[LinearForm],
        geo: &ElementGeometry,
        tab: &Tabulation,
        dim: usize,
        ws: &mut AssemblyWorkspace,
        out: &mut [f64],
    ) {
        assert!(!forms.is_empty(), "empty form batch");
        let ncomp = forms[0].ncomp(dim);
        for f in forms {
            assert_eq!(f.ncomp(dim), ncomp, "mixed ncomp in form batch");
        }
        let kl = tab.k * ncomp;
        assert_eq!(kl, self.n_local, "form kl does not match the plan");
        let s_n = forms.len();
        let n = routing.n_dofs;
        assert_eq!(out.len(), s_n * n, "output must be S × n_dofs");
        if self.n_elems == 0 {
            out.fill(0.0);
            return;
        }
        let tile_len = self.tile * kl;
        // The vector halo reuses the matrix halo's sibling buffer so the
        // two sides never fight over one allocation high-water mark.
        let side = &self.vec;
        self.run_tiles_vec(
            s_n,
            tile_len,
            side,
            ws,
            n,
            out,
            |s, e0, buf| local::fill_vector_tile(&forms[s], e0, kl, buf, geo, tab, ncomp),
            |i| (routing.vec_ptr[i], routing.vec_ptr[i + 1]),
            &routing.vec_src,
        );
    }

    /// Tile driver for the matrix side. `fill(s, e0, buf)` Maps the whole
    /// zeroed tile starting at element `e0` (one slot per element) — form
    /// dispatch is the callee's, hoisted out of the element loop;
    /// `range(p)`/`src` are the routing gather lists.
    #[allow(clippy::too_many_arguments)]
    fn run_tiles(
        &self,
        s_n: usize,
        tile_len: usize,
        side: &TiledSide,
        ws: &mut AssemblyWorkspace,
        stride_out: usize,
        data: &mut [f64],
        fill: impl Fn(usize, usize, &mut [f64]) + Sync,
        range: impl Fn(usize) -> (usize, usize) + Sync,
        src: &[u32],
    ) {
        let threads = threadpool::default_threads();
        let total = s_n * self.n_tiles;
        let n_tasks = threadpool::n_chunks(total, threads);
        let scratch = AssemblyWorkspace::grown(&mut ws.scratch, n_tasks * tile_len);
        let halo_n = side.halo_len();
        let halo = AssemblyWorkspace::grown(&mut ws.halo, s_n * halo_n);

        let (tile, n_tiles, ne) = (self.tile, self.n_tiles, self.n_elems);
        let slot = tile_len / tile; // kl² (matrix) or kl (vector)
        debug_assert_eq!(slot * tile, tile_len);
        {
            let scratch_ptr = SyncPtr::new(scratch);
            let data_ptr = SyncPtr::new(data);
            let halo_ptr = SyncPtr::new(halo);
            threadpool::parallel_indexed_ranges(total, threads, |task, lo, hi| {
                // SAFETY: each task owns a disjoint scratch slice; interior
                // targets and halo ranges are disjoint across (s, tile).
                let buf = unsafe {
                    std::slice::from_raw_parts_mut(scratch_ptr.get().add(task * tile_len), tile_len)
                };
                for w in lo..hi {
                    #[cfg(feature = "fault-inject")]
                    crate::util::faults::maybe_panic(crate::util::faults::ASSEMBLY_TILE_PANIC, w);
                    let (s, t) = (w / n_tiles, w % n_tiles);
                    let e0 = t * tile;
                    let e1 = ((t + 1) * tile).min(ne);
                    let used = (e1 - e0) * slot;
                    buf[..used].fill(0.0);
                    // Map this tile (one monomorphized kernel call).
                    fill(s, e0, &mut buf[..used]);
                    // In-tile Reduce of fully-owned targets (ascending
                    // source order — identical to the two-stage gather).
                    let base = t * tile_len;
                    for &p in &side.int_targets[side.int_tile_ptr[t]..side.int_tile_ptr[t + 1]] {
                        let (plo, phi) = range(p as usize);
                        let mut acc = 0.0;
                        for &g in &src[plo..phi] {
                            acc += buf[g as usize - base];
                        }
                        unsafe { *data_ptr.get().add(s * stride_out + p as usize) = acc };
                    }
                    // Export this tile's cross-tile sources to the halo.
                    for h in side.halo_tile_ptr[t]..side.halo_tile_ptr[t + 1] {
                        let v = buf[side.halo_local[h] as usize];
                        unsafe { *halo_ptr.get().add(s * halo_n + h) = v };
                    }
                }
            });
        }
        // Fix-up: boundary targets, accumulated in ascending global source
        // order from the halo — the exact two-stage summation sequence.
        let n_bnd = side.bnd_targets.len();
        if n_bnd == 0 {
            return;
        }
        let halo: &[f64] = halo;
        let data_ptr = SyncPtr::new(data);
        threadpool::parallel_ranges(s_n * n_bnd, threads, |lo, hi| {
            for j in lo..hi {
                let (s, b) = (j / n_bnd, j % n_bnd);
                let p = side.bnd_targets[b] as usize;
                let mut acc = 0.0;
                for &h in &side.bnd_src[side.bnd_ptr[b]..side.bnd_ptr[b + 1]] {
                    acc += halo[s * halo_n + h as usize];
                }
                // SAFETY: boundary targets are disjoint from interior
                // targets and from each other.
                unsafe { *data_ptr.get().add(s * stride_out + p) = acc };
            }
        });
    }

    /// Vector-side twin of [`FusedPlan::run_tiles`] using the `vhalo`
    /// buffer (separate high-water mark from the matrix halo).
    #[allow(clippy::too_many_arguments)]
    fn run_tiles_vec(
        &self,
        s_n: usize,
        tile_len: usize,
        side: &TiledSide,
        ws: &mut AssemblyWorkspace,
        stride_out: usize,
        data: &mut [f64],
        fill: impl Fn(usize, usize, &mut [f64]) + Sync,
        range: impl Fn(usize) -> (usize, usize) + Sync,
        src: &[u32],
    ) {
        // Swap vhalo in as the halo buffer, run the shared driver, swap
        // back — keeps one driver implementation for both sides.
        std::mem::swap(&mut ws.halo, &mut ws.vhalo);
        self.run_tiles(s_n, tile_len, side, ws, stride_out, data, fill, range, src);
        std::mem::swap(&mut ws.halo, &mut ws.vhalo);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembly::forms::Coefficient;
    use crate::assembly::local::{local_matrices_batch, local_vectors_batch};
    use crate::fem::dofmap::DofMap;
    use crate::fem::geometry;
    use crate::mesh::structured::{jitter, unit_cube_tet, unit_square_tri};

    /// Fused assembly with tiny tiles (forcing many cross-tile boundary
    /// targets) must be bitwise identical to the two-stage path.
    #[test]
    fn tiny_tiles_match_two_stage_bitwise() {
        let mut m = unit_square_tri(5);
        jitter(&mut m, 0.2, 7);
        let ctx_quad = crate::assembly::map_reduce::default_quadrature(m.cell_type);
        let element = crate::fem::reference::RefElement::for_cell(m.cell_type);
        let tab = element.tabulate(&ctx_quad);
        let geo = geometry::compute(&m, &tab, &ctx_quad);
        let dm = DofMap::scalar(&m);
        let routing = Routing::build(&dm);
        let forms = vec![
            BilinearForm::Diffusion { rho: Coefficient::from_fn(&geo, |p| 1.0 + p[0] * p[1]) },
            BilinearForm::Mass { rho: Coefficient::Const(2.0) },
        ];
        let local = local_matrices_batch(&forms, &geo, &tab, 2);
        let mut oracle = vec![0.0; forms.len() * routing.nnz()];
        routing.reduce_matrix_batch_into(&local, forms.len(), &mut oracle);
        for tile in [1, 3, 7, 1000] {
            let plan = FusedPlan::with_tile(&routing, m.n_cells(), tile);
            let mut ws = AssemblyWorkspace::new();
            let mut fused = vec![0.0; forms.len() * routing.nnz()];
            plan.assemble_matrix_batch_into(&routing, &forms, &geo, &tab, 2, &mut ws, &mut fused);
            assert_eq!(fused, oracle, "tile={tile}");
            // Steady state: a second call through the same workspace must
            // reproduce the result exactly (buffer reuse is clean).
            plan.assemble_matrix_batch_into(&routing, &forms, &geo, &tab, 2, &mut ws, &mut fused);
            assert_eq!(fused, oracle, "tile={tile} repeat");
        }
    }

    #[test]
    fn tiny_tiles_match_two_stage_vectors() {
        let m = unit_cube_tet(3);
        let quad = crate::assembly::map_reduce::default_quadrature(m.cell_type);
        let element = crate::fem::reference::RefElement::for_cell(m.cell_type);
        let tab = element.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let routing = Routing::build(&DofMap::scalar(&m));
        let forms = vec![
            LinearForm::Source { f: Coefficient::from_fn(&geo, |p| p[0] - 2.0 * p[2]) },
            LinearForm::Source { f: Coefficient::Const(1.5) },
        ];
        let local = local_vectors_batch(&forms, &geo, &tab, 3);
        let oracle = routing.reduce_vector_batch(&local, forms.len());
        for tile in [2, 11, 4096] {
            let plan = FusedPlan::with_tile(&routing, m.n_cells(), tile);
            let mut ws = AssemblyWorkspace::new();
            let mut fused = vec![0.0; forms.len() * routing.n_dofs];
            plan.assemble_vector_batch_into(&routing, &forms, &geo, &tab, 3, &mut ws, &mut fused);
            assert_eq!(fused, oracle, "tile={tile}");
        }
    }

    /// Every routing target lands either in exactly one tile's interior
    /// list or in the boundary list, and halo slots biject with the
    /// boundary targets' sources.
    #[test]
    fn plan_partitions_targets_exactly_once() {
        let m = unit_square_tri(4);
        let routing = Routing::build(&DofMap::scalar(&m));
        let plan = FusedPlan::with_tile(&routing, m.n_cells(), 3);
        let side = &plan.mat;
        let mut seen = vec![false; routing.nnz()];
        for &p in &side.int_targets {
            assert!(!seen[p as usize], "target {p} in two tiles");
            seen[p as usize] = true;
        }
        for &p in &side.bnd_targets {
            assert!(!seen[p as usize], "target {p} interior AND boundary");
            seen[p as usize] = true;
        }
        assert!(seen.iter().all(|&b| b), "target uncovered");
        let n_bnd_srcs: usize = side
            .bnd_targets
            .iter()
            .map(|&p| routing.mat_ptr[p as usize + 1] - routing.mat_ptr[p as usize])
            .sum();
        assert_eq!(side.halo_len(), n_bnd_srcs);
        assert_eq!(*side.bnd_ptr.last().unwrap(), n_bnd_srcs);
        assert_eq!(*side.halo_tile_ptr.last().unwrap(), n_bnd_srcs);
    }

    #[test]
    fn default_tile_is_cache_sized() {
        let m = unit_square_tri(4);
        let routing = Routing::build(&DofMap::scalar(&m));
        let plan = FusedPlan::build(&routing, m.n_cells());
        let budget = super::TILE_BUDGET_F64.max(16 * 9);
        assert!(plan.tile * routing.n_local * routing.n_local <= budget);
        assert!(plan.tile >= 1);
        assert_eq!(plan.n_tiles, m.n_cells().div_ceil(plan.tile));
    }
}
