//! Stage I — Batch-Map: batched local element matrices and vectors.
//!
//! Computes the full local stiffness tensor `𝒦_local ∈ R^{E×kl×kl}`
//! (resp. `ℱ_local ∈ R^{E×kl}`) in one pass over a flat buffer — the native
//! reference implementation of Eq. (7)/(A.12). The AOT Pallas kernel
//! (`python/compile/kernels/local_assembly.py`) computes the identical
//! contraction; pytest checks them against the same pure-jnp oracle, and the
//! Rust integration tests check the PJRT-executed artifact against this
//! implementation.
//!
//! Parallelism: elements are partitioned across threads into disjoint
//! output slices — no atomics, deterministic for any thread count.

use crate::fem::geometry::ElementGeometry;
use crate::fem::reference::Tabulation;
use crate::util::threadpool;

use super::forms::{BilinearForm, LinearForm};

/// Batched local matrices for a bilinear form: returns `E × kl × kl`
/// (row-major) with `kl = k·ncomp`.
pub fn local_matrices(
    form: &BilinearForm,
    geo: &ElementGeometry,
    tab: &Tabulation,
    dim: usize,
) -> Vec<f64> {
    let k = tab.k;
    let nq = geo.q;
    let ncomp = form.ncomp(dim);
    let kl = k * ncomp;
    let mut out = vec![0.0; geo.n_elems * kl * kl];
    let threads = threadpool::default_threads();

    // §Perf: P1 simplices have quadrature-constant physical gradients, so
    // the basis contraction can be hoisted out of the q-loop (the weights ×
    // coefficient sum collapses to one scalar per element). Measured ~2.5×
    // on the 2D/3D diffusion Map stage (see EXPERIMENTS.md §Perf).
    let const_grad = matches!(
        tab.element,
        crate::fem::reference::RefElement::P1Tri | crate::fem::reference::RefElement::P1Tet
    );

    match form {
        BilinearForm::Diffusion { rho } if const_grad => {
            threadpool::for_each_row_mut(&mut out, kl * kl, threads, |e, ke| {
                let mut c = 0.0;
                for q in 0..nq {
                    c += geo.detj[e * nq + q] * quad_weight(tab, q) * rho.at(e, q, nq);
                }
                if c == 0.0 {
                    return;
                }
                for a in 0..k {
                    let ga = geo.grad(e, 0, a);
                    for b in a..k {
                        let gb = geo.grad(e, 0, b);
                        let mut dotg = 0.0;
                        for d in 0..dim {
                            dotg += ga[d] * gb[d];
                        }
                        let v = c * dotg;
                        ke[a * k + b] = v;
                        ke[b * k + a] = v;
                    }
                }
            });
        }
        BilinearForm::Diffusion { rho } => {
            threadpool::for_each_row_mut(&mut out, kl * kl, threads, |e, ke| {
                for q in 0..nq {
                    let w = geo.detj[e * nq + q] * quad_weight(tab, q);
                    if w == 0.0 {
                        continue;
                    }
                    let c = w * rho.at(e, q, nq);
                    for a in 0..k {
                        let ga = geo.grad(e, q, a);
                        for b in 0..k {
                            let gb = geo.grad(e, q, b);
                            let mut dotg = 0.0;
                            for d in 0..dim {
                                dotg += ga[d] * gb[d];
                            }
                            ke[a * k + b] += c * dotg;
                        }
                    }
                }
            });
        }
        BilinearForm::Mass { rho } => {
            threadpool::for_each_row_mut(&mut out, kl * kl, threads, |e, ke| {
                for q in 0..nq {
                    let w = geo.detj[e * nq + q] * quad_weight(tab, q);
                    if w == 0.0 {
                        continue;
                    }
                    let c = w * rho.at(e, q, nq);
                    for a in 0..k {
                        let pa = tab.val(q, a);
                        for b in 0..k {
                            ke[a * k + b] += c * pa * tab.val(q, b);
                        }
                    }
                }
            });
        }
        BilinearForm::Elasticity { lambda, mu, e_mod } if const_grad => {
            // Same hoisting for the (much heavier) elasticity contraction.
            let (lambda, mu) = (*lambda, *mu);
            threadpool::for_each_row_mut(&mut out, kl * kl, threads, |e, ke| {
                let mut scale = 0.0;
                for q in 0..nq {
                    scale += geo.detj[e * nq + q] * quad_weight(tab, q) * e_mod.at(e, q, nq);
                }
                if scale == 0.0 {
                    return;
                }
                for a in 0..k {
                    let ga = geo.grad(e, 0, a);
                    for b in 0..k {
                        let gb = geo.grad(e, 0, b);
                        let mut dotg = 0.0;
                        for d in 0..dim {
                            dotg += ga[d] * gb[d];
                        }
                        for i in 0..ncomp {
                            for j in 0..ncomp {
                                let mut v = lambda * ga[i] * gb[j] + mu * ga[j] * gb[i];
                                if i == j {
                                    v += mu * dotg;
                                }
                                ke[(a * ncomp + i) * kl + (b * ncomp + j)] = scale * v;
                            }
                        }
                    }
                }
            });
        }
        BilinearForm::Elasticity { lambda, mu, e_mod } => {
            let (lambda, mu) = (*lambda, *mu);
            threadpool::for_each_row_mut(&mut out, kl * kl, threads, |e, ke| {
                for q in 0..nq {
                    let w = geo.detj[e * nq + q] * quad_weight(tab, q);
                    if w == 0.0 {
                        continue;
                    }
                    let scale = w * e_mod.at(e, q, nq);
                    for a in 0..k {
                        let ga = geo.grad(e, q, a);
                        for b in 0..k {
                            let gb = geo.grad(e, q, b);
                            let mut dotg = 0.0;
                            for d in 0..dim {
                                dotg += ga[d] * gb[d];
                            }
                            // K[(a,i),(b,j)] += λ Ga[i] Gb[j]
                            //                 + μ (Ga[j] Gb[i] + δ_ij Ga·Gb)
                            for i in 0..ncomp {
                                for j in 0..ncomp {
                                    let mut v =
                                        lambda * ga[i] * gb[j] + mu * ga[j] * gb[i];
                                    if i == j {
                                        v += mu * dotg;
                                    }
                                    ke[(a * ncomp + i) * kl + (b * ncomp + j)] += scale * v;
                                }
                            }
                        }
                    }
                }
            });
        }
        BilinearForm::FacetMass { alpha } => {
            // Identical to Mass but `geo` is facet geometry (metric in detj).
            threadpool::for_each_row_mut(&mut out, kl * kl, threads, |e, ke| {
                for q in 0..nq {
                    let w = geo.detj[e * nq + q] * quad_weight(tab, q);
                    if w == 0.0 {
                        continue;
                    }
                    let c = w * alpha.at(e, q, nq);
                    for a in 0..k {
                        let pa = tab.val(q, a);
                        for b in 0..k {
                            ke[a * k + b] += c * pa * tab.val(q, b);
                        }
                    }
                }
            });
        }
    }
    out
}

/// Batched local load vectors for a linear form: returns `E × kl`.
pub fn local_vectors(
    form: &LinearForm,
    geo: &ElementGeometry,
    tab: &Tabulation,
    dim: usize,
) -> Vec<f64> {
    let k = tab.k;
    let nq = geo.q;
    let ncomp = form.ncomp(dim);
    let kl = k * ncomp;
    let mut out = vec![0.0; geo.n_elems * kl];
    let threads = threadpool::default_threads();

    match form {
        LinearForm::Source { f } | LinearForm::FacetFlux { g: f } => {
            threadpool::for_each_row_mut(&mut out, kl, threads, |e, fe| {
                for q in 0..nq {
                    let w = geo.detj[e * nq + q] * quad_weight(tab, q);
                    if w == 0.0 {
                        continue;
                    }
                    let c = w * f.at(e, q, nq);
                    for a in 0..k {
                        fe[a] += c * tab.val(q, a);
                    }
                }
            });
        }
        LinearForm::VectorSource { f } | LinearForm::FacetTraction { t: f } => {
            assert_eq!(f.len(), ncomp);
            let f = f.clone();
            threadpool::for_each_row_mut(&mut out, kl, threads, |e, fe| {
                for q in 0..nq {
                    let w = geo.detj[e * nq + q] * quad_weight(tab, q);
                    if w == 0.0 {
                        continue;
                    }
                    for a in 0..k {
                        let pa = w * tab.val(q, a);
                        for (i, fi) in f.iter().enumerate() {
                            fe[a * ncomp + i] += pa * fi;
                        }
                    }
                }
            });
        }
    }
    out
}

#[inline]
fn quad_weight(tab: &Tabulation, q: usize) -> f64 {
    tab.weights[q]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembly::forms::Coefficient;
    use crate::fem::geometry;
    use crate::fem::quadrature::{tet_deg2, tri_deg2};
    use crate::fem::reference::RefElement;
    use crate::mesh::structured::{unit_cube_tet, unit_square_tri};

    #[test]
    fn diffusion_local_matrix_reference_triangle() {
        // Unit right triangle (0,0),(1,0),(0,1):
        // K = 1/2 [[2,-1,-1],[-1,1,0],[-1,0,1]].
        let coords = vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0];
        let quad = tri_deg2();
        let tab = RefElement::P1Tri.tabulate(&quad);
        let geo = geometry::compute_from_coords(&coords, &tab, &quad, 2);
        let ke = local_matrices(
            &BilinearForm::Diffusion { rho: Coefficient::Const(1.0) },
            &geo,
            &tab,
            2,
        );
        let expect = [1.0, -0.5, -0.5, -0.5, 0.5, 0.0, -0.5, 0.0, 0.5];
        for (v, e) in ke.iter().zip(expect.iter()) {
            assert!((v - e).abs() < 1e-14, "{ke:?}");
        }
    }

    #[test]
    fn mass_matrix_row_sums_equal_area_third() {
        // Row sums of the P1 mass matrix equal |e|/3 (partition of unity).
        let m = unit_square_tri(2);
        let quad = tri_deg2();
        let tab = RefElement::P1Tri.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let me = local_matrices(
            &BilinearForm::Mass { rho: Coefficient::Const(1.0) },
            &geo,
            &tab,
            2,
        );
        let area = 0.125 / 2.0 * 2.0; // each cell area = 1/8
        for e in 0..m.n_cells() {
            for a in 0..3 {
                let s: f64 = (0..3).map(|b| me[e * 9 + a * 3 + b]).sum();
                assert!((s - area / 3.0 * 0.5 * 2.0).abs() < 1e-14, "s={s}");
            }
        }
    }

    #[test]
    fn stiffness_rows_sum_to_zero() {
        // ∇(Σφ)=0 ⇒ every row of any diffusion local matrix sums to 0.
        let m = unit_cube_tet(2);
        let quad = tet_deg2();
        let tab = RefElement::P1Tet.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let ke = local_matrices(
            &BilinearForm::Diffusion { rho: Coefficient::Const(3.0) },
            &geo,
            &tab,
            3,
        );
        for e in 0..m.n_cells() {
            for a in 0..4 {
                let s: f64 = (0..4).map(|b| ke[e * 16 + a * 4 + b]).sum();
                assert!(s.abs() < 1e-13);
            }
        }
    }

    #[test]
    fn elasticity_local_is_symmetric_and_psd_diag() {
        let m = unit_cube_tet(1);
        let quad = tet_deg2();
        let tab = RefElement::P1Tet.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let (lambda, mu) = (0.5769, 0.3846);
        let ke = local_matrices(
            &BilinearForm::Elasticity {
                lambda,
                mu,
                e_mod: Coefficient::Const(1.0),
            },
            &geo,
            &tab,
            3,
        );
        let kl = 12;
        for e in 0..m.n_cells() {
            let k = &ke[e * kl * kl..(e + 1) * kl * kl];
            for i in 0..kl {
                assert!(k[i * kl + i] >= 0.0, "negative diagonal");
                for j in 0..kl {
                    assert!((k[i * kl + j] - k[j * kl + i]).abs() < 1e-13, "asymmetry");
                }
            }
            // Rigid translation in x must be in the kernel.
            let mut ux = vec![0.0; kl];
            for a in 0..4 {
                ux[a * 3] = 1.0;
            }
            for i in 0..kl {
                let r: f64 = (0..kl).map(|j| k[i * kl + j] * ux[j]).sum();
                assert!(r.abs() < 1e-12, "translation not in kernel");
            }
        }
    }

    #[test]
    fn source_vector_total_equals_integral() {
        // Σ_ea (F_local)_ea = ∫ f over the domain (partition of unity).
        let m = unit_square_tri(4);
        let quad = tri_deg2();
        let tab = RefElement::P1Tri.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let fe = local_vectors(
            &LinearForm::Source { f: Coefficient::Const(2.0) },
            &geo,
            &tab,
            2,
        );
        let total: f64 = fe.iter().sum();
        assert!((total - 2.0).abs() < 1e-13);
    }

    #[test]
    fn vector_source_components() {
        let m = unit_cube_tet(2);
        let quad = tet_deg2();
        let tab = RefElement::P1Tet.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let fe = local_vectors(
            &LinearForm::VectorSource { f: vec![1.0, 2.0, 3.0] },
            &geo,
            &tab,
            3,
        );
        // Per-component totals = component × volume(=1).
        let mut totals = [0.0f64; 3];
        for (idx, v) in fe.iter().enumerate() {
            totals[idx % 3] += v;
        }
        assert!((totals[0] - 1.0).abs() < 1e-12);
        assert!((totals[1] - 2.0).abs() < 1e-12);
        assert!((totals[2] - 3.0).abs() < 1e-12);
    }
}
