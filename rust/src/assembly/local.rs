//! Stage I — Batch-Map: batched local element matrices and vectors.
//!
//! Computes the full local stiffness tensor `𝒦_local ∈ R^{E×kl×kl}`
//! (resp. `ℱ_local ∈ R^{E×kl}`) in one pass over a flat buffer — the native
//! reference implementation of Eq. (7)/(A.12). The AOT Pallas kernel
//! (`python/compile/kernels/local_assembly.py`) computes the identical
//! contraction; pytest checks them against the same pure-jnp oracle, and the
//! Rust integration tests check the PJRT-executed artifact against this
//! implementation.
//!
//! Parallelism: elements are partitioned across threads into disjoint
//! output slices — no atomics, deterministic for any thread count.

use crate::fem::geometry::ElementGeometry;
use crate::fem::reference::Tabulation;
use crate::util::threadpool;

use super::forms::{BilinearForm, LinearForm};

/// Batched local matrices for a bilinear form: returns `E × kl × kl`
/// (row-major) with `kl = k·ncomp`.
pub fn local_matrices(
    form: &BilinearForm,
    geo: &ElementGeometry,
    tab: &Tabulation,
    dim: usize,
) -> Vec<f64> {
    let k = tab.k;
    let ncomp = form.ncomp(dim);
    let kl = k * ncomp;
    let mut out = vec![0.0; geo.n_elems * kl * kl];
    let threads = threadpool::default_threads();

    // §Perf: P1 simplices have quadrature-constant physical gradients, so
    // the basis contraction can be hoisted out of the q-loop (the weights ×
    // coefficient sum collapses to one scalar per element). Measured ~2.5×
    // on the 2D/3D diffusion Map stage (see EXPERIMENTS.md §Perf). The
    // per-element bodies live in `fill_matrix_one`, shared with the
    // batched multi-instance driver.
    let const_grad = is_const_grad(tab);
    threadpool::for_each_row_mut(&mut out, kl * kl, threads, |e, ke| {
        fill_matrix_one(form, const_grad, e, ke, geo, tab, dim, ncomp);
    });
    out
}

/// Batched local load vectors for a linear form: returns `E × kl`.
pub fn local_vectors(
    form: &LinearForm,
    geo: &ElementGeometry,
    tab: &Tabulation,
    dim: usize,
) -> Vec<f64> {
    let k = tab.k;
    let ncomp = form.ncomp(dim);
    let kl = k * ncomp;
    let mut out = vec![0.0; geo.n_elems * kl];
    let threads = threadpool::default_threads();
    threadpool::for_each_row_mut(&mut out, kl, threads, |e, fe| {
        fill_vector_one(form, e, fe, geo, tab, ncomp);
    });
    out
}

/// Batched local matrices for `S` (possibly distinct) volumetric bilinear
/// forms over one shared geometry: the multi-instance Batch-Map. Returns
/// the fused `S × E × kl × kl` flat tensor, produced by a single parallel
/// pass over the fused `S·E` element range (one thread-scope for the whole
/// batch instead of one per instance).
///
/// The per-element bodies are shared with [`local_matrices`]
/// (`fill_matrix_one`), so slice `s` of the result is bitwise-identical to
/// `local_matrices(&forms[s], …)`. All forms must agree on `ncomp` (they
/// share the DoF layout).
pub fn local_matrices_batch(
    forms: &[BilinearForm],
    geo: &ElementGeometry,
    tab: &Tabulation,
    dim: usize,
) -> Vec<f64> {
    assert!(!forms.is_empty(), "empty form batch");
    let ncomp = forms[0].ncomp(dim);
    for f in forms {
        assert_eq!(f.ncomp(dim), ncomp, "mixed ncomp in form batch");
    }
    let k = tab.k;
    let kl = k * ncomp;
    let ne = geo.n_elems;
    let mut out = vec![0.0; forms.len() * ne * kl * kl];
    if ne == 0 {
        return out;
    }
    let threads = threadpool::default_threads();
    let const_grad = is_const_grad(tab);
    threadpool::for_each_row_mut(&mut out, kl * kl, threads, |r, ke| {
        let (s, e) = (r / ne, r % ne);
        fill_matrix_one(&forms[s], const_grad, e, ke, geo, tab, dim, ncomp);
    });
    out
}

/// Batched local vectors for `S` linear forms over one shared geometry:
/// fused `S × E × kl` flat tensor, one parallel pass. Slice `s` is
/// bitwise-identical to `local_vectors(&forms[s], …)`.
pub fn local_vectors_batch(
    forms: &[LinearForm],
    geo: &ElementGeometry,
    tab: &Tabulation,
    dim: usize,
) -> Vec<f64> {
    assert!(!forms.is_empty(), "empty form batch");
    let ncomp = forms[0].ncomp(dim);
    for f in forms {
        assert_eq!(f.ncomp(dim), ncomp, "mixed ncomp in form batch");
    }
    let k = tab.k;
    let kl = k * ncomp;
    let ne = geo.n_elems;
    let mut out = vec![0.0; forms.len() * ne * kl];
    if ne == 0 {
        return out;
    }
    let threads = threadpool::default_threads();
    threadpool::for_each_row_mut(&mut out, kl, threads, |r, fe| {
        let (s, e) = (r / ne, r % ne);
        fill_vector_one(&forms[s], e, fe, geo, tab, ncomp);
    });
    out
}

/// `∇φ_a·∇φ_b` over the first `dim` gradient components — the entry kernel
/// shared by every diffusion arm and the separable plan construction in
/// `map_reduce::AssemblyContext::batched` (one copy keeps them bitwise
/// consistent).
#[inline]
pub(crate) fn grad_dot(ga: &[f64], gb: &[f64], dim: usize) -> f64 {
    let mut dotg = 0.0;
    for d in 0..dim {
        dotg += ga[d] * gb[d];
    }
    dotg
}

/// Isotropic elasticity entry `λ Ga[i] Gb[j] + μ (Ga[j] Gb[i] + δ_ij Ga·Gb)`
/// — shared by both elasticity arms and the separable plan construction.
#[inline]
pub(crate) fn elasticity_entry(
    lambda: f64,
    mu: f64,
    ga: &[f64],
    gb: &[f64],
    dotg: f64,
    i: usize,
    j: usize,
) -> f64 {
    let mut v = lambda * ga[i] * gb[j] + mu * ga[j] * gb[i];
    if i == j {
        v += mu * dotg;
    }
    v
}

/// Quadrature-constant-gradient detection (P1 simplices) shared by every
/// Map driver, including the fused tile engine.
#[inline]
pub(crate) fn is_const_grad(tab: &Tabulation) -> bool {
    matches!(
        tab.element,
        crate::fem::reference::RefElement::P1Tri | crate::fem::reference::RefElement::P1Tet
    )
}

/// Per-form element kernels. Each is the body of one `match` arm of the
/// historical `fill_matrix_one`, extracted so the two dispatch styles —
/// per-element ([`fill_matrix_one`], the two-stage Map) and per-tile
/// ([`fill_matrix_tile`], the fused engine) — share one copy of the
/// arithmetic and therefore agree bitwise by construction.
#[inline]
fn diffusion_const_grad_elem(
    rho: &super::forms::Coefficient,
    e: usize,
    ke: &mut [f64],
    geo: &ElementGeometry,
    tab: &Tabulation,
    dim: usize,
) {
    let k = tab.k;
    let nq = geo.q;
    let mut c = 0.0;
    for q in 0..nq {
        c += geo.detj[e * nq + q] * quad_weight(tab, q) * rho.at(e, q, nq);
    }
    if c == 0.0 {
        return;
    }
    for a in 0..k {
        let ga = geo.grad(e, 0, a);
        for b in a..k {
            let v = c * grad_dot(ga, geo.grad(e, 0, b), dim);
            ke[a * k + b] = v;
            ke[b * k + a] = v;
        }
    }
}

#[inline]
fn diffusion_elem(
    rho: &super::forms::Coefficient,
    e: usize,
    ke: &mut [f64],
    geo: &ElementGeometry,
    tab: &Tabulation,
    dim: usize,
) {
    let k = tab.k;
    let nq = geo.q;
    for q in 0..nq {
        let w = geo.detj[e * nq + q] * quad_weight(tab, q);
        if w == 0.0 {
            continue;
        }
        let c = w * rho.at(e, q, nq);
        for a in 0..k {
            let ga = geo.grad(e, q, a);
            for b in 0..k {
                ke[a * k + b] += c * grad_dot(ga, geo.grad(e, q, b), dim);
            }
        }
    }
}

/// Shared by `Mass` and `FacetMass` (identical arithmetic, different
/// coefficient slot).
#[inline]
fn mass_elem(
    rho: &super::forms::Coefficient,
    e: usize,
    ke: &mut [f64],
    geo: &ElementGeometry,
    tab: &Tabulation,
) {
    let k = tab.k;
    let nq = geo.q;
    for q in 0..nq {
        let w = geo.detj[e * nq + q] * quad_weight(tab, q);
        if w == 0.0 {
            continue;
        }
        let c = w * rho.at(e, q, nq);
        for a in 0..k {
            let pa = tab.val(q, a);
            for b in 0..k {
                ke[a * k + b] += c * pa * tab.val(q, b);
            }
        }
    }
}

#[inline]
#[allow(clippy::too_many_arguments)]
fn elasticity_const_grad_elem(
    lambda: f64,
    mu: f64,
    e_mod: &super::forms::Coefficient,
    e: usize,
    ke: &mut [f64],
    geo: &ElementGeometry,
    tab: &Tabulation,
    dim: usize,
    ncomp: usize,
) {
    let k = tab.k;
    let nq = geo.q;
    let kl = k * ncomp;
    let mut scale = 0.0;
    for q in 0..nq {
        scale += geo.detj[e * nq + q] * quad_weight(tab, q) * e_mod.at(e, q, nq);
    }
    if scale == 0.0 {
        return;
    }
    for a in 0..k {
        let ga = geo.grad(e, 0, a);
        for b in 0..k {
            let gb = geo.grad(e, 0, b);
            let dotg = grad_dot(ga, gb, dim);
            for i in 0..ncomp {
                for j in 0..ncomp {
                    let v = elasticity_entry(lambda, mu, ga, gb, dotg, i, j);
                    ke[(a * ncomp + i) * kl + (b * ncomp + j)] = scale * v;
                }
            }
        }
    }
}

#[inline]
#[allow(clippy::too_many_arguments)]
fn elasticity_elem(
    lambda: f64,
    mu: f64,
    e_mod: &super::forms::Coefficient,
    e: usize,
    ke: &mut [f64],
    geo: &ElementGeometry,
    tab: &Tabulation,
    dim: usize,
    ncomp: usize,
) {
    let k = tab.k;
    let nq = geo.q;
    let kl = k * ncomp;
    for q in 0..nq {
        let w = geo.detj[e * nq + q] * quad_weight(tab, q);
        if w == 0.0 {
            continue;
        }
        let scale = w * e_mod.at(e, q, nq);
        for a in 0..k {
            let ga = geo.grad(e, q, a);
            for b in 0..k {
                let gb = geo.grad(e, q, b);
                let dotg = grad_dot(ga, gb, dim);
                for i in 0..ncomp {
                    for j in 0..ncomp {
                        let v = elasticity_entry(lambda, mu, ga, gb, dotg, i, j);
                        ke[(a * ncomp + i) * kl + (b * ncomp + j)] += scale * v;
                    }
                }
            }
        }
    }
}

/// One element of the Map stage — dispatches once and calls the shared
/// per-form kernel. Used by the per-element drivers ([`local_matrices`],
/// [`local_matrices_batch`]); the fused tile engine goes through
/// [`fill_matrix_tile`], which hoists this `match` out of the element
/// loop. `ke` must be zeroed.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fill_matrix_one(
    form: &BilinearForm,
    const_grad: bool,
    e: usize,
    ke: &mut [f64],
    geo: &ElementGeometry,
    tab: &Tabulation,
    dim: usize,
    ncomp: usize,
) {
    match form {
        BilinearForm::Diffusion { rho } if const_grad => {
            diffusion_const_grad_elem(rho, e, ke, geo, tab, dim)
        }
        BilinearForm::Diffusion { rho } => diffusion_elem(rho, e, ke, geo, tab, dim),
        BilinearForm::Mass { rho } => mass_elem(rho, e, ke, geo, tab),
        BilinearForm::Elasticity { lambda, mu, e_mod } if const_grad => {
            elasticity_const_grad_elem(*lambda, *mu, e_mod, e, ke, geo, tab, dim, ncomp)
        }
        BilinearForm::Elasticity { lambda, mu, e_mod } => {
            elasticity_elem(*lambda, *mu, e_mod, e, ke, geo, tab, dim, ncomp)
        }
        BilinearForm::FacetMass { alpha } => mass_elem(alpha, e, ke, geo, tab),
    }
}

/// Run a monomorphized per-element kernel over a contiguous element tile
/// (`slot` f64s per element in `buf`). Generic over the kernel closure, so
/// each call site below compiles to a direct loop with the form dispatch
/// hoisted entirely out of it.
#[inline]
fn for_tile(e0: usize, slot: usize, buf: &mut [f64], f: impl Fn(usize, &mut [f64])) {
    for (i, ke) in buf.chunks_exact_mut(slot).enumerate() {
        f(e0 + i, ke);
    }
}

/// Tile-level Map for one bilinear form: the form `match` runs once per
/// tile, then a monomorphized element loop fills `buf` (`(e1−e0) × slot`,
/// zeroed by the caller). Element `e` lands in the same slot with the same
/// bits as [`fill_matrix_one`] — the fused engine's parity contract with
/// the two-stage path is unchanged.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fill_matrix_tile(
    form: &BilinearForm,
    const_grad: bool,
    e0: usize,
    slot: usize,
    buf: &mut [f64],
    geo: &ElementGeometry,
    tab: &Tabulation,
    dim: usize,
    ncomp: usize,
) {
    match form {
        BilinearForm::Diffusion { rho } if const_grad => {
            for_tile(e0, slot, buf, |e, ke| diffusion_const_grad_elem(rho, e, ke, geo, tab, dim))
        }
        BilinearForm::Diffusion { rho } => {
            for_tile(e0, slot, buf, |e, ke| diffusion_elem(rho, e, ke, geo, tab, dim))
        }
        BilinearForm::Mass { rho } => {
            for_tile(e0, slot, buf, |e, ke| mass_elem(rho, e, ke, geo, tab))
        }
        BilinearForm::Elasticity { lambda, mu, e_mod } if const_grad => {
            let (lambda, mu) = (*lambda, *mu);
            for_tile(e0, slot, buf, |e, ke| {
                elasticity_const_grad_elem(lambda, mu, e_mod, e, ke, geo, tab, dim, ncomp)
            })
        }
        BilinearForm::Elasticity { lambda, mu, e_mod } => {
            let (lambda, mu) = (*lambda, *mu);
            for_tile(e0, slot, buf, |e, ke| {
                elasticity_elem(lambda, mu, e_mod, e, ke, geo, tab, dim, ncomp)
            })
        }
        BilinearForm::FacetMass { alpha } => {
            for_tile(e0, slot, buf, |e, ke| mass_elem(alpha, e, ke, geo, tab))
        }
    }
}

/// Scalar-source element kernel (shared by `Source` and `FacetFlux`).
#[inline]
fn source_elem(
    f: &super::forms::Coefficient,
    e: usize,
    fe: &mut [f64],
    geo: &ElementGeometry,
    tab: &Tabulation,
) {
    let k = tab.k;
    let nq = geo.q;
    for q in 0..nq {
        let w = geo.detj[e * nq + q] * quad_weight(tab, q);
        if w == 0.0 {
            continue;
        }
        let c = w * f.at(e, q, nq);
        for a in 0..k {
            fe[a] += c * tab.val(q, a);
        }
    }
}

/// Constant-vector element kernel (shared by `VectorSource` and
/// `FacetTraction`).
#[inline]
fn vector_source_elem(
    f: &[f64],
    e: usize,
    fe: &mut [f64],
    geo: &ElementGeometry,
    tab: &Tabulation,
    ncomp: usize,
) {
    assert_eq!(f.len(), ncomp);
    let k = tab.k;
    let nq = geo.q;
    for q in 0..nq {
        let w = geo.detj[e * nq + q] * quad_weight(tab, q);
        if w == 0.0 {
            continue;
        }
        for a in 0..k {
            let pa = w * tab.val(q, a);
            for (i, fi) in f.iter().enumerate() {
                fe[a * ncomp + i] += pa * fi;
            }
        }
    }
}

/// Per-element body of [`local_vectors`] (see [`fill_matrix_one`]).
pub(crate) fn fill_vector_one(
    form: &LinearForm,
    e: usize,
    fe: &mut [f64],
    geo: &ElementGeometry,
    tab: &Tabulation,
    ncomp: usize,
) {
    match form {
        LinearForm::Source { f } | LinearForm::FacetFlux { g: f } => {
            source_elem(f, e, fe, geo, tab)
        }
        LinearForm::VectorSource { f } | LinearForm::FacetTraction { t: f } => {
            vector_source_elem(f, e, fe, geo, tab, ncomp)
        }
    }
}

/// Tile-level twin of [`fill_vector_one`] (see [`fill_matrix_tile`]).
pub(crate) fn fill_vector_tile(
    form: &LinearForm,
    e0: usize,
    slot: usize,
    buf: &mut [f64],
    geo: &ElementGeometry,
    tab: &Tabulation,
    ncomp: usize,
) {
    match form {
        LinearForm::Source { f } | LinearForm::FacetFlux { g: f } => {
            for_tile(e0, slot, buf, |e, fe| source_elem(f, e, fe, geo, tab))
        }
        LinearForm::VectorSource { f } | LinearForm::FacetTraction { t: f } => {
            for_tile(e0, slot, buf, |e, fe| vector_source_elem(f, e, fe, geo, tab, ncomp))
        }
    }
}

#[inline]
fn quad_weight(tab: &Tabulation, q: usize) -> f64 {
    tab.weights[q]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembly::forms::Coefficient;
    use crate::fem::geometry;
    use crate::fem::quadrature::{tet_deg2, tri_deg2};
    use crate::fem::reference::RefElement;
    use crate::mesh::structured::{unit_cube_tet, unit_square_tri};

    #[test]
    fn diffusion_local_matrix_reference_triangle() {
        // Unit right triangle (0,0),(1,0),(0,1):
        // K = 1/2 [[2,-1,-1],[-1,1,0],[-1,0,1]].
        let coords = vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0];
        let quad = tri_deg2();
        let tab = RefElement::P1Tri.tabulate(&quad);
        let geo = geometry::compute_from_coords(&coords, &tab, &quad, 2);
        let ke = local_matrices(
            &BilinearForm::Diffusion { rho: Coefficient::Const(1.0) },
            &geo,
            &tab,
            2,
        );
        let expect = [1.0, -0.5, -0.5, -0.5, 0.5, 0.0, -0.5, 0.0, 0.5];
        for (v, e) in ke.iter().zip(expect.iter()) {
            assert!((v - e).abs() < 1e-14, "{ke:?}");
        }
    }

    #[test]
    fn mass_matrix_row_sums_equal_area_third() {
        // Row sums of the P1 mass matrix equal |e|/3 (partition of unity).
        let m = unit_square_tri(2);
        let quad = tri_deg2();
        let tab = RefElement::P1Tri.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let me = local_matrices(
            &BilinearForm::Mass { rho: Coefficient::Const(1.0) },
            &geo,
            &tab,
            2,
        );
        let area = 0.125 / 2.0 * 2.0; // each cell area = 1/8
        for e in 0..m.n_cells() {
            for a in 0..3 {
                let s: f64 = (0..3).map(|b| me[e * 9 + a * 3 + b]).sum();
                assert!((s - area / 3.0 * 0.5 * 2.0).abs() < 1e-14, "s={s}");
            }
        }
    }

    #[test]
    fn stiffness_rows_sum_to_zero() {
        // ∇(Σφ)=0 ⇒ every row of any diffusion local matrix sums to 0.
        let m = unit_cube_tet(2);
        let quad = tet_deg2();
        let tab = RefElement::P1Tet.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let ke = local_matrices(
            &BilinearForm::Diffusion { rho: Coefficient::Const(3.0) },
            &geo,
            &tab,
            3,
        );
        for e in 0..m.n_cells() {
            for a in 0..4 {
                let s: f64 = (0..4).map(|b| ke[e * 16 + a * 4 + b]).sum();
                assert!(s.abs() < 1e-13);
            }
        }
    }

    #[test]
    fn elasticity_local_is_symmetric_and_psd_diag() {
        let m = unit_cube_tet(1);
        let quad = tet_deg2();
        let tab = RefElement::P1Tet.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let (lambda, mu) = (0.5769, 0.3846);
        let ke = local_matrices(
            &BilinearForm::Elasticity {
                lambda,
                mu,
                e_mod: Coefficient::Const(1.0),
            },
            &geo,
            &tab,
            3,
        );
        let kl = 12;
        for e in 0..m.n_cells() {
            let k = &ke[e * kl * kl..(e + 1) * kl * kl];
            for i in 0..kl {
                assert!(k[i * kl + i] >= 0.0, "negative diagonal");
                for j in 0..kl {
                    assert!((k[i * kl + j] - k[j * kl + i]).abs() < 1e-13, "asymmetry");
                }
            }
            // Rigid translation in x must be in the kernel.
            let mut ux = vec![0.0; kl];
            for a in 0..4 {
                ux[a * 3] = 1.0;
            }
            for i in 0..kl {
                let r: f64 = (0..kl).map(|j| k[i * kl + j] * ux[j]).sum();
                assert!(r.abs() < 1e-12, "translation not in kernel");
            }
        }
    }

    #[test]
    fn batched_matrices_match_per_instance_map() {
        let m = unit_square_tri(3);
        let quad = tri_deg2();
        let tab = RefElement::P1Tri.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let nq = geo.q;
        // Heterogeneous batch: diffusion (const-grad fast path), mass, and
        // a spatially varying diffusion instance.
        let varying: Vec<f64> = (0..m.n_cells() * nq).map(|i| 1.0 + (i % 7) as f64 * 0.1).collect();
        let forms = vec![
            BilinearForm::Diffusion { rho: Coefficient::Const(2.0) },
            BilinearForm::Mass { rho: Coefficient::Const(1.5) },
            BilinearForm::Diffusion { rho: Coefficient::Quad(varying) },
        ];
        let batch = local_matrices_batch(&forms, &geo, &tab, 2);
        let per = m.n_cells() * 9;
        assert_eq!(batch.len(), forms.len() * per);
        for (s, form) in forms.iter().enumerate() {
            let single = local_matrices(form, &geo, &tab, 2);
            assert_eq!(&batch[s * per..(s + 1) * per], &single[..], "instance {s}");
        }
    }

    #[test]
    fn batched_elasticity_matches_per_instance_map() {
        let m = unit_cube_tet(2);
        let quad = tet_deg2();
        let tab = RefElement::P1Tet.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let forms = vec![
            BilinearForm::Elasticity { lambda: 0.5, mu: 0.4, e_mod: Coefficient::Const(1.0) },
            BilinearForm::Elasticity { lambda: 0.5, mu: 0.4, e_mod: Coefficient::Const(2.5) },
        ];
        let batch = local_matrices_batch(&forms, &geo, &tab, 3);
        let per = m.n_cells() * 144;
        for (s, form) in forms.iter().enumerate() {
            let single = local_matrices(form, &geo, &tab, 3);
            assert_eq!(&batch[s * per..(s + 1) * per], &single[..], "instance {s}");
        }
    }

    #[test]
    fn batched_vectors_match_per_instance_map() {
        let m = unit_square_tri(3);
        let quad = tri_deg2();
        let tab = RefElement::P1Tri.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let forms = vec![
            LinearForm::Source { f: Coefficient::Const(2.0) },
            LinearForm::Source { f: Coefficient::Const(-1.0) },
        ];
        let batch = local_vectors_batch(&forms, &geo, &tab, 2);
        let per = m.n_cells() * 3;
        for (s, form) in forms.iter().enumerate() {
            let single = local_vectors(form, &geo, &tab, 2);
            assert_eq!(&batch[s * per..(s + 1) * per], &single[..], "instance {s}");
        }
    }

    #[test]
    fn source_vector_total_equals_integral() {
        // Σ_ea (F_local)_ea = ∫ f over the domain (partition of unity).
        let m = unit_square_tri(4);
        let quad = tri_deg2();
        let tab = RefElement::P1Tri.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let fe = local_vectors(
            &LinearForm::Source { f: Coefficient::Const(2.0) },
            &geo,
            &tab,
            2,
        );
        let total: f64 = fe.iter().sum();
        assert!((total - 2.0).abs() < 1e-13);
    }

    #[test]
    fn vector_source_components() {
        let m = unit_cube_tet(2);
        let quad = tet_deg2();
        let tab = RefElement::P1Tet.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let fe = local_vectors(
            &LinearForm::VectorSource { f: vec![1.0, 2.0, 3.0] },
            &geo,
            &tab,
            3,
        );
        // Per-component totals = component × volume(=1).
        let mut totals = [0.0f64; 3];
        for (idx, v) in fe.iter().enumerate() {
            totals[idx % 3] += v;
        }
        assert!((totals[0] - 1.0).abs() < 1e-12);
        assert!((totals[1] - 2.0).abs() < 1e-12);
        assert!((totals[2] - 3.0).abs() < 1e-12);
    }
}
