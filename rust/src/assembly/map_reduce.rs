//! The TensorGalerkin engine: Batch-Map + Sparse-Reduce with cached
//! topology.
//!
//! [`AssemblyContext`] plays the role of the paper's "setup" phase
//! (Table 3): it tabulates the reference basis, computes batched geometry
//! and builds the routing matrices once. Every subsequent assembly — with
//! new coefficients, densities or time-step combinations — runs through the
//! **fused tile engine** ([`super::fused::FusedPlan`]): Map and Reduce are
//! interleaved per cache-sized element tile, so the full `E×kl²` local
//! tensor is never materialized and repeat calls do zero heap allocation
//! (transients live in the context's [`AssemblyWorkspace`]). The two-stage
//! path (explicit [`AssemblyContext::map_matrix`] +
//! [`AssemblyContext::reduce_matrix`], and the `*_two_stage` oracles) is
//! kept for externally produced Map results — when the PJRT runtime is
//! attached (phase 2) the Map stage can be executed by the AOT-compiled
//! Pallas kernel — and as the bitwise-parity reference in tests/benches.

use std::borrow::Cow;
use std::sync::Mutex;

use crate::fem::dofmap::DofMap;
use crate::fem::geometry::{self, ElementGeometry};
use crate::fem::quadrature::{self, Quadrature};
use crate::fem::reference::{RefElement, Tabulation};
use crate::mesh::{CellType, Mesh};
use crate::sparse::{Csr, CsrBatch};
use crate::util::threadpool;

use super::forms::{BilinearForm, Coefficient, LinearForm};
use super::fused::{AssemblyWorkspace, FusedPlan};
use super::local;
use super::routing::Routing;

/// Default volumetric quadrature for a cell type (exact for the P1/Q1
/// forms used in the paper's benchmarks).
pub fn default_quadrature(ct: CellType) -> Quadrature {
    match ct {
        CellType::Tri3 => quadrature::tri_deg2(),
        CellType::Tet4 => quadrature::tet_deg2(),
        CellType::Quad4 => quadrature::quad_gauss(2),
    }
}

/// Default facet quadrature.
pub fn default_facet_quadrature(ct: CellType) -> Quadrature {
    match ct {
        CellType::Tri3 | CellType::Quad4 => quadrature::edge_gauss(2),
        CellType::Tet4 => quadrature::tri_deg2(),
    }
}

/// Cached volumetric assembly state for one (mesh, ncomp) pair.
pub struct AssemblyContext {
    pub mesh: Mesh,
    pub ncomp: usize,
    pub dofmap: DofMap,
    pub quad: Quadrature,
    pub tab: Tabulation,
    pub geo: ElementGeometry,
    pub routing: Routing,
    /// Tiling of the routing for the fused zero-materialization engine.
    pub fused: FusedPlan,
    /// Grow-once scratch shared by every assembly call on this context
    /// (tile buffers, halos, per-element scalars) — repeat assemblies
    /// allocate nothing.
    workspace: Mutex<AssemblyWorkspace>,
}

impl AssemblyContext {
    /// Build the context (the paper's setup phase). `ncomp = 1` for scalar
    /// problems, `= dim` for elasticity.
    pub fn new(mesh: &Mesh, ncomp: usize) -> AssemblyContext {
        let quad = default_quadrature(mesh.cell_type);
        Self::with_quadrature(mesh, ncomp, quad)
    }

    /// Build with an explicit quadrature rule.
    pub fn with_quadrature(mesh: &Mesh, ncomp: usize, quad: Quadrature) -> AssemblyContext {
        let element = RefElement::for_cell(mesh.cell_type);
        let tab = element.tabulate(&quad);
        let geo = geometry::compute(mesh, &tab, &quad);
        let dofmap = if ncomp == 1 {
            DofMap::scalar(mesh)
        } else {
            DofMap::vector(mesh, ncomp)
        };
        let routing = Routing::build(&dofmap);
        let fused = FusedPlan::build(&routing, mesh.n_cells());
        AssemblyContext {
            mesh: mesh.clone(),
            ncomp,
            dofmap,
            quad,
            tab,
            geo,
            routing,
            fused,
            workspace: Mutex::new(AssemblyWorkspace::new()),
        }
    }

    /// Borrow the context's reusable assembly workspace (poisoning is
    /// recovered: a panic mid-assembly leaves only dirty scratch, which
    /// every entry point fully re-initializes).
    pub fn with_workspace<R>(&self, f: impl FnOnce(&mut AssemblyWorkspace) -> R) -> R {
        let mut ws = self.workspace.lock().unwrap_or_else(|e| e.into_inner());
        f(&mut ws)
    }

    pub fn n_dofs(&self) -> usize {
        self.dofmap.n_dofs
    }

    pub fn n_cells(&self) -> usize {
        self.mesh.n_cells()
    }

    /// Stage I only: batched local matrices (`E × kl × kl` flat).
    pub fn map_matrix(&self, form: &BilinearForm) -> Vec<f64> {
        assert!(!form.is_facet(), "facet form passed to volumetric context");
        assert_eq!(form.ncomp(self.mesh.dim), self.ncomp, "form/context ncomp mismatch");
        local::local_matrices(form, &self.geo, &self.tab, self.mesh.dim)
    }

    /// Stage I only: batched local vectors (`E × kl` flat).
    pub fn map_vector(&self, form: &LinearForm) -> Vec<f64> {
        assert!(!form.is_facet());
        assert_eq!(form.ncomp(self.mesh.dim), self.ncomp);
        local::local_vectors(form, &self.geo, &self.tab, self.mesh.dim)
    }

    /// Assemble the global matrix through the fused tile engine (bitwise
    /// identical to [`AssemblyContext::assemble_matrix_two_stage`], no
    /// `E×kl²` intermediate).
    pub fn assemble_matrix(&self, form: &BilinearForm) -> Csr {
        let mut k = self.pattern_matrix();
        self.assemble_matrix_into(form, &mut k.data);
        k
    }

    /// Fused assembly into preallocated CSR values (hot loop: zero heap
    /// allocation in steady state).
    pub fn assemble_matrix_into(&self, form: &BilinearForm, data: &mut [f64]) {
        self.assemble_matrix_batch_into(std::slice::from_ref(form), data);
    }

    /// Two-stage oracle: materialize the full local tensor, then Reduce.
    /// Kept as the parity/benchmark baseline for the fused engine.
    pub fn assemble_matrix_two_stage(&self, form: &BilinearForm) -> Csr {
        self.routing.reduce_matrix(&self.map_matrix(form))
    }

    /// Assemble the global load vector through the fused tile engine.
    pub fn assemble_vector(&self, form: &LinearForm) -> Vec<f64> {
        let mut out = vec![0.0; self.n_dofs()];
        self.assemble_vector_into(form, &mut out);
        out
    }

    /// Fused vector assembly into a preallocated output.
    pub fn assemble_vector_into(&self, form: &LinearForm, out: &mut [f64]) {
        self.assemble_vector_batch_into(std::slice::from_ref(form), out);
    }

    /// Two-stage vector oracle (see
    /// [`AssemblyContext::assemble_matrix_two_stage`]).
    pub fn assemble_vector_two_stage(&self, form: &LinearForm) -> Vec<f64> {
        self.routing.reduce_vector(&self.map_vector(form))
    }

    /// Stage I, batched: local matrices for `S` forms over this context's
    /// shared geometry (`S × E × kl²` flat), one fused parallel pass.
    pub fn map_matrix_batch(&self, forms: &[BilinearForm]) -> Vec<f64> {
        for form in forms {
            assert!(!form.is_facet(), "facet form passed to volumetric context");
            assert_eq!(form.ncomp(self.mesh.dim), self.ncomp, "form/context ncomp mismatch");
        }
        local::local_matrices_batch(forms, &self.geo, &self.tab, self.mesh.dim)
    }

    /// Batched fused assembly: `S` global matrices sharing one symbolic
    /// pattern (one `indptr`/`indices`, `S` value arrays). The generic
    /// multi-instance path — works for any mix of volumetric forms with
    /// this context's `ncomp`; see [`AssemblyContext::batched`] for the
    /// faster separable plan. Instance `s` is bitwise-identical to
    /// `assemble_matrix(&forms[s])` and to the two-stage oracle.
    pub fn assemble_matrix_batch(&self, forms: &[BilinearForm]) -> CsrBatch {
        let mut data = vec![0.0; forms.len() * self.routing.nnz()];
        self.assemble_matrix_batch_into(forms, &mut data);
        self.routing.csr_batch(data, forms.len())
    }

    /// Batched fused assembly into preallocated `S × nnz` instance-major
    /// values — the zero-allocation hot path for repeated re-assembly.
    pub fn assemble_matrix_batch_into(&self, forms: &[BilinearForm], data: &mut [f64]) {
        for form in forms {
            assert!(!form.is_facet(), "facet form passed to volumetric context");
            assert_eq!(form.ncomp(self.mesh.dim), self.ncomp, "form/context ncomp mismatch");
        }
        self.with_workspace(|ws| {
            self.fused.assemble_matrix_batch_into(
                &self.routing,
                forms,
                &self.geo,
                &self.tab,
                self.mesh.dim,
                ws,
                data,
            );
        });
    }

    /// Two-stage batched oracle (full `S×E×kl²` intermediate).
    pub fn assemble_matrix_batch_two_stage(&self, forms: &[BilinearForm]) -> CsrBatch {
        self.routing.reduce_matrix_batch(&self.map_matrix_batch(forms), forms.len())
    }

    /// Batched vector assembly: `S` load vectors through the fused tile
    /// engine (`S × N` flat, instance-major).
    pub fn assemble_vector_batch(&self, forms: &[LinearForm]) -> Vec<f64> {
        let mut out = vec![0.0; forms.len() * self.n_dofs()];
        self.assemble_vector_batch_into(forms, &mut out);
        out
    }

    /// Batched fused vector assembly into a preallocated `S × N` output.
    pub fn assemble_vector_batch_into(&self, forms: &[LinearForm], out: &mut [f64]) {
        for form in forms {
            assert!(!form.is_facet(), "facet form passed to volumetric context");
            assert_eq!(form.ncomp(self.mesh.dim), self.ncomp, "form/context ncomp mismatch");
        }
        self.with_workspace(|ws| {
            self.fused.assemble_vector_batch_into(
                &self.routing,
                forms,
                &self.geo,
                &self.tab,
                self.mesh.dim,
                ws,
                out,
            );
        });
    }

    /// Two-stage batched vector oracle.
    pub fn assemble_vector_batch_two_stage(&self, forms: &[LinearForm]) -> Vec<f64> {
        for form in forms {
            assert!(!form.is_facet(), "facet form passed to volumetric context");
            assert_eq!(form.ncomp(self.mesh.dim), self.ncomp, "form/context ncomp mismatch");
        }
        let local = local::local_vectors_batch(forms, &self.geo, &self.tab, self.mesh.dim);
        self.routing.reduce_vector_batch(&local, forms.len())
    }

    /// Separable batched-assembly plan for `form`: `Some` when the local
    /// matrix factors as `c_e(coefficient) · U_e` with coefficient-free
    /// `U_e` — the constant-gradient P1 simplex cases (diffusion and
    /// elasticity). The coefficient inside `form` is ignored; per-instance
    /// coefficients go to [`BatchedAssembly::assemble`]. Returns `None` for
    /// non-separable forms (fall back to
    /// [`AssemblyContext::assemble_matrix_batch`]).
    pub fn batched(&self, form: &BilinearForm) -> Option<BatchedAssembly<'_>> {
        let const_grad = matches!(self.tab.element, RefElement::P1Tri | RefElement::P1Tet);
        if !const_grad {
            return None;
        }
        assert_eq!(form.ncomp(self.mesh.dim), self.ncomp, "form/context ncomp mismatch");
        let dim = self.mesh.dim;
        let k = self.tab.k;
        let ne = self.n_cells();
        let threads = threadpool::default_threads();
        let unit = match form {
            BilinearForm::Diffusion { .. } => {
                // U_e[a,b] = ∇φ_a·∇φ_b (the hoisted dot products of the
                // native const-gradient arm, computed once per topology;
                // the entry kernel is shared with `local::fill_matrix_one`).
                let mut unit = vec![0.0; ne * k * k];
                threadpool::for_each_row_mut(&mut unit, k * k, threads, |e, ge| {
                    for a in 0..k {
                        let ga = self.geo.grad(e, 0, a);
                        for b in a..k {
                            let dotg = local::grad_dot(ga, self.geo.grad(e, 0, b), dim);
                            ge[a * k + b] = dotg;
                            ge[b * k + a] = dotg;
                        }
                    }
                });
                unit
            }
            BilinearForm::Elasticity { lambda, mu, .. } => {
                let (lambda, mu) = (*lambda, *mu);
                let ncomp = self.ncomp;
                let kl = k * ncomp;
                let mut unit = vec![0.0; ne * kl * kl];
                threadpool::for_each_row_mut(&mut unit, kl * kl, threads, |e, ve| {
                    for a in 0..k {
                        let ga = self.geo.grad(e, 0, a);
                        for b in 0..k {
                            let gb = self.geo.grad(e, 0, b);
                            let dotg = local::grad_dot(ga, gb, dim);
                            for i in 0..ncomp {
                                for j in 0..ncomp {
                                    ve[(a * ncomp + i) * kl + (b * ncomp + j)] =
                                        local::elasticity_entry(lambda, mu, ga, gb, dotg, i, j);
                                }
                            }
                        }
                    }
                });
                unit
            }
            _ => return None,
        };
        Some(self.batched_from_unit_local(&unit))
    }

    /// Separable plan from precomputed unit-coefficient local matrices
    /// (`E × kl²` flat) — e.g. SIMP's cached unit-modulus stiffness, where
    /// the per-instance scalars are the interpolated Young's moduli.
    pub fn batched_from_unit_local(&self, unit_local: &[f64]) -> BatchedAssembly<'_> {
        let kl = self.routing.n_local;
        let kl2 = kl * kl;
        assert_eq!(unit_local.len(), self.n_cells() * kl2, "unit local tensor shape");
        let weights: Vec<f64> =
            self.routing.mat_src.iter().map(|&s| unit_local[s as usize]).collect();
        let src_elem: Vec<u32> =
            self.routing.mat_src.iter().map(|&s| (s as usize / kl2) as u32).collect();
        BatchedAssembly {
            ctx: self,
            plan: Cow::Owned(BatchedPlan { weights, src_elem }),
        }
    }

    /// The owned separable plan for `form` (see
    /// [`AssemblyContext::batched`]) — `None` for non-separable forms.
    /// Cache it next to the context and rebind per batch with
    /// [`AssemblyContext::batched_cached`]; the unit-tensor Map then runs
    /// once per topology instead of once per call.
    pub fn batched_plan(&self, form: &BilinearForm) -> Option<BatchedPlan> {
        self.batched(form).map(BatchedAssembly::into_plan)
    }

    /// Rebind a cached [`BatchedPlan`] to this context (zero-copy).
    ///
    /// Contract: the plan must have been built from this context's
    /// topology AND the same bilinear form (including parameters such as
    /// elasticity's `lambda`/`mu`) — only the topology half is cheap
    /// enough to assert here, so rebinding a plan from a *different form*
    /// on the same context would silently assemble that other operator.
    /// Cache one plan per (context, form) pair, as
    /// [`crate::coordinator::BatchSolver`] does.
    pub fn batched_cached<'c>(&'c self, plan: &'c BatchedPlan) -> BatchedAssembly<'c> {
        assert_eq!(plan.weights.len(), self.routing.mat_src.len(), "plan/context mismatch");
        BatchedAssembly {
            ctx: self,
            plan: Cow::Borrowed(plan),
        }
    }

    /// Reduce externally produced local matrices (the PJRT-artifact Map
    /// path feeds this).
    pub fn reduce_matrix(&self, local: &[f64]) -> Csr {
        self.routing.reduce_matrix(local)
    }

    /// Reduce externally produced local vectors.
    pub fn reduce_vector(&self, local: &[f64]) -> Vec<f64> {
        self.routing.reduce_vector(local)
    }

    /// An empty global matrix sharing the cached pattern.
    pub fn pattern_matrix(&self) -> Csr {
        Csr {
            nrows: self.n_dofs(),
            ncols: self.n_dofs(),
            indptr: self.routing.pattern_indptr.clone(),
            indices: self.routing.pattern_indices.clone(),
            data: vec![0.0; self.routing.nnz()],
        }
    }

    /// Coefficient from a spatial function, evaluated at the cached
    /// physical quadrature points.
    pub fn coeff_fn(&self, f: impl Fn(&[f64]) -> f64) -> Coefficient {
        Coefficient::from_fn(&self.geo, f)
    }

    /// Coefficient interpolated from a nodal (scalar) field.
    pub fn coeff_nodal(&self, u: &[f64]) -> Coefficient {
        Coefficient::from_nodal(u, &self.mesh.cells, &self.tab)
    }
}

/// A separable batched-assembly plan: shared-topology Map-Reduce over `S`
/// problem instances.
///
/// For forms whose local matrix factors as `K_local[e] = c_e · U_e` with a
/// coefficient-independent `U_e` (P1 diffusion/elasticity, SIMP-scaled unit
/// stiffness), Map and Reduce collapse into one *weighted gather* per
/// instance: the unit values are gathered into routing order once, and each
/// assembly then costs a single pass over the `nnz` targets,
/// `K_s[p] = Σ_{j∈p} U[j] · c_s[elem(j)]`. Geometry, basis contraction and
/// routing index reads are all amortized across the batch — this is what
/// makes re-assembly with new coefficients scale with batch size instead of
/// call count (the paper's batch-generation regime, Fig B.4 / §B.4).
///
/// Per-term products and summation order match the native const-gradient
/// Map arms + [`Routing::reduce_matrix_into`], so every instance is
/// bitwise-identical to a sequential [`AssemblyContext::assemble_matrix`].
pub struct BatchedAssembly<'c> {
    ctx: &'c AssemblyContext,
    plan: Cow<'c, BatchedPlan>,
}

/// The owned data of a separable batched-assembly plan, detached from the
/// [`AssemblyContext`] borrow so long-lived owners (e.g. the coordinator's
/// per-mesh registry) can cache it next to the context and rebind with
/// [`AssemblyContext::batched_cached`] on every batch instead of paying the
/// `E × kl²` unit-tensor Map again per call.
#[derive(Clone, Debug)]
pub struct BatchedPlan {
    /// Unit local values gathered into `routing.mat_src` order.
    weights: Vec<f64>,
    /// Owning element of each gather source.
    src_elem: Vec<u32>,
}

impl<'c> BatchedAssembly<'c> {
    /// Detach the owned plan data (to cache; rebind later with
    /// [`AssemblyContext::batched_cached`]).
    pub fn into_plan(self) -> BatchedPlan {
        self.plan.into_owned()
    }
}

impl BatchedAssembly<'_> {
    /// Per-element scalars `c_e = Σ_q |det J| w · coeff(e, q)` — the
    /// coefficient collapse of the separable Map stage (bitwise-identical
    /// to the hoisted sum in the native const-gradient arms) — into a
    /// caller-owned buffer (zero allocation on repeat calls).
    pub fn element_scalars_into(&self, coeff: &Coefficient, out: &mut [f64]) {
        let geo = &self.ctx.geo;
        let weights_q = &self.ctx.tab.weights;
        let nq = geo.q;
        let ne = self.ctx.n_cells();
        assert_eq!(out.len(), ne, "scalar buffer must be E long");
        for (e, o) in out.iter_mut().enumerate() {
            let mut c = 0.0;
            for q in 0..nq {
                c += geo.detj[e * nq + q] * weights_q[q] * coeff.at(e, q, nq);
            }
            *o = c;
        }
    }

    /// Allocating convenience around
    /// [`BatchedAssembly::element_scalars_into`].
    pub fn element_scalars(&self, coeff: &Coefficient) -> Vec<f64> {
        let mut out = vec![0.0; self.ctx.n_cells()];
        self.element_scalars_into(coeff, &mut out);
        out
    }

    /// Per-element scalars for a *nodal* scalar field, skipping the
    /// quadrature-point materialization of [`Coefficient::from_nodal`]
    /// entirely: the interpolation `Σ_a u[g_e(a)] φ̂_a(x̂_q)` is folded
    /// into the collapse sum with the identical arithmetic order, so the
    /// result is bitwise-equal to
    /// `element_scalars(&ctx.coeff_nodal(u))` — without the fresh `E × Q`
    /// `Vec` per call (the coordinator's per-request path).
    pub fn element_scalars_nodal_into(&self, u: &[f64], out: &mut [f64]) {
        let ctx = self.ctx;
        assert_eq!(ctx.ncomp, 1, "nodal scalar collapse is a scalar-field path");
        let geo = &ctx.geo;
        let tab = &ctx.tab;
        let nq = geo.q;
        let k = tab.k;
        let ne = ctx.n_cells();
        assert_eq!(out.len(), ne, "scalar buffer must be E long");
        for (e, o) in out.iter_mut().enumerate() {
            let dofs = &ctx.mesh.cells[e * k..(e + 1) * k];
            let mut c = 0.0;
            for q in 0..nq {
                let s = super::forms::interp_nodal(u, dofs, tab, q);
                c += geo.detj[e * nq + q] * tab.weights[q] * s;
            }
            *o = c;
        }
    }

    /// Assemble `S` instances from flat `S × E` per-element scalars into
    /// preallocated `S × nnz` instance-major values — one fused parallel
    /// region over all `S × nnz` targets, zero heap allocation.
    pub fn assemble_scaled_into(&self, scalars: &[f64], data: &mut [f64]) {
        let ne = self.ctx.n_cells();
        assert!(ne > 0, "empty mesh");
        assert_eq!(scalars.len() % ne, 0, "scalars must be S × E flat");
        let n_instances = scalars.len() / ne;
        let routing = &self.ctx.routing;
        let nnz = routing.nnz();
        assert_eq!(data.len(), n_instances * nnz, "values must be S × nnz");
        let threads = threadpool::default_threads();
        threadpool::for_each_row_mut(data, 1, threads, |r, out| {
            let (s, p) = (r / nnz, r % nnz);
            let cs = &scalars[s * ne..(s + 1) * ne];
            let mut acc = 0.0;
            for j in routing.mat_ptr[p]..routing.mat_ptr[p + 1] {
                acc += self.plan.weights[j] * cs[self.plan.src_elem[j] as usize];
            }
            out[0] = acc;
        });
    }

    /// Assemble `S` instances from flat `S × E` per-element scalars into a
    /// fresh [`CsrBatch`] on the shared pattern.
    pub fn assemble_scaled(&self, scalars: &[f64]) -> CsrBatch {
        let ne = self.ctx.n_cells();
        assert!(ne > 0, "empty mesh");
        assert_eq!(scalars.len() % ne, 0, "scalars must be S × E flat");
        let n_instances = scalars.len() / ne;
        let mut data = vec![0.0; n_instances * self.ctx.routing.nnz()];
        self.assemble_scaled_into(scalars, &mut data);
        self.ctx.routing.csr_batch(data, n_instances)
    }

    /// Assemble `S` instances from per-instance coefficient fields. The
    /// coefficient collapse runs as one parallel pass over the fused
    /// `S × E` scalar range (same arithmetic as
    /// [`BatchedAssembly::element_scalars_into`]) through the context
    /// workspace — no per-call scalar allocation.
    pub fn assemble(&self, coeffs: &[Coefficient]) -> CsrBatch {
        let ne = self.ctx.n_cells();
        let mut data = vec![0.0; coeffs.len() * self.ctx.routing.nnz()];
        self.ctx.with_workspace(|ws| {
            let scalars = AssemblyWorkspace::grown(&mut ws.scalars, coeffs.len() * ne);
            let geo = &self.ctx.geo;
            let weights_q = &self.ctx.tab.weights;
            let nq = geo.q;
            let threads = threadpool::default_threads();
            threadpool::for_each_row_mut(scalars, 1, threads, |r, out| {
                let (s, e) = (r / ne, r % ne);
                let coeff = &coeffs[s];
                let mut c = 0.0;
                for q in 0..nq {
                    c += geo.detj[e * nq + q] * weights_q[q] * coeff.at(e, q, nq);
                }
                out[0] = c;
            });
            self.assemble_scaled_into(scalars, &mut data);
        });
        self.ctx.routing.csr_batch(data, coeffs.len())
    }

    /// Assemble `S` instances from `S` *nodal* coefficient fields without
    /// materializing any per-request quadrature `Vec`
    /// ([`BatchedAssembly::element_scalars_nodal_into`] through the
    /// context workspace). Bitwise-identical to
    /// `assemble(&[ctx.coeff_nodal(u_s), …])`.
    pub fn assemble_nodal<U: AsRef<[f64]>>(&self, nodal: &[U]) -> CsrBatch {
        let ne = self.ctx.n_cells();
        let mut data = vec![0.0; nodal.len() * self.ctx.routing.nnz()];
        self.ctx.with_workspace(|ws| {
            let scalars = AssemblyWorkspace::grown(&mut ws.scalars, nodal.len() * ne);
            for (s, u) in nodal.iter().enumerate() {
                self.element_scalars_nodal_into(u.as_ref(), &mut scalars[s * ne..(s + 1) * ne]);
            }
            self.assemble_scaled_into(scalars, &mut data);
        });
        self.ctx.routing.csr_batch(data, nodal.len())
    }

    /// Single-instance convenience through the amortized plan.
    pub fn assemble_one(&self, coeff: &Coefficient) -> Csr {
        self.assemble(std::slice::from_ref(coeff)).instance(0)
    }
}

/// Cached boundary-facet assembly state (Neumann/Robin contributions are
/// routed through the *same* Map-Reduce pipeline — batched facet einsum +
/// sparse boundary routing; no special-case code path, §B.1.5).
pub struct FacetContext {
    /// The facet ids (into `mesh.facets`) covered by this context.
    pub facet_ids: Vec<usize>,
    pub ncomp: usize,
    pub dofmap: DofMap,
    pub quad: Quadrature,
    pub tab: Tabulation,
    pub geo: ElementGeometry,
    pub routing: Routing,
    dim: usize,
}

impl FacetContext {
    /// Build over all boundary facets carrying one of `markers`.
    pub fn new(mesh: &Mesh, markers: &[u32], ncomp: usize) -> FacetContext {
        let facet_ids: Vec<usize> = (0..mesh.n_facets())
            .filter(|&f| markers.contains(&mesh.facet_markers[f]))
            .collect();
        let quad = default_facet_quadrature(mesh.cell_type);
        let element = RefElement::for_facet(mesh.cell_type);
        let tab = element.tabulate(&quad);
        let coords = geometry::gather_facet_coords(mesh, &facet_ids);
        let geo = geometry::compute_facets(&coords, &tab, &quad, mesh.dim);
        let dofmap = if ncomp == 1 {
            DofMap::facet_scalar(mesh, &facet_ids)
        } else {
            DofMap::facet_vector(mesh, &facet_ids, ncomp)
        };
        let routing = Routing::build(&dofmap);
        FacetContext {
            facet_ids,
            ncomp,
            dofmap,
            quad,
            tab,
            geo,
            routing,
            dim: mesh.dim,
        }
    }

    /// Assemble a facet bilinear form (Robin mass) into a global-size CSR.
    pub fn assemble_matrix(&self, form: &BilinearForm) -> Csr {
        assert!(form.is_facet());
        let local = local::local_matrices(form, &self.geo, &self.tab, self.dim);
        self.routing.reduce_matrix(&local)
    }

    /// Assemble a facet linear form (Neumann flux / traction) into a
    /// global-size vector.
    pub fn assemble_vector(&self, form: &LinearForm) -> Vec<f64> {
        assert!(form.is_facet());
        let local = local::local_vectors(form, &self.geo, &self.tab, self.dim);
        self.routing.reduce_vector(&local)
    }

    /// Coefficient from a spatial function at facet quadrature points.
    pub fn coeff_fn(&self, f: impl Fn(&[f64]) -> f64) -> Coefficient {
        Coefficient::from_fn(&self.geo, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembly::scatter;
    use crate::mesh::structured::{hollow_cube_tet, jitter, unit_cube_tet, unit_square_tri};
    use crate::mesh::marker;

    /// The central equivalence: Map-Reduce == scatter-add, to rounding.
    #[test]
    fn map_reduce_equals_scatter_add_poisson() {
        let mut m = unit_square_tri(6);
        jitter(&mut m, 0.2, 3);
        let ctx = AssemblyContext::new(&m, 1);
        let rho = ctx.coeff_fn(|p| 1.0 + p[0] * p[1]);
        let form = BilinearForm::Diffusion { rho };
        let k_mr = ctx.assemble_matrix(&form);
        let k_sc = scatter::assemble_matrix(&m, &ctx.dofmap, &form, &ctx.tab, &ctx.geo);
        assert_eq!(k_mr.indices, k_sc.indices);
        assert!(k_mr.frob_distance(&k_sc) < 1e-12);
    }

    #[test]
    fn map_reduce_equals_scatter_add_elasticity_3d() {
        let m = hollow_cube_tet(4);
        let ctx = AssemblyContext::new(&m, 3);
        let form = BilinearForm::Elasticity {
            lambda: 0.5769,
            mu: 0.3846,
            e_mod: Coefficient::Const(1.0),
        };
        let k_mr = ctx.assemble_matrix(&form);
        let k_sc = scatter::assemble_matrix(&m, &ctx.dofmap, &form, &ctx.tab, &ctx.geo);
        assert!(k_mr.frob_distance(&k_sc) < 1e-10);
    }

    #[test]
    fn vector_assembly_matches_scatter() {
        let m = unit_cube_tet(3);
        let ctx = AssemblyContext::new(&m, 1);
        let f = ctx.coeff_fn(|p| p[0] + 2.0 * p[1] + 3.0 * p[2]);
        let form = LinearForm::Source { f };
        let f_mr = ctx.assemble_vector(&form);
        let f_sc = scatter::assemble_vector(&m, &ctx.dofmap, &form, &ctx.tab, &ctx.geo);
        for (a, b) in f_mr.iter().zip(&f_sc) {
            assert!((a - b).abs() < 1e-13);
        }
    }

    #[test]
    fn assemble_into_reuses_pattern() {
        let m = unit_square_tri(4);
        let ctx = AssemblyContext::new(&m, 1);
        let mut k = ctx.pattern_matrix();
        let form = BilinearForm::Diffusion { rho: Coefficient::Const(2.0) };
        ctx.assemble_matrix_into(&form, &mut k.data);
        let fresh = ctx.assemble_matrix(&form);
        assert!(k.frob_distance(&fresh) < 1e-14);
    }

    #[test]
    fn batched_generic_assembly_matches_sequential() {
        let mut m = unit_square_tri(5);
        jitter(&mut m, 0.15, 11);
        let ctx = AssemblyContext::new(&m, 1);
        let forms = vec![
            BilinearForm::Diffusion { rho: ctx.coeff_fn(|p| 1.0 + p[0]) },
            BilinearForm::Mass { rho: Coefficient::Const(2.0) },
            BilinearForm::Diffusion { rho: Coefficient::Const(0.5) },
        ];
        let batch = ctx.assemble_matrix_batch(&forms);
        batch.check_invariants().unwrap();
        assert_eq!(batch.n_instances, 3);
        for (s, form) in forms.iter().enumerate() {
            let seq = ctx.assemble_matrix(form);
            assert_eq!(batch.indices, seq.indices, "instance {s} pattern");
            assert_eq!(batch.values(s), &seq.data[..], "instance {s} values");
        }
    }

    #[test]
    fn separable_plan_matches_sequential_diffusion() {
        let mut m = unit_square_tri(6);
        jitter(&mut m, 0.2, 3);
        let ctx = AssemblyContext::new(&m, 1);
        let plan = ctx
            .batched(&BilinearForm::Diffusion { rho: Coefficient::Const(1.0) })
            .expect("P1 triangles are separable");
        let coeffs: Vec<Coefficient> = (0..4)
            .map(|s| ctx.coeff_fn(move |p| 1.0 + 0.3 * s as f64 + p[0] * p[1]))
            .collect();
        let batch = plan.assemble(&coeffs);
        for (s, rho) in coeffs.iter().enumerate() {
            let seq = ctx.assemble_matrix(&BilinearForm::Diffusion { rho: rho.clone() });
            assert_eq!(batch.indices, seq.indices);
            assert_eq!(batch.values(s), &seq.data[..], "instance {s}");
        }
    }

    #[test]
    fn separable_plan_matches_sequential_elasticity() {
        let m = unit_cube_tet(2);
        let ctx = AssemblyContext::new(&m, 3);
        let (lambda, mu) = (0.5769, 0.3846);
        let proto = BilinearForm::Elasticity {
            lambda,
            mu,
            e_mod: Coefficient::Const(1.0),
        };
        let plan = ctx.batched(&proto).expect("P1 tets are separable");
        let coeffs =
            vec![Coefficient::Const(1.0), ctx.coeff_fn(|p| 1.0 + 0.5 * p[2])];
        let batch = plan.assemble(&coeffs);
        for (s, e_mod) in coeffs.iter().enumerate() {
            let seq = ctx.assemble_matrix(&BilinearForm::Elasticity {
                lambda,
                mu,
                e_mod: e_mod.clone(),
            });
            assert_eq!(batch.values(s), &seq.data[..], "instance {s}");
        }
    }

    #[test]
    fn cached_plan_rebinding_is_bitwise_fresh_plan() {
        let mut m = unit_square_tri(5);
        jitter(&mut m, 0.1, 7);
        let ctx = AssemblyContext::new(&m, 1);
        let proto = BilinearForm::Diffusion { rho: Coefficient::Const(1.0) };
        let owned = ctx.batched_plan(&proto).expect("P1 triangles are separable");
        let coeffs: Vec<Coefficient> =
            (0..3).map(|s| ctx.coeff_fn(move |p| 1.0 + 0.2 * s as f64 + p[1])).collect();
        let fresh = ctx.batched(&proto).unwrap().assemble(&coeffs);
        let cached = ctx.batched_cached(&owned).assemble(&coeffs);
        assert_eq!(fresh.data, cached.data);
        // The rebound plan also serves the nodal collapse path.
        let nodal: Vec<Vec<f64>> = (0..2)
            .map(|s| (0..ctx.n_dofs()).map(|i| 1.0 + (i + s) as f64 * 1e-3).collect())
            .collect();
        let a = ctx.batched(&proto).unwrap().assemble_nodal(&nodal);
        let b = ctx.batched_cached(&owned).assemble_nodal(&nodal);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn separable_plan_unavailable_for_quads() {
        // Q1 gradients vary over the cell: no constant-gradient factoring.
        let m = crate::mesh::structured::rect_quad(4, 2, 4.0, 2.0);
        let ctx = AssemblyContext::new(&m, 1);
        assert!(ctx
            .batched(&BilinearForm::Diffusion { rho: Coefficient::Const(1.0) })
            .is_none());
    }

    #[test]
    fn batched_vector_assembly_matches_sequential() {
        let m = unit_cube_tet(2);
        let ctx = AssemblyContext::new(&m, 1);
        let forms = vec![
            LinearForm::Source { f: ctx.coeff_fn(|p| p[0] + p[1]) },
            LinearForm::Source { f: Coefficient::Const(3.0) },
        ];
        let fbatch = ctx.assemble_vector_batch(&forms);
        let n = ctx.n_dofs();
        for (s, form) in forms.iter().enumerate() {
            let seq = ctx.assemble_vector(form);
            assert_eq!(&fbatch[s * n..(s + 1) * n], &seq[..], "instance {s}");
        }
    }

    #[test]
    fn facet_mass_measures_boundary_length() {
        // Σ_ij (facet mass)_ij = ∫_∂Ω 1 = perimeter = 4.
        let m = unit_square_tri(8);
        let fc = FacetContext::new(&m, &[marker::BOUNDARY], 1);
        let robin = fc.assemble_matrix(&BilinearForm::FacetMass {
            alpha: Coefficient::Const(1.0),
        });
        let total: f64 = robin.data.iter().sum();
        assert!((total - 4.0).abs() < 1e-12, "perimeter {total}");
    }

    #[test]
    fn facet_flux_measures_marked_portion() {
        let mut m = unit_square_tri(8);
        m.mark_boundary(|c| if c[1] < 1e-12 { marker::NEUMANN } else { marker::DIRICHLET });
        let fc = FacetContext::new(&m, &[marker::NEUMANN], 1);
        let g = fc.assemble_vector(&LinearForm::FacetFlux {
            g: Coefficient::Const(5.0),
        });
        let total: f64 = g.iter().sum();
        assert!((total - 5.0).abs() < 1e-12, "bottom edge flux {total}");
        // Only bottom-edge nodes receive contributions.
        for (i, &v) in g.iter().enumerate() {
            if v != 0.0 {
                assert!(m.point(i)[1].abs() < 1e-12);
            }
        }
    }

    #[test]
    fn facet_traction_vector_components() {
        let m = crate::mesh::structured::rect_quad(6, 3, 60.0, 30.0);
        let mut m = m;
        m.mark_boundary(|c| {
            if (c[0] - 60.0).abs() < 1e-9 && c[1] < 10.0 {
                marker::NEUMANN
            } else {
                marker::DIRICHLET
            }
        });
        let fc = FacetContext::new(&m, &[marker::NEUMANN], 2);
        let t = fc.assemble_vector(&LinearForm::FacetTraction { t: vec![0.0, -100.0] });
        let total_y: f64 = t.iter().skip(1).step_by(2).sum();
        // One edge of length 10 under ty=-100 → total -1000.
        assert!((total_y + 1000.0).abs() < 1e-9, "total_y={total_y}");
    }
}
