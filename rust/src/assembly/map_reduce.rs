//! The TensorGalerkin engine: Batch-Map + Sparse-Reduce with cached
//! topology.
//!
//! [`AssemblyContext`] plays the role of the paper's "setup" phase
//! (Table 3): it tabulates the reference basis, computes batched geometry
//! and builds the routing matrices once. Every subsequent assembly — with
//! new coefficients, densities or time-step combinations — is two monolithic
//! operations: one batched local contraction (Map) and one routing product
//! (Reduce). When the PJRT runtime is attached (phase 2), the Map stage can
//! be executed by the AOT-compiled Pallas kernel instead of the native code;
//! the Reduce stage is identical for both backends.

use crate::fem::dofmap::DofMap;
use crate::fem::geometry::{self, ElementGeometry};
use crate::fem::quadrature::{self, Quadrature};
use crate::fem::reference::{RefElement, Tabulation};
use crate::mesh::{CellType, Mesh};
use crate::sparse::Csr;

use super::forms::{BilinearForm, Coefficient, LinearForm};
use super::local;
use super::routing::Routing;

/// Default volumetric quadrature for a cell type (exact for the P1/Q1
/// forms used in the paper's benchmarks).
pub fn default_quadrature(ct: CellType) -> Quadrature {
    match ct {
        CellType::Tri3 => quadrature::tri_deg2(),
        CellType::Tet4 => quadrature::tet_deg2(),
        CellType::Quad4 => quadrature::quad_gauss(2),
    }
}

/// Default facet quadrature.
pub fn default_facet_quadrature(ct: CellType) -> Quadrature {
    match ct {
        CellType::Tri3 | CellType::Quad4 => quadrature::edge_gauss(2),
        CellType::Tet4 => quadrature::tri_deg2(),
    }
}

/// Cached volumetric assembly state for one (mesh, ncomp) pair.
pub struct AssemblyContext {
    pub mesh: Mesh,
    pub ncomp: usize,
    pub dofmap: DofMap,
    pub quad: Quadrature,
    pub tab: Tabulation,
    pub geo: ElementGeometry,
    pub routing: Routing,
}

impl AssemblyContext {
    /// Build the context (the paper's setup phase). `ncomp = 1` for scalar
    /// problems, `= dim` for elasticity.
    pub fn new(mesh: &Mesh, ncomp: usize) -> AssemblyContext {
        let quad = default_quadrature(mesh.cell_type);
        Self::with_quadrature(mesh, ncomp, quad)
    }

    /// Build with an explicit quadrature rule.
    pub fn with_quadrature(mesh: &Mesh, ncomp: usize, quad: Quadrature) -> AssemblyContext {
        let element = RefElement::for_cell(mesh.cell_type);
        let tab = element.tabulate(&quad);
        let geo = geometry::compute(mesh, &tab, &quad);
        let dofmap = if ncomp == 1 {
            DofMap::scalar(mesh)
        } else {
            DofMap::vector(mesh, ncomp)
        };
        let routing = Routing::build(&dofmap);
        AssemblyContext {
            mesh: mesh.clone(),
            ncomp,
            dofmap,
            quad,
            tab,
            geo,
            routing,
        }
    }

    pub fn n_dofs(&self) -> usize {
        self.dofmap.n_dofs
    }

    pub fn n_cells(&self) -> usize {
        self.mesh.n_cells()
    }

    /// Stage I only: batched local matrices (`E × kl × kl` flat).
    pub fn map_matrix(&self, form: &BilinearForm) -> Vec<f64> {
        assert!(!form.is_facet(), "facet form passed to volumetric context");
        assert_eq!(form.ncomp(self.mesh.dim), self.ncomp, "form/context ncomp mismatch");
        local::local_matrices(form, &self.geo, &self.tab, self.mesh.dim)
    }

    /// Stage I only: batched local vectors (`E × kl` flat).
    pub fn map_vector(&self, form: &LinearForm) -> Vec<f64> {
        assert!(!form.is_facet());
        assert_eq!(form.ncomp(self.mesh.dim), self.ncomp);
        local::local_vectors(form, &self.geo, &self.tab, self.mesh.dim)
    }

    /// Map + Reduce: assemble the global matrix.
    pub fn assemble_matrix(&self, form: &BilinearForm) -> Csr {
        self.routing.reduce_matrix(&self.map_matrix(form))
    }

    /// Map + Reduce into preallocated CSR values (hot loop: zero alloc for
    /// the global matrix).
    pub fn assemble_matrix_into(&self, form: &BilinearForm, data: &mut [f64]) {
        self.routing.reduce_matrix_into(&self.map_matrix(form), data);
    }

    /// Map + Reduce: assemble the global load vector.
    pub fn assemble_vector(&self, form: &LinearForm) -> Vec<f64> {
        self.routing.reduce_vector(&self.map_vector(form))
    }

    /// Reduce externally produced local matrices (the PJRT-artifact Map
    /// path feeds this).
    pub fn reduce_matrix(&self, local: &[f64]) -> Csr {
        self.routing.reduce_matrix(local)
    }

    /// Reduce externally produced local vectors.
    pub fn reduce_vector(&self, local: &[f64]) -> Vec<f64> {
        self.routing.reduce_vector(local)
    }

    /// An empty global matrix sharing the cached pattern.
    pub fn pattern_matrix(&self) -> Csr {
        Csr {
            nrows: self.n_dofs(),
            ncols: self.n_dofs(),
            indptr: self.routing.pattern_indptr.clone(),
            indices: self.routing.pattern_indices.clone(),
            data: vec![0.0; self.routing.nnz()],
        }
    }

    /// Coefficient from a spatial function, evaluated at the cached
    /// physical quadrature points.
    pub fn coeff_fn(&self, f: impl Fn(&[f64]) -> f64) -> Coefficient {
        Coefficient::from_fn(&self.geo, f)
    }

    /// Coefficient interpolated from a nodal (scalar) field.
    pub fn coeff_nodal(&self, u: &[f64]) -> Coefficient {
        Coefficient::from_nodal(u, &self.mesh.cells, &self.tab)
    }
}

/// Cached boundary-facet assembly state (Neumann/Robin contributions are
/// routed through the *same* Map-Reduce pipeline — batched facet einsum +
/// sparse boundary routing; no special-case code path, §B.1.5).
pub struct FacetContext {
    /// The facet ids (into `mesh.facets`) covered by this context.
    pub facet_ids: Vec<usize>,
    pub ncomp: usize,
    pub dofmap: DofMap,
    pub quad: Quadrature,
    pub tab: Tabulation,
    pub geo: ElementGeometry,
    pub routing: Routing,
    dim: usize,
}

impl FacetContext {
    /// Build over all boundary facets carrying one of `markers`.
    pub fn new(mesh: &Mesh, markers: &[u32], ncomp: usize) -> FacetContext {
        let facet_ids: Vec<usize> = (0..mesh.n_facets())
            .filter(|&f| markers.contains(&mesh.facet_markers[f]))
            .collect();
        let quad = default_facet_quadrature(mesh.cell_type);
        let element = RefElement::for_facet(mesh.cell_type);
        let tab = element.tabulate(&quad);
        let coords = geometry::gather_facet_coords(mesh, &facet_ids);
        let geo = geometry::compute_facets(&coords, &tab, &quad, mesh.dim);
        let dofmap = if ncomp == 1 {
            DofMap::facet_scalar(mesh, &facet_ids)
        } else {
            DofMap::facet_vector(mesh, &facet_ids, ncomp)
        };
        let routing = Routing::build(&dofmap);
        FacetContext {
            facet_ids,
            ncomp,
            dofmap,
            quad,
            tab,
            geo,
            routing,
            dim: mesh.dim,
        }
    }

    /// Assemble a facet bilinear form (Robin mass) into a global-size CSR.
    pub fn assemble_matrix(&self, form: &BilinearForm) -> Csr {
        assert!(form.is_facet());
        let local = local::local_matrices(form, &self.geo, &self.tab, self.dim);
        self.routing.reduce_matrix(&local)
    }

    /// Assemble a facet linear form (Neumann flux / traction) into a
    /// global-size vector.
    pub fn assemble_vector(&self, form: &LinearForm) -> Vec<f64> {
        assert!(form.is_facet());
        let local = local::local_vectors(form, &self.geo, &self.tab, self.dim);
        self.routing.reduce_vector(&local)
    }

    /// Coefficient from a spatial function at facet quadrature points.
    pub fn coeff_fn(&self, f: impl Fn(&[f64]) -> f64) -> Coefficient {
        Coefficient::from_fn(&self.geo, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembly::scatter;
    use crate::mesh::structured::{hollow_cube_tet, jitter, unit_cube_tet, unit_square_tri};
    use crate::mesh::marker;

    /// The central equivalence: Map-Reduce == scatter-add, to rounding.
    #[test]
    fn map_reduce_equals_scatter_add_poisson() {
        let mut m = unit_square_tri(6);
        jitter(&mut m, 0.2, 3);
        let ctx = AssemblyContext::new(&m, 1);
        let rho = ctx.coeff_fn(|p| 1.0 + p[0] * p[1]);
        let form = BilinearForm::Diffusion { rho };
        let k_mr = ctx.assemble_matrix(&form);
        let k_sc = scatter::assemble_matrix(&m, &ctx.dofmap, &form, &ctx.tab, &ctx.geo);
        assert_eq!(k_mr.indices, k_sc.indices);
        assert!(k_mr.frob_distance(&k_sc) < 1e-12);
    }

    #[test]
    fn map_reduce_equals_scatter_add_elasticity_3d() {
        let m = hollow_cube_tet(4);
        let ctx = AssemblyContext::new(&m, 3);
        let form = BilinearForm::Elasticity {
            lambda: 0.5769,
            mu: 0.3846,
            e_mod: Coefficient::Const(1.0),
        };
        let k_mr = ctx.assemble_matrix(&form);
        let k_sc = scatter::assemble_matrix(&m, &ctx.dofmap, &form, &ctx.tab, &ctx.geo);
        assert!(k_mr.frob_distance(&k_sc) < 1e-10);
    }

    #[test]
    fn vector_assembly_matches_scatter() {
        let m = unit_cube_tet(3);
        let ctx = AssemblyContext::new(&m, 1);
        let f = ctx.coeff_fn(|p| p[0] + 2.0 * p[1] + 3.0 * p[2]);
        let form = LinearForm::Source { f };
        let f_mr = ctx.assemble_vector(&form);
        let f_sc = scatter::assemble_vector(&m, &ctx.dofmap, &form, &ctx.tab, &ctx.geo);
        for (a, b) in f_mr.iter().zip(&f_sc) {
            assert!((a - b).abs() < 1e-13);
        }
    }

    #[test]
    fn assemble_into_reuses_pattern() {
        let m = unit_square_tri(4);
        let ctx = AssemblyContext::new(&m, 1);
        let mut k = ctx.pattern_matrix();
        let form = BilinearForm::Diffusion { rho: Coefficient::Const(2.0) };
        ctx.assemble_matrix_into(&form, &mut k.data);
        let fresh = ctx.assemble_matrix(&form);
        assert!(k.frob_distance(&fresh) < 1e-14);
    }

    #[test]
    fn facet_mass_measures_boundary_length() {
        // Σ_ij (facet mass)_ij = ∫_∂Ω 1 = perimeter = 4.
        let m = unit_square_tri(8);
        let fc = FacetContext::new(&m, &[marker::BOUNDARY], 1);
        let robin = fc.assemble_matrix(&BilinearForm::FacetMass {
            alpha: Coefficient::Const(1.0),
        });
        let total: f64 = robin.data.iter().sum();
        assert!((total - 4.0).abs() < 1e-12, "perimeter {total}");
    }

    #[test]
    fn facet_flux_measures_marked_portion() {
        let mut m = unit_square_tri(8);
        m.mark_boundary(|c| if c[1] < 1e-12 { marker::NEUMANN } else { marker::DIRICHLET });
        let fc = FacetContext::new(&m, &[marker::NEUMANN], 1);
        let g = fc.assemble_vector(&LinearForm::FacetFlux {
            g: Coefficient::Const(5.0),
        });
        let total: f64 = g.iter().sum();
        assert!((total - 5.0).abs() < 1e-12, "bottom edge flux {total}");
        // Only bottom-edge nodes receive contributions.
        for (i, &v) in g.iter().enumerate() {
            if v != 0.0 {
                assert!(m.point(i)[1].abs() < 1e-12);
            }
        }
    }

    #[test]
    fn facet_traction_vector_components() {
        let m = crate::mesh::structured::rect_quad(6, 3, 60.0, 30.0);
        let mut m = m;
        m.mark_boundary(|c| {
            if (c[0] - 60.0).abs() < 1e-9 && c[1] < 10.0 {
                marker::NEUMANN
            } else {
                marker::DIRICHLET
            }
        });
        let fc = FacetContext::new(&m, &[marker::NEUMANN], 2);
        let t = fc.assemble_vector(&LinearForm::FacetTraction { t: vec![0.0, -100.0] });
        let total_y: f64 = t.iter().skip(1).step_by(2).sum();
        // One edge of length 10 under ty=-100 → total -1000.
        assert!((total_y + 1000.0).abs() < 1e-9, "total_y={total_y}");
    }
}
