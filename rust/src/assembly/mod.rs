//! The TensorGalerkin assembly engine (the paper's core contribution).
//!
//! * [`forms`] — weak-form descriptions (diffusion, mass, elasticity,
//!   boundary Neumann/Robin, sources) and coefficient evaluation.
//! * [`local`] — **Stage I, Batch-Map**: batched local element matrices /
//!   vectors as flat tensors `K_local ∈ R^{E×kl×kl}` (native reference
//!   implementation of the Pallas kernel; bit-comparable to the AOT path).
//! * [`routing`] — **Stage II, Sparse-Reduce**: precomputed routing
//!   "matrices" `S_mat`, `S_vec` (stored as gather lists — a binary CSR ×
//!   vector product is exactly a gather-sum) and their deterministic
//!   application.
//! * [`scatter`] — the classical per-element **scatter-add baseline**
//!   (what FEniCS/SKFEM-style assembly does), kept for benchmarking.
//! * [`fused`] — the **zero-materialization tile engine**: Map and Reduce
//!   interleaved per cache-sized element tile (never the full `E×kl²`
//!   tensor), with a deterministic cross-tile fix-up and grow-once
//!   workspaces; bitwise identical to the two-stage path.
//! * [`map_reduce`] — the user-facing engine combining Map and Reduce with
//!   cached topology (and, in phase 2, a PJRT artifact Map backend).

pub mod forms;
pub mod fused;
pub mod local;
pub mod map_reduce;
pub mod routing;
pub mod scatter;

pub use forms::{BilinearForm, Coefficient, LinearForm};
pub use fused::{AssemblyWorkspace, FusedPlan};
pub use map_reduce::{AssemblyContext, BatchedAssembly, BatchedPlan};
pub use routing::Routing;
