//! Stage II — Sparse-Reduce: topology-aware routing (Algorithm 2).
//!
//! Assembly is linear in the local contributions, so global aggregation can
//! be precomputed from topology alone: the binary routing matrices
//! `S_mat ∈ {0,1}^{nnz×Ekl²}` and `S_vec ∈ {0,1}^{N×Ekl}` of Eq. (8). A
//! binary-CSR × vector product is exactly a *gather-sum*, which is how we
//! store and execute it: for each global target (a CSR nonzero or a global
//! DoF) the sorted list of flat local-tensor source indices. Application is
//! deterministic (fixed summation order), parallel over disjoint targets —
//! the paper's replacement for nondeterministic atomic scatter-add.

use anyhow::Result;

use crate::fem::dofmap::DofMap;
use crate::sparse::{Csr, CsrBatch};
use crate::util::threadpool;

/// Precomputed routing from local tensors to the global CSR matrix and
/// global vector. Built once per (mesh topology, DoF map); reused across
/// coefficient changes, optimization iterations and time steps.
#[derive(Clone, Debug)]
pub struct Routing {
    /// Number of global DoFs `N`.
    pub n_dofs: usize,
    /// Local DoFs per element `kl`.
    pub n_local: usize,
    /// Symbolic CSR pattern of the global matrix (values all zero).
    pub pattern_indptr: Vec<usize>,
    pub pattern_indices: Vec<usize>,
    /// `S_mat` as gather lists: `mat_ptr[p]..mat_ptr[p+1]` indexes
    /// `mat_src`, whose entries are flat positions into `vec(K_local)`.
    pub mat_ptr: Vec<usize>,
    pub mat_src: Vec<u32>,
    /// `S_vec` gather lists over flat positions into `vec(F_local)`.
    pub vec_ptr: Vec<usize>,
    pub vec_src: Vec<u32>,
}

impl Routing {
    /// Build routing from a DoF map (Algorithm 2's precomputation).
    pub fn build(dofmap: &DofMap) -> Routing {
        let n = dofmap.n_dofs;
        let kl = dofmap.n_local;
        let ne = dofmap.n_cells();

        // --- Symbolic pattern: unique (row, col) pairs.
        // Count row degrees with duplicates first, then sort+dedup per row.
        let mut row_lists: Vec<Vec<usize>> = vec![Vec::new(); n];
        for e in 0..ne {
            let dofs = dofmap.cell_dofs(e);
            for &i in dofs {
                for &j in dofs {
                    row_lists[i].push(j);
                }
            }
        }
        let mut pattern_indptr = Vec::with_capacity(n + 1);
        pattern_indptr.push(0);
        let mut pattern_indices = Vec::new();
        for list in row_lists.iter_mut() {
            list.sort_unstable();
            list.dedup();
            pattern_indices.extend_from_slice(list);
            pattern_indptr.push(pattern_indices.len());
        }
        let nnz = pattern_indices.len();

        // --- S_mat gather lists (counting sort by target position).
        let find_pos = |i: usize, j: usize| -> usize {
            let lo = pattern_indptr[i];
            let hi = pattern_indptr[i + 1];
            lo + pattern_indices[lo..hi].binary_search(&j).expect("pattern miss")
        };
        let total_mat = ne * kl * kl;
        assert!(total_mat < u32::MAX as usize, "local tensor too large for u32 routing");
        let mut mat_count = vec![0usize; nnz + 1];
        // First pass: count.
        for e in 0..ne {
            let dofs = dofmap.cell_dofs(e);
            for &i in dofs {
                for &j in dofs {
                    mat_count[find_pos(i, j) + 1] += 1;
                }
            }
        }
        for p in 0..nnz {
            mat_count[p + 1] += mat_count[p];
        }
        let mat_ptr = mat_count.clone();
        let mut mat_src = vec![0u32; total_mat];
        let mut next = mat_count;
        for e in 0..ne {
            let dofs = dofmap.cell_dofs(e);
            for (a, &i) in dofs.iter().enumerate() {
                for (b, &j) in dofs.iter().enumerate() {
                    let p = find_pos(i, j);
                    mat_src[next[p]] = (e * kl * kl + a * kl + b) as u32;
                    next[p] += 1;
                }
            }
        }

        // --- S_vec gather lists.
        let total_vec = ne * kl;
        let mut vec_count = vec![0usize; n + 1];
        for e in 0..ne {
            for &i in dofmap.cell_dofs(e) {
                vec_count[i + 1] += 1;
            }
        }
        for i in 0..n {
            vec_count[i + 1] += vec_count[i];
        }
        let vec_ptr = vec_count.clone();
        let mut vec_src = vec![0u32; total_vec];
        let mut nextv = vec_count;
        for e in 0..ne {
            for (a, &i) in dofmap.cell_dofs(e).iter().enumerate() {
                vec_src[nextv[i]] = (e * kl + a) as u32;
                nextv[i] += 1;
            }
        }

        Routing {
            n_dofs: n,
            n_local: kl,
            pattern_indptr,
            pattern_indices,
            mat_ptr,
            mat_src,
            vec_ptr,
            vec_src,
        }
    }

    /// Number of global nonzeros.
    pub fn nnz(&self) -> usize {
        self.pattern_indices.len()
    }

    /// Reduce local matrices into preallocated CSR values:
    /// `K = CSR(ℐ, S_mat · vec(K_local))`.
    pub fn reduce_matrix_into(&self, local: &[f64], data: &mut [f64]) {
        assert_eq!(data.len(), self.nnz());
        let threads = threadpool::default_threads();
        threadpool::for_each_row_mut(data, 1, threads, |p, out| {
            let mut acc = 0.0;
            for &s in &self.mat_src[self.mat_ptr[p]..self.mat_ptr[p + 1]] {
                acc += local[s as usize];
            }
            out[0] = acc;
        });
    }

    /// Reduce local matrices into a fresh CSR matrix.
    pub fn reduce_matrix(&self, local: &[f64]) -> Csr {
        assert_eq!(local.len(), self.mat_src.len(), "local tensor size mismatch");
        let mut data = vec![0.0; self.nnz()];
        self.reduce_matrix_into(local, &mut data);
        Csr {
            nrows: self.n_dofs,
            ncols: self.n_dofs,
            indptr: self.pattern_indptr.clone(),
            indices: self.pattern_indices.clone(),
            data,
        }
    }

    /// Reduce local vectors into a global vector: `F = S_vec · vec(F_local)`.
    pub fn reduce_vector_into(&self, local: &[f64], out: &mut [f64]) {
        assert_eq!(local.len(), self.vec_src.len());
        assert_eq!(out.len(), self.n_dofs);
        let threads = threadpool::default_threads();
        threadpool::for_each_row_mut(out, 1, threads, |i, o| {
            let mut acc = 0.0;
            for &s in &self.vec_src[self.vec_ptr[i]..self.vec_ptr[i + 1]] {
                acc += local[s as usize];
            }
            o[0] = acc;
        });
    }

    /// Allocating vector reduce.
    pub fn reduce_vector(&self, local: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.n_dofs];
        self.reduce_vector_into(local, &mut out);
        out
    }

    /// Batched Sparse-Reduce: `S` local tensors (`local` is the fused
    /// `S × E × kl²` buffer) into `S × nnz` value arrays sharing this
    /// routing's pattern. One parallel region covers the whole `S × nnz`
    /// target range, and per-target summation order matches
    /// [`Routing::reduce_matrix_into`] exactly, so instance `s` of the
    /// result is bitwise-identical to a sequential reduce of its slice.
    pub fn reduce_matrix_batch_into(&self, local: &[f64], n_instances: usize, data: &mut [f64]) {
        let total = self.mat_src.len();
        let nnz = self.nnz();
        assert_eq!(local.len(), n_instances * total, "local tensor size mismatch");
        assert_eq!(data.len(), n_instances * nnz);
        let threads = threadpool::default_threads();
        threadpool::for_each_row_mut(data, 1, threads, |r, out| {
            let (s, p) = (r / nnz, r % nnz);
            let inst = &local[s * total..(s + 1) * total];
            let mut acc = 0.0;
            for &src in &self.mat_src[self.mat_ptr[p]..self.mat_ptr[p + 1]] {
                acc += inst[src as usize];
            }
            out[0] = acc;
        });
    }

    /// Wrap `S × nnz` value arrays in a [`CsrBatch`] on this routing's
    /// symbolic pattern (the single place the shared pattern is cloned).
    pub fn csr_batch(&self, data: Vec<f64>, n_instances: usize) -> CsrBatch {
        assert_eq!(data.len(), n_instances * self.nnz());
        CsrBatch {
            nrows: self.n_dofs,
            ncols: self.n_dofs,
            indptr: self.pattern_indptr.clone(),
            indices: self.pattern_indices.clone(),
            n_instances,
            data,
        }
    }

    /// Batched matrix reduce into a fresh [`CsrBatch`] (pattern cloned once
    /// for all `S` instances).
    pub fn reduce_matrix_batch(&self, local: &[f64], n_instances: usize) -> CsrBatch {
        let mut data = vec![0.0; n_instances * self.nnz()];
        self.reduce_matrix_batch_into(local, n_instances, &mut data);
        self.csr_batch(data, n_instances)
    }

    /// Batched vector reduce: `S × E × kl` local vectors into `S × N`
    /// global vectors (flat, instance-major), one fused parallel region.
    pub fn reduce_vector_batch_into(&self, local: &[f64], n_instances: usize, out: &mut [f64]) {
        let total = self.vec_src.len();
        let n = self.n_dofs;
        assert_eq!(local.len(), n_instances * total, "local vector size mismatch");
        assert_eq!(out.len(), n_instances * n);
        let threads = threadpool::default_threads();
        threadpool::for_each_row_mut(out, 1, threads, |r, o| {
            let (s, i) = (r / n, r % n);
            let inst = &local[s * total..(s + 1) * total];
            let mut acc = 0.0;
            for &src in &self.vec_src[self.vec_ptr[i]..self.vec_ptr[i + 1]] {
                acc += inst[src as usize];
            }
            o[0] = acc;
        });
    }

    /// Allocating batched vector reduce (`S × N` flat result).
    pub fn reduce_vector_batch(&self, local: &[f64], n_instances: usize) -> Vec<f64> {
        let mut out = vec![0.0; n_instances * self.n_dofs];
        self.reduce_vector_batch_into(local, n_instances, &mut out);
        out
    }

    /// The *transpose* action of `S_mat`: scatter global CSR values back to
    /// local positions (`vec(K_local) = S_matᵀ v`). This is the backward
    /// pass of Sparse-Reduce — a pure gather, used by TensorOpt's adjoint
    /// to push `∂Γ/∂K` back to per-element contributions.
    pub fn scatter_matrix_adjoint(&self, data: &[f64]) -> Vec<f64> {
        assert_eq!(data.len(), self.nnz());
        let mut local = vec![0.0; self.mat_src.len()];
        for p in 0..self.nnz() {
            let v = data[p];
            for &s in &self.mat_src[self.mat_ptr[p]..self.mat_ptr[p + 1]] {
                local[s as usize] = v;
            }
        }
        local
    }

    /// Invariants for property tests: every flat local index routed exactly
    /// once; gather lists sorted (deterministic order).
    pub fn check_invariants(&self) -> Result<()> {
        let mut seen = vec![false; self.mat_src.len()];
        for &s in &self.mat_src {
            anyhow::ensure!(!seen[s as usize], "matrix source {s} routed twice");
            seen[s as usize] = true;
        }
        anyhow::ensure!(seen.iter().all(|&b| b), "matrix source not covered");
        let mut seenv = vec![false; self.vec_src.len()];
        for &s in &self.vec_src {
            anyhow::ensure!(!seenv[s as usize], "vector source {s} routed twice");
            seenv[s as usize] = true;
        }
        anyhow::ensure!(seenv.iter().all(|&b| b), "vector source not covered");
        anyhow::ensure!(*self.mat_ptr.last().unwrap() == self.mat_src.len());
        anyhow::ensure!(*self.vec_ptr.last().unwrap() == self.vec_src.len());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::structured::{unit_cube_tet, unit_square_tri};

    #[test]
    fn routing_covers_all_sources_once() {
        let m = unit_square_tri(4);
        let dm = DofMap::scalar(&m);
        let r = Routing::build(&dm);
        r.check_invariants().unwrap();
        assert_eq!(r.mat_src.len(), m.n_cells() * 9);
        assert_eq!(r.vec_src.len(), m.n_cells() * 3);
    }

    #[test]
    fn vector_routing_reduces_ones_to_valence() {
        // Reducing all-ones local vectors gives each node its cell valence.
        let m = unit_square_tri(2);
        let dm = DofMap::scalar(&m);
        let r = Routing::build(&dm);
        let local = vec![1.0; m.n_cells() * 3];
        let out = r.reduce_vector(&local);
        // Corner node 0 belongs to 1 or 2 cells depending on the diagonal;
        // total must equal total local entries.
        let total: f64 = out.iter().sum();
        assert_eq!(total, (m.n_cells() * 3) as f64);
        for (i, &v) in out.iter().enumerate() {
            assert!(v >= 1.0, "node {i} uncovered");
        }
    }

    #[test]
    fn matrix_reduce_matches_manual_sum() {
        let m = unit_square_tri(2);
        let dm = DofMap::scalar(&m);
        let r = Routing::build(&dm);
        // Local "matrices" = all ones: global entry (i,j) counts shared cells.
        let local = vec![1.0; m.n_cells() * 9];
        let k = r.reduce_matrix(&local);
        k.check_invariants().unwrap();
        // Diagonal of node i = number of incident cells.
        let valence = {
            let mut v = vec![0.0; m.n_nodes()];
            for e in 0..m.n_cells() {
                for &n in m.cell(e) {
                    v[n] += 1.0;
                }
            }
            v
        };
        for i in 0..m.n_nodes() {
            assert_eq!(k.get(i, i), Some(valence[i]));
        }
    }

    #[test]
    fn vector_dofmap_routing() {
        let m = unit_cube_tet(2);
        let dm = DofMap::vector(&m, 3);
        let r = Routing::build(&dm);
        r.check_invariants().unwrap();
        assert_eq!(r.n_dofs, 3 * m.n_nodes());
        assert_eq!(r.mat_src.len(), m.n_cells() * 144);
    }

    #[test]
    fn batched_matrix_reduce_matches_sequential() {
        let m = unit_square_tri(3);
        let dm = DofMap::scalar(&m);
        let r = Routing::build(&dm);
        let total = m.n_cells() * 9;
        // Three instances with distinct deterministic values.
        let local: Vec<f64> = (0..3 * total).map(|i| (i % 17) as f64 - 8.0).collect();
        let batch = r.reduce_matrix_batch(&local, 3);
        batch.check_invariants().unwrap();
        assert_eq!(batch.n_instances, 3);
        for s in 0..3 {
            let seq = r.reduce_matrix(&local[s * total..(s + 1) * total]);
            assert_eq!(batch.indices, seq.indices, "instance {s} pattern");
            assert_eq!(batch.values(s), &seq.data[..], "instance {s} values");
        }
    }

    #[test]
    fn batched_vector_reduce_matches_sequential() {
        let m = unit_square_tri(3);
        let dm = DofMap::scalar(&m);
        let r = Routing::build(&dm);
        let total = m.n_cells() * 3;
        let local: Vec<f64> = (0..2 * total).map(|i| (i as f64).sin()).collect();
        let batch = r.reduce_vector_batch(&local, 2);
        for s in 0..2 {
            let seq = r.reduce_vector(&local[s * total..(s + 1) * total]);
            assert_eq!(&batch[s * r.n_dofs..(s + 1) * r.n_dofs], &seq[..]);
        }
    }

    #[test]
    fn adjoint_scatter_is_right_inverse_on_sums() {
        // scatter(reduce(x)) sums within routing groups: reducing again is
        // idempotent in the sense reduce(scatter(y)) = valence ⊙ y for the
        // vector case analog; check matrix adjoint shape/coverage instead.
        let m = unit_square_tri(2);
        let dm = DofMap::scalar(&m);
        let r = Routing::build(&dm);
        let data: Vec<f64> = (0..r.nnz()).map(|p| p as f64).collect();
        let local = r.scatter_matrix_adjoint(&data);
        assert_eq!(local.len(), m.n_cells() * 9);
        // Re-reducing the scattered field reproduces data ⊙ multiplicity.
        let reduced = r.reduce_matrix(&local);
        for p in 0..r.nnz() {
            let mult = (r.mat_ptr[p + 1] - r.mat_ptr[p]) as f64;
            assert!((reduced.data[p] - data[p] * mult).abs() < 1e-12);
        }
    }
}
