//! Classical scatter-add assembly — the baseline TensorGalerkin replaces.
//!
//! Mirrors what legacy FEM stacks (FEniCS/SKFEM/torch-fem CPU paths) do
//! algorithmically: loop over elements, compute the local matrix *inside
//! the loop* (no batching), and scatter-add entries into a triplet store
//! that is compressed at the end (Eq. 6). Complexity per assembly is
//! `O(E·kl²)` *sequential* operations plus an `O(nnz log)` compression —
//! and, embedded in an AD framework, `O(E·kl²)` graph nodes, which is the
//! fragmentation the paper measures.

use crate::fem::dofmap::DofMap;
use crate::fem::geometry::{self, ElementGeometry};
use crate::fem::reference::Tabulation;
use crate::mesh::Mesh;
use crate::sparse::{Coo, Csr};

use super::forms::{BilinearForm, LinearForm};
use super::local;

/// Assemble the global matrix with per-element scatter-add.
///
/// The local matrix is computed element-by-element through the same
/// contraction as the Map stage (sliced to one element), so the *only*
/// difference versus [`super::map_reduce`] is the assembly strategy — the
/// comparison isolates exactly the paper's variable.
pub fn assemble_matrix(
    mesh: &Mesh,
    dofmap: &DofMap,
    form: &BilinearForm,
    tab: &Tabulation,
    geo: &ElementGeometry,
) -> Csr {
    let kl = dofmap.n_local;
    let ne = dofmap.n_cells();
    let mut coo = Coo::with_capacity(dofmap.n_dofs, dofmap.n_dofs, ne * kl * kl);
    let nq = geo.q;
    let k = tab.k;
    let d = mesh.dim;
    // Per-element geometry slice reused across the loop.
    for e in 0..ne {
        let sub = ElementGeometry {
            n_elems: 1,
            q: nq,
            k,
            dim: geo.dim,
            detj: geo.detj[e * nq..(e + 1) * nq].to_vec(),
            phys_grads: if geo.phys_grads.is_empty() {
                Vec::new()
            } else {
                geo.phys_grads[e * nq * k * d..(e + 1) * nq * k * d].to_vec()
            },
            qpoints: geo.qpoints[e * nq * d..(e + 1) * nq * d].to_vec(),
        };
        let form_e = slice_bilinear(form, e, nq);
        let ke = local::local_matrices(&form_e, &sub, tab, d);
        let dofs = dofmap.cell_dofs(e);
        for (a, &i) in dofs.iter().enumerate() {
            for (b, &j) in dofs.iter().enumerate() {
                coo.push(i, j, ke[a * kl + b]);
            }
        }
    }
    coo.to_csr()
}

/// Assemble the global load vector with per-element scatter-add.
pub fn assemble_vector(
    mesh: &Mesh,
    dofmap: &DofMap,
    form: &LinearForm,
    tab: &Tabulation,
    geo: &ElementGeometry,
) -> Vec<f64> {
    let kl = dofmap.n_local;
    let ne = dofmap.n_cells();
    let nq = geo.q;
    let k = tab.k;
    let d = mesh.dim;
    let mut out = vec![0.0; dofmap.n_dofs];
    for e in 0..ne {
        let sub = ElementGeometry {
            n_elems: 1,
            q: nq,
            k,
            dim: geo.dim,
            detj: geo.detj[e * nq..(e + 1) * nq].to_vec(),
            phys_grads: if geo.phys_grads.is_empty() {
                Vec::new()
            } else {
                geo.phys_grads[e * nq * k * d..(e + 1) * nq * k * d].to_vec()
            },
            qpoints: geo.qpoints[e * nq * d..(e + 1) * nq * d].to_vec(),
        };
        let form_e = slice_linear(form, e, nq);
        let fe = local::local_vectors(&form_e, &sub, tab, d);
        for (a, &i) in dofmap.cell_dofs(e).iter().enumerate() {
            out[i] += fe[a];
        }
        debug_assert_eq!(fe.len(), kl);
    }
    out
}

/// Convenience: full scatter-add pipeline (geometry + assembly) for a mesh —
/// the "legacy solver" entry used by benchmark baselines, recomputing
/// everything from scratch exactly like a per-solve FEM call.
pub fn assemble_matrix_from_scratch(
    mesh: &Mesh,
    dofmap: &DofMap,
    form: &BilinearForm,
    tab: &Tabulation,
    quad: &crate::fem::quadrature::Quadrature,
) -> Csr {
    let geo = geometry::compute(mesh, tab, quad);
    assemble_matrix(mesh, dofmap, form, tab, &geo)
}

fn slice_coeff(
    c: &super::forms::Coefficient,
    e: usize,
    nq: usize,
) -> super::forms::Coefficient {
    use super::forms::Coefficient;
    match c {
        Coefficient::Const(v) => Coefficient::Const(*v),
        Coefficient::Quad(v) => Coefficient::Quad(v[e * nq..(e + 1) * nq].to_vec()),
    }
}

fn slice_bilinear(form: &BilinearForm, e: usize, nq: usize) -> BilinearForm {
    match form {
        BilinearForm::Diffusion { rho } => BilinearForm::Diffusion {
            rho: slice_coeff(rho, e, nq),
        },
        BilinearForm::Mass { rho } => BilinearForm::Mass {
            rho: slice_coeff(rho, e, nq),
        },
        BilinearForm::Elasticity { lambda, mu, e_mod } => BilinearForm::Elasticity {
            lambda: *lambda,
            mu: *mu,
            e_mod: slice_coeff(e_mod, e, nq),
        },
        BilinearForm::FacetMass { alpha } => BilinearForm::FacetMass {
            alpha: slice_coeff(alpha, e, nq),
        },
    }
}

fn slice_linear(form: &LinearForm, e: usize, nq: usize) -> LinearForm {
    match form {
        LinearForm::Source { f } => LinearForm::Source {
            f: slice_coeff(f, e, nq),
        },
        LinearForm::FacetFlux { g } => LinearForm::FacetFlux {
            g: slice_coeff(g, e, nq),
        },
        LinearForm::VectorSource { f } => LinearForm::VectorSource { f: f.clone() },
        LinearForm::FacetTraction { t } => LinearForm::FacetTraction { t: t.clone() },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembly::forms::Coefficient;
    use crate::fem::quadrature::tri_deg2;
    use crate::fem::reference::RefElement;
    use crate::mesh::structured::unit_square_tri;

    #[test]
    fn scatter_add_poisson_row_sums_zero() {
        let m = unit_square_tri(3);
        let dm = DofMap::scalar(&m);
        let quad = tri_deg2();
        let tab = RefElement::P1Tri.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let k = assemble_matrix(
            &m,
            &dm,
            &BilinearForm::Diffusion { rho: Coefficient::Const(1.0) },
            &tab,
            &geo,
        );
        k.check_invariants().unwrap();
        let ones = vec![1.0; m.n_nodes()];
        let r = k.dot(&ones);
        for v in r {
            assert!(v.abs() < 1e-12, "constants not in kernel");
        }
    }

    #[test]
    fn load_vector_total_is_integral() {
        let m = unit_square_tri(3);
        let dm = DofMap::scalar(&m);
        let quad = tri_deg2();
        let tab = RefElement::P1Tri.tabulate(&quad);
        let geo = geometry::compute(&m, &tab, &quad);
        let f = assemble_vector(
            &m,
            &dm,
            &LinearForm::Source { f: Coefficient::Const(3.0) },
            &tab,
            &geo,
        );
        let total: f64 = f.iter().sum();
        assert!((total - 3.0).abs() < 1e-12);
    }
}
