//! Hard Dirichlet constraints via condensation.
//!
//! Given `K U = F` with prescribed values `U_d = g` on constrained DoFs,
//! the reduced (condensed) system over free DoFs is
//! `K_ff U_f = F_f − K_fd g`. TensorPILS imposes Dirichlet BCs the same way
//! (reducing the linear system — "hard constraints", §B.2.2), so this
//! module is shared by the solver, the neural-solver residual and the
//! topology-optimization pipeline.
//!
//! For the multi-instance workloads ([`crate::sparse::CsrBatch`]: `S`
//! operators on one shared sparsity pattern) the condensation bookkeeping
//! is itself a function of the pattern alone, so [`CondensePlan`] computes
//! the free-DoF symbolic mapping ONCE and [`condense_batch`] applies it to
//! all `S` value arrays, producing a [`ReducedBatch`] whose per-instance
//! numbers are bitwise identical to `S` scalar [`condense`] calls.

use crate::sparse::{Csr, CsrBatch};

/// A set of Dirichlet constraints: `dofs[i] ↦ values[i]`.
#[derive(Clone, Debug, Default)]
pub struct DirichletBc {
    pub dofs: Vec<usize>,
    pub values: Vec<f64>,
}

impl DirichletBc {
    /// Homogeneous (zero) constraints.
    pub fn homogeneous(dofs: Vec<usize>) -> DirichletBc {
        let values = vec![0.0; dofs.len()];
        DirichletBc { dofs, values }
    }

    /// Constraints from a boundary-value function evaluated at nodes.
    /// `dofs` must be scalar node DoFs.
    pub fn from_fn(
        mesh: &crate::mesh::Mesh,
        nodes: &[usize],
        g: impl Fn(&[f64]) -> f64,
    ) -> DirichletBc {
        DirichletBc {
            dofs: nodes.to_vec(),
            values: nodes.iter().map(|&n| g(mesh.point(n))).collect(),
        }
    }

    /// Sorted + deduplicated copy (required by [`condense`]).
    pub fn normalized(&self) -> DirichletBc {
        let mut pairs: Vec<(usize, f64)> =
            self.dofs.iter().copied().zip(self.values.iter().copied()).collect();
        pairs.sort_by_key(|&(d, _)| d);
        pairs.dedup_by_key(|&mut (d, _)| d);
        DirichletBc {
            dofs: pairs.iter().map(|&(d, _)| d).collect(),
            values: pairs.iter().map(|&(_, v)| v).collect(),
        }
    }
}

/// A condensed linear system plus the bookkeeping to expand solutions back
/// to the full DoF set.
#[derive(Clone, Debug)]
pub struct ReducedSystem {
    /// Sorted free (unconstrained) DoF indices.
    pub free: Vec<usize>,
    /// `K_ff` over free DoFs.
    pub k: Csr,
    /// `F_f − K_fd·g`.
    pub rhs: Vec<f64>,
    /// Constraints used for expansion.
    pub bc: DirichletBc,
    n_full: usize,
}

/// Insert prescribed boundary values and a free-DoF solution into a full
/// DoF vector — the one expansion kernel shared by the scalar and batched
/// reduced systems.
fn expand_free(free: &[usize], bc: &DirichletBc, n_full: usize, u_free: &[f64]) -> Vec<f64> {
    assert_eq!(u_free.len(), free.len());
    let mut full = vec![0.0; n_full];
    for (&d, &v) in bc.dofs.iter().zip(&bc.values) {
        full[d] = v;
    }
    for (&f, &v) in free.iter().zip(u_free) {
        full[f] = v;
    }
    full
}

/// Gather a full vector's free-DoF entries (shared restriction kernel).
fn restrict_free(free: &[usize], full: &[f64]) -> Vec<f64> {
    free.iter().map(|&f| full[f]).collect()
}

impl ReducedSystem {
    /// Expand a free-DoF solution to the full DoF vector (inserting the
    /// prescribed boundary values).
    pub fn expand(&self, u_free: &[f64]) -> Vec<f64> {
        expand_free(&self.free, &self.bc, self.n_full, u_free)
    }

    /// Restrict a full vector to free DoFs.
    pub fn restrict(&self, full: &[f64]) -> Vec<f64> {
        restrict_free(&self.free, full)
    }

    /// Number of DoFs in the full (uncondensed) system.
    pub fn n_full(&self) -> usize {
        self.n_full
    }
}

/// Condense `K U = F` with the given Dirichlet constraints. Implemented as
/// a single-instance [`CondensePlan`] application, so the scalar and
/// batched ([`condense_batch`]) paths share one symbolic traversal and one
/// numeric kernel — their parity holds by construction.
pub fn condense(k: &Csr, f: &[f64], bc: &DirichletBc) -> ReducedSystem {
    assert_eq!(f.len(), k.nrows);
    CondensePlan::new(k.nrows, &k.indptr, &k.indices, bc).into_apply(&k.data, f)
}

/// The symbolic (pattern-only) part of Dirichlet condensation, computed
/// once per shared sparsity pattern and reusable across every value
/// instance and every repeated solve (long-lived drivers like the lockstep
/// topology-optimization loop build one plan and apply it each iteration).
#[derive(Clone, Debug)]
pub struct CondensePlan {
    /// Sorted free (unconstrained) DoF indices.
    pub free: Vec<usize>,
    /// Condensed pattern: row pointers over free rows.
    indptr: Vec<usize>,
    /// Condensed pattern: renumbered free column indices.
    indices: Vec<usize>,
    /// Source position in the full value array of each kept entry, aligned
    /// with `indices` — per instance the condensed values are one gather.
    keep: Vec<usize>,
    /// Boundary lift `(free_row, source_pos, g)` triples in row-major entry
    /// order: `rhs[free_row] -= values[source_pos] * g`, exactly the
    /// per-row accumulation order of scalar [`condense`].
    lifts: Vec<(usize, usize, f64)>,
    /// Normalized constraints (for expansion).
    bc: DirichletBc,
    n_full: usize,
    /// Pattern nnz the plan was built for (guards mismatched reuse).
    nnz_full: usize,
    /// FNV hash of the source pattern; debug builds verify it on every
    /// batched reuse so a plan applied to a *different* equal-size pattern
    /// fails loudly instead of gathering from wrong positions.
    fingerprint: u64,
}

/// FNV-1a over a pattern's `indptr` + `indices`.
fn pattern_fingerprint(indptr: &[usize], indices: &[usize]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in indptr.iter().chain(indices) {
        h ^= v as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

impl CondensePlan {
    /// Build the symbolic mapping from a shared pattern.
    pub fn new(
        nrows: usize,
        indptr: &[usize],
        indices: &[usize],
        bc: &DirichletBc,
    ) -> CondensePlan {
        let n = nrows;
        let bc = bc.normalized();
        let mut constrained = vec![false; n];
        let mut gvals = vec![0.0; n];
        for (&d, &v) in bc.dofs.iter().zip(&bc.values) {
            assert!(d < n, "constraint DoF out of range");
            constrained[d] = true;
            gvals[d] = v;
        }
        let free: Vec<usize> = (0..n).filter(|&i| !constrained[i]).collect();
        let mut free_index = vec![usize::MAX; n];
        for (new, &old) in free.iter().enumerate() {
            free_index[old] = new;
        }
        let mut red_indptr = Vec::with_capacity(free.len() + 1);
        red_indptr.push(0);
        let mut red_indices = Vec::new();
        let mut keep = Vec::new();
        let mut lifts = Vec::new();
        for (rnew, &r) in free.iter().enumerate() {
            for p in indptr[r]..indptr[r + 1] {
                let c = indices[p];
                if constrained[c] {
                    lifts.push((rnew, p, gvals[c]));
                } else {
                    red_indices.push(free_index[c]);
                    keep.push(p);
                }
            }
            red_indptr.push(red_indices.len());
        }
        CondensePlan {
            free,
            indptr: red_indptr,
            indices: red_indices,
            keep,
            lifts,
            bc,
            n_full: n,
            nnz_full: indices.len(),
            fingerprint: pattern_fingerprint(indptr, indices),
        }
    }

    /// Build from the shared pattern of a [`CsrBatch`].
    pub fn from_batch(k: &CsrBatch, bc: &DirichletBc) -> CondensePlan {
        CondensePlan::new(k.nrows, &k.indptr, &k.indices, bc)
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    /// Apply the plan to ONE value instance, keeping the plan for reuse
    /// (clones its symbolic arrays into the result).
    pub fn apply(&self, values: &[f64], f: &[f64]) -> ReducedSystem {
        self.clone().into_apply(values, f)
    }

    /// One-shot apply: gather the kept entries, then lift the prescribed
    /// boundary values into the load in row-major entry order, moving the
    /// plan's symbolic arrays into the result (no clones — scalar
    /// [`condense`] is exactly this).
    pub fn into_apply(self, values: &[f64], f: &[f64]) -> ReducedSystem {
        assert_eq!(values.len(), self.nnz_full, "plan/matrix pattern mismatch");
        assert_eq!(f.len(), self.n_full, "plan/load length mismatch");
        let data: Vec<f64> = self.keep.iter().map(|&p| values[p]).collect();
        let mut rhs: Vec<f64> = self.free.iter().map(|&r| f[r]).collect();
        for &(rnew, p, g) in &self.lifts {
            rhs[rnew] -= values[p] * g;
        }
        ReducedSystem {
            k: Csr {
                nrows: self.free.len(),
                ncols: self.free.len(),
                indptr: self.indptr,
                indices: self.indices,
                data,
            },
            free: self.free,
            rhs,
            bc: self.bc,
            n_full: self.n_full,
        }
    }

    /// Refill a previously applied [`ReducedSystem`] with new values (and
    /// the same-or-new load) on the same pattern: the value gather, free
    /// restriction and boundary lift only — **zero heap allocation**.
    /// Numbers are produced in exactly the order of
    /// [`CondensePlan::into_apply`], so the refilled system is bitwise
    /// identical to a fresh application (iteration loops hold one
    /// `ReducedSystem` and refill it per solve).
    pub fn reapply_into(&self, values: &[f64], f: &[f64], sys: &mut ReducedSystem) {
        assert_eq!(values.len(), self.nnz_full, "plan/matrix pattern mismatch");
        assert_eq!(f.len(), self.n_full, "plan/load length mismatch");
        assert_eq!(sys.k.data.len(), self.keep.len(), "system/plan pattern mismatch");
        assert_eq!(sys.rhs.len(), self.free.len(), "system/plan free-set mismatch");
        for (d, &p) in sys.k.data.iter_mut().zip(&self.keep) {
            *d = values[p];
        }
        for (r, &row) in sys.rhs.iter_mut().zip(&self.free) {
            *r = f[row];
        }
        for &(rnew, p, g) in &self.lifts {
            sys.rhs[rnew] -= values[p] * g;
        }
        #[cfg(feature = "fault-inject")]
        if crate::util::faults::fire(crate::util::faults::CONDENSE_POISON, 0, 0) {
            sys.k.data[0] = f64::NAN;
        }
    }

    /// Apply the plan to `S` value instances and their loads. `f` is either
    /// one shared load vector (`n_full` entries, broadcast across the
    /// batch) or `S` instance-major load vectors (`S × n_full`).
    pub fn apply_batch(&self, k: &CsrBatch, f: &[f64]) -> ReducedBatch {
        let s_n = k.n_instances;
        assert_eq!(k.nrows, self.n_full, "plan/matrix row mismatch");
        assert_eq!(k.nnz(), self.nnz_full, "plan/matrix pattern mismatch");
        debug_assert_eq!(
            pattern_fingerprint(&k.indptr, &k.indices),
            self.fingerprint,
            "plan applied to a different pattern of equal size"
        );
        let broadcast = f.len() == self.n_full;
        assert!(
            broadcast || f.len() == s_n * self.n_full,
            "load vector must be n_full (broadcast) or S × n_full"
        );
        let nf = self.free.len();
        let red_nnz = self.indices.len();
        let mut data = Vec::with_capacity(s_n * red_nnz);
        let mut rhs = Vec::with_capacity(s_n * nf);
        for s in 0..s_n {
            let vals = k.values(s);
            // Condensed values: one gather over the kept positions.
            data.extend(self.keep.iter().map(|&p| vals[p]));
            // Condensed load: restrict, then lift in scalar entry order.
            let fs = if broadcast { f } else { &f[s * self.n_full..(s + 1) * self.n_full] };
            let rhs0 = rhs.len();
            rhs.extend(self.free.iter().map(|&r| fs[r]));
            for &(rnew, p, g) in &self.lifts {
                rhs[rhs0 + rnew] -= vals[p] * g;
            }
        }
        ReducedBatch {
            k: CsrBatch {
                nrows: nf,
                ncols: nf,
                indptr: self.indptr.clone(),
                indices: self.indices.clone(),
                n_instances: s_n,
                data,
            },
            rhs,
            free: self.free.clone(),
            bc: self.bc.clone(),
            n_full: self.n_full,
        }
    }
}

/// `S` condensed systems over one shared free-DoF structure, plus the
/// shared expand/restrict bookkeeping.
#[derive(Clone, Debug)]
pub struct ReducedBatch {
    /// Sorted free (unconstrained) DoF indices — shared by all instances.
    pub free: Vec<usize>,
    /// Condensed `K_ff` instances on one shared pattern.
    pub k: CsrBatch,
    /// Instance-major condensed right-hand sides, `S × n_free`.
    pub rhs: Vec<f64>,
    /// Constraints used for expansion.
    pub bc: DirichletBc,
    n_full: usize,
}

impl ReducedBatch {
    pub fn n_instances(&self) -> usize {
        self.k.n_instances
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    /// Condensed right-hand side of instance `s`.
    pub fn rhs_of(&self, s: usize) -> &[f64] {
        let nf = self.free.len();
        &self.rhs[s * nf..(s + 1) * nf]
    }

    /// Expand one instance's free-DoF solution to the full DoF vector
    /// (inserting the prescribed boundary values — the bookkeeping is
    /// shared across the batch).
    pub fn expand(&self, u_free: &[f64]) -> Vec<f64> {
        expand_free(&self.free, &self.bc, self.n_full, u_free)
    }

    /// Restrict a full vector to free DoFs.
    pub fn restrict(&self, full: &[f64]) -> Vec<f64> {
        restrict_free(&self.free, full)
    }
}

/// Condense `S` systems `K_s U_s = F_s` sharing one sparsity pattern: the
/// free-DoF symbolic mapping is computed once (see [`CondensePlan`]) and
/// applied to every value instance. `f` is either one shared load vector
/// (broadcast) or `S` instance-major loads; results match per-instance
/// [`condense`] bitwise.
pub fn condense_batch(k: &CsrBatch, f: &[f64], bc: &DirichletBc) -> ReducedBatch {
    CondensePlan::from_batch(k, bc).apply_batch(k, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
    use crate::mesh::structured::unit_square_tri;
    use crate::sparse::Dense;

    #[test]
    fn condensed_poisson_solves_manufactured_solution() {
        // -Δu = 0 with u = x on the boundary ⇒ u = x everywhere.
        let m = unit_square_tri(6);
        let ctx = AssemblyContext::new(&m, 1);
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        let f = ctx.assemble_vector(&LinearForm::Source { f: Coefficient::Const(0.0) });
        let bc = DirichletBc::from_fn(&m, &m.boundary_nodes(), |p| p[0]);
        let sys = condense(&k, &f, &bc);
        // Solve densely (small system) and compare to u = x.
        let kd = sys.k.to_dense();
        let dense = Dense {
            nrows: sys.k.nrows,
            ncols: sys.k.ncols,
            data: kd,
        };
        let u_free = dense.solve(&sys.rhs).unwrap();
        let u = sys.expand(&u_free);
        for i in 0..m.n_nodes() {
            assert!((u[i] - m.point(i)[0]).abs() < 1e-10, "node {i}");
        }
    }

    #[test]
    fn expand_restrict_roundtrip() {
        let m = unit_square_tri(3);
        let ctx = AssemblyContext::new(&m, 1);
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        let f = vec![0.0; ctx.n_dofs()];
        let bc = DirichletBc::homogeneous(m.boundary_nodes());
        let sys = condense(&k, &f, &bc);
        let u_free: Vec<f64> = (0..sys.free.len()).map(|i| i as f64).collect();
        let full = sys.expand(&u_free);
        assert_eq!(sys.restrict(&full), u_free);
        for &d in &sys.bc.dofs {
            assert_eq!(full[d], 0.0);
        }
    }

    #[test]
    fn reapply_into_matches_fresh_condense_bitwise() {
        // Inhomogeneous BCs exercise the boundary lift; refilling a stale
        // system with new values must equal a fresh condense exactly.
        let m = unit_square_tri(5);
        let ctx = AssemblyContext::new(&m, 1);
        let bc = DirichletBc::from_fn(&m, &m.boundary_nodes(), |p| p[0] - 2.0 * p[1]);
        let k1 = ctx.assemble_matrix(&BilinearForm::Diffusion { rho: Coefficient::Const(1.0) });
        let k2 = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: ctx.coeff_fn(|p| 1.0 + p[0] * p[1]),
        });
        let f = ctx.assemble_vector(&LinearForm::Source { f: Coefficient::Const(1.0) });
        let plan = CondensePlan::new(k1.nrows, &k1.indptr, &k1.indices, &bc);
        let mut sys = plan.apply(&k1.data, &f);
        plan.reapply_into(&k2.data, &f, &mut sys);
        let fresh = condense(&k2, &f, &bc);
        assert_eq!(sys.k.data, fresh.k.data);
        assert_eq!(sys.rhs, fresh.rhs);
        assert_eq!(sys.free, fresh.free);
    }

    #[test]
    fn condense_batch_matches_per_instance_condense() {
        // S diffusion operators with distinct coefficients on one topology,
        // inhomogeneous BCs to exercise the boundary lift.
        let m = unit_square_tri(5);
        let ctx = AssemblyContext::new(&m, 1);
        let n = ctx.n_dofs();
        let forms: Vec<BilinearForm> = (0..3)
            .map(|s| BilinearForm::Diffusion {
                rho: Coefficient::Const(1.0 + 0.5 * s as f64),
            })
            .collect();
        let kbatch = ctx.assemble_matrix_batch(&forms);
        let f: Vec<f64> = (0..3 * n).map(|i| 0.01 * (i % 17) as f64 - 0.05).collect();
        let bc = DirichletBc::from_fn(&m, &m.boundary_nodes(), |p| p[0] + 2.0 * p[1]);
        let red = condense_batch(&kbatch, &f, &bc);
        assert_eq!(red.n_instances(), 3);
        for s in 0..3 {
            let sys = condense(&kbatch.instance(s), &f[s * n..(s + 1) * n], &bc);
            assert_eq!(red.free, sys.free, "instance {s} free set");
            assert_eq!(red.k.indptr, sys.k.indptr, "instance {s} indptr");
            assert_eq!(red.k.indices, sys.k.indices, "instance {s} indices");
            assert_eq!(red.k.values(s), &sys.k.data[..], "instance {s} values");
            assert_eq!(red.rhs_of(s), &sys.rhs[..], "instance {s} rhs");
            let u: Vec<f64> = (0..red.n_free()).map(|i| i as f64).collect();
            assert_eq!(red.expand(&u), sys.expand(&u), "instance {s} expand");
        }
    }

    #[test]
    fn condense_batch_broadcasts_shared_load() {
        let m = unit_square_tri(4);
        let ctx = AssemblyContext::new(&m, 1);
        let n = ctx.n_dofs();
        let forms: Vec<BilinearForm> = (0..2)
            .map(|s| BilinearForm::Diffusion {
                rho: Coefficient::Const(1.0 + s as f64),
            })
            .collect();
        let kbatch = ctx.assemble_matrix_batch(&forms);
        let f: Vec<f64> = (0..n).map(|i| (i % 7) as f64 * 0.1).collect();
        let bc = DirichletBc::homogeneous(m.boundary_nodes());
        let red = condense_batch(&kbatch, &f, &bc);
        for s in 0..2 {
            let sys = condense(&kbatch.instance(s), &f, &bc);
            assert_eq!(red.rhs_of(s), &sys.rhs[..], "instance {s} rhs");
            assert_eq!(red.k.values(s), &sys.k.data[..], "instance {s} values");
        }
    }

    #[test]
    fn condense_plan_is_reusable_across_value_instances() {
        let m = unit_square_tri(4);
        let ctx = AssemblyContext::new(&m, 1);
        let n = ctx.n_dofs();
        let bc = DirichletBc::homogeneous(m.boundary_nodes());
        let k1 = ctx.assemble_matrix_batch(&[BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        }]);
        let plan = CondensePlan::from_batch(&k1, &bc);
        // Same pattern, different values: the plan applies unchanged.
        let k2 = ctx.assemble_matrix_batch(&[BilinearForm::Diffusion {
            rho: Coefficient::Const(4.0),
        }]);
        let zero = vec![0.0; n];
        let a = plan.apply_batch(&k2, &zero);
        let b = condense(&k2.instance(0), &zero, &bc);
        assert_eq!(a.k.values(0), &b.k.data[..]);
        assert_eq!(plan.n_free(), b.free.len());
    }

    #[test]
    fn duplicate_constraints_are_deduped() {
        let bc = DirichletBc {
            dofs: vec![3, 1, 3, 2],
            values: vec![30.0, 10.0, 30.0, 20.0],
        };
        let n = bc.normalized();
        assert_eq!(n.dofs, vec![1, 2, 3]);
        assert_eq!(n.values, vec![10.0, 20.0, 30.0]);
    }

    #[test]
    fn inhomogeneous_rhs_lift() {
        // 1D-like check on a tiny matrix: K = [[2,-1,0],[-1,2,-1],[0,-1,2]],
        // constrain u2 = 5 ⇒ reduced rhs gains +5 at row of u1.
        let k = Csr {
            nrows: 3,
            ncols: 3,
            indptr: vec![0, 2, 5, 7],
            indices: vec![0, 1, 0, 1, 2, 1, 2],
            data: vec![2.0, -1.0, -1.0, 2.0, -1.0, -1.0, 2.0],
        };
        let f = vec![0.0; 3];
        let bc = DirichletBc {
            dofs: vec![2],
            values: vec![5.0],
        };
        let sys = condense(&k, &f, &bc);
        assert_eq!(sys.free, vec![0, 1]);
        assert_eq!(sys.rhs, vec![0.0, 5.0]);
    }
}
