//! Hard Dirichlet constraints via condensation.
//!
//! Given `K U = F` with prescribed values `U_d = g` on constrained DoFs,
//! the reduced (condensed) system over free DoFs is
//! `K_ff U_f = F_f − K_fd g`. TensorPILS imposes Dirichlet BCs the same way
//! (reducing the linear system — "hard constraints", §B.2.2), so this
//! module is shared by the solver, the neural-solver residual and the
//! topology-optimization pipeline.

use crate::sparse::Csr;

/// A set of Dirichlet constraints: `dofs[i] ↦ values[i]`.
#[derive(Clone, Debug, Default)]
pub struct DirichletBc {
    pub dofs: Vec<usize>,
    pub values: Vec<f64>,
}

impl DirichletBc {
    /// Homogeneous (zero) constraints.
    pub fn homogeneous(dofs: Vec<usize>) -> DirichletBc {
        let values = vec![0.0; dofs.len()];
        DirichletBc { dofs, values }
    }

    /// Constraints from a boundary-value function evaluated at nodes.
    /// `dofs` must be scalar node DoFs.
    pub fn from_fn(
        mesh: &crate::mesh::Mesh,
        nodes: &[usize],
        g: impl Fn(&[f64]) -> f64,
    ) -> DirichletBc {
        DirichletBc {
            dofs: nodes.to_vec(),
            values: nodes.iter().map(|&n| g(mesh.point(n))).collect(),
        }
    }

    /// Sorted + deduplicated copy (required by [`condense`]).
    pub fn normalized(&self) -> DirichletBc {
        let mut pairs: Vec<(usize, f64)> =
            self.dofs.iter().copied().zip(self.values.iter().copied()).collect();
        pairs.sort_by_key(|&(d, _)| d);
        pairs.dedup_by_key(|&mut (d, _)| d);
        DirichletBc {
            dofs: pairs.iter().map(|&(d, _)| d).collect(),
            values: pairs.iter().map(|&(_, v)| v).collect(),
        }
    }
}

/// A condensed linear system plus the bookkeeping to expand solutions back
/// to the full DoF set.
#[derive(Clone, Debug)]
pub struct ReducedSystem {
    /// Sorted free (unconstrained) DoF indices.
    pub free: Vec<usize>,
    /// `K_ff` over free DoFs.
    pub k: Csr,
    /// `F_f − K_fd·g`.
    pub rhs: Vec<f64>,
    /// Constraints used for expansion.
    pub bc: DirichletBc,
    n_full: usize,
}

impl ReducedSystem {
    /// Expand a free-DoF solution to the full DoF vector (inserting the
    /// prescribed boundary values).
    pub fn expand(&self, u_free: &[f64]) -> Vec<f64> {
        assert_eq!(u_free.len(), self.free.len());
        let mut full = vec![0.0; self.n_full];
        for (&d, &v) in self.bc.dofs.iter().zip(&self.bc.values) {
            full[d] = v;
        }
        for (&f, &v) in self.free.iter().zip(u_free) {
            full[f] = v;
        }
        full
    }

    /// Restrict a full vector to free DoFs.
    pub fn restrict(&self, full: &[f64]) -> Vec<f64> {
        self.free.iter().map(|&f| full[f]).collect()
    }
}

/// Condense `K U = F` with the given Dirichlet constraints.
pub fn condense(k: &Csr, f: &[f64], bc: &DirichletBc) -> ReducedSystem {
    let n = k.nrows;
    assert_eq!(f.len(), n);
    let bc = bc.normalized();
    let mut constrained = vec![false; n];
    let mut gvals = vec![0.0; n];
    for (&d, &v) in bc.dofs.iter().zip(&bc.values) {
        assert!(d < n, "constraint DoF out of range");
        constrained[d] = true;
        gvals[d] = v;
    }
    let free: Vec<usize> = (0..n).filter(|&i| !constrained[i]).collect();
    let mut free_index = vec![usize::MAX; n];
    for (new, &old) in free.iter().enumerate() {
        free_index[old] = new;
    }

    // Build K_ff and rhs = F_f − K_fd g in one pass over rows.
    let mut indptr = Vec::with_capacity(free.len() + 1);
    indptr.push(0);
    let mut indices = Vec::new();
    let mut data = Vec::new();
    let mut rhs = Vec::with_capacity(free.len());
    for &r in &free {
        let (cols, vals) = k.row(r);
        let mut b = f[r];
        for (c, v) in cols.iter().zip(vals) {
            if constrained[*c] {
                b -= v * gvals[*c];
            } else {
                indices.push(free_index[*c]);
                data.push(*v);
            }
        }
        indptr.push(indices.len());
        rhs.push(b);
    }
    ReducedSystem {
        k: Csr {
            nrows: free.len(),
            ncols: free.len(),
            indptr,
            indices,
            data,
        },
        free,
        rhs,
        bc,
        n_full: n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
    use crate::mesh::structured::unit_square_tri;
    use crate::sparse::Dense;

    #[test]
    fn condensed_poisson_solves_manufactured_solution() {
        // -Δu = 0 with u = x on the boundary ⇒ u = x everywhere.
        let m = unit_square_tri(6);
        let ctx = AssemblyContext::new(&m, 1);
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        let f = ctx.assemble_vector(&LinearForm::Source { f: Coefficient::Const(0.0) });
        let bc = DirichletBc::from_fn(&m, &m.boundary_nodes(), |p| p[0]);
        let sys = condense(&k, &f, &bc);
        // Solve densely (small system) and compare to u = x.
        let kd = sys.k.to_dense();
        let dense = Dense {
            nrows: sys.k.nrows,
            ncols: sys.k.ncols,
            data: kd,
        };
        let u_free = dense.solve(&sys.rhs).unwrap();
        let u = sys.expand(&u_free);
        for i in 0..m.n_nodes() {
            assert!((u[i] - m.point(i)[0]).abs() < 1e-10, "node {i}");
        }
    }

    #[test]
    fn expand_restrict_roundtrip() {
        let m = unit_square_tri(3);
        let ctx = AssemblyContext::new(&m, 1);
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        let f = vec![0.0; ctx.n_dofs()];
        let bc = DirichletBc::homogeneous(m.boundary_nodes());
        let sys = condense(&k, &f, &bc);
        let u_free: Vec<f64> = (0..sys.free.len()).map(|i| i as f64).collect();
        let full = sys.expand(&u_free);
        assert_eq!(sys.restrict(&full), u_free);
        for &d in &sys.bc.dofs {
            assert_eq!(full[d], 0.0);
        }
    }

    #[test]
    fn duplicate_constraints_are_deduped() {
        let bc = DirichletBc {
            dofs: vec![3, 1, 3, 2],
            values: vec![30.0, 10.0, 30.0, 20.0],
        };
        let n = bc.normalized();
        assert_eq!(n.dofs, vec![1, 2, 3]);
        assert_eq!(n.values, vec![10.0, 20.0, 30.0]);
    }

    #[test]
    fn inhomogeneous_rhs_lift() {
        // 1D-like check on a tiny matrix: K = [[2,-1,0],[-1,2,-1],[0,-1,2]],
        // constrain u2 = 5 ⇒ reduced rhs gains +5 at row of u1.
        let k = Csr {
            nrows: 3,
            ncols: 3,
            indptr: vec![0, 2, 5, 7],
            indices: vec![0, 1, 0, 1, 2, 1, 2],
            data: vec![2.0, -1.0, -1.0, 2.0, -1.0, -1.0, 2.0],
        };
        let f = vec![0.0; 3];
        let bc = DirichletBc {
            dofs: vec![2],
            values: vec![5.0],
        };
        let sys = condense(&k, &f, &bc);
        assert_eq!(sys.free, vec![0, 1]);
        assert_eq!(sys.rhs, vec![0.0, 5.0]);
    }
}
