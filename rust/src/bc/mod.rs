//! Boundary conditions.
//!
//! * [`dirichlet`] — hard Dirichlet constraints by condensation (the paper's
//!   "condensed stiffness matrix", §B.1.2/B.2.2), in both scalar
//!   ([`condense`]) and batched ([`condense_batch`]: one symbolic mapping
//!   shared by `S` value instances) form.
//! * Neumann and Robin conditions need no dedicated module: they are
//!   assembled by [`crate::assembly::map_reduce::FacetContext`] through the
//!   same Map-Reduce pipeline and simply added to `K`/`F`.

pub mod dirichlet;

pub use dirichlet::{
    condense, condense_batch, CondensePlan, DirichletBc, ReducedBatch, ReducedSystem,
};
