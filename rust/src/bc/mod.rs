//! Boundary conditions.
//!
//! * [`dirichlet`] — hard Dirichlet constraints by condensation (the paper's
//!   "condensed stiffness matrix", §B.1.2/B.2.2).
//! * Neumann and Robin conditions need no dedicated module: they are
//!   assembled by [`crate::assembly::map_reduce::FacetContext`] through the
//!   same Map-Reduce pipeline and simply added to `K`/`F`.

pub mod dirichlet;

pub use dirichlet::{condense, DirichletBc, ReducedSystem};
