//! Request/response types of the batch-solve service.
//!
//! Every request is tagged with the `mesh_id` of the topology it targets:
//! one [`super::router::BatchServer`] instance serves many registered
//! meshes, routing each request to the shard that owns its mesh and
//! grouping drained requests by mesh key before dispatching each group as
//! one batched solve. Single-mesh callers can ignore the tag —
//! [`DEFAULT_MESH`] is what `BatchServer::start` registers its mesh under
//! and what the convenience constructors fill in.
//!
//! Failed requests are answered with a typed [`SolveError`] (wrapped in
//! `anyhow`; downcast with `err.downcast_ref::<SolveError>()`) so clients
//! can branch on the failure class — invalid input, expired deadline,
//! admission rejection, a circuit-breaker shed on an unhealthy mesh, or
//! a classified solver failure with its escalation accounting.

use std::time::Instant;

use crate::solver::{EscalationReport, FailureKind, SolveStats};

/// The mesh key used by single-mesh servers and the plain constructors.
pub const DEFAULT_MESH: u64 = 0;

/// A single solve request: right-hand side nodal values for the shared
/// operator of the target mesh (the Fig B.4 regime — fixed mesh/K,
/// varying `f`).
#[derive(Clone, Debug)]
pub struct SolveRequest {
    pub id: u64,
    /// Key of the registered mesh topology this request targets.
    pub mesh_id: u64,
    /// Nodal source values, interpolated to quadrature by the solver.
    pub f_nodal: Vec<f64>,
    /// Optional serving deadline: a deadline already passed at submit is
    /// answered with [`SolveError::Expired`] synchronously (no queue
    /// slot); one that expires while queued is answered `Expired` at
    /// dispatch, before any assembly work. While queued-but-live, the
    /// time left also budgets the escalation ladder (unaffordable rungs
    /// are skipped).
    pub deadline: Option<Instant>,
}

impl SolveRequest {
    /// Request against the default (single-server) mesh.
    pub fn new(id: u64, f_nodal: Vec<f64>) -> SolveRequest {
        SolveRequest {
            id,
            mesh_id: DEFAULT_MESH,
            f_nodal,
            deadline: None,
        }
    }

    /// Request against a specific registered mesh.
    pub fn on_mesh(id: u64, mesh_id: u64, f_nodal: Vec<f64>) -> SolveRequest {
        SolveRequest { id, mesh_id, f_nodal, deadline: None }
    }

    /// Attach a serving deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> SolveRequest {
        self.deadline = Some(deadline);
        self
    }
}

/// A solve request carrying its *own* diffusion coefficient field in
/// addition to the right-hand side — the multi-instance regime where every
/// sample is a different operator on the shared mesh topology (material
/// sampling, UQ sweeps, operator-learning data generation). Served by
/// [`super::batcher::BatchSolver::solve_varcoeff_batch`], which assembles
/// all `S` operators through one shared-topology Batch-Map + Sparse-Reduce.
#[derive(Clone, Debug)]
pub struct VarCoeffRequest {
    pub id: u64,
    /// Key of the registered mesh topology this request targets.
    pub mesh_id: u64,
    /// Nodal diffusion coefficient (must stay strictly positive).
    pub rho_nodal: Vec<f64>,
    /// Nodal source values.
    pub f_nodal: Vec<f64>,
    /// Optional serving deadline (see [`SolveRequest::deadline`]).
    pub deadline: Option<Instant>,
}

impl VarCoeffRequest {
    /// Request against the default (single-server) mesh.
    pub fn new(id: u64, rho_nodal: Vec<f64>, f_nodal: Vec<f64>) -> VarCoeffRequest {
        VarCoeffRequest {
            id,
            mesh_id: DEFAULT_MESH,
            rho_nodal,
            f_nodal,
            deadline: None,
        }
    }

    /// Request against a specific registered mesh.
    pub fn on_mesh(
        id: u64,
        mesh_id: u64,
        rho_nodal: Vec<f64>,
        f_nodal: Vec<f64>,
    ) -> VarCoeffRequest {
        VarCoeffRequest {
            id,
            mesh_id,
            rho_nodal,
            f_nodal,
            deadline: None,
        }
    }

    /// Attach a serving deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> VarCoeffRequest {
        self.deadline = Some(deadline);
        self
    }
}

/// The answer.
#[derive(Clone, Debug)]
pub struct SolveResponse {
    pub id: u64,
    pub u: Vec<f64>,
    pub iterations: usize,
    pub rel_residual: f64,
    /// Per-stage accounting when the escalation ladder recovered this
    /// request; `None` on the (normal) first-attempt success path.
    pub escalation: Option<EscalationReport>,
}

/// Typed failure answer of the serving layer, carried inside `anyhow`
/// errors (`err.downcast_ref::<SolveError>()`). The variants partition
/// the failure surface: bad input, deadline expiry before solving,
/// admission-queue rejection, and classified solver failures (with the
/// escalation ladder's accounting when it ran).
#[derive(Clone, Debug)]
pub enum SolveError {
    /// Request rejected by validation before entering a batch.
    Invalid { id: u64, reason: String },
    /// The request's deadline passed while it was still queued; answered
    /// without solving.
    Expired { id: u64 },
    /// The bounded admission queue was full; the request was never
    /// enqueued. Back off and resubmit.
    Overloaded {
        id: u64,
        queue_depth: usize,
        max_queue: usize,
    },
    /// The target mesh's circuit breaker is Open (chronic failures):
    /// the request was shed synchronously without entering the queue.
    /// Retry after roughly `retry_after_ms` — the breaker will admit a
    /// probe then.
    Unhealthy {
        id: u64,
        mesh_id: u64,
        retry_after_ms: u64,
    },
    /// The solve failed with the given classification; `escalation`
    /// records the recovery ladder when it ran (and was exhausted).
    Solver {
        id: u64,
        kind: FailureKind,
        stats: SolveStats,
        escalation: Option<EscalationReport>,
    },
    /// The shard worker holding this request died (a panic escaped the
    /// per-chunk isolation) and the supervisor's per-request retry budget
    /// was exhausted, so the request was not requeued. The input was never
    /// at fault: `retryable: true` means an identical resubmission is
    /// expected to succeed on the respawned worker.
    WorkerLost {
        id: u64,
        /// Index of the shard whose worker died holding the request.
        shard: usize,
        /// Whether resubmitting the identical request is reasonable.
        retryable: bool,
    },
    /// The server was asked to shut down with a drain deadline
    /// ([`super::router::BatchServer::shutdown_within`]) and the deadline
    /// passed before this request was served.
    Shutdown { id: u64 },
}

impl SolveError {
    /// The id of the request this error answers.
    pub fn id(&self) -> u64 {
        match self {
            SolveError::Invalid { id, .. }
            | SolveError::Expired { id }
            | SolveError::Overloaded { id, .. }
            | SolveError::Unhealthy { id, .. }
            | SolveError::Solver { id, .. }
            | SolveError::WorkerLost { id, .. }
            | SolveError::Shutdown { id } => *id,
        }
    }
}

impl std::fmt::Display for SolveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolveError::Invalid { id, reason } => write!(f, "request {id}: {reason}"),
            SolveError::Expired { id } => {
                write!(f, "request {id}: deadline expired before solving")
            }
            SolveError::Overloaded { id, queue_depth, max_queue } => write!(
                f,
                "request {id}: admission queue full ({queue_depth}/{max_queue}), not enqueued"
            ),
            SolveError::Unhealthy { id, mesh_id, retry_after_ms } => write!(
                f,
                "request {id}: mesh {mesh_id} circuit breaker open, shed; retry in ~{retry_after_ms} ms"
            ),
            SolveError::Solver { id, kind, stats, escalation } => {
                write!(
                    f,
                    "request {id}: solve failed ({kind}) after {} iterations, rel residual {:.3e}",
                    stats.iterations, stats.rel_residual
                )?;
                if let Some(rep) = escalation {
                    write!(f, "; escalation ladder exhausted after {} stages", rep.attempts.len())?;
                }
                Ok(())
            }
            SolveError::WorkerLost { id, shard, retryable } => write!(
                f,
                "request {id}: shard {shard} worker died holding the request; \
                 retry budget exhausted (retryable: {retryable})"
            ),
            SolveError::Shutdown { id } => {
                write!(f, "request {id}: server shut down before the request was served")
            }
        }
    }
}

impl std::error::Error for SolveError {}

/// Sharding configuration of a [`super::router::BatchServer`]: how many
/// shard workers drain the queue and whether idle shards may steal whole
/// `(mesh_id, kind)` groups from busy siblings.
///
/// The default ([`ShardConfig::from_env`]) reads `TG_SHARDS` (worker
/// count, default 1) and `TG_STEAL` (`0` disables stealing, default on),
/// so CI can cross the whole test suite over shard counts without code
/// changes. With `num_shards = 1` stealing is inert (there is no sibling
/// to steal from) and every serving path is bitwise identical to the
/// single-worker server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardConfig {
    /// Shard worker count; clamped to `1..=MAX_SHARDS` at server start.
    /// Shard workers submit into the one global `TG_THREADS` pool (they
    /// never spawn solve threads of their own), so raising this does not
    /// oversubscribe cores — see `util::threadpool`.
    pub num_shards: usize,
    /// Allow idle shards to steal whole `(mesh_id, kind)` groups from a
    /// sibling's queue. Group granularity preserves batched dispatch and
    /// per-request bitwise answers.
    pub steal: bool,
}

impl ShardConfig {
    /// One shard, no stealing — the single-worker server.
    pub fn single() -> ShardConfig {
        ShardConfig { num_shards: 1, steal: false }
    }

    /// Read `TG_SHARDS` / `TG_STEAL` from the environment (defaults:
    /// 1 shard, stealing enabled once there are siblings).
    pub fn from_env() -> ShardConfig {
        let num_shards = std::env::var("TG_SHARDS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(1);
        let steal = std::env::var("TG_STEAL").map(|v| v.trim() != "0").unwrap_or(true);
        ShardConfig { num_shards, steal }
    }
}

impl Default for ShardConfig {
    fn default() -> ShardConfig {
        ShardConfig::from_env()
    }
}

/// Supervision policy of a [`super::router::BatchServer`]: whether a
/// router-side supervisor thread watches the shard workers and what it
/// does when one dies.
///
/// Default-off, like every robustness layer in this crate: without
/// [`super::router::BatchServer::set_supervision_config`] no supervisor
/// thread exists, workers are never parked-for and never respawned, and
/// every serving path is bitwise identical to the unsupervised server
/// (pinned by `crash_recovery.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SupervisionConfig {
    /// Spawn the supervisor thread and park in-flight batches so a dead
    /// worker's requests are salvageable.
    pub enabled: bool,
    /// Per-request retry budget: how many times one request may be
    /// requeued after losing its worker before it is answered with a
    /// typed [`SolveError::WorkerLost`]. `0` = never requeue (every
    /// salvaged request is answered `WorkerLost { retryable: true }`).
    pub max_requeues: u32,
    /// Supervisor poll period in milliseconds (liveness checks + respawn
    /// latency; also the granularity of wedge detection).
    pub poll_ms: u64,
    /// Declare a live worker *wedged* when its heartbeat has not advanced
    /// for this long while its queue is non-empty. Detection only — a
    /// wedged thread cannot be killed, so the supervisor counts the
    /// episode ([`CoordinatorStats::wedged_detections`]) for operators
    /// instead of respawning. `0` disables wedge detection.
    pub wedged_after_ms: u64,
}

impl SupervisionConfig {
    /// No supervision (the default): no supervisor thread, no parking,
    /// bitwise-identical serving to the unsupervised server.
    pub fn disabled() -> SupervisionConfig {
        SupervisionConfig {
            enabled: false,
            max_requeues: 0,
            poll_ms: 2,
            wedged_after_ms: 0,
        }
    }

    /// Supervision with one requeue attempt per request — the
    /// production-shaped default for crash tolerance.
    pub fn supervised() -> SupervisionConfig {
        SupervisionConfig {
            enabled: true,
            max_requeues: 1,
            poll_ms: 2,
            wedged_after_ms: 0,
        }
    }
}

impl Default for SupervisionConfig {
    fn default() -> SupervisionConfig {
        SupervisionConfig::disabled()
    }
}

/// Instantaneous per-shard counters ([`super::router::BatchServer::per_shard`]):
/// read directly from the shard handles without a queue round-trip, so
/// depths are a live sample, not a post-drain snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Shard index in `0..num_shards`.
    pub shard: usize,
    /// Requests currently admitted to this shard but not yet drained.
    pub queue_depth: u64,
    /// High-water mark of this shard's queue depth since server start.
    pub queue_high_water: u64,
    /// Whole `(mesh_id, kind)` groups this shard stole from siblings.
    pub stolen_groups: u64,
    /// Requests for meshes homed on this shard that were shed by the
    /// circuit breaker (at submit or at drain).
    pub shed_requests: u64,
}

/// Aggregate serving counters of a [`super::router::BatchServer`], folded
/// across its shard workers (monotone counters are summed; the queue
/// high-water mark is the max over shards) and summed over every per-mesh
/// [`super::batcher::BatchSolver`] each shard has built (observability +
/// the regression hook proving drained bursts really go through the
/// batched pipelines).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CoordinatorStats {
    /// Batched dispatches (one `solve_batch`/`solve_varcoeff_batch` call,
    /// whatever the group size).
    pub batched_solves: u64,
    /// Scalar dispatches (`solve_one`/`solve_varcoeff_one` — singleton
    /// groups only).
    pub scalar_solves: u64,
    /// Requests answered with an error (validation, unconverged lane, or
    /// recovered panic).
    pub failed_requests: u64,
    /// Mesh states currently resident in the registry.
    pub meshes_built: u64,
    /// Registry entries evicted by the LRU cap (`max_mesh_states`).
    pub evicted_states: u64,
    /// Mesh states rebuilt after a prior eviction — sustained traffic on
    /// more meshes than the cap shows up here as churn.
    pub state_rebuilds: u64,
    /// Requests drained from the queue, summed over drain cycles — the
    /// queue-depth integral (`queued_requests / drain_cycles` is the mean
    /// drained batch size under load). Monotone: survives evictions.
    pub queued_requests: u64,
    /// Non-empty drain cycles the worker has completed.
    pub drain_cycles: u64,
    /// `(mesh_id, kind)` dispatch groups formed across all drain cycles —
    /// with `queued_requests`, the per-drain group-size signal
    /// (`queued_requests / dispatch_groups` is the mean group size).
    pub dispatch_groups: u64,
    /// Requests answered with [`SolveError::Expired`] — their deadline
    /// passed while queued, so they were never solved.
    pub expired_requests: u64,
    /// Requests rejected at admission ([`SolveError::Overloaded`]) by the
    /// bounded queue; they never reached the worker.
    pub rejected_requests: u64,
    /// Lanes that failed their first solve and entered the escalation
    /// ladder (whether or not a stage recovered them).
    pub retried_lanes: u64,
    /// Escalated lanes a ladder stage successfully recovered.
    pub rescued_lanes: u64,
    /// High-water mark of the admission-queue depth (requests submitted
    /// but not yet drained) since server start. With multiple shards this
    /// is the MAX over per-shard high-water marks — a depth, not a
    /// throughput counter, so summing shards would overstate it.
    pub queue_high_water: u64,
    /// Requests shed synchronously ([`SolveError::Unhealthy`]) because
    /// their mesh's circuit breaker was Open.
    pub shed_requests: u64,
    /// Circuit-breaker trips: Closed → Open plus failed-probe
    /// HalfOpen → Open transitions.
    pub breaker_opens: u64,
    /// Open → HalfOpen probe admissions.
    pub breaker_half_opens: u64,
    /// HalfOpen → Closed recoveries (successful probes).
    pub breaker_closes: u64,
    /// Escalation-ladder rungs skipped by budget-aware escalation
    /// because their cost estimate exceeded the deadline budget.
    pub skipped_rungs: u64,
    /// Episodes in which adaptive shedding tightened the admission bound
    /// (sick traffic dominated recent outcomes).
    pub queue_tightenings: u64,
    /// Whole `(mesh_id, kind)` groups stolen by idle shards from busy
    /// siblings, summed over shards. Always 0 with stealing off or
    /// `num_shards = 1`.
    pub stolen_groups: u64,
    /// Steal candidates an idle shard skipped because the group's mesh
    /// breaker was Open (shedding belongs on the home shard) or HalfOpen
    /// (the probe group must not migrate), summed over shards.
    pub steals_skipped: u64,
    /// The admission bound currently in force: the configured
    /// `set_max_queue` value, or its tightened fraction while adaptive
    /// shedding is active (`0` = unbounded).
    pub effective_max_queue: u64,
    /// Shard workers respawned by the supervisor after dying (a panic
    /// escaping the per-chunk isolation). Router-owned: 0 in per-shard
    /// partial stats, set once on the folded total.
    pub worker_respawns: u64,
    /// Salvaged in-flight requests the supervisor requeued onto a live
    /// worker after their shard died (each within its retry budget).
    /// Router-owned.
    pub requeued_requests: u64,
    /// Salvaged in-flight requests answered with a typed
    /// [`SolveError::WorkerLost`] because their retry budget was
    /// exhausted. Router-owned.
    pub lost_requests: u64,
    /// Requests answered with a typed [`SolveError::Shutdown`] because
    /// the drain deadline of `shutdown_within` passed first. Router-owned.
    pub shutdown_answered: u64,
    /// Wedge episodes detected: a live worker whose heartbeat stalled
    /// past `wedged_after_ms` with work queued. Router-owned.
    pub wedged_detections: u64,
}
