//! Request/response types of the batch-solve service.

/// A single solve request: right-hand side nodal values for the shared
/// operator (the Fig B.4 regime — fixed mesh/K, varying `f`).
#[derive(Clone, Debug)]
pub struct SolveRequest {
    pub id: u64,
    /// Nodal source values, interpolated to quadrature by the solver.
    pub f_nodal: Vec<f64>,
}

/// The answer.
#[derive(Clone, Debug)]
pub struct SolveResponse {
    pub id: u64,
    pub u: Vec<f64>,
    pub iterations: usize,
    pub rel_residual: f64,
}
