//! Request/response types of the batch-solve service.

/// A single solve request: right-hand side nodal values for the shared
/// operator (the Fig B.4 regime — fixed mesh/K, varying `f`).
#[derive(Clone, Debug)]
pub struct SolveRequest {
    pub id: u64,
    /// Nodal source values, interpolated to quadrature by the solver.
    pub f_nodal: Vec<f64>,
}

/// A solve request carrying its *own* diffusion coefficient field in
/// addition to the right-hand side — the multi-instance regime where every
/// sample is a different operator on the shared mesh topology (material
/// sampling, UQ sweeps, operator-learning data generation). Served by
/// [`super::batcher::BatchSolver::solve_varcoeff_batch`], which assembles
/// all `S` operators through one shared-topology Batch-Map + Sparse-Reduce.
#[derive(Clone, Debug)]
pub struct VarCoeffRequest {
    pub id: u64,
    /// Nodal diffusion coefficient (must stay strictly positive).
    pub rho_nodal: Vec<f64>,
    /// Nodal source values.
    pub f_nodal: Vec<f64>,
}

/// The answer.
#[derive(Clone, Debug)]
pub struct SolveResponse {
    pub id: u64,
    pub u: Vec<f64>,
    pub iterations: usize,
    pub rel_residual: f64,
}
