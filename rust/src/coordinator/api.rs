//! Request/response types of the batch-solve service.
//!
//! Every request is tagged with the `mesh_id` of the topology it targets:
//! one [`super::server::BatchServer`] instance serves many registered
//! meshes, grouping drained requests by mesh key before dispatching each
//! group as one batched solve. Single-mesh callers can ignore the tag —
//! [`DEFAULT_MESH`] is what `BatchServer::start` registers its mesh under
//! and what the convenience constructors fill in.

/// The mesh key used by single-mesh servers and the plain constructors.
pub const DEFAULT_MESH: u64 = 0;

/// A single solve request: right-hand side nodal values for the shared
/// operator of the target mesh (the Fig B.4 regime — fixed mesh/K,
/// varying `f`).
#[derive(Clone, Debug)]
pub struct SolveRequest {
    pub id: u64,
    /// Key of the registered mesh topology this request targets.
    pub mesh_id: u64,
    /// Nodal source values, interpolated to quadrature by the solver.
    pub f_nodal: Vec<f64>,
}

impl SolveRequest {
    /// Request against the default (single-server) mesh.
    pub fn new(id: u64, f_nodal: Vec<f64>) -> SolveRequest {
        SolveRequest {
            id,
            mesh_id: DEFAULT_MESH,
            f_nodal,
        }
    }

    /// Request against a specific registered mesh.
    pub fn on_mesh(id: u64, mesh_id: u64, f_nodal: Vec<f64>) -> SolveRequest {
        SolveRequest { id, mesh_id, f_nodal }
    }
}

/// A solve request carrying its *own* diffusion coefficient field in
/// addition to the right-hand side — the multi-instance regime where every
/// sample is a different operator on the shared mesh topology (material
/// sampling, UQ sweeps, operator-learning data generation). Served by
/// [`super::batcher::BatchSolver::solve_varcoeff_batch`], which assembles
/// all `S` operators through one shared-topology Batch-Map + Sparse-Reduce.
#[derive(Clone, Debug)]
pub struct VarCoeffRequest {
    pub id: u64,
    /// Key of the registered mesh topology this request targets.
    pub mesh_id: u64,
    /// Nodal diffusion coefficient (must stay strictly positive).
    pub rho_nodal: Vec<f64>,
    /// Nodal source values.
    pub f_nodal: Vec<f64>,
}

impl VarCoeffRequest {
    /// Request against the default (single-server) mesh.
    pub fn new(id: u64, rho_nodal: Vec<f64>, f_nodal: Vec<f64>) -> VarCoeffRequest {
        VarCoeffRequest {
            id,
            mesh_id: DEFAULT_MESH,
            rho_nodal,
            f_nodal,
        }
    }

    /// Request against a specific registered mesh.
    pub fn on_mesh(
        id: u64,
        mesh_id: u64,
        rho_nodal: Vec<f64>,
        f_nodal: Vec<f64>,
    ) -> VarCoeffRequest {
        VarCoeffRequest {
            id,
            mesh_id,
            rho_nodal,
            f_nodal,
        }
    }
}

/// The answer.
#[derive(Clone, Debug)]
pub struct SolveResponse {
    pub id: u64,
    pub u: Vec<f64>,
    pub iterations: usize,
    pub rel_residual: f64,
}

/// Aggregate serving counters of a [`super::server::BatchServer`] worker,
/// summed over every per-mesh [`super::batcher::BatchSolver`] it has built
/// (observability + the regression hook proving drained bursts really go
/// through the batched pipelines).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CoordinatorStats {
    /// Batched dispatches (one `solve_batch`/`solve_varcoeff_batch` call,
    /// whatever the group size).
    pub batched_solves: u64,
    /// Scalar dispatches (`solve_one`/`solve_varcoeff_one` — singleton
    /// groups only).
    pub scalar_solves: u64,
    /// Requests answered with an error (validation, unconverged lane, or
    /// recovered panic).
    pub failed_requests: u64,
    /// Mesh states currently resident in the registry.
    pub meshes_built: u64,
    /// Registry entries evicted by the LRU cap (`max_mesh_states`).
    pub evicted_states: u64,
    /// Mesh states rebuilt after a prior eviction — sustained traffic on
    /// more meshes than the cap shows up here as churn.
    pub state_rebuilds: u64,
    /// Requests drained from the queue, summed over drain cycles — the
    /// queue-depth integral (`queued_requests / drain_cycles` is the mean
    /// drained batch size under load). Monotone: survives evictions.
    pub queued_requests: u64,
    /// Non-empty drain cycles the worker has completed.
    pub drain_cycles: u64,
    /// `(mesh_id, kind)` dispatch groups formed across all drain cycles —
    /// with `queued_requests`, the per-drain group-size signal
    /// (`queued_requests / dispatch_groups` is the mean group size).
    pub dispatch_groups: u64,
}
