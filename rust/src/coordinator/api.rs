//! Request/response types of the batch-solve service.
//!
//! Every request is tagged with the `mesh_id` of the topology it targets:
//! one [`super::server::BatchServer`] instance serves many registered
//! meshes, grouping drained requests by mesh key before dispatching each
//! group as one batched solve. Single-mesh callers can ignore the tag —
//! [`DEFAULT_MESH`] is what `BatchServer::start` registers its mesh under
//! and what the convenience constructors fill in.
//!
//! Failed requests are answered with a typed [`SolveError`] (wrapped in
//! `anyhow`; downcast with `err.downcast_ref::<SolveError>()`) so clients
//! can branch on the failure class — invalid input, expired deadline,
//! admission rejection, a circuit-breaker shed on an unhealthy mesh, or
//! a classified solver failure with its escalation accounting.

use std::time::Instant;

use crate::solver::{EscalationReport, FailureKind, SolveStats};

/// The mesh key used by single-mesh servers and the plain constructors.
pub const DEFAULT_MESH: u64 = 0;

/// A single solve request: right-hand side nodal values for the shared
/// operator of the target mesh (the Fig B.4 regime — fixed mesh/K,
/// varying `f`).
#[derive(Clone, Debug)]
pub struct SolveRequest {
    pub id: u64,
    /// Key of the registered mesh topology this request targets.
    pub mesh_id: u64,
    /// Nodal source values, interpolated to quadrature by the solver.
    pub f_nodal: Vec<f64>,
    /// Optional serving deadline: a deadline already passed at submit is
    /// answered with [`SolveError::Expired`] synchronously (no queue
    /// slot); one that expires while queued is answered `Expired` at
    /// dispatch, before any assembly work. While queued-but-live, the
    /// time left also budgets the escalation ladder (unaffordable rungs
    /// are skipped).
    pub deadline: Option<Instant>,
}

impl SolveRequest {
    /// Request against the default (single-server) mesh.
    pub fn new(id: u64, f_nodal: Vec<f64>) -> SolveRequest {
        SolveRequest {
            id,
            mesh_id: DEFAULT_MESH,
            f_nodal,
            deadline: None,
        }
    }

    /// Request against a specific registered mesh.
    pub fn on_mesh(id: u64, mesh_id: u64, f_nodal: Vec<f64>) -> SolveRequest {
        SolveRequest { id, mesh_id, f_nodal, deadline: None }
    }

    /// Attach a serving deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> SolveRequest {
        self.deadline = Some(deadline);
        self
    }
}

/// A solve request carrying its *own* diffusion coefficient field in
/// addition to the right-hand side — the multi-instance regime where every
/// sample is a different operator on the shared mesh topology (material
/// sampling, UQ sweeps, operator-learning data generation). Served by
/// [`super::batcher::BatchSolver::solve_varcoeff_batch`], which assembles
/// all `S` operators through one shared-topology Batch-Map + Sparse-Reduce.
#[derive(Clone, Debug)]
pub struct VarCoeffRequest {
    pub id: u64,
    /// Key of the registered mesh topology this request targets.
    pub mesh_id: u64,
    /// Nodal diffusion coefficient (must stay strictly positive).
    pub rho_nodal: Vec<f64>,
    /// Nodal source values.
    pub f_nodal: Vec<f64>,
    /// Optional serving deadline (see [`SolveRequest::deadline`]).
    pub deadline: Option<Instant>,
}

impl VarCoeffRequest {
    /// Request against the default (single-server) mesh.
    pub fn new(id: u64, rho_nodal: Vec<f64>, f_nodal: Vec<f64>) -> VarCoeffRequest {
        VarCoeffRequest {
            id,
            mesh_id: DEFAULT_MESH,
            rho_nodal,
            f_nodal,
            deadline: None,
        }
    }

    /// Request against a specific registered mesh.
    pub fn on_mesh(
        id: u64,
        mesh_id: u64,
        rho_nodal: Vec<f64>,
        f_nodal: Vec<f64>,
    ) -> VarCoeffRequest {
        VarCoeffRequest {
            id,
            mesh_id,
            rho_nodal,
            f_nodal,
            deadline: None,
        }
    }

    /// Attach a serving deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> VarCoeffRequest {
        self.deadline = Some(deadline);
        self
    }
}

/// The answer.
#[derive(Clone, Debug)]
pub struct SolveResponse {
    pub id: u64,
    pub u: Vec<f64>,
    pub iterations: usize,
    pub rel_residual: f64,
    /// Per-stage accounting when the escalation ladder recovered this
    /// request; `None` on the (normal) first-attempt success path.
    pub escalation: Option<EscalationReport>,
}

/// Typed failure answer of the serving layer, carried inside `anyhow`
/// errors (`err.downcast_ref::<SolveError>()`). The variants partition
/// the failure surface: bad input, deadline expiry before solving,
/// admission-queue rejection, and classified solver failures (with the
/// escalation ladder's accounting when it ran).
#[derive(Clone, Debug)]
pub enum SolveError {
    /// Request rejected by validation before entering a batch.
    Invalid { id: u64, reason: String },
    /// The request's deadline passed while it was still queued; answered
    /// without solving.
    Expired { id: u64 },
    /// The bounded admission queue was full; the request was never
    /// enqueued. Back off and resubmit.
    Overloaded {
        id: u64,
        queue_depth: usize,
        max_queue: usize,
    },
    /// The target mesh's circuit breaker is Open (chronic failures):
    /// the request was shed synchronously without entering the queue.
    /// Retry after roughly `retry_after_ms` — the breaker will admit a
    /// probe then.
    Unhealthy {
        id: u64,
        mesh_id: u64,
        retry_after_ms: u64,
    },
    /// The solve failed with the given classification; `escalation`
    /// records the recovery ladder when it ran (and was exhausted).
    Solver {
        id: u64,
        kind: FailureKind,
        stats: SolveStats,
        escalation: Option<EscalationReport>,
    },
}

impl SolveError {
    /// The id of the request this error answers.
    pub fn id(&self) -> u64 {
        match self {
            SolveError::Invalid { id, .. }
            | SolveError::Expired { id }
            | SolveError::Overloaded { id, .. }
            | SolveError::Unhealthy { id, .. }
            | SolveError::Solver { id, .. } => *id,
        }
    }
}

impl std::fmt::Display for SolveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolveError::Invalid { id, reason } => write!(f, "request {id}: {reason}"),
            SolveError::Expired { id } => {
                write!(f, "request {id}: deadline expired before solving")
            }
            SolveError::Overloaded { id, queue_depth, max_queue } => write!(
                f,
                "request {id}: admission queue full ({queue_depth}/{max_queue}), not enqueued"
            ),
            SolveError::Unhealthy { id, mesh_id, retry_after_ms } => write!(
                f,
                "request {id}: mesh {mesh_id} circuit breaker open, shed; retry in ~{retry_after_ms} ms"
            ),
            SolveError::Solver { id, kind, stats, escalation } => {
                write!(
                    f,
                    "request {id}: solve failed ({kind}) after {} iterations, rel residual {:.3e}",
                    stats.iterations, stats.rel_residual
                )?;
                if let Some(rep) = escalation {
                    write!(f, "; escalation ladder exhausted after {} stages", rep.attempts.len())?;
                }
                Ok(())
            }
        }
    }
}

impl std::error::Error for SolveError {}

/// Aggregate serving counters of a [`super::server::BatchServer`] worker,
/// summed over every per-mesh [`super::batcher::BatchSolver`] it has built
/// (observability + the regression hook proving drained bursts really go
/// through the batched pipelines).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CoordinatorStats {
    /// Batched dispatches (one `solve_batch`/`solve_varcoeff_batch` call,
    /// whatever the group size).
    pub batched_solves: u64,
    /// Scalar dispatches (`solve_one`/`solve_varcoeff_one` — singleton
    /// groups only).
    pub scalar_solves: u64,
    /// Requests answered with an error (validation, unconverged lane, or
    /// recovered panic).
    pub failed_requests: u64,
    /// Mesh states currently resident in the registry.
    pub meshes_built: u64,
    /// Registry entries evicted by the LRU cap (`max_mesh_states`).
    pub evicted_states: u64,
    /// Mesh states rebuilt after a prior eviction — sustained traffic on
    /// more meshes than the cap shows up here as churn.
    pub state_rebuilds: u64,
    /// Requests drained from the queue, summed over drain cycles — the
    /// queue-depth integral (`queued_requests / drain_cycles` is the mean
    /// drained batch size under load). Monotone: survives evictions.
    pub queued_requests: u64,
    /// Non-empty drain cycles the worker has completed.
    pub drain_cycles: u64,
    /// `(mesh_id, kind)` dispatch groups formed across all drain cycles —
    /// with `queued_requests`, the per-drain group-size signal
    /// (`queued_requests / dispatch_groups` is the mean group size).
    pub dispatch_groups: u64,
    /// Requests answered with [`SolveError::Expired`] — their deadline
    /// passed while queued, so they were never solved.
    pub expired_requests: u64,
    /// Requests rejected at admission ([`SolveError::Overloaded`]) by the
    /// bounded queue; they never reached the worker.
    pub rejected_requests: u64,
    /// Lanes that failed their first solve and entered the escalation
    /// ladder (whether or not a stage recovered them).
    pub retried_lanes: u64,
    /// Escalated lanes a ladder stage successfully recovered.
    pub rescued_lanes: u64,
    /// High-water mark of the admission-queue depth (requests submitted
    /// but not yet drained) since server start.
    pub queue_high_water: u64,
    /// Requests shed synchronously ([`SolveError::Unhealthy`]) because
    /// their mesh's circuit breaker was Open.
    pub shed_requests: u64,
    /// Circuit-breaker trips: Closed → Open plus failed-probe
    /// HalfOpen → Open transitions.
    pub breaker_opens: u64,
    /// Open → HalfOpen probe admissions.
    pub breaker_half_opens: u64,
    /// HalfOpen → Closed recoveries (successful probes).
    pub breaker_closes: u64,
    /// Escalation-ladder rungs skipped by budget-aware escalation
    /// because their cost estimate exceeded the deadline budget.
    pub skipped_rungs: u64,
    /// Episodes in which adaptive shedding tightened the admission bound
    /// (sick traffic dominated recent outcomes).
    pub queue_tightenings: u64,
    /// The admission bound currently in force: the configured
    /// `set_max_queue` value, or its tightened fraction while adaptive
    /// shedding is active (`0` = unbounded).
    pub effective_max_queue: u64,
}
