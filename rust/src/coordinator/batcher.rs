//! The batched solver: amortizes per-problem state across a group of
//! right-hand sides.
//!
//! Naive pipeline per sample: assemble K → assemble F → condense → build
//! preconditioner → solve. Batched pipeline: K, condensation bookkeeping
//! and the preconditioner are built ONCE; each sample costs one load
//! assembly + one iterative solve. This is exactly the amortization
//! Fig B.4 measures (flat runtime until the per-sample cost dominates).

use anyhow::Result;

use crate::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
use crate::bc::{condense, DirichletBc, ReducedSystem};
use crate::mesh::Mesh;
use crate::solver::{cg, JacobiPrecond, SolverConfig};

use super::api::{SolveRequest, SolveResponse};

/// Shared state for a fixed-operator batch workload.
pub struct BatchSolver {
    pub ctx: AssemblyContext,
    sys: ReducedSystem,
    precond: JacobiPrecond,
    config: SolverConfig,
}

impl BatchSolver {
    /// Build the amortized state (assemble K once, condense, precondition).
    pub fn new(mesh: &Mesh, config: SolverConfig) -> BatchSolver {
        let ctx = AssemblyContext::new(mesh, 1);
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        let zero = vec![0.0; ctx.n_dofs()];
        let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
        let sys = condense(&k, &zero, &bc);
        let precond = JacobiPrecond::new(&sys.k);
        BatchSolver {
            ctx,
            sys,
            precond,
            config,
        }
    }

    /// Solve one request against the amortized operator.
    pub fn solve_one(&self, req: &SolveRequest) -> Result<SolveResponse> {
        let f = self.ctx.assemble_vector(&LinearForm::Source {
            f: self.ctx.coeff_nodal(&req.f_nodal),
        });
        let rhs = self.sys.restrict(&f);
        let (u_free, stats) = cg(&self.sys.k, &rhs, &self.precond, &self.config);
        anyhow::ensure!(stats.converged, "batch solve {} failed: {stats:?}", req.id);
        Ok(SolveResponse {
            id: req.id,
            u: self.sys.expand(&u_free),
            iterations: stats.iterations,
            rel_residual: stats.rel_residual,
        })
    }

    /// Solve a whole batch; per-sample state sharing is the point.
    pub fn solve_batch(&self, reqs: &[SolveRequest]) -> Result<Vec<SolveResponse>> {
        reqs.iter().map(|r| self.solve_one(r)).collect()
    }

    pub fn n_dofs(&self) -> usize {
        self.ctx.n_dofs()
    }
}

/// The naive per-sample pipeline (baseline in Fig B.4): everything rebuilt
/// for every sample.
pub fn solve_unbatched(
    mesh: &Mesh,
    reqs: &[SolveRequest],
    config: SolverConfig,
) -> Result<Vec<SolveResponse>> {
    reqs.iter()
        .map(|r| {
            let solver = BatchSolver::new(mesh, config);
            solver.solve_one(r)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::structured::unit_cube_tet;
    use crate::util::rng::Rng;

    fn requests(n_nodes: usize, count: usize, seed: u64) -> Vec<SolveRequest> {
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|id| SolveRequest {
                id: id as u64,
                f_nodal: (0..n_nodes).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
            })
            .collect()
    }

    #[test]
    fn batched_equals_unbatched() {
        let mesh = unit_cube_tet(4);
        let cfg = SolverConfig::default();
        let reqs = requests(mesh.n_nodes(), 3, 5);
        let batch = BatchSolver::new(&mesh, cfg);
        let a = batch.solve_batch(&reqs).unwrap();
        let b = solve_unbatched(&mesh, &reqs, cfg).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert!(crate::util::rel_l2(&x.u, &y.u) < 1e-9);
        }
    }

    #[test]
    fn linearity_of_the_solve() {
        // u(f1 + f2) = u(f1) + u(f2) — catches state leakage across batch.
        let mesh = unit_cube_tet(3);
        let batch = BatchSolver::new(&mesh, SolverConfig::default());
        let reqs = requests(mesh.n_nodes(), 2, 9);
        let sum_req = SolveRequest {
            id: 99,
            f_nodal: reqs[0]
                .f_nodal
                .iter()
                .zip(&reqs[1].f_nodal)
                .map(|(a, b)| a + b)
                .collect(),
        };
        let r = batch.solve_batch(&reqs).unwrap();
        let rs = batch.solve_one(&sum_req).unwrap();
        let sum_u: Vec<f64> = r[0].u.iter().zip(&r[1].u).map(|(a, b)| a + b).collect();
        assert!(crate::util::rel_l2(&rs.u, &sum_u) < 1e-7);
    }
}
