//! The batched solver: amortizes per-problem state across a group of
//! right-hand sides.
//!
//! Naive pipeline per sample: assemble K → assemble F → condense → build
//! preconditioner → solve. Batched pipeline: K, condensation bookkeeping
//! and the preconditioner are built ONCE; each sample costs one load
//! assembly + one iterative solve. This is exactly the amortization
//! Fig B.4 measures (flat runtime until the per-sample cost dominates).
//! Since PR 2 the solve phase is blocked as well: the `S` CG solves
//! advance in lockstep ([`cg_batch`]) so every Krylov iteration performs
//! ONE fused pass over the shared sparsity pattern instead of `S`, and the
//! varcoeff path condenses all `S` operators through one setup-time
//! symbolic mapping ([`CondensePlan`]).

use anyhow::Result;

use crate::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
use crate::bc::{condense, CondensePlan, DirichletBc, ReducedSystem};
use crate::mesh::Mesh;
use crate::solver::{cg, cg_batch, JacobiPrecond, MultiRhs, SolverConfig};

use super::api::{SolveRequest, SolveResponse, VarCoeffRequest};

/// Shared state for a fixed-operator batch workload.
pub struct BatchSolver {
    pub ctx: AssemblyContext,
    sys: ReducedSystem,
    precond: JacobiPrecond,
    /// Dirichlet symbolic mapping on the shared pattern — built once at
    /// setup, reused by every varcoeff batch condensation.
    cplan: CondensePlan,
    config: SolverConfig,
}

impl BatchSolver {
    /// Build the amortized state (assemble K once, condense, precondition).
    pub fn new(mesh: &Mesh, config: SolverConfig) -> BatchSolver {
        let ctx = AssemblyContext::new(mesh, 1);
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        let zero = vec![0.0; ctx.n_dofs()];
        let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
        let cplan = CondensePlan::new(k.nrows, &k.indptr, &k.indices, &bc);
        // One symbolic traversal serves both the cached plan and the
        // fixed-operator reduced system.
        let sys = cplan.apply(&k.data, &zero);
        let precond = JacobiPrecond::new(&sys.k);
        BatchSolver {
            ctx,
            sys,
            precond,
            cplan,
            config,
        }
    }

    /// Solve one request against the amortized operator.
    pub fn solve_one(&self, req: &SolveRequest) -> Result<SolveResponse> {
        let f = self.ctx.assemble_vector(&LinearForm::Source {
            f: self.ctx.coeff_nodal(&req.f_nodal),
        });
        let rhs = self.sys.restrict(&f);
        let (u_free, stats) = cg(&self.sys.k, &rhs, &self.precond, &self.config);
        anyhow::ensure!(stats.converged, "batch solve {} failed: {stats:?}", req.id);
        Ok(SolveResponse {
            id: req.id,
            u: self.sys.expand(&u_free),
            iterations: stats.iterations,
            rel_residual: stats.rel_residual,
        })
    }

    /// Solve a whole batch. Beyond the amortized operator state, the `S`
    /// load assemblies run as ONE batched Map-Reduce (fused `S × E`
    /// Batch-Map + fused `S × N` Sparse-Reduce) instead of `S` scalar
    /// assembly calls, and the `S` solves run as ONE lockstep CG on the
    /// shared condensed operator ([`MultiRhs`]: every Krylov iteration
    /// reads the pattern and values once for the whole batch). Results are
    /// identical to [`BatchSolver::solve_one`] per request.
    pub fn solve_batch(&self, reqs: &[SolveRequest]) -> Result<Vec<SolveResponse>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let forms: Vec<LinearForm> = reqs
            .iter()
            .map(|r| LinearForm::Source { f: self.ctx.coeff_nodal(&r.f_nodal) })
            .collect();
        let fbatch = self.ctx.assemble_vector_batch(&forms);
        let n = self.ctx.n_dofs();
        let nf = self.sys.free.len();
        let mut rhs = Vec::with_capacity(reqs.len() * nf);
        for s in 0..reqs.len() {
            rhs.extend(self.sys.restrict(&fbatch[s * n..(s + 1) * n]));
        }
        let op =
            MultiRhs::with_inv_diag(&self.sys.k, reqs.len(), self.precond.inv_diag().to_vec());
        let (u, stats) = cg_batch(&op, &rhs, &self.config);
        reqs.iter()
            .enumerate()
            .map(|(s, req)| {
                let st = stats[s];
                anyhow::ensure!(st.converged, "batch solve {} failed: {st:?}", req.id);
                Ok(SolveResponse {
                    id: req.id,
                    u: self.sys.expand(&u[s * nf..(s + 1) * nf]),
                    iterations: st.iterations,
                    rel_residual: st.rel_residual,
                })
            })
            .collect()
    }

    /// Multi-instance batch: every request carries its own coefficient
    /// field, so each sample is a *different operator* on the shared
    /// topology. All `S` stiffness matrices are produced by one
    /// shared-topology Map-Reduce — the separable weighted-gather plan on
    /// P1 simplices, the fused generic batch otherwise — into a
    /// [`crate::sparse::CsrBatch`] with one symbolic pattern; the `S` load
    /// vectors by one batched vector assembly. Condensation reuses the
    /// setup-time symbolic mapping ([`CondensePlan`]) and the `S` solves
    /// advance in lockstep ([`cg_batch`]: one fused SpMV per Krylov
    /// iteration), bitwise identical to the per-instance pipeline.
    pub fn solve_varcoeff_batch(&self, reqs: &[VarCoeffRequest]) -> Result<Vec<SolveResponse>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let ctx = &self.ctx;
        let proto = BilinearForm::Diffusion { rho: Coefficient::Const(1.0) };
        let kbatch = match ctx.batched(&proto) {
            Some(plan) => {
                // Separable path: each request's nodal coefficient
                // collapses straight to per-element scalars through the
                // context workspace — no per-request quadrature `Vec` is
                // materialized (bitwise-identical to evaluating
                // `coeff_nodal` first).
                let nodal: Vec<&[f64]> = reqs.iter().map(|r| r.rho_nodal.as_slice()).collect();
                plan.assemble_nodal(&nodal)
            }
            None => {
                let forms: Vec<BilinearForm> = reqs
                    .iter()
                    .map(|r| BilinearForm::Diffusion { rho: ctx.coeff_nodal(&r.rho_nodal) })
                    .collect();
                ctx.assemble_matrix_batch(&forms)
            }
        };
        let lforms: Vec<LinearForm> = reqs
            .iter()
            .map(|r| LinearForm::Source { f: ctx.coeff_nodal(&r.f_nodal) })
            .collect();
        let fbatch = ctx.assemble_vector_batch(&lforms);
        // The Dirichlet symbolic mapping was computed once at setup; each
        // batch only pays the value gather + lift.
        let red = self.cplan.apply_batch(&kbatch, &fbatch);
        let (u, stats) = cg_batch(&red.k, &red.rhs, &self.config);
        let nf = red.n_free();
        reqs.iter()
            .enumerate()
            .map(|(s, req)| {
                let st = stats[s];
                anyhow::ensure!(st.converged, "varcoeff solve {} failed: {st:?}", req.id);
                Ok(SolveResponse {
                    id: req.id,
                    u: red.expand(&u[s * nf..(s + 1) * nf]),
                    iterations: st.iterations,
                    rel_residual: st.rel_residual,
                })
            })
            .collect()
    }

    /// The scalar (one-assembly-per-request) counterpart of
    /// [`BatchSolver::solve_varcoeff_batch`] — the baseline the batched
    /// path is benchmarked against, and its parity oracle in tests.
    pub fn solve_varcoeff_sequential(
        &self,
        reqs: &[VarCoeffRequest],
    ) -> Result<Vec<SolveResponse>> {
        let ctx = &self.ctx;
        reqs.iter()
            .map(|req| {
                let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
                    rho: ctx.coeff_nodal(&req.rho_nodal),
                });
                let f = ctx.assemble_vector(&LinearForm::Source {
                    f: ctx.coeff_nodal(&req.f_nodal),
                });
                let sys = condense(&k, &f, &self.sys.bc);
                let pc = JacobiPrecond::new(&sys.k);
                let (u_free, stats) = cg(&sys.k, &sys.rhs, &pc, &self.config);
                anyhow::ensure!(stats.converged, "varcoeff solve {} failed: {stats:?}", req.id);
                Ok(SolveResponse {
                    id: req.id,
                    u: sys.expand(&u_free),
                    iterations: stats.iterations,
                    rel_residual: stats.rel_residual,
                })
            })
            .collect()
    }

    pub fn n_dofs(&self) -> usize {
        self.ctx.n_dofs()
    }
}

/// The naive per-sample pipeline (baseline in Fig B.4): everything rebuilt
/// for every sample.
pub fn solve_unbatched(
    mesh: &Mesh,
    reqs: &[SolveRequest],
    config: SolverConfig,
) -> Result<Vec<SolveResponse>> {
    reqs.iter()
        .map(|r| {
            let solver = BatchSolver::new(mesh, config);
            solver.solve_one(r)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::structured::unit_cube_tet;
    use crate::util::rng::Rng;

    fn requests(n_nodes: usize, count: usize, seed: u64) -> Vec<SolveRequest> {
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|id| SolveRequest {
                id: id as u64,
                f_nodal: (0..n_nodes).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
            })
            .collect()
    }

    #[test]
    fn batched_equals_unbatched() {
        let mesh = unit_cube_tet(4);
        let cfg = SolverConfig::default();
        let reqs = requests(mesh.n_nodes(), 3, 5);
        let batch = BatchSolver::new(&mesh, cfg);
        let a = batch.solve_batch(&reqs).unwrap();
        let b = solve_unbatched(&mesh, &reqs, cfg).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert!(crate::util::rel_l2(&x.u, &y.u) < 1e-9);
        }
    }

    #[test]
    fn varcoeff_batch_matches_sequential() {
        let mesh = unit_cube_tet(3);
        let n = mesh.n_nodes();
        let solver = BatchSolver::new(&mesh, SolverConfig::default());
        let mut rng = Rng::new(17);
        let reqs: Vec<VarCoeffRequest> = (0..4)
            .map(|id| VarCoeffRequest {
                id,
                rho_nodal: (0..n).map(|_| rng.uniform_in(0.5, 2.0)).collect(),
                f_nodal: (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
            })
            .collect();
        let batched = solver.solve_varcoeff_batch(&reqs).unwrap();
        let seq = solver.solve_varcoeff_sequential(&reqs).unwrap();
        assert_eq!(batched.len(), 4);
        for (a, b) in batched.iter().zip(&seq) {
            assert_eq!(a.id, b.id);
            // Same operators bitwise → same CG trajectory → same solution.
            assert_eq!(a.iterations, b.iterations);
            assert!(crate::util::rel_l2(&a.u, &b.u) < 1e-14, "id {}", a.id);
        }
        // Distinct coefficients produce distinct solutions.
        assert!(crate::util::rel_l2(&batched[0].u, &batched[1].u) > 1e-6);
    }

    #[test]
    fn linearity_of_the_solve() {
        // u(f1 + f2) = u(f1) + u(f2) — catches state leakage across batch.
        let mesh = unit_cube_tet(3);
        let batch = BatchSolver::new(&mesh, SolverConfig::default());
        let reqs = requests(mesh.n_nodes(), 2, 9);
        let sum_req = SolveRequest {
            id: 99,
            f_nodal: reqs[0]
                .f_nodal
                .iter()
                .zip(&reqs[1].f_nodal)
                .map(|(a, b)| a + b)
                .collect(),
        };
        let r = batch.solve_batch(&reqs).unwrap();
        let rs = batch.solve_one(&sum_req).unwrap();
        let sum_u: Vec<f64> = r[0].u.iter().zip(&r[1].u).map(|(a, b)| a + b).collect();
        assert!(crate::util::rel_l2(&rs.u, &sum_u) < 1e-7);
    }
}
