//! The batched solver: amortizes per-problem state across a group of
//! right-hand sides.
//!
//! Naive pipeline per sample: assemble K → assemble F → condense → build
//! preconditioner → solve. Batched pipeline: K, condensation bookkeeping
//! and the preconditioner are built ONCE; each sample costs one load
//! assembly + one iterative solve. This is exactly the amortization
//! Fig B.4 measures (flat runtime until the per-sample cost dominates).
//! Since PR 2 the solve phase is blocked as well: the `S` CG solves
//! advance in lockstep ([`crate::solver::cg_batch`]) so every Krylov
//! iteration performs ONE fused pass over the shared sparsity pattern
//! instead of `S`, and the varcoeff path condenses all `S` operators
//! through one setup-time symbolic mapping.
//!
//! Since PR 6 the amortized per-mesh state itself lives in a
//! [`MeshSession`] (one owner for plan + engine + reduced system — see
//! [`crate::session`]); `BatchSolver` is the thin serving adapter that
//! adds request validation, batched load assembly, dispatch counters and
//! per-request fault isolation on top. In the sharded server each shard
//! worker owns its own `mesh_id → Arc<BatchSolver>` registry slice
//! (meshes are homed on one shard by the router's stable hash); the
//! `Arc` is what lets an idle shard steal a hot mesh's group and serve
//! it against a clone of the victim's built solver instead of
//! rebuilding it. The `*_each` entry points return
//! one `Result` per request — a malformed request (shape mismatch,
//! non-positive coefficient, NaN load), an expired deadline, or an
//! unconverged lane fails *that request only*; its healthy neighbors in
//! the same batched dispatch still get answers. Failures carry a typed
//! [`SolveError`] (downcast from the `anyhow` error) with the classified
//! [`crate::solver::FailureKind`] and the escalation ladder's accounting.
//! A live request deadline also *budgets* the ladder: the milliseconds
//! left at dispatch gate which rescue rungs may run, and unaffordable
//! rungs are skipped and recorded in the report (see [`crate::session`]).
//! The legacy `Result<Vec<_>>` wrappers keep the old abort-on-first-error
//! contract for callers that want it.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use anyhow::Result;

use crate::assembly::{BatchedPlan, BilinearForm, Coefficient, LinearForm};
use crate::mesh::Mesh;
use crate::session::MeshSession;
use crate::solver::{EscalationReport, SolveStats, SolverConfig};

use super::api::{SolveError, SolveRequest, SolveResponse, VarCoeffRequest};

/// Shared state for a fixed-operator batch workload: a [`MeshSession`]
/// (the solve stack) plus the serving-layer extras.
pub struct BatchSolver {
    /// The per-mesh solve stack — fixed Poisson operator, homogeneous
    /// Dirichlet clamp, engine built once. Under
    /// [`crate::solver::PrecondKind::Amg`] its hierarchy is the "one
    /// hierarchy per mesh": the fixed-operator paths use it directly and
    /// the varcoeff paths — whose per-request operators share this
    /// topology and spectrum — reuse it as a shared SPD preconditioner,
    /// so no request ever pays a hierarchy construction.
    session: MeshSession,
    /// Separable weighted-gather plan for the varcoeff diffusion operator
    /// (P1 simplices) — built lazily on the first varcoeff batch (pure
    /// fixed-operator workloads never pay the `E × kl²` unit-tensor Map),
    /// then reused by every later batch. `Some(None)` on non-separable
    /// topologies (Quad4), where the generic fused batch path runs.
    vplan: OnceLock<Option<BatchedPlan>>,
    /// Batched dispatches performed (one per `solve_batch`-family call
    /// that reached the lockstep solver) — the serving layer's regression
    /// hook proving drained bursts cost ONE batched solve, not S scalar
    /// ones.
    batched_solves: AtomicU64,
    /// Scalar dispatches performed (`solve_one` / `solve_varcoeff_one`).
    scalar_solves: AtomicU64,
    /// Lanes whose first solve failed and entered the escalation ladder.
    retried_lanes: AtomicU64,
    /// Escalated lanes a ladder stage recovered.
    rescued_lanes: AtomicU64,
    /// Ladder rungs skipped as unaffordable by budget-aware escalation.
    skipped_rungs: AtomicU64,
}

impl BatchSolver {
    /// Build the amortized state (assemble K once, condense, precondition).
    pub fn new(mesh: &Mesh, config: SolverConfig) -> BatchSolver {
        BatchSolver {
            session: MeshSession::poisson(mesh, config),
            vplan: OnceLock::new(),
            batched_solves: AtomicU64::new(0),
            scalar_solves: AtomicU64::new(0),
            retried_lanes: AtomicU64::new(0),
            rescued_lanes: AtomicU64::new(0),
            skipped_rungs: AtomicU64::new(0),
        }
    }

    /// The underlying per-mesh session.
    pub fn session(&self) -> &MeshSession {
        &self.session
    }

    /// The cached separable plan for the varcoeff diffusion operator,
    /// built on first use.
    fn varcoeff_plan(&self) -> &Option<BatchedPlan> {
        self.vplan.get_or_init(|| {
            self.session.ctx().batched_plan(&BilinearForm::Diffusion {
                rho: Coefficient::Const(1.0),
            })
        })
    }

    /// Batched dispatches performed so far (each covering a whole group).
    pub fn n_batched_solves(&self) -> u64 {
        self.batched_solves.load(Ordering::Relaxed)
    }

    /// Scalar dispatches performed so far.
    pub fn n_scalar_solves(&self) -> u64 {
        self.scalar_solves.load(Ordering::Relaxed)
    }

    /// Lanes that entered the escalation ladder so far.
    pub fn n_retried_lanes(&self) -> u64 {
        self.retried_lanes.load(Ordering::Relaxed)
    }

    /// Escalated lanes a ladder stage recovered so far.
    pub fn n_rescued_lanes(&self) -> u64 {
        self.rescued_lanes.load(Ordering::Relaxed)
    }

    /// Ladder rungs skipped as unaffordable so far.
    pub fn n_skipped_rungs(&self) -> u64 {
        self.skipped_rungs.load(Ordering::Relaxed)
    }

    /// Count an escalation report toward the retry/rescue/skip counters.
    fn track_escalation(&self, rep: &Option<EscalationReport>) {
        if let Some(rep) = rep {
            self.retried_lanes.fetch_add(1, Ordering::Relaxed);
            if rep.resolved() {
                self.rescued_lanes.fetch_add(1, Ordering::Relaxed);
            }
            if !rep.skipped.is_empty() {
                self.skipped_rungs.fetch_add(rep.skipped.len() as u64, Ordering::Relaxed);
            }
        }
    }

    /// Shape-check a fixed-operator request (and reject NaN/Inf loads — a
    /// non-finite `f_nodal` would contaminate its whole assembly tile) and
    /// enforce its deadline. Rejecting up front is what keeps a malformed
    /// request from panicking inside the nodal interpolation
    /// (out-of-bounds `f_nodal[cell[a]]`) and killing the serving worker.
    pub fn validate(&self, req: &SolveRequest) -> Result<()> {
        if let Some(d) = req.deadline {
            if Instant::now() >= d {
                return Err(SolveError::Expired { id: req.id }.into());
            }
        }
        if req.f_nodal.len() != self.n_dofs() {
            return Err(SolveError::Invalid {
                id: req.id,
                reason: format!(
                    "f_nodal has {} entries, mesh has {} dofs",
                    req.f_nodal.len(),
                    self.n_dofs()
                ),
            }
            .into());
        }
        if !req.f_nodal.iter().all(|v| v.is_finite()) {
            return Err(SolveError::Invalid {
                id: req.id,
                reason: "f_nodal must be finite (NaN/Inf load rejected)".to_string(),
            }
            .into());
        }
        Ok(())
    }

    /// Shape- and positivity-check a varcoeff request (`rho` must be a
    /// strictly positive finite field for the operator to stay SPD, and
    /// `f_nodal` must be finite) and enforce its deadline.
    pub fn validate_varcoeff(&self, req: &VarCoeffRequest) -> Result<()> {
        if let Some(d) = req.deadline {
            if Instant::now() >= d {
                return Err(SolveError::Expired { id: req.id }.into());
            }
        }
        let n = self.n_dofs();
        let invalid = |reason: String| -> Result<()> {
            Err(SolveError::Invalid { id: req.id, reason }.into())
        };
        if req.rho_nodal.len() != n {
            return invalid(format!(
                "rho_nodal has {} entries, mesh has {n} dofs",
                req.rho_nodal.len()
            ));
        }
        if req.f_nodal.len() != n {
            return invalid(format!(
                "f_nodal has {} entries, mesh has {n} dofs",
                req.f_nodal.len()
            ));
        }
        if !req.rho_nodal.iter().all(|&r| r.is_finite() && r > 0.0) {
            return invalid("rho_nodal must be strictly positive and finite".to_string());
        }
        if !req.f_nodal.iter().all(|v| v.is_finite()) {
            return invalid("f_nodal must be finite (NaN/Inf load rejected)".to_string());
        }
        Ok(())
    }

    /// Solve one request against the amortized operator.
    pub fn solve_one(&self, req: &SolveRequest) -> Result<SolveResponse> {
        self.validate(req)?;
        self.scalar_solves.fetch_add(1, Ordering::Relaxed);
        let ctx = self.session.ctx();
        let f = ctx.assemble_vector(&LinearForm::Source {
            f: ctx.coeff_nodal(&req.f_nodal),
        });
        let (u, stats, rep) =
            self.session.solve_with_load_resilient_budgeted(&f, budget_ms(req.deadline));
        self.track_escalation(&rep);
        respond(req.id, u, stats, rep)
    }

    /// Solve one varcoeff request through the full per-instance pipeline
    /// (assemble its operator, condense through the session constraints,
    /// precondition, solve — see [`MeshSession::solve_foreign`]).
    pub fn solve_varcoeff_one(&self, req: &VarCoeffRequest) -> Result<SolveResponse> {
        self.validate_varcoeff(req)?;
        self.scalar_solves.fetch_add(1, Ordering::Relaxed);
        let ctx = self.session.ctx();
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: ctx.coeff_nodal(&req.rho_nodal),
        });
        let f = ctx.assemble_vector(&LinearForm::Source {
            f: ctx.coeff_nodal(&req.f_nodal),
        });
        let (u, stats, rep) =
            self.session.solve_foreign_resilient_budgeted(&k, &f, budget_ms(req.deadline));
        self.track_escalation(&rep);
        respond(req.id, u, stats, rep)
    }

    /// Solve a whole batch with per-request fault isolation. Beyond the
    /// amortized operator state, the `S` load assemblies run as ONE
    /// batched Map-Reduce (fused `S × E` Batch-Map + fused `S × N`
    /// Sparse-Reduce) instead of `S` scalar assembly calls, and the `S`
    /// solves run as ONE lockstep CG on the shared condensed operator
    /// ([`MeshSession::solve_load_batch`]: every Krylov iteration reads
    /// the pattern and values once for the whole batch). Each lane is
    /// bitwise-identical to [`BatchSolver::solve_one`] on the same
    /// request.
    ///
    /// Malformed requests are rejected before assembly and unconverged
    /// lanes yield an `Err` — in both cases only for the offending
    /// request; every other lane still gets its answer.
    pub fn solve_batch_each(&self, reqs: &[SolveRequest]) -> Vec<Result<SolveResponse>> {
        let (out, valid) = partition_valid(reqs, |r| self.validate(r));
        if valid.is_empty() {
            return seal_lanes(out, &valid, |_, _| unreachable!("no valid lanes"));
        }
        self.batched_solves.fetch_add(1, Ordering::Relaxed);
        let ctx = self.session.ctx();
        let forms: Vec<LinearForm> = valid
            .iter()
            .map(|&i| LinearForm::Source { f: ctx.coeff_nodal(&reqs[i].f_nodal) })
            .collect();
        let fbatch = ctx.assemble_vector_batch(&forms);
        let n = self.n_dofs();
        let nf = self.session.n_free();
        let mut rhs = Vec::with_capacity(valid.len() * nf);
        for s in 0..valid.len() {
            rhs.extend(self.session.restrict(&fbatch[s * n..(s + 1) * n]));
        }
        let budgets: Vec<Option<f64>> =
            valid.iter().map(|&i| budget_ms(reqs[i].deadline)).collect();
        let (u, stats, reps) =
            self.session.solve_load_batch_resilient_budgeted(&rhs, Some(&budgets));
        seal_lanes(out, &valid, |s, i| {
            self.track_escalation(&reps[s]);
            respond(
                reqs[i].id,
                self.session.expand(&u[s * nf..(s + 1) * nf]),
                stats[s],
                reps[s].clone(),
            )
        })
    }

    /// Abort-on-first-error wrapper around
    /// [`BatchSolver::solve_batch_each`] (the historical contract: any
    /// failing lane fails the call).
    pub fn solve_batch(&self, reqs: &[SolveRequest]) -> Result<Vec<SolveResponse>> {
        self.solve_batch_each(reqs).into_iter().collect()
    }

    /// Multi-instance batch with per-request fault isolation: every
    /// request carries its own coefficient field, so each sample is a
    /// *different operator* on the shared topology. All `S` stiffness
    /// matrices are produced by one shared-topology Map-Reduce — the
    /// setup-cached separable weighted-gather plan on P1 simplices, the
    /// fused generic batch otherwise — into a [`crate::sparse::CsrBatch`]
    /// with one symbolic pattern; the `S` load vectors by one batched
    /// vector assembly. Condensation reuses the session's setup-time
    /// symbolic mapping and the `S` solves advance in lockstep
    /// ([`MeshSession::solve_varcoeff_batch`]: one fused SpMV per Krylov
    /// iteration), bitwise identical to the per-instance pipeline.
    /// Malformed requests and unconverged lanes fail individually, as in
    /// [`BatchSolver::solve_batch_each`].
    pub fn solve_varcoeff_batch_each(
        &self,
        reqs: &[VarCoeffRequest],
    ) -> Vec<Result<SolveResponse>> {
        let (out, valid) = partition_valid(reqs, |r| self.validate_varcoeff(r));
        if valid.is_empty() {
            return seal_lanes(out, &valid, |_, _| unreachable!("no valid lanes"));
        }
        self.batched_solves.fetch_add(1, Ordering::Relaxed);
        let ctx = self.session.ctx();
        let kbatch = match self.varcoeff_plan() {
            Some(plan) => {
                // Separable path: each request's nodal coefficient
                // collapses straight to per-element scalars through the
                // context workspace — no per-request quadrature `Vec` is
                // materialized (bitwise-identical to evaluating
                // `coeff_nodal` first).
                let nodal: Vec<&[f64]> =
                    valid.iter().map(|&i| reqs[i].rho_nodal.as_slice()).collect();
                ctx.batched_cached(plan).assemble_nodal(&nodal)
            }
            None => {
                let forms: Vec<BilinearForm> = valid
                    .iter()
                    .map(|&i| BilinearForm::Diffusion {
                        rho: ctx.coeff_nodal(&reqs[i].rho_nodal),
                    })
                    .collect();
                ctx.assemble_matrix_batch(&forms)
            }
        };
        let lforms: Vec<LinearForm> = valid
            .iter()
            .map(|&i| LinearForm::Source { f: ctx.coeff_nodal(&reqs[i].f_nodal) })
            .collect();
        let fbatch = ctx.assemble_vector_batch(&lforms);
        // The Dirichlet symbolic mapping was computed once at session
        // build; each batch only pays the value gather + lift. The
        // lockstep CG uses per-lane Jacobi under the default config
        // (bitwise) or ONE shared-mesh AMG hierarchy applied to all lanes
        // per iteration.
        let budgets: Vec<Option<f64>> =
            valid.iter().map(|&i| budget_ms(reqs[i].deadline)).collect();
        let (red, u, stats, reps) =
            self.session.solve_varcoeff_batch_resilient_budgeted(&kbatch, &fbatch, Some(&budgets));
        let nf = red.n_free();
        seal_lanes(out, &valid, |s, i| {
            self.track_escalation(&reps[s]);
            respond(
                reqs[i].id,
                red.expand(&u[s * nf..(s + 1) * nf]),
                stats[s],
                reps[s].clone(),
            )
        })
    }

    /// Abort-on-first-error wrapper around
    /// [`BatchSolver::solve_varcoeff_batch_each`].
    pub fn solve_varcoeff_batch(&self, reqs: &[VarCoeffRequest]) -> Result<Vec<SolveResponse>> {
        self.solve_varcoeff_batch_each(reqs).into_iter().collect()
    }

    /// The scalar (one-assembly-per-request) counterpart of
    /// [`BatchSolver::solve_varcoeff_batch`] — the baseline the batched
    /// path is benchmarked against, and its parity oracle in tests.
    pub fn solve_varcoeff_sequential(
        &self,
        reqs: &[VarCoeffRequest],
    ) -> Result<Vec<SolveResponse>> {
        reqs.iter().map(|req| self.solve_varcoeff_one(req)).collect()
    }

    pub fn n_dofs(&self) -> usize {
        self.session.ctx().n_dofs()
    }
}

/// Milliseconds left until a request deadline — the budget handed to the
/// session's escalation ladder (`None` = no deadline = unbounded).
/// Validation already rejected expired deadlines, so this is positive
/// for requests that reach a solve.
fn budget_ms(deadline: Option<Instant>) -> Option<f64> {
    deadline.map(|d| d.saturating_duration_since(Instant::now()).as_secs_f64() * 1e3)
}

/// Seal one lane's outcome: a converged solve becomes a [`SolveResponse`]
/// (carrying the escalation report when the ladder recovered it); a failed
/// one becomes a typed [`SolveError::Solver`] naming the
/// [`crate::solver::FailureKind`] — the single replacement for the four
/// historical `ensure!(stats.converged, …)` sites that stringified the
/// failure away.
fn respond(
    id: u64,
    u: Vec<f64>,
    stats: SolveStats,
    escalation: Option<EscalationReport>,
) -> Result<SolveResponse> {
    if stats.converged {
        Ok(SolveResponse {
            id,
            u,
            iterations: stats.iterations,
            rel_residual: stats.rel_residual,
            escalation,
        })
    } else {
        Err(SolveError::Solver { id, kind: stats.failure, stats, escalation }.into())
    }
}

/// Validate every request, pre-filling the per-request outcome slots with
/// the rejections; returns `(slots, indices of the valid lanes)`. Shared
/// scaffold of the `*_each` fault-isolated batch entry points.
fn partition_valid<R>(
    reqs: &[R],
    validate: impl Fn(&R) -> Result<()>,
) -> (Vec<Option<Result<SolveResponse>>>, Vec<usize>) {
    let mut out = Vec::with_capacity(reqs.len());
    let mut valid = Vec::with_capacity(reqs.len());
    for (i, req) in reqs.iter().enumerate() {
        match validate(req) {
            Ok(()) => {
                valid.push(i);
                out.push(None);
            }
            Err(e) => out.push(Some(Err(e))),
        }
    }
    (out, valid)
}

/// Fill the still-open outcome slots from the lockstep solve — `lane(s, i)`
/// answers request `i = valid[s]` — and unwrap every slot.
fn seal_lanes(
    mut out: Vec<Option<Result<SolveResponse>>>,
    valid: &[usize],
    mut lane: impl FnMut(usize, usize) -> Result<SolveResponse>,
) -> Vec<Result<SolveResponse>> {
    for (s, &i) in valid.iter().enumerate() {
        out[i] = Some(lane(s, i));
    }
    out.into_iter().map(|r| r.expect("every lane answered")).collect()
}

/// The naive per-sample pipeline (baseline in Fig B.4): everything rebuilt
/// for every sample.
pub fn solve_unbatched(
    mesh: &Mesh,
    reqs: &[SolveRequest],
    config: SolverConfig,
) -> Result<Vec<SolveResponse>> {
    reqs.iter()
        .map(|r| {
            let solver = BatchSolver::new(mesh, config);
            solver.solve_one(r)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::structured::unit_cube_tet;
    use crate::util::rng::Rng;

    fn requests(n_nodes: usize, count: usize, seed: u64) -> Vec<SolveRequest> {
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|id| {
                SolveRequest::new(
                    id as u64,
                    (0..n_nodes).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
                )
            })
            .collect()
    }

    fn varcoeff_requests(n_nodes: usize, count: usize, seed: u64) -> Vec<VarCoeffRequest> {
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|id| {
                VarCoeffRequest::new(
                    id as u64,
                    (0..n_nodes).map(|_| rng.uniform_in(0.5, 2.0)).collect(),
                    (0..n_nodes).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
                )
            })
            .collect()
    }

    #[test]
    fn batched_equals_unbatched() {
        let mesh = unit_cube_tet(4);
        let cfg = SolverConfig::default();
        let reqs = requests(mesh.n_nodes(), 3, 5);
        let batch = BatchSolver::new(&mesh, cfg);
        let a = batch.solve_batch(&reqs).unwrap();
        let b = solve_unbatched(&mesh, &reqs, cfg).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert!(crate::util::rel_l2(&x.u, &y.u) < 1e-9);
        }
    }

    #[test]
    fn batched_lane_is_bitwise_solve_one() {
        let mesh = unit_cube_tet(3);
        let solver = BatchSolver::new(&mesh, SolverConfig::default());
        let reqs = requests(mesh.n_nodes(), 4, 11);
        let batched = solver.solve_batch(&reqs).unwrap();
        for (resp, req) in batched.iter().zip(&reqs) {
            let one = solver.solve_one(req).unwrap();
            assert_eq!(resp.u, one.u, "lane {} not bitwise", req.id);
            assert_eq!(resp.iterations, one.iterations);
        }
    }

    #[test]
    fn varcoeff_batch_matches_sequential() {
        let mesh = unit_cube_tet(3);
        let solver = BatchSolver::new(&mesh, SolverConfig::default());
        let reqs = varcoeff_requests(mesh.n_nodes(), 4, 17);
        let batched = solver.solve_varcoeff_batch(&reqs).unwrap();
        let seq = solver.solve_varcoeff_sequential(&reqs).unwrap();
        assert_eq!(batched.len(), 4);
        for (a, b) in batched.iter().zip(&seq) {
            assert_eq!(a.id, b.id);
            // Same operators bitwise → same CG trajectory → same solution.
            assert_eq!(a.iterations, b.iterations);
            assert!(crate::util::rel_l2(&a.u, &b.u) < 1e-14, "id {}", a.id);
        }
        // Distinct coefficients produce distinct solutions.
        assert!(crate::util::rel_l2(&batched[0].u, &batched[1].u) > 1e-6);
    }

    #[test]
    fn linearity_of_the_solve() {
        // u(f1 + f2) = u(f1) + u(f2) — catches state leakage across batch.
        let mesh = unit_cube_tet(3);
        let batch = BatchSolver::new(&mesh, SolverConfig::default());
        let reqs = requests(mesh.n_nodes(), 2, 9);
        let sum_req = SolveRequest::new(
            99,
            reqs[0]
                .f_nodal
                .iter()
                .zip(&reqs[1].f_nodal)
                .map(|(a, b)| a + b)
                .collect(),
        );
        let r = batch.solve_batch(&reqs).unwrap();
        let rs = batch.solve_one(&sum_req).unwrap();
        let sum_u: Vec<f64> = r[0].u.iter().zip(&r[1].u).map(|(a, b)| a + b).collect();
        assert!(crate::util::rel_l2(&rs.u, &sum_u) < 1e-7);
    }

    #[test]
    fn malformed_lane_fails_alone() {
        let mesh = unit_cube_tet(3);
        let solver = BatchSolver::new(&mesh, SolverConfig::default());
        let mut reqs = requests(mesh.n_nodes(), 4, 23);
        reqs[2].f_nodal.truncate(5); // wrong shape
        let each = solver.solve_batch_each(&reqs);
        assert!(each[0].is_ok() && each[1].is_ok() && each[3].is_ok());
        assert!(each[2].is_err());
        // Healthy lanes are unchanged by the sick neighbor: bitwise equal
        // to solving them without it.
        let healthy: Vec<SolveRequest> =
            [0usize, 1, 3].iter().map(|&i| reqs[i].clone()).collect();
        let alone = solver.solve_batch(&healthy).unwrap();
        for (resp, idx) in alone.iter().zip([0usize, 1, 3]) {
            assert_eq!(each[idx].as_ref().unwrap().u, resp.u);
        }
    }

    #[test]
    fn varcoeff_malformed_and_nonpositive_fail_alone() {
        let mesh = unit_cube_tet(3);
        let solver = BatchSolver::new(&mesh, SolverConfig::default());
        let mut reqs = varcoeff_requests(mesh.n_nodes(), 4, 29);
        reqs[0].rho_nodal[3] = -1.0; // SPD violation
        reqs[2].rho_nodal.push(1.0); // wrong shape
        let each = solver.solve_varcoeff_batch_each(&reqs);
        assert!(each[0].is_err());
        assert!(each[1].is_ok());
        assert!(each[2].is_err());
        assert!(each[3].is_ok());
        let oracle = solver.solve_varcoeff_one(&reqs[1]).unwrap();
        assert_eq!(each[1].as_ref().unwrap().u, oracle.u);
    }

    #[test]
    fn unconverged_lane_fails_alone() {
        // max_iter too small for a genuine solve, but a zero RHS converges
        // at iteration 0 — so lane 1 succeeds while its neighbors fail.
        let mesh = unit_cube_tet(3);
        let cfg = SolverConfig {
            max_iter: 1,
            ..SolverConfig::default()
        };
        let solver = BatchSolver::new(&mesh, cfg);
        let mut reqs = requests(mesh.n_nodes(), 3, 31);
        reqs[1].f_nodal.iter_mut().for_each(|v| *v = 0.0);
        let each = solver.solve_batch_each(&reqs);
        assert!(each[0].is_err());
        assert!(each[2].is_err());
        let zero = each[1].as_ref().unwrap();
        assert!(zero.u.iter().all(|&v| v == 0.0));
        assert_eq!(zero.iterations, 0);
    }

    #[test]
    fn amg_configured_solver_serves_all_paths() {
        let mesh = unit_cube_tet(3);
        let cfg = SolverConfig {
            precond: crate::solver::PrecondKind::amg(),
            ..SolverConfig::default()
        };
        let solver = BatchSolver::new(&mesh, cfg);
        // Fixed-operator: batched lanes bitwise-match scalar AMG-PCG (one
        // shared hierarchy drives both paths).
        let reqs = requests(mesh.n_nodes(), 3, 51);
        let batched = solver.solve_batch(&reqs).unwrap();
        for (resp, req) in batched.iter().zip(&reqs) {
            let one = solver.solve_one(req).unwrap();
            assert_eq!(resp.u, one.u, "lane {} not bitwise under AMG", req.id);
            assert_eq!(resp.iterations, one.iterations);
        }
        // Varcoeff: the shared-mesh hierarchy preconditions every
        // per-request operator; batch lanes bitwise-match the scalar path.
        let vreqs = varcoeff_requests(mesh.n_nodes(), 3, 53);
        let vb = solver.solve_varcoeff_batch(&vreqs).unwrap();
        let vs = solver.solve_varcoeff_sequential(&vreqs).unwrap();
        for (a, b) in vb.iter().zip(&vs) {
            assert_eq!(a.iterations, b.iterations, "id {}", a.id);
            assert_eq!(a.u, b.u, "id {}", a.id);
        }
        // Same physics as the Jacobi-configured solver, to solver tol.
        let jac = BatchSolver::new(&mesh, SolverConfig::default());
        let jb = jac.solve_batch(&reqs).unwrap();
        for (a, b) in batched.iter().zip(&jb) {
            assert!(crate::util::rel_l2(&a.u, &b.u) < 1e-8, "id {}", a.id);
        }
    }

    #[test]
    fn dispatch_counters_track_calls() {
        let mesh = unit_cube_tet(3);
        let solver = BatchSolver::new(&mesh, SolverConfig::default());
        assert_eq!(solver.n_batched_solves(), 0);
        assert_eq!(solver.n_scalar_solves(), 0);
        let reqs = requests(mesh.n_nodes(), 4, 37);
        solver.solve_batch(&reqs).unwrap();
        assert_eq!((solver.n_batched_solves(), solver.n_scalar_solves()), (1, 0));
        solver.solve_one(&reqs[0]).unwrap();
        assert_eq!((solver.n_batched_solves(), solver.n_scalar_solves()), (1, 1));
        let vreqs = varcoeff_requests(mesh.n_nodes(), 3, 41);
        solver.solve_varcoeff_batch(&vreqs).unwrap();
        assert_eq!((solver.n_batched_solves(), solver.n_scalar_solves()), (2, 1));
    }
}
