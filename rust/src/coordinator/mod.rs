//! Batch-solve coordinator: the serving layer for many-query workloads
//! (batched dataset generation, Fig B.4; uncertainty quantification;
//! operator-learning data pipelines).
//!
//! Architecture (vLLM-router-style continuous batching, multi-mesh):
//! callers submit mesh-tagged [`SolveRequest`]s / [`VarCoeffRequest`]s to a
//! [`BatchServer`]; a worker thread drains the queue, groups pending
//! requests by `(mesh_id, request kind)`, and dispatches each group as ONE
//! batched assembly + lockstep-CG call through the per-mesh
//! [`BatchSolver`] — the scalar `solve_one` path runs only for singleton
//! groups. Per-mesh amortized state (assembly context, routing,
//! condensation plan, preconditioner engine — Jacobi or a per-mesh AMG
//! hierarchy, separable batched-assembly plan) lives in a registry
//! `mesh_id → BatchSolver`, built lazily on the first request for each
//! registered topology and LRU-capped by `max_mesh_states`, so one server
//! instance serves many mesh topologies with bounded resident state.
//!
//! Fault isolation: requests are shape-validated before they can reach the
//! assembly kernels, an unconverged lane fails only its own reply
//! (`solve_batch_each` / `solve_varcoeff_batch_each` return one `Result`
//! per request), and panics while serving a chunk are caught and converted
//! into per-request error responses — the worker survives hostile traffic
//! and `submit` surfaces a gone worker instead of hanging the client.
//! [`CoordinatorStats`] exposes the worker's dispatch counters (batched vs
//! scalar, failures, registry fills, evictions/rebuilds) for observability
//! and regression tests. Everything is std::sync::mpsc — no external
//! runtime.

pub mod api;
pub mod batcher;
pub mod server;

pub use api::{
    CoordinatorStats, SolveRequest, SolveResponse, VarCoeffRequest, DEFAULT_MESH,
};
pub use batcher::BatchSolver;
pub use server::BatchServer;
