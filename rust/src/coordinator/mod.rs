//! Batch-solve coordinator: the serving layer for many-query workloads
//! (batched dataset generation, Fig B.4; uncertainty quantification;
//! operator-learning data pipelines).
//!
//! Architecture (vLLM-router-style continuous batching, multi-mesh,
//! sharded): callers submit mesh-tagged [`SolveRequest`]s /
//! [`VarCoeffRequest`]s to a [`BatchServer`], which is split into a
//! routing front-end ([`router`]) and N per-shard workers ([`shard`],
//! `TG_SHARDS` / [`ShardConfig`]):
//!
//! * **Routing rule.** Every request is homed on
//!   `shard = splitmix64(mesh_id) % num_shards` — a stable hash, so a
//!   mesh's queue slot, solver state and LRU accounting always live on
//!   one shard (mesh affinity), and a burst lands as at most one queue
//!   entry per shard. All submit-time decisions (deadline expiry,
//!   circuit-breaker sheds, bounded admission) are made by the router
//!   before a request reaches any queue.
//! * **Global admission.** The bound set by [`BatchServer::set_max_queue`]
//!   is enforced against ONE server-wide in-flight depth, admitted or
//!   rejected all-or-nothing per burst — so [`SolveError::Overloaded`]
//!   semantics are identical at `TG_SHARDS=1` and `TG_SHARDS=8` (pinned
//!   by `tests/crash_recovery.rs`). Per-shard depths remain as live
//!   observability ([`BatchServer::per_shard`]), not as the gate.
//! * **Per-shard drain.** Each shard worker drains its own queue exactly
//!   like the original single worker: pending requests are grouped by
//!   `(mesh_id, request kind)` and the groups served round-robin in
//!   `max_batch`-sized chunks — each chunk ONE batched assembly +
//!   lockstep-CG call through the per-mesh [`BatchSolver`], with the
//!   scalar `solve_one` path reserved for singleton groups — so a large
//!   group cannot starve other meshes within a drain cycle.
//! * **Steal granularity.** With stealing on (`TG_STEAL`, default), an
//!   idle shard steals a whole `(mesh_id, kind)` group from a busy
//!   sibling's queue — never a partial group — so batched dispatch and
//!   the bitwise lockstep semantics survive stealing unchanged; the
//!   stolen mesh's built `Arc<BatchSolver>` is cloned from the victim's
//!   registry, never rebuilt. Candidates are breaker-gated (an Open
//!   mesh's backlog and a HalfOpen mesh's probe group never migrate;
//!   skips are counted in [`CoordinatorStats::steals_skipped`]) and
//!   ranked by hotness × estimated per-iteration cost × queue age. With
//!   `num_shards = 1` and stealing off ([`ShardConfig::single`]) every
//!   path is bitwise identical to the single-worker server (pinned by
//!   `tests/sharded_server.rs`).
//! * **Stats semantics.** [`CoordinatorStats`] stays the aggregate view:
//!   per-shard partials are folded with monotone counters SUMMED and the
//!   queue high-water mark MAXED over shards (a depth, not a flow);
//!   [`BatchServer::per_shard`] exposes the live per-shard breakdown
//!   ([`ShardStats`]: depth, high-water, steals, sheds) without a queue
//!   round-trip.
//!
//! The per-mesh amortized state is a [`BatchSolver`]: a thin adapter over
//! one [`crate::session::MeshSession`] (assembly context, condensation
//! plan, preconditioner engine — Jacobi or AMG hierarchy — and persistent
//! reduced-system scratch) plus the lazily built separable
//! batched-assembly plan. Solvers live in shard-local registries
//! `mesh_id → Arc<BatchSolver>`, built lazily on the first request for
//! each registered topology and LRU-capped by `max_mesh_states` per
//! shard, so one server instance serves many mesh topologies with
//! bounded resident state. New topologies can be registered over the
//! running server ([`BatchServer::register_mesh`]) — the
//! AMR-as-served-workload path. Shard workers do not oversubscribe the
//! element-parallel pool: all shards pipeline into the one global
//! `TG_THREADS` pool (see [`crate::util::threadpool`]).
//!
//! Fault isolation: requests are shape-validated before they can reach the
//! assembly kernels, an unconverged lane fails only its own reply
//! (`solve_batch_each` / `solve_varcoeff_batch_each` return one `Result`
//! per request), and panics while serving a chunk are caught and converted
//! into per-request error responses — the worker survives hostile traffic
//! and `submit` surfaces a gone worker instead of hanging the client.
//! [`CoordinatorStats`] exposes the worker's dispatch counters (batched vs
//! scalar, failures, registry fills, evictions/rebuilds, drained-queue
//! depth and dispatch-group telemetry) for observability and regression
//! tests. Everything is std::sync::mpsc — no external runtime.
//!
//! # Failure semantics
//!
//! Every failed request is answered with a typed [`SolveError`] carried
//! inside the `anyhow` error (`err.downcast_ref::<SolveError>()`), so
//! clients branch on the failure class instead of parsing strings:
//!
//! * [`SolveError::Invalid`] — rejected by validation (shape mismatch,
//!   non-positive coefficient, non-finite load) before any assembly.
//! * [`SolveError::Expired`] — the request carried a deadline
//!   ([`SolveRequest::with_deadline`]) that passed while it was queued;
//!   answered at dispatch without solving.
//! * [`SolveError::Overloaded`] — the bounded admission queue
//!   ([`BatchServer::set_max_queue`]) was full at submission; the request
//!   never reached the worker. Back off and resubmit.
//! * [`SolveError::Unhealthy`] — the target mesh's circuit breaker was
//!   Open; the request was shed synchronously with a `retry_after_ms`
//!   hint and never occupied a queue slot — or it was already queued
//!   when the breaker opened and was shed at drain time instead of
//!   occupying a dispatch slot.
//! * [`SolveError::Solver`] — the solve failed with a classified
//!   [`crate::solver::FailureKind`] (max-iterations, stagnation,
//!   breakdown, non-finite), including the escalation ladder's per-stage
//!   accounting when the session policy ran it and it was exhausted.
//! * [`SolveError::WorkerLost`] — the shard worker died holding the
//!   request (a panic escaped the per-chunk isolation) and the
//!   supervision retry budget was exhausted (or supervision was off at
//!   shutdown); `retryable` says whether an identical resubmission is
//!   expected to succeed.
//! * [`SolveError::Shutdown`] — [`BatchServer::shutdown_within`]'s drain
//!   deadline passed before the request was served.
//!
//! When [`crate::solver::EscalationPolicy`] is enabled on the server's
//! `SolverConfig`, failed lanes are retried through the session ladder
//! (cold restart → preconditioner escalation → iteration-budget bump →
//! dense-LU fallback) before a `Solver` error is returned; a rescued
//! request answers normally with the [`SolveResponse::escalation`] report
//! attached. Expired/rejected/retried/rescued counts and the
//! admission-queue high-water mark are surfaced in [`CoordinatorStats`].
//!
//! # Health tracking and the circuit breaker
//!
//! [`BatchServer::set_health_config`] (off by default — the disabled
//! default keeps every serving path bitwise identical to the tracker-free
//! stack) turns each served outcome into per-mesh failure history
//! ([`crate::session::health`]): outcome EWMAs, consecutive-failure
//! streaks and per-rung ladder statistics drive a Closed → Open →
//! HalfOpen circuit breaker per mesh. A chronically failing mesh is shed
//! *synchronously* at submission ([`SolveError::Unhealthy`]) without
//! occupying queue slots or the drain budget of healthy meshes, and
//! stragglers already queued when the breaker opened are shed at drain
//! time; after the open window one probe group tests recovery. The
//! health registry is GLOBAL — shared by the router and every shard —
//! so the one-probe-group-per-mesh invariant holds no matter how a
//! mesh's traffic is spread across shards. A request deadline doubles
//! as an escalation-ladder budget (rungs whose cost estimate does not fit
//! the time remaining are skipped and recorded), and a globally sick
//! request mix adaptively tightens the admission bound. Breaker
//! transitions, sheds, skipped rungs and the effective bound are
//! surfaced in [`CoordinatorStats`]; per-mesh [`HealthSnapshot`]s via
//! [`BatchServer::health`].
//!
//! # Supervision: crash tolerance and the answer guarantee
//!
//! [`BatchServer::set_supervision_config`] (off by default — disabled
//! supervision keeps every serving path bitwise identical to the
//! unsupervised server, pinned by `tests/crash_recovery.rs`) makes the
//! serving contract *every submitted request gets exactly one typed
//! answer, even across worker crashes*. The lifecycle:
//!
//! 1. **Liveness.** A router-side supervisor thread polls each shard:
//!    a `JoinHandle` watchdog detects a dead worker (a panic that escaped
//!    the per-chunk isolation — e.g. a registry state build blowing up),
//!    and a heartbeat epoch bumped each drain iteration detects a *wedged*
//!    one (alive but stuck with work queued; counted in
//!    [`CoordinatorStats::wedged_detections`], not killed).
//! 2. **Respawn.** A dead worker is replaced immediately. Workers are
//!    disposable: the registry (the retained mesh topology store plus
//!    built states), the queue and the monotone serving counters all live
//!    on the shard handle, so the respawned worker rebuilds any lost
//!    per-mesh solver state lazily and the folded stats never reset.
//! 3. **Salvage.** Before serving, a supervised worker parks clones of
//!    its in-flight batch on the handle, each sharing an answered flag
//!    with the live reply. After a crash the supervisor requeues the
//!    unanswered remainder to each request's home shard — bounded by the
//!    per-request retry budget ([`SupervisionConfig::max_requeues`]) —
//!    and answers the rest with a typed [`SolveError::WorkerLost`]. A
//!    HalfOpen probe group that died with its worker has its probe slot
//!    canceled, so a breaker cannot wedge in HalfOpen forever.
//! 4. **Shutdown.** [`BatchServer::shutdown`] still drains everything;
//!    [`BatchServer::shutdown_within`] bounds the wait and answers the
//!    undrained remainder with a typed [`SolveError::Shutdown`] instead
//!    of dropped channels.
//!
//! Respawns, requeues, losses, deadline-shutdown answers and wedge
//! detections are surfaced in [`CoordinatorStats`]; the crash drivers are
//! the `SHARD_PANIC` / `SESSION_BUILD_PANIC` failpoints under the
//! `fault-inject` feature (`util::faults`).

pub mod api;
pub mod batcher;
pub mod router;
mod shard;

pub use crate::session::health::{BreakerState, HealthConfig, HealthSnapshot};
pub use api::{
    CoordinatorStats, ShardConfig, ShardStats, SolveError, SolveRequest, SolveResponse,
    SupervisionConfig, VarCoeffRequest, DEFAULT_MESH,
};
pub use batcher::BatchSolver;
pub use router::BatchServer;
