//! Batch-solve coordinator: the serving layer for many-query workloads
//! (batched dataset generation, Fig B.4; uncertainty quantification;
//! operator-learning data pipelines).
//!
//! Architecture (vLLM-router-style continuous batching, multi-mesh):
//! callers submit mesh-tagged [`SolveRequest`]s / [`VarCoeffRequest`]s to a
//! [`BatchServer`]; a worker thread drains the queue, groups pending
//! requests by `(mesh_id, request kind)`, and serves the groups
//! round-robin in `max_batch`-sized chunks — each chunk ONE batched
//! assembly + lockstep-CG call through the per-mesh [`BatchSolver`], with
//! the scalar `solve_one` path reserved for singleton groups — so a large
//! group cannot starve requests for other meshes within a drain cycle.
//! The per-mesh amortized state is a [`BatchSolver`]: a thin adapter over
//! one [`crate::session::MeshSession`] (assembly context, condensation
//! plan, preconditioner engine — Jacobi or AMG hierarchy — and persistent
//! reduced-system scratch) plus the lazily built separable
//! batched-assembly plan. Solvers live in a registry
//! `mesh_id → Arc<BatchSolver>`, built lazily on the first request for
//! each registered topology and LRU-capped by `max_mesh_states`, so one
//! server instance serves many mesh topologies with bounded resident
//! state; the `Arc` is the seam for sharded multi-worker serving. New
//! topologies can be registered over the running server
//! ([`BatchServer::register_mesh`]) — the AMR-as-served-workload path.
//!
//! Fault isolation: requests are shape-validated before they can reach the
//! assembly kernels, an unconverged lane fails only its own reply
//! (`solve_batch_each` / `solve_varcoeff_batch_each` return one `Result`
//! per request), and panics while serving a chunk are caught and converted
//! into per-request error responses — the worker survives hostile traffic
//! and `submit` surfaces a gone worker instead of hanging the client.
//! [`CoordinatorStats`] exposes the worker's dispatch counters (batched vs
//! scalar, failures, registry fills, evictions/rebuilds, drained-queue
//! depth and dispatch-group telemetry) for observability and regression
//! tests. Everything is std::sync::mpsc — no external runtime.
//!
//! # Failure semantics
//!
//! Every failed request is answered with a typed [`SolveError`] carried
//! inside the `anyhow` error (`err.downcast_ref::<SolveError>()`), so
//! clients branch on the failure class instead of parsing strings:
//!
//! * [`SolveError::Invalid`] — rejected by validation (shape mismatch,
//!   non-positive coefficient, non-finite load) before any assembly.
//! * [`SolveError::Expired`] — the request carried a deadline
//!   ([`SolveRequest::with_deadline`]) that passed while it was queued;
//!   answered at dispatch without solving.
//! * [`SolveError::Overloaded`] — the bounded admission queue
//!   ([`BatchServer::set_max_queue`]) was full at submission; the request
//!   never reached the worker. Back off and resubmit.
//! * [`SolveError::Unhealthy`] — the target mesh's circuit breaker was
//!   Open; the request was shed synchronously with a `retry_after_ms`
//!   hint and never occupied a queue slot.
//! * [`SolveError::Solver`] — the solve failed with a classified
//!   [`crate::solver::FailureKind`] (max-iterations, stagnation,
//!   breakdown, non-finite), including the escalation ladder's per-stage
//!   accounting when the session policy ran it and it was exhausted.
//!
//! When [`crate::solver::EscalationPolicy`] is enabled on the server's
//! `SolverConfig`, failed lanes are retried through the session ladder
//! (cold restart → preconditioner escalation → iteration-budget bump →
//! dense-LU fallback) before a `Solver` error is returned; a rescued
//! request answers normally with the [`SolveResponse::escalation`] report
//! attached. Expired/rejected/retried/rescued counts and the
//! admission-queue high-water mark are surfaced in [`CoordinatorStats`].
//!
//! # Health tracking and the circuit breaker
//!
//! [`BatchServer::set_health_config`] (off by default — the disabled
//! default keeps every serving path bitwise identical to the tracker-free
//! stack) turns each served outcome into per-mesh failure history
//! ([`crate::session::health`]): outcome EWMAs, consecutive-failure
//! streaks and per-rung ladder statistics drive a Closed → Open →
//! HalfOpen circuit breaker per mesh. A chronically failing mesh is shed
//! *synchronously* at submission ([`SolveError::Unhealthy`]) without
//! occupying queue slots or the drain budget of healthy meshes; after the
//! open window one probe group tests recovery. A request deadline doubles
//! as an escalation-ladder budget (rungs whose cost estimate does not fit
//! the time remaining are skipped and recorded), and a globally sick
//! request mix adaptively tightens the admission bound. Breaker
//! transitions, sheds, skipped rungs and the effective bound are
//! surfaced in [`CoordinatorStats`]; per-mesh [`HealthSnapshot`]s via
//! [`BatchServer::health`].

pub mod api;
pub mod batcher;
pub mod server;

pub use crate::session::health::{BreakerState, HealthConfig, HealthSnapshot};
pub use api::{
    CoordinatorStats, SolveError, SolveRequest, SolveResponse, VarCoeffRequest, DEFAULT_MESH,
};
pub use batcher::BatchSolver;
pub use server::BatchServer;
