//! Batch-solve coordinator: the serving layer for many-query workloads
//! (batched dataset generation, Fig B.4; uncertainty quantification;
//! operator-learning data pipelines).
//!
//! Architecture (vLLM-router-style, scaled to this problem): callers submit
//! [`SolveRequest`]s to a [`BatchServer`]; a batcher thread drains the
//! queue, groups requests sharing a problem signature, amortizes the
//! per-problem state (assembly context, routing, condensation pattern,
//! preconditioner) across the group, and answers через response channels.
//! Everything is std::sync::mpsc — no external runtime.

pub mod api;
pub mod batcher;
pub mod server;

pub use api::{SolveRequest, SolveResponse, VarCoeffRequest};
pub use batcher::BatchSolver;
pub use server::BatchServer;
