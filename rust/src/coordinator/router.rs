//! The routing front-end of the sharded multi-mesh batch server.
//!
//! [`BatchServer`] owns N shard workers ([`super::shard`]), each draining
//! its own queue with the continuous-batching semantics of the original
//! single-worker server. The router makes every submit-time decision —
//! deadline expiry, circuit-breaker sheds, bounded admission — and then
//! routes each surviving request to the shard that owns its mesh:
//! `shard = splitmix64(mesh_id) % num_shards`, a stable hash, so a mesh's
//! requests, registry state and LRU accounting always live on one shard
//! (mesh affinity). A burst is split into at most one queue entry per
//! shard, so each shard's slice of the burst still lands in a single
//! drain cycle.
//!
//! Admission is bounded GLOBALLY: the configured `max_queue` applies to
//! one server-wide in-flight depth ([`super::shard::Admission::depth`]),
//! and a burst is admitted or rejected all-or-nothing against it — so
//! `Overloaded` behavior is identical at `TG_SHARDS=1` and `TG_SHARDS=8`
//! (a single shard was already whole-burst). Health tracking is
//! GLOBAL: one `HealthRegistry` serves router-side admission, drain-time
//! straggler sheds and outcome observation on every shard, which makes
//! the one-probe-group-per-mesh invariant hold across shards for free.
//!
//! Supervision (default-off, [`BatchServer::set_supervision_config`]):
//! a router-side supervisor thread polls per-shard liveness — a
//! `JoinHandle` watchdog for dead workers, a heartbeat epoch for wedged
//! ones — and on a crash respawns the worker (the registry and counters
//! live on the [`ShardHandle`], which outlives the thread), then salvages
//! the parked in-flight batch: unanswered requests are requeued to their
//! mesh's home shard within a per-request retry budget, the rest are
//! answered with a typed [`SolveError::WorkerLost`]; a HalfOpen probe
//! group that died with its worker has its probe slot canceled. Every
//! submitted request gets exactly ONE typed answer, crash or not.
//! [`BatchServer::shutdown_within`] bounds shutdown: queued requests
//! that do not drain before the deadline are answered with a typed
//! [`SolveError::Shutdown`] instead of a dropped channel.
//!
//! Stats: [`BatchServer::stats`] broadcasts to every shard, folds the
//! per-shard partials (monotone counters summed, queue high-water maxed
//! — see [`fold_stats`]) and adds the router-owned globals; per-shard
//! live counters are available without a round-trip via
//! [`BatchServer::per_shard`]. With `num_shards = 1` and stealing off
//! (`ShardConfig::single`) every path — submission, drain order,
//! dispatch grouping, counters — is bitwise identical to the
//! single-worker server, pinned by `tests/sharded_server.rs`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::mesh::Mesh;
use crate::session::health::{AdmitDecision, BreakerState, HealthConfig, HealthSnapshot};
use crate::solver::SolverConfig;

use super::api::{
    CoordinatorStats, ShardConfig, ShardStats, SolveError, SolveRequest, SolveResponse,
    SupervisionConfig, VarCoeffRequest, DEFAULT_MESH,
};
use super::shard::{
    Admission, HealthShared, Msg, Reply, Req, ShardHandle, ShardWorker, SupervisionShared,
};

/// Hard cap on the shard worker count: shard workers are cheap (they
/// pipeline into the one global solve pool rather than spawning threads),
/// but an absurd `TG_SHARDS` must not spawn thousands of OS threads.
pub const MAX_SHARDS: usize = 64;

/// SplitMix64 finalizer: a stable, well-mixed `mesh_id → u64` hash, so
/// shard assignment is reproducible across runs/processes (no RandomState)
/// and sequential mesh ids spread evenly over shards.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Handle to the running sharded server.
pub struct BatchServer {
    shards: Arc<Vec<ShardHandle>>,
    /// Worker join handles, slot-per-shard; shared with the supervisor so
    /// it can watch, join and replace a dead shard's handle in place.
    workers: Arc<Mutex<Vec<Option<JoinHandle<()>>>>>,
    max_batch: usize,
    num_shards: usize,
    steal: bool,
    admission: Arc<Admission>,
    health: Arc<HealthShared>,
    sup: Arc<SupervisionShared>,
    supervisor: Mutex<Option<Supervisor>>,
}

/// The running supervisor thread and its stop flag.
struct Supervisor {
    stop: Arc<AtomicBool>,
    thread: JoinHandle<()>,
}

/// Fold per-shard PARTIAL stats into one aggregate: every monotone
/// counter is summed; `queue_high_water` — a depth, not a flow — is the
/// MAX over shards (summing would report a depth no single queue ever
/// reached). Router-owned fields (`effective_max_queue`, the health
/// counters, submit-time expiry) are zero in the partials and filled in
/// by the caller afterwards.
pub(super) fn fold_stats(parts: &[CoordinatorStats]) -> CoordinatorStats {
    let mut s = CoordinatorStats::default();
    for p in parts {
        s.batched_solves += p.batched_solves;
        s.scalar_solves += p.scalar_solves;
        s.failed_requests += p.failed_requests;
        s.meshes_built += p.meshes_built;
        s.evicted_states += p.evicted_states;
        s.state_rebuilds += p.state_rebuilds;
        s.queued_requests += p.queued_requests;
        s.drain_cycles += p.drain_cycles;
        s.dispatch_groups += p.dispatch_groups;
        s.expired_requests += p.expired_requests;
        s.rejected_requests += p.rejected_requests;
        s.retried_lanes += p.retried_lanes;
        s.rescued_lanes += p.rescued_lanes;
        s.shed_requests += p.shed_requests;
        s.breaker_opens += p.breaker_opens;
        s.breaker_half_opens += p.breaker_half_opens;
        s.breaker_closes += p.breaker_closes;
        s.skipped_rungs += p.skipped_rungs;
        s.queue_tightenings += p.queue_tightenings;
        s.stolen_groups += p.stolen_groups;
        s.steals_skipped += p.steals_skipped;
        s.queue_high_water = s.queue_high_water.max(p.queue_high_water);
    }
    s
}

/// Spawn one shard worker thread over the shared handles. Used both at
/// startup and by the supervisor when it resurrects a crashed shard: the
/// worker carries no state of its own (registry, queue and counters all
/// live on the [`ShardHandle`]), so a respawn is exactly a restart.
fn spawn_shard_worker(
    idx: usize,
    shards: &Arc<Vec<ShardHandle>>,
    max_batch: usize,
    steal: bool,
    admission: &Arc<Admission>,
    health: &Arc<HealthShared>,
    sup: &Arc<SupervisionShared>,
) -> JoinHandle<()> {
    let w = ShardWorker::new(
        idx,
        Arc::clone(shards),
        max_batch,
        steal,
        Arc::clone(admission),
        Arc::clone(health),
        Arc::clone(sup),
    );
    std::thread::Builder::new()
        .name(format!("tg-shard-{idx}"))
        .spawn(move || w.run())
        .expect("spawn shard worker")
}

impl BatchServer {
    /// Spawn a single-mesh server (the mesh is registered under
    /// [`DEFAULT_MESH`]); `max_batch` bounds the batched dispatch size.
    /// Shard count and stealing come from the environment
    /// ([`ShardConfig::from_env`]: `TG_SHARDS` / `TG_STEAL`).
    pub fn start(mesh: Mesh, config: SolverConfig, max_batch: usize) -> BatchServer {
        BatchServer::start_multi(vec![(DEFAULT_MESH, mesh)], config, max_batch, 0)
    }

    /// Spawn a server over many registered mesh topologies. Per-mesh
    /// solver state is built lazily on the first request tagged with each
    /// `mesh_id`; `max_mesh_states` caps how many built states stay
    /// resident PER SHARD (LRU eviction; 0 = unbounded). Shard count and
    /// stealing come from the environment ([`ShardConfig::from_env`]).
    pub fn start_multi(
        meshes: Vec<(u64, Mesh)>,
        config: SolverConfig,
        max_batch: usize,
        max_mesh_states: usize,
    ) -> BatchServer {
        BatchServer::start_sharded(meshes, config, max_batch, max_mesh_states, ShardConfig::from_env())
    }

    /// Spawn a server with an explicit [`ShardConfig`]. Each registered
    /// mesh is homed on `splitmix64(mesh_id) % num_shards`; with
    /// `num_shards = 1` and stealing off this is bitwise the
    /// single-worker server.
    pub fn start_sharded(
        meshes: Vec<(u64, Mesh)>,
        config: SolverConfig,
        max_batch: usize,
        max_mesh_states: usize,
        shard_cfg: ShardConfig,
    ) -> BatchServer {
        let num_shards = shard_cfg.num_shards.clamp(1, MAX_SHARDS);
        // One shard has no sibling to steal from; keep the flag honest.
        let steal = shard_cfg.steal && num_shards > 1;
        let shards: Arc<Vec<ShardHandle>> = Arc::new(
            (0..num_shards).map(|_| ShardHandle::new(config, max_mesh_states)).collect(),
        );
        let admission = Arc::new(Admission::default());
        let health = Arc::new(HealthShared::new());
        let sup = Arc::new(SupervisionShared::new());
        for (mesh_id, mesh) in meshes {
            let si = shard_of_n(mesh_id, num_shards);
            shards[si].registry().register(mesh_id, mesh);
        }
        let workers = Arc::new(Mutex::new(
            (0..num_shards)
                .map(|idx| {
                    Some(spawn_shard_worker(
                        idx,
                        &shards,
                        max_batch,
                        steal,
                        &admission,
                        &health,
                        &sup,
                    ))
                })
                .collect::<Vec<_>>(),
        ));
        BatchServer {
            shards,
            workers,
            max_batch,
            num_shards,
            steal,
            admission,
            health,
            sup,
            supervisor: Mutex::new(None),
        }
    }

    /// Max requests per batched dispatch (larger groups are served in
    /// `max_batch`-sized chunks, bounding lockstep memory). Fixed at
    /// start — the shard workers snapshot it.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Number of shard workers draining the server.
    pub fn num_shards(&self) -> usize {
        self.num_shards
    }

    /// Whether idle shards may steal groups from busy siblings.
    pub fn steal_enabled(&self) -> bool {
        self.steal
    }

    /// The shard that owns `mesh_id` (stable hash): its requests queue
    /// there, its registry state lives there. Exposed so tests and
    /// benchmarks can construct colliding or spread-out mesh id sets.
    pub fn shard_of(&self, mesh_id: u64) -> usize {
        shard_of_n(mesh_id, self.num_shards)
    }

    /// Bound the admission queue: a burst that would push the GLOBAL
    /// in-flight depth (submitted but not yet drained, summed over all
    /// shards) past `n` is rejected at submission with
    /// [`SolveError::Overloaded`] per request — it never reaches a shard,
    /// and the decision is all-or-nothing per burst, so it is independent
    /// of the shard count. `0` removes the bound (the default). Setting
    /// the bound also resets any adaptive tightening: `n` becomes both
    /// the base and the effective bound until the next retune.
    pub fn set_max_queue(&self, n: usize) {
        self.admission.base_max_queue.store(n, Ordering::Relaxed);
        self.admission.max_queue.store(n, Ordering::Relaxed);
    }

    /// Enable (or reconfigure) health tracking and the per-mesh circuit
    /// breaker; `HealthConfig::disabled()` switches it back off. Resets
    /// all tracked health state. While disabled (the default) every
    /// serving path is bitwise identical to the tracker-free stack. The
    /// registry is global — one breaker and one probe group per mesh, no
    /// matter how many shards serve its traffic.
    pub fn set_health_config(&self, cfg: HealthConfig) {
        let enabled = cfg.enabled;
        self.health.lock().reconfigure(cfg);
        self.health.enabled.store(enabled, Ordering::Relaxed);
    }

    /// Per-mesh health snapshot: `None` while tracking is disabled or
    /// before the first observed/shed request for `mesh_id`.
    pub fn health(&self, mesh_id: u64) -> Option<HealthSnapshot> {
        if !self.health.enabled.load(Ordering::Relaxed) {
            return None;
        }
        self.health.lock().snapshot(mesh_id)
    }

    /// Advance the injected manual clock (tests; requires
    /// `HealthConfig::manual_clock`). A no-op on the wall clock.
    pub fn advance_health_clock(&self, ms: u64) {
        self.health.lock().advance_clock(ms);
    }

    /// Enable (or reconfigure) the supervision layer.
    /// [`SupervisionConfig::supervised`] starts a router-side supervisor
    /// thread that watches per-shard liveness and resurrects crashed
    /// workers, salvaging their parked in-flight batches (see the module
    /// docs for the answer guarantees); [`SupervisionConfig::disabled`]
    /// stops it. While disabled (the default) every serving path is
    /// bitwise identical to the unsupervised stack — workers skip the
    /// in-flight parking entirely.
    pub fn set_supervision_config(&self, cfg: SupervisionConfig) {
        self.stop_supervisor();
        self.sup.max_requeues.store(cfg.max_requeues as u64, Ordering::Relaxed);
        self.sup.enabled.store(cfg.enabled, Ordering::Relaxed);
        if !cfg.enabled {
            return;
        }
        let stop = Arc::new(AtomicBool::new(false));
        let ctx = SupervisorCtx {
            shards: Arc::clone(&self.shards),
            workers: Arc::clone(&self.workers),
            sup: Arc::clone(&self.sup),
            admission: Arc::clone(&self.admission),
            health: Arc::clone(&self.health),
            max_batch: self.max_batch,
            steal: self.steal,
            poll: Duration::from_millis(cfg.poll_ms.max(1)),
            wedged_after: (cfg.wedged_after_ms > 0)
                .then(|| Duration::from_millis(cfg.wedged_after_ms)),
            stop: Arc::clone(&stop),
        };
        let thread = std::thread::Builder::new()
            .name("tg-supervisor".into())
            .spawn(move || ctx.run())
            .expect("spawn supervisor");
        *self.lock_supervisor() = Some(Supervisor { stop, thread });
    }

    fn lock_supervisor(&self) -> MutexGuard<'_, Option<Supervisor>> {
        self.supervisor.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Stop the supervisor thread if one is running (idempotent).
    fn stop_supervisor(&self) {
        let running = self.lock_supervisor().take();
        if let Some(s) = running {
            s.stop.store(true, Ordering::Relaxed);
            let _ = s.thread.join();
        }
    }

    /// Register (or replace) a mesh topology on the running server — it
    /// is homed on its hash shard. Synchronous: returns once the owning
    /// shard has installed the mesh, so a subsequent request tagged with
    /// `mesh_id` is guaranteed to find it. Replacing an id retires any
    /// built solver state for the old topology (counted as an eviction).
    pub fn register_mesh(&self, mesh_id: u64, mesh: Mesh) -> Result<()> {
        let (tx, rx) = channel();
        let si = self.shard_of(mesh_id);
        self.shards[si]
            .queue
            .push(Msg::Register(mesh_id, Box::new(mesh), tx))
            .map_err(|_| anyhow!("batch server worker is gone; mesh {mesh_id} not registered"))?;
        rx.recv()
            .map_err(|_| anyhow!("batch server worker died before registering mesh {mesh_id}"))
    }

    /// Submit a fixed-operator request; returns the response receiver.
    pub fn submit(&self, req: SolveRequest) -> Receiver<Result<SolveResponse>> {
        self.submit_burst(vec![Req::Fixed(req)]).remove(0)
    }

    /// Submit a varcoeff (own-operator) request.
    pub fn submit_varcoeff(&self, req: VarCoeffRequest) -> Receiver<Result<SolveResponse>> {
        self.submit_burst(vec![Req::Var(req)]).remove(0)
    }

    /// Submit a burst as ONE queue entry per shard: each shard's slice of
    /// the burst lands in a single drain cycle, so same-mesh bursts are
    /// guaranteed to be served by batched dispatches (in
    /// `max_batch`-sized chunks).
    pub fn submit_many(&self, reqs: Vec<SolveRequest>) -> Vec<Receiver<Result<SolveResponse>>> {
        self.submit_burst(reqs.into_iter().map(Req::Fixed).collect())
    }

    /// Varcoeff counterpart of [`BatchServer::submit_many`].
    pub fn submit_many_varcoeff(
        &self,
        reqs: Vec<VarCoeffRequest>,
    ) -> Vec<Receiver<Result<SolveResponse>>> {
        self.submit_burst(reqs.into_iter().map(Req::Var).collect())
    }

    fn submit_burst(&self, reqs: Vec<Req>) -> Vec<Receiver<Result<SolveResponse>>> {
        let adm = &self.admission;
        let n = reqs.len();
        // Synchronously decidable requests never take a queue slot. First:
        // a deadline already passed at submission is an immediate Expired
        // (the clock is read at most once, and only when a deadline is
        // present at all).
        let mut decisions: Vec<Option<SolveError>> = Vec::with_capacity(n);
        let mut now: Option<Instant> = None;
        for req in &reqs {
            let expired = req
                .deadline()
                .is_some_and(|d| *now.get_or_insert_with(Instant::now) >= d);
            if expired {
                adm.expired_at_submit.fetch_add(1, Ordering::Relaxed);
                decisions.push(Some(SolveError::Expired { id: req.id() }));
            } else {
                decisions.push(None);
            }
        }
        // Second: circuit-breaker sheds. ONE admit decision per mesh per
        // burst, so a HalfOpen mesh admits this burst's whole group as
        // its single probe (one probe *group*, not one probe request) —
        // the registry is global, so this holds across shards too.
        let mut probe_meshes: Vec<u64> = Vec::new();
        if self.health.enabled.load(Ordering::Relaxed) {
            let mut reg = self.health.lock();
            let mut memo: HashMap<u64, AdmitDecision> = HashMap::new();
            let mut shed = 0u64;
            for (req, slot) in reqs.iter().zip(decisions.iter_mut()) {
                if slot.is_some() {
                    continue;
                }
                let mesh_id = req.mesh_id();
                let decision = *memo.entry(mesh_id).or_insert_with(|| {
                    let d = reg.admit(mesh_id);
                    let probing = d == AdmitDecision::Admit
                        && reg
                            .snapshot(mesh_id)
                            .is_some_and(|s| s.state == BreakerState::HalfOpen);
                    if probing {
                        probe_meshes.push(mesh_id);
                    }
                    d
                });
                if let AdmitDecision::Shed { retry_after_ms } = decision {
                    shed += 1;
                    self.shards[self.shard_of(mesh_id)].shed.fetch_add(1, Ordering::Relaxed);
                    *slot = Some(SolveError::Unhealthy {
                        id: req.id(),
                        mesh_id,
                        retry_after_ms,
                    });
                }
            }
            if shed > 0 {
                reg.note_shed(shed);
            }
        }
        // Bounded admission for the undecided remainder, against ONE
        // global in-flight depth: the whole burst is admitted or rejected
        // all-or-nothing, so the `Overloaded` decision is independent of
        // how the burst happens to split across shards — identical at
        // `TG_SHARDS=1` and `TG_SHARDS=8` (a single shard was already
        // whole-burst, so this is also bitwise the old one-shard check).
        let mut shard_k = vec![0usize; self.num_shards];
        let mut k_total = 0usize;
        for (req, slot) in reqs.iter().zip(decisions.iter()) {
            if slot.is_none() {
                shard_k[self.shard_of(req.mesh_id())] += 1;
                k_total += 1;
            }
        }
        let max = adm.max_queue.load(Ordering::Relaxed);
        let mut overloaded: Option<(usize, usize)> = None;
        if k_total > 0 {
            let prev = adm.depth.fetch_add(k_total, Ordering::Relaxed);
            if max > 0 && prev + k_total > max {
                // Shed the whole burst without enqueueing (no worker ever
                // sees it), answering each request with a typed rejection
                // the caller can back off on. Rejections are attributed
                // to each request's home shard for observability.
                adm.depth.fetch_sub(k_total, Ordering::Relaxed);
                for (si, &k) in shard_k.iter().enumerate() {
                    if k > 0 {
                        self.shards[si].rejected.fetch_add(k as u64, Ordering::Relaxed);
                    }
                }
                overloaded = Some((prev, max));
            } else {
                // Per-shard depth/high-water stay maintained as live
                // observability (`per_shard`), not as admission authority.
                for (si, &k) in shard_k.iter().enumerate() {
                    if k > 0 {
                        let h = &self.shards[si];
                        let p = h.depth.fetch_add(k, Ordering::Relaxed);
                        h.high_water.fetch_max((p + k) as u64, Ordering::Relaxed);
                    }
                }
            }
        }
        // A rejected burst may have carried some meshes' HalfOpen probes:
        // free the probe slot so the next burst can probe instead of
        // waiting out the timeout.
        if overloaded.is_some() && !probe_meshes.is_empty() {
            let mut reg = self.health.lock();
            for &m in &probe_meshes {
                reg.cancel_probe(m);
            }
        }
        let mut items: Vec<Vec<(Req, Reply)>> =
            (0..self.num_shards).map(|_| Vec::new()).collect();
        let mut receivers = Vec::with_capacity(n);
        for (req, decision) in reqs.into_iter().zip(decisions) {
            let (reply_tx, reply_rx) = channel();
            if let Some(err) = decision {
                let _ = reply_tx.send(Err(err.into()));
            } else if let Some((prev, max)) = overloaded {
                let err = SolveError::Overloaded {
                    id: req.id(),
                    queue_depth: prev,
                    max_queue: max,
                };
                let _ = reply_tx.send(Err(err.into()));
            } else {
                let si = self.shard_of(req.mesh_id());
                let mut reply = Reply::new(reply_tx);
                // Tag the probe group's members: if the worker serving
                // them crashes, salvage must free the probe slot.
                reply.probe = probe_meshes.contains(&req.mesh_id());
                items[si].push((req, reply));
            }
            receivers.push(reply_rx);
        }
        for (si, batch) in items.into_iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            let k = batch.len();
            if let Err(Msg::Many(batch)) = self.shards[si].queue.push(Msg::Many(batch)) {
                // The worker is gone (shutdown): answer immediately
                // instead of leaving callers parked on `recv` forever.
                self.shards[si].depth.fetch_sub(k, Ordering::Relaxed);
                self.admission.depth.fetch_sub(k, Ordering::Relaxed);
                for (req, reply) in batch {
                    reply.send(Err(anyhow!(
                        "batch server worker is gone; request {} was not accepted",
                        req.id()
                    )));
                }
            }
        }
        receivers
    }

    /// Submit many and wait for all; any failed request fails the call.
    pub fn solve_all(&self, reqs: Vec<SolveRequest>) -> Result<Vec<SolveResponse>> {
        self.solve_all_each(reqs).into_iter().collect()
    }

    /// Submit many and wait for all, keeping per-request outcomes.
    pub fn solve_all_each(&self, reqs: Vec<SolveRequest>) -> Vec<Result<SolveResponse>> {
        Self::collect(self.submit_many(reqs))
    }

    /// Varcoeff counterpart of [`BatchServer::solve_all_each`].
    pub fn solve_all_varcoeff_each(
        &self,
        reqs: Vec<VarCoeffRequest>,
    ) -> Vec<Result<SolveResponse>> {
        Self::collect(self.submit_many_varcoeff(reqs))
    }

    fn collect(receivers: Vec<Receiver<Result<SolveResponse>>>) -> Vec<Result<SolveResponse>> {
        receivers
            .into_iter()
            .map(|rx| {
                rx.recv()
                    .unwrap_or_else(|_| Err(anyhow!("batch server dropped the reply channel")))
            })
            .collect()
    }

    /// Snapshot of the aggregate serving counters — a synchronous
    /// round-trip through every shard's queue, answered only after each
    /// shard has dispatched every request enqueued on it ahead of the
    /// query (FIFO per shard), so a `submit_many` + `stats` sequence
    /// observes the burst's dispatch. Per-shard partials are folded with
    /// [`fold_stats`] (sums; high-water maxed), then the router adds the
    /// globals it owns (submit-time expiry, rejection/steal counters,
    /// health counters, the effective bound). `None` when a worker is
    /// gone (shut down) — NOT the same as a fresh idle server's all-zero
    /// counters.
    pub fn stats(&self) -> Option<CoordinatorStats> {
        let mut rxs = Vec::with_capacity(self.num_shards);
        for h in self.shards.iter() {
            let (tx, rx) = channel();
            h.queue.push(Msg::Stats(tx)).ok()?;
            rxs.push(rx);
        }
        let mut parts = Vec::with_capacity(self.num_shards);
        for (si, rx) in rxs.into_iter().enumerate() {
            let mut p = rx.recv().ok()?;
            let h = &self.shards[si];
            p.rejected_requests = h.rejected.load(Ordering::Relaxed);
            p.queue_high_water = h.high_water.load(Ordering::Relaxed);
            p.stolen_groups = h.stolen.load(Ordering::Relaxed);
            p.steals_skipped = h.steals_skipped.load(Ordering::Relaxed);
            parts.push(p);
        }
        let mut s = fold_stats(&parts);
        // Submit-time expiries never reached a worker; fold them into
        // both the expired and failed totals so "an expiry is a failed
        // request" holds regardless of where it was detected.
        let expired_at_submit = self.admission.expired_at_submit.load(Ordering::Relaxed);
        s.failed_requests += expired_at_submit;
        s.expired_requests += expired_at_submit;
        s.effective_max_queue = self.admission.max_queue.load(Ordering::Relaxed) as u64;
        {
            let reg = self.health.lock();
            s.shed_requests = reg.shed();
            s.breaker_opens = reg.opens();
            s.breaker_half_opens = reg.half_opens();
            s.breaker_closes = reg.closes();
            s.queue_tightenings = reg.tightenings();
        }
        s.worker_respawns = self.sup.respawns.load(Ordering::Relaxed);
        s.requeued_requests = self.sup.requeued.load(Ordering::Relaxed);
        s.lost_requests = self.sup.lost.load(Ordering::Relaxed);
        s.shutdown_answered = self.sup.shutdown_answered.load(Ordering::Relaxed);
        s.wedged_detections = self.sup.wedged.load(Ordering::Relaxed);
        Some(s)
    }

    /// Live per-shard counters (depth, high-water, steals, sheds) read
    /// straight from the shard handles — no queue round-trip, so depths
    /// are an instantaneous sample.
    pub fn per_shard(&self) -> Vec<ShardStats> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, h)| ShardStats {
                shard: i,
                queue_depth: h.depth.load(Ordering::Relaxed) as u64,
                queue_high_water: h.high_water.load(Ordering::Relaxed),
                stolen_groups: h.stolen.load(Ordering::Relaxed),
                shed_requests: h.shed.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Stop all shard workers, flushing (batched) any pending requests.
    /// Idempotent; also run by `Drop`.
    pub fn shutdown(&mut self) {
        self.sup.shutting_down.store(true, Ordering::Relaxed);
        self.stop_supervisor();
        for h in self.shards.iter() {
            h.queue.close_and_shutdown();
        }
        self.join_workers();
        self.flush_leftovers(false);
    }

    /// Graceful shutdown with a drain deadline: stop accepting, let the
    /// workers drain for at most `ms` milliseconds, then answer every
    /// request still queued (or parked on a dead worker) with a typed
    /// [`SolveError::Shutdown`] instead of a dropped channel. A request
    /// already mid-dispatch still completes — the deadline bounds how
    /// long we WAIT for the queues, not an in-progress solve — so the
    /// final join can outlast the deadline by one dispatch.
    pub fn shutdown_within(&mut self, ms: u64) {
        self.sup.shutting_down.store(true, Ordering::Relaxed);
        self.stop_supervisor();
        for h in self.shards.iter() {
            h.queue.close_and_shutdown();
        }
        let deadline = Instant::now() + Duration::from_millis(ms);
        loop {
            let all_done = {
                let ws = self.lock_workers();
                ws.iter().all(|w| w.as_ref().is_none_or(|w| w.is_finished()))
            };
            if all_done || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        // Deadline passed with work still queued: pull the remaining
        // batches out from under the (still draining) workers and answer
        // them typed. The Shutdown sentinel stays queued, so each worker
        // still exits after its current dispatch.
        for h in self.shards.iter() {
            for batch in h.queue.extract_many() {
                h.depth.fetch_sub(batch.len(), Ordering::Relaxed);
                self.admission.depth.fetch_sub(batch.len(), Ordering::Relaxed);
                for (req, reply) in batch {
                    self.sup.shutdown_answered.fetch_add(1, Ordering::Relaxed);
                    reply.send(Err(SolveError::Shutdown { id: req.id() }.into()));
                }
            }
        }
        self.join_workers();
        self.flush_leftovers(true);
    }

    fn lock_workers(&self) -> MutexGuard<'_, Vec<Option<JoinHandle<()>>>> {
        self.workers.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Blocking-join every worker (slots already reaped are `None`).
    fn join_workers(&self) {
        let handles: Vec<JoinHandle<()>> = {
            let mut ws = self.lock_workers();
            ws.iter_mut().filter_map(|w| w.take()).collect()
        };
        for w in handles {
            let _ = w.join();
        }
    }

    /// Answer whatever is still sitting in the queues (a submission that
    /// raced the close) or parked on a dead worker's handle, so no caller
    /// stays parked on `recv` forever. `typed` selects the deadline
    /// shutdown's [`SolveError::Shutdown`] over the legacy message.
    fn flush_leftovers(&self, typed: bool) {
        for (si, h) in self.shards.iter().enumerate() {
            for msg in h.queue.drain() {
                if let Msg::Many(batch) = msg {
                    h.depth.fetch_sub(batch.len(), Ordering::Relaxed);
                    self.admission.depth.fetch_sub(batch.len(), Ordering::Relaxed);
                    for (req, reply) in batch {
                        if typed {
                            self.sup.shutdown_answered.fetch_add(1, Ordering::Relaxed);
                            reply.send(Err(SolveError::Shutdown { id: req.id() }.into()));
                        } else {
                            reply.send(Err(anyhow!(
                                "batch server worker is gone; request {} was not accepted",
                                req.id()
                            )));
                        }
                    }
                }
                // Register acks and Stats senders are simply dropped:
                // their receivers see a disconnect, not a hang.
            }
            // A worker that died holding a parked batch, with the
            // supervisor already stopped, leaves it on the handle: answer
            // the unanswered remainder (not retryable — the server is
            // gone). Dispatch already removed these from depth.
            let parked = std::mem::take(&mut *h.inflight());
            for (req, reply) in parked {
                if reply.answered.as_ref().is_some_and(|f| f.load(Ordering::Acquire)) {
                    continue;
                }
                self.sup.lost.fetch_add(1, Ordering::Relaxed);
                let err = SolveError::WorkerLost { id: req.id(), shard: si, retryable: false };
                reply.send(Err(err.into()));
            }
        }
    }
}

/// Everything the supervisor thread needs, cloned out of the server so
/// the thread borrows nothing and survives the `BatchServer` handle
/// moving across threads.
struct SupervisorCtx {
    shards: Arc<Vec<ShardHandle>>,
    workers: Arc<Mutex<Vec<Option<JoinHandle<()>>>>>,
    sup: Arc<SupervisionShared>,
    admission: Arc<Admission>,
    health: Arc<HealthShared>,
    max_batch: usize,
    steal: bool,
    poll: Duration,
    wedged_after: Option<Duration>,
    stop: Arc<AtomicBool>,
}

impl SupervisorCtx {
    fn run(&self) {
        // Per-shard wedge tracking: last observed heartbeat epoch, when
        // it last advanced, and whether this stall was already counted.
        let mut seen: Vec<(u64, Instant, bool)> = self
            .shards
            .iter()
            .map(|h| (h.heartbeat.load(Ordering::Relaxed), Instant::now(), false))
            .collect();
        while !self.stop.load(Ordering::Relaxed)
            && !self.sup.shutting_down.load(Ordering::Relaxed)
        {
            for idx in 0..self.shards.len() {
                let finished = {
                    let ws = self.workers.lock().unwrap_or_else(|e| e.into_inner());
                    ws[idx].as_ref().is_none_or(|w| w.is_finished())
                };
                if finished {
                    // Re-check shutdown: a worker exiting because the
                    // server is draining must not be "resurrected".
                    if self.sup.shutting_down.load(Ordering::Relaxed) {
                        return;
                    }
                    self.resurrect(idx);
                    seen[idx] = (
                        self.shards[idx].heartbeat.load(Ordering::Relaxed),
                        Instant::now(),
                        false,
                    );
                    continue;
                }
                let hb = self.shards[idx].heartbeat.load(Ordering::Relaxed);
                let (last_hb, since, counted) = &mut seen[idx];
                if hb != *last_hb {
                    (*last_hb, *since, *counted) = (hb, Instant::now(), false);
                } else if let Some(window) = self.wedged_after {
                    // Alive thread, stale heartbeat, work queued: wedged.
                    // Counted for observability but NOT killed — the
                    // thread may hold solver locks, and a std thread
                    // cannot be safely terminated from outside.
                    let depth = self.shards[idx].depth.load(Ordering::Relaxed);
                    if !*counted && depth > 0 && since.elapsed() >= window {
                        self.sup.wedged.fetch_add(1, Ordering::Relaxed);
                        *counted = true;
                    }
                }
            }
            std::thread::sleep(self.poll);
        }
    }

    /// Replace a dead shard worker, then answer or requeue whatever it
    /// parked. Respawn happens FIRST so requeued groups land on a live
    /// worker's queue; the new worker rebuilds any lost per-mesh solver
    /// state lazily from the retained topology store on the handle.
    fn resurrect(&self, idx: usize) {
        {
            let mut ws = self.workers.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(corpse) = ws[idx].take() {
                // Reap the dead thread (it already exited; join is
                // immediate and swallows its panic payload).
                let _ = corpse.join();
            }
            ws[idx] = Some(spawn_shard_worker(
                idx,
                &self.shards,
                self.max_batch,
                self.steal,
                &self.admission,
                &self.health,
                &self.sup,
            ));
        }
        self.sup.respawns.fetch_add(1, Ordering::Relaxed);
        self.salvage(idx);
    }

    /// Answer-or-requeue the in-flight batch a dead worker left parked
    /// on its handle: an unanswered request with retry budget left goes
    /// back to its home shard's queue (re-entering depth accounting);
    /// the rest get a typed [`SolveError::WorkerLost`]. A probe-tagged
    /// request ANSWERED here also frees its mesh's HalfOpen probe slot,
    /// so a breaker cannot wedge in HalfOpen because its probe died with
    /// the worker (a REQUEUED probe keeps the slot — it will still be
    /// served and observed).
    fn salvage(&self, idx: usize) {
        let parked = std::mem::take(&mut *self.shards[idx].inflight());
        if parked.is_empty() {
            return;
        }
        let n = self.shards.len();
        let max_requeues = self.sup.max_requeues.load(Ordering::Relaxed);
        let mut requeue: Vec<Vec<(Req, Reply)>> = (0..n).map(|_| Vec::new()).collect();
        let mut dead_probe_meshes: Vec<u64> = Vec::new();
        for (req, mut reply) in parked {
            if reply.answered.as_ref().is_some_and(|f| f.load(Ordering::Acquire)) {
                continue; // the worker answered this one before dying
            }
            if (reply.attempts as u64) < max_requeues {
                reply.attempts += 1;
                // The requeued copy is re-parked (with a fresh answered
                // flag) by whichever worker dequeues it.
                reply.answered = None;
                requeue[shard_of_n(req.mesh_id(), n)].push((req, reply));
            } else {
                self.sup.lost.fetch_add(1, Ordering::Relaxed);
                if reply.probe && !dead_probe_meshes.contains(&req.mesh_id()) {
                    dead_probe_meshes.push(req.mesh_id());
                }
                let err =
                    SolveError::WorkerLost { id: req.id(), shard: idx, retryable: true };
                reply.send(Err(err.into()));
            }
        }
        for (si, batch) in requeue.into_iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            let k = batch.len();
            // Depth re-enters BEFORE the push: the worker decrements at
            // dispatch, and must never observe the batch first.
            self.shards[si].depth.fetch_add(k, Ordering::Relaxed);
            self.admission.depth.fetch_add(k, Ordering::Relaxed);
            match self.shards[si].queue.push(Msg::Many(batch)) {
                Ok(()) => {
                    self.sup.requeued.fetch_add(k as u64, Ordering::Relaxed);
                }
                Err(Msg::Many(batch)) => {
                    // The requeue raced shutdown: answer typed instead of
                    // dropping the channels.
                    self.shards[si].depth.fetch_sub(k, Ordering::Relaxed);
                    self.admission.depth.fetch_sub(k, Ordering::Relaxed);
                    for (req, reply) in batch {
                        self.sup.shutdown_answered.fetch_add(1, Ordering::Relaxed);
                        if reply.probe && !dead_probe_meshes.contains(&req.mesh_id()) {
                            dead_probe_meshes.push(req.mesh_id());
                        }
                        reply.send(Err(SolveError::Shutdown { id: req.id() }.into()));
                    }
                }
                Err(_) => unreachable!("push returns the rejected message unchanged"),
            }
        }
        if !dead_probe_meshes.is_empty() && self.health.enabled.load(Ordering::Relaxed) {
            let mut reg = self.health.lock();
            for &m in &dead_probe_meshes {
                reg.cancel_probe(m);
            }
        }
    }
}

/// `mesh_id → shard` for a given shard count (the routing rule).
fn shard_of_n(mesh_id: u64, num_shards: usize) -> usize {
    if num_shards <= 1 {
        0
    } else {
        (splitmix64(mesh_id) % num_shards as u64) as usize
    }
}

impl Drop for BatchServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchSolver;
    use crate::mesh::structured::unit_cube_tet;
    use crate::util::rng::Rng;

    /// Single shard, no stealing: the configuration whose scheduling
    /// (drain cycles, LRU churn, chunk interleaving) the counter-pinning
    /// tests below depend on.
    fn single(
        meshes: Vec<(u64, crate::mesh::Mesh)>,
        max_batch: usize,
        max_states: usize,
    ) -> BatchServer {
        BatchServer::start_sharded(
            meshes,
            SolverConfig::default(),
            max_batch,
            max_states,
            ShardConfig::single(),
        )
    }

    #[test]
    fn fold_sums_monotone_counters_and_maxes_high_water() {
        let a = CoordinatorStats {
            batched_solves: 1,
            scalar_solves: 2,
            failed_requests: 3,
            meshes_built: 4,
            evicted_states: 5,
            state_rebuilds: 6,
            queued_requests: 7,
            drain_cycles: 8,
            dispatch_groups: 9,
            expired_requests: 10,
            rejected_requests: 11,
            retried_lanes: 12,
            rescued_lanes: 13,
            queue_high_water: 40,
            shed_requests: 14,
            breaker_opens: 15,
            breaker_half_opens: 16,
            breaker_closes: 17,
            skipped_rungs: 18,
            queue_tightenings: 19,
            stolen_groups: 20,
            steals_skipped: 21,
            effective_max_queue: 0,
            worker_respawns: 0,
            requeued_requests: 0,
            lost_requests: 0,
            shutdown_answered: 0,
            wedged_detections: 0,
        };
        let b = CoordinatorStats {
            batched_solves: 100,
            scalar_solves: 100,
            failed_requests: 100,
            meshes_built: 100,
            evicted_states: 100,
            state_rebuilds: 100,
            queued_requests: 100,
            drain_cycles: 100,
            dispatch_groups: 100,
            expired_requests: 100,
            rejected_requests: 100,
            retried_lanes: 100,
            rescued_lanes: 100,
            queue_high_water: 25,
            shed_requests: 100,
            breaker_opens: 100,
            breaker_half_opens: 100,
            breaker_closes: 100,
            skipped_rungs: 100,
            queue_tightenings: 100,
            stolen_groups: 100,
            steals_skipped: 100,
            effective_max_queue: 0,
            worker_respawns: 0,
            requeued_requests: 0,
            lost_requests: 0,
            shutdown_answered: 0,
            wedged_detections: 0,
        };
        let s = fold_stats(&[a, b]);
        assert_eq!(s.batched_solves, 101);
        assert_eq!(s.scalar_solves, 102);
        assert_eq!(s.failed_requests, 103);
        assert_eq!(s.meshes_built, 104);
        assert_eq!(s.evicted_states, 105);
        assert_eq!(s.state_rebuilds, 106);
        assert_eq!(s.queued_requests, 107);
        assert_eq!(s.drain_cycles, 108);
        assert_eq!(s.dispatch_groups, 109);
        assert_eq!(s.expired_requests, 110);
        assert_eq!(s.rejected_requests, 111);
        assert_eq!(s.retried_lanes, 112);
        assert_eq!(s.rescued_lanes, 113);
        assert_eq!(s.shed_requests, 114);
        assert_eq!(s.breaker_opens, 115);
        assert_eq!(s.breaker_half_opens, 116);
        assert_eq!(s.breaker_closes, 117);
        assert_eq!(s.skipped_rungs, 118);
        assert_eq!(s.queue_tightenings, 119);
        assert_eq!(s.stolen_groups, 120);
        assert_eq!(s.steals_skipped, 121);
        // The one non-sum: a depth high-water mark folds as max.
        assert_eq!(s.queue_high_water, 40, "high-water must be max, not sum");
        // Router-owned: untouched by the fold (the router fills these in
        // from its own atomics AFTER folding — summing would double).
        assert_eq!(s.effective_max_queue, 0);
        assert_eq!(s.worker_respawns, 0);
        assert_eq!(s.requeued_requests, 0);
        assert_eq!(s.lost_requests, 0);
        assert_eq!(s.shutdown_answered, 0);
        assert_eq!(s.wedged_detections, 0);
    }

    #[test]
    fn routing_is_stable_and_in_range() {
        let mesh = unit_cube_tet(2);
        let server = BatchServer::start_sharded(
            vec![(DEFAULT_MESH, mesh)],
            SolverConfig::default(),
            4,
            0,
            ShardConfig { num_shards: 4, steal: true },
        );
        assert_eq!(server.num_shards(), 4);
        assert_eq!(server.per_shard().len(), 4);
        let mut seen = [false; 4];
        for id in 0..64u64 {
            let s = server.shard_of(id);
            assert!(s < 4);
            assert_eq!(s, server.shard_of(id), "routing must be deterministic");
            seen[s] = true;
        }
        assert!(seen.iter().all(|&s| s), "64 sequential ids must hit every shard");
    }

    #[test]
    fn server_answers_all_requests() {
        let mesh = unit_cube_tet(3);
        let n = mesh.n_nodes();
        let server = BatchServer::start(mesh, SolverConfig::default(), 8);
        let mut rng = Rng::new(2);
        let reqs: Vec<_> = (0..10)
            .map(|id| {
                SolveRequest::new(id, (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect())
            })
            .collect();
        let out = server.solve_all(reqs).unwrap();
        assert_eq!(out.len(), 10);
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        assert!(out.iter().all(|r| r.rel_residual < 1e-8));
    }

    #[test]
    fn shutdown_flushes_pending() {
        let mesh = unit_cube_tet(2);
        let n = mesh.n_nodes();
        let server = BatchServer::start(mesh, SolverConfig::default(), 4);
        let rx = server.submit(SolveRequest::new(7, vec![1.0; n]));
        drop(server); // shutdown must still answer
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 7);
    }

    #[test]
    fn submit_after_shutdown_surfaces_error() {
        let mesh = unit_cube_tet(2);
        let n = mesh.n_nodes();
        let mut server = BatchServer::start(mesh, SolverConfig::default(), 4);
        server.shutdown();
        let rx = server.submit(SolveRequest::new(3, vec![1.0; n]));
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("worker is gone"), "{err}");
        // Burst submission surfaces the same condition per request.
        let outs = server.solve_all_each(vec![SolveRequest::new(4, vec![1.0; n])]);
        assert!(outs[0].is_err());
        // Registration over a dead worker errors instead of hanging.
        assert!(server.register_mesh(9, unit_cube_tet(2)).is_err());
        // Stats over a dead worker is None, not a hang.
        assert!(server.stats().is_none());
    }

    #[test]
    fn lru_cap_evicts_and_rebuilds_states() {
        // Two meshes, a one-state cap: alternating traffic must evict and
        // rebuild, with every request still answered correctly. Pinned to
        // one shard: the cap is per shard, so the two meshes must share a
        // registry slice for the churn signature to be deterministic.
        let (a, b) = (unit_cube_tet(2), unit_cube_tet(3));
        let (na, nb) = (a.n_nodes(), b.n_nodes());
        let server = single(vec![(1, a), (2, b)], 4, 1);
        let mut answers = Vec::new();
        for (round, (mesh_id, n)) in [(1u64, na), (2, nb), (1, na), (2, nb)].iter().enumerate() {
            let rx = server.submit(SolveRequest::on_mesh(round as u64, *mesh_id, vec![1.0; *n]));
            answers.push(rx.recv().unwrap().unwrap());
        }
        // Round-trip answers are mesh-consistent (u length = mesh DoFs).
        assert_eq!(answers[0].u.len(), na);
        assert_eq!(answers[1].u.len(), nb);
        // Re-serving an evicted mesh gives the same solution bitwise (the
        // rebuilt state is a pure function of mesh + config).
        assert_eq!(answers[0].u, answers[2].u);
        assert_eq!(answers[1].u, answers[3].u);
        let stats = server.stats().expect("worker alive");
        assert!(stats.evicted_states >= 2, "stats: {stats:?}");
        assert!(stats.state_rebuilds >= 2, "stats: {stats:?}");
        // One resident state at most, but dispatch counters stay monotone
        // (retired counts folded in).
        assert!(stats.meshes_built <= 1, "stats: {stats:?}");
        assert_eq!(stats.scalar_solves, 4, "stats: {stats:?}");
    }

    #[test]
    fn uncapped_registry_never_evicts() {
        let (a, b) = (unit_cube_tet(2), unit_cube_tet(2));
        let n = a.n_nodes();
        let server =
            BatchServer::start_multi(vec![(1, a), (2, b)], SolverConfig::default(), 4, 0);
        for (i, mesh_id) in [1u64, 2, 1, 2].iter().enumerate() {
            let rx = server.submit(SolveRequest::on_mesh(i as u64, *mesh_id, vec![1.0; n]));
            assert!(rx.recv().unwrap().is_ok());
        }
        let stats = server.stats().expect("worker alive");
        assert_eq!(stats.evicted_states, 0);
        assert_eq!(stats.state_rebuilds, 0);
        assert_eq!(stats.meshes_built, 2);
    }

    #[test]
    fn unknown_mesh_id_is_answered_not_hung() {
        let mesh = unit_cube_tet(2);
        let n = mesh.n_nodes();
        let server = BatchServer::start(mesh, SolverConfig::default(), 4);
        let rx = server.submit(SolveRequest::on_mesh(1, 42, vec![1.0; n]));
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("no mesh registered"), "{err}");
        // The worker is still alive and serving.
        let ok = server.submit(SolveRequest::new(2, vec![1.0; n]));
        assert!(ok.recv().unwrap().is_ok());
        assert_eq!(server.stats().expect("worker alive").failed_requests, 1);
    }

    /// Starvation regression: a 12-request group and a singleton for a
    /// second mesh land in one drain cycle with `max_batch = 4` and a
    /// one-state registry cap. Round-robin chunking serves the singleton
    /// after the large group's FIRST chunk, which is observable through
    /// the LRU churn: the interleaving m1(4), m2(1), m1(4), m1(4) forces
    /// an eviction of each state and a REBUILD of mesh 1's
    /// (`state_rebuilds ≥ 1`); the old serve-each-group-fully order
    /// (m1×3 chunks, then m2) never rebuilds anything. Pinned to one
    /// shard with stealing off: the signature requires both meshes in
    /// the same drain cycle of the same worker.
    #[test]
    fn large_group_cannot_starve_singleton() {
        let (a, b) = (unit_cube_tet(3), unit_cube_tet(2));
        let (na, nb) = (a.n_nodes(), b.n_nodes());
        let server = single(vec![(1, a), (2, b)], 4, 1);
        let mut rng = Rng::new(61);
        let mut reqs: Vec<SolveRequest> = (0..12)
            .map(|id| {
                SolveRequest::on_mesh(
                    id,
                    1,
                    (0..na).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
                )
            })
            .collect();
        reqs.push(SolveRequest::on_mesh(100, 2, vec![1.0; nb]));
        // One burst → one drain cycle; the server regroups by mesh.
        let out = server.solve_all(reqs.clone()).unwrap();
        assert_eq!(out.len(), 13);
        assert_eq!(out[12].u.len(), nb, "singleton answered on its own mesh");
        // Lane parity survives the mid-group rebuild: the rebuilt state is
        // a pure function of mesh + config.
        let oracle = BatchSolver::new(&unit_cube_tet(3), SolverConfig::default());
        for (resp, req) in out[..12].iter().zip(&reqs[..12]) {
            let want = oracle.solve_one(req).unwrap();
            assert_eq!(resp.u, want.u, "request {} not bitwise", req.id);
        }
        let stats = server.stats().expect("worker alive");
        // The fairness signature: the singleton ran between mesh-1 chunks.
        assert!(stats.state_rebuilds >= 1, "singleton starved: {stats:?}");
        assert!(stats.evicted_states >= 2, "stats: {stats:?}");
        // 12 requests in 4-sized chunks (batched) + 1 singleton (scalar).
        assert_eq!(stats.batched_solves, 3, "stats: {stats:?}");
        assert_eq!(stats.scalar_solves, 1, "stats: {stats:?}");
        // Drain telemetry: one non-empty cycle, 13 drained requests, two
        // (mesh, kind) groups.
        assert_eq!(stats.drain_cycles, 1, "stats: {stats:?}");
        assert_eq!(stats.queued_requests, 13, "stats: {stats:?}");
        assert_eq!(stats.dispatch_groups, 2, "stats: {stats:?}");
    }

    /// Dynamic registration: an unknown mesh id errors, then
    /// `register_mesh` installs the topology over the running server and
    /// the same request succeeds — matching a statically registered
    /// oracle bitwise.
    #[test]
    fn unknown_mesh_then_register_then_solve() {
        let a = unit_cube_tet(2);
        let b = unit_cube_tet(3);
        let nb = b.n_nodes();
        let server = BatchServer::start_multi(vec![(1, a)], SolverConfig::default(), 4, 0);
        let mut rng = Rng::new(67);
        let req = SolveRequest::on_mesh(
            5,
            7,
            (0..nb).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
        );
        let err = server.submit(req.clone()).recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("no mesh registered"), "{err}");
        server.register_mesh(7, b.clone()).unwrap();
        let resp = server.submit(req.clone()).recv().unwrap().unwrap();
        let oracle = BatchSolver::new(&b, SolverConfig::default());
        let want = oracle.solve_one(&req).unwrap();
        assert_eq!(resp.u, want.u, "registered-mesh solve not bitwise");
        let stats = server.stats().expect("worker alive");
        assert_eq!(stats.failed_requests, 1, "stats: {stats:?}");
        assert_eq!(stats.meshes_built, 2, "stats: {stats:?}");
    }
}
