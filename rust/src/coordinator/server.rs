//! The multi-mesh continuous-batching server.
//!
//! A request queue drained by a worker thread (vLLM-style continuous
//! batching): callers submit mesh-tagged [`SolveRequest`]s /
//! [`VarCoeffRequest`]s; the worker drains the queue, groups pending
//! requests by `(mesh_id, request kind)`, and dispatches every group as
//! batched assembly + lockstep-CG calls through the per-mesh
//! [`BatchSolver`] — `solve_one` runs only for singleton groups. Per-mesh
//! state (the [`crate::session::MeshSession`] solve stack plus the
//! separable batched-assembly plan) lives in a registry
//! `mesh_id → Arc<BatchSolver>` filled lazily on the first request for
//! each registered topology, so one server instance serves many meshes
//! with amortized setup; the `Arc` is the designed seam for sharded
//! multi-worker serving (N workers sharing one registry). The registry is
//! LRU-capped (`max_mesh_states` on [`BatchServer::start_multi`]): beyond
//! the cap the least-recently-used state is dropped and transparently
//! rebuilt on its next request, with eviction/rebuild counters in
//! [`CoordinatorStats`]. New topologies can be registered over the
//! running server ([`BatchServer::register_mesh`]) — the AMR-as-served-
//! workload entry point; re-registering an id retires any built state so
//! the next request solves against the new mesh.
//!
//! Drain fairness: within one drain cycle the worker serves groups
//! round-robin in `max_batch`-sized chunks — a large group takes one
//! chunk, then every other group takes one, and so on until all are
//! drained — so a burst of hundreds of requests for one mesh cannot
//! starve a singleton for another past the first chunk.
//!
//! Fault isolation: requests are validated before assembly, an
//! unconverged lane fails only its own reply, and a panic while serving a
//! chunk is caught and converted into per-request errors — the worker
//! never dies with clients parked on `recv`. [`BatchServer::submit`]
//! surfaces a gone worker as an error response instead of silently
//! dropping the request.
//!
//! Admission control: [`BatchServer::set_max_queue`] bounds the number of
//! requests allowed in flight (submitted but not yet drained). A burst
//! that would push the depth past the bound is rejected at submission
//! with a per-request [`super::api::SolveError::Overloaded`] — it never
//! reaches the worker, so an overloaded server sheds load in O(1) instead
//! of queueing unboundedly. A request whose deadline has *already* passed
//! at submission is answered `SolveError::Expired` synchronously, without
//! occupying a queue slot; one that expires while queued is answered
//! `Expired` at dispatch, before any assembly work. Both outcomes, plus
//! the queue-depth high-water mark and the escalation ladder's
//! retried/rescued lane counts, are surfaced through [`CoordinatorStats`].
//!
//! Health tracking and the circuit breaker
//! ([`BatchServer::set_health_config`], off by default — every serving
//! path is bitwise the tracker-free stack until enabled): the worker
//! feeds each served outcome into a per-mesh
//! [`crate::session::health::HealthRegistry`]. A chronically failing
//! mesh trips its breaker Open, and submission then sheds that mesh's
//! requests *synchronously* with [`super::api::SolveError::Unhealthy`]
//! (carrying a `retry_after_ms` hint) — they never occupy queue slots or
//! the drain budget of healthy meshes. After the open window the next
//! burst for that mesh is admitted as ONE probe group (HalfOpen); a
//! successful probe closes the breaker, a failed one re-opens it. When
//! rescued/exhausted lanes dominate recent traffic across all meshes,
//! the effective admission bound tightens to
//! `max_queue / tighten_divisor` and relaxes again on recovery. Breaker
//! transitions, shed counts, skipped ladder rungs and the effective
//! bound are surfaced through [`CoordinatorStats`]; per-mesh snapshots
//! through [`BatchServer::health`].

use std::collections::{HashMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, SendError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::mesh::Mesh;
use crate::session::health::{
    AdmitDecision, BreakerState, HealthConfig, HealthRegistry, HealthSnapshot, LaneOutcome,
};
use crate::solver::SolverConfig;

use super::api::{
    CoordinatorStats, SolveError, SolveRequest, SolveResponse, VarCoeffRequest, DEFAULT_MESH,
};
use super::batcher::BatchSolver;

type Reply = Sender<Result<SolveResponse>>;

/// A queued request of either kind.
enum Req {
    Fixed(SolveRequest),
    Var(VarCoeffRequest),
}

impl Req {
    fn id(&self) -> u64 {
        match self {
            Req::Fixed(r) => r.id,
            Req::Var(r) => r.id,
        }
    }

    fn mesh_id(&self) -> u64 {
        match self {
            Req::Fixed(r) => r.mesh_id,
            Req::Var(r) => r.mesh_id,
        }
    }

    fn deadline(&self) -> Option<Instant> {
        match self {
            Req::Fixed(r) => r.deadline,
            Req::Var(r) => r.deadline,
        }
    }
}

enum Msg {
    /// One or more requests submitted together ([`BatchServer::submit`] /
    /// [`BatchServer::submit_many`]): a burst arrives as one queue entry,
    /// so the whole burst is guaranteed to land in a single drain cycle.
    Many(Vec<(Req, Reply)>),
    /// Register (or replace) a mesh topology over the running server;
    /// acknowledged once the worker has installed it.
    Register(u64, Box<Mesh>, Sender<()>),
    Stats(Sender<CoordinatorStats>),
    Shutdown,
}

/// Admission bookkeeping shared between the submitting side
/// ([`BatchServer`]) and the worker: queue depth is incremented at
/// submission and decremented when the worker drains, so the bound holds
/// across concurrent submitters without a round-trip through the queue.
#[derive(Default)]
struct Admission {
    /// Requests submitted but not yet drained by the worker.
    depth: AtomicUsize,
    /// Depth bound currently in force (0 = unbounded, the default).
    /// Adaptive shedding may hold this at a tightened fraction of
    /// `base_max_queue` while sick traffic dominates.
    max_queue: AtomicUsize,
    /// The caller-configured bound ([`BatchServer::set_max_queue`]) that
    /// the tightened bound is derived from and relaxes back to.
    base_max_queue: AtomicUsize,
    /// Bursts rejected at admission, counted per request.
    rejected: AtomicU64,
    /// High-water mark of `depth` since server start.
    high_water: AtomicU64,
    /// Requests whose deadline had already passed at submission —
    /// answered [`SolveError::Expired`] synchronously, never enqueued.
    /// Folded into both `expired_requests` and `failed_requests`.
    expired_at_submit: AtomicU64,
}

/// Health state shared between the submitting side (synchronous breaker
/// sheds) and the worker (outcome observation, adaptive retuning). The
/// `enabled` flag is read lock-free on every submit so the disabled
/// default costs one relaxed atomic load and nothing else.
struct HealthShared {
    enabled: AtomicBool,
    registry: Mutex<HealthRegistry>,
}

impl HealthShared {
    fn new() -> HealthShared {
        HealthShared {
            enabled: AtomicBool::new(false),
            registry: Mutex::new(HealthRegistry::new(HealthConfig::disabled())),
        }
    }

    /// Lock the registry, surviving a poisoned mutex (a panic while a
    /// health call was in flight must not take the serving path down).
    fn lock(&self) -> std::sync::MutexGuard<'_, HealthRegistry> {
        self.registry.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Handle to the running server.
pub struct BatchServer {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    max_batch: usize,
    admission: Arc<Admission>,
    health: Arc<HealthShared>,
}

/// A registry slot: the built (or failed) per-mesh state plus its
/// last-touch tick for LRU eviction. Built states sit behind an `Arc` so
/// future sharded workers can hold a group's solver across a drain cycle
/// without blocking registry mutation.
struct RegistryEntry {
    /// A failed build (panicking setup of a *registered* mesh) is memoized
    /// too, so sustained traffic for a bad mesh pays the setup attempt
    /// once, not per drain cycle (until the slot is evicted). Unregistered
    /// keys never get a slot at all.
    state: std::result::Result<Arc<BatchSolver>, String>,
    last_used: u64,
}

/// One `(mesh_id, kind)` group's still-unserved requests within a drain
/// cycle, consumed chunk by chunk by the round-robin scheduler.
struct GroupQueue<R> {
    mesh_id: u64,
    items: Vec<(R, Reply)>,
    /// Whether the group *arrived* as a singleton (scalar dispatch); a
    /// trailing chunk of 1 carved from a larger group still dispatches
    /// batched, keeping the batched/scalar counters an exact regression
    /// signal.
    singleton: bool,
}

/// The worker-side state: registered meshes and the lazily built per-mesh
/// solver registry (LRU-capped at `max_states` when nonzero).
struct Worker {
    meshes: HashMap<u64, Mesh>,
    /// Lazily built per-mesh state.
    states: HashMap<u64, RegistryEntry>,
    config: SolverConfig,
    max_batch: usize,
    /// Registry cap (`max_mesh_states` on `start_multi`; 0 = unbounded).
    max_states: usize,
    /// Monotone access clock driving the LRU order.
    tick: u64,
    evictions: u64,
    rebuilds: u64,
    /// Keys that were evicted at least once — a rebuild of one of these
    /// counts as registry churn (`state_rebuilds`).
    evicted_keys: HashSet<u64>,
    /// Dispatch counters of evicted solvers, folded in so the aggregate
    /// stats stay monotone across evictions.
    retired_batched: u64,
    retired_scalar: u64,
    /// Escalation-ladder counters of evicted solvers (same fold).
    retired_retried: u64,
    retired_rescued: u64,
    /// Budget-skipped ladder rungs of evicted solvers (same fold).
    retired_skipped: u64,
    failed: u64,
    /// Requests answered with [`SolveError::Expired`] — deadline passed
    /// while queued, answered without solving.
    expired: u64,
    /// Shared admission bookkeeping (depth decremented at drain).
    admission: Arc<Admission>,
    /// Requests drained from the queue, summed over drain cycles (the
    /// queue-depth integral: `queued_requests / drain_cycles` is the mean
    /// drained batch size under load).
    queued_requests: u64,
    /// Non-empty drain cycles completed.
    drain_cycles: u64,
    /// `(mesh_id, kind)` groups formed across all drain cycles.
    dispatch_groups: u64,
    /// Stats queries seen in the current drain cycle — answered only
    /// AFTER the cycle's dispatch, so a snapshot reflects every request
    /// that was enqueued ahead of it (FIFO through the queue).
    stats_waiters: Vec<Sender<CoordinatorStats>>,
    /// Shared health state: the worker observes served outcomes into it
    /// and retunes the adaptive admission bound after each drain cycle.
    health: Arc<HealthShared>,
}

/// Bucket mesh-homogeneous items by mesh key, preserving arrival order
/// within each bucket (first-seen key order across buckets).
fn group_by_mesh<R>(items: Vec<(R, Reply)>, mesh_id: fn(&R) -> u64) -> Vec<GroupQueue<R>> {
    let mut groups: Vec<GroupQueue<R>> = Vec::new();
    let mut index: HashMap<u64, usize> = HashMap::new();
    for (req, reply) in items {
        let key = mesh_id(&req);
        let gi = *index.entry(key).or_insert_with(|| {
            groups.push(GroupQueue {
                mesh_id: key,
                items: Vec::new(),
                singleton: false,
            });
            groups.len() - 1
        });
        groups[gi].items.push((req, reply));
    }
    for g in &mut groups {
        g.singleton = g.items.len() == 1;
    }
    groups
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

impl Worker {
    /// Returns `false` on shutdown.
    fn accept(&mut self, msg: Msg, pending: &mut Vec<(Req, Reply)>) -> bool {
        match msg {
            Msg::Many(items) => pending.extend(items),
            Msg::Register(mesh_id, mesh, ack) => {
                self.register(mesh_id, *mesh);
                let _ = ack.send(());
            }
            Msg::Stats(tx) => self.stats_waiters.push(tx),
            Msg::Shutdown => return false,
        }
        true
    }

    /// Install (or replace) a mesh topology. Replacing a registered id
    /// retires any built state for the old topology — counted as an
    /// eviction, dispatch counters folded into the retired totals — so
    /// the next request builds against the new mesh (the AMR
    /// re-registration path).
    fn register(&mut self, mesh_id: u64, mesh: Mesh) {
        if let Some(entry) = self.states.remove(&mesh_id) {
            self.evictions += 1;
            self.evicted_keys.insert(mesh_id);
            if let Ok(solver) = entry.state {
                self.retire(&solver);
            }
        }
        self.meshes.insert(mesh_id, mesh);
    }

    /// Fold an evicted solver's counters into the retired totals so the
    /// aggregate stats stay monotone across evictions.
    fn retire(&mut self, solver: &BatchSolver) {
        self.retired_batched += solver.n_batched_solves();
        self.retired_scalar += solver.n_scalar_solves();
        self.retired_retried += solver.n_retried_lanes();
        self.retired_rescued += solver.n_rescued_lanes();
        self.retired_skipped += solver.n_skipped_rungs();
    }

    /// Answer the stats queries collected this cycle (post-dispatch).
    fn flush_stats(&mut self) {
        if self.stats_waiters.is_empty() {
            return;
        }
        let snapshot = self.stats();
        for tx in self.stats_waiters.drain(..) {
            let _ = tx.send(snapshot);
        }
    }

    fn stats(&self) -> CoordinatorStats {
        // Submit-time expiries never reached the worker; fold them into
        // both the expired and failed totals so "an expiry is a failed
        // request" holds regardless of where it was detected.
        let expired_at_submit =
            self.admission.expired_at_submit.load(Ordering::Relaxed);
        let mut s = CoordinatorStats {
            failed_requests: self.failed + expired_at_submit,
            evicted_states: self.evictions,
            state_rebuilds: self.rebuilds,
            batched_solves: self.retired_batched,
            scalar_solves: self.retired_scalar,
            queued_requests: self.queued_requests,
            drain_cycles: self.drain_cycles,
            dispatch_groups: self.dispatch_groups,
            expired_requests: self.expired + expired_at_submit,
            rejected_requests: self.admission.rejected.load(Ordering::Relaxed),
            retried_lanes: self.retired_retried,
            rescued_lanes: self.retired_rescued,
            queue_high_water: self.admission.high_water.load(Ordering::Relaxed),
            skipped_rungs: self.retired_skipped,
            effective_max_queue: self.admission.max_queue.load(Ordering::Relaxed) as u64,
            ..CoordinatorStats::default()
        };
        for entry in self.states.values() {
            if let Ok(solver) = &entry.state {
                s.meshes_built += 1;
                s.batched_solves += solver.n_batched_solves();
                s.scalar_solves += solver.n_scalar_solves();
                s.retried_lanes += solver.n_retried_lanes();
                s.rescued_lanes += solver.n_rescued_lanes();
                s.skipped_rungs += solver.n_skipped_rungs();
            }
        }
        {
            let reg = self.health.lock();
            s.shed_requests = reg.shed();
            s.breaker_opens = reg.opens();
            s.breaker_half_opens = reg.half_opens();
            s.breaker_closes = reg.closes();
            s.queue_tightenings = reg.tightenings();
        }
        s
    }

    /// Look up (or lazily build, memoizing success AND failure) the
    /// amortized state for a mesh key, touching its LRU clock. When the
    /// registry is at its cap, the least-recently-used slot is evicted
    /// before the new build (its dispatch counters fold into the retired
    /// totals so aggregate stats stay monotone).
    fn solver_for(&mut self, mesh_id: u64) -> std::result::Result<Arc<BatchSolver>, String> {
        self.tick += 1;
        let tick = self.tick;
        if !self.states.contains_key(&mesh_id) {
            // Unregistered keys never occupy a registry slot: a hostile
            // stream of bogus mesh_ids must not evict built states or grow
            // the eviction bookkeeping (the error string is cheap to
            // rebuild per request).
            let Some(mesh) = self.meshes.get(&mesh_id) else {
                return Err(format!("no mesh registered under mesh_id {mesh_id}"));
            };
            if self.max_states > 0 && self.states.len() >= self.max_states {
                // LRU victim: stalest tick, smallest key on (never-occurring
                // within one worker) ties — fully deterministic.
                if let Some((&victim, _)) =
                    self.states.iter().min_by_key(|&(k, e)| (e.last_used, *k))
                {
                    if let Some(entry) = self.states.remove(&victim) {
                        self.evictions += 1;
                        self.evicted_keys.insert(victim);
                        if let Ok(solver) = entry.state {
                            self.retire(&solver);
                        }
                    }
                }
            }
            if self.evicted_keys.contains(&mesh_id) {
                self.rebuilds += 1;
            }
            let config = self.config;
            let built =
                catch_unwind(AssertUnwindSafe(|| Arc::new(BatchSolver::new(mesh, config))))
                    .map_err(|p| {
                        format!(
                            "building state for mesh_id {mesh_id} panicked: {}",
                            panic_msg(&*p)
                        )
                    });
            self.states.insert(mesh_id, RegistryEntry { state: built, last_used: tick });
        }
        let entry = self.states.get_mut(&mesh_id).expect("slot just ensured");
        entry.last_used = tick;
        entry.state.as_ref().map(Arc::clone).map_err(|e| e.clone())
    }

    /// Group the drained queue by `(mesh_id, kind)` — arrival order is
    /// preserved within each group — and serve the groups round-robin in
    /// `max_batch`-sized chunks until all are drained: every group gets
    /// one chunk per round, so a large group cannot starve the others
    /// past its first chunk.
    fn dispatch(&mut self, pending: Vec<(Req, Reply)>) {
        #[cfg(feature = "fault-inject")]
        if let Some(ms) = crate::util::faults::stall_ms(crate::util::faults::SERVER_STALL) {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
        self.admission.depth.fetch_sub(pending.len(), Ordering::Relaxed);
        if pending.is_empty() {
            return;
        }
        self.drain_cycles += 1;
        self.queued_requests += pending.len() as u64;
        let mut fixed_items = Vec::new();
        let mut var_items = Vec::new();
        for (req, reply) in pending {
            match req {
                Req::Fixed(q) => fixed_items.push((q, reply)),
                Req::Var(q) => var_items.push((q, reply)),
            }
        }
        let mut fixed = group_by_mesh(fixed_items, |r| r.mesh_id);
        let mut var = group_by_mesh(var_items, |r| r.mesh_id);
        self.dispatch_groups += (fixed.len() + var.len()) as u64;
        loop {
            let served_fixed = self.serve_round(
                &mut fixed,
                |r: &SolveRequest| r.id,
                BatchSolver::solve_one,
                BatchSolver::solve_batch_each,
            );
            let served_var = self.serve_round(
                &mut var,
                |r: &VarCoeffRequest| r.id,
                BatchSolver::solve_varcoeff_one,
                BatchSolver::solve_varcoeff_batch_each,
            );
            if !served_fixed && !served_var {
                break;
            }
        }
        self.retune_admission();
    }

    /// After a drain cycle, retune the effective admission bound from the
    /// global sick-traffic signal: while rescued/exhausted lanes dominate
    /// recent outcomes the bound tightens to `base / tighten_divisor`
    /// (floor 1), relaxing back to the configured base on recovery. A
    /// no-op while health tracking is disabled or the base bound is 0
    /// (unbounded).
    fn retune_admission(&mut self) {
        if !self.health.enabled.load(Ordering::Relaxed) {
            return;
        }
        let base = self.admission.base_max_queue.load(Ordering::Relaxed);
        let mut reg = self.health.lock();
        let tight = reg.update_tightened();
        let cfg = reg.config();
        let effective = if tight && base > 0 {
            (base / cfg.tighten_divisor.max(1)).max(1)
        } else {
            base
        };
        self.admission.max_queue.store(effective, Ordering::Relaxed);
    }

    /// Feed one served outcome into the health registry: a clean solve is
    /// `Ok`, a ladder-recovered one `Rescued`, a classified solver failure
    /// (or an unclassifiable panic / state-build failure) `Exhausted`.
    /// Validation and expiry answers say nothing about mesh health and
    /// are not observed. A no-op while health tracking is disabled.
    fn observe_health(&mut self, mesh_id: u64, res: &Result<SolveResponse>) {
        if !self.health.enabled.load(Ordering::Relaxed) {
            return;
        }
        let (outcome, report) = match res {
            Ok(resp) => match &resp.escalation {
                Some(rep) => (LaneOutcome::Rescued, Some(rep)),
                None => (LaneOutcome::Ok, None),
            },
            Err(e) => match e.downcast_ref::<SolveError>() {
                Some(SolveError::Solver { escalation, .. }) => {
                    (LaneOutcome::Exhausted, escalation.as_ref())
                }
                Some(
                    SolveError::Invalid { .. }
                    | SolveError::Expired { .. }
                    | SolveError::Overloaded { .. }
                    | SolveError::Unhealthy { .. },
                ) => return,
                // No typed error: a recovered panic or a failed state
                // build — the mesh is not serving, count it against its
                // health.
                None => (LaneOutcome::Exhausted, None),
            },
        };
        self.health.lock().observe(mesh_id, outcome, report);
    }

    /// One fairness round: take at most one `max_batch`-sized chunk from
    /// every non-empty group, in first-seen group order. Returns whether
    /// any work was served.
    fn serve_round<R>(
        &mut self,
        groups: &mut [GroupQueue<R>],
        req_id: fn(&R) -> u64,
        solve_single: fn(&BatchSolver, &R) -> Result<SolveResponse>,
        solve_batch: fn(&BatchSolver, &[R]) -> Vec<Result<SolveResponse>>,
    ) -> bool {
        let max_batch = self.max_batch.max(1);
        let mut any = false;
        for g in groups.iter_mut() {
            if g.items.is_empty() {
                continue;
            }
            any = true;
            let take = g.items.len().min(max_batch);
            let chunk: Vec<(R, Reply)> = g.items.drain(..take).collect();
            self.serve_chunk(g.mesh_id, chunk, g.singleton, req_id, solve_single, solve_batch);
        }
        any
    }

    /// Serve one chunk of a homogeneous `(mesh_id, kind)` group: the
    /// scalar path runs only for a true singleton group; everything else
    /// goes through the batched dispatch. A panic while solving answers
    /// the chunk's requests with errors and keeps the worker alive.
    fn serve_chunk<R>(
        &mut self,
        mesh_id: u64,
        chunk: Vec<(R, Reply)>,
        singleton: bool,
        req_id: fn(&R) -> u64,
        solve_single: fn(&BatchSolver, &R) -> Result<SolveResponse>,
        solve_batch: fn(&BatchSolver, &[R]) -> Vec<Result<SolveResponse>>,
    ) {
        let mut failed = 0u64;
        match self.solver_for(mesh_id) {
            Err(msg) => {
                failed = chunk.len() as u64;
                // A failed state build for a *registered* mesh counts
                // against its health (it cannot serve); unregistered keys
                // are caller errors, not mesh sickness, and must not grow
                // the health registry.
                let registered = self.meshes.contains_key(&mesh_id);
                for (req, reply) in chunk {
                    let res = Err(anyhow!("request {}: {msg}", req_id(&req)));
                    if registered {
                        self.observe_health(mesh_id, &res);
                    }
                    let _ = reply.send(res);
                }
            }
            Ok(solver) => {
                let solver = &*solver;
                let (reqs, replies): (Vec<R>, Vec<Reply>) = chunk.into_iter().unzip();
                let results = catch_unwind(AssertUnwindSafe(|| {
                    if singleton {
                        vec![solve_single(solver, &reqs[0])]
                    } else {
                        solve_batch(solver, &reqs)
                    }
                }))
                .unwrap_or_else(|p| {
                    let m = panic_msg(&*p);
                    reqs.iter()
                        .map(|r| {
                            Err(anyhow!("solve panicked serving request {}: {m}", req_id(r)))
                        })
                        .collect()
                });
                for (res, reply) in results.into_iter().zip(replies) {
                    if let Err(e) = &res {
                        failed += 1;
                        if matches!(
                            e.downcast_ref::<SolveError>(),
                            Some(SolveError::Expired { .. })
                        ) {
                            self.expired += 1;
                        }
                    }
                    self.observe_health(mesh_id, &res);
                    let _ = reply.send(res);
                }
            }
        }
        self.failed += failed;
    }
}

impl BatchServer {
    /// Spawn a single-mesh server (the mesh is registered under
    /// [`DEFAULT_MESH`]); `max_batch` bounds the batched dispatch size.
    pub fn start(mesh: Mesh, config: SolverConfig, max_batch: usize) -> BatchServer {
        BatchServer::start_multi(vec![(DEFAULT_MESH, mesh)], config, max_batch, 0)
    }

    /// Spawn a server over many registered mesh topologies. Per-mesh
    /// solver state is built lazily on the first request tagged with each
    /// `mesh_id`; `max_mesh_states` caps how many built states stay
    /// resident at once (LRU eviction; 0 = unbounded, the pre-cap
    /// behavior). Eviction/rebuild churn is surfaced through
    /// [`CoordinatorStats`], so an undersized cap under steady multi-mesh
    /// traffic is visible as `state_rebuilds` growth.
    pub fn start_multi(
        meshes: Vec<(u64, Mesh)>,
        config: SolverConfig,
        max_batch: usize,
        max_mesh_states: usize,
    ) -> BatchServer {
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
        let admission = Arc::new(Admission::default());
        let worker_admission = Arc::clone(&admission);
        let health = Arc::new(HealthShared::new());
        let worker_health = Arc::clone(&health);
        let worker = std::thread::spawn(move || {
            let mut w = Worker {
                meshes: meshes.into_iter().collect(),
                states: HashMap::new(),
                config,
                max_batch,
                max_states: max_mesh_states,
                tick: 0,
                evictions: 0,
                rebuilds: 0,
                evicted_keys: HashSet::new(),
                retired_batched: 0,
                retired_scalar: 0,
                retired_retried: 0,
                retired_rescued: 0,
                retired_skipped: 0,
                failed: 0,
                expired: 0,
                admission: worker_admission,
                queued_requests: 0,
                drain_cycles: 0,
                dispatch_groups: 0,
                stats_waiters: Vec::new(),
                health: worker_health,
            };
            let mut pending: Vec<(Req, Reply)> = Vec::new();
            loop {
                // Block for the first message, then drain without blocking.
                let msg = match rx.recv() {
                    Ok(m) => m,
                    Err(_) => return,
                };
                if !w.accept(msg, &mut pending) {
                    w.dispatch(std::mem::take(&mut pending));
                    w.flush_stats();
                    return;
                }
                while pending.len() < w.max_batch.max(1) {
                    match rx.try_recv() {
                        Ok(m) => {
                            if !w.accept(m, &mut pending) {
                                w.dispatch(std::mem::take(&mut pending));
                                w.flush_stats();
                                return;
                            }
                        }
                        Err(_) => break,
                    }
                }
                w.dispatch(std::mem::take(&mut pending));
                w.flush_stats();
            }
        });
        BatchServer {
            tx,
            worker: Some(worker),
            max_batch,
            admission,
            health,
        }
    }

    /// Max requests per batched dispatch (larger groups are served in
    /// `max_batch`-sized chunks, bounding lockstep memory). Fixed at
    /// start — the worker snapshots it.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Bound the admission queue: a burst that would push the in-flight
    /// depth (submitted but not yet drained) past `n` is rejected at
    /// submission with [`SolveError::Overloaded`] per request — it never
    /// reaches the worker. `0` removes the bound (the default). Setting
    /// the bound also resets any adaptive tightening: `n` becomes both
    /// the base and the effective bound until the next worker retune.
    pub fn set_max_queue(&self, n: usize) {
        self.admission.base_max_queue.store(n, Ordering::Relaxed);
        self.admission.max_queue.store(n, Ordering::Relaxed);
    }

    /// Enable (or reconfigure) health tracking and the per-mesh circuit
    /// breaker; `HealthConfig::disabled()` switches it back off. Resets
    /// all tracked health state. While disabled (the default) every
    /// serving path is bitwise identical to the tracker-free stack.
    pub fn set_health_config(&self, cfg: HealthConfig) {
        let enabled = cfg.enabled;
        self.health.lock().reconfigure(cfg);
        self.health.enabled.store(enabled, Ordering::Relaxed);
    }

    /// Per-mesh health snapshot: `None` while tracking is disabled or
    /// before the first observed/shed request for `mesh_id`.
    pub fn health(&self, mesh_id: u64) -> Option<HealthSnapshot> {
        if !self.health.enabled.load(Ordering::Relaxed) {
            return None;
        }
        self.health.lock().snapshot(mesh_id)
    }

    /// Advance the injected manual clock (tests; requires
    /// `HealthConfig::manual_clock`). A no-op on the wall clock.
    pub fn advance_health_clock(&self, ms: u64) {
        self.health.lock().advance_clock(ms);
    }

    /// Register (or replace) a mesh topology on the running server.
    /// Synchronous: returns once the worker has installed the mesh, so a
    /// subsequent request tagged with `mesh_id` is guaranteed to find it.
    /// Replacing an id retires any built solver state for the old
    /// topology (counted as an eviction).
    pub fn register_mesh(&self, mesh_id: u64, mesh: Mesh) -> Result<()> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Register(mesh_id, Box::new(mesh), tx))
            .map_err(|_| anyhow!("batch server worker is gone; mesh {mesh_id} not registered"))?;
        rx.recv()
            .map_err(|_| anyhow!("batch server worker died before registering mesh {mesh_id}"))
    }

    /// Submit a fixed-operator request; returns the response receiver.
    pub fn submit(&self, req: SolveRequest) -> Receiver<Result<SolveResponse>> {
        self.submit_burst(vec![Req::Fixed(req)]).remove(0)
    }

    /// Submit a varcoeff (own-operator) request.
    pub fn submit_varcoeff(&self, req: VarCoeffRequest) -> Receiver<Result<SolveResponse>> {
        self.submit_burst(vec![Req::Var(req)]).remove(0)
    }

    /// Submit a burst as ONE queue entry: the whole burst lands in a
    /// single drain cycle, so same-mesh bursts are guaranteed to be served
    /// by batched dispatches (in `max_batch`-sized chunks).
    pub fn submit_many(&self, reqs: Vec<SolveRequest>) -> Vec<Receiver<Result<SolveResponse>>> {
        self.submit_burst(reqs.into_iter().map(Req::Fixed).collect())
    }

    /// Varcoeff counterpart of [`BatchServer::submit_many`].
    pub fn submit_many_varcoeff(
        &self,
        reqs: Vec<VarCoeffRequest>,
    ) -> Vec<Receiver<Result<SolveResponse>>> {
        self.submit_burst(reqs.into_iter().map(Req::Var).collect())
    }

    fn submit_burst(&self, reqs: Vec<Req>) -> Vec<Receiver<Result<SolveResponse>>> {
        let adm = &self.admission;
        let n = reqs.len();
        // Synchronously decidable requests never take a queue slot. First:
        // a deadline already passed at submission is an immediate Expired
        // (the clock is read at most once, and only when a deadline is
        // present at all).
        let mut decisions: Vec<Option<SolveError>> = Vec::with_capacity(n);
        let mut now: Option<Instant> = None;
        for req in &reqs {
            let expired = req
                .deadline()
                .is_some_and(|d| *now.get_or_insert_with(Instant::now) >= d);
            if expired {
                adm.expired_at_submit.fetch_add(1, Ordering::Relaxed);
                decisions.push(Some(SolveError::Expired { id: req.id() }));
            } else {
                decisions.push(None);
            }
        }
        // Second: circuit-breaker sheds. ONE admit decision per mesh per
        // burst, so a HalfOpen mesh admits this burst's whole group as
        // its single probe (one probe *group*, not one probe request).
        let mut probe_meshes: Vec<u64> = Vec::new();
        if self.health.enabled.load(Ordering::Relaxed) {
            let mut reg = self.health.lock();
            let mut memo: HashMap<u64, AdmitDecision> = HashMap::new();
            let mut shed = 0u64;
            for (req, slot) in reqs.iter().zip(decisions.iter_mut()) {
                if slot.is_some() {
                    continue;
                }
                let mesh_id = req.mesh_id();
                let decision = *memo.entry(mesh_id).or_insert_with(|| {
                    let d = reg.admit(mesh_id);
                    let probing = d == AdmitDecision::Admit
                        && reg
                            .snapshot(mesh_id)
                            .is_some_and(|s| s.state == BreakerState::HalfOpen);
                    if probing {
                        probe_meshes.push(mesh_id);
                    }
                    d
                });
                if let AdmitDecision::Shed { retry_after_ms } = decision {
                    shed += 1;
                    *slot = Some(SolveError::Unhealthy {
                        id: req.id(),
                        mesh_id,
                        retry_after_ms,
                    });
                }
            }
            if shed > 0 {
                reg.note_shed(shed);
            }
        }
        // Bounded admission for the undecided remainder.
        let k = decisions.iter().filter(|d| d.is_none()).count();
        let mut overloaded: Option<(usize, usize)> = None;
        if k > 0 {
            let prev = adm.depth.fetch_add(k, Ordering::Relaxed);
            let max = adm.max_queue.load(Ordering::Relaxed);
            if max > 0 && prev + k > max {
                // Shed the remainder without enqueueing (the worker never
                // sees it), answering each request with a typed rejection
                // the caller can back off on.
                adm.depth.fetch_sub(k, Ordering::Relaxed);
                adm.rejected.fetch_add(k as u64, Ordering::Relaxed);
                // This burst carried these meshes' HalfOpen probes but
                // got rejected at admission: free the probe slot so the
                // next burst can probe instead of waiting out the
                // timeout.
                if !probe_meshes.is_empty() {
                    let mut reg = self.health.lock();
                    for &m in &probe_meshes {
                        reg.cancel_probe(m);
                    }
                }
                overloaded = Some((prev, max));
            } else {
                adm.high_water.fetch_max((prev + k) as u64, Ordering::Relaxed);
            }
        }
        let mut items = Vec::with_capacity(k);
        let mut receivers = Vec::with_capacity(n);
        for (req, decision) in reqs.into_iter().zip(decisions) {
            let (reply_tx, reply_rx) = channel();
            if let Some(err) = decision {
                let _ = reply_tx.send(Err(err.into()));
            } else if let Some((prev, max)) = overloaded {
                let err = SolveError::Overloaded {
                    id: req.id(),
                    queue_depth: prev,
                    max_queue: max,
                };
                let _ = reply_tx.send(Err(err.into()));
            } else {
                items.push((req, reply_tx));
            }
            receivers.push(reply_rx);
        }
        if !items.is_empty() {
            if let Err(SendError(msg)) = self.tx.send(Msg::Many(items)) {
                // The worker is gone (shutdown or died): answer immediately
                // instead of leaving callers parked on `recv` forever.
                adm.depth.fetch_sub(k, Ordering::Relaxed);
                if let Msg::Many(items) = msg {
                    for (req, reply) in items {
                        let _ = reply.send(Err(anyhow!(
                            "batch server worker is gone; request {} was not accepted",
                            req.id()
                        )));
                    }
                }
            }
        }
        receivers
    }

    /// Submit many and wait for all; any failed request fails the call.
    pub fn solve_all(&self, reqs: Vec<SolveRequest>) -> Result<Vec<SolveResponse>> {
        self.solve_all_each(reqs).into_iter().collect()
    }

    /// Submit many and wait for all, keeping per-request outcomes.
    pub fn solve_all_each(&self, reqs: Vec<SolveRequest>) -> Vec<Result<SolveResponse>> {
        Self::collect(self.submit_many(reqs))
    }

    /// Varcoeff counterpart of [`BatchServer::solve_all_each`].
    pub fn solve_all_varcoeff_each(
        &self,
        reqs: Vec<VarCoeffRequest>,
    ) -> Vec<Result<SolveResponse>> {
        Self::collect(self.submit_many_varcoeff(reqs))
    }

    fn collect(receivers: Vec<Receiver<Result<SolveResponse>>>) -> Vec<Result<SolveResponse>> {
        receivers
            .into_iter()
            .map(|rx| {
                rx.recv()
                    .unwrap_or_else(|_| Err(anyhow!("batch server dropped the reply channel")))
            })
            .collect()
    }

    /// Snapshot of the worker's aggregate serving counters — a synchronous
    /// round-trip through the queue, answered only after the worker has
    /// dispatched every request enqueued ahead of the query (FIFO), so a
    /// `submit_many` + `stats` sequence observes the burst's dispatch.
    /// `None` when the worker is gone (shut down or died) — NOT the same
    /// as a fresh idle server's all-zero counters.
    pub fn stats(&self) -> Option<CoordinatorStats> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Stats(tx)).ok()?;
        rx.recv().ok()
    }

    /// Stop the worker, flushing (batched) any pending requests.
    /// Idempotent; also run by `Drop`.
    pub fn shutdown(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for BatchServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::structured::unit_cube_tet;
    use crate::util::rng::Rng;

    #[test]
    fn server_answers_all_requests() {
        let mesh = unit_cube_tet(3);
        let n = mesh.n_nodes();
        let server = BatchServer::start(mesh, SolverConfig::default(), 8);
        let mut rng = Rng::new(2);
        let reqs: Vec<_> = (0..10)
            .map(|id| {
                SolveRequest::new(id, (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect())
            })
            .collect();
        let out = server.solve_all(reqs).unwrap();
        assert_eq!(out.len(), 10);
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        assert!(out.iter().all(|r| r.rel_residual < 1e-8));
    }

    #[test]
    fn shutdown_flushes_pending() {
        let mesh = unit_cube_tet(2);
        let n = mesh.n_nodes();
        let server = BatchServer::start(mesh, SolverConfig::default(), 4);
        let rx = server.submit(SolveRequest::new(7, vec![1.0; n]));
        drop(server); // shutdown must still answer
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 7);
    }

    #[test]
    fn submit_after_shutdown_surfaces_error() {
        let mesh = unit_cube_tet(2);
        let n = mesh.n_nodes();
        let mut server = BatchServer::start(mesh, SolverConfig::default(), 4);
        server.shutdown();
        let rx = server.submit(SolveRequest::new(3, vec![1.0; n]));
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("worker is gone"), "{err}");
        // Burst submission surfaces the same condition per request.
        let outs = server.solve_all_each(vec![SolveRequest::new(4, vec![1.0; n])]);
        assert!(outs[0].is_err());
        // Registration over a dead worker errors instead of hanging.
        assert!(server.register_mesh(9, unit_cube_tet(2)).is_err());
    }

    #[test]
    fn lru_cap_evicts_and_rebuilds_states() {
        // Two meshes, a one-state cap: alternating traffic must evict and
        // rebuild, with every request still answered correctly.
        let (a, b) = (unit_cube_tet(2), unit_cube_tet(3));
        let (na, nb) = (a.n_nodes(), b.n_nodes());
        let server =
            BatchServer::start_multi(vec![(1, a), (2, b)], SolverConfig::default(), 4, 1);
        let mut answers = Vec::new();
        for (round, (mesh_id, n)) in [(1u64, na), (2, nb), (1, na), (2, nb)].iter().enumerate() {
            let rx = server.submit(SolveRequest::on_mesh(round as u64, *mesh_id, vec![1.0; *n]));
            answers.push(rx.recv().unwrap().unwrap());
        }
        // Round-trip answers are mesh-consistent (u length = mesh DoFs).
        assert_eq!(answers[0].u.len(), na);
        assert_eq!(answers[1].u.len(), nb);
        // Re-serving an evicted mesh gives the same solution bitwise (the
        // rebuilt state is a pure function of mesh + config).
        assert_eq!(answers[0].u, answers[2].u);
        assert_eq!(answers[1].u, answers[3].u);
        let stats = server.stats().expect("worker alive");
        assert!(stats.evicted_states >= 2, "stats: {stats:?}");
        assert!(stats.state_rebuilds >= 2, "stats: {stats:?}");
        // One resident state at most, but dispatch counters stay monotone
        // (retired counts folded in).
        assert!(stats.meshes_built <= 1, "stats: {stats:?}");
        assert_eq!(stats.scalar_solves, 4, "stats: {stats:?}");
    }

    #[test]
    fn uncapped_registry_never_evicts() {
        let (a, b) = (unit_cube_tet(2), unit_cube_tet(2));
        let n = a.n_nodes();
        let server =
            BatchServer::start_multi(vec![(1, a), (2, b)], SolverConfig::default(), 4, 0);
        for (i, mesh_id) in [1u64, 2, 1, 2].iter().enumerate() {
            let rx = server.submit(SolveRequest::on_mesh(i as u64, *mesh_id, vec![1.0; n]));
            assert!(rx.recv().unwrap().is_ok());
        }
        let stats = server.stats().expect("worker alive");
        assert_eq!(stats.evicted_states, 0);
        assert_eq!(stats.state_rebuilds, 0);
        assert_eq!(stats.meshes_built, 2);
    }

    #[test]
    fn unknown_mesh_id_is_answered_not_hung() {
        let mesh = unit_cube_tet(2);
        let n = mesh.n_nodes();
        let server = BatchServer::start(mesh, SolverConfig::default(), 4);
        let rx = server.submit(SolveRequest::on_mesh(1, 42, vec![1.0; n]));
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("no mesh registered"), "{err}");
        // The worker is still alive and serving.
        let ok = server.submit(SolveRequest::new(2, vec![1.0; n]));
        assert!(ok.recv().unwrap().is_ok());
        assert_eq!(server.stats().expect("worker alive").failed_requests, 1);
    }

    /// Starvation regression: a 12-request group and a singleton for a
    /// second mesh land in one drain cycle with `max_batch = 4` and a
    /// one-state registry cap. Round-robin chunking serves the singleton
    /// after the large group's FIRST chunk, which is observable through
    /// the LRU churn: the interleaving m1(4), m2(1), m1(4), m1(4) forces
    /// an eviction of each state and a REBUILD of mesh 1's
    /// (`state_rebuilds ≥ 1`); the old serve-each-group-fully order
    /// (m1×3 chunks, then m2) never rebuilds anything.
    #[test]
    fn large_group_cannot_starve_singleton() {
        let (a, b) = (unit_cube_tet(3), unit_cube_tet(2));
        let (na, nb) = (a.n_nodes(), b.n_nodes());
        let server =
            BatchServer::start_multi(vec![(1, a), (2, b)], SolverConfig::default(), 4, 1);
        let mut rng = Rng::new(61);
        let mut reqs: Vec<SolveRequest> = (0..12)
            .map(|id| {
                SolveRequest::on_mesh(
                    id,
                    1,
                    (0..na).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
                )
            })
            .collect();
        reqs.push(SolveRequest::on_mesh(100, 2, vec![1.0; nb]));
        // One burst → one drain cycle; the server regroups by mesh.
        let out = server.solve_all(reqs.clone()).unwrap();
        assert_eq!(out.len(), 13);
        assert_eq!(out[12].u.len(), nb, "singleton answered on its own mesh");
        // Lane parity survives the mid-group rebuild: the rebuilt state is
        // a pure function of mesh + config.
        let oracle = BatchSolver::new(&unit_cube_tet(3), SolverConfig::default());
        for (resp, req) in out[..12].iter().zip(&reqs[..12]) {
            let want = oracle.solve_one(req).unwrap();
            assert_eq!(resp.u, want.u, "request {} not bitwise", req.id);
        }
        let stats = server.stats().expect("worker alive");
        // The fairness signature: the singleton ran between mesh-1 chunks.
        assert!(stats.state_rebuilds >= 1, "singleton starved: {stats:?}");
        assert!(stats.evicted_states >= 2, "stats: {stats:?}");
        // 12 requests in 4-sized chunks (batched) + 1 singleton (scalar).
        assert_eq!(stats.batched_solves, 3, "stats: {stats:?}");
        assert_eq!(stats.scalar_solves, 1, "stats: {stats:?}");
        // Drain telemetry: one non-empty cycle, 13 drained requests, two
        // (mesh, kind) groups.
        assert_eq!(stats.drain_cycles, 1, "stats: {stats:?}");
        assert_eq!(stats.queued_requests, 13, "stats: {stats:?}");
        assert_eq!(stats.dispatch_groups, 2, "stats: {stats:?}");
    }

    /// Dynamic registration: an unknown mesh id errors, then
    /// `register_mesh` installs the topology over the running server and
    /// the same request succeeds — matching a statically registered
    /// oracle bitwise.
    #[test]
    fn unknown_mesh_then_register_then_solve() {
        let a = unit_cube_tet(2);
        let b = unit_cube_tet(3);
        let nb = b.n_nodes();
        let server = BatchServer::start_multi(vec![(1, a)], SolverConfig::default(), 4, 0);
        let mut rng = Rng::new(67);
        let req = SolveRequest::on_mesh(
            5,
            7,
            (0..nb).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
        );
        let err = server.submit(req.clone()).recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("no mesh registered"), "{err}");
        server.register_mesh(7, b.clone()).unwrap();
        let resp = server.submit(req.clone()).recv().unwrap().unwrap();
        let oracle = BatchSolver::new(&b, SolverConfig::default());
        let want = oracle.solve_one(&req).unwrap();
        assert_eq!(resp.u, want.u, "registered-mesh solve not bitwise");
        let stats = server.stats().expect("worker alive");
        assert_eq!(stats.failed_requests, 1, "stats: {stats:?}");
        assert_eq!(stats.meshes_built, 2, "stats: {stats:?}");
    }
}
