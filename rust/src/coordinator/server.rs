//! The leader/worker batch server: a request queue drained by a worker
//! thread that groups pending requests into batches (vLLM-style continuous
//! batching, degenerate single-queue form appropriate to one shared
//! operator) and answers over per-request channels.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::mesh::Mesh;
use crate::solver::SolverConfig;

use super::api::{SolveRequest, SolveResponse};
use super::batcher::BatchSolver;

enum Msg {
    Request(SolveRequest, Sender<Result<SolveResponse>>),
    Shutdown,
}

/// Handle to the running server.
pub struct BatchServer {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    /// Max requests drained into one batch.
    pub max_batch: usize,
}

impl BatchServer {
    /// Spawn the worker; `max_batch` bounds the drain per cycle.
    pub fn start(mesh: Mesh, config: SolverConfig, max_batch: usize) -> BatchServer {
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
        let worker = std::thread::spawn(move || {
            let solver = BatchSolver::new(&mesh, config);
            let mut pending: Vec<(SolveRequest, Sender<Result<SolveResponse>>)> = Vec::new();
            loop {
                // Block for the first message, then drain without blocking.
                match rx.recv() {
                    Err(_) | Ok(Msg::Shutdown) => break,
                    Ok(Msg::Request(r, reply)) => pending.push((r, reply)),
                }
                while pending.len() < max_batch {
                    match rx.try_recv() {
                        Ok(Msg::Request(r, reply)) => pending.push((r, reply)),
                        Ok(Msg::Shutdown) => {
                            for (req, reply) in pending.drain(..) {
                                let _ = reply.send(solver.solve_one(&req));
                            }
                            return;
                        }
                        Err(_) => break,
                    }
                }
                for (req, reply) in pending.drain(..) {
                    let _ = reply.send(solver.solve_one(&req));
                }
            }
        });
        BatchServer {
            tx,
            worker: Some(worker),
            max_batch,
        }
    }

    /// Submit a request; returns the receiver for the response.
    pub fn submit(&self, req: SolveRequest) -> Receiver<Result<SolveResponse>> {
        let (reply_tx, reply_rx) = channel();
        let _ = self.tx.send(Msg::Request(req, reply_tx));
        reply_rx
    }

    /// Submit many and wait for all.
    pub fn solve_all(&self, reqs: Vec<SolveRequest>) -> Result<Vec<SolveResponse>> {
        let receivers: Vec<_> = reqs.into_iter().map(|r| self.submit(r)).collect();
        let mut out = Vec::with_capacity(receivers.len());
        for rx in receivers {
            out.push(rx.recv()??);
        }
        Ok(out)
    }
}

impl Drop for BatchServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::structured::unit_cube_tet;
    use crate::util::rng::Rng;

    #[test]
    fn server_answers_all_requests() {
        let mesh = unit_cube_tet(3);
        let n = mesh.n_nodes();
        let server = BatchServer::start(mesh, SolverConfig::default(), 8);
        let mut rng = Rng::new(2);
        let reqs: Vec<_> = (0..10)
            .map(|id| crate::coordinator::SolveRequest {
                id,
                f_nodal: (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
            })
            .collect();
        let out = server.solve_all(reqs).unwrap();
        assert_eq!(out.len(), 10);
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        assert!(out.iter().all(|r| r.rel_residual < 1e-8));
    }

    #[test]
    fn shutdown_flushes_pending() {
        let mesh = unit_cube_tet(2);
        let n = mesh.n_nodes();
        let server = BatchServer::start(mesh, SolverConfig::default(), 4);
        let rx = server.submit(crate::coordinator::SolveRequest {
            id: 7,
            f_nodal: vec![1.0; n],
        });
        drop(server); // shutdown must still answer
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 7);
    }
}
