//! The per-shard worker of the sharded batch server.
//!
//! Each shard owns its slice of the `mesh_id → Arc<BatchSolver>` registry
//! (meshes are homed on exactly one shard by the router's stable hash)
//! and its own bounded queue, and drains it with the same continuous-
//! batching semantics as the single-worker server: block for the first
//! message, opportunistically drain up to `max_batch` more without
//! blocking, group the drained requests by `(mesh_id, kind)`, and serve
//! the groups round-robin in `max_batch`-sized chunks.
//!
//! Work stealing: when stealing is enabled an *idle* shard (own queue
//! empty after a short park) scans its siblings' queues and steals the
//! best still-queued `(mesh_id, kind)` group — always the WHOLE group,
//! never a split, so a stolen burst is still served by batched dispatch
//! and every lane stays bitwise identical to the scalar oracle. Victim
//! groups are breaker-gated (Open/HalfOpen meshes are never stolen) and
//! ranked by hotness × estimated group cost × queue age; see
//! [`ShardWorker::try_steal`]. The thief serves the group against the
//! victim's registry slice (the victim's `Arc<BatchSolver>` is cloned,
//! not rebuilt), so per-mesh state — sessions, LRU accounting, dispatch
//! counters — stays homed on one shard. The only compound lock hold is
//! the steal scan's queue → health → registry order; every other path
//! locks one of them at a time, so there is no lock-order cycle.
//!
//! Supervision (default-off): with a [`SupervisionShared`] enabled, the
//! worker parks clones of each batch it is about to serve on its
//! [`ShardHandle`] — the handle outlives the worker thread, so the
//! router's supervisor can salvage the unanswered remainder of a crashed
//! worker's batch, respawn the worker, and requeue or answer the
//! casualties. Serving counters live on the handle for the same reason:
//! a respawn must not reset the folded stats.
//!
//! Threading: shard workers do not solve on threads of their own — every
//! assembly/solve they dispatch lands in the one global `TG_THREADS`
//! pool (`util::threadpool`), whose submission gate serializes
//! concurrent top-level submitters. N shards therefore never
//! oversubscribe the configured core budget; they overlap their
//! per-request bookkeeping and queueing, and pipeline into the pool.

use std::collections::{HashMap, HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::mesh::Mesh;
use crate::session::health::{BreakerState, HealthConfig, HealthRegistry, LaneOutcome};
use crate::solver::SolverConfig;

use super::api::{CoordinatorStats, SolveError, SolveRequest, SolveResponse, VarCoeffRequest};
use super::batcher::BatchSolver;

/// A request's answer channel plus the supervision bookkeeping that makes
/// exactly-once answers provable across worker crashes. Without
/// supervision every field but `tx` stays at its `new` default and
/// [`Reply::send`] degenerates to a bare channel send.
#[derive(Clone)]
pub(super) struct Reply {
    pub(super) tx: Sender<Result<SolveResponse>>,
    /// Shared answered flag, present only while supervision has parked a
    /// clone of this request: stored (Release) immediately before the
    /// answer goes out so the supervisor's salvage pass (Acquire) never
    /// requeues or re-answers an already-answered request.
    pub(super) answered: Option<Arc<AtomicBool>>,
    /// How many times this request has already been requeued after losing
    /// its worker (checked against the supervision retry budget).
    pub(super) attempts: u32,
    /// Whether this request entered as part of its mesh's HalfOpen probe
    /// group: salvage `cancel_probe`s the mesh for probe-tagged
    /// casualties so a breaker cannot wedge in HalfOpen forever.
    pub(super) probe: bool,
}

impl Reply {
    pub(super) fn new(tx: Sender<Result<SolveResponse>>) -> Reply {
        Reply { tx, answered: None, attempts: 0, probe: false }
    }

    /// Answer the request, marking the shared answered flag (when parked)
    /// BEFORE the send so a concurrent salvage pass observes it.
    pub(super) fn send(&self, res: Result<SolveResponse>) {
        if let Some(flag) = &self.answered {
            flag.store(true, Ordering::Release);
        }
        let _ = self.tx.send(res);
    }
}

/// A queued request of either kind. `Clone` exists for supervision
/// parking: the worker parks a clone of its in-flight batch so the
/// supervisor can requeue it if the worker dies mid-serve.
#[derive(Clone)]
pub(super) enum Req {
    Fixed(SolveRequest),
    Var(VarCoeffRequest),
}

/// Request kind discriminant: groups are homogeneous in `(mesh_id, kind)`
/// and stealing moves whole groups, so the kind is part of the group key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub(super) enum ReqKind {
    Fixed,
    Var,
}

impl Req {
    pub(super) fn id(&self) -> u64 {
        match self {
            Req::Fixed(r) => r.id,
            Req::Var(r) => r.id,
        }
    }

    pub(super) fn mesh_id(&self) -> u64 {
        match self {
            Req::Fixed(r) => r.mesh_id,
            Req::Var(r) => r.mesh_id,
        }
    }

    pub(super) fn deadline(&self) -> Option<Instant> {
        match self {
            Req::Fixed(r) => r.deadline,
            Req::Var(r) => r.deadline,
        }
    }

    fn kind(&self) -> ReqKind {
        match self {
            Req::Fixed(_) => ReqKind::Fixed,
            Req::Var(_) => ReqKind::Var,
        }
    }
}

pub(super) enum Msg {
    /// One or more requests submitted together: a burst for one shard
    /// arrives as one queue entry, so the whole per-shard burst is
    /// guaranteed to land in a single drain cycle.
    Many(Vec<(Req, Reply)>),
    /// Register (or replace) a mesh topology on this shard's registry
    /// slice; acknowledged once the worker has installed it.
    Register(u64, Box<Mesh>, Sender<()>),
    /// Ask this shard for its PARTIAL stats (worker-local + registry
    /// counters); the router folds the partials and adds the globals.
    Stats(Sender<CoordinatorStats>),
    Shutdown,
}

/// Admission bookkeeping shared between the router and all shards. The
/// bound is enforced against ONE global in-flight depth (`depth`), so
/// `Overloaded` semantics are identical at any shard count; the per-shard
/// depths on each [`ShardHandle`] remain observability (live `per_shard`
/// samples and the per-shard high-water marks), not the admission gate.
#[derive(Default)]
pub(super) struct Admission {
    /// Depth bound currently in force (0 = unbounded, the default).
    /// Adaptive shedding may hold this at a tightened fraction
    /// of `base_max_queue` while sick traffic dominates.
    pub(super) max_queue: AtomicUsize,
    /// Requests admitted (to ANY shard) but not yet drained — the single
    /// depth the bound compares against. Submit adds, drain/steal
    /// subtracts, supervision requeues re-add.
    pub(super) depth: AtomicUsize,
    /// The caller-configured bound (`BatchServer::set_max_queue`) that
    /// the tightened bound is derived from and relaxes back to.
    pub(super) base_max_queue: AtomicUsize,
    /// Requests whose deadline had already passed at submission —
    /// answered `SolveError::Expired` synchronously, never enqueued.
    /// Folded into both `expired_requests` and `failed_requests`.
    pub(super) expired_at_submit: AtomicU64,
}

/// Supervision state shared between the router's supervisor thread and
/// every shard worker. Counters are router-owned in the stats fold (the
/// supervisor is the only writer of respawns/requeued/lost/wedged);
/// `enabled` gates the workers' parking so the default path does no
/// supervision work at all.
pub(super) struct SupervisionShared {
    /// Workers park in-flight clones and the supervisor thread runs.
    pub(super) enabled: AtomicBool,
    /// Per-request retry budget ([`super::api::SupervisionConfig`]).
    pub(super) max_requeues: AtomicU64,
    /// Set at the start of every shutdown path: the supervisor must stop
    /// respawning (a worker exiting on `Msg::Shutdown` is not a crash).
    pub(super) shutting_down: AtomicBool,
    /// Workers respawned after dying.
    pub(super) respawns: AtomicU64,
    /// Salvaged requests requeued onto a live worker.
    pub(super) requeued: AtomicU64,
    /// Salvaged requests answered `WorkerLost` (budget exhausted).
    pub(super) lost: AtomicU64,
    /// Requests answered with a typed `Shutdown` at the drain deadline.
    pub(super) shutdown_answered: AtomicU64,
    /// Wedge episodes detected (stale heartbeat with work queued).
    pub(super) wedged: AtomicU64,
}

impl SupervisionShared {
    pub(super) fn new() -> SupervisionShared {
        SupervisionShared {
            enabled: AtomicBool::new(false),
            max_requeues: AtomicU64::new(0),
            shutting_down: AtomicBool::new(false),
            respawns: AtomicU64::new(0),
            requeued: AtomicU64::new(0),
            lost: AtomicU64::new(0),
            shutdown_answered: AtomicU64::new(0),
            wedged: AtomicU64::new(0),
        }
    }
}

/// Health state shared between the router (synchronous breaker sheds)
/// and every shard worker (outcome observation, drain-time sheds,
/// adaptive retuning). ONE registry for the whole server — probe-group
/// bookkeeping is per mesh, not per shard, so the one-probe-group
/// invariant holds even when a sick mesh's traffic is served by a thief.
pub(super) struct HealthShared {
    pub(super) enabled: AtomicBool,
    registry: Mutex<HealthRegistry>,
}

impl HealthShared {
    pub(super) fn new() -> HealthShared {
        HealthShared {
            enabled: AtomicBool::new(false),
            registry: Mutex::new(HealthRegistry::new(HealthConfig::disabled())),
        }
    }

    /// Lock the registry, surviving a poisoned mutex (a panic while a
    /// health call was in flight must not take the serving path down).
    pub(super) fn lock(&self) -> MutexGuard<'_, HealthRegistry> {
        self.registry.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// One shard's queue: a mutex-guarded deque + condvar instead of mpsc so
/// that sibling shards can scan and extract whole groups (stealing needs
/// multi-consumer access mpsc cannot give).
pub(super) struct ShardQueue {
    inner: Mutex<VecDeque<Msg>>,
    ready: Condvar,
    /// Set by shutdown: further submissions are refused (the caller
    /// answers "worker is gone") while the internal Shutdown message
    /// still goes through.
    closed: AtomicBool,
}

impl ShardQueue {
    fn new() -> ShardQueue {
        ShardQueue {
            inner: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            closed: AtomicBool::new(false),
        }
    }

    fn lock(&self) -> MutexGuard<'_, VecDeque<Msg>> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Enqueue a message; `Err(msg)` once the queue is closed (shutdown
    /// begun) so the submitter can answer instead of parking clients.
    pub(super) fn push(&self, msg: Msg) -> std::result::Result<(), Msg> {
        if self.closed.load(Ordering::Acquire) {
            return Err(msg);
        }
        self.lock().push_back(msg);
        self.ready.notify_one();
        Ok(())
    }

    /// Close the queue and enqueue the worker's Shutdown (bypassing the
    /// closed check). Messages racing past the closed check may land
    /// behind the Shutdown; the router drains and answers them after
    /// joining the worker.
    pub(super) fn close_and_shutdown(&self) {
        self.closed.store(true, Ordering::Release);
        self.lock().push_back(Msg::Shutdown);
        self.ready.notify_one();
    }

    /// Drain everything still queued (post-join leftover cleanup).
    pub(super) fn drain(&self) -> Vec<Msg> {
        self.lock().drain(..).collect()
    }

    /// Pull every queued request out of the queue, leaving control
    /// messages (Register/Stats/Shutdown) in place so a still-running
    /// worker exits normally — the drain-deadline path of
    /// `shutdown_within` answers the extracted requests `Shutdown`.
    pub(super) fn extract_many(&self) -> Vec<(Req, Reply)> {
        let mut q = self.lock();
        let mut out = Vec::new();
        for msg in q.iter_mut() {
            if let Msg::Many(list) = msg {
                out.append(list);
            }
        }
        q.retain(|m| !matches!(m, Msg::Many(v) if v.is_empty()));
        out
    }
}

/// Shared per-shard state: the queue, live admission/steal counters read
/// by `per_shard()` without a round-trip, and the shard's registry slice
/// (behind a mutex so a thief can borrow a victim's built solvers).
///
/// Everything a respawned worker needs outlives the worker thread here:
/// the registry (meshes + built states — the retained topology store),
/// the monotone serving counters, and the supervision parking slot with
/// the batch a dead worker was serving.
pub(super) struct ShardHandle {
    pub(super) queue: ShardQueue,
    /// Requests admitted to this shard but not yet drained. Observability
    /// (live `per_shard` depths, high-water) — the admission BOUND is
    /// enforced against the global [`Admission::depth`].
    pub(super) depth: AtomicUsize,
    /// High-water mark of `depth` since server start.
    pub(super) high_water: AtomicU64,
    /// Requests overload-rejected at submission for this shard.
    pub(super) rejected: AtomicU64,
    /// Breaker sheds attributed to meshes homed on this shard (submit-
    /// time and drain-time).
    pub(super) shed: AtomicU64,
    /// Whole groups THIS shard stole from siblings.
    pub(super) stolen: AtomicU64,
    /// Steal candidates this shard skipped because the group's mesh
    /// breaker was Open or HalfOpen (the probe group must not migrate).
    pub(super) steals_skipped: AtomicU64,
    /// Liveness epoch: the worker bumps this once per loop iteration, so
    /// a heartbeat that stops advancing while `depth > 0` marks a wedged
    /// (live but stuck) worker to the supervisor.
    pub(super) heartbeat: AtomicU64,
    /// Worker serving counters, kept on the handle — not the worker —
    /// so they survive a respawn (the folded stats stay monotone across
    /// crashes; pinned by `crash_recovery.rs`).
    pub(super) failed: AtomicU64,
    pub(super) expired: AtomicU64,
    pub(super) queued: AtomicU64,
    pub(super) cycles: AtomicU64,
    pub(super) groups: AtomicU64,
    /// Supervision parking slot: clones of the batch the worker is
    /// currently serving, sharing answered flags with the live replies.
    /// Empty whenever no serve is in flight (or supervision is off).
    inflight: Mutex<Vec<(Req, Reply)>>,
    registry: Mutex<Registry>,
}

impl ShardHandle {
    pub(super) fn new(config: SolverConfig, max_states: usize) -> ShardHandle {
        ShardHandle {
            queue: ShardQueue::new(),
            depth: AtomicUsize::new(0),
            high_water: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            stolen: AtomicU64::new(0),
            steals_skipped: AtomicU64::new(0),
            heartbeat: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            queued: AtomicU64::new(0),
            cycles: AtomicU64::new(0),
            groups: AtomicU64::new(0),
            inflight: Mutex::new(Vec::new()),
            registry: Mutex::new(Registry::new(config, max_states)),
        }
    }

    pub(super) fn registry(&self) -> MutexGuard<'_, Registry> {
        self.registry.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Lock the parking slot, surviving the poison a crashed worker
    /// leaves behind (the slot contents stay consistent: parking writes
    /// it whole before any serve begins).
    pub(super) fn inflight(&self) -> MutexGuard<'_, Vec<(Req, Reply)>> {
        self.inflight.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// A registry slot: the built (or failed) per-mesh state plus its
/// last-touch tick for LRU eviction. Built states sit behind an `Arc` so
/// a thief shard can hold a group's solver across a serve without
/// blocking registry mutation.
struct RegistryEntry {
    /// A failed build (panicking setup of a *registered* mesh) is memoized
    /// too, so sustained traffic for a bad mesh pays the setup attempt
    /// once, not per drain cycle (until the slot is evicted). Unregistered
    /// keys never get a slot at all.
    state: std::result::Result<Arc<BatchSolver>, String>,
    last_used: u64,
}

/// One shard's slice of the mesh/solver registry: the meshes homed on
/// this shard and their lazily built per-mesh states, LRU-capped at
/// `max_states` (0 = unbounded; the cap is PER SHARD). Lives behind the
/// shard handle's mutex so that work stealing can clone a victim's
/// `Arc<BatchSolver>` instead of rebuilding it.
pub(super) struct Registry {
    meshes: HashMap<u64, Mesh>,
    /// Lazily built per-mesh state.
    states: HashMap<u64, RegistryEntry>,
    config: SolverConfig,
    max_states: usize,
    /// Monotone access clock driving the LRU order.
    tick: u64,
    evictions: u64,
    rebuilds: u64,
    /// Keys that were evicted at least once — a rebuild of one of these
    /// counts as registry churn (`state_rebuilds`).
    evicted_keys: HashSet<u64>,
    /// Dispatch counters of evicted solvers, folded in so the aggregate
    /// stats stay monotone across evictions.
    retired_batched: u64,
    retired_scalar: u64,
    /// Escalation-ladder counters of evicted solvers (same fold).
    retired_retried: u64,
    retired_rescued: u64,
    /// Budget-skipped ladder rungs of evicted solvers (same fold).
    retired_skipped: u64,
}

impl Registry {
    fn new(config: SolverConfig, max_states: usize) -> Registry {
        Registry {
            meshes: HashMap::new(),
            states: HashMap::new(),
            config,
            max_states,
            tick: 0,
            evictions: 0,
            rebuilds: 0,
            evicted_keys: HashSet::new(),
            retired_batched: 0,
            retired_scalar: 0,
            retired_retried: 0,
            retired_rescued: 0,
            retired_skipped: 0,
        }
    }

    /// Install (or replace) a mesh topology. Replacing a registered id
    /// retires any built state for the old topology — counted as an
    /// eviction, dispatch counters folded into the retired totals — so
    /// the next request builds against the new mesh (the AMR
    /// re-registration path).
    pub(super) fn register(&mut self, mesh_id: u64, mesh: Mesh) {
        if let Some(entry) = self.states.remove(&mesh_id) {
            self.evictions += 1;
            self.evicted_keys.insert(mesh_id);
            if let Ok(solver) = entry.state {
                self.retire(&solver);
            }
        }
        self.meshes.insert(mesh_id, mesh);
    }

    /// Whether `mesh_id` is registered on this shard (independent of
    /// whether its state is built).
    fn contains_mesh(&self, mesh_id: u64) -> bool {
        self.meshes.contains_key(&mesh_id)
    }

    /// Fold an evicted solver's counters into the retired totals so the
    /// aggregate stats stay monotone across evictions.
    fn retire(&mut self, solver: &BatchSolver) {
        self.retired_batched += solver.n_batched_solves();
        self.retired_scalar += solver.n_scalar_solves();
        self.retired_retried += solver.n_retried_lanes();
        self.retired_rescued += solver.n_rescued_lanes();
        self.retired_skipped += solver.n_skipped_rungs();
    }

    /// Look up (or lazily build, memoizing success AND failure) the
    /// amortized state for a mesh key, touching its LRU clock. When the
    /// registry is at its cap, the least-recently-used slot is evicted
    /// before the new build (its dispatch counters fold into the retired
    /// totals so aggregate stats stay monotone).
    fn solver_for(&mut self, mesh_id: u64) -> std::result::Result<Arc<BatchSolver>, String> {
        self.tick += 1;
        let tick = self.tick;
        if !self.states.contains_key(&mesh_id) {
            // Unregistered keys never occupy a registry slot: a hostile
            // stream of bogus mesh_ids must not evict built states or grow
            // the eviction bookkeeping (the error string is cheap to
            // rebuild per request).
            if !self.meshes.contains_key(&mesh_id) {
                return Err(format!("no mesh registered under mesh_id {mesh_id}"));
            }
            if self.max_states > 0 && self.states.len() >= self.max_states {
                // LRU victim: stalest tick, smallest key on (never-occurring
                // within one shard) ties — fully deterministic.
                if let Some((&victim, _)) =
                    self.states.iter().min_by_key(|&(k, e)| (e.last_used, *k))
                {
                    if let Some(entry) = self.states.remove(&victim) {
                        self.evictions += 1;
                        self.evicted_keys.insert(victim);
                        if let Ok(solver) = entry.state {
                            self.retire(&solver);
                        }
                    }
                }
            }
            if self.evicted_keys.contains(&mesh_id) {
                self.rebuilds += 1;
            }
            let config = self.config;
            let mesh = self.meshes.get(&mesh_id).expect("registration checked above");
            // Deliberately OUTSIDE the catch_unwind: this failpoint models
            // a registry build taking the whole worker down (the crash the
            // supervision layer exists for), not a memoized failed build.
            #[cfg(feature = "fault-inject")]
            crate::util::faults::maybe_panic(
                crate::util::faults::SESSION_BUILD_PANIC,
                mesh_id as usize,
            );
            let built =
                catch_unwind(AssertUnwindSafe(|| Arc::new(BatchSolver::new(mesh, config))))
                    .map_err(|p| {
                        format!(
                            "building state for mesh_id {mesh_id} panicked: {}",
                            panic_msg(&*p)
                        )
                    });
            self.states.insert(mesh_id, RegistryEntry { state: built, last_used: tick });
        }
        let entry = self.states.get_mut(&mesh_id).expect("slot just ensured");
        entry.last_used = tick;
        entry.state.as_ref().map(Arc::clone).map_err(|e| e.clone())
    }

    /// Estimated per-iteration solve cost (ms) for `mesh_id`, from the
    /// per-rung EWMAs of its built session — `None` while the state is
    /// unbuilt, failed, or not yet calibrated by served traffic. Read-only:
    /// does not touch the LRU clock (a steal *scan* must not pin slots).
    pub(super) fn cost_estimate(&self, mesh_id: u64) -> Option<f64> {
        let entry = self.states.get(&mesh_id)?;
        let solver = entry.state.as_ref().ok()?;
        let ms = solver.session().cost_ms_per_iter();
        (ms > 0.0).then_some(ms)
    }

    /// Fold this slice's registry counters into a (partial) stats value.
    fn stats_into(&self, s: &mut CoordinatorStats) {
        s.evicted_states += self.evictions;
        s.state_rebuilds += self.rebuilds;
        s.batched_solves += self.retired_batched;
        s.scalar_solves += self.retired_scalar;
        s.retried_lanes += self.retired_retried;
        s.rescued_lanes += self.retired_rescued;
        s.skipped_rungs += self.retired_skipped;
        for entry in self.states.values() {
            if let Ok(solver) = &entry.state {
                s.meshes_built += 1;
                s.batched_solves += solver.n_batched_solves();
                s.scalar_solves += solver.n_scalar_solves();
                s.retried_lanes += solver.n_retried_lanes();
                s.rescued_lanes += solver.n_rescued_lanes();
                s.skipped_rungs += solver.n_skipped_rungs();
            }
        }
    }
}

/// One `(mesh_id, kind)` group's still-unserved requests within a drain
/// cycle, consumed chunk by chunk by the round-robin scheduler.
struct GroupQueue<R> {
    mesh_id: u64,
    items: Vec<(R, Reply)>,
    /// Whether the group *arrived* as a singleton (scalar dispatch); a
    /// trailing chunk of 1 carved from a larger group still dispatches
    /// batched, keeping the batched/scalar counters an exact regression
    /// signal.
    singleton: bool,
}

/// A whole `(mesh_id, kind)` group extracted from a sibling's queue.
struct Stolen {
    /// The shard the group was stolen from — its registry slice homes the
    /// mesh, so the thief serves against it.
    victim: usize,
    mesh_id: u64,
    kind: ReqKind,
    items: Vec<(Req, Reply)>,
}

/// Bucket mesh-homogeneous items by mesh key, preserving arrival order
/// within each bucket (first-seen key order across buckets).
fn group_by_mesh<R>(items: Vec<(R, Reply)>, mesh_id: fn(&R) -> u64) -> Vec<GroupQueue<R>> {
    let mut groups: Vec<GroupQueue<R>> = Vec::new();
    let mut index: HashMap<u64, usize> = HashMap::new();
    for (req, reply) in items {
        let key = mesh_id(&req);
        let gi = *index.entry(key).or_insert_with(|| {
            groups.push(GroupQueue {
                mesh_id: key,
                items: Vec::new(),
                singleton: false,
            });
            groups.len() - 1
        });
        groups[gi].items.push((req, reply));
    }
    for g in &mut groups {
        g.singleton = g.items.len() == 1;
    }
    groups
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

/// How long an idle steal-enabled shard parks on its own condvar before
/// scanning siblings. Short enough that a hot mesh's backlog is picked up
/// within a drain cycle; long enough that idle shards cost ~nothing.
const STEAL_PARK: Duration = Duration::from_millis(1);

/// The worker loop state of one shard. The serving counters live on the
/// shard's [`ShardHandle`] (not here) so a respawned worker continues
/// them instead of resetting — the worker itself is disposable.
pub(super) struct ShardWorker {
    pub(super) idx: usize,
    pub(super) shards: Arc<Vec<ShardHandle>>,
    pub(super) max_batch: usize,
    pub(super) steal: bool,
    /// Stats queries seen in the current drain cycle — answered only
    /// AFTER the cycle's dispatch, so a snapshot reflects every request
    /// that was enqueued on THIS shard ahead of it (FIFO per shard).
    pub(super) stats_waiters: Vec<Sender<CoordinatorStats>>,
    pub(super) admission: Arc<Admission>,
    pub(super) health: Arc<HealthShared>,
    pub(super) sup: Arc<SupervisionShared>,
}

enum Popped {
    Msg(Msg),
    /// A stolen group was served inside the wait; loop again.
    ServedStolen,
}

impl ShardWorker {
    pub(super) fn new(
        idx: usize,
        shards: Arc<Vec<ShardHandle>>,
        max_batch: usize,
        steal: bool,
        admission: Arc<Admission>,
        health: Arc<HealthShared>,
        sup: Arc<SupervisionShared>,
    ) -> ShardWorker {
        ShardWorker {
            idx,
            shards,
            max_batch,
            steal,
            stats_waiters: Vec::new(),
            admission,
            health,
            sup,
        }
    }

    fn my(&self) -> &ShardHandle {
        &self.shards[self.idx]
    }

    /// Park clones of the batch this worker is about to serve in the
    /// handle's in-flight slot, wiring a fresh shared answered flag into
    /// each live reply, so the supervisor can salvage exactly the
    /// unanswered remainder if the worker dies mid-serve. A no-op while
    /// supervision is disabled (the default path clones nothing).
    fn park(&self, pending: &mut [(Req, Reply)]) {
        if !self.sup.enabled.load(Ordering::Relaxed) {
            return;
        }
        let mut slot = self.my().inflight();
        slot.clear();
        slot.reserve(pending.len());
        for (req, reply) in pending.iter_mut() {
            let flag = Arc::new(AtomicBool::new(false));
            reply.answered = Some(Arc::clone(&flag));
            slot.push((
                req.clone(),
                Reply {
                    tx: reply.tx.clone(),
                    answered: Some(flag),
                    attempts: reply.attempts,
                    probe: reply.probe,
                },
            ));
        }
    }

    /// Clear the parking slot after a serve completed: every parked
    /// request has been answered, there is nothing left to salvage.
    fn unpark(&self) {
        if self.sup.enabled.load(Ordering::Relaxed) {
            self.my().inflight().clear();
        }
    }

    /// The drain loop: block for the first message (or steal while
    /// idle), opportunistically drain more without blocking, dispatch.
    pub(super) fn run(mut self) {
        let mut pending: Vec<(Req, Reply)> = Vec::new();
        loop {
            // Liveness epoch for the supervisor's wedge detection.
            self.my().heartbeat.fetch_add(1, Ordering::Relaxed);
            let msg = match self.next_msg() {
                Popped::Msg(m) => m,
                Popped::ServedStolen => continue,
            };
            if !self.accept(msg, &mut pending) {
                self.dispatch(std::mem::take(&mut pending));
                self.flush_stats();
                return;
            }
            while pending.len() < self.max_batch.max(1) {
                let next = self.my().queue.lock().pop_front();
                match next {
                    Some(m) => {
                        if !self.accept(m, &mut pending) {
                            self.dispatch(std::mem::take(&mut pending));
                            self.flush_stats();
                            return;
                        }
                    }
                    None => break,
                }
            }
            self.dispatch(std::mem::take(&mut pending));
            self.flush_stats();
        }
    }

    /// Pop the next message from the own queue, blocking while empty.
    /// With stealing enabled the block is a short park: each timeout the
    /// shard scans its siblings and serves a stolen group in place.
    fn next_msg(&mut self) -> Popped {
        loop {
            let mut q = self.my().queue.lock();
            if let Some(m) = q.pop_front() {
                return Popped::Msg(m);
            }
            if !self.steal {
                while q.is_empty() {
                    q = self
                        .my()
                        .queue
                        .ready
                        .wait(q)
                        .unwrap_or_else(|e| e.into_inner());
                }
                continue;
            }
            let (guard, _) = self
                .my()
                .queue
                .ready
                .wait_timeout(q, STEAL_PARK)
                .unwrap_or_else(|e| e.into_inner());
            if !guard.is_empty() {
                continue;
            }
            drop(guard);
            if let Some(stolen) = self.try_steal() {
                self.serve_stolen(stolen);
                return Popped::ServedStolen;
            }
        }
    }

    /// Scan sibling queues (rotating from the next index for fairness)
    /// and extract the best still-queued `(mesh_id, kind)` group — the
    /// WHOLE group, merged across queued bursts, exactly what the victim
    /// would have regrouped in one drain cycle. Control messages
    /// (Register/Stats/Shutdown) are never touched or reordered.
    ///
    /// Candidates whose mesh breaker is Open (shedding belongs on the
    /// home shard's drain) or HalfOpen (the queued group IS the probe and
    /// must not migrate) are skipped and counted. The survivors are
    /// ranked by hotness × estimated per-iteration cost (the victim
    /// session's per-rung EWMAs; 1.0 while unbuilt or uncalibrated) ×
    /// queue age (first-seen position — earlier ⇒ queued longer), strict
    /// `>` so exact ties keep the first-seen candidate: deterministic,
    /// and degrades to the old hottest-first rule when all groups are
    /// equally aged and uncalibrated. Whichever group wins, answers stay
    /// bitwise — ranking only reorders whole-group serving.
    ///
    /// Lock order: victim queue → health registry → victim registry. No
    /// path acquires these in reverse (serving drops the registry guard
    /// before touching any queue; health calls never take a queue), so
    /// there is no cycle.
    fn try_steal(&self) -> Option<Stolen> {
        let n = self.shards.len();
        for off in 1..n {
            let v = (self.idx + off) % n;
            let mut q = self.shards[v].queue.lock();
            // Tally queued groups in first-seen order.
            let mut counts: Vec<((u64, ReqKind), usize)> = Vec::new();
            for msg in q.iter() {
                if let Msg::Many(items) = msg {
                    for (req, _) in items {
                        let key = (req.mesh_id(), req.kind());
                        match counts.iter_mut().find(|(k, _)| *k == key) {
                            Some((_, c)) => *c += 1,
                            None => counts.push((key, 1)),
                        }
                    }
                }
            }
            // Breaker gate: drop Open/HalfOpen meshes from the candidates.
            let gated: Vec<((u64, ReqKind), usize)> =
                if self.health.enabled.load(Ordering::Relaxed) && !counts.is_empty() {
                    let reg = self.health.lock();
                    let mut keep = Vec::with_capacity(counts.len());
                    for &(key, c) in &counts {
                        let blocked = reg.snapshot(key.0).is_some_and(|s| {
                            matches!(s.state, BreakerState::Open | BreakerState::HalfOpen)
                        });
                        if blocked {
                            self.my().steals_skipped.fetch_add(1, Ordering::Relaxed);
                        } else {
                            keep.push((key, c));
                        }
                    }
                    keep
                } else {
                    counts
                };
            // Rank the survivors: count × cost estimate × age weight.
            let best = {
                let vreg = self.shards[v].registry();
                let g = gated.len();
                let mut best: Option<((u64, ReqKind), f64)> = None;
                for (i, &(key, c)) in gated.iter().enumerate() {
                    let est = vreg.cost_estimate(key.0).unwrap_or(1.0);
                    let score = c as f64 * est * (g - i) as f64;
                    let better = match best {
                        Some((_, bs)) => score > bs,
                        None => true,
                    };
                    if better {
                        best = Some((key, score));
                    }
                }
                best
            };
            let Some(((mesh_id, kind), _)) = best else {
                continue;
            };
            let mut items = Vec::new();
            for msg in q.iter_mut() {
                if let Msg::Many(list) = msg {
                    let mut keep = Vec::with_capacity(list.len());
                    for it in list.drain(..) {
                        if it.0.mesh_id() == mesh_id && it.0.kind() == kind {
                            items.push(it);
                        } else {
                            keep.push(it);
                        }
                    }
                    *list = keep;
                }
            }
            q.retain(|m| !matches!(m, Msg::Many(v) if v.is_empty()));
            drop(q);
            self.shards[v].depth.fetch_sub(items.len(), Ordering::Relaxed);
            // The stolen items never pass the victim's dispatch, so the
            // global admission depth is released here instead.
            self.admission.depth.fetch_sub(items.len(), Ordering::Relaxed);
            return Some(Stolen { victim: v, mesh_id, kind, items });
        }
        None
    }

    /// Serve a stolen group whole (in `max_batch`-sized chunks) against
    /// the VICTIM's registry slice — the stolen mesh's solver is cloned
    /// out of the victim's registry, never rebuilt on the thief.
    fn serve_stolen(&mut self, mut s: Stolen) {
        if s.items.is_empty() {
            return;
        }
        // Park on the THIEF's slot: it is the thief that would die
        // mid-serve; salvage routes the requests back by mesh home.
        self.park(&mut s.items);
        self.my().stolen.fetch_add(1, Ordering::Relaxed);
        self.my().cycles.fetch_add(1, Ordering::Relaxed);
        self.my().queued.fetch_add(s.items.len() as u64, Ordering::Relaxed);
        self.my().groups.fetch_add(1, Ordering::Relaxed);
        let singleton = s.items.len() == 1;
        match s.kind {
            ReqKind::Fixed => {
                let items: Vec<(SolveRequest, Reply)> = s
                    .items
                    .into_iter()
                    .map(|(req, reply)| match req {
                        Req::Fixed(r) => (r, reply),
                        Req::Var(_) => unreachable!("kind-homogeneous group"),
                    })
                    .collect();
                self.serve_group(
                    s.victim,
                    s.mesh_id,
                    items,
                    singleton,
                    |r: &SolveRequest| r.id,
                    BatchSolver::solve_one,
                    BatchSolver::solve_batch_each,
                );
            }
            ReqKind::Var => {
                let items: Vec<(VarCoeffRequest, Reply)> = s
                    .items
                    .into_iter()
                    .map(|(req, reply)| match req {
                        Req::Var(r) => (r, reply),
                        Req::Fixed(_) => unreachable!("kind-homogeneous group"),
                    })
                    .collect();
                self.serve_group(
                    s.victim,
                    s.mesh_id,
                    items,
                    singleton,
                    |r: &VarCoeffRequest| r.id,
                    BatchSolver::solve_varcoeff_one,
                    BatchSolver::solve_varcoeff_batch_each,
                );
            }
        }
        self.unpark();
        self.retune_admission();
    }

    /// Serve one whole group in `max_batch`-sized chunks (what the
    /// round-robin scheduler does when it is the only non-empty group).
    #[allow(clippy::too_many_arguments)]
    fn serve_group<R>(
        &mut self,
        home: usize,
        mesh_id: u64,
        mut items: Vec<(R, Reply)>,
        singleton: bool,
        req_id: fn(&R) -> u64,
        solve_single: fn(&BatchSolver, &R) -> Result<SolveResponse>,
        solve_batch: fn(&BatchSolver, &[R]) -> Vec<Result<SolveResponse>>,
    ) {
        let max_batch = self.max_batch.max(1);
        while !items.is_empty() {
            let take = items.len().min(max_batch);
            let rest = items.split_off(take);
            let chunk = std::mem::replace(&mut items, rest);
            self.serve_chunk(home, mesh_id, chunk, singleton, req_id, solve_single, solve_batch);
        }
    }

    /// Returns `false` on shutdown.
    fn accept(&mut self, msg: Msg, pending: &mut Vec<(Req, Reply)>) -> bool {
        match msg {
            Msg::Many(items) => pending.extend(items),
            Msg::Register(mesh_id, mesh, ack) => {
                self.my().registry().register(mesh_id, *mesh);
                let _ = ack.send(());
            }
            Msg::Stats(tx) => self.stats_waiters.push(tx),
            Msg::Shutdown => return false,
        }
        true
    }

    /// Answer the stats queries collected this cycle (post-dispatch)
    /// with this shard's PARTIAL counters; the router folds partials
    /// across shards and adds the router-owned globals (admission,
    /// health, per-shard handle counters).
    fn flush_stats(&mut self) {
        if self.stats_waiters.is_empty() {
            return;
        }
        let snapshot = self.stats();
        for tx in self.stats_waiters.drain(..) {
            let _ = tx.send(snapshot);
        }
    }

    fn stats(&self) -> CoordinatorStats {
        let h = self.my();
        let mut s = CoordinatorStats {
            failed_requests: h.failed.load(Ordering::Relaxed),
            queued_requests: h.queued.load(Ordering::Relaxed),
            drain_cycles: h.cycles.load(Ordering::Relaxed),
            dispatch_groups: h.groups.load(Ordering::Relaxed),
            expired_requests: h.expired.load(Ordering::Relaxed),
            ..CoordinatorStats::default()
        };
        h.registry().stats_into(&mut s);
        s
    }

    /// Group the drained queue by `(mesh_id, kind)` — arrival order is
    /// preserved within each group — and serve the groups round-robin in
    /// `max_batch`-sized chunks until all are drained: every group gets
    /// one chunk per round, so a large group cannot starve the others
    /// past its first chunk.
    fn dispatch(&mut self, mut pending: Vec<(Req, Reply)>) {
        #[cfg(feature = "fault-inject")]
        if let Some(ms) = crate::util::faults::stall_ms(crate::util::faults::SERVER_STALL) {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
        self.my().depth.fetch_sub(pending.len(), Ordering::Relaxed);
        self.admission.depth.fetch_sub(pending.len(), Ordering::Relaxed);
        if pending.is_empty() {
            return;
        }
        self.park(&mut pending);
        // AFTER parking, so an injected crash leaves every request of the
        // cycle salvageable — exactly what a real drain-loop panic does
        // (parking precedes all fallible serving work).
        #[cfg(feature = "fault-inject")]
        if crate::util::faults::fire(
            crate::util::faults::SHARD_PANIC,
            self.idx,
            self.my().cycles.load(Ordering::Relaxed) as usize,
        ) {
            panic!("fault-inject: shard.panic_drain fired on shard {}", self.idx);
        }
        self.my().cycles.fetch_add(1, Ordering::Relaxed);
        self.my().queued.fetch_add(pending.len() as u64, Ordering::Relaxed);
        let mut fixed_items = Vec::new();
        let mut var_items = Vec::new();
        for (req, reply) in pending {
            match req {
                Req::Fixed(q) => fixed_items.push((q, reply)),
                Req::Var(q) => var_items.push((q, reply)),
            }
        }
        let mut fixed = group_by_mesh(fixed_items, |r| r.mesh_id);
        let mut var = group_by_mesh(var_items, |r| r.mesh_id);
        self.my().groups.fetch_add((fixed.len() + var.len()) as u64, Ordering::Relaxed);
        loop {
            let served_fixed = self.serve_round(
                &mut fixed,
                |r: &SolveRequest| r.id,
                BatchSolver::solve_one,
                BatchSolver::solve_batch_each,
            );
            let served_var = self.serve_round(
                &mut var,
                |r: &VarCoeffRequest| r.id,
                BatchSolver::solve_varcoeff_one,
                BatchSolver::solve_varcoeff_batch_each,
            );
            if !served_fixed && !served_var {
                break;
            }
        }
        self.unpark();
        self.retune_admission();
    }

    /// After a drain cycle, retune the effective admission bound from the
    /// global sick-traffic signal: while rescued/exhausted lanes dominate
    /// recent outcomes the bound tightens to `base / tighten_divisor`
    /// (floor 1), relaxing back to the configured base on recovery. A
    /// no-op while health tracking is disabled or the base bound is 0
    /// (unbounded). Signal, registry and bound are all global, so any
    /// shard retuning is idempotent across shards.
    fn retune_admission(&mut self) {
        if !self.health.enabled.load(Ordering::Relaxed) {
            return;
        }
        let base = self.admission.base_max_queue.load(Ordering::Relaxed);
        let mut reg = self.health.lock();
        let tight = reg.update_tightened();
        let cfg = reg.config();
        let effective = if tight && base > 0 {
            (base / cfg.tighten_divisor.max(1)).max(1)
        } else {
            base
        };
        self.admission.max_queue.store(effective, Ordering::Relaxed);
    }

    /// Feed one served outcome into the health registry: a clean solve is
    /// `Ok`, a ladder-recovered one `Rescued`, a classified solver failure
    /// (or an unclassifiable panic / state-build failure) `Exhausted`.
    /// Validation and expiry answers say nothing about mesh health and
    /// are not observed. A no-op while health tracking is disabled.
    fn observe_health(&mut self, mesh_id: u64, res: &Result<SolveResponse>) {
        if !self.health.enabled.load(Ordering::Relaxed) {
            return;
        }
        let (outcome, report) = match res {
            Ok(resp) => match &resp.escalation {
                Some(rep) => (LaneOutcome::Rescued, Some(rep)),
                None => (LaneOutcome::Ok, None),
            },
            Err(e) => match e.downcast_ref::<SolveError>() {
                Some(SolveError::Solver { escalation, .. }) => {
                    (LaneOutcome::Exhausted, escalation.as_ref())
                }
                Some(
                    SolveError::Invalid { .. }
                    | SolveError::Expired { .. }
                    | SolveError::Overloaded { .. }
                    | SolveError::Unhealthy { .. }
                    | SolveError::WorkerLost { .. }
                    | SolveError::Shutdown { .. },
                ) => return,
                // No typed error: a recovered panic or a failed state
                // build — the mesh is not serving, count it against its
                // health.
                None => (LaneOutcome::Exhausted, None),
            },
        };
        self.health.lock().observe(mesh_id, outcome, report);
    }

    /// One fairness round over this shard's own drained groups: take at
    /// most one `max_batch`-sized chunk from every non-empty group, in
    /// first-seen group order. Returns whether any work was served.
    fn serve_round<R>(
        &mut self,
        groups: &mut [GroupQueue<R>],
        req_id: fn(&R) -> u64,
        solve_single: fn(&BatchSolver, &R) -> Result<SolveResponse>,
        solve_batch: fn(&BatchSolver, &[R]) -> Vec<Result<SolveResponse>>,
    ) -> bool {
        let max_batch = self.max_batch.max(1);
        let mut any = false;
        let home = self.idx;
        for g in groups.iter_mut() {
            if g.items.is_empty() {
                continue;
            }
            any = true;
            let take = g.items.len().min(max_batch);
            let chunk: Vec<(R, Reply)> = g.items.drain(..take).collect();
            self.serve_chunk(home, g.mesh_id, chunk, g.singleton, req_id, solve_single, solve_batch);
        }
        any
    }

    /// Serve one chunk of a homogeneous `(mesh_id, kind)` group against
    /// the registry slice of shard `home` (own dispatch: `home == idx`;
    /// stolen group: the victim). The scalar path runs only for a true
    /// singleton group; everything else goes through the batched
    /// dispatch. A panic while solving answers the chunk's requests with
    /// errors and keeps the worker alive.
    ///
    /// Drain-time breaker check: a chunk whose mesh breaker is (still)
    /// Open — stragglers queued before the trip — is answered `Unhealthy`
    /// here instead of occupying a dispatch slot, counted under the shed
    /// counter like a submit-time shed (not a failure, not observed).
    #[allow(clippy::too_many_arguments)]
    fn serve_chunk<R>(
        &mut self,
        home: usize,
        mesh_id: u64,
        chunk: Vec<(R, Reply)>,
        singleton: bool,
        req_id: fn(&R) -> u64,
        solve_single: fn(&BatchSolver, &R) -> Result<SolveResponse>,
        solve_batch: fn(&BatchSolver, &[R]) -> Vec<Result<SolveResponse>>,
    ) {
        if self.health.enabled.load(Ordering::Relaxed) {
            let retry = {
                let mut reg = self.health.lock();
                let retry = reg.shed_at_drain(mesh_id);
                if retry.is_some() {
                    reg.note_shed(chunk.len() as u64);
                }
                retry
            };
            if let Some(retry_after_ms) = retry {
                self.shards[home].shed.fetch_add(chunk.len() as u64, Ordering::Relaxed);
                for (req, reply) in chunk {
                    let err = SolveError::Unhealthy {
                        id: req_id(&req),
                        mesh_id,
                        retry_after_ms,
                    };
                    reply.send(Err(err.into()));
                }
                return;
            }
        }
        let mut failed = 0u64;
        let looked_up = {
            let mut reg = self.shards[home].registry();
            let registered = reg.contains_mesh(mesh_id);
            (reg.solver_for(mesh_id), registered)
        };
        match looked_up {
            (Err(msg), registered) => {
                failed = chunk.len() as u64;
                // A failed state build for a *registered* mesh counts
                // against its health (it cannot serve); unregistered keys
                // are caller errors, not mesh sickness, and must not grow
                // the health registry.
                for (req, reply) in chunk {
                    let res = Err(anyhow!("request {}: {msg}", req_id(&req)));
                    if registered {
                        self.observe_health(mesh_id, &res);
                    }
                    reply.send(res);
                }
            }
            (Ok(solver), _) => {
                let solver = &*solver;
                let (reqs, replies): (Vec<R>, Vec<Reply>) = chunk.into_iter().unzip();
                let results = catch_unwind(AssertUnwindSafe(|| {
                    if singleton {
                        vec![solve_single(solver, &reqs[0])]
                    } else {
                        solve_batch(solver, &reqs)
                    }
                }))
                .unwrap_or_else(|p| {
                    let m = panic_msg(&*p);
                    reqs.iter()
                        .map(|r| {
                            Err(anyhow!("solve panicked serving request {}: {m}", req_id(r)))
                        })
                        .collect()
                });
                for (res, reply) in results.into_iter().zip(replies) {
                    if let Err(e) = &res {
                        failed += 1;
                        if matches!(
                            e.downcast_ref::<SolveError>(),
                            Some(SolveError::Expired { .. })
                        ) {
                            self.my().expired.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    self.observe_health(mesh_id, &res);
                    reply.send(res);
                }
            }
        }
        self.my().failed.fetch_add(failed, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn test_worker(shards: Arc<Vec<ShardHandle>>) -> ShardWorker {
        ShardWorker::new(
            0,
            shards,
            8,
            true,
            Arc::new(Admission::default()),
            Arc::new(HealthShared::new()),
            Arc::new(SupervisionShared::new()),
        )
    }

    fn queued(mesh_id: u64, n: usize) -> Vec<(Req, Reply)> {
        (0..n)
            .map(|i| {
                let (tx, _rx) = mpsc::channel();
                // The receiver is dropped: these requests are only ever
                // scanned/extracted, never answered.
                std::mem::forget(_rx);
                (
                    Req::Fixed(SolveRequest::on_mesh(i as u64, mesh_id, vec![0.0])),
                    Reply::new(tx),
                )
            })
            .collect()
    }

    /// The steal ranking weighs queue age (first-seen position) against
    /// hotness: an older group beats a slightly hotter younger one, where
    /// the pre-ranking rule (hottest-first) picked the younger.
    #[test]
    fn steal_ranking_weighs_age_against_hotness() {
        let shards = Arc::new(vec![
            ShardHandle::new(SolverConfig::default(), 0),
            ShardHandle::new(SolverConfig::default(), 0),
        ]);
        let w = test_worker(Arc::clone(&shards));
        w.admission.depth.store(100, Ordering::Relaxed);
        shards[1].depth.store(5, Ordering::Relaxed);
        // Mesh 10 queued first (older), 2 requests; mesh 20 second, 3
        // requests. Uncalibrated costs (no built states) default to 1.0,
        // so scores are 2·1·2 = 4 (mesh 10) vs 3·1·1 = 3 (mesh 20):
        // age wins. Hottest-first would have stolen mesh 20.
        let mut burst = queued(10, 2);
        burst.extend(queued(20, 3));
        shards[1].queue.push(Msg::Many(burst)).unwrap();
        let stolen = w.try_steal().expect("a queued group must be stolen");
        assert_eq!(stolen.mesh_id, 10, "older group must win the ranking");
        assert_eq!(stolen.items.len(), 2, "the WHOLE group, never a split");
        assert_eq!(stolen.victim, 1);
        assert_eq!(w.admission.depth.load(Ordering::Relaxed), 98);
        assert_eq!(shards[1].depth.load(Ordering::Relaxed), 3);
    }

    /// Calibrated per-rung cost estimates dominate the ranking: a colder
    /// but much more expensive group is stolen first (moving it relieves
    /// the victim of more work per request).
    #[test]
    fn steal_ranking_weighs_estimated_group_cost() {
        let mesh = crate::mesh::structured::unit_square_tri(3);
        let shards = Arc::new(vec![
            ShardHandle::new(SolverConfig::default(), 0),
            ShardHandle::new(SolverConfig::default(), 0),
        ]);
        let w = test_worker(Arc::clone(&shards));
        w.admission.depth.store(100, Ordering::Relaxed);
        shards[1].depth.store(5, Ordering::Relaxed);
        {
            let mut reg = shards[1].registry();
            reg.register(10, mesh.clone());
            reg.register(20, mesh);
            // Build both states and calibrate: mesh 20 is 10× the cost.
            reg.solver_for(10).unwrap().session().set_cost_ms_per_iter(1.0);
            reg.solver_for(20).unwrap().session().set_cost_ms_per_iter(10.0);
        }
        // Mesh 10: older AND hotter (3 vs 2), scores 3·1·2 = 6 — but
        // mesh 20's cost estimate lifts it to 2·10·1 = 20.
        let mut burst = queued(10, 3);
        burst.extend(queued(20, 2));
        shards[1].queue.push(Msg::Many(burst)).unwrap();
        let stolen = w.try_steal().expect("a queued group must be stolen");
        assert_eq!(stolen.mesh_id, 20, "cost estimate must dominate");
        assert_eq!(stolen.items.len(), 2);
    }
}
