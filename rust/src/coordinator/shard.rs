//! The per-shard worker of the sharded batch server.
//!
//! Each shard owns its slice of the `mesh_id → Arc<BatchSolver>` registry
//! (meshes are homed on exactly one shard by the router's stable hash)
//! and its own bounded queue, and drains it with the same continuous-
//! batching semantics as the single-worker server: block for the first
//! message, opportunistically drain up to `max_batch` more without
//! blocking, group the drained requests by `(mesh_id, kind)`, and serve
//! the groups round-robin in `max_batch`-sized chunks.
//!
//! Work stealing: when stealing is enabled an *idle* shard (own queue
//! empty after a short park) scans its siblings' queues and steals the
//! hottest still-queued `(mesh_id, kind)` group — always the WHOLE group,
//! never a split, so a stolen burst is still served by batched dispatch
//! and every lane stays bitwise identical to the scalar oracle. The thief
//! serves the group against the victim's registry slice (the victim's
//! `Arc<BatchSolver>` is cloned, not rebuilt), so per-mesh state —
//! sessions, LRU accounting, dispatch counters — stays homed on one
//! shard. Queue and registry locks are never held together across
//! shards, and each serve path locks exactly one registry at a time, so
//! there is no lock-order cycle.
//!
//! Threading: shard workers do not solve on threads of their own — every
//! assembly/solve they dispatch lands in the one global `TG_THREADS`
//! pool (`util::threadpool`), whose submission gate serializes
//! concurrent top-level submitters. N shards therefore never
//! oversubscribe the configured core budget; they overlap their
//! per-request bookkeeping and queueing, and pipeline into the pool.

use std::collections::{HashMap, HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::mesh::Mesh;
use crate::session::health::{HealthConfig, HealthRegistry, LaneOutcome};
use crate::solver::SolverConfig;

use super::api::{CoordinatorStats, SolveError, SolveRequest, SolveResponse, VarCoeffRequest};
use super::batcher::BatchSolver;

pub(super) type Reply = Sender<Result<SolveResponse>>;

/// A queued request of either kind.
pub(super) enum Req {
    Fixed(SolveRequest),
    Var(VarCoeffRequest),
}

/// Request kind discriminant: groups are homogeneous in `(mesh_id, kind)`
/// and stealing moves whole groups, so the kind is part of the group key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub(super) enum ReqKind {
    Fixed,
    Var,
}

impl Req {
    pub(super) fn id(&self) -> u64 {
        match self {
            Req::Fixed(r) => r.id,
            Req::Var(r) => r.id,
        }
    }

    pub(super) fn mesh_id(&self) -> u64 {
        match self {
            Req::Fixed(r) => r.mesh_id,
            Req::Var(r) => r.mesh_id,
        }
    }

    pub(super) fn deadline(&self) -> Option<Instant> {
        match self {
            Req::Fixed(r) => r.deadline,
            Req::Var(r) => r.deadline,
        }
    }

    fn kind(&self) -> ReqKind {
        match self {
            Req::Fixed(_) => ReqKind::Fixed,
            Req::Var(_) => ReqKind::Var,
        }
    }
}

pub(super) enum Msg {
    /// One or more requests submitted together: a burst for one shard
    /// arrives as one queue entry, so the whole per-shard burst is
    /// guaranteed to land in a single drain cycle.
    Many(Vec<(Req, Reply)>),
    /// Register (or replace) a mesh topology on this shard's registry
    /// slice; acknowledged once the worker has installed it.
    Register(u64, Box<Mesh>, Sender<()>),
    /// Ask this shard for its PARTIAL stats (worker-local + registry
    /// counters); the router folds the partials and adds the globals.
    Stats(Sender<CoordinatorStats>),
    Shutdown,
}

/// Admission bookkeeping shared between the router and all shards. The
/// per-shard queue depth lives on each [`ShardHandle`]; only the bound
/// itself (and submit-time expiry, which never reaches a shard) is
/// global: the bound applies to EACH shard's depth, so `num_shards = 1`
/// keeps the exact single-queue semantics.
#[derive(Default)]
pub(super) struct Admission {
    /// Depth bound currently in force per shard (0 = unbounded, the
    /// default). Adaptive shedding may hold this at a tightened fraction
    /// of `base_max_queue` while sick traffic dominates.
    pub(super) max_queue: AtomicUsize,
    /// The caller-configured bound (`BatchServer::set_max_queue`) that
    /// the tightened bound is derived from and relaxes back to.
    pub(super) base_max_queue: AtomicUsize,
    /// Requests whose deadline had already passed at submission —
    /// answered `SolveError::Expired` synchronously, never enqueued.
    /// Folded into both `expired_requests` and `failed_requests`.
    pub(super) expired_at_submit: AtomicU64,
}

/// Health state shared between the router (synchronous breaker sheds)
/// and every shard worker (outcome observation, drain-time sheds,
/// adaptive retuning). ONE registry for the whole server — probe-group
/// bookkeeping is per mesh, not per shard, so the one-probe-group
/// invariant holds even when a sick mesh's traffic is served by a thief.
pub(super) struct HealthShared {
    pub(super) enabled: AtomicBool,
    registry: Mutex<HealthRegistry>,
}

impl HealthShared {
    pub(super) fn new() -> HealthShared {
        HealthShared {
            enabled: AtomicBool::new(false),
            registry: Mutex::new(HealthRegistry::new(HealthConfig::disabled())),
        }
    }

    /// Lock the registry, surviving a poisoned mutex (a panic while a
    /// health call was in flight must not take the serving path down).
    pub(super) fn lock(&self) -> MutexGuard<'_, HealthRegistry> {
        self.registry.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// One shard's queue: a mutex-guarded deque + condvar instead of mpsc so
/// that sibling shards can scan and extract whole groups (stealing needs
/// multi-consumer access mpsc cannot give).
pub(super) struct ShardQueue {
    inner: Mutex<VecDeque<Msg>>,
    ready: Condvar,
    /// Set by shutdown: further submissions are refused (the caller
    /// answers "worker is gone") while the internal Shutdown message
    /// still goes through.
    closed: AtomicBool,
}

impl ShardQueue {
    fn new() -> ShardQueue {
        ShardQueue {
            inner: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            closed: AtomicBool::new(false),
        }
    }

    fn lock(&self) -> MutexGuard<'_, VecDeque<Msg>> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Enqueue a message; `Err(msg)` once the queue is closed (shutdown
    /// begun) so the submitter can answer instead of parking clients.
    pub(super) fn push(&self, msg: Msg) -> std::result::Result<(), Msg> {
        if self.closed.load(Ordering::Acquire) {
            return Err(msg);
        }
        self.lock().push_back(msg);
        self.ready.notify_one();
        Ok(())
    }

    /// Close the queue and enqueue the worker's Shutdown (bypassing the
    /// closed check). Messages racing past the closed check may land
    /// behind the Shutdown; the router drains and answers them after
    /// joining the worker.
    pub(super) fn close_and_shutdown(&self) {
        self.closed.store(true, Ordering::Release);
        self.lock().push_back(Msg::Shutdown);
        self.ready.notify_one();
    }

    /// Drain everything still queued (post-join leftover cleanup).
    pub(super) fn drain(&self) -> Vec<Msg> {
        self.lock().drain(..).collect()
    }
}

/// Shared per-shard state: the queue, live admission/steal counters read
/// by `per_shard()` without a round-trip, and the shard's registry slice
/// (behind a mutex so a thief can borrow a victim's built solvers).
pub(super) struct ShardHandle {
    pub(super) queue: ShardQueue,
    /// Requests admitted to this shard but not yet drained.
    pub(super) depth: AtomicUsize,
    /// High-water mark of `depth` since server start.
    pub(super) high_water: AtomicU64,
    /// Requests overload-rejected at submission for this shard.
    pub(super) rejected: AtomicU64,
    /// Breaker sheds attributed to meshes homed on this shard (submit-
    /// time and drain-time).
    pub(super) shed: AtomicU64,
    /// Whole groups THIS shard stole from siblings.
    pub(super) stolen: AtomicU64,
    registry: Mutex<Registry>,
}

impl ShardHandle {
    pub(super) fn new(config: SolverConfig, max_states: usize) -> ShardHandle {
        ShardHandle {
            queue: ShardQueue::new(),
            depth: AtomicUsize::new(0),
            high_water: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            stolen: AtomicU64::new(0),
            registry: Mutex::new(Registry::new(config, max_states)),
        }
    }

    pub(super) fn registry(&self) -> MutexGuard<'_, Registry> {
        self.registry.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// A registry slot: the built (or failed) per-mesh state plus its
/// last-touch tick for LRU eviction. Built states sit behind an `Arc` so
/// a thief shard can hold a group's solver across a serve without
/// blocking registry mutation.
struct RegistryEntry {
    /// A failed build (panicking setup of a *registered* mesh) is memoized
    /// too, so sustained traffic for a bad mesh pays the setup attempt
    /// once, not per drain cycle (until the slot is evicted). Unregistered
    /// keys never get a slot at all.
    state: std::result::Result<Arc<BatchSolver>, String>,
    last_used: u64,
}

/// One shard's slice of the mesh/solver registry: the meshes homed on
/// this shard and their lazily built per-mesh states, LRU-capped at
/// `max_states` (0 = unbounded; the cap is PER SHARD). Lives behind the
/// shard handle's mutex so that work stealing can clone a victim's
/// `Arc<BatchSolver>` instead of rebuilding it.
pub(super) struct Registry {
    meshes: HashMap<u64, Mesh>,
    /// Lazily built per-mesh state.
    states: HashMap<u64, RegistryEntry>,
    config: SolverConfig,
    max_states: usize,
    /// Monotone access clock driving the LRU order.
    tick: u64,
    evictions: u64,
    rebuilds: u64,
    /// Keys that were evicted at least once — a rebuild of one of these
    /// counts as registry churn (`state_rebuilds`).
    evicted_keys: HashSet<u64>,
    /// Dispatch counters of evicted solvers, folded in so the aggregate
    /// stats stay monotone across evictions.
    retired_batched: u64,
    retired_scalar: u64,
    /// Escalation-ladder counters of evicted solvers (same fold).
    retired_retried: u64,
    retired_rescued: u64,
    /// Budget-skipped ladder rungs of evicted solvers (same fold).
    retired_skipped: u64,
}

impl Registry {
    fn new(config: SolverConfig, max_states: usize) -> Registry {
        Registry {
            meshes: HashMap::new(),
            states: HashMap::new(),
            config,
            max_states,
            tick: 0,
            evictions: 0,
            rebuilds: 0,
            evicted_keys: HashSet::new(),
            retired_batched: 0,
            retired_scalar: 0,
            retired_retried: 0,
            retired_rescued: 0,
            retired_skipped: 0,
        }
    }

    /// Install (or replace) a mesh topology. Replacing a registered id
    /// retires any built state for the old topology — counted as an
    /// eviction, dispatch counters folded into the retired totals — so
    /// the next request builds against the new mesh (the AMR
    /// re-registration path).
    pub(super) fn register(&mut self, mesh_id: u64, mesh: Mesh) {
        if let Some(entry) = self.states.remove(&mesh_id) {
            self.evictions += 1;
            self.evicted_keys.insert(mesh_id);
            if let Ok(solver) = entry.state {
                self.retire(&solver);
            }
        }
        self.meshes.insert(mesh_id, mesh);
    }

    /// Whether `mesh_id` is registered on this shard (independent of
    /// whether its state is built).
    fn contains_mesh(&self, mesh_id: u64) -> bool {
        self.meshes.contains_key(&mesh_id)
    }

    /// Fold an evicted solver's counters into the retired totals so the
    /// aggregate stats stay monotone across evictions.
    fn retire(&mut self, solver: &BatchSolver) {
        self.retired_batched += solver.n_batched_solves();
        self.retired_scalar += solver.n_scalar_solves();
        self.retired_retried += solver.n_retried_lanes();
        self.retired_rescued += solver.n_rescued_lanes();
        self.retired_skipped += solver.n_skipped_rungs();
    }

    /// Look up (or lazily build, memoizing success AND failure) the
    /// amortized state for a mesh key, touching its LRU clock. When the
    /// registry is at its cap, the least-recently-used slot is evicted
    /// before the new build (its dispatch counters fold into the retired
    /// totals so aggregate stats stay monotone).
    fn solver_for(&mut self, mesh_id: u64) -> std::result::Result<Arc<BatchSolver>, String> {
        self.tick += 1;
        let tick = self.tick;
        if !self.states.contains_key(&mesh_id) {
            // Unregistered keys never occupy a registry slot: a hostile
            // stream of bogus mesh_ids must not evict built states or grow
            // the eviction bookkeeping (the error string is cheap to
            // rebuild per request).
            if !self.meshes.contains_key(&mesh_id) {
                return Err(format!("no mesh registered under mesh_id {mesh_id}"));
            }
            if self.max_states > 0 && self.states.len() >= self.max_states {
                // LRU victim: stalest tick, smallest key on (never-occurring
                // within one shard) ties — fully deterministic.
                if let Some((&victim, _)) =
                    self.states.iter().min_by_key(|&(k, e)| (e.last_used, *k))
                {
                    if let Some(entry) = self.states.remove(&victim) {
                        self.evictions += 1;
                        self.evicted_keys.insert(victim);
                        if let Ok(solver) = entry.state {
                            self.retire(&solver);
                        }
                    }
                }
            }
            if self.evicted_keys.contains(&mesh_id) {
                self.rebuilds += 1;
            }
            let config = self.config;
            let mesh = self.meshes.get(&mesh_id).expect("registration checked above");
            let built =
                catch_unwind(AssertUnwindSafe(|| Arc::new(BatchSolver::new(mesh, config))))
                    .map_err(|p| {
                        format!(
                            "building state for mesh_id {mesh_id} panicked: {}",
                            panic_msg(&*p)
                        )
                    });
            self.states.insert(mesh_id, RegistryEntry { state: built, last_used: tick });
        }
        let entry = self.states.get_mut(&mesh_id).expect("slot just ensured");
        entry.last_used = tick;
        entry.state.as_ref().map(Arc::clone).map_err(|e| e.clone())
    }

    /// Fold this slice's registry counters into a (partial) stats value.
    fn stats_into(&self, s: &mut CoordinatorStats) {
        s.evicted_states += self.evictions;
        s.state_rebuilds += self.rebuilds;
        s.batched_solves += self.retired_batched;
        s.scalar_solves += self.retired_scalar;
        s.retried_lanes += self.retired_retried;
        s.rescued_lanes += self.retired_rescued;
        s.skipped_rungs += self.retired_skipped;
        for entry in self.states.values() {
            if let Ok(solver) = &entry.state {
                s.meshes_built += 1;
                s.batched_solves += solver.n_batched_solves();
                s.scalar_solves += solver.n_scalar_solves();
                s.retried_lanes += solver.n_retried_lanes();
                s.rescued_lanes += solver.n_rescued_lanes();
                s.skipped_rungs += solver.n_skipped_rungs();
            }
        }
    }
}

/// One `(mesh_id, kind)` group's still-unserved requests within a drain
/// cycle, consumed chunk by chunk by the round-robin scheduler.
struct GroupQueue<R> {
    mesh_id: u64,
    items: Vec<(R, Reply)>,
    /// Whether the group *arrived* as a singleton (scalar dispatch); a
    /// trailing chunk of 1 carved from a larger group still dispatches
    /// batched, keeping the batched/scalar counters an exact regression
    /// signal.
    singleton: bool,
}

/// A whole `(mesh_id, kind)` group extracted from a sibling's queue.
struct Stolen {
    /// The shard the group was stolen from — its registry slice homes the
    /// mesh, so the thief serves against it.
    victim: usize,
    mesh_id: u64,
    kind: ReqKind,
    items: Vec<(Req, Reply)>,
}

/// Bucket mesh-homogeneous items by mesh key, preserving arrival order
/// within each bucket (first-seen key order across buckets).
fn group_by_mesh<R>(items: Vec<(R, Reply)>, mesh_id: fn(&R) -> u64) -> Vec<GroupQueue<R>> {
    let mut groups: Vec<GroupQueue<R>> = Vec::new();
    let mut index: HashMap<u64, usize> = HashMap::new();
    for (req, reply) in items {
        let key = mesh_id(&req);
        let gi = *index.entry(key).or_insert_with(|| {
            groups.push(GroupQueue {
                mesh_id: key,
                items: Vec::new(),
                singleton: false,
            });
            groups.len() - 1
        });
        groups[gi].items.push((req, reply));
    }
    for g in &mut groups {
        g.singleton = g.items.len() == 1;
    }
    groups
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

/// How long an idle steal-enabled shard parks on its own condvar before
/// scanning siblings. Short enough that a hot mesh's backlog is picked up
/// within a drain cycle; long enough that idle shards cost ~nothing.
const STEAL_PARK: Duration = Duration::from_millis(1);

/// The worker loop state of one shard.
pub(super) struct ShardWorker {
    pub(super) idx: usize,
    pub(super) shards: Arc<Vec<ShardHandle>>,
    pub(super) max_batch: usize,
    pub(super) steal: bool,
    pub(super) failed: u64,
    /// Requests answered with `SolveError::Expired` — deadline passed
    /// while queued, answered without solving.
    pub(super) expired: u64,
    /// Requests drained, summed over drain cycles (the queue-depth
    /// integral: `queued_requests / drain_cycles` is the mean drained
    /// batch size under load).
    pub(super) queued_requests: u64,
    /// Non-empty drain cycles (own + stolen) completed.
    pub(super) drain_cycles: u64,
    /// `(mesh_id, kind)` groups formed across all drain cycles.
    pub(super) dispatch_groups: u64,
    /// Stats queries seen in the current drain cycle — answered only
    /// AFTER the cycle's dispatch, so a snapshot reflects every request
    /// that was enqueued on THIS shard ahead of it (FIFO per shard).
    pub(super) stats_waiters: Vec<Sender<CoordinatorStats>>,
    pub(super) admission: Arc<Admission>,
    pub(super) health: Arc<HealthShared>,
}

enum Popped {
    Msg(Msg),
    /// A stolen group was served inside the wait; loop again.
    ServedStolen,
}

impl ShardWorker {
    pub(super) fn new(
        idx: usize,
        shards: Arc<Vec<ShardHandle>>,
        max_batch: usize,
        steal: bool,
        admission: Arc<Admission>,
        health: Arc<HealthShared>,
    ) -> ShardWorker {
        ShardWorker {
            idx,
            shards,
            max_batch,
            steal,
            failed: 0,
            expired: 0,
            queued_requests: 0,
            drain_cycles: 0,
            dispatch_groups: 0,
            stats_waiters: Vec::new(),
            admission,
            health,
        }
    }

    fn my(&self) -> &ShardHandle {
        &self.shards[self.idx]
    }

    /// The drain loop: block for the first message (or steal while
    /// idle), opportunistically drain more without blocking, dispatch.
    pub(super) fn run(mut self) {
        let mut pending: Vec<(Req, Reply)> = Vec::new();
        loop {
            let msg = match self.next_msg() {
                Popped::Msg(m) => m,
                Popped::ServedStolen => continue,
            };
            if !self.accept(msg, &mut pending) {
                self.dispatch(std::mem::take(&mut pending));
                self.flush_stats();
                return;
            }
            while pending.len() < self.max_batch.max(1) {
                let next = self.my().queue.lock().pop_front();
                match next {
                    Some(m) => {
                        if !self.accept(m, &mut pending) {
                            self.dispatch(std::mem::take(&mut pending));
                            self.flush_stats();
                            return;
                        }
                    }
                    None => break,
                }
            }
            self.dispatch(std::mem::take(&mut pending));
            self.flush_stats();
        }
    }

    /// Pop the next message from the own queue, blocking while empty.
    /// With stealing enabled the block is a short park: each timeout the
    /// shard scans its siblings and serves a stolen group in place.
    fn next_msg(&mut self) -> Popped {
        loop {
            let mut q = self.my().queue.lock();
            if let Some(m) = q.pop_front() {
                return Popped::Msg(m);
            }
            if !self.steal {
                while q.is_empty() {
                    q = self
                        .my()
                        .queue
                        .ready
                        .wait(q)
                        .unwrap_or_else(|e| e.into_inner());
                }
                continue;
            }
            let (guard, _) = self
                .my()
                .queue
                .ready
                .wait_timeout(q, STEAL_PARK)
                .unwrap_or_else(|e| e.into_inner());
            if !guard.is_empty() {
                continue;
            }
            drop(guard);
            if let Some(stolen) = self.try_steal() {
                self.serve_stolen(stolen);
                return Popped::ServedStolen;
            }
        }
    }

    /// Scan sibling queues (rotating from the next index for fairness)
    /// and extract the hottest still-queued `(mesh_id, kind)` group —
    /// the WHOLE group, merged across queued bursts, exactly what the
    /// victim would have regrouped in one drain cycle. Control messages
    /// (Register/Stats/Shutdown) are never touched or reordered.
    fn try_steal(&self) -> Option<Stolen> {
        let n = self.shards.len();
        for off in 1..n {
            let v = (self.idx + off) % n;
            let mut q = self.shards[v].queue.lock();
            // Tally queued groups in first-seen order.
            let mut counts: Vec<((u64, ReqKind), usize)> = Vec::new();
            for msg in q.iter() {
                if let Msg::Many(items) = msg {
                    for (req, _) in items {
                        let key = (req.mesh_id(), req.kind());
                        match counts.iter_mut().find(|(k, _)| *k == key) {
                            Some((_, c)) => *c += 1,
                            None => counts.push((key, 1)),
                        }
                    }
                }
            }
            // Hottest group; first-seen wins ties (deterministic).
            let mut best: Option<((u64, ReqKind), usize)> = None;
            for &(key, c) in &counts {
                let hotter = match best {
                    Some((_, bc)) => c > bc,
                    None => true,
                };
                if hotter {
                    best = Some((key, c));
                }
            }
            let Some(((mesh_id, kind), _)) = best else {
                continue;
            };
            let mut items = Vec::new();
            for msg in q.iter_mut() {
                if let Msg::Many(list) = msg {
                    let mut keep = Vec::with_capacity(list.len());
                    for it in list.drain(..) {
                        if it.0.mesh_id() == mesh_id && it.0.kind() == kind {
                            items.push(it);
                        } else {
                            keep.push(it);
                        }
                    }
                    *list = keep;
                }
            }
            q.retain(|m| !matches!(m, Msg::Many(v) if v.is_empty()));
            drop(q);
            self.shards[v].depth.fetch_sub(items.len(), Ordering::Relaxed);
            return Some(Stolen { victim: v, mesh_id, kind, items });
        }
        None
    }

    /// Serve a stolen group whole (in `max_batch`-sized chunks) against
    /// the VICTIM's registry slice — the stolen mesh's solver is cloned
    /// out of the victim's registry, never rebuilt on the thief.
    fn serve_stolen(&mut self, s: Stolen) {
        if s.items.is_empty() {
            return;
        }
        self.my().stolen.fetch_add(1, Ordering::Relaxed);
        self.drain_cycles += 1;
        self.queued_requests += s.items.len() as u64;
        self.dispatch_groups += 1;
        let singleton = s.items.len() == 1;
        match s.kind {
            ReqKind::Fixed => {
                let items: Vec<(SolveRequest, Reply)> = s
                    .items
                    .into_iter()
                    .map(|(req, reply)| match req {
                        Req::Fixed(r) => (r, reply),
                        Req::Var(_) => unreachable!("kind-homogeneous group"),
                    })
                    .collect();
                self.serve_group(
                    s.victim,
                    s.mesh_id,
                    items,
                    singleton,
                    |r: &SolveRequest| r.id,
                    BatchSolver::solve_one,
                    BatchSolver::solve_batch_each,
                );
            }
            ReqKind::Var => {
                let items: Vec<(VarCoeffRequest, Reply)> = s
                    .items
                    .into_iter()
                    .map(|(req, reply)| match req {
                        Req::Var(r) => (r, reply),
                        Req::Fixed(_) => unreachable!("kind-homogeneous group"),
                    })
                    .collect();
                self.serve_group(
                    s.victim,
                    s.mesh_id,
                    items,
                    singleton,
                    |r: &VarCoeffRequest| r.id,
                    BatchSolver::solve_varcoeff_one,
                    BatchSolver::solve_varcoeff_batch_each,
                );
            }
        }
        self.retune_admission();
    }

    /// Serve one whole group in `max_batch`-sized chunks (what the
    /// round-robin scheduler does when it is the only non-empty group).
    #[allow(clippy::too_many_arguments)]
    fn serve_group<R>(
        &mut self,
        home: usize,
        mesh_id: u64,
        mut items: Vec<(R, Reply)>,
        singleton: bool,
        req_id: fn(&R) -> u64,
        solve_single: fn(&BatchSolver, &R) -> Result<SolveResponse>,
        solve_batch: fn(&BatchSolver, &[R]) -> Vec<Result<SolveResponse>>,
    ) {
        let max_batch = self.max_batch.max(1);
        while !items.is_empty() {
            let take = items.len().min(max_batch);
            let rest = items.split_off(take);
            let chunk = std::mem::replace(&mut items, rest);
            self.serve_chunk(home, mesh_id, chunk, singleton, req_id, solve_single, solve_batch);
        }
    }

    /// Returns `false` on shutdown.
    fn accept(&mut self, msg: Msg, pending: &mut Vec<(Req, Reply)>) -> bool {
        match msg {
            Msg::Many(items) => pending.extend(items),
            Msg::Register(mesh_id, mesh, ack) => {
                self.my().registry().register(mesh_id, *mesh);
                let _ = ack.send(());
            }
            Msg::Stats(tx) => self.stats_waiters.push(tx),
            Msg::Shutdown => return false,
        }
        true
    }

    /// Answer the stats queries collected this cycle (post-dispatch)
    /// with this shard's PARTIAL counters; the router folds partials
    /// across shards and adds the router-owned globals (admission,
    /// health, per-shard handle counters).
    fn flush_stats(&mut self) {
        if self.stats_waiters.is_empty() {
            return;
        }
        let snapshot = self.stats();
        for tx in self.stats_waiters.drain(..) {
            let _ = tx.send(snapshot);
        }
    }

    fn stats(&self) -> CoordinatorStats {
        let mut s = CoordinatorStats {
            failed_requests: self.failed,
            queued_requests: self.queued_requests,
            drain_cycles: self.drain_cycles,
            dispatch_groups: self.dispatch_groups,
            expired_requests: self.expired,
            ..CoordinatorStats::default()
        };
        self.my().registry().stats_into(&mut s);
        s
    }

    /// Group the drained queue by `(mesh_id, kind)` — arrival order is
    /// preserved within each group — and serve the groups round-robin in
    /// `max_batch`-sized chunks until all are drained: every group gets
    /// one chunk per round, so a large group cannot starve the others
    /// past its first chunk.
    fn dispatch(&mut self, pending: Vec<(Req, Reply)>) {
        #[cfg(feature = "fault-inject")]
        if let Some(ms) = crate::util::faults::stall_ms(crate::util::faults::SERVER_STALL) {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
        self.my().depth.fetch_sub(pending.len(), Ordering::Relaxed);
        if pending.is_empty() {
            return;
        }
        self.drain_cycles += 1;
        self.queued_requests += pending.len() as u64;
        let mut fixed_items = Vec::new();
        let mut var_items = Vec::new();
        for (req, reply) in pending {
            match req {
                Req::Fixed(q) => fixed_items.push((q, reply)),
                Req::Var(q) => var_items.push((q, reply)),
            }
        }
        let mut fixed = group_by_mesh(fixed_items, |r| r.mesh_id);
        let mut var = group_by_mesh(var_items, |r| r.mesh_id);
        self.dispatch_groups += (fixed.len() + var.len()) as u64;
        loop {
            let served_fixed = self.serve_round(
                &mut fixed,
                |r: &SolveRequest| r.id,
                BatchSolver::solve_one,
                BatchSolver::solve_batch_each,
            );
            let served_var = self.serve_round(
                &mut var,
                |r: &VarCoeffRequest| r.id,
                BatchSolver::solve_varcoeff_one,
                BatchSolver::solve_varcoeff_batch_each,
            );
            if !served_fixed && !served_var {
                break;
            }
        }
        self.retune_admission();
    }

    /// After a drain cycle, retune the effective admission bound from the
    /// global sick-traffic signal: while rescued/exhausted lanes dominate
    /// recent outcomes the bound tightens to `base / tighten_divisor`
    /// (floor 1), relaxing back to the configured base on recovery. A
    /// no-op while health tracking is disabled or the base bound is 0
    /// (unbounded). Signal, registry and bound are all global, so any
    /// shard retuning is idempotent across shards.
    fn retune_admission(&mut self) {
        if !self.health.enabled.load(Ordering::Relaxed) {
            return;
        }
        let base = self.admission.base_max_queue.load(Ordering::Relaxed);
        let mut reg = self.health.lock();
        let tight = reg.update_tightened();
        let cfg = reg.config();
        let effective = if tight && base > 0 {
            (base / cfg.tighten_divisor.max(1)).max(1)
        } else {
            base
        };
        self.admission.max_queue.store(effective, Ordering::Relaxed);
    }

    /// Feed one served outcome into the health registry: a clean solve is
    /// `Ok`, a ladder-recovered one `Rescued`, a classified solver failure
    /// (or an unclassifiable panic / state-build failure) `Exhausted`.
    /// Validation and expiry answers say nothing about mesh health and
    /// are not observed. A no-op while health tracking is disabled.
    fn observe_health(&mut self, mesh_id: u64, res: &Result<SolveResponse>) {
        if !self.health.enabled.load(Ordering::Relaxed) {
            return;
        }
        let (outcome, report) = match res {
            Ok(resp) => match &resp.escalation {
                Some(rep) => (LaneOutcome::Rescued, Some(rep)),
                None => (LaneOutcome::Ok, None),
            },
            Err(e) => match e.downcast_ref::<SolveError>() {
                Some(SolveError::Solver { escalation, .. }) => {
                    (LaneOutcome::Exhausted, escalation.as_ref())
                }
                Some(
                    SolveError::Invalid { .. }
                    | SolveError::Expired { .. }
                    | SolveError::Overloaded { .. }
                    | SolveError::Unhealthy { .. },
                ) => return,
                // No typed error: a recovered panic or a failed state
                // build — the mesh is not serving, count it against its
                // health.
                None => (LaneOutcome::Exhausted, None),
            },
        };
        self.health.lock().observe(mesh_id, outcome, report);
    }

    /// One fairness round over this shard's own drained groups: take at
    /// most one `max_batch`-sized chunk from every non-empty group, in
    /// first-seen group order. Returns whether any work was served.
    fn serve_round<R>(
        &mut self,
        groups: &mut [GroupQueue<R>],
        req_id: fn(&R) -> u64,
        solve_single: fn(&BatchSolver, &R) -> Result<SolveResponse>,
        solve_batch: fn(&BatchSolver, &[R]) -> Vec<Result<SolveResponse>>,
    ) -> bool {
        let max_batch = self.max_batch.max(1);
        let mut any = false;
        let home = self.idx;
        for g in groups.iter_mut() {
            if g.items.is_empty() {
                continue;
            }
            any = true;
            let take = g.items.len().min(max_batch);
            let chunk: Vec<(R, Reply)> = g.items.drain(..take).collect();
            self.serve_chunk(home, g.mesh_id, chunk, g.singleton, req_id, solve_single, solve_batch);
        }
        any
    }

    /// Serve one chunk of a homogeneous `(mesh_id, kind)` group against
    /// the registry slice of shard `home` (own dispatch: `home == idx`;
    /// stolen group: the victim). The scalar path runs only for a true
    /// singleton group; everything else goes through the batched
    /// dispatch. A panic while solving answers the chunk's requests with
    /// errors and keeps the worker alive.
    ///
    /// Drain-time breaker check: a chunk whose mesh breaker is (still)
    /// Open — stragglers queued before the trip — is answered `Unhealthy`
    /// here instead of occupying a dispatch slot, counted under the shed
    /// counter like a submit-time shed (not a failure, not observed).
    #[allow(clippy::too_many_arguments)]
    fn serve_chunk<R>(
        &mut self,
        home: usize,
        mesh_id: u64,
        chunk: Vec<(R, Reply)>,
        singleton: bool,
        req_id: fn(&R) -> u64,
        solve_single: fn(&BatchSolver, &R) -> Result<SolveResponse>,
        solve_batch: fn(&BatchSolver, &[R]) -> Vec<Result<SolveResponse>>,
    ) {
        if self.health.enabled.load(Ordering::Relaxed) {
            let retry = {
                let mut reg = self.health.lock();
                let retry = reg.shed_at_drain(mesh_id);
                if retry.is_some() {
                    reg.note_shed(chunk.len() as u64);
                }
                retry
            };
            if let Some(retry_after_ms) = retry {
                self.shards[home].shed.fetch_add(chunk.len() as u64, Ordering::Relaxed);
                for (req, reply) in chunk {
                    let err = SolveError::Unhealthy {
                        id: req_id(&req),
                        mesh_id,
                        retry_after_ms,
                    };
                    let _ = reply.send(Err(err.into()));
                }
                return;
            }
        }
        let mut failed = 0u64;
        let looked_up = {
            let mut reg = self.shards[home].registry();
            let registered = reg.contains_mesh(mesh_id);
            (reg.solver_for(mesh_id), registered)
        };
        match looked_up {
            (Err(msg), registered) => {
                failed = chunk.len() as u64;
                // A failed state build for a *registered* mesh counts
                // against its health (it cannot serve); unregistered keys
                // are caller errors, not mesh sickness, and must not grow
                // the health registry.
                for (req, reply) in chunk {
                    let res = Err(anyhow!("request {}: {msg}", req_id(&req)));
                    if registered {
                        self.observe_health(mesh_id, &res);
                    }
                    let _ = reply.send(res);
                }
            }
            (Ok(solver), _) => {
                let solver = &*solver;
                let (reqs, replies): (Vec<R>, Vec<Reply>) = chunk.into_iter().unzip();
                let results = catch_unwind(AssertUnwindSafe(|| {
                    if singleton {
                        vec![solve_single(solver, &reqs[0])]
                    } else {
                        solve_batch(solver, &reqs)
                    }
                }))
                .unwrap_or_else(|p| {
                    let m = panic_msg(&*p);
                    reqs.iter()
                        .map(|r| {
                            Err(anyhow!("solve panicked serving request {}: {m}", req_id(r)))
                        })
                        .collect()
                });
                for (res, reply) in results.into_iter().zip(replies) {
                    if let Err(e) = &res {
                        failed += 1;
                        if matches!(
                            e.downcast_ref::<SolveError>(),
                            Some(SolveError::Expired { .. })
                        ) {
                            self.expired += 1;
                        }
                    }
                    self.observe_health(mesh_id, &res);
                    let _ = reply.send(res);
                }
            }
        }
        self.failed += failed;
    }
}
