//! CLI dispatcher: `tensor-galerkin <command> [options]`.

use crate::util::cli::Args;

const HELP: &str = "\
tensor-galerkin — TensorGalerkin reproduction CLI

USAGE:
    tensor-galerkin <COMMAND> [OPTIONS]

COMMANDS (one per paper experiment, DESIGN.md §5):
    solve       solve a built-in PDE benchmark (Fig 2 instances)
                  --problem poisson3d|elasticity3d  --n <cells>  --vtk <path>
    fig2        solver scaling sweep (Fig 2a-b)
                  --sizes 4,8,12,16  --problem both|poisson3d|elasticity3d
    table1      neural PDE solver comparison (Table 1)
                  --adam N --lbfgs N --freqs 2,4,8 --seed S [--vtk]
    table2      physics-informed operator learning (Table 2)
                  --pde wave|ac|both --epochs N --samples N
    table3      topology-optimization timing (Table 3)
                  --iters 51 [--vtk]
    figb4       batched data-generation scaling (Fig B.4)
    figb18      data-efficiency sweep (Fig B.18)
    tableb2     PINN error/residual under refinement (Table B.2)
    tableb3     mixed-BC benchmark, circle + boomerang (Table B.3)
    help        show this message

Artifacts must exist (run `make artifacts`) for commands touching the
PJRT path; native-only commands run without them.
";

pub fn run(raw: Vec<String>) -> i32 {
    let args = Args::parse(&raw);
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        "solve" => crate::experiments::fig2::run(&args),
        "fig2" => crate::experiments::fig2::run(&args),
        "table1" => crate::experiments::table1::run(&args),
        "table2" => crate::experiments::table2::run(&args),
        "table3" => crate::experiments::table3::run(&args),
        "figb4" => crate::experiments::figb4::run(&args),
        "figb18" => crate::experiments::table2::run_figb18(&args),
        "tableb2" => crate::experiments::tableb2::run(&args),
        "tableb3" => crate::experiments::tableb3::run(&args),
        other => {
            eprintln!("unknown command {other:?}\n\n{HELP}");
            return 2;
        }
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}
