//! Shared experiment plumbing: result records written as JSON (so
//! EXPERIMENTS.md tables regenerate from raw data) + markdown helpers.

use std::io::Write as _;

use anyhow::Result;

use crate::util::json::Json;

/// A tagged experiment record appended to `target/experiments.jsonl`.
#[derive(Debug)]
pub struct ExperimentRecord {
    pub experiment: String,
    pub fields: Vec<(String, Json)>,
}

impl ExperimentRecord {
    pub fn new(experiment: &str) -> Self {
        ExperimentRecord {
            experiment: experiment.to_string(),
            fields: Vec::new(),
        }
    }

    pub fn num(mut self, key: &str, value: f64) -> Self {
        self.fields.push((key.to_string(), Json::Num(value)));
        self
    }

    pub fn str(mut self, key: &str, value: &str) -> Self {
        self.fields.push((key.to_string(), Json::Str(value.to_string())));
        self
    }

    /// Append to the experiments log.
    pub fn write(self) -> Result<()> {
        std::fs::create_dir_all("target")?;
        let mut file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open("target/experiments.jsonl")?;
        let mut pairs = vec![("experiment", Json::Str(self.experiment.clone()))];
        for (k, v) in &self.fields {
            pairs.push((k.as_str(), v.clone()));
        }
        writeln!(file, "{}", crate::util::json::obj(pairs).to_string_compact())?;
        Ok(())
    }
}

/// Render a markdown table.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push_str("| ");
    s.push_str(&headers.join(" | "));
    s.push_str(" |\n|");
    for _ in headers {
        s.push_str("---|");
    }
    s.push('\n');
    for row in rows {
        s.push_str("| ");
        s.push_str(&row.join(" | "));
        s.push_str(" |\n");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let t = markdown_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("| a | b |"));
        assert!(t.contains("| 1 | 2 |"));
    }
}
