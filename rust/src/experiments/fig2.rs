//! Fig 2 — numerical PDE solver benchmarks on 3D meshes: solve-time
//! scaling with DoFs for the 3D Poisson (unit cube) and 3D linear
//! elasticity (hollow cube) problems.
//!
//! Baselines reproduced per DESIGN.md §7:
//! * `scatter`      — classical per-element scatter-add assembly, pattern
//!                    rebuilt per solve (the FEniCS/SKFEM algorithmic core),
//! * `mapreduce`    — TensorGalerkin native Map + routing Reduce (cached
//!                    setup, like TENSORMESH CPU),
//! * `pjrt`         — TensorGalerkin with the AOT Pallas kernel on the Map
//!                    stage (TENSORMESH "GPU-style" dispatch path),
//! * `recompile`    — the JAX-FEM archetype: artifact cache cleared per
//!                    solve, so PJRT compilation lands on the hot path.
//!
//! All share BiCGSTAB + Jacobi at 1e-10 (Table B.1).

use anyhow::Result;

use crate::assembly::{scatter, AssemblyContext, BilinearForm, Coefficient, LinearForm};
use crate::bc::{condense, DirichletBc};
use crate::experiments::common::ExperimentRecord;
use crate::mesh::structured::{hollow_cube_tet, unit_cube_tet};
use crate::mesh::Mesh;
use crate::runtime::{MapKind, PjrtMapper, Runtime};
use crate::solver::{self, Method, SolverConfig};
use crate::util::cli::Args;
use crate::util::timer::time_it;

/// One measured scaling point.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub problem: String,
    pub variant: String,
    pub n_dofs: usize,
    pub n_elems: usize,
    pub assemble_s: f64,
    pub solve_s: f64,
    pub setup_s: f64,
    pub rel_residual: f64,
}

pub fn run(args: &Args) -> Result<()> {
    let sizes = args.get_usize_list("sizes", &[4, 8, 12, 16]);
    let problems: Vec<String> = match args.get_str("problem", "both").as_str() {
        "both" => vec!["poisson3d".into(), "elasticity3d".into()],
        p => vec![p.to_string()],
    };
    let runtime = Runtime::new().ok();
    if runtime.is_none() {
        crate::tg_warn!("artifacts missing: skipping pjrt/recompile variants");
    }
    let mut points = Vec::new();
    for problem in &problems {
        for &n in &sizes {
            let pts = scale_point(problem, n, runtime.as_ref())?;
            for p in &pts {
                println!(
                    "{:<12} {:<10} dofs={:<8} setup={:.3}s assemble={:.3}s solve={:.3}s res={:.2e}",
                    p.problem, p.variant, p.n_dofs, p.setup_s, p.assemble_s, p.solve_s, p.rel_residual
                );
                ExperimentRecord::new("fig2")
                    .str("problem", &p.problem)
                    .str("variant", &p.variant)
                    .num("n_dofs", p.n_dofs as f64)
                    .num("n_elems", p.n_elems as f64)
                    .num("setup_s", p.setup_s)
                    .num("assemble_s", p.assemble_s)
                    .num("solve_s", p.solve_s)
                    .num("rel_residual", p.rel_residual)
                    .write()?;
            }
            points.extend(pts);
        }
    }
    summarize(&points);
    Ok(())
}

fn mesh_for(problem: &str, n: usize) -> (Mesh, usize) {
    match problem {
        "poisson3d" => (unit_cube_tet(n), 1),
        "elasticity3d" => {
            let n4 = ((n + 3) / 4) * 4; // hollow cube needs n % 4 == 0
            (hollow_cube_tet(n4.max(4)), 3)
        }
        other => panic!("unknown problem {other}"),
    }
}

/// Measure all variants at one size.
pub fn scale_point(problem: &str, n: usize, runtime: Option<&Runtime>) -> Result<Vec<ScalePoint>> {
    let (mesh, ncomp) = mesh_for(problem, n);
    let cfg = SolverConfig::default();
    let bc_nodes = mesh.boundary_nodes();
    let bc_dofs: Vec<usize> = bc_nodes
        .iter()
        .flat_map(|&b| (0..ncomp).map(move |c| b * ncomp + c))
        .collect();
    let bc = DirichletBc::homogeneous(bc_dofs);

    let bilinear = |_: &AssemblyContext| -> BilinearForm {
        if ncomp == 1 {
            BilinearForm::Diffusion { rho: Coefficient::Const(1.0) }
        } else {
            BilinearForm::Elasticity {
                lambda: 0.3 / (1.3 * 0.4),
                mu: 1.0 / 2.6,
                e_mod: Coefficient::Const(1.0),
            }
        }
    };
    let linear = || -> LinearForm {
        if ncomp == 1 {
            LinearForm::Source { f: Coefficient::Const(1.0) }
        } else {
            LinearForm::VectorSource { f: vec![1.0, 1.0, 1.0] }
        }
    };

    let mut out = Vec::new();

    // --- mapreduce (native TensorGalerkin), setup separated ------------
    let (ctx, setup_s) = time_it(|| AssemblyContext::new(&mesh, ncomp));
    let form = bilinear(&ctx);
    let ((k, f), assemble_s) = time_it(|| {
        let k = ctx.assemble_matrix(&form);
        let f = ctx.assemble_vector(&linear());
        (k, f)
    });
    let (solved, solve_s) = time_it(|| {
        let sys = condense(&k, &f, &bc);
        let (u, _) = solver::solve(&sys.k, &sys.rhs, Method::BiCgStab, &cfg);
        let rel = solver::rel_residual(&sys.k, &u, &sys.rhs);
        (sys, rel)
    });
    let n_dofs = ctx.n_dofs();
    out.push(ScalePoint {
        problem: problem.into(),
        variant: "mapreduce".into(),
        n_dofs,
        n_elems: mesh.n_cells(),
        assemble_s,
        solve_s,
        setup_s,
        rel_residual: solved.1,
    });

    // --- scatter-add baseline (pattern rebuilt inside the call) --------
    let (k_sc, sc_s) = time_it(|| {
        scatter::assemble_matrix_from_scratch(&mesh, &ctx.dofmap, &form, &ctx.tab, &ctx.quad)
    });
    let (rel_sc, solve_sc_s) = time_it(|| {
        let sys = condense(&k_sc, &f, &bc);
        let (u, _) = solver::solve(&sys.k, &sys.rhs, Method::BiCgStab, &cfg);
        solver::rel_residual(&sys.k, &u, &sys.rhs)
    });
    out.push(ScalePoint {
        problem: problem.into(),
        variant: "scatter".into(),
        n_dofs,
        n_elems: mesh.n_cells(),
        assemble_s: sc_s,
        solve_s: solve_sc_s,
        setup_s: 0.0,
        rel_residual: rel_sc,
    });

    // --- PJRT artifact variants ----------------------------------------
    if let Some(rt) = runtime {
        let kind = if ncomp == 1 { MapKind::Poisson3d } else { MapKind::Elasticity3d };
        let nq = ctx.quad.len();
        let coeff = vec![1.0; mesh.n_cells() * nq];
        let mapper = PjrtMapper::new(rt);
        // Warm (cached executable) path.
        let _ = mapper.assemble_matrix(&ctx, kind, &coeff)?; // warm the cache
        let (k_pj, pj_s) = time_it(|| mapper.assemble_matrix(&ctx, kind, &coeff).unwrap());
        let (rel_pj, solve_pj_s) = time_it(|| {
            let fv = if ncomp == 1 {
                mapper.assemble_vector(&ctx, MapKind::Load3d, &coeff).unwrap()
            } else {
                f.clone()
            };
            let sys = condense(&k_pj, &fv, &bc);
            let (u, _) = solver::solve(&sys.k, &sys.rhs, Method::BiCgStab, &cfg);
            solver::rel_residual(&sys.k, &u, &sys.rhs)
        });
        out.push(ScalePoint {
            problem: problem.into(),
            variant: "pjrt".into(),
            n_dofs,
            n_elems: mesh.n_cells(),
            assemble_s: pj_s,
            solve_s: solve_pj_s,
            setup_s: 0.0,
            rel_residual: rel_pj,
        });
        // Recompile-per-solve baseline (JAX-FEM archetype).
        rt.clear_cache();
        let (_k_rc, rc_s) = time_it(|| mapper.assemble_matrix(&ctx, kind, &coeff).unwrap());
        out.push(ScalePoint {
            problem: problem.into(),
            variant: "recompile".into(),
            n_dofs,
            n_elems: mesh.n_cells(),
            assemble_s: rc_s,
            solve_s: 0.0,
            setup_s: 0.0,
            rel_residual: 0.0,
        });
    }
    Ok(out)
}

fn summarize(points: &[ScalePoint]) {
    // Who-wins summary: assembly speedup of mapreduce vs scatter at the
    // largest size per problem (the Fig 2 headline).
    for problem in ["poisson3d", "elasticity3d"] {
        let at_max = |variant: &str| -> Option<&ScalePoint> {
            points
                .iter()
                .filter(|p| p.problem == problem && p.variant == variant)
                .max_by_key(|p| p.n_dofs)
        };
        if let (Some(mr), Some(sc)) = (at_max("mapreduce"), at_max("scatter")) {
            println!(
                "{problem}: assembly speedup map-reduce vs scatter-add at {} DoFs: {:.2}×",
                mr.n_dofs,
                sc.assemble_s / mr.assemble_s.max(1e-12)
            );
        }
    }
}
