//! Fig B.4 — efficient batch data generation: wall-clock of generating a
//! batch of (f, u) pairs on a fixed 3D Poisson operator (~7.3k DoFs in the
//! paper), batched (amortized operator state) vs the naive per-sample
//! pipeline. The shape under test: near-flat scaling at small batches and
//! a sub-linear slope at large ones.

use anyhow::Result;

use crate::coordinator::batcher::{solve_unbatched, BatchSolver};
use crate::coordinator::SolveRequest;
use crate::experiments::common::{markdown_table, ExperimentRecord};
use crate::mesh::structured::unit_cube_tet;
use crate::solver::SolverConfig;
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::util::timer::time_it;

pub fn run(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 18); // 19³ = 6,859 nodes ≈ paper's 7,315 DoFs
    let batches = args.get_usize_list("batches", &[1, 4, 16, 64, 256]);
    let mesh = unit_cube_tet(n);
    let cfg = SolverConfig {
        rel_tol: 1e-8,
        ..SolverConfig::default()
    };
    let mut rng = Rng::new(42);
    let gen = |count: usize, rng: &mut Rng| -> Vec<SolveRequest> {
        (0..count)
            .map(|id| {
                SolveRequest::new(
                    id as u64,
                    (0..mesh.n_nodes()).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
                )
            })
            .collect()
    };

    let (solver, setup_s) = time_it(|| BatchSolver::new(&mesh, cfg));
    println!(
        "figb4: mesh {} nodes ({} DoFs condensed), setup {:.3}s",
        mesh.n_nodes(),
        solver.n_dofs(),
        setup_s
    );

    let mut rows = Vec::new();
    for &b in &batches {
        let reqs = gen(b, &mut rng);
        let (out, batched_s) = time_it(|| solver.solve_batch(&reqs).unwrap());
        assert_eq!(out.len(), b);
        // Naive baseline gets expensive fast; cap the measured set and
        // extrapolate linearly (it is embarrassingly per-sample).
        let measured = b.min(8);
        let (_, naive_part) = time_it(|| solve_unbatched(&mesh, &reqs[..measured], cfg).unwrap());
        let naive_s = naive_part * b as f64 / measured as f64;
        rows.push(vec![
            format!("{b}"),
            format!("{:.3} s", setup_s + batched_s),
            format!("{:.3} s", naive_s),
            format!("{:.1}×", naive_s / (setup_s + batched_s)),
        ]);
        ExperimentRecord::new("figb4")
            .num("batch", b as f64)
            .num("batched_s", setup_s + batched_s)
            .num("naive_s", naive_s)
            .write()?;
    }
    println!(
        "\nFig B.4 (batched data generation):\n\n{}",
        markdown_table(&["Batch", "Batched (ours)", "Per-sample naive", "Speedup"], &rows)
    );
    Ok(())
}
