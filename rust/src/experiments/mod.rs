//! Experiment drivers — one module per paper table/figure (DESIGN.md §5) —
//! and the CLI dispatcher.

pub mod cli;
pub mod common;
pub mod fig2;
pub mod figb4;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod tableb2;
pub mod tableb3;

pub use common::ExperimentRecord;
