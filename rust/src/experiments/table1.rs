//! Table 1 — neural PDE solver comparison on the 2D checkerboard Poisson
//! problem: PINN vs VPINN vs Deep Ritz vs TensorPILS, shared SIREN
//! backbone, shared mesh, Adam + L-BFGS schedule. Reports relative L2
//! error (%) per frequency K and training throughput (it/s).
//!
//! Schedule defaults are scaled for the 1-core CI box (paper: 10k Adam +
//! 200 L-BFGS on an RTX 3090); pass `--adam/--lbfgs` to run the full
//! schedule. All methods share the schedule, so rankings are comparable.

use anyhow::Result;

use crate::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
use crate::analysis::mms::checkerboard;
use crate::bc::DirichletBc;
use crate::experiments::common::{markdown_table, ExperimentRecord};
use crate::fem::geometry::gather_coords;
use crate::mesh::structured::unit_square_tri;
use crate::pils::trainer::{train_schedule, ArtifactLoss, Operand};
use crate::pils::siren;
use crate::runtime::Runtime;
use crate::solver::{Method, SolverConfig};
use crate::tensormesh::{self, Problem};
use crate::util::cli::Args;

/// The four Table-1 methods.
pub const METHODS: [&str; 4] = ["pinn", "vpinn", "deepritz", "pils"];

pub struct MethodResult {
    pub method: String,
    pub kfreq: usize,
    pub rel_l2_pct: f64,
    pub adam_its: f64,
    pub lbfgs_its: f64,
    pub final_loss: f64,
}

pub fn run(args: &Args) -> Result<()> {
    let adam_iters = args.get_usize("adam", 400);
    let lbfgs_iters = args.get_usize("lbfgs", 25);
    let lr = args.get_f64("lr", 1e-3);
    let seed = args.get_usize("seed", 0);
    let freqs = args.get_usize_list("freqs", &[2, 4, 8]);
    let methods: Vec<String> = match args.positional.get(1) {
        Some(m) => vec![m.clone()],
        None => METHODS.iter().map(|s| s.to_string()).collect(),
    };

    let rt = Runtime::new()?;
    let results = run_with(&rt, &methods, &freqs, adam_iters, lbfgs_iters, lr, seed, args.flag("vtk"))?;

    // Render Table 1.
    let mut rows = Vec::new();
    for m in &methods {
        let mut row = vec![m.to_string()];
        for &k in &freqs {
            let r = results
                .iter()
                .find(|r| &r.method == m && r.kfreq == k)
                .expect("missing result");
            row.push(format!("{:.2}", r.rel_l2_pct));
        }
        let r0 = results.iter().find(|r| &r.method == m).unwrap();
        row.push(format!("{:.1}", r0.adam_its));
        row.push(format!("{:.1}", r0.lbfgs_its));
        rows.push(row);
    }
    let mut headers = vec!["Method".to_string()];
    headers.extend(freqs.iter().map(|k| format!("K={k} relL2%")));
    headers.push("Adam it/s".into());
    headers.push("LBFGS it/s".into());
    let headers_ref: Vec<&str> = headers.iter().map(String::as_str).collect();
    println!("\nTable 1 (adam={adam_iters}, lbfgs={lbfgs_iters}, seed={seed}):\n");
    println!("{}", markdown_table(&headers_ref, &rows));
    Ok(())
}

/// Core Table-1 runner, reusable from examples and tests.
#[allow(clippy::too_many_arguments)]
pub fn run_with(
    rt: &Runtime,
    methods: &[String],
    freqs: &[usize],
    adam_iters: usize,
    lbfgs_iters: usize,
    lr: f64,
    seed: usize,
    dump_vtk: bool,
) -> Result<Vec<MethodResult>> {
    // Mesh must match the artifact shapes (mirrored generators).
    let info = rt.manifest.get("table1_pils")?;
    let mesh_n = info.meta["mesh_n"] as usize;
    let n_nodes = info.meta["n_nodes"] as usize;
    let nnz_expect = info.meta["nnz"] as usize;
    let mesh = unit_square_tri(mesh_n);
    anyhow::ensure!(mesh.n_nodes() == n_nodes, "mesh/artifact node mismatch");
    let ctx = AssemblyContext::new(&mesh, 1);
    anyhow::ensure!(ctx.routing.nnz() == nnz_expect, "mesh/artifact nnz mismatch");

    // Shared buffers.
    let coords: Vec<f64> = mesh.points.clone();
    let mut mask = vec![1.0f64; mesh.n_nodes()];
    for b in mesh.boundary_nodes() {
        mask[b] = 0.0;
    }
    let cell_coords = gather_coords(&mesh);
    let cells: Vec<usize> = mesh.cells.clone();

    // K (frequency-independent) in routing-pattern order + COO indices.
    let kmat = ctx.assemble_matrix(&BilinearForm::Diffusion {
        rho: Coefficient::Const(1.0),
    });
    let mut rows_idx = Vec::with_capacity(kmat.nnz());
    for r in 0..kmat.nrows {
        for _ in kmat.indptr[r]..kmat.indptr[r + 1] {
            rows_idx.push(r);
        }
    }

    let mut results = Vec::new();
    for &kfreq in freqs {
        // Ground truth: FEM on a 4× finer structured mesh, restricted to
        // the coarse nodes (exact node embedding).
        let u_ref = fem_reference(mesh_n, 4, kfreq)?;

        // Load vector for the PILS residual.
        let fvec = ctx.assemble_vector(&LinearForm::Source {
            f: ctx.coeff_fn(|p| checkerboard(kfreq, p)),
        });

        for method in methods {
            let fixed: Vec<Operand> = match method.as_str() {
                "pinn" => vec![
                    Operand::from_f64(&coords),
                    Operand::from_f64(&mask),
                    Operand::F32(vec![kfreq as f32]),
                ],
                "vpinn" => vec![
                    Operand::from_f64(&cell_coords),
                    Operand::from_usize(&cells),
                    Operand::from_f64(&coords),
                    Operand::from_f64(&mask),
                    Operand::F32(vec![kfreq as f32]),
                ],
                "deepritz" => vec![
                    Operand::from_f64(&cell_coords),
                    Operand::from_f64(&coords),
                    Operand::from_f64(&mask),
                    Operand::F32(vec![kfreq as f32]),
                ],
                "pils" => vec![
                    Operand::from_f64(&coords),
                    Operand::from_f64(&mask),
                    Operand::from_f64(&kmat.data),
                    Operand::from_usize(&rows_idx),
                    Operand::from_usize(&kmat.indices),
                    Operand::from_f64(&fvec),
                ],
                other => anyhow::bail!("unknown method {other}"),
            };
            let mut loss = ArtifactLoss::new(rt, &format!("table1_{method}"), fixed);
            let params0 = siren::load_init(rt, seed)?;
            let (params, log) = train_schedule(&mut loss, params0, adam_iters, lbfgs_iters, lr)?;

            // Evaluate at mesh nodes; hard-BC methods mask the output.
            let mut u = siren::eval(rt, &params, &coords)?;
            if method == "pils" {
                for (ui, mi) in u.iter_mut().zip(&mask) {
                    *ui *= mi;
                }
            }
            let rel = crate::util::rel_l2(&u, &u_ref) * 100.0;
            crate::tg_info!(
                "table1 {method} K={kfreq}: relL2 {rel:.2}% loss {:.3e} adam {:.1} it/s lbfgs {:.1} it/s",
                log.final_loss,
                log.adam_its_per_sec(),
                log.lbfgs_its_per_sec()
            );
            ExperimentRecord::new("table1")
                .str("method", method)
                .num("kfreq", kfreq as f64)
                .num("rel_l2_pct", rel)
                .num("adam_its_per_sec", log.adam_its_per_sec())
                .num("lbfgs_its_per_sec", log.lbfgs_its_per_sec())
                .num("final_loss", log.final_loss)
                .num("adam_iters", adam_iters as f64)
                .num("lbfgs_iters", lbfgs_iters as f64)
                .write()?;
            if dump_vtk {
                crate::mesh::io::write_vtk(
                    format!("target/fields/table1_{method}_K{kfreq}.vtk"),
                    &mesh,
                    &[("u", &u), ("u_ref", &u_ref)],
                    &[],
                )?;
            }
            results.push(MethodResult {
                method: method.clone(),
                kfreq,
                rel_l2_pct: rel,
                adam_its: log.adam_its_per_sec(),
                lbfgs_its: log.lbfgs_its_per_sec(),
                final_loss: log.final_loss,
            });
        }
    }
    Ok(results)
}

/// FEM ground truth: solve on a `refine×` finer structured mesh, restrict
/// to coarse nodes (structured meshes nest exactly).
pub fn fem_reference(mesh_n: usize, refine: usize, kfreq: usize) -> Result<Vec<f64>> {
    let fine_n = mesh_n * refine;
    let fine = unit_square_tri(fine_n);
    let mut p = Problem::scalar();
    p.bilinear.push(BilinearForm::Diffusion {
        rho: Coefficient::Const(1.0),
    });
    let ctx = AssemblyContext::new(&fine, 1);
    p.linear.push(LinearForm::Source {
        f: ctx.coeff_fn(|x| checkerboard(kfreq, x)),
    });
    p.dirichlet = DirichletBc::homogeneous(fine.boundary_nodes());
    let sol = tensormesh::solve(&fine, &p, Method::Cg, &SolverConfig::default())?;
    anyhow::ensure!(sol.stats.converged, "reference solve failed");
    // Coarse node (i,j) ↦ fine node (refine·i, refine·j).
    let mut out = Vec::with_capacity((mesh_n + 1) * (mesh_n + 1));
    for j in 0..=mesh_n {
        for i in 0..=mesh_n {
            out.push(sol.u[(j * refine) * (fine_n + 1) + i * refine]);
        }
    }
    Ok(out)
}
