//! Table 2 / Figs B.17-B.18 — physics-informed operator learning
//! (wave + Allen-Cahn). Wired up in phase 5 (see `crate::oplearn`).

use anyhow::Result;

use crate::util::cli::Args;

pub fn run(args: &Args) -> Result<()> {
    crate::oplearn::experiment::run(args)
}

pub fn run_figb18(args: &Args) -> Result<()> {
    crate::oplearn::experiment::run_figb18(args)
}
