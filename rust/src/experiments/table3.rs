//! Table 3 — topology-optimization timing on the 2D cantilever
//! (60×30 Q4, SIMP p=3, 51 iterations): setup / optimization-loop / total,
//! TensorOpt (cached TensorGalerkin setup) vs the rebuild-per-iteration
//! archetype standing in for JAX-FEM's JIT pipeline (DESIGN.md §7).
//! Also dumps the Fig 5 / B.19-20 artifacts (density evolution +
//! convergence history).

use anyhow::Result;

use crate::experiments::common::{markdown_table, ExperimentRecord};
use crate::opt::topopt::{run_topopt, TopOptConfig};
use crate::util::cli::Args;

pub fn run(args: &Args) -> Result<()> {
    let iters = args.get_usize("iters", 51);
    let nx = args.get_usize("nx", 60);
    let ny = args.get_usize("ny", 30);
    let optimizer = args.get_str("optimizer", "mma");

    let mut cfg = TopOptConfig {
        iters,
        optimizer: optimizer.clone(),
        ..TopOptConfig::default()
    };
    cfg.simp.nx = nx;
    cfg.simp.ny = ny;
    cfg.simp.lx = nx as f64;
    cfg.simp.ly = ny as f64;

    // TensorOpt: cached setup.
    let ours = run_topopt(&cfg)?;
    // Baseline: rebuild-everything-per-iteration (JAX-FEM archetype).
    let mut base_cfg = cfg.clone();
    base_cfg.rebuild_setup_each_iter = true;
    let baseline = run_topopt(&base_cfg)?;

    let total_ours = ours.setup_s + ours.loop_s;
    let total_base = baseline.setup_s + baseline.loop_s;
    let rows = vec![
        vec![
            "Setup Time".to_string(),
            format!("{:.2} s", baseline.setup_s),
            format!("{:.2} s", ours.setup_s),
            format!("{:.1}×", baseline.setup_s / ours.setup_s.max(1e-9)),
        ],
        vec![
            "Optimization Loop".to_string(),
            format!("{:.2} s", baseline.loop_s),
            format!("{:.2} s", ours.loop_s),
            format!("{:.1}×", baseline.loop_s / ours.loop_s.max(1e-9)),
        ],
        vec![
            "Total Time".to_string(),
            format!("{:.2} s", total_base),
            format!("{:.2} s", total_ours),
            format!("{:.1}×", total_base / total_ours.max(1e-9)),
        ],
    ];
    println!("\nTable 3 ({nx}×{ny} cantilever, {iters} iterations, {optimizer}):\n");
    println!(
        "{}",
        markdown_table(&["Stage", "Rebuild-baseline", "TensorOpt (ours)", "Speedup"], &rows)
    );
    let dc = (ours.final_compliance() - baseline.final_compliance()).abs()
        / baseline.final_compliance();
    println!(
        "final compliance: ours {:.4}, baseline {:.4} (diff {:.3}%)",
        ours.final_compliance(),
        baseline.final_compliance(),
        dc * 100.0
    );
    println!(
        "compliance drop from initial: {:.1}%",
        100.0 * (1.0 - ours.final_compliance() / ours.compliance_history[0])
    );

    ExperimentRecord::new("table3")
        .str("optimizer", &optimizer)
        .num("iters", iters as f64)
        .num("setup_s_ours", ours.setup_s)
        .num("loop_s_ours", ours.loop_s)
        .num("setup_s_baseline", baseline.setup_s)
        .num("loop_s_baseline", baseline.loop_s)
        .num("final_compliance", ours.final_compliance())
        .num("compliance_rel_diff", dc)
        .write()?;

    if args.flag("vtk") {
        let mesh = crate::mesh::structured::rect_quad(nx, ny, nx as f64, ny as f64);
        for (it, rho) in &ours.snapshots {
            crate::mesh::io::write_vtk(
                format!("target/fields/topopt_iter{it:03}.vtk"),
                &mesh,
                &[],
                &[("rho", rho)],
            )?;
        }
        println!("density snapshots written to target/fields/ (Fig 5 / B.20)");
    }
    Ok(())
}
