//! Table B.2 — PINN error & linear-system residual under mesh refinement
//! (3D Poisson / 3D elasticity), demonstrating that strong-form PINNs do
//! not track FEM-level residual decay. We train a PINN (through the AOT
//! loss artifacts used by Table 1, 2D instance) and additionally report
//! the *FEM* refinement ladder for contrast — the 3D SIREN artifacts are
//! intentionally replaced by the 2D instance to keep CPU budgets sane
//! (documented substitution; the measured *trend* is the deliverable).

use anyhow::Result;

use crate::analysis::mms::checkerboard;
use crate::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
use crate::bc::{condense, DirichletBc};
use crate::experiments::common::{markdown_table, ExperimentRecord};
use crate::mesh::structured::unit_cube_tet;
use crate::solver::{self, Method, SolverConfig};
use crate::util::cli::Args;

pub fn run(args: &Args) -> Result<()> {
    let sizes = args.get_usize_list("sizes", &[4, 6, 8, 12]);
    let kfreq = args.get_usize("kfreq", 2);
    let mut rows = Vec::new();
    // FEM refinement ladder (3D Poisson): rel residual at solver tolerance.
    for &n in &sizes {
        let mesh = unit_cube_tet(n);
        let ctx = AssemblyContext::new(&mesh, 1);
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        let f = ctx.assemble_vector(&LinearForm::Source {
            f: ctx.coeff_fn(|p| checkerboard(kfreq, p)),
        });
        let sys = condense(&k, &f, &DirichletBc::homogeneous(mesh.boundary_nodes()));
        let (u, stats) = solver::solve(&sys.k, &sys.rhs, Method::BiCgStab, &SolverConfig::default());
        let rel = solver::rel_residual(&sys.k, &u, &sys.rhs);
        rows.push(vec![
            "Poisson3D-FEM".to_string(),
            format!("{}", sys.k.nrows),
            format!("{:.2e}", rel),
            format!("{}", stats.iterations),
        ]);
        ExperimentRecord::new("tableb2")
            .str("method", "fem")
            .num("dofs", sys.k.nrows as f64)
            .num("rel_res", rel)
            .write()?;
    }
    // PINN ladder via the Fig-4 artifacts (2D instance).
    if let Ok(rt) = crate::runtime::Runtime::new() {
        let fig4 = crate::experiments::table1::fem_reference(16, 4, kfreq)?;
        let _ = fig4; // reference available for error reporting below
        for gn in [8usize, 16, 32] {
            let name = format!("fig4_pinn_grad_n{gn}");
            if rt.manifest.get(&name).is_err() {
                continue;
            }
            let res = train_pinn_at(&rt, gn, kfreq, 200)?;
            rows.push(vec![
                "Poisson2D-PINN".to_string(),
                format!("{}", (gn + 1) * (gn + 1)),
                format!("{:.2e}", res.1),
                format!("relErr {:.3}", res.0),
            ]);
            ExperimentRecord::new("tableb2")
                .str("method", "pinn")
                .num("dofs", ((gn + 1) * (gn + 1)) as f64)
                .num("rel_err", res.0)
                .num("rel_res", res.1)
                .write()?;
        }
    } else {
        crate::tg_warn!("artifacts missing: PINN rows skipped");
    }
    println!(
        "\nTable B.2 (residual/error under refinement):\n\n{}",
        markdown_table(&["Problem", "DoFs", "RelRes_lin", "notes"], &rows)
    );
    Ok(())
}

/// Train a PINN on the `n`-grid and report (relErr vs FEM, discrete
/// linear-system relative residual of its nodal field).
fn train_pinn_at(
    rt: &crate::runtime::Runtime,
    n: usize,
    kfreq: usize,
    adam_iters: usize,
) -> Result<(f64, f64)> {
    use crate::mesh::structured::unit_square_tri;
    use crate::pils::trainer::{train_schedule, ArtifactLoss, Operand};

    let mesh = unit_square_tri(n);
    let coords = mesh.points.clone();
    let mut mask = vec![1.0f64; mesh.n_nodes()];
    for b in mesh.boundary_nodes() {
        mask[b] = 0.0;
    }
    let fixed = vec![
        Operand::from_f64(&coords),
        Operand::from_f64(&mask),
        Operand::F32(vec![kfreq as f32]),
    ];
    let mut loss = ArtifactLoss::new(rt, &format!("fig4_pinn_grad_n{n}"), fixed);
    let params0 = crate::pils::siren::load_init(rt, 0)?;
    let (params, _) = train_schedule(&mut loss, params0, adam_iters, 0, 1e-3)?;
    let u = crate::pils::siren::eval(rt, &params, &coords)?;

    // Error vs FEM reference on the same grid.
    let u_ref = crate::experiments::table1::fem_reference(n, 4, kfreq)?;
    let rel_err = crate::util::rel_l2(&u, &u_ref);

    // Discrete residual of the PINN field in the Galerkin system.
    let ctx = AssemblyContext::new(&mesh, 1);
    let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
        rho: Coefficient::Const(1.0),
    });
    let f = ctx.assemble_vector(&LinearForm::Source {
        f: ctx.coeff_fn(|p| checkerboard(kfreq, p)),
    });
    let sys = condense(&k, &f, &DirichletBc::homogeneous(mesh.boundary_nodes()));
    let u_free = sys.restrict(&u);
    let rel_res = solver::rel_residual(&sys.k, &u_free, &sys.rhs);
    Ok((rel_err, rel_res))
}
