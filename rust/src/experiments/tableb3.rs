//! Table B.3 — mixed Dirichlet+Neumann+Robin Poisson on a circular and a
//! non-convex boomerang domain (§B.1.5): TensorMesh assembles the boundary
//! terms through the same Map-Reduce pipeline, the scatter-add baseline
//! stands in for FEniCSx, and correctness is checked against a manufactured
//! solution with all three BC types active.

use anyhow::Result;

use crate::assembly::map_reduce::FacetContext;
use crate::assembly::{scatter, AssemblyContext, BilinearForm, Coefficient, LinearForm};
use crate::bc::{condense, DirichletBc};
use crate::experiments::common::{markdown_table, ExperimentRecord};
use crate::mesh::curved::{boomerang_tri, circle_tri};
use crate::mesh::{marker, Mesh};
use crate::solver::{self, Method, SolverConfig};
use crate::util::cli::Args;
use crate::util::timer::time_it;

/// Manufactured solution u = x² + y² ⇒ −Δu = −4, ∂u/∂n = 2(x·n), plus a
/// Robin combination α u + ∂u/∂n = g_R — all computable exactly.
struct Mms;

impl Mms {
    fn u(p: &[f64]) -> f64 {
        p[0] * p[0] + p[1] * p[1]
    }

    fn f() -> f64 {
        -4.0
    }
}

/// Split the boundary into three sectors by polar angle around the domain
/// centroid: Dirichlet / Neumann / Robin.
fn mark_thirds(mesh: &mut Mesh) {
    let n = mesh.n_nodes() as f64;
    let (mut cx, mut cy) = (0.0, 0.0);
    for i in 0..mesh.n_nodes() {
        cx += mesh.point(i)[0] / n;
        cy += mesh.point(i)[1] / n;
    }
    mesh.mark_boundary(|c| {
        let theta = (c[1] - cy).atan2(c[0] - cx);
        let t = (theta + std::f64::consts::PI) / (2.0 * std::f64::consts::PI);
        if t < 1.0 / 3.0 {
            marker::DIRICHLET
        } else if t < 2.0 / 3.0 {
            marker::NEUMANN
        } else {
            marker::ROBIN
        }
    });
}

struct BenchOut {
    dofs: usize,
    ours_ms: f64,
    baseline_ms: f64,
    rel_err: f64,
}

fn run_domain(mesh: &mut Mesh, alpha: f64) -> Result<BenchOut> {
    mark_thirds(mesh);
    let n = mesh.n_nodes() as f64;
    let (mut cx, mut cy) = (0.0, 0.0);
    for i in 0..mesh.n_nodes() {
        cx += mesh.point(i)[0] / n;
        cy += mesh.point(i)[1] / n;
    }

    let _ = (cx, cy);
    // True outward normals via the owning cell (valid on non-convex domains).
    let normals = mesh.facet_outward_normals_2d();
    let facet_ids_neumann: Vec<usize> = (0..mesh.n_facets())
        .filter(|&f| mesh.facet_markers[f] == marker::NEUMANN)
        .collect();
    let facet_ids_robin: Vec<usize> = (0..mesh.n_facets())
        .filter(|&f| mesh.facet_markers[f] == marker::ROBIN)
        .collect();

    // --- TensorMesh (Map-Reduce everywhere) -----------------------------
    let mesh_c = mesh.clone();
    let ((k, fvec, bc), ours_s) = time_it(|| {
        let ctx = AssemblyContext::new(&mesh_c, 1);
        let mut k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        let mut f = ctx.assemble_vector(&LinearForm::Source {
            f: Coefficient::Const(Mms::f()),
        });
        // Neumann: ∫ g v with g = ∂u/∂n = 2 x·n.
        let fc_n = FacetContext::new(&mesh_c, &[marker::NEUMANN], 1);
        let g_n = neumann_coeff(&fc_n, &mesh_c, &facet_ids_neumann, &normals);
        let fn_vec = fc_n.assemble_vector(&LinearForm::FacetFlux { g: g_n });
        for (a, b) in f.iter_mut().zip(&fn_vec) {
            *a += b;
        }
        // Robin: ∫ α u v added to K; ∫ (α u_exact + ∂u/∂n) v added to F.
        let fc_r = FacetContext::new(&mesh_c, &[marker::ROBIN], 1);
        let kr = fc_r.assemble_matrix(&BilinearForm::FacetMass {
            alpha: Coefficient::Const(alpha),
        });
        k = k.add_scaled(&kr, 1.0).unwrap();
        let g_r = robin_coeff(&fc_r, &mesh_c, &facet_ids_robin, &normals, alpha);
        let fr_vec = fc_r.assemble_vector(&LinearForm::FacetFlux { g: g_r });
        for (a, b) in f.iter_mut().zip(&fr_vec) {
            *a += b;
        }
        let dn = mesh_c.boundary_nodes_with(&[marker::DIRICHLET]);
        let bc = DirichletBc::from_fn(&mesh_c, &dn, Mms::u);
        (k, f, bc)
    });
    let (sol, solve_s) = time_it(|| {
        let sys = condense(&k, &fvec, &bc);
        let (u_free, stats) = solver::solve(&sys.k, &sys.rhs, Method::BiCgStab, &SolverConfig::default());
        (sys.expand(&u_free), stats)
    });
    anyhow::ensure!(sol.1.converged, "mixed-BC solve failed");
    let exact: Vec<f64> = (0..mesh.n_nodes()).map(|i| Mms::u(mesh.point(i))).collect();
    let rel_err = crate::util::rel_l2(&sol.0, &exact);

    // --- Scatter-add baseline (volume part; boundary assembly shared) ---
    let ctx2 = AssemblyContext::new(mesh, 1);
    let (_k_b, base_s) = time_it(|| {
        scatter::assemble_matrix_from_scratch(
            mesh,
            &ctx2.dofmap,
            &BilinearForm::Diffusion { rho: Coefficient::Const(1.0) },
            &ctx2.tab,
            &ctx2.quad,
        )
    });
    // Baseline end-to-end = scatter assembly + the same solve time.
    Ok(BenchOut {
        dofs: mesh.n_nodes(),
        ours_ms: (ours_s + solve_s) * 1e3,
        baseline_ms: (base_s + solve_s) * 1e3,
        rel_err,
    })
}

fn neumann_coeff(
    fc: &FacetContext,
    mesh: &Mesh,
    facet_ids: &[usize],
    normals: &[[f64; 2]],
) -> Coefficient {
    let mut vals = Vec::with_capacity(fc.geo.n_elems * fc.geo.q);
    for (idx, &f) in facet_ids.iter().enumerate() {
        let n = normals[f];
        for q in 0..fc.geo.q {
            let p = fc.geo.qpoint(idx, q);
            vals.push(2.0 * (p[0] * n[0] + p[1] * n[1]));
        }
    }
    let _ = mesh;
    Coefficient::Quad(vals)
}

fn robin_coeff(
    fc: &FacetContext,
    mesh: &Mesh,
    facet_ids: &[usize],
    normals: &[[f64; 2]],
    alpha: f64,
) -> Coefficient {
    let mut vals = Vec::with_capacity(fc.geo.n_elems * fc.geo.q);
    for (idx, &f) in facet_ids.iter().enumerate() {
        let n = normals[f];
        for q in 0..fc.geo.q {
            let p = fc.geo.qpoint(idx, q);
            vals.push(alpha * Mms::u(p) + 2.0 * (p[0] * n[0] + p[1] * n[1]));
        }
    }
    let _ = mesh;
    Coefficient::Quad(vals)
}

pub fn run(args: &Args) -> Result<()> {
    let n_circle = args.get_usize("ncircle", 54); // ~6k nodes per the paper
    let nr = args.get_usize("nr", 24);
    let nt = args.get_usize("nt", 240); // ~15k nodes
    let alpha = args.get_f64("alpha", 1.0);

    let mut rows = Vec::new();
    let mut circle = circle_tri(n_circle, 0.0, 0.0, 1.0);
    let c = run_domain(&mut circle, alpha)?;
    let mut boomerang = boomerang_tri(nr, nt, 0.35, 1.0);
    let b = run_domain(&mut boomerang, alpha)?;

    for (name, r) in [("Poisson circle (D+N+R)", &c), ("Poisson boomerang (D+N+R)", &b)] {
        rows.push(vec![
            name.to_string(),
            format!("{}", r.dofs),
            format!("{:.0} ms", r.baseline_ms),
            format!("{:.0} ms", r.ours_ms),
            format!("~{:.1}×", r.baseline_ms / r.ours_ms.max(1e-9)),
            format!("{:.2e}", r.rel_err),
        ]);
        ExperimentRecord::new("tableb3")
            .str("domain", name)
            .num("dofs", r.dofs as f64)
            .num("baseline_ms", r.baseline_ms)
            .num("ours_ms", r.ours_ms)
            .num("rel_err", r.rel_err)
            .write()?;
    }
    println!(
        "\nTable B.3 (mixed D+N+Robin; scatter-add stands in for FEniCSx):\n\n{}",
        markdown_table(
            &["Dataset", "Nodes", "Baseline", "TensorMesh", "Speedup", "relErr"],
            &rows
        )
    );
    // The paper reports relErr < 1e-4 vs analytic at its resolutions; on
    // the polygonal boundary approximation the bound is O(h²) — enforce a
    // conservative bar that still catches sign/BC errors outright.
    anyhow::ensure!(c.rel_err < 2e-3, "circle accuracy bar failed: {}", c.rel_err);
    anyhow::ensure!(b.rel_err < 2e-3, "boomerang accuracy bar failed: {}", b.rel_err);
    Ok(())
}
