//! Degree-of-freedom maps: the local→global index maps `g_e` of Eq. (6).
//!
//! Scalar problems attach one DoF per node; vector problems (elasticity)
//! interleave components (`dof = node·ncomp + c`). Local DoF ordering is
//! node-major, component-minor, matching the batched local matrices the Map
//! stage emits.

use crate::mesh::Mesh;

/// A DoF map over a set of cells (or boundary facets).
#[derive(Clone, Debug)]
pub struct DofMap {
    /// Total number of global DoFs.
    pub n_dofs: usize,
    /// Local DoFs per cell (`k · ncomp`).
    pub n_local: usize,
    /// Number of vector components.
    pub ncomp: usize,
    /// `E × n_local` global indices, row-major.
    pub entries: Vec<usize>,
}

impl DofMap {
    /// Scalar P1/Q1 map: DoFs are mesh nodes.
    pub fn scalar(mesh: &Mesh) -> DofMap {
        let k = mesh.cell_type.nodes();
        DofMap {
            n_dofs: mesh.n_nodes(),
            n_local: k,
            ncomp: 1,
            entries: mesh.cells.clone(),
        }
    }

    /// Vector map with `ncomp` interleaved components per node.
    pub fn vector(mesh: &Mesh, ncomp: usize) -> DofMap {
        assert!(ncomp >= 1);
        let k = mesh.cell_type.nodes();
        let mut entries = Vec::with_capacity(mesh.n_cells() * k * ncomp);
        for e in 0..mesh.n_cells() {
            for &v in mesh.cell(e) {
                for c in 0..ncomp {
                    entries.push(v * ncomp + c);
                }
            }
        }
        DofMap {
            n_dofs: mesh.n_nodes() * ncomp,
            n_local: k * ncomp,
            ncomp,
            entries,
        }
    }

    /// Scalar map over a subset of boundary facets (for Neumann/Robin
    /// integrals): row `i` maps the facet's nodes into global node DoFs.
    pub fn facet_scalar(mesh: &Mesh, facet_ids: &[usize]) -> DofMap {
        let fk = mesh.cell_type.facet_nodes();
        let mut entries = Vec::with_capacity(facet_ids.len() * fk);
        for &f in facet_ids {
            entries.extend_from_slice(mesh.facet(f));
        }
        DofMap {
            n_dofs: mesh.n_nodes(),
            n_local: fk,
            ncomp: 1,
            entries,
        }
    }

    /// Vector map over boundary facets (e.g. surface tractions): facet
    /// nodes × interleaved components.
    pub fn facet_vector(mesh: &Mesh, facet_ids: &[usize], ncomp: usize) -> DofMap {
        let fk = mesh.cell_type.facet_nodes();
        let mut entries = Vec::with_capacity(facet_ids.len() * fk * ncomp);
        for &f in facet_ids {
            for &v in mesh.facet(f) {
                for c in 0..ncomp {
                    entries.push(v * ncomp + c);
                }
            }
        }
        DofMap {
            n_dofs: mesh.n_nodes() * ncomp,
            n_local: fk * ncomp,
            ncomp,
            entries,
        }
    }

    /// Number of cells covered by this map.
    pub fn n_cells(&self) -> usize {
        if self.n_local == 0 {
            0
        } else {
            self.entries.len() / self.n_local
        }
    }

    /// The global DoFs of cell `e`.
    pub fn cell_dofs(&self, e: usize) -> &[usize] {
        &self.entries[e * self.n_local..(e + 1) * self.n_local]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::structured::unit_square_tri;

    #[test]
    fn scalar_map_is_cells() {
        let m = unit_square_tri(2);
        let dm = DofMap::scalar(&m);
        assert_eq!(dm.n_dofs, m.n_nodes());
        assert_eq!(dm.n_cells(), m.n_cells());
        assert_eq!(dm.cell_dofs(0), m.cell(0));
    }

    #[test]
    fn vector_map_interleaves() {
        let m = unit_square_tri(1);
        let dm = DofMap::vector(&m, 2);
        assert_eq!(dm.n_dofs, 2 * m.n_nodes());
        assert_eq!(dm.n_local, 6);
        let cell = m.cell(0);
        let dofs = dm.cell_dofs(0);
        for (a, &v) in cell.iter().enumerate() {
            assert_eq!(dofs[2 * a], 2 * v);
            assert_eq!(dofs[2 * a + 1], 2 * v + 1);
        }
    }

    #[test]
    fn facet_map_covers_boundary_nodes() {
        let m = unit_square_tri(2);
        let ids: Vec<usize> = (0..m.n_facets()).collect();
        let dm = DofMap::facet_scalar(&m, &ids);
        assert_eq!(dm.n_cells(), m.n_facets());
        let mut nodes: Vec<usize> = dm.entries.clone();
        nodes.sort_unstable();
        nodes.dedup();
        assert_eq!(nodes, m.boundary_nodes());
    }
}
