//! Batched element geometry — the geometric half of Stage I (Batch-Map).
//!
//! For every element `e` and quadrature point `q` we compute the Jacobian
//! `J_eq = Σ_a X_ea ⊗ ∇φ̂_a(x̂_q)` of the reference→physical map, its
//! absolute determinant and the push-forward gradients `G = J^{-T}∇φ̂`
//! (Algorithm 1, lines 1-2). The layout mirrors the paper's batched tensors
//! `𝒳 ∈ R^{E×k×d}`, `𝒥 ∈ R^{E×Q×d×d}`, `𝒢 ∈ R^{E×Q×k×d}`.
//!
//! Degenerate (zero-volume) elements — used to pad element batches up to an
//! AOT bucket size — produce `|det J| = 0` and zeroed gradients, i.e. they
//! contribute nothing to assembly by construction.

use super::quadrature::Quadrature;
use super::reference::Tabulation;
use crate::mesh::Mesh;

/// Batched geometry for a (sub)set of elements.
#[derive(Clone, Debug)]
pub struct ElementGeometry {
    pub n_elems: usize,
    pub q: usize,
    pub k: usize,
    pub dim: usize,
    /// `E × Q` absolute Jacobian determinants (× facet metric for facets).
    pub detj: Vec<f64>,
    /// `E × Q × k × dim` physical basis gradients `J^{-T}∇φ̂`.
    pub phys_grads: Vec<f64>,
    /// `E × Q × dim` physical quadrature point coordinates.
    pub qpoints: Vec<f64>,
}

impl ElementGeometry {
    pub fn det(&self, e: usize, q: usize) -> f64 {
        self.detj[e * self.q + q]
    }

    pub fn grad(&self, e: usize, q: usize, a: usize) -> &[f64] {
        let base = (((e * self.q) + q) * self.k + a) * self.dim;
        &self.phys_grads[base..base + self.dim]
    }

    pub fn qpoint(&self, e: usize, q: usize) -> &[f64] {
        let base = (e * self.q + q) * self.dim;
        &self.qpoints[base..base + self.dim]
    }
}

/// Gather per-element node coordinates `𝒳 ∈ R^{E×k×d}` (row-major).
pub fn gather_coords(mesh: &Mesh) -> Vec<f64> {
    let k = mesh.cell_type.nodes();
    let d = mesh.dim;
    let mut x = Vec::with_capacity(mesh.n_cells() * k * d);
    for e in 0..mesh.n_cells() {
        for &v in mesh.cell(e) {
            x.extend_from_slice(mesh.point(v));
        }
    }
    x
}

/// Gather boundary-facet node coordinates `𝒳_f ∈ R^{F×fk×d}`.
pub fn gather_facet_coords(mesh: &Mesh, facet_ids: &[usize]) -> Vec<f64> {
    let fk = mesh.cell_type.facet_nodes();
    let d = mesh.dim;
    let mut x = Vec::with_capacity(facet_ids.len() * fk * d);
    for &f in facet_ids {
        for &v in mesh.facet(f) {
            x.extend_from_slice(mesh.point(v));
        }
    }
    x
}

/// Compute batched geometry from raw element coordinates
/// (`coords` is `E × k × d`). This is the entry point both the native Map
/// stage and the test oracle share; meshes go through [`compute`].
pub fn compute_from_coords(
    coords: &[f64],
    tab: &Tabulation,
    quad: &Quadrature,
    dim: usize,
) -> ElementGeometry {
    let k = tab.k;
    let q = quad.len();
    assert_eq!(tab.q, q);
    assert_eq!(tab.dim, dim, "volumetric geometry needs ref dim == ambient dim");
    assert_eq!(coords.len() % (k * dim), 0);
    let n_elems = coords.len() / (k * dim);

    let mut detj = vec![0.0; n_elems * q];
    let mut phys_grads = vec![0.0; n_elems * q * k * dim];
    let mut qpoints = vec![0.0; n_elems * q * dim];

    let mut jac = vec![0.0; dim * dim];
    let mut inv_t = vec![0.0; dim * dim];

    for e in 0..n_elems {
        let x = &coords[e * k * dim..(e + 1) * k * dim];
        for qi in 0..q {
            // J[r][c] = Σ_a x[a][r] * dφ̂_a/dx̂_c ; also x_q = Σ_a φ̂_a x_a.
            jac.iter_mut().for_each(|v| *v = 0.0);
            for a in 0..k {
                let g = tab.grad(qi, a);
                let xa = &x[a * dim..(a + 1) * dim];
                for r in 0..dim {
                    for c in 0..dim {
                        jac[r * dim + c] += xa[r] * g[c];
                    }
                }
                let phi = tab.val(qi, a);
                for r in 0..dim {
                    qpoints[(e * q + qi) * dim + r] += phi * xa[r];
                }
            }
            let det = det_n(&jac, dim);
            detj[e * q + qi] = det.abs();
            if det.abs() < 1e-300 {
                // Degenerate padding element: leave gradients zero.
                continue;
            }
            inv_transpose_n(&jac, det, dim, &mut inv_t);
            for a in 0..k {
                let g = tab.grad(qi, a);
                let out = &mut phys_grads[(((e * q) + qi) * k + a) * dim..][..dim];
                for r in 0..dim {
                    let mut s = 0.0;
                    for c in 0..dim {
                        // (J^{-T})[r][c] g[c]
                        s += inv_t[r * dim + c] * g[c];
                    }
                    out[r] = s;
                }
            }
        }
    }
    ElementGeometry {
        n_elems,
        q,
        k,
        dim,
        detj,
        phys_grads,
        qpoints,
    }
}

/// Batched geometry for all cells of a mesh.
pub fn compute(mesh: &Mesh, tab: &Tabulation, quad: &Quadrature) -> ElementGeometry {
    compute_from_coords(&gather_coords(mesh), tab, quad, mesh.dim)
}

/// Batched *facet* geometry: the reference facet (dim `d-1`) is mapped into
/// ambient dimension `d`; `detj` holds the facet surface metric
/// `sqrt(det(JᵀJ))` and `phys_grads` is unused (boundary forms in this crate
/// only need basis values). `qpoints` are physical facet quadrature points.
pub fn compute_facets(
    coords: &[f64],
    tab: &Tabulation,
    quad: &Quadrature,
    ambient: usize,
) -> ElementGeometry {
    let k = tab.k;
    let q = quad.len();
    let rdim = tab.dim;
    assert_eq!(rdim + 1, ambient, "facet must have codimension 1");
    assert_eq!(coords.len() % (k * ambient), 0);
    let n = coords.len() / (k * ambient);

    let mut detj = vec![0.0; n * q];
    let mut qpoints = vec![0.0; n * q * ambient];

    for e in 0..n {
        let x = &coords[e * k * ambient..(e + 1) * k * ambient];
        for qi in 0..q {
            // J (ambient × rdim)
            let mut jac = vec![0.0; ambient * rdim];
            for a in 0..k {
                let g = tab.grad(qi, a);
                let xa = &x[a * ambient..(a + 1) * ambient];
                for r in 0..ambient {
                    for c in 0..rdim {
                        jac[r * rdim + c] += xa[r] * g[c];
                    }
                }
                let phi = tab.val(qi, a);
                for r in 0..ambient {
                    qpoints[(e * q + qi) * ambient + r] += phi * xa[r];
                }
            }
            // Gram matrix JᵀJ (rdim × rdim), metric = sqrt(det).
            let mut gram = vec![0.0; rdim * rdim];
            for i in 0..rdim {
                for j in 0..rdim {
                    let mut s = 0.0;
                    for r in 0..ambient {
                        s += jac[r * rdim + i] * jac[r * rdim + j];
                    }
                    gram[i * rdim + j] = s;
                }
            }
            detj[e * q + qi] = det_n(&gram, rdim).max(0.0).sqrt();
        }
    }
    ElementGeometry {
        n_elems: n,
        q,
        k,
        dim: ambient,
        detj,
        phys_grads: Vec::new(),
        qpoints,
    }
}

fn det_n(m: &[f64], n: usize) -> f64 {
    match n {
        1 => m[0],
        2 => m[0] * m[3] - m[1] * m[2],
        3 => {
            m[0] * (m[4] * m[8] - m[5] * m[7]) - m[1] * (m[3] * m[8] - m[5] * m[6])
                + m[2] * (m[3] * m[7] - m[4] * m[6])
        }
        _ => panic!("det_n: unsupported dimension {n}"),
    }
}

/// `out = (M^{-1})ᵀ` for `n ∈ {1,2,3}` given `det(M)`.
fn inv_transpose_n(m: &[f64], det: f64, n: usize, out: &mut [f64]) {
    let inv_det = 1.0 / det;
    match n {
        1 => out[0] = inv_det,
        2 => {
            // M^{-1} = 1/det [d -b; -c a]; transpose it.
            out[0] = m[3] * inv_det;
            out[1] = -m[2] * inv_det;
            out[2] = -m[1] * inv_det;
            out[3] = m[0] * inv_det;
        }
        3 => {
            // Cofactor matrix / det == (M^{-1})ᵀ.
            out[0] = (m[4] * m[8] - m[5] * m[7]) * inv_det;
            out[1] = (m[5] * m[6] - m[3] * m[8]) * inv_det;
            out[2] = (m[3] * m[7] - m[4] * m[6]) * inv_det;
            out[3] = (m[2] * m[7] - m[1] * m[8]) * inv_det;
            out[4] = (m[0] * m[8] - m[2] * m[6]) * inv_det;
            out[5] = (m[1] * m[6] - m[0] * m[7]) * inv_det;
            out[6] = (m[1] * m[5] - m[2] * m[4]) * inv_det;
            out[7] = (m[2] * m[3] - m[0] * m[5]) * inv_det;
            out[8] = (m[0] * m[4] - m[1] * m[3]) * inv_det;
        }
        _ => panic!("inv_transpose_n: unsupported dimension {n}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fem::quadrature::{edge_gauss, tet_deg1, tri_deg1, tri_deg2};
    use crate::fem::reference::RefElement;
    use crate::mesh::structured::{unit_cube_tet, unit_square_tri};

    #[test]
    fn triangle_det_equals_twice_area() {
        let m = unit_square_tri(2);
        let quad = tri_deg1();
        let tab = RefElement::P1Tri.tabulate(&quad);
        let geo = compute(&m, &tab, &quad);
        // Each structured triangle has area (1/2)(1/2)² = 1/8; det = 2·area.
        for e in 0..m.n_cells() {
            assert!((geo.det(e, 0) - 0.25).abs() < 1e-14);
        }
    }

    #[test]
    fn tet_det_equals_six_volumes() {
        let m = unit_cube_tet(2);
        let quad = tet_deg1();
        let tab = RefElement::P1Tet.tabulate(&quad);
        let geo = compute(&m, &tab, &quad);
        let total: f64 = (0..m.n_cells()).map(|e| geo.det(e, 0) / 6.0).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn physical_gradients_reproduce_linear_functions() {
        // For u(x,y)=3x+2y on any P1 triangle: Σ_a u(x_a) G_a = (3,2).
        let m = unit_square_tri(3);
        let quad = tri_deg2();
        let tab = RefElement::P1Tri.tabulate(&quad);
        let geo = compute(&m, &tab, &quad);
        for e in 0..m.n_cells() {
            let cell = m.cell(e);
            for q in 0..quad.len() {
                let mut gx = 0.0;
                let mut gy = 0.0;
                for (a, &v) in cell.iter().enumerate() {
                    let p = m.point(v);
                    let u = 3.0 * p[0] + 2.0 * p[1];
                    let g = geo.grad(e, q, a);
                    gx += u * g[0];
                    gy += u * g[1];
                }
                assert!((gx - 3.0).abs() < 1e-12 && (gy - 2.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn qpoints_lie_inside_elements() {
        let m = unit_square_tri(2);
        let quad = tri_deg2();
        let tab = RefElement::P1Tri.tabulate(&quad);
        let geo = compute(&m, &tab, &quad);
        for e in 0..m.n_cells() {
            for q in 0..quad.len() {
                let p = geo.qpoint(e, q);
                assert!(p[0] >= 0.0 && p[0] <= 1.0 && p[1] >= 0.0 && p[1] <= 1.0);
            }
        }
    }

    #[test]
    fn degenerate_padding_element_contributes_zero() {
        // A zero-area triangle (all nodes identical).
        let coords = vec![0.5, 0.5, 0.5, 0.5, 0.5, 0.5];
        let quad = tri_deg1();
        let tab = RefElement::P1Tri.tabulate(&quad);
        let geo = compute_from_coords(&coords, &tab, &quad, 2);
        assert_eq!(geo.det(0, 0), 0.0);
        assert!(geo.phys_grads.iter().all(|&g| g == 0.0));
    }

    #[test]
    fn facet_metric_edge_length() {
        // Edge from (0,0) to (3,4): length 5, metric must be 5.
        let coords = vec![0.0, 0.0, 3.0, 4.0];
        let quad = edge_gauss(2);
        let tab = RefElement::P1Edge.tabulate(&quad);
        let geo = compute_facets(&coords, &tab, &quad, 2);
        for q in 0..quad.len() {
            assert!((geo.det(0, q) - 5.0).abs() < 1e-12);
        }
        // Integral of 1 over the edge = Σ w_q · metric = 5.
        let total: f64 = (0..quad.len()).map(|q| quad.weights[q] * geo.det(0, q)).sum();
        assert!((total - 5.0).abs() < 1e-12);
    }

    #[test]
    fn facet_metric_triangle_area_3d() {
        // Triangle (0,0,0),(1,0,0),(0,1,0): area 1/2 → ∫1 = Σ w detj = 1/2.
        let coords = vec![0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0, 0.0];
        let quad = tri_deg2();
        let tab = RefElement::P1TriFacet.tabulate(&quad);
        let geo = compute_facets(&coords, &tab, &quad, 3);
        let total: f64 = (0..quad.len()).map(|q| quad.weights[q] * geo.det(0, q)).sum();
        assert!((total - 0.5).abs() < 1e-12);
    }
}
