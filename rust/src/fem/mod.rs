//! FEM core: reference elements, quadrature rules, DoF maps and batched
//! geometry — everything Stage I (Batch-Map) of TensorGalerkin consumes.

pub mod dofmap;
pub mod geometry;
pub mod quadrature;
pub mod reference;

pub use dofmap::DofMap;
pub use geometry::ElementGeometry;
pub use quadrature::Quadrature;
pub use reference::{RefElement, Tabulation};
