//! Quadrature rules on reference cells.
//!
//! Conventions: the reference triangle is `{x,y ≥ 0, x+y ≤ 1}` (area 1/2),
//! the reference tetrahedron `{x,y,z ≥ 0, x+y+z ≤ 1}` (volume 1/6), the
//! reference quadrilateral and edge are `[0,1]²` and `[0,1]`. Weights sum to
//! the reference measure so `∫_ê f ≈ Σ_q w_q f(x̂_q)` directly.

/// A quadrature rule: `Q` points in `dim` reference coordinates.
#[derive(Clone, Debug)]
pub struct Quadrature {
    pub dim: usize,
    /// `Q × dim`, row-major.
    pub points: Vec<f64>,
    pub weights: Vec<f64>,
}

impl Quadrature {
    pub fn len(&self) -> usize {
        self.weights.len()
    }

    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }

    pub fn point(&self, q: usize) -> &[f64] {
        &self.points[q * self.dim..(q + 1) * self.dim]
    }
}

/// Midpoint rule on the reference triangle (degree 1).
pub fn tri_deg1() -> Quadrature {
    Quadrature {
        dim: 2,
        points: vec![1.0 / 3.0, 1.0 / 3.0],
        weights: vec![0.5],
    }
}

/// Three-point rule, exact to degree 2 on the reference triangle.
pub fn tri_deg2() -> Quadrature {
    Quadrature {
        dim: 2,
        points: vec![1.0 / 6.0, 1.0 / 6.0, 2.0 / 3.0, 1.0 / 6.0, 1.0 / 6.0, 2.0 / 3.0],
        weights: vec![1.0 / 6.0; 3],
    }
}

/// Dunavant 7-point rule, exact to degree 5 on the reference triangle.
pub fn tri_deg5() -> Quadrature {
    let s15 = 15f64.sqrt();
    let a1 = (6.0 + s15) / 21.0;
    let a2 = (6.0 - s15) / 21.0;
    let w0 = 9.0 / 80.0;
    let w1 = (155.0 + s15) / 2400.0;
    let w2 = (155.0 - s15) / 2400.0;
    let mut points = vec![1.0 / 3.0, 1.0 / 3.0];
    let mut weights = vec![w0];
    for &(a, w) in &[(a1, w1), (a2, w2)] {
        let b = 1.0 - 2.0 * a;
        points.extend_from_slice(&[a, a, b, a, a, b]);
        weights.extend_from_slice(&[w, w, w]);
    }
    Quadrature { dim: 2, points, weights }
}

/// Midpoint rule on the reference tetrahedron (degree 1).
pub fn tet_deg1() -> Quadrature {
    Quadrature {
        dim: 3,
        points: vec![0.25, 0.25, 0.25],
        weights: vec![1.0 / 6.0],
    }
}

/// Four-point rule, exact to degree 2 on the reference tetrahedron.
pub fn tet_deg2() -> Quadrature {
    let a = (5.0 - 5f64.sqrt()) / 20.0;
    let b = (5.0 + 3.0 * 5f64.sqrt()) / 20.0;
    let mut points = Vec::with_capacity(12);
    for i in 0..4 {
        let mut p = [a, a, a];
        if i < 3 {
            p[i] = b;
        }
        points.extend_from_slice(&p);
    }
    Quadrature {
        dim: 3,
        points,
        weights: vec![1.0 / 24.0; 4],
    }
}

/// Tensor-product Gauss rule on `[0,1]²` with `n × n` points (n = 2 or 3).
pub fn quad_gauss(n: usize) -> Quadrature {
    let (nodes, weights) = gauss_01(n);
    let mut points = Vec::with_capacity(n * n * 2);
    let mut w = Vec::with_capacity(n * n);
    for j in 0..n {
        for i in 0..n {
            points.push(nodes[i]);
            points.push(nodes[j]);
            w.push(weights[i] * weights[j]);
        }
    }
    Quadrature { dim: 2, points, weights: w }
}

/// Gauss rule on the reference edge `[0,1]` with `n` points (1..=3).
pub fn edge_gauss(n: usize) -> Quadrature {
    let (nodes, weights) = gauss_01(n);
    Quadrature {
        dim: 1,
        points: nodes,
        weights,
    }
}

/// Gauss-Legendre nodes/weights mapped from `[-1,1]` to `[0,1]`.
fn gauss_01(n: usize) -> (Vec<f64>, Vec<f64>) {
    let (x, w): (Vec<f64>, Vec<f64>) = match n {
        1 => (vec![0.0], vec![2.0]),
        2 => {
            let a = 1.0 / 3f64.sqrt();
            (vec![-a, a], vec![1.0, 1.0])
        }
        3 => {
            let a = (3.0 / 5.0f64).sqrt();
            (vec![-a, 0.0, a], vec![5.0 / 9.0, 8.0 / 9.0, 5.0 / 9.0])
        }
        _ => panic!("gauss_01: unsupported order {n}"),
    };
    (
        x.iter().map(|t| 0.5 * (t + 1.0)).collect(),
        w.iter().map(|t| 0.5 * t).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn integrate_tri(q: &Quadrature, f: impl Fn(f64, f64) -> f64) -> f64 {
        (0..q.len()).map(|i| q.weights[i] * f(q.point(i)[0], q.point(i)[1])).sum()
    }

    #[test]
    fn tri_rules_integrate_polynomials_exactly() {
        // ∫_T 1 = 1/2; ∫_T x = 1/6; ∫_T x² = 1/12; ∫_T x²y = 1/60; ∫_T x⁴y = ?
        for q in [tri_deg1(), tri_deg2(), tri_deg5()] {
            assert!((integrate_tri(&q, |_, _| 1.0) - 0.5).abs() < 1e-14);
        }
        for q in [tri_deg2(), tri_deg5()] {
            assert!((integrate_tri(&q, |x, _| x) - 1.0 / 6.0).abs() < 1e-14);
            assert!((integrate_tri(&q, |x, y| x * y) - 1.0 / 24.0).abs() < 1e-14);
        }
        let q5 = tri_deg5();
        assert!((integrate_tri(&q5, |x, y| x * x * y) - 1.0 / 60.0).abs() < 1e-14);
        assert!(
            (integrate_tri(&q5, |x, y| x.powi(3) * y * y) - 1.0 / 420.0).abs() < 1e-14,
            "degree-5 exactness"
        );
    }

    #[test]
    fn tet_rules() {
        let q1 = tet_deg1();
        let q2 = tet_deg2();
        let int = |q: &Quadrature, f: &dyn Fn(&[f64]) -> f64| -> f64 {
            (0..q.len()).map(|i| q.weights[i] * f(q.point(i))).sum()
        };
        assert!((int(&q1, &|_| 1.0) - 1.0 / 6.0).abs() < 1e-14);
        assert!((int(&q2, &|_| 1.0) - 1.0 / 6.0).abs() < 1e-14);
        // ∫ x = 1/24, ∫ x y = 1/120.
        assert!((int(&q2, &|p| p[0]) - 1.0 / 24.0).abs() < 1e-14);
        assert!((int(&q2, &|p| p[0] * p[1]) - 1.0 / 120.0).abs() < 1e-14);
    }

    #[test]
    fn quad_and_edge_rules() {
        let q = quad_gauss(2);
        let int: f64 = (0..q.len())
            .map(|i| q.weights[i] * q.point(i)[0].powi(3) * q.point(i)[1])
            .sum();
        assert!((int - 0.25 * 0.5).abs() < 1e-14, "2x2 Gauss exact to degree 3");

        let e = edge_gauss(2);
        let int_e: f64 = (0..e.len()).map(|i| e.weights[i] * e.point(i)[0].powi(3)).sum();
        assert!((int_e - 0.25).abs() < 1e-14);

        let e3 = edge_gauss(3);
        let int_e5: f64 = (0..e3.len()).map(|i| e3.weights[i] * e3.point(i)[0].powi(5)).sum();
        assert!((int_e5 - 1.0 / 6.0).abs() < 1e-14);
    }
}
