//! Reference elements: basis values and gradients on the reference cell.
//!
//! First-order Lagrange bases on the simplicial / tensor-product reference
//! cells used throughout the paper (P1 triangles and tetrahedra, Q1
//! quadrilaterals for the SIMP benchmark, plus a P1 edge element for
//! Neumann/Robin boundary integrals).

use super::quadrature::Quadrature;
use crate::mesh::CellType;

/// A reference finite element.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefElement {
    /// P1 Lagrange on the reference triangle `{x,y≥0, x+y≤1}`.
    P1Tri,
    /// P1 Lagrange on the reference tetrahedron.
    P1Tet,
    /// Q1 (bilinear) Lagrange on `[0,1]²`, CCW node order.
    Q1Quad,
    /// P1 Lagrange on the reference edge `[0,1]` (boundary integrals, 2D).
    P1Edge,
    /// P1 Lagrange triangle used as a 3D boundary facet element.
    P1TriFacet,
}

impl RefElement {
    /// The volumetric element matching a mesh cell type.
    pub fn for_cell(ct: CellType) -> RefElement {
        match ct {
            CellType::Tri3 => RefElement::P1Tri,
            CellType::Tet4 => RefElement::P1Tet,
            CellType::Quad4 => RefElement::Q1Quad,
        }
    }

    /// The boundary facet element matching a mesh cell type.
    pub fn for_facet(ct: CellType) -> RefElement {
        match ct {
            CellType::Tri3 | CellType::Quad4 => RefElement::P1Edge,
            CellType::Tet4 => RefElement::P1TriFacet,
        }
    }

    /// Number of local basis functions.
    pub fn k(self) -> usize {
        match self {
            RefElement::P1Tri | RefElement::P1TriFacet => 3,
            RefElement::P1Tet => 4,
            RefElement::Q1Quad => 4,
            RefElement::P1Edge => 2,
        }
    }

    /// Reference-cell dimension (the parametric dimension, not the ambient).
    pub fn dim(self) -> usize {
        match self {
            RefElement::P1Tri | RefElement::Q1Quad | RefElement::P1TriFacet => 2,
            RefElement::P1Tet => 3,
            RefElement::P1Edge => 1,
        }
    }

    /// Basis values at a reference point (length `k`).
    pub fn basis(self, p: &[f64]) -> Vec<f64> {
        match self {
            RefElement::P1Tri | RefElement::P1TriFacet => {
                vec![1.0 - p[0] - p[1], p[0], p[1]]
            }
            RefElement::P1Tet => vec![1.0 - p[0] - p[1] - p[2], p[0], p[1], p[2]],
            RefElement::Q1Quad => {
                let (x, y) = (p[0], p[1]);
                vec![(1.0 - x) * (1.0 - y), x * (1.0 - y), x * y, (1.0 - x) * y]
            }
            RefElement::P1Edge => vec![1.0 - p[0], p[0]],
        }
    }

    /// Basis gradients at a reference point (`k × dim`, row-major).
    pub fn grads(self, p: &[f64]) -> Vec<f64> {
        match self {
            RefElement::P1Tri | RefElement::P1TriFacet => {
                vec![-1.0, -1.0, 1.0, 0.0, 0.0, 1.0]
            }
            RefElement::P1Tet => vec![
                -1.0, -1.0, -1.0, //
                1.0, 0.0, 0.0, //
                0.0, 1.0, 0.0, //
                0.0, 0.0, 1.0,
            ],
            RefElement::Q1Quad => {
                let (x, y) = (p[0], p[1]);
                vec![
                    -(1.0 - y),
                    -(1.0 - x),
                    1.0 - y,
                    -x,
                    y,
                    x,
                    -y,
                    1.0 - x,
                ]
            }
            RefElement::P1Edge => vec![-1.0, 1.0],
        }
    }

    /// Tabulate values and gradients at all quadrature points.
    pub fn tabulate(self, quad: &Quadrature) -> Tabulation {
        assert_eq!(quad.dim, self.dim(), "quadrature/element dimension mismatch");
        let k = self.k();
        let d = self.dim();
        let q = quad.len();
        let mut vals = Vec::with_capacity(q * k);
        let mut grads = Vec::with_capacity(q * k * d);
        for qi in 0..q {
            let p = quad.point(qi);
            vals.extend(self.basis(p));
            grads.extend(self.grads(p));
        }
        Tabulation {
            element: self,
            q,
            k,
            dim: d,
            vals,
            grads,
            weights: quad.weights.clone(),
        }
    }
}

/// Basis values/gradients tabulated at quadrature points.
#[derive(Clone, Debug)]
pub struct Tabulation {
    pub element: RefElement,
    pub q: usize,
    pub k: usize,
    pub dim: usize,
    /// `Q × k`.
    pub vals: Vec<f64>,
    /// `Q × k × dim`.
    pub grads: Vec<f64>,
    /// Quadrature weights (copied from the rule used to tabulate), so the
    /// Map stage needs only the tabulation + geometry.
    pub weights: Vec<f64>,
}

impl Tabulation {
    /// Value of basis `a` at quadrature point `q`.
    pub fn val(&self, q: usize, a: usize) -> f64 {
        self.vals[q * self.k + a]
    }

    /// Gradient (reference coords) of basis `a` at quadrature point `q`.
    pub fn grad(&self, q: usize, a: usize) -> &[f64] {
        let base = (q * self.k + a) * self.dim;
        &self.grads[base..base + self.dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fem::quadrature::{quad_gauss, tet_deg2, tri_deg2};

    #[test]
    fn partition_of_unity() {
        for el in [
            RefElement::P1Tri,
            RefElement::P1Tet,
            RefElement::Q1Quad,
            RefElement::P1Edge,
        ] {
            let p = vec![0.21; el.dim()];
            let sum: f64 = el.basis(&p).iter().sum();
            assert!((sum - 1.0).abs() < 1e-14, "{el:?} not a partition of unity");
            // Gradients of a partition of unity sum to zero.
            let g = el.grads(&p);
            for d in 0..el.dim() {
                let gsum: f64 = (0..el.k()).map(|a| g[a * el.dim() + d]).sum();
                assert!(gsum.abs() < 1e-14, "{el:?} grad sum nonzero");
            }
        }
    }

    #[test]
    fn kronecker_delta_at_nodes() {
        let nodes: Vec<Vec<f64>> = vec![vec![0.0, 0.0], vec![1.0, 0.0], vec![0.0, 1.0]];
        for (i, n) in nodes.iter().enumerate() {
            let vals = RefElement::P1Tri.basis(n);
            for (j, v) in vals.iter().enumerate() {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((v - expect).abs() < 1e-14);
            }
        }
        let qnodes: Vec<Vec<f64>> =
            vec![vec![0.0, 0.0], vec![1.0, 0.0], vec![1.0, 1.0], vec![0.0, 1.0]];
        for (i, n) in qnodes.iter().enumerate() {
            let vals = RefElement::Q1Quad.basis(n);
            for (j, v) in vals.iter().enumerate() {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((v - expect).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn q1_grads_match_finite_differences() {
        let el = RefElement::Q1Quad;
        let p = [0.3, 0.7];
        let g = el.grads(&p);
        let h = 1e-7;
        for a in 0..4 {
            for d in 0..2 {
                let mut pp = p;
                pp[d] += h;
                let mut pm = p;
                pm[d] -= h;
                let fd = (el.basis(&pp)[a] - el.basis(&pm)[a]) / (2.0 * h);
                assert!((g[a * 2 + d] - fd).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn tabulation_shapes() {
        for (el, quad) in [
            (RefElement::P1Tri, tri_deg2()),
            (RefElement::P1Tet, tet_deg2()),
            (RefElement::Q1Quad, quad_gauss(2)),
        ] {
            let t = el.tabulate(&quad);
            assert_eq!(t.vals.len(), t.q * t.k);
            assert_eq!(t.grads.len(), t.q * t.k * t.dim);
            assert_eq!(t.val(0, 0), el.basis(quad.point(0))[0]);
        }
    }
}
