//! # TensorGalerkin
//!
//! A Rust + JAX + Pallas reproduction of *"Learning, Solving and Optimizing
//! PDEs with TensorGalerkin: an efficient high-performance Galerkin assembly
//! algorithm"* (ICML 2026).
//!
//! The library reformulates Galerkin (FEM) assembly as a two-stage
//! **Map-Reduce**:
//!
//! * **Stage I — Batch-Map**: all `E` local element matrices
//!   `K_local ∈ R^{E×k×k}` are produced by one batched tensor contraction
//!   (natively in [`assembly::local`], or by an AOT-compiled Pallas kernel
//!   executed through the PJRT runtime in [`runtime`]).
//! * **Stage II — Sparse-Reduce**: local contributions are aggregated into
//!   the global CSR matrix with precomputed binary *routing matrices*
//!   applied as one deterministic sparse product ([`assembly::routing`]).
//!
//! Between the assembly engine and the applications sits the shared
//! per-mesh solver session ([`session`]): every downstream path solves
//! through one [`session::MeshSession`] owning the condensation plan,
//! preconditioner engine and warm-start state for its mesh.
//!
//! On top of the assembly engine sit the paper's three downstream systems:
//!
//! * **TensorMesh** — a numerical PDE solver ([`tensormesh`]),
//! * **TensorPILS** — physics-informed neural solvers & operator learning
//!   ([`pils`], [`oplearn`]),
//! * **TensorOpt** — end-to-end differentiable PDE-constrained optimization
//!   ([`opt`]).
//!
//! Python/JAX/Pallas run only at *build time* (`make artifacts`); the request
//! path is pure Rust + PJRT-compiled HLO artifacts.

pub mod analysis;
pub mod assembly;
pub mod bc;
pub mod coordinator;
pub mod experiments;
pub mod fem;
pub mod mesh;
pub mod oplearn;
pub mod opt;
pub mod pils;
pub mod runtime;
pub mod session;
pub mod solver;
pub mod sparse;
pub mod tensormesh;
pub mod timestep;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
