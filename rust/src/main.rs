//! `tensor-galerkin` — leader entrypoint / CLI.
//!
//! Subcommands map one-to-one onto the paper's experiments (DESIGN.md §5).

fn main() {
    let code = tensor_galerkin::experiments::cli::run(std::env::args().skip(1).collect());
    std::process::exit(code);
}
