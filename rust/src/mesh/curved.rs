//! Curved benchmark domains: circle (wave equation, mixed-BC Poisson) and
//! the non-convex "boomerang" (mixed-BC Poisson, §B.1.5).
//!
//! The circle is produced by the smooth, bijective elliptical square→disk
//! mapping (no degenerate corner elements, unlike naive polar grids); the
//! boomerang is a 3/4 annulus sector — non-convex with a re-entrant corner,
//! matching the role of the paper's boomerang geometry.

use super::structured::rect_tri;
use super::Mesh;

/// Triangulated disk of radius `r` centred at `(cx, cy)`, with `2·n²`
/// elements. Uses the elliptical mapping
/// `u = x·sqrt(1 - y²/2), v = y·sqrt(1 - x²/2)` from `[-1,1]²` to the unit
/// disk, which is smooth and orientation preserving.
pub fn circle_tri(n: usize, cx: f64, cy: f64, r: f64) -> Mesh {
    let mut m = rect_tri(n, n, 1.0, 1.0);
    m.map_points(|p| {
        let x = 2.0 * p[0] - 1.0;
        let y = 2.0 * p[1] - 1.0;
        let u = x * (1.0 - 0.5 * y * y).sqrt();
        let v = y * (1.0 - 0.5 * x * x).sqrt();
        vec![cx + r * u, cy + r * v]
    });
    m.extract_boundary();
    m
}

/// Paper's wave-equation domain: circle centred `(0.5, 0.5)`, radius `0.5`.
pub fn wave_circle(n: usize) -> Mesh {
    circle_tri(n, 0.5, 0.5, 0.5)
}

/// Non-convex "boomerang": the annulus sector
/// `r ∈ [r0, r1], θ ∈ [0, 3π/2]`, triangulated on an `(nr × nt)` parametric
/// grid. Re-entrant corner at the origin side makes the domain non-convex.
pub fn boomerang_tri(nr: usize, nt: usize, r0: f64, r1: f64) -> Mesh {
    assert!(r0 > 0.0 && r1 > r0);
    // p[0] parametrizes radius, p[1] the angle — this ordering keeps the
    // mapping orientation-preserving (det J = r·θ_max·(r1−r0) > 0).
    let mut m = rect_tri(nr, nt, 1.0, 1.0);
    let theta_max = 1.5 * std::f64::consts::PI;
    m.map_points(|p| {
        let r = r0 + (r1 - r0) * p[0];
        let theta = theta_max * p[1];
        vec![r * theta.cos(), r * theta.sin()]
    });
    m.extract_boundary();
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::quality;

    #[test]
    fn circle_is_a_disk() {
        let m = wave_circle(16);
        assert!(quality::min_cell_volume(&m) > 0.0);
        // Every node within radius (tolerance for the polygonal boundary).
        for i in 0..m.n_nodes() {
            let p = m.point(i);
            let d = ((p[0] - 0.5).powi(2) + (p[1] - 0.5).powi(2)).sqrt();
            assert!(d <= 0.5 + 1e-12);
        }
        // Area → π r² as n grows (polygonal deficit shrinks).
        let area = quality::total_volume(&m);
        let exact = std::f64::consts::PI * 0.25;
        assert!((area - exact).abs() / exact < 0.02, "area {area} vs {exact}");
    }

    #[test]
    fn circle_boundary_nodes_on_rim() {
        let m = wave_circle(12);
        for b in m.boundary_nodes() {
            let p = m.point(b);
            let d = ((p[0] - 0.5).powi(2) + (p[1] - 0.5).powi(2)).sqrt();
            assert!((d - 0.5).abs() < 1e-9, "boundary node at distance {d}");
        }
    }

    #[test]
    fn boomerang_valid_and_nonconvex() {
        let m = boomerang_tri(8, 48, 0.35, 1.0);
        assert!(quality::min_cell_volume(&m) > 0.0);
        let area = quality::total_volume(&m);
        let exact = 0.75 * std::f64::consts::PI * (1.0 - 0.35f64.powi(2));
        assert!((area - exact).abs() / exact < 0.02, "area {area} vs {exact}");
        // Non-convexity: the point (0.7, -0.1) lies in the convex hull but
        // outside the domain (θ stops at 3π/2 → fourth quadrant partially
        // missing near the positive x-axis below y=0)? Instead verify the
        // hole: origin is inside hull, outside domain.
        let (lo, hi) = m.bbox();
        assert!(lo[0] < 0.0 && hi[0] > 0.0 && lo[1] < 0.0 && hi[1] > 0.0);
        let min_r = (0..m.n_nodes())
            .map(|i| {
                let p = m.point(i);
                (p[0] * p[0] + p[1] * p[1]).sqrt()
            })
            .fold(f64::INFINITY, f64::min);
        assert!(min_r > 0.34, "annulus hole must be empty (min r = {min_r})");
    }
}
