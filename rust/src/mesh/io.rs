//! Mesh + field output in legacy VTK format (readable by ParaView), used by
//! the `--vtk` flags of the experiment drivers for the paper's qualitative
//! figures (Fig 2c-d, Fig 3, Fig 5, B.2, B.5, B.15-16, B.20).

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

use super::{CellType, Mesh};

/// VTK cell type ids.
fn vtk_cell_id(ct: CellType) -> usize {
    match ct {
        CellType::Tri3 => 5,
        CellType::Quad4 => 9,
        CellType::Tet4 => 10,
    }
}

/// Serialize the mesh plus named point/cell scalar fields as legacy VTK.
pub fn to_vtk(
    mesh: &Mesh,
    point_fields: &[(&str, &[f64])],
    cell_fields: &[(&str, &[f64])],
) -> String {
    let mut s = String::new();
    s.push_str("# vtk DataFile Version 3.0\ntensor-galerkin\nASCII\nDATASET UNSTRUCTURED_GRID\n");
    let n = mesh.n_nodes();
    let _ = writeln!(s, "POINTS {n} double");
    for i in 0..n {
        let p = mesh.point(i);
        let z = if mesh.dim == 3 { p[2] } else { 0.0 };
        let _ = writeln!(s, "{} {} {}", p[0], p[1], z);
    }
    let e = mesh.n_cells();
    let k = mesh.cell_type.nodes();
    let _ = writeln!(s, "CELLS {e} {}", e * (k + 1));
    for c in 0..e {
        let _ = write!(s, "{k}");
        for &v in mesh.cell(c) {
            let _ = write!(s, " {v}");
        }
        s.push('\n');
    }
    let _ = writeln!(s, "CELL_TYPES {e}");
    let id = vtk_cell_id(mesh.cell_type);
    for _ in 0..e {
        let _ = writeln!(s, "{id}");
    }
    if !point_fields.is_empty() {
        let _ = writeln!(s, "POINT_DATA {n}");
        for (name, values) in point_fields {
            assert_eq!(values.len(), n, "point field {name} wrong length");
            let _ = writeln!(s, "SCALARS {name} double 1\nLOOKUP_TABLE default");
            for v in *values {
                let _ = writeln!(s, "{v}");
            }
        }
    }
    if !cell_fields.is_empty() {
        let _ = writeln!(s, "CELL_DATA {e}");
        for (name, values) in cell_fields {
            assert_eq!(values.len(), e, "cell field {name} wrong length");
            let _ = writeln!(s, "SCALARS {name} double 1\nLOOKUP_TABLE default");
            for v in *values {
                let _ = writeln!(s, "{v}");
            }
        }
    }
    s
}

/// Write VTK to disk, creating parent directories.
pub fn write_vtk(
    path: impl AsRef<Path>,
    mesh: &Mesh,
    point_fields: &[(&str, &[f64])],
    cell_fields: &[(&str, &[f64])],
) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, to_vtk(mesh, point_fields, cell_fields))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::structured::unit_square_tri;

    #[test]
    fn vtk_contains_sections() {
        let m = unit_square_tri(2);
        let u = vec![1.0; m.n_nodes()];
        let rho = vec![0.5; m.n_cells()];
        let s = to_vtk(&m, &[("u", &u)], &[("rho", &rho)]);
        for section in ["POINTS 9 double", "CELLS 8 32", "CELL_TYPES 8", "POINT_DATA 9", "CELL_DATA 8"] {
            assert!(s.contains(section), "missing {section}");
        }
    }

    #[test]
    #[should_panic]
    fn wrong_field_length_panics() {
        let m = unit_square_tri(2);
        let bad = vec![0.0; 3];
        to_vtk(&m, &[("u", &bad)], &[]);
    }
}
