//! Mesh substrate: generation, topology, refinement, quality and I/O.
//!
//! The paper relies on Gmsh for unstructured meshes; offline we generate all
//! benchmark geometries ourselves (DESIGN.md §7): structured triangulations,
//! quad grids, Kuhn tetrahedralizations, plus curved domains (circle via a
//! square→disk mapping, L-shape, non-convex "boomerang" annulus sector) and
//! an interior-node jitter pass that produces genuinely unstructured
//! geometry while preserving validity.

pub mod curved;
pub mod io;
pub mod quality;
pub mod refine;
pub mod structured;

use std::collections::HashMap;

/// Element topology supported by the assembly engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CellType {
    /// Linear triangle (3 nodes, 2D).
    Tri3,
    /// Bilinear quadrilateral (4 nodes, 2D).
    Quad4,
    /// Linear tetrahedron (4 nodes, 3D).
    Tet4,
}

impl CellType {
    /// Nodes per cell.
    pub fn nodes(self) -> usize {
        match self {
            CellType::Tri3 => 3,
            CellType::Quad4 => 4,
            CellType::Tet4 => 4,
        }
    }

    /// Spatial dimension.
    pub fn dim(self) -> usize {
        match self {
            CellType::Tri3 | CellType::Quad4 => 2,
            CellType::Tet4 => 3,
        }
    }

    /// Nodes per boundary facet (edge in 2D, triangle face in 3D).
    pub fn facet_nodes(self) -> usize {
        match self {
            CellType::Tri3 | CellType::Quad4 => 2,
            CellType::Tet4 => 3,
        }
    }

    /// Local facet → local node indices.
    pub fn facets(self) -> &'static [&'static [usize]] {
        match self {
            CellType::Tri3 => &[&[0, 1], &[1, 2], &[2, 0]],
            CellType::Quad4 => &[&[0, 1], &[1, 2], &[2, 3], &[3, 0]],
            // Faces opposite each vertex, outward-consistent for the
            // positively oriented reference tet.
            CellType::Tet4 => &[&[1, 2, 3], &[0, 3, 2], &[0, 1, 3], &[0, 2, 1]],
        }
    }
}

/// Boundary facet marker values used by the benchmark geometries.
pub mod marker {
    /// Default marker for all boundary facets.
    pub const BOUNDARY: u32 = 1;
    /// Dirichlet portion in mixed-BC benchmarks.
    pub const DIRICHLET: u32 = 1;
    /// Neumann portion.
    pub const NEUMANN: u32 = 2;
    /// Robin portion.
    pub const ROBIN: u32 = 3;
}

/// An unstructured conforming mesh.
///
/// `points` is `N × dim` row-major; `cells` is `E × k` row-major with `k =
/// cell_type.nodes()`. Boundary facets are extracted from topology (facets
/// incident to exactly one cell) and carry integer markers used to split the
/// boundary into Dirichlet/Neumann/Robin parts.
#[derive(Clone, Debug)]
pub struct Mesh {
    pub dim: usize,
    pub points: Vec<f64>,
    pub cells: Vec<usize>,
    pub cell_type: CellType,
    /// Boundary facets, `F × facet_nodes` row-major.
    pub facets: Vec<usize>,
    /// One marker per boundary facet.
    pub facet_markers: Vec<u32>,
}

impl Mesh {
    /// Build a mesh from raw points/cells, extracting boundary facets.
    pub fn new(dim: usize, points: Vec<f64>, cells: Vec<usize>, cell_type: CellType) -> Mesh {
        assert_eq!(dim, cell_type.dim());
        assert_eq!(points.len() % dim, 0);
        assert_eq!(cells.len() % cell_type.nodes(), 0);
        let mut mesh = Mesh {
            dim,
            points,
            cells,
            cell_type,
            facets: Vec::new(),
            facet_markers: Vec::new(),
        };
        mesh.extract_boundary();
        mesh
    }

    pub fn n_nodes(&self) -> usize {
        self.points.len() / self.dim
    }

    pub fn n_cells(&self) -> usize {
        self.cells.len() / self.cell_type.nodes()
    }

    pub fn n_facets(&self) -> usize {
        self.facet_markers.len()
    }

    /// Coordinates of node `i`.
    pub fn point(&self, i: usize) -> &[f64] {
        &self.points[i * self.dim..(i + 1) * self.dim]
    }

    /// Node indices of cell `e`.
    pub fn cell(&self, e: usize) -> &[usize] {
        let k = self.cell_type.nodes();
        &self.cells[e * k..(e + 1) * k]
    }

    /// Node indices of boundary facet `f`.
    pub fn facet(&self, f: usize) -> &[usize] {
        let k = self.cell_type.facet_nodes();
        &self.facets[f * k..(f + 1) * k]
    }

    /// Recompute `facets`/`facet_markers` from cell topology. Every facet
    /// incident to exactly one cell is a boundary facet (marker 1).
    pub fn extract_boundary(&mut self) {
        let fk = self.cell_type.facet_nodes();
        let mut seen: HashMap<Vec<usize>, (usize, Vec<usize>)> = HashMap::new();
        for e in 0..self.n_cells() {
            let cell = self.cell(e);
            for loc in self.cell_type.facets() {
                let facet: Vec<usize> = loc.iter().map(|&a| cell[a]).collect();
                let mut key = facet.clone();
                key.sort_unstable();
                seen.entry(key)
                    .and_modify(|(c, _)| *c += 1)
                    .or_insert((1, facet));
            }
        }
        let mut boundary: Vec<Vec<usize>> = seen
            .into_values()
            .filter(|(count, _)| *count == 1)
            .map(|(_, facet)| facet)
            .collect();
        // Deterministic order regardless of HashMap iteration.
        boundary.sort();
        self.facets = Vec::with_capacity(boundary.len() * fk);
        for f in &boundary {
            self.facets.extend_from_slice(f);
        }
        self.facet_markers = vec![marker::BOUNDARY; boundary.len()];
    }

    /// Set of node indices lying on boundary facets with any of `markers`
    /// (sorted, deduplicated).
    pub fn boundary_nodes_with(&self, markers: &[u32]) -> Vec<usize> {
        let mut nodes: Vec<usize> = (0..self.n_facets())
            .filter(|&f| markers.contains(&self.facet_markers[f]))
            .flat_map(|f| self.facet(f).to_vec())
            .collect();
        nodes.sort_unstable();
        nodes.dedup();
        nodes
    }

    /// All boundary node indices.
    pub fn boundary_nodes(&self) -> Vec<usize> {
        let mut nodes: Vec<usize> = self.facets.clone();
        nodes.sort_unstable();
        nodes.dedup();
        nodes
    }

    /// Re-mark boundary facets with a classifier on the facet centroid.
    pub fn mark_boundary(&mut self, classify: impl Fn(&[f64]) -> u32) {
        let fk = self.cell_type.facet_nodes();
        for f in 0..self.n_facets() {
            let mut c = vec![0.0; self.dim];
            let facet: Vec<usize> = self.facet(f).to_vec();
            for n in facet {
                for d in 0..self.dim {
                    c[d] += self.point(n)[d] / fk as f64;
                }
            }
            self.facet_markers[f] = classify(&c);
        }
    }

    /// For each boundary facet, the index of its (unique) owning cell.
    pub fn facet_owners(&self) -> Vec<usize> {
        let mut owner: HashMap<Vec<usize>, usize> = HashMap::new();
        for e in 0..self.n_cells() {
            let cell = self.cell(e);
            for loc in self.cell_type.facets() {
                let mut key: Vec<usize> = loc.iter().map(|&a| cell[a]).collect();
                key.sort_unstable();
                owner.insert(key, e);
            }
        }
        (0..self.n_facets())
            .map(|f| {
                let mut key = self.facet(f).to_vec();
                key.sort_unstable();
                owner[&key]
            })
            .collect()
    }

    /// Outward unit normals of all boundary facets (2D meshes): the edge
    /// tangent rotated by 90°, oriented away from the owning cell's
    /// centroid — correct for non-convex domains (boomerang, L-shape,
    /// hollow interiors), unlike domain-centroid heuristics.
    pub fn facet_outward_normals_2d(&self) -> Vec<[f64; 2]> {
        assert_eq!(self.dim, 2);
        let owners = self.facet_owners();
        let k = self.cell_type.nodes();
        (0..self.n_facets())
            .map(|f| {
                let fac = self.facet(f);
                let (a, b) = (self.point(fac[0]), self.point(fac[1]));
                let tx = b[0] - a[0];
                let ty = b[1] - a[1];
                let len = (tx * tx + ty * ty).sqrt();
                let mut n = [ty / len, -tx / len];
                // Owning cell centroid.
                let cell = self.cell(owners[f]);
                let mut cx = 0.0;
                let mut cy = 0.0;
                for &v in cell {
                    cx += self.point(v)[0] / k as f64;
                    cy += self.point(v)[1] / k as f64;
                }
                let mx = 0.5 * (a[0] + b[0]) - cx;
                let my = 0.5 * (a[1] + b[1]) - cy;
                if n[0] * mx + n[1] * my < 0.0 {
                    n = [-n[0], -n[1]];
                }
                n
            })
            .collect()
    }

    /// Bounding box `(min, max)` of all nodes.
    pub fn bbox(&self) -> (Vec<f64>, Vec<f64>) {
        let mut lo = vec![f64::INFINITY; self.dim];
        let mut hi = vec![f64::NEG_INFINITY; self.dim];
        for i in 0..self.n_nodes() {
            for (d, &x) in self.point(i).iter().enumerate() {
                lo[d] = lo[d].min(x);
                hi[d] = hi[d].max(x);
            }
        }
        (lo, hi)
    }

    /// Characteristic mesh size: max edge length over all cells.
    pub fn h_max(&self) -> f64 {
        let mut h: f64 = 0.0;
        for e in 0..self.n_cells() {
            let cell = self.cell(e);
            for i in 0..cell.len() {
                for j in (i + 1)..cell.len() {
                    let (a, b) = (self.point(cell[i]), self.point(cell[j]));
                    let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
                    h = h.max(d2.sqrt());
                }
            }
        }
        h
    }

    /// Apply a smooth coordinate mapping to all nodes (used by curved
    /// domain generators).
    pub fn map_points(&mut self, f: impl Fn(&[f64]) -> Vec<f64>) {
        let dim = self.dim;
        let n = self.n_nodes();
        for i in 0..n {
            let original = self.points[i * dim..(i + 1) * dim].to_vec();
            let mapped = f(&original);
            assert_eq!(mapped.len(), dim);
            self.points[i * dim..(i + 1) * dim].copy_from_slice(&mapped);
        }
    }

    /// Drop nodes not referenced by any cell, compacting indices.
    pub fn remove_unused_nodes(&mut self) {
        let n = self.n_nodes();
        let mut used = vec![false; n];
        for &c in &self.cells {
            used[c] = true;
        }
        let mut remap = vec![usize::MAX; n];
        let mut new_points = Vec::new();
        let mut next = 0;
        for i in 0..n {
            if used[i] {
                remap[i] = next;
                new_points.extend_from_slice(&self.points[i * self.dim..(i + 1) * self.dim]);
                next += 1;
            }
        }
        self.points = new_points;
        for c in self.cells.iter_mut() {
            *c = remap[*c];
        }
        self.extract_boundary();
    }
}

#[cfg(test)]
mod tests {
    use super::structured::unit_square_tri;
    use super::*;

    #[test]
    fn boundary_extraction_unit_square() {
        let m = unit_square_tri(4);
        assert_eq!(m.n_nodes(), 25);
        assert_eq!(m.n_cells(), 32);
        // 4 sides × 4 edges each.
        assert_eq!(m.n_facets(), 16);
        assert_eq!(m.boundary_nodes().len(), 16);
    }

    #[test]
    fn mark_boundary_by_side() {
        let mut m = unit_square_tri(4);
        m.mark_boundary(|c| if c[0] < 1e-12 { marker::NEUMANN } else { marker::DIRICHLET });
        let neumann = m.boundary_nodes_with(&[marker::NEUMANN]);
        assert_eq!(neumann.len(), 5); // left edge nodes
        for &n in &neumann {
            assert!(m.point(n)[0].abs() < 1e-12);
        }
    }

    #[test]
    fn bbox_and_hmax() {
        let m = unit_square_tri(8);
        let (lo, hi) = m.bbox();
        assert_eq!(lo, vec![0.0, 0.0]);
        assert_eq!(hi, vec![1.0, 1.0]);
        let h = m.h_max();
        assert!((h - (2.0f64).sqrt() / 8.0).abs() < 1e-12);
    }

    #[test]
    fn remove_unused_nodes_compacts() {
        let mut m = unit_square_tri(2);
        // Keep only the first two cells.
        m.cells.truncate(2 * 3);
        m.remove_unused_nodes();
        assert!(m.n_nodes() <= 6);
        for &c in &m.cells {
            assert!(c < m.n_nodes());
        }
    }
}
