//! Mesh quality metrics: signed cell volumes, totals, angle bounds.
//!
//! Used both by generator tests (no inverted elements) and by the assembly
//! engine's degenerate-element padding scheme (padded elements have exactly
//! zero volume and must contribute nothing).

use super::{CellType, Mesh};

/// Signed volume (area in 2D) of cell `e`.
pub fn cell_volume(mesh: &Mesh, e: usize) -> f64 {
    let c = mesh.cell(e);
    match mesh.cell_type {
        CellType::Tri3 => {
            let (a, b, d) = (mesh.point(c[0]), mesh.point(c[1]), mesh.point(c[2]));
            0.5 * ((b[0] - a[0]) * (d[1] - a[1]) - (d[0] - a[0]) * (b[1] - a[1]))
        }
        CellType::Quad4 => {
            // Shoelace over the 4 vertices (valid for planar, convex or not).
            let mut area = 0.0;
            for i in 0..4 {
                let p = mesh.point(c[i]);
                let q = mesh.point(c[(i + 1) % 4]);
                area += p[0] * q[1] - q[0] * p[1];
            }
            0.5 * area
        }
        CellType::Tet4 => {
            let (a, b, cc, d) = (
                mesh.point(c[0]),
                mesh.point(c[1]),
                mesh.point(c[2]),
                mesh.point(c[3]),
            );
            let u = [b[0] - a[0], b[1] - a[1], b[2] - a[2]];
            let v = [cc[0] - a[0], cc[1] - a[1], cc[2] - a[2]];
            let w = [d[0] - a[0], d[1] - a[1], d[2] - a[2]];
            let det = u[0] * (v[1] * w[2] - v[2] * w[1]) - u[1] * (v[0] * w[2] - v[2] * w[0])
                + u[2] * (v[0] * w[1] - v[1] * w[0]);
            det / 6.0
        }
    }
}

/// Minimum signed cell volume — positive iff no element is inverted.
pub fn min_cell_volume(mesh: &Mesh) -> f64 {
    (0..mesh.n_cells())
        .map(|e| cell_volume(mesh, e))
        .fold(f64::INFINITY, f64::min)
}

/// Sum of signed volumes — the measure of the domain for valid meshes.
pub fn total_volume(mesh: &Mesh) -> f64 {
    (0..mesh.n_cells()).map(|e| cell_volume(mesh, e)).sum()
}

/// Minimum interior angle over all triangles, in radians (Tri3 only).
pub fn min_angle_tri(mesh: &Mesh) -> f64 {
    assert_eq!(mesh.cell_type, CellType::Tri3);
    let mut min_angle = f64::INFINITY;
    for e in 0..mesh.n_cells() {
        let c = mesh.cell(e);
        for i in 0..3 {
            let p = mesh.point(c[i]);
            let q = mesh.point(c[(i + 1) % 3]);
            let r = mesh.point(c[(i + 2) % 3]);
            let u = [q[0] - p[0], q[1] - p[1]];
            let v = [r[0] - p[0], r[1] - p[1]];
            let nu = (u[0] * u[0] + u[1] * u[1]).sqrt();
            let nv = (v[0] * v[0] + v[1] * v[1]).sqrt();
            let cosang = ((u[0] * v[0] + u[1] * v[1]) / (nu * nv)).clamp(-1.0, 1.0);
            min_angle = min_angle.min(cosang.acos());
        }
    }
    min_angle
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::structured::{unit_cube_tet, unit_square_tri};

    #[test]
    fn triangle_angles_structured() {
        let m = unit_square_tri(4);
        let a = min_angle_tri(&m);
        // Structured right triangles: min angle = 45°.
        assert!((a - std::f64::consts::FRAC_PI_4).abs() < 1e-12);
    }

    #[test]
    fn angle_sum_property() {
        // Property: for random valid triangles the minimum angle is ≤ 60°.
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..100 {
            let pts = vec![
                rng.uniform(),
                rng.uniform(),
                rng.uniform() + 1.5,
                rng.uniform(),
                rng.uniform(),
                rng.uniform() + 1.5,
            ];
            let m = super::super::Mesh::new(2, pts, vec![0, 1, 2], CellType::Tri3);
            if min_cell_volume(&m) > 1e-9 {
                assert!(min_angle_tri(&m) <= std::f64::consts::FRAC_PI_3 + 1e-12);
            }
        }
    }

    #[test]
    fn tet_volumes_positive() {
        let m = unit_cube_tet(3);
        assert!(min_cell_volume(&m) > 0.0);
        assert!((total_volume(&m) - 1.0).abs() < 1e-12);
    }
}
