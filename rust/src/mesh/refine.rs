//! Uniform mesh refinement.
//!
//! Red refinement for triangles (each parent → 4 similar children) —
//! used by convergence studies and by the "dynamic mesh" agility benchmark
//! (the paper's adaptive-refinement motivation for zero-compilation
//! assembly: topology changes every refinement, so routing matrices are
//! rebuilt while PJRT artifacts stay valid thanks to bucket padding).

use std::collections::HashMap;

use super::{CellType, Mesh};

/// Uniformly refine a triangle mesh once: every edge is bisected and each
/// triangle is split into 4. Node ordering keeps children positively
/// oriented when parents are.
pub fn refine_tri(mesh: &Mesh) -> Mesh {
    assert_eq!(mesh.cell_type, CellType::Tri3);
    let mut points = mesh.points.clone();
    let mut midpoint: HashMap<(usize, usize), usize> = HashMap::new();
    let mut mid = |a: usize, b: usize, points: &mut Vec<f64>| -> usize {
        let key = (a.min(b), a.max(b));
        if let Some(&m) = midpoint.get(&key) {
            return m;
        }
        let pa = [points[a * 2], points[a * 2 + 1]];
        let pb = [points[b * 2], points[b * 2 + 1]];
        let idx = points.len() / 2;
        points.push(0.5 * (pa[0] + pb[0]));
        points.push(0.5 * (pa[1] + pb[1]));
        midpoint.insert(key, idx);
        idx
    };

    let mut cells = Vec::with_capacity(mesh.cells.len() * 4);
    for e in 0..mesh.n_cells() {
        let c = mesh.cell(e);
        let (v0, v1, v2) = (c[0], c[1], c[2]);
        let m01 = mid(v0, v1, &mut points);
        let m12 = mid(v1, v2, &mut points);
        let m20 = mid(v2, v0, &mut points);
        cells.extend_from_slice(&[v0, m01, m20]);
        cells.extend_from_slice(&[m01, v1, m12]);
        cells.extend_from_slice(&[m20, m12, v2]);
        cells.extend_from_slice(&[m01, m12, m20]);
    }
    Mesh::new(2, points, cells, CellType::Tri3)
}

/// Refine `levels` times.
pub fn refine_tri_n(mesh: &Mesh, levels: usize) -> Mesh {
    let mut m = mesh.clone();
    for _ in 0..levels {
        m = refine_tri(&m);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::quality::{min_cell_volume, total_volume};
    use crate::mesh::structured::unit_square_tri;

    #[test]
    fn refine_quadruples_cells_preserves_area() {
        let m = unit_square_tri(2);
        let r = refine_tri(&m);
        assert_eq!(r.n_cells(), 4 * m.n_cells());
        assert!((total_volume(&r) - 1.0).abs() < 1e-12);
        assert!(min_cell_volume(&r) > 0.0);
    }

    #[test]
    fn refine_shares_edge_midpoints() {
        let m = unit_square_tri(2);
        let r = refine_tri(&m);
        // Euler: refined structured square with n=2 → grid n=4: 25 nodes.
        assert_eq!(r.n_nodes(), 25);
    }

    #[test]
    fn multi_level() {
        let m = unit_square_tri(1);
        let r = refine_tri_n(&m, 3);
        assert_eq!(r.n_cells(), 2 * 64);
        assert!((total_volume(&r) - 1.0).abs() < 1e-12);
    }
}
