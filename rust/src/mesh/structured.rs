//! Structured mesh generators for the benchmark domains.
//!
//! All generators produce positively oriented elements (checked in
//! [`crate::mesh::quality`] tests). "Unstructured" variants are obtained by
//! applying [`jitter`] to interior nodes — this exercises exactly the same
//! code paths as a Gmsh mesh (arbitrary local→global maps, element-dependent
//! Jacobians) while remaining reproducible offline.

use super::{CellType, Mesh};
use crate::util::rng::Rng;

/// Triangulated rectangle `[0,Lx]×[0,Ly]` with `nx × ny` cells split into 2
/// triangles each (`2·nx·ny` elements, `(nx+1)(ny+1)` nodes).
pub fn rect_tri(nx: usize, ny: usize, lx: f64, ly: f64) -> Mesh {
    assert!(nx > 0 && ny > 0);
    let mut points = Vec::with_capacity((nx + 1) * (ny + 1) * 2);
    for j in 0..=ny {
        for i in 0..=nx {
            points.push(lx * i as f64 / nx as f64);
            points.push(ly * j as f64 / ny as f64);
        }
    }
    let id = |i: usize, j: usize| j * (nx + 1) + i;
    let mut cells = Vec::with_capacity(nx * ny * 6);
    for j in 0..ny {
        for i in 0..nx {
            let (a, b, c, d) = (id(i, j), id(i + 1, j), id(i + 1, j + 1), id(i, j + 1));
            // Alternate the diagonal to avoid a globally biased mesh.
            if (i + j) % 2 == 0 {
                cells.extend_from_slice(&[a, b, c]);
                cells.extend_from_slice(&[a, c, d]);
            } else {
                cells.extend_from_slice(&[a, b, d]);
                cells.extend_from_slice(&[b, c, d]);
            }
        }
    }
    Mesh::new(2, points, cells, CellType::Tri3)
}

/// Unit square `[0,1]²` triangulation with `n × n × 2` elements.
pub fn unit_square_tri(n: usize) -> Mesh {
    rect_tri(n, n, 1.0, 1.0)
}

/// Quadrilateral (Q4) rectangle mesh `[0,Lx]×[0,Ly]`, `nx × ny` cells.
/// Node ordering per cell is counter-clockwise — the standard Q4 convention
/// used by the SIMP topology-optimization benchmark.
pub fn rect_quad(nx: usize, ny: usize, lx: f64, ly: f64) -> Mesh {
    assert!(nx > 0 && ny > 0);
    let mut points = Vec::with_capacity((nx + 1) * (ny + 1) * 2);
    for j in 0..=ny {
        for i in 0..=nx {
            points.push(lx * i as f64 / nx as f64);
            points.push(ly * j as f64 / ny as f64);
        }
    }
    let id = |i: usize, j: usize| j * (nx + 1) + i;
    let mut cells = Vec::with_capacity(nx * ny * 4);
    for j in 0..ny {
        for i in 0..nx {
            cells.extend_from_slice(&[id(i, j), id(i + 1, j), id(i + 1, j + 1), id(i, j + 1)]);
        }
    }
    Mesh::new(2, points, cells, CellType::Quad4)
}

/// L-shaped domain: `[0,1]² \ (0.5,1]×(0.5,1]`, triangulated. Used by the
/// Allen-Cahn operator-learning benchmark (paper §B.3).
pub fn lshape_tri(n: usize) -> Mesh {
    assert!(n >= 2 && n % 2 == 0, "lshape_tri needs even n");
    let full = rect_tri(n, n, 1.0, 1.0);
    // Keep cells whose centroid is outside the removed quadrant.
    let mut cells = Vec::new();
    for e in 0..full.n_cells() {
        let cell = full.cell(e);
        let cx: f64 = cell.iter().map(|&v| full.point(v)[0]).sum::<f64>() / 3.0;
        let cy: f64 = cell.iter().map(|&v| full.point(v)[1]).sum::<f64>() / 3.0;
        if !(cx > 0.5 && cy > 0.5) {
            cells.extend_from_slice(cell);
        }
    }
    let mut m = Mesh {
        dim: 2,
        points: full.points,
        cells,
        cell_type: CellType::Tri3,
        facets: Vec::new(),
        facet_markers: Vec::new(),
    };
    m.remove_unused_nodes();
    m
}

/// Kuhn (6-tet) tetrahedralization of the box `[0,Lx]×[0,Ly]×[0,Lz]` with
/// `nx × ny × nz` cubes. All tets positively oriented.
pub fn box_tet(nx: usize, ny: usize, nz: usize, l: [f64; 3]) -> Mesh {
    assert!(nx > 0 && ny > 0 && nz > 0);
    let mut points = Vec::with_capacity((nx + 1) * (ny + 1) * (nz + 1) * 3);
    for k in 0..=nz {
        for j in 0..=ny {
            for i in 0..=nx {
                points.push(l[0] * i as f64 / nx as f64);
                points.push(l[1] * j as f64 / ny as f64);
                points.push(l[2] * k as f64 / nz as f64);
            }
        }
    }
    let id = |i: usize, j: usize, k: usize| (k * (ny + 1) + j) * (nx + 1) + i;
    // Kuhn triangulation of the unit cube: 6 tets around the main diagonal
    // v0→v6, each positively oriented.
    const TETS: [[usize; 4]; 6] = [
        [0, 1, 3, 7],
        [0, 1, 7, 5],
        [0, 5, 7, 4],
        [0, 3, 2, 7],
        [0, 2, 6, 7],
        [0, 6, 4, 7],
    ];
    let mut cells = Vec::with_capacity(nx * ny * nz * 24);
    for k in 0..nz {
        for j in 0..ny {
            for i in 0..nx {
                let v = [
                    id(i, j, k),
                    id(i + 1, j, k),
                    id(i, j + 1, k),
                    id(i + 1, j + 1, k),
                    id(i, j, k + 1),
                    id(i + 1, j, k + 1),
                    id(i, j + 1, k + 1),
                    id(i + 1, j + 1, k + 1),
                ];
                for t in TETS {
                    cells.extend_from_slice(&[v[t[0]], v[t[1]], v[t[2]], v[t[3]]]);
                }
            }
        }
    }
    Mesh::new(3, points, cells, CellType::Tet4)
}

/// Unit cube `[0,1]³` tetrahedralization with `n³·6` elements
/// (Fig 2 Poisson benchmark).
pub fn unit_cube_tet(n: usize) -> Mesh {
    box_tet(n, n, n, [1.0, 1.0, 1.0])
}

/// Hollow cube `[0,1]³ \ (0.25,0.75)³` (Fig 2 elasticity benchmark,
/// Eq. B.5). `n` must be divisible by 4 so the cavity is resolved exactly.
pub fn hollow_cube_tet(n: usize) -> Mesh {
    assert!(n >= 4 && n % 4 == 0, "hollow_cube_tet needs n divisible by 4");
    let full = box_tet(n, n, n, [1.0, 1.0, 1.0]);
    let mut cells = Vec::new();
    for e in 0..full.n_cells() {
        let cell = full.cell(e);
        let mut c = [0.0f64; 3];
        for &v in cell {
            let p = full.point(v);
            for d in 0..3 {
                c[d] += p[d] / 4.0;
            }
        }
        let inside = c.iter().all(|&x| x > 0.25 && x < 0.75);
        if !inside {
            cells.extend_from_slice(cell);
        }
    }
    let mut m = Mesh {
        dim: 3,
        points: full.points,
        cells,
        cell_type: CellType::Tet4,
        facets: Vec::new(),
        facet_markers: Vec::new(),
    };
    m.remove_unused_nodes();
    m
}

/// Perturb interior nodes by `amount · h` in each coordinate
/// (`amount ≤ 0.25` keeps structured simplicial meshes valid). Boundary
/// nodes are left untouched so the geometry is preserved.
pub fn jitter(mesh: &mut Mesh, amount: f64, seed: u64) {
    assert!(amount >= 0.0 && amount < 0.5);
    let mut rng = Rng::new(seed);
    let h = mesh.h_max() / (2.0f64).sqrt(); // roughly the grid spacing
    let boundary = mesh.boundary_nodes();
    let mut is_boundary = vec![false; mesh.n_nodes()];
    for b in boundary {
        is_boundary[b] = true;
    }
    let dim = mesh.dim;
    for i in 0..mesh.n_nodes() {
        if is_boundary[i] {
            continue;
        }
        for d in 0..dim {
            mesh.points[i * dim + d] += rng.uniform_in(-amount * h, amount * h);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::quality;

    #[test]
    fn rect_tri_counts_and_orientation() {
        let m = rect_tri(3, 5, 2.0, 1.0);
        assert_eq!(m.n_nodes(), 4 * 6);
        assert_eq!(m.n_cells(), 30);
        assert!(quality::min_cell_volume(&m) > 0.0);
        // Total area = 2.0 × 1.0.
        assert!((quality::total_volume(&m) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn quad_mesh_counts() {
        let m = rect_quad(60, 30, 60.0, 30.0);
        assert_eq!(m.n_nodes(), 61 * 31); // 1,891 nodes — paper's §B.4 mesh
        assert_eq!(m.n_cells(), 1800);
        assert!((quality::total_volume(&m) - 1800.0).abs() < 1e-9);
    }

    #[test]
    fn cube_tet_volume_and_orientation() {
        let m = unit_cube_tet(4);
        assert_eq!(m.n_cells(), 4 * 4 * 4 * 6);
        assert!(quality::min_cell_volume(&m) > 0.0, "inverted tets");
        assert!((quality::total_volume(&m) - 1.0).abs() < 1e-12);
        // Boundary of a cube with n=4: 6 faces × 16 squares × 2 tris.
        assert_eq!(m.n_facets(), 6 * 16 * 2);
    }

    #[test]
    fn hollow_cube_removes_cavity() {
        let m = hollow_cube_tet(4);
        assert!((quality::total_volume(&m) - (1.0 - 0.125)).abs() < 1e-12);
        assert!(quality::min_cell_volume(&m) > 0.0);
    }

    #[test]
    fn lshape_area() {
        let m = lshape_tri(8);
        assert!((quality::total_volume(&m) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn jitter_keeps_mesh_valid_and_boundary_fixed() {
        let mut m = unit_square_tri(8);
        let before = m.boundary_nodes();
        let coords_before: Vec<f64> = before.iter().flat_map(|&b| m.point(b).to_vec()).collect();
        jitter(&mut m, 0.2, 42);
        let coords_after: Vec<f64> = before.iter().flat_map(|&b| m.point(b).to_vec()).collect();
        assert_eq!(coords_before, coords_after);
        assert!(quality::min_cell_volume(&m) > 0.0, "jitter inverted an element");
    }

    #[test]
    fn jitter_3d_valid() {
        let mut m = unit_cube_tet(4);
        jitter(&mut m, 0.15, 7);
        assert!(quality::min_cell_volume(&m) > 0.0);
    }
}
