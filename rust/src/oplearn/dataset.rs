//! Problem setups, initial-condition sampling and FEM reference
//! trajectories for the operator-learning experiments.

use anyhow::Result;

use crate::assembly::{AssemblyContext, BilinearForm, Coefficient};
use crate::analysis::mms::sine_expansion_ic;
use crate::mesh::curved::wave_circle;
use crate::mesh::structured::lshape_tri;
use crate::mesh::Mesh;
use crate::runtime::Runtime;
use crate::solver::PrecondKind;
use crate::timestep::{AllenCahnIntegrator, WaveIntegrator};
use crate::util::rng::Rng;

/// Which PDE family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PdeKind {
    Wave,
    AllenCahn,
}

impl PdeKind {
    pub fn tag(self) -> &'static str {
        match self {
            PdeKind::Wave => "wave",
            PdeKind::AllenCahn => "ac",
        }
    }
}

/// Shared, artifact-shape-validated problem state.
pub struct PdeSetup {
    pub kind: PdeKind,
    pub mesh: Mesh,
    pub ctx: AssemblyContext,
    /// Mass/stiffness values in routing-pattern order + COO indices.
    pub mvals: Vec<f64>,
    pub kvals: Vec<f64>,
    pub rows_idx: Vec<usize>,
    pub cols_idx: Vec<usize>,
    /// Interior mask (0 on Dirichlet nodes).
    pub mask: Vec<f64>,
    /// Directed element-graph edges.
    pub edge_src: Vec<usize>,
    pub edge_dst: Vec<usize>,
    pub deg_inv: Vec<f64>,
    pub dt: f64,
    pub rollout_t: usize,
    pub param_count: usize,
    /// Preconditioner for the reference integrators (default Jacobi —
    /// bitwise-preserved data generation; [`PdeSetup::set_precond`] opts a
    /// generation run into AMG, one hierarchy per integrator reused across
    /// every trajectory of the set).
    pub precond: PrecondKind,
}

impl PdeSetup {
    /// Build and validate against the artifact manifest shapes.
    pub fn new(rt: &Runtime, kind: PdeKind) -> Result<PdeSetup> {
        let name = format!("oplearn_{}_rollout", kind.tag());
        let info = rt.manifest.get(&name)?;
        let mesh_n = info.meta["mesh_n"] as usize;
        let mesh = match kind {
            PdeKind::Wave => wave_circle(mesh_n),
            PdeKind::AllenCahn => lshape_tri(mesh_n),
        };
        anyhow::ensure!(
            mesh.n_nodes() == info.meta["n_nodes"] as usize,
            "mesh/artifact node mismatch for {name}"
        );
        let ctx = AssemblyContext::new(&mesh, 1);
        anyhow::ensure!(
            ctx.routing.nnz() == info.meta["nnz"] as usize,
            "mesh/artifact nnz mismatch"
        );
        // Stiffness + mass share the topology: one fused batched
        // Map-Reduce (tile engine — no S×E×kl² intermediate) produces
        // both value arrays on a single symbolic pattern.
        let km = ctx.assemble_matrix_batch(&[
            BilinearForm::Diffusion { rho: Coefficient::Const(1.0) },
            BilinearForm::Mass { rho: Coefficient::Const(1.0) },
        ]);
        let mut rows_idx = Vec::with_capacity(km.nnz());
        for r in 0..km.nrows {
            for _ in km.indptr[r]..km.indptr[r + 1] {
                rows_idx.push(r);
            }
        }
        let mut mask = vec![1.0; mesh.n_nodes()];
        for b in mesh.boundary_nodes() {
            mask[b] = 0.0;
        }
        let (edge_src, edge_dst) = element_edges(&mesh);
        anyhow::ensure!(
            edge_src.len() == info.meta["n_edges"] as usize,
            "mesh/artifact edge-count mismatch"
        );
        let mut deg = vec![0.0f64; mesh.n_nodes()];
        for &d in &edge_dst {
            deg[d] += 1.0;
        }
        let deg_inv: Vec<f64> = deg.iter().map(|&d| 1.0 / d.max(1.0)).collect();
        Ok(PdeSetup {
            kind,
            mvals: km.values(1).to_vec(),
            kvals: km.values(0).to_vec(),
            rows_idx,
            cols_idx: km.indices,
            ctx,
            mask,
            edge_src,
            edge_dst,
            deg_inv,
            dt: info.meta["dt"],
            rollout_t: info.meta["rollout_t"] as usize,
            param_count: info.meta["param_count"] as usize,
            precond: PrecondKind::Jacobi,
            mesh,
        })
    }

    /// Select the preconditioner used by the reference integrators for
    /// every subsequent trajectory generation.
    pub fn set_precond(&mut self, kind: PrecondKind) {
        self.precond = kind;
    }

    /// FEM reference trajectory (full nodal states) of length `steps+1`.
    pub fn reference_trajectory(&self, u0_full: &[f64], steps: usize) -> Vec<Vec<f64>> {
        match self.kind {
            PdeKind::Wave => {
                let integ = self.wave_integrator();
                integ
                    .rollout(u0_full, steps)
                    .into_iter()
                    .map(|free| integ.expand(&free))
                    .collect()
            }
            PdeKind::AllenCahn => {
                let integ = self.allen_cahn_integrator();
                integ
                    .rollout(u0_full, steps)
                    .into_iter()
                    .map(|free| integ.expand(&free))
                    .collect()
            }
        }
    }

    /// The wave reference integrator (c = 4, the experiment's setting) —
    /// one constructor shared by the scalar and batched generators so the
    /// PDE constants cannot drift between them.
    fn wave_integrator(&self) -> WaveIntegrator {
        WaveIntegrator::with_precond(&self.mesh, 4.0, self.dt, self.precond)
    }

    /// The Allen-Cahn reference integrator (a² = 1e-2, ε² = 1).
    fn allen_cahn_integrator(&self) -> AllenCahnIntegrator {
        AllenCahnIntegrator::with_precond(&self.mesh, 1e-2, 1.0, self.dt, self.precond)
    }

    /// Batched FEM reference trajectories: the whole IC set advances in
    /// lockstep through ONE integrator — whose matrices are assembled and
    /// condensed once into a single shared
    /// [`crate::session::MeshSession`], so the scalar and blocked
    /// generators draw on the same plan and preconditioner — with one
    /// fused SpMV and one blocked solve per time step for the whole set:
    /// this is the data-generation workload the blocked solve pipeline
    /// targets. For the wave equation each trajectory is bitwise identical
    /// to [`PdeSetup::reference_trajectory`]; for Allen-Cahn agreement is
    /// to solver tolerance (CG vs BiCGSTAB on the same SPD system).
    pub fn reference_trajectories(&self, ics: &[Vec<f64>], steps: usize) -> Vec<Vec<Vec<f64>>> {
        match self.kind {
            PdeKind::Wave => {
                let integ = self.wave_integrator();
                integ
                    .rollout_batch(ics, steps)
                    .into_iter()
                    .map(|traj| traj.into_iter().map(|free| integ.expand(&free)).collect())
                    .collect()
            }
            PdeKind::AllenCahn => {
                let integ = self.allen_cahn_integrator();
                integ
                    .rollout_batch(ics, steps)
                    .into_iter()
                    .map(|traj| traj.into_iter().map(|free| integ.expand(&free)).collect())
                    .collect()
            }
        }
    }
}

/// Directed element-graph edges (mirrors python `element_edges`): every
/// ordered pair of distinct nodes within a cell, deduplicated, sorted.
pub fn element_edges(mesh: &Mesh) -> (Vec<usize>, Vec<usize>) {
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    for e in 0..mesh.n_cells() {
        let cell = mesh.cell(e);
        for &a in cell {
            for &b in cell {
                if a != b {
                    pairs.push((a, b));
                }
            }
        }
    }
    pairs.sort_unstable();
    pairs.dedup();
    (
        pairs.iter().map(|&(a, _)| a).collect(),
        pairs.iter().map(|&(_, b)| b).collect(),
    )
}

/// Sample `count` initial conditions from the Eq. (B.15) distribution
/// (K=6, r=0.5), clamped to zero on the boundary.
pub fn sample_ics(mesh: &Mesh, count: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    let boundary = {
        let mut b = vec![false; mesh.n_nodes()];
        for n in mesh.boundary_nodes() {
            b[n] = true;
        }
        b
    };
    (0..count)
        .map(|_| {
            let ic = sine_expansion_ic(6, 0.5, &mut rng);
            (0..mesh.n_nodes())
                .map(|i| if boundary[i] { 0.0 } else { ic(mesh.point(i)) })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::structured::unit_square_tri;

    #[test]
    fn element_edges_counts() {
        let m = unit_square_tri(2);
        let (src, dst) = element_edges(&m);
        assert_eq!(src.len(), dst.len());
        // Every undirected mesh edge appears twice (both directions).
        assert_eq!(src.len() % 2, 0);
        // No self loops.
        assert!(src.iter().zip(&dst).all(|(a, b)| a != b));
    }

    #[test]
    fn ics_are_distinct_and_clamped() {
        let m = unit_square_tri(6);
        let ics = sample_ics(&m, 3, 11);
        assert_eq!(ics.len(), 3);
        for b in m.boundary_nodes() {
            assert_eq!(ics[0][b], 0.0);
        }
        assert!(crate::util::rel_l2(&ics[0], &ics[1]) > 1e-3);
    }
}
