//! Training/evaluation drivers over the operator-learning artifacts.

use anyhow::Result;

use crate::pils::trainer::{ArtifactLoss, LossFn, Operand};
use crate::pils::Adam;
use crate::runtime::exec::Operand as ExecOperand;
use crate::runtime::Runtime;
use crate::util::rng::Rng;

use super::dataset::{PdeKind, PdeSetup};

/// Load a binary f32 init blob by artifact name.
pub fn load_init_blob(rt: &Runtime, name: &str) -> Result<Vec<f64>> {
    let info = rt.manifest.get(name)?;
    let bytes = std::fs::read(&info.file)?;
    let mut out = Vec::with_capacity(bytes.len() / 4);
    for c in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f64);
    }
    Ok(out)
}

/// The common AGN fixed inputs (everything after `params` and the
/// per-sample leading inputs).
fn agn_graph_inputs(setup: &PdeSetup) -> Vec<Operand> {
    vec![
        Operand::from_f64(&setup.mesh.points),
        Operand::from_usize(&setup.edge_src),
        Operand::from_usize(&setup.edge_dst),
        Operand::from_f64(&setup.deg_inv),
        Operand::from_f64(&setup.mask),
    ]
}

/// Train an AGN with the Galerkin-residual (TensorPILS) loss on a set of
/// initial conditions. Returns trained parameters.
pub fn train_pils(
    rt: &Runtime,
    setup: &PdeSetup,
    ics: &[Vec<f64>],
    epochs: usize,
    lr: f64,
    seed: usize,
) -> Result<Vec<f64>> {
    let name = format!("oplearn_{}_pils", setup.kind.tag());
    // Per-IC fixed input sets (u0 leads; graph + sparse follow).
    let mut per_ic: Vec<ArtifactLoss<'_>> = Vec::new();
    for ic in ics {
        let mut fixed = vec![Operand::from_f64(ic)];
        fixed.extend(agn_graph_inputs(setup));
        fixed.push(Operand::from_f64(&setup.mvals));
        fixed.push(Operand::from_f64(&setup.kvals));
        fixed.push(Operand::from_usize(&setup.rows_idx));
        fixed.push(Operand::from_usize(&setup.cols_idx));
        if setup.kind == PdeKind::AllenCahn {
            let coords = crate::fem::geometry::gather_coords(&setup.mesh);
            fixed.push(Operand::from_f64(&coords));
            fixed.push(Operand::from_usize(&setup.mesh.cells));
        }
        per_ic.push(ArtifactLoss::new(rt, &name, fixed));
    }
    train_sgd(rt, setup, &mut per_ic, epochs, lr, seed)
}

/// Train the same AGN supervised on FEM trajectories.
pub fn train_datadriven(
    rt: &Runtime,
    setup: &PdeSetup,
    ics: &[Vec<f64>],
    epochs: usize,
    lr: f64,
    seed: usize,
) -> Result<Vec<f64>> {
    let name = format!("oplearn_{}_datadriven", setup.kind.tag());
    let mut per_ic: Vec<ArtifactLoss<'_>> = Vec::new();
    // Supervision targets generated in lockstep across the whole IC set
    // (one blocked solve per time step instead of one per IC per step).
    let trajs = setup.reference_trajectories(ics, setup.rollout_t);
    for (ic, traj) in ics.iter().zip(&trajs) {
        let flat: Vec<f64> = traj.iter().flatten().copied().collect();
        let mut fixed = vec![Operand::from_f64(ic), Operand::from_f64(&flat)];
        fixed.extend(agn_graph_inputs(setup));
        per_ic.push(ArtifactLoss::new(rt, &name, fixed));
    }
    train_sgd(rt, setup, &mut per_ic, epochs, lr, seed)
}

fn train_sgd(
    rt: &Runtime,
    setup: &PdeSetup,
    per_ic: &mut [ArtifactLoss<'_>],
    epochs: usize,
    lr: f64,
    seed: usize,
) -> Result<Vec<f64>> {
    let mut params = load_init_blob(rt, &format!("agn_init_{}_s{seed}", setup.kind.tag()))?;
    let mut adam = Adam::new(params.len(), lr);
    let mut order: Vec<usize> = (0..per_ic.len()).collect();
    let mut rng = Rng::new(7 + seed as u64);
    for ep in 0..epochs {
        rng.shuffle(&mut order);
        let mut ep_loss = 0.0;
        for &i in &order {
            let (loss, mut grad) = per_ic[i].eval(&params)?;
            crate::pils::trainer::clip_grad(&mut grad, 1.0);
            adam.step(&mut params, &grad);
            ep_loss += loss;
        }
        if ep % (epochs / 10).max(1) == 0 {
            crate::tg_debug!(
                "{} epoch {ep}: mean loss {:.4e}",
                setup.kind.tag(),
                ep_loss / per_ic.len() as f64
            );
        }
    }
    Ok(params)
}

/// Roll out the trained AGN at the 2× horizon; returns `(2T+1) × N` states.
pub fn rollout(rt: &Runtime, setup: &PdeSetup, params: &[f64], ic: &[f64]) -> Result<Vec<Vec<f64>>> {
    let name = format!("oplearn_{}_rollout", setup.kind.tag());
    let p32: Vec<f32> = params.iter().map(|&x| x as f32).collect();
    let mut fixed = vec![Operand::from_f64(ic)];
    fixed.extend(agn_graph_inputs(setup));
    let mut inputs: Vec<ExecOperand<'_>> = vec![ExecOperand::F32(&p32)];
    let owned: Vec<Operand> = fixed;
    for op in &owned {
        inputs.push(match op {
            Operand::F32(v) => ExecOperand::F32(v),
            Operand::I32(v) => ExecOperand::I32(v),
        });
    }
    let out = rt.execute(&name, &inputs)?;
    let n = setup.mesh.n_nodes();
    let steps = out[0].len() / n;
    Ok((0..steps)
        .map(|s| out[0][s * n..(s + 1) * n].iter().map(|&v| v as f64).collect())
        .collect())
}

/// Segment errors: (ID, OOD) stacked relative L2 against the FEM reference
/// (steps 1..T vs T+1..2T, §B.3.3).
pub fn id_ood_errors(pred: &[Vec<f64>], reference: &[Vec<f64>], t: usize) -> (f64, f64) {
    let seg = |lo: usize, hi: usize| -> f64 {
        let p: Vec<f64> = pred[lo..hi].iter().flatten().copied().collect();
        let r: Vec<f64> = reference[lo..hi].iter().flatten().copied().collect();
        crate::util::rel_l2(&p, &r)
    };
    (seg(1, t + 1), seg(t + 1, 2 * t + 1))
}

/// Per-step RMSE curve (Fig B.17).
pub fn per_step_rmse(pred: &[Vec<f64>], reference: &[Vec<f64>]) -> Vec<f64> {
    pred.iter()
        .zip(reference)
        .map(|(p, r)| {
            let n = p.len() as f64;
            (p.iter().zip(r).map(|(a, b)| (a - b) * (a - b)).sum::<f64>() / n).sqrt()
        })
        .collect()
}
