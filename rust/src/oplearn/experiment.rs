//! Table 2 / Fig B.17 / Fig B.18 drivers.

use anyhow::Result;

use crate::experiments::common::{markdown_table, ExperimentRecord};
use crate::pils::trainer::{train_schedule, ArtifactLoss, Operand};
use crate::runtime::Runtime;
use crate::util::cli::Args;
use crate::util::rng::Rng;

use super::dataset::{sample_ics, PdeKind, PdeSetup};
use super::driver;

/// Table 2: rel-L2 (ID / OOD) for data-driven, PI-DeepONet and TensorPILS
/// on wave + Allen-Cahn, averaged over held-out test ICs.
pub fn run(args: &Args) -> Result<()> {
    let epochs = args.get_usize("epochs", 60);
    let n_train = args.get_usize("samples", 8);
    let n_test = args.get_usize("test", 4);
    let lr = args.get_f64("lr", 2e-3);
    let pdes: Vec<PdeKind> = match args.get_str("pde", "both").as_str() {
        "wave" => vec![PdeKind::Wave],
        "ac" => vec![PdeKind::AllenCahn],
        _ => vec![PdeKind::Wave, PdeKind::AllenCahn],
    };
    let with_deeponet = !args.flag("skip-deeponet");

    let rt = Runtime::new()?;
    let mut rows = Vec::new();
    for kind in pdes {
        let setup = PdeSetup::new(&rt, kind)?;
        let train_ics = sample_ics(&setup.mesh, n_train, 1000);
        let test_ics = sample_ics(&setup.mesh, n_test, 9000);
        // Reference trajectories for the test set (2× horizon), generated
        // in lockstep: one blocked solve per step for the whole IC set.
        let refs: Vec<Vec<Vec<f64>>> =
            setup.reference_trajectories(&test_ics, 2 * setup.rollout_t);

        for method in ["datadriven", "pils"] {
            let params = match method {
                "pils" => driver::train_pils(&rt, &setup, &train_ics, epochs, lr, 0)?,
                _ => driver::train_datadriven(&rt, &setup, &train_ics, epochs, lr, 0)?,
            };
            let (mut id_acc, mut ood_acc) = (Vec::new(), Vec::new());
            for (ic, reference) in test_ics.iter().zip(&refs) {
                let pred = driver::rollout(&rt, &setup, &params, ic)?;
                let (id, ood) = driver::id_ood_errors(&pred, reference, setup.rollout_t);
                id_acc.push(id);
                ood_acc.push(ood);
            }
            let (id_m, id_s) = mean_std(&id_acc);
            let (ood_m, ood_s) = mean_std(&ood_acc);
            crate::tg_info!("table2 {} {method}: ID {id_m:.3}±{id_s:.3} OOD {ood_m:.3}±{ood_s:.3}", kind.tag());
            rows.push(vec![
                format!("{} / {method}", kind.tag()),
                format!("{id_m:.3}±{id_s:.3}"),
                format!("{ood_m:.3}±{ood_s:.3}"),
            ]);
            ExperimentRecord::new("table2")
                .str("pde", kind.tag())
                .str("method", method)
                .num("id_mean", id_m)
                .num("id_std", id_s)
                .num("ood_mean", ood_m)
                .num("ood_std", ood_s)
                .num("epochs", epochs as f64)
                .num("samples", n_train as f64)
                .write()?;

            // Fig B.17: per-step RMSE curves on the first test IC (wave).
            if kind == PdeKind::Wave {
                let pred = driver::rollout(&rt, &setup, &params, &test_ics[0])?;
                let rmse = driver::per_step_rmse(&pred, &refs[0]);
                let rec = ExperimentRecord::new("figb17").str("method", method).num(
                    "final_rmse",
                    *rmse.last().unwrap(),
                );
                rec.write()?;
            }
        }

        // PI-DeepONet (wave only, as in our artifact set).
        if kind == PdeKind::Wave && with_deeponet {
            let (id_m, ood_m) = train_eval_deeponet(&rt, &setup, &train_ics, &test_ics, &refs, epochs, lr)?;
            rows.push(vec![
                "wave / pideeponet".to_string(),
                format!("{id_m:.3}"),
                format!("{ood_m:.3}"),
            ]);
            ExperimentRecord::new("table2")
                .str("pde", "wave")
                .str("method", "pideeponet")
                .num("id_mean", id_m)
                .num("ood_mean", ood_m)
                .write()?;
        }
    }
    println!(
        "\nTable 2 (operator learning, rel-L2; epochs={epochs}, train ICs={n_train}):\n\n{}",
        markdown_table(&["PDE / method", "ID", "OOD"], &rows)
    );
    Ok(())
}

/// Fig B.18: error vs number of training ICs for data-driven vs PILS.
pub fn run_figb18(args: &Args) -> Result<()> {
    let epochs = args.get_usize("epochs", 40);
    let counts = args.get_usize_list("counts", &[1, 2, 4, 8]);
    let n_test = args.get_usize("test", 4);
    let lr = args.get_f64("lr", 2e-3);
    let rt = Runtime::new()?;
    let setup = PdeSetup::new(&rt, PdeKind::Wave)?;
    let test_ics = sample_ics(&setup.mesh, n_test, 9000);
    let refs: Vec<Vec<Vec<f64>>> =
        setup.reference_trajectories(&test_ics, 2 * setup.rollout_t);
    let mut rows = Vec::new();
    for &c in &counts {
        let train_ics = sample_ics(&setup.mesh, c, 1000);
        let mut row = vec![format!("{c}")];
        for method in ["datadriven", "pils"] {
            let params = match method {
                "pils" => driver::train_pils(&rt, &setup, &train_ics, epochs, lr, 0)?,
                _ => driver::train_datadriven(&rt, &setup, &train_ics, epochs, lr, 0)?,
            };
            let errs: Vec<f64> = test_ics
                .iter()
                .zip(&refs)
                .map(|(ic, reference)| {
                    let pred = driver::rollout(&rt, &setup, &params, ic).unwrap();
                    driver::id_ood_errors(&pred, reference, setup.rollout_t).0
                })
                .collect();
            let (m, s) = mean_std(&errs);
            row.push(format!("{m:.3}±{s:.3}"));
            ExperimentRecord::new("figb18")
                .str("method", method)
                .num("n_train", c as f64)
                .num("id_mean", m)
                .num("id_std", s)
                .write()?;
        }
        rows.push(row);
    }
    println!(
        "\nFig B.18 (error vs #training ICs, wave):\n\n{}",
        markdown_table(&["#ICs", "data-driven", "TensorPILS"], &rows)
    );
    Ok(())
}

fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len().max(1) as f64;
    let m = xs.iter().sum::<f64>() / n;
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n;
    (m, v.sqrt())
}

/// PI-DeepONet: trained on the strong-form residual for the first training
/// IC family, evaluated by querying (x, y, t) on the test ICs.
fn train_eval_deeponet(
    rt: &Runtime,
    setup: &PdeSetup,
    train_ics: &[Vec<f64>],
    test_ics: &[Vec<f64>],
    refs: &[Vec<Vec<f64>>],
    epochs: usize,
    lr: f64,
) -> Result<(f64, f64)> {
    let info = rt.manifest.get("oplearn_wave_pideeponet")?.clone();
    let m_col = info.meta["m_col"] as usize;
    let m_bc = info.meta["m_bc"] as usize;
    let t_max = info.meta["t_max"];
    let n = setup.mesh.n_nodes();
    let mut rng = Rng::new(31);
    let boundary = setup.mesh.boundary_nodes();

    // Collocation/IC/BC point sets shared across ICs.
    let mut colloc = Vec::with_capacity(m_col * 3);
    let interior: Vec<usize> = (0..n).filter(|i| setup.mask[*i] > 0.5).collect();
    for _ in 0..m_col {
        let node = interior[rng.below(interior.len())];
        let p = setup.mesh.point(node);
        colloc.extend_from_slice(&[p[0], p[1], rng.uniform_in(0.0, t_max)]);
    }
    let mut ic_pts = Vec::with_capacity(n * 3);
    for i in 0..n {
        let p = setup.mesh.point(i);
        ic_pts.extend_from_slice(&[p[0], p[1], 0.0]);
    }
    let mut bc_pts = Vec::with_capacity(m_bc * 3);
    for _ in 0..m_bc {
        let b = boundary[rng.below(boundary.len())];
        let p = setup.mesh.point(b);
        bc_pts.extend_from_slice(&[p[0], p[1], rng.uniform_in(0.0, t_max)]);
    }

    // Round-robin SGD over the training ICs.
    let mut per_ic: Vec<ArtifactLoss<'_>> = train_ics
        .iter()
        .map(|ic| {
            ArtifactLoss::new(
                rt,
                "oplearn_wave_pideeponet",
                vec![
                    Operand::from_f64(ic),
                    Operand::from_f64(&colloc),
                    Operand::from_f64(&ic_pts),
                    Operand::from_f64(ic),
                    Operand::from_f64(&bc_pts),
                ],
            )
        })
        .collect();
    let mut params = driver::load_init_blob(rt, "deeponet_init_wave")?;
    // Use the shared schedule runner for the first IC, then SGD rounds.
    let (p_trained, _) = train_schedule(&mut per_ic[0], params.clone(), epochs, 0, lr)?;
    params = p_trained;
    let mut adam = crate::pils::Adam::new(params.len(), lr * 0.5);
    for _ in 0..epochs {
        for loss in per_ic.iter_mut().skip(1) {
            let (_, grad) = crate::pils::trainer::LossFn::eval(loss, &params)?;
            adam.step(&mut params, &grad);
        }
    }

    // Evaluate: query each time slice.
    let (mut id_acc, mut ood_acc) = (Vec::new(), Vec::new());
    let t_steps = 2 * setup.rollout_t;
    for (ic, reference) in test_ics.iter().zip(refs) {
        let s32: Vec<f32> = ic.iter().map(|&x| x as f32).collect();
        let mut pred = Vec::with_capacity(t_steps + 1);
        for s in 0..=t_steps {
            let t = s as f64 * setup.dt;
            let mut q = Vec::with_capacity(n * 3);
            for i in 0..n {
                let p = setup.mesh.point(i);
                q.extend_from_slice(&[p[0] as f32, p[1] as f32, t as f32]);
            }
            let out = rt.execute(
                "oplearn_wave_pideeponet_eval",
                &[
                    crate::runtime::exec::Operand::F32(
                        &params.iter().map(|&x| x as f32).collect::<Vec<f32>>(),
                    ),
                    crate::runtime::exec::Operand::F32(&s32),
                    crate::runtime::exec::Operand::F32(&q),
                ],
            )?;
            pred.push(out[0].iter().map(|&v| v as f64).collect::<Vec<f64>>());
        }
        let (id, ood) = driver::id_ood_errors(&pred, reference, setup.rollout_t);
        id_acc.push(id);
        ood_acc.push(ood);
    }
    let (id_m, _) = mean_std(&id_acc);
    let (ood_m, _) = mean_std(&ood_acc);
    crate::tg_info!("table2 wave pideeponet: ID {id_m:.3} OOD {ood_m:.3}");
    Ok((id_m, ood_m))
}
