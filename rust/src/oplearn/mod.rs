//! Physics-informed operator learning (Table 2, Figs B.15-B.18): learn the
//! map initial-condition → trajectory for the wave equation (circle) and
//! Allen-Cahn (L-shape) with an AGN backbone, trained either data-free
//! through the TensorGalerkin discrete residual (TensorPILS), supervised on
//! FEM trajectories (data-driven), or as a PI-DeepONet baseline.

pub mod dataset;
pub mod driver;
pub mod experiment;

pub use dataset::{sample_ics, PdeKind, PdeSetup};
