//! Adjoint sensitivities through the differentiable assembly pipeline.
//!
//! For compliance `C = Fᵀu` with `K(ρ)u = F`, the adjoint is `λ = −u`
//! (self-adjoint), giving `∂C/∂K = −u uᵀ` and, through the SIMP chain rule,
//! the closed form of Eq. (B.28). The paper's point is that TensorOpt does
//! NOT hand-code this: gradients flow through the same Map-Reduce graph.
//! We reproduce that structurally: [`sensitivity_via_routing`] pushes
//! `∂C/∂K` backwards through the routing matrices' transpose (Stage II
//! backward) and then through the Map stage's linear dependence on the
//! element modulus (Stage I backward). [`sensitivity_closed_form`] is
//! Eq. (B.28); the two must agree to machine precision (tested, plus a
//! finite-difference check).

use super::simp::SimpProblem;

/// Closed-form SIMP compliance sensitivity (Eq. B.28):
/// `∂C/∂ρ_e = −p ρ^{p−1} (Emax−Emin) · u_eᵀ K0_e u_e`.
pub fn sensitivity_closed_form(p: &SimpProblem, rho: &[f64], u: &[f64]) -> Vec<f64> {
    let energies = p.element_energies(u);
    rho.iter()
        .zip(&energies)
        .map(|(&r, &w)| {
            -p.cfg.penal * r.powf(p.cfg.penal - 1.0) * (p.cfg.e_max - p.cfg.e_min) * w
        })
        .collect()
}

/// Sensitivity via the assembly graph's backward pass:
/// `∂C/∂K = λuᵀ = −uuᵀ` restricted to the CSR pattern (never densified),
/// scattered back to local positions by `S_matᵀ`, then contracted with
/// `∂K_local/∂E_e = K0_e` and the SIMP derivative `dE/dρ`.
pub fn sensitivity_via_routing(p: &SimpProblem, rho: &[f64], u: &[f64]) -> Vec<f64> {
    let routing = &p.ctx.routing;
    // ∂C/∂K on the sparse pattern: (−u_i u_j) at each stored (i,j).
    let mut dc_dk = vec![0.0; routing.nnz()];
    for i in 0..routing.n_dofs {
        let ui = u[i];
        for pidx in routing.pattern_indptr[i]..routing.pattern_indptr[i + 1] {
            let j = routing.pattern_indices[pidx];
            dc_dk[pidx] = -ui * u[j];
        }
    }
    // Stage II backward: scatter to local positions (pure gather).
    let dc_dlocal = routing.scatter_matrix_adjoint(&dc_dk);
    // Stage I backward: K_local_e = E(ρ_e)·K0_e ⇒
    // ∂C/∂E_e = Σ_{ab} dC/dK_local[e,a,b] · K0_e[a,b].
    let kl2 = 64;
    let dedrho: Vec<f64> = rho
        .iter()
        .map(|&r| p.cfg.penal * r.powf(p.cfg.penal - 1.0) * (p.cfg.e_max - p.cfg.e_min))
        .collect();
    let mut out = Vec::with_capacity(p.n_elems());
    for e in 0..p.n_elems() {
        let mut acc = 0.0;
        for idx in 0..kl2 {
            acc += dc_dlocal[e * kl2 + idx] * p.k0_local[e * kl2 + idx];
        }
        out.push(acc * dedrho[e]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::simp::SimpConfig;

    fn small() -> SimpProblem {
        SimpProblem::new(SimpConfig {
            nx: 8,
            ny: 4,
            lx: 8.0,
            ly: 4.0,
            ..SimpConfig::default()
        })
    }

    #[test]
    fn routing_adjoint_matches_closed_form() {
        let p = small();
        let rho: Vec<f64> = (0..p.n_elems()).map(|e| 0.3 + 0.02 * (e % 20) as f64).collect();
        let k = p.assemble_k(&rho);
        let (u, _) = p.solve_state(&k, None).unwrap();
        let a = sensitivity_closed_form(&p, &rho, &u);
        let b = sensitivity_via_routing(&p, &rho, &u);
        for (x, y) in a.iter().zip(&b) {
            let scale = x.abs().max(1e-9);
            assert!((x - y).abs() / scale < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn sensitivity_matches_finite_differences() {
        let p = small();
        let mut rho = vec![0.5; p.n_elems()];
        let k = p.assemble_k(&rho);
        let (u, _) = p.solve_state(&k, None).unwrap();
        let sens = sensitivity_closed_form(&p, &rho, &u);
        let c0 = p.compliance(&u);
        let h = 1e-6;
        for e in [0usize, p.n_elems() / 2, p.n_elems() - 1] {
            rho[e] += h;
            let k2 = p.assemble_k(&rho);
            let (u2, _) = p.solve_state(&k2, None).unwrap();
            let c2 = p.compliance(&u2);
            rho[e] -= h;
            let fd = (c2 - c0) / h;
            let rel = (sens[e] - fd).abs() / fd.abs().max(1e-9);
            assert!(rel < 2e-2, "element {e}: adjoint {} vs FD {fd}", sens[e]);
        }
    }

    #[test]
    fn sensitivities_are_negative() {
        // Adding material can only decrease compliance.
        let p = small();
        let rho = vec![0.4; p.n_elems()];
        let k = p.assemble_k(&rho);
        let (u, _) = p.solve_state(&k, None).unwrap();
        let sens = sensitivity_closed_form(&p, &rho, &u);
        assert!(sens.iter().all(|&s| s <= 1e-12));
    }
}
