//! Sensitivity filter (Sigmund): convolution of `ρ_i · ∂C/∂ρ_i` with a
//! linear decay kernel of radius `r_min`, normalized — suppresses
//! checkerboarding and mesh dependence (§B.4.1, radius 1.5h).

use crate::mesh::Mesh;

/// Precomputed filter neighborhoods over element centroids.
pub struct SensitivityFilter {
    /// For each element: (neighbor, weight) pairs, including self.
    neighbors: Vec<Vec<(usize, f64)>>,
}

impl SensitivityFilter {
    /// Build from element centroids with radius `rmin` (absolute units).
    pub fn new(mesh: &Mesh, rmin: f64) -> SensitivityFilter {
        let ne = mesh.n_cells();
        let k = mesh.cell_type.nodes();
        let dim = mesh.dim;
        let mut centroids = Vec::with_capacity(ne * dim);
        for e in 0..ne {
            let mut c = vec![0.0; dim];
            for &v in mesh.cell(e) {
                for (ci, xi) in c.iter_mut().zip(mesh.point(v)) {
                    *ci += xi / k as f64;
                }
            }
            centroids.extend(c);
        }
        // Spatial hash on a grid of cell size rmin.
        let (lo, _) = mesh.bbox();
        let cell_of = |p: &[f64]| -> (i64, i64) {
            (
                ((p[0] - lo[0]) / rmin).floor() as i64,
                ((p[1] - lo[1]) / rmin).floor() as i64,
            )
        };
        use std::collections::HashMap;
        let mut grid: HashMap<(i64, i64), Vec<usize>> = HashMap::new();
        for e in 0..ne {
            grid.entry(cell_of(&centroids[e * dim..e * dim + 2]))
                .or_default()
                .push(e);
        }
        let mut neighbors = Vec::with_capacity(ne);
        for e in 0..ne {
            let ce = &centroids[e * dim..e * dim + 2];
            let (gx, gy) = cell_of(ce);
            let mut list = Vec::new();
            for dx in -1..=1 {
                for dy in -1..=1 {
                    if let Some(cands) = grid.get(&(gx + dx, gy + dy)) {
                        for &o in cands {
                            let co = &centroids[o * dim..o * dim + 2];
                            let d = ((ce[0] - co[0]).powi(2) + (ce[1] - co[1]).powi(2)).sqrt();
                            let w = rmin - d;
                            if w > 0.0 {
                                list.push((o, w));
                            }
                        }
                    }
                }
            }
            neighbors.push(list);
        }
        SensitivityFilter { neighbors }
    }

    /// Apply: `dĉ_j = Σ_i w_ij ρ_i dc_i / (ρ_j Σ_i w_ij)`.
    pub fn apply(&self, rho: &[f64], dc: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; dc.len()];
        for j in 0..dc.len() {
            let mut num = 0.0;
            let mut den = 0.0;
            for &(i, w) in &self.neighbors[j] {
                num += w * rho[i] * dc[i];
                den += w;
            }
            out[j] = num / (den * rho[j].max(1e-3));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::structured::rect_quad;

    #[test]
    fn constant_field_is_invariant() {
        let m = rect_quad(10, 5, 10.0, 5.0);
        let f = SensitivityFilter::new(&m, 1.5);
        let rho = vec![0.5; m.n_cells()];
        let dc = vec![-2.0; m.n_cells()];
        let filtered = f.apply(&rho, &dc);
        for v in filtered {
            assert!((v + 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn filter_smooths_checkerboard() {
        let m = rect_quad(10, 10, 10.0, 10.0);
        let f = SensitivityFilter::new(&m, 1.5);
        let rho = vec![0.5; m.n_cells()];
        let dc: Vec<f64> = (0..m.n_cells())
            .map(|e| if (e / 10 + e % 10) % 2 == 0 { -1.0 } else { -3.0 })
            .collect();
        let filtered = f.apply(&rho, &dc);
        let var_before: f64 = dc.iter().map(|&x| (x + 2.0) * (x + 2.0)).sum();
        let var_after: f64 = filtered.iter().map(|&x| (x + 2.0) * (x + 2.0)).sum();
        assert!(var_after < 0.3 * var_before, "{var_after} vs {var_before}");
    }

    #[test]
    fn every_element_includes_itself() {
        let m = rect_quad(6, 3, 6.0, 3.0);
        let f = SensitivityFilter::new(&m, 1.5);
        for (j, list) in f.neighbors.iter().enumerate() {
            assert!(list.iter().any(|&(i, w)| i == j && w > 0.0));
        }
    }
}
