//! Design-variable updates: the Method of Moving Asymptotes (Svanberg
//! 1987) for a single inequality constraint, and the classical OC
//! (optimality-criteria) update as a cross-check. Both enforce the move
//! limit and box constraints of §B.4.1.

/// MMA state for `min f(x) s.t. g(x) ≤ 0, xmin ≤ x ≤ xmax`.
pub struct Mma {
    n: usize,
    pub move_limit: f64,
    pub asy_init: f64,
    pub asy_incr: f64,
    pub asy_decr: f64,
    low: Vec<f64>,
    upp: Vec<f64>,
    xold1: Vec<f64>,
    xold2: Vec<f64>,
    iter: usize,
}

impl Mma {
    pub fn new(n: usize, move_limit: f64) -> Mma {
        Mma {
            n,
            move_limit,
            asy_init: 0.5,
            asy_incr: 1.2,
            asy_decr: 0.7,
            low: vec![0.0; n],
            upp: vec![0.0; n],
            xold1: vec![0.0; n],
            xold2: vec![0.0; n],
            iter: 0,
        }
    }

    /// One MMA update. `dfdx` is ∇f, `g` the constraint value (≤ 0
    /// feasible), `dgdx` its gradient. Returns the new design.
    pub fn update(
        &mut self,
        x: &[f64],
        dfdx: &[f64],
        g: f64,
        dgdx: &[f64],
        xmin: f64,
        xmax: f64,
    ) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        self.iter += 1;
        let range = (xmax - xmin).max(1e-12);
        // Asymptote update.
        for j in 0..self.n {
            if self.iter <= 2 {
                self.low[j] = x[j] - self.asy_init * range;
                self.upp[j] = x[j] + self.asy_init * range;
            } else {
                let osc = (x[j] - self.xold1[j]) * (self.xold1[j] - self.xold2[j]);
                let factor = if osc > 0.0 {
                    self.asy_incr
                } else if osc < 0.0 {
                    self.asy_decr
                } else {
                    1.0
                };
                let lold = self.xold1[j] - self.low[j];
                let uold = self.upp[j] - self.xold1[j];
                self.low[j] = x[j] - factor * lold;
                self.upp[j] = x[j] + factor * uold;
                // Svanberg's bounds.
                self.low[j] = self.low[j].clamp(x[j] - 10.0 * range, x[j] - 0.01 * range);
                self.upp[j] = self.upp[j].clamp(x[j] + 0.01 * range, x[j] + 10.0 * range);
            }
        }
        // Bounds α, β.
        let mut alpha = vec![0.0; self.n];
        let mut beta = vec![0.0; self.n];
        for j in 0..self.n {
            alpha[j] = (self.low[j] + 0.1 * (x[j] - self.low[j]))
                .max(x[j] - self.move_limit * range)
                .max(xmin);
            beta[j] = (self.upp[j] - 0.1 * (self.upp[j] - x[j]))
                .min(x[j] + self.move_limit * range)
                .min(xmax);
            beta[j] = beta[j].max(alpha[j]);
        }
        // MMA approximation coefficients (objective p0/q0, constraint p1/q1).
        let eps = 1e-9;
        let mut p0 = vec![0.0; self.n];
        let mut q0 = vec![0.0; self.n];
        let mut p1 = vec![0.0; self.n];
        let mut q1 = vec![0.0; self.n];
        for j in 0..self.n {
            let du = (self.upp[j] - x[j]).max(1e-9);
            let dl = (x[j] - self.low[j]).max(1e-9);
            p0[j] = du * du * (dfdx[j].max(0.0) + eps);
            q0[j] = dl * dl * ((-dfdx[j]).max(0.0) + eps);
            p1[j] = du * du * dgdx[j].max(0.0);
            q1[j] = dl * dl * (-dgdx[j]).max(0.0);
        }
        // Constraint residual at x under the approximation:
        // g̃(y) = g + Σ [p1/(upp−y) − p1/(upp−x)] + [q1/(y−low) − q1/(x−low)]
        let base: f64 = g;
        let x_of_lambda = |lambda: f64, out: &mut Vec<f64>| {
            for j in 0..self.n {
                let pj = p0[j] + lambda * p1[j];
                let qj = q0[j] + lambda * q1[j];
                let sp = pj.sqrt();
                let sq = qj.sqrt();
                let y = (self.low[j] * sp + self.upp[j] * sq) / (sp + sq).max(1e-300);
                out[j] = y.clamp(alpha[j], beta[j]);
            }
        };
        let gtilde = |y: &[f64]| -> f64 {
            let mut acc = base;
            for j in 0..self.n {
                acc += p1[j] * (1.0 / (self.upp[j] - y[j]).max(1e-9) - 1.0 / (self.upp[j] - x[j]).max(1e-9));
                acc += q1[j] * (1.0 / (y[j] - self.low[j]).max(1e-9) - 1.0 / (x[j] - self.low[j]).max(1e-9));
            }
            acc
        };
        // Dual bisection on λ ≥ 0.
        let mut y = vec![0.0; self.n];
        x_of_lambda(0.0, &mut y);
        let xnew = if gtilde(&y) <= 0.0 {
            y
        } else {
            let (mut l1, mut l2) = (0.0, 1.0);
            x_of_lambda(l2, &mut y);
            let mut guard = 0;
            while gtilde(&y) > 0.0 && guard < 200 {
                l2 *= 2.0;
                x_of_lambda(l2, &mut y);
                guard += 1;
            }
            for _ in 0..60 {
                let lm = 0.5 * (l1 + l2);
                x_of_lambda(lm, &mut y);
                if gtilde(&y) > 0.0 {
                    l1 = lm;
                } else {
                    l2 = lm;
                }
            }
            x_of_lambda(l2, &mut y);
            y
        };
        self.xold2 = std::mem::take(&mut self.xold1);
        self.xold1 = x.to_vec();
        xnew
    }
}

/// Classical OC update for compliance + volume fraction (the 99-line
/// topopt scheme) — used to cross-validate MMA.
pub struct OcUpdate {
    pub move_limit: f64,
    pub damping: f64,
}

impl Default for OcUpdate {
    fn default() -> Self {
        OcUpdate {
            move_limit: 0.2,
            damping: 0.5,
        }
    }
}

impl OcUpdate {
    /// `dc` must be ≤ 0 (compliance sensitivities); `vol_frac` the target
    /// mean density.
    pub fn update(&self, x: &[f64], dc: &[f64], vol_frac: f64, xmin: f64) -> Vec<f64> {
        let (mut l1, mut l2) = (1e-9, 1e9);
        let mut xnew = vec![0.0; x.len()];
        while (l2 - l1) / (l1 + l2) > 1e-6 {
            let lmid = 0.5 * (l1 + l2);
            for j in 0..x.len() {
                let b = (-dc[j] / lmid).max(0.0).powf(self.damping);
                let cand = x[j] * b;
                xnew[j] = cand
                    .min(x[j] + self.move_limit)
                    .max(x[j] - self.move_limit)
                    .clamp(xmin, 1.0);
            }
            let mean: f64 = xnew.iter().sum::<f64>() / x.len() as f64;
            if mean > vol_frac {
                l1 = lmid;
            } else {
                l2 = lmid;
            }
        }
        xnew
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// min Σ(x−2)² s.t. mean(x) ≤ 0.5 → all x at the constraint.
    #[test]
    fn mma_converges_on_constrained_quadratic() {
        let n = 12;
        let mut mma = Mma::new(n, 0.2);
        let mut x = vec![0.4; n];
        for _ in 0..60 {
            let dfdx: Vec<f64> = x.iter().map(|&v| 2.0 * (v - 2.0)).collect();
            let g = x.iter().sum::<f64>() / n as f64 - 0.5;
            let dgdx = vec![1.0 / n as f64; n];
            x = mma.update(&x, &dfdx, g, &dgdx, 0.0, 1.0);
        }
        let mean = x.iter().sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 1e-2, "mean {mean}");
        for &v in &x {
            assert!((v - 0.5).abs() < 5e-2, "x {v}");
        }
    }

    #[test]
    fn mma_respects_bounds_and_move_limit() {
        let n = 5;
        let mut mma = Mma::new(n, 0.1);
        let x = vec![0.5; n];
        let dfdx = vec![-100.0; n]; // push hard toward xmax
        let xnew = mma.update(&x, &dfdx, -1.0, &vec![0.0; n], 0.0, 1.0);
        for &v in &xnew {
            assert!(v <= 0.6 + 1e-12, "move limit violated: {v}");
            assert!(v >= 0.0 && v <= 1.0);
        }
    }

    #[test]
    fn oc_hits_volume_target() {
        let n = 50;
        let oc = OcUpdate::default();
        let x = vec![0.5; n];
        let dc: Vec<f64> = (0..n).map(|j| -1.0 - (j as f64) / 10.0).collect();
        let xnew = oc.update(&x, &dc, 0.4, 1e-3);
        let mean = xnew.iter().sum::<f64>() / n as f64;
        assert!(mean <= 0.4 + 5e-2);
        assert!(xnew.iter().all(|&v| (1e-3..=1.0).contains(&v)));
    }
}
