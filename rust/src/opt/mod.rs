//! TensorOpt — end-to-end differentiable PDE-constrained optimization
//! (downstream application *iii* of the paper): SIMP topology optimization
//! of the 2D cantilever beam (§B.4).
//!
//! * [`simp`] — the SIMP-interpolated elasticity problem on the Q4 grid,
//!   assembled through the cached TensorGalerkin pipeline every iteration.
//! * [`adjoint`] — sensitivity computation: the closed-form SIMP expression
//!   *and* the generic adjoint route through the routing matrices'
//!   transpose (`∂Γ/∂K → ∂Γ/∂K_local → ∂Γ/∂ρ`), cross-validated in tests.
//! * [`filter`] — sensitivity filter (radius 1.5h) against checkerboards.
//! * [`mma`] — Method of Moving Asymptotes (Svanberg 1987) + the OC
//!   (optimality criteria) fallback.
//! * [`topopt`] — the optimization driver with the Table-3 stage timings.

pub mod adjoint;
pub mod filter;
pub mod mma;
pub mod simp;
pub mod topopt;

pub use mma::{Mma, OcUpdate};
pub use simp::SimpProblem;
pub use topopt::{run_topopt, run_topopt_batch, TopOptConfig, TopOptResult};
