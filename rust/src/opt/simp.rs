//! The SIMP cantilever problem (§B.4.1): Q4 elasticity on `[0,60]×[0,30]`,
//! left edge clamped, downward traction on the lower-right boundary strip,
//! Young's modulus `E(ρ) = Emin + ρᵖ(Emax − Emin)`.

use anyhow::Result;

use crate::assembly::map_reduce::FacetContext;
use crate::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
use crate::bc::DirichletBc;
use crate::mesh::structured::rect_quad;
use crate::mesh::{marker, Mesh};
use crate::session::MeshSession;
use crate::solver::{PrecondKind, SolverConfig};
use crate::sparse::{Csr, CsrBatch};

/// Material and discretization parameters (paper defaults).
#[derive(Clone, Debug, PartialEq)]
pub struct SimpConfig {
    pub nx: usize,
    pub ny: usize,
    pub lx: f64,
    pub ly: f64,
    pub e_max: f64,
    pub e_min: f64,
    pub nu: f64,
    pub penal: f64,
    pub traction: f64,
    /// Fraction of the right edge (from the bottom) carrying the load.
    pub load_frac: f64,
}

impl Default for SimpConfig {
    fn default() -> Self {
        SimpConfig {
            nx: 60,
            ny: 30,
            lx: 60.0,
            ly: 30.0,
            e_max: 70_000.0,
            e_min: 70.0,
            nu: 0.3,
            penal: 3.0,
            traction: -100.0,
            load_frac: 0.1,
        }
    }
}

/// Precomputed problem state (the Table-3 "setup" phase): mesh, cached
/// assembly context + routing, unit-modulus local matrices, load vector
/// and Dirichlet set.
pub struct SimpProblem {
    pub cfg: SimpConfig,
    pub mesh: Mesh,
    pub ctx: AssemblyContext,
    /// Local stiffness at unit Young's modulus, `E × 64` flat (Q4, kl=8).
    pub k0_local: Vec<f64>,
    /// Global load vector (traction only).
    pub f: Vec<f64>,
    pub bc: DirichletBc,
    pub lambda: f64,
    pub mu: f64,
    solver_cfg: SolverConfig,
}

impl SimpProblem {
    pub fn new(cfg: SimpConfig) -> SimpProblem {
        let mut mesh = rect_quad(cfg.nx, cfg.ny, cfg.lx, cfg.ly);
        let load_y = cfg.load_frac * cfg.ly;
        let lx = cfg.lx;
        mesh.mark_boundary(|c| {
            if (c[0] - lx).abs() < 1e-9 && c[1] <= load_y {
                marker::NEUMANN
            } else {
                marker::DIRICHLET
            }
        });
        let ctx = AssemblyContext::new(&mesh, 2);
        // Unit-modulus local matrices (the SIMP scaling factors multiply
        // these every iteration — one batched Map with a per-element
        // coefficient, no per-element loops).
        let lambda = cfg.nu / ((1.0 + cfg.nu) * (1.0 - 2.0 * cfg.nu));
        let mu = 1.0 / (2.0 * (1.0 + cfg.nu));
        let k0_local = ctx.map_matrix(&BilinearForm::Elasticity {
            lambda,
            mu,
            e_mod: Coefficient::Const(1.0),
        });
        // Traction load through the facet Map-Reduce pipeline.
        let fc = FacetContext::new(&mesh, &[marker::NEUMANN], 2);
        let f = fc.assemble_vector(&LinearForm::FacetTraction {
            t: vec![0.0, cfg.traction],
        });
        // Clamp the left edge (both components).
        let left: Vec<usize> = (0..mesh.n_nodes())
            .filter(|&i| mesh.point(i)[0].abs() < 1e-9)
            .flat_map(|i| [2 * i, 2 * i + 1])
            .collect();
        let bc = DirichletBc::homogeneous(left);
        SimpProblem {
            cfg,
            mesh,
            ctx,
            k0_local,
            f,
            bc,
            lambda,
            mu,
            // Topopt-standard state tolerance (sensitivities need ~1e-6).
            solver_cfg: SolverConfig {
                rel_tol: 1e-7,
                abs_tol: 1e-12,
                max_iter: 50_000,
                ..SolverConfig::default()
            },
        }
    }

    /// Select the state-solve preconditioner (default Jacobi — bitwise
    /// back-compat). With [`PrecondKind::Amg`] the drivers build ONE
    /// hierarchy from the first condensed stiffness and refill it per
    /// iteration (aggregation + symbolic triple-product reused; only
    /// values flow with the SIMP densities).
    pub fn set_solver_precond(&mut self, kind: PrecondKind) {
        self.solver_cfg.precond = kind;
    }

    pub fn n_elems(&self) -> usize {
        self.mesh.n_cells()
    }

    /// Young's modulus per element under SIMP, into a caller-owned buffer
    /// (the per-iteration hot path allocates nothing).
    pub fn e_of_rho_into(&self, rho: &[f64], out: &mut [f64]) {
        assert_eq!(rho.len(), out.len(), "density/modulus length");
        for (o, &r) in out.iter_mut().zip(rho) {
            *o = self.cfg.e_min + r.powf(self.cfg.penal) * (self.cfg.e_max - self.cfg.e_min);
        }
    }

    /// Allocating convenience around [`SimpProblem::e_of_rho_into`].
    pub fn e_of_rho(&self, rho: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; rho.len()];
        self.e_of_rho_into(rho, &mut out);
        out
    }

    /// Assemble `K(ρ)` through the separable weighted-gather plan over the
    /// cached unit-modulus locals — Map and Reduce fused, no `E × kl²`
    /// intermediate (bitwise-identical to scaling the locals and reducing:
    /// same per-source products, same ascending summation order). One-shot
    /// convenience: hot loops hold [`SimpProblem::batched_plan`] and use
    /// `assemble_scaled_into` instead.
    pub fn assemble_k(&self, rho: &[f64]) -> Csr {
        self.batched_plan().assemble_scaled(&self.e_of_rho(rho)).instance(0)
    }

    /// Shared-topology assembly plan over the cached unit-modulus locals:
    /// routing-aligned gather weights built once, after which every `K(ρ)`
    /// instance costs one weighted gather over the shared pattern (Map and
    /// Reduce fused). Long-lived drivers (e.g. [`super::topopt::run_topopt_batch`])
    /// build this once and reuse it across iterations.
    pub fn batched_plan(&self) -> crate::assembly::BatchedAssembly<'_> {
        self.ctx.batched_from_unit_local(&self.k0_local)
    }

    /// Flat `S × E` SIMP moduli into a caller-owned buffer — the scalar
    /// input of [`SimpProblem::batched_plan`]'s `assemble_scaled_into`
    /// (zero allocation across iterations).
    pub fn moduli_into(&self, rhos: &[Vec<f64>], out: &mut [f64]) {
        let ne = self.n_elems();
        assert_eq!(out.len(), rhos.len() * ne, "moduli buffer must be S × E");
        for (rho, chunk) in rhos.iter().zip(out.chunks_mut(ne)) {
            assert_eq!(rho.len(), ne, "density field length");
            self.e_of_rho_into(rho, chunk);
        }
    }

    /// Allocating convenience around [`SimpProblem::moduli_into`].
    pub fn moduli_flat(&self, rhos: &[Vec<f64>]) -> Vec<f64> {
        let mut scalars = vec![0.0; rhos.len() * self.n_elems()];
        self.moduli_into(rhos, &mut scalars);
        scalars
    }

    /// One-shot batched `K(ρ)` for `S` density fields (plan built per
    /// call — hold [`SimpProblem::batched_plan`] to amortize it across
    /// repeated batches). Instance `s` is bitwise-identical to
    /// `assemble_k(&rhos[s])`.
    pub fn assemble_k_batch(&self, rhos: &[Vec<f64>]) -> CsrBatch {
        self.batched_plan().assemble_scaled(&self.moduli_flat(rhos))
    }

    /// Solve the state equation; returns (u_full, iterations). `K(ρ)` is
    /// SPD, so preconditioned CG is the right solver — BiCGSTAB stalls at
    /// the extreme (Emax/Emin = 10³) stiffness contrast SIMP develops.
    /// `warm` (a full nodal field, e.g. the previous topopt iterate) seeds
    /// the CG; `None` reproduces the cold start bitwise. One-shot
    /// convenience — iteration loops hold a [`SimpProblem::session`] and
    /// call [`SimpProblem::solve_state_session`] so the Dirichlet symbolic
    /// mapping and preconditioner setup are not rebuilt per solve.
    pub fn solve_state(&self, k: &Csr, warm: Option<&[f64]>) -> Result<(Vec<f64>, usize)> {
        // An ephemeral session IS exactly plan-build + apply + engine
        // build + warm CG, so this agrees bitwise with the cached path.
        let session = MeshSession::from_matrix(k, &self.f, &self.bc, self.solver_cfg);
        let (u, stats) = session.solve_current(warm);
        anyhow::ensure!(stats.converged, "state solve failed: {stats:?}");
        Ok((u, stats.iterations))
    }

    /// The per-problem solver session: the clamp's symbolic mapping on
    /// this problem's (fixed) pattern plus persistent condensed-system
    /// scratch, built once by long-lived drivers and refilled with each
    /// iteration's `K(ρ)` values through
    /// [`SimpProblem::solve_state_session`] /
    /// [`SimpProblem::solve_state_batch_session`]. The engine is deferred
    /// to the first solve (AMG aggregation must see real stiffness
    /// values, not the zeroed pattern).
    pub fn session(&self) -> MeshSession {
        let pat = self.ctx.pattern_matrix();
        MeshSession::from_pattern(&pat, &self.f, &self.bc, self.solver_cfg)
    }

    /// Scalar state solve through a long-lived session: when `kvalues` is
    /// `Some`, the session system is renumerated in place (value gather +
    /// lift, zero allocation); `None` solves the session's current
    /// operator as-is. The engine is refilled per call — for Jacobi that
    /// is the per-solve diagonal extraction the historical path performed
    /// (bitwise-identical); for AMG the aggregation and symbolic
    /// triple-product built on the first solve serve the whole
    /// optimization loop. Bitwise identical to [`SimpProblem::solve_state`]
    /// on the same values and seed.
    pub fn solve_state_session(
        &self,
        session: &mut MeshSession,
        kvalues: Option<&[f64]>,
        warm: Option<&[f64]>,
    ) -> Result<(Vec<f64>, usize)> {
        if let Some(values) = kvalues {
            session.refill(values, &self.f);
        }
        session.sync_engine();
        let (u, stats) = session.solve_current(warm);
        anyhow::ensure!(stats.converged, "state solve failed: {stats:?}");
        Ok((u, stats.iterations))
    }

    /// Blocked multi-design state solve through a long-lived session: `S`
    /// stiffness instances on the shared pattern are condensed through the
    /// session's symbolic mapping and solved by lockstep CG (one fused
    /// SpMV per Krylov iteration for the whole design set). `warm` carries
    /// per-design full nodal seeds (previous iterates). Under the default
    /// Jacobi config each lane uses its own diagonal — per design bitwise
    /// identical to [`SimpProblem::solve_state`] with the same seed; under
    /// [`PrecondKind::Amg`] ONE hierarchy, built from design 0's condensed
    /// stiffness on the first call and refilled afterwards, preconditions
    /// every lane (the designs share a topology, so the shared-mesh
    /// hierarchy is a valid SPD preconditioner for the whole set).
    pub fn solve_state_batch_session(
        &self,
        session: &mut MeshSession,
        kbatch: &CsrBatch,
        warm: Option<&[&[f64]]>,
    ) -> Result<(Vec<Vec<f64>>, Vec<usize>)> {
        let (red, u, stats) = session.solve_refit_batch(kbatch, &self.f, warm);
        let nf = red.n_free();
        let mut us = Vec::with_capacity(kbatch.n_instances);
        let mut iters = Vec::with_capacity(kbatch.n_instances);
        for (s, st) in stats.iter().enumerate() {
            anyhow::ensure!(st.converged, "state solve (design {s}) failed: {st:?}");
            us.push(red.expand(&u[s * nf..(s + 1) * nf]));
            iters.push(st.iterations);
        }
        Ok((us, iters))
    }

    /// One-shot blocked state solve (session built per call — hold
    /// [`SimpProblem::session`] to amortize the symbolic work across
    /// iterations).
    pub fn solve_state_batch(&self, kbatch: &CsrBatch) -> Result<(Vec<Vec<f64>>, Vec<usize>)> {
        let mut session = self.session();
        self.solve_state_batch_session(&mut session, kbatch, None)
    }

    /// Compliance `C = Fᵀu`.
    pub fn compliance(&self, u: &[f64]) -> f64 {
        crate::util::dot(&self.f, u)
    }

    /// Element strain energies at unit modulus: `w_e = u_eᵀ K0_e u_e`.
    pub fn element_energies(&self, u: &[f64]) -> Vec<f64> {
        let kl = 8;
        let mut out = Vec::with_capacity(self.n_elems());
        for e in 0..self.n_elems() {
            let dofs = self.ctx.dofmap.cell_dofs(e);
            let ke = &self.k0_local[e * kl * kl..(e + 1) * kl * kl];
            let mut acc = 0.0;
            for (a, &i) in dofs.iter().enumerate() {
                let ui = u[i];
                for (b, &j) in dofs.iter().enumerate() {
                    acc += ui * ke[a * kl + b] * u[j];
                }
            }
            out.push(acc);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SimpProblem {
        SimpProblem::new(SimpConfig {
            nx: 12,
            ny: 6,
            lx: 12.0,
            ly: 6.0,
            ..SimpConfig::default()
        })
    }

    #[test]
    fn solid_beam_deflects_downward() {
        let p = small();
        let rho = vec![1.0; p.n_elems()];
        let k = p.assemble_k(&rho);
        let (u, _) = p.solve_state(&k, None).unwrap();
        // Tip node (bottom-right) moves down.
        let tip = (0..p.mesh.n_nodes())
            .find(|&i| {
                let pt = p.mesh.point(i);
                (pt[0] - 12.0).abs() < 1e-9 && pt[1].abs() < 1e-9
            })
            .unwrap();
        assert!(u[2 * tip + 1] < 0.0, "tip uy = {}", u[2 * tip + 1]);
        assert!(p.compliance(&u) > 0.0);
    }

    #[test]
    fn compliance_decreases_with_density() {
        let p = small();
        let k_half = p.assemble_k(&vec![0.5; p.n_elems()]);
        let k_full = p.assemble_k(&vec![1.0; p.n_elems()]);
        let (u_half, _) = p.solve_state(&k_half, None).unwrap();
        let (u_full, _) = p.solve_state(&k_full, None).unwrap();
        assert!(
            p.compliance(&u_full) < p.compliance(&u_half),
            "stiffer structure must be more compliant-efficient"
        );
    }

    #[test]
    fn batched_k_matches_sequential_assembly() {
        let p = small();
        let ne = p.n_elems();
        let rhos: Vec<Vec<f64>> = (0..3)
            .map(|s| (0..ne).map(|e| 0.2 + 0.1 * s as f64 + 0.005 * (e % 9) as f64).collect())
            .collect();
        let batch = p.assemble_k_batch(&rhos);
        batch.check_invariants().unwrap();
        for (s, rho) in rhos.iter().enumerate() {
            let seq = p.assemble_k(rho);
            assert_eq!(batch.indices, seq.indices, "instance {s} pattern");
            assert_eq!(batch.values(s), &seq.data[..], "instance {s} values");
        }
    }

    #[test]
    fn blocked_state_solve_matches_scalar() {
        let p = small();
        let ne = p.n_elems();
        let rhos: Vec<Vec<f64>> = (0..3)
            .map(|s| (0..ne).map(|e| 0.3 + 0.2 * s as f64 + 0.004 * (e % 11) as f64).collect())
            .collect();
        let kbatch = p.assemble_k_batch(&rhos);
        let (us, iters) = p.solve_state_batch(&kbatch).unwrap();
        for (s, rho) in rhos.iter().enumerate() {
            let k = p.assemble_k(rho);
            let (u_ref, it_ref) = p.solve_state(&k, None).unwrap();
            assert_eq!(iters[s], it_ref, "design {s} iterations");
            assert_eq!(us[s], u_ref, "design {s} state");
        }
    }

    #[test]
    fn energies_are_nonnegative_and_localized() {
        let p = small();
        let rho = vec![1.0; p.n_elems()];
        let k = p.assemble_k(&rho);
        let (u, _) = p.solve_state(&k, None).unwrap();
        let w = p.element_energies(&u);
        assert!(w.iter().all(|&x| x >= -1e-12));
        assert!(w.iter().any(|&x| x > 0.0));
    }
}
