//! The TensorOpt driver: SIMP compliance minimization with MMA (or OC),
//! instrumented with the Table-3 stage split (setup vs optimization loop).

use anyhow::Result;

use crate::solver::PrecondKind;
use crate::util::timer::Stopwatch;

use super::adjoint;
use super::filter::SensitivityFilter;
use super::mma::{Mma, OcUpdate};
use super::simp::{SimpConfig, SimpProblem};

/// Driver configuration.
#[derive(Clone, Debug)]
pub struct TopOptConfig {
    pub simp: SimpConfig,
    pub vol_frac: f64,
    pub iters: usize,
    pub move_limit: f64,
    /// "mma" or "oc".
    pub optimizer: String,
    /// Filter radius in units of element size h.
    pub rmin_h: f64,
    /// Baseline mode: rebuild the assembly context (routing, tabulation,
    /// K0 locals, facet context) every iteration — the JIT/recompile-style
    /// archetype that Table 3 compares against.
    pub rebuild_setup_each_iter: bool,
    /// State-solve preconditioner. Default Jacobi (bitwise-identical to
    /// the historical driver); [`PrecondKind::Amg`] builds one hierarchy
    /// at iteration 0 and refills it per iteration — warm starts and AMG
    /// compose, so per-iteration CG counts drop on both axes.
    pub precond: PrecondKind,
}

impl Default for TopOptConfig {
    fn default() -> Self {
        TopOptConfig {
            simp: SimpConfig::default(),
            vol_frac: 0.5,
            iters: 51,
            move_limit: 0.1,
            optimizer: "mma".into(),
            rmin_h: 1.5,
            rebuild_setup_each_iter: false,
            precond: PrecondKind::Jacobi,
        }
    }
}

/// Outcome with the Table-3 numbers.
pub struct TopOptResult {
    pub rho: Vec<f64>,
    pub compliance_history: Vec<f64>,
    pub setup_s: f64,
    pub loop_s: f64,
    pub total_solver_iters: usize,
    /// CG iterations per optimization iteration — warm starts show up here
    /// as a sharp drop after iteration 0.
    pub solver_iters_history: Vec<usize>,
    /// Snapshots of the density field at selected iterations (Fig 5).
    pub snapshots: Vec<(usize, Vec<f64>)>,
}

impl TopOptResult {
    pub fn final_compliance(&self) -> f64 {
        *self.compliance_history.last().unwrap()
    }
}

/// Per-design optimizer state shared by the scalar and lockstep drivers —
/// one place for the post-solve update so both paths stay in step.
struct Lane {
    rho: Vec<f64>,
    mma: Mma,
    oc: OcUpdate,
    filt: SensitivityFilter,
    history: Vec<f64>,
    snapshots: Vec<(usize, Vec<f64>)>,
    solver_iters: usize,
    iter_history: Vec<usize>,
    /// Previous state iterate (full nodal field) — the warm-start seed.
    u_prev: Option<Vec<f64>>,
}

impl Lane {
    fn new(problem: &SimpProblem, cfg: &TopOptConfig, h: f64) -> Lane {
        let ne = problem.n_elems();
        Lane {
            rho: vec![cfg.vol_frac; ne],
            mma: Mma::new(ne, cfg.move_limit),
            oc: OcUpdate {
                move_limit: cfg.move_limit.max(0.1),
                ..OcUpdate::default()
            },
            filt: SensitivityFilter::new(&problem.mesh, cfg.rmin_h * h),
            history: Vec::with_capacity(cfg.iters),
            snapshots: Vec::new(),
            solver_iters: 0,
            iter_history: Vec::with_capacity(cfg.iters),
            u_prev: None,
        }
    }

    /// Compliance bookkeeping + sensitivity + design update for one
    /// iteration's state solution.
    fn advance(
        &mut self,
        problem: &SimpProblem,
        cfg: &TopOptConfig,
        u: Vec<f64>,
        iters: usize,
        it: usize,
    ) {
        let ne = problem.n_elems();
        self.solver_iters += iters;
        self.iter_history.push(iters);
        self.history.push(problem.compliance(&u));

        let dc = adjoint::sensitivity_closed_form(problem, &self.rho, &u);
        let dc_f = self.filt.apply(&self.rho, &dc);

        self.rho = if cfg.optimizer == "oc" {
            self.oc.update(&self.rho, &dc_f, cfg.vol_frac, 1e-3)
        } else {
            let mean: f64 = self.rho.iter().sum::<f64>() / ne as f64;
            let g = mean / cfg.vol_frac - 1.0;
            let dgdx = vec![1.0 / (cfg.vol_frac * ne as f64); ne];
            self.mma.update(&self.rho, &dc_f, g, &dgdx, 1e-3, 1.0)
        };
        if it % (cfg.iters / 4).max(1) == 0 || it + 1 == cfg.iters {
            self.snapshots.push((it, self.rho.clone()));
        }
        self.u_prev = Some(u);
    }

    fn into_result(self, setup_s: f64, loop_s: f64) -> TopOptResult {
        TopOptResult {
            rho: self.rho,
            compliance_history: self.history,
            setup_s,
            loop_s,
            total_solver_iters: self.solver_iters,
            solver_iters_history: self.iter_history,
            snapshots: self.snapshots,
        }
    }
}

/// Run SIMP topology optimization.
pub fn run_topopt(cfg: &TopOptConfig) -> Result<TopOptResult> {
    if cfg.rebuild_setup_each_iter {
        return run_topopt_rebuild_baseline(cfg);
    }
    let mut sw = Stopwatch::new();
    sw.start("setup");
    let mut problem = SimpProblem::new(cfg.simp.clone());
    problem.set_solver_precond(cfg.precond);
    let h = cfg.simp.lx / cfg.simp.nx as f64;
    let mut lane = Lane::new(&problem, cfg, h);
    // Per-iteration state, built once: the separable weighted-gather plan
    // over the cached unit-modulus locals, a persistent stiffness value
    // array refilled in place, the modulus buffer, and the solver session
    // (Dirichlet symbolic mapping + persistent condensed system +
    // preconditioner engine — Jacobi rebuilds its diagonal per solve, the
    // historical behavior bitwise; an AMG engine is built at iteration 0
    // and only refilled afterwards). The K(ρ) update allocates nothing
    // after this point and the solve pays only the value gather + lift
    // per iteration.
    let plan = problem.batched_plan();
    let mut session = problem.session();
    let mut kvals = vec![0.0; problem.ctx.routing.nnz()];
    let mut moduli = vec![0.0; problem.n_elems()];
    sw.stop();

    sw.start("loop");
    for it in 0..cfg.iters {
        problem.e_of_rho_into(&lane.rho, &mut moduli);
        plan.assemble_scaled_into(&moduli, &mut kvals);
        // Warm start: seed CG with the previous iterate (densities move a
        // little per iteration, so the previous state is an excellent
        // guess; the drop shows up in `solver_iters_history`).
        let (u, iters) = problem.solve_state_session(
            &mut session,
            Some(&kvals),
            lane.u_prev.as_deref(),
        )?;
        lane.advance(&problem, cfg, u, iters, it);
    }
    sw.stop();
    Ok(lane.into_result(sw.total("setup"), sw.total("loop")))
}

/// Baseline archetype (Table 3's recompile-per-iteration column):
/// everything — mesh, routing, tabulation, K0 locals, facet context,
/// filter — rebuilt every iteration, cold solver starts.
fn run_topopt_rebuild_baseline(cfg: &TopOptConfig) -> Result<TopOptResult> {
    let mut sw = Stopwatch::new();
    sw.start("setup");
    let problem = SimpProblem::new(cfg.simp.clone());
    let h = cfg.simp.lx / cfg.simp.nx as f64;
    let mut lane = Lane::new(&problem, cfg, h);
    sw.stop();

    sw.start("loop");
    for it in 0..cfg.iters {
        let mut problem = SimpProblem::new(cfg.simp.clone());
        problem.set_solver_precond(cfg.precond);
        lane.filt = SensitivityFilter::new(&problem.mesh, cfg.rmin_h * h);
        let k = problem.assemble_k(&lane.rho);
        let (u, iters) = problem.solve_state(&k, None)?;
        lane.advance(&problem, cfg, u, iters, it);
        lane.u_prev = None;
    }
    sw.stop();
    Ok(lane.into_result(sw.total("setup"), sw.total("loop")))
}

/// Run `S` SIMP problems in lockstep on one shared mesh topology: each
/// iteration re-assembles ALL `S` stiffness matrices through one
/// shared-topology batched Map-Reduce ([`SimpProblem::assemble_k_batch`])
/// instead of `S` scalar assemblies, and solves ALL `S` state equations
/// through one batched condensation (symbolic mapping built once at setup)
/// plus one lockstep CG — every Krylov iteration performs a single fused
/// SpMV over the shared pattern for the whole design set instead of `S`
/// scalar solves. The multi-start / sweep workload (varying volume
/// fraction, optimizer, filter radius, move limit) served at batch cost.
/// Every lane's CG is warm-started with that lane's previous iterate
/// (mirroring [`run_topopt`], so per-lane results stay identical to the
/// scalar driver), and after setup the per-iteration re-assembly writes
/// into persistent buffers — zero heap allocation on the assembly path.
/// Configs must share `simp` and `iters`; setup/loop timings are shared
/// across the batch.
pub fn run_topopt_batch(cfgs: &[TopOptConfig]) -> Result<Vec<TopOptResult>> {
    anyhow::ensure!(!cfgs.is_empty(), "empty topopt batch");
    let base = &cfgs[0];
    for cfg in cfgs {
        anyhow::ensure!(cfg.simp == base.simp, "topopt batch must share the SIMP problem");
        anyhow::ensure!(cfg.iters == base.iters, "topopt batch must share the iteration count");
        anyhow::ensure!(
            cfg.precond == base.precond,
            "topopt batch must share the preconditioner (one hierarchy per mesh)"
        );
        anyhow::ensure!(
            !cfg.rebuild_setup_each_iter,
            "the rebuild baseline is a per-problem archetype"
        );
    }

    let mut sw = Stopwatch::new();
    sw.start("setup");
    let mut problem = SimpProblem::new(base.simp.clone());
    problem.set_solver_precond(base.precond);
    // Gather weights built once; every iteration's S-instance re-assembly
    // is then a weighted gather over the shared pattern into a persistent
    // CsrBatch (values refilled in place). Likewise the solver session:
    // the Dirichlet symbolic mapping is a function of pattern + clamp
    // only, so it is built once here and reused by every iteration's
    // blocked solve; under AMG the session also keeps the one shared
    // hierarchy (built from design 0 at iteration 0, refilled per
    // iteration) that preconditions every lockstep lane.
    let plan = problem.batched_plan();
    let mut session = problem.session();
    let ne = problem.n_elems();
    let h = base.simp.lx / base.simp.nx as f64;
    let mut lanes: Vec<Lane> = cfgs.iter().map(|cfg| Lane::new(&problem, cfg, h)).collect();
    let mut moduli = vec![0.0; lanes.len() * ne];
    let mut kbatch = problem
        .ctx
        .routing
        .csr_batch(vec![0.0; lanes.len() * problem.ctx.routing.nnz()], lanes.len());
    sw.stop();

    sw.start("loop");
    for it in 0..base.iters {
        // One shared-topology batched assembly for the whole lane set,
        // into the persistent value arrays.
        for (lane, chunk) in lanes.iter().zip(moduli.chunks_mut(ne)) {
            problem.e_of_rho_into(&lane.rho, chunk);
        }
        plan.assemble_scaled_into(&moduli, &mut kbatch.data);
        // One blocked condensation + lockstep CG for the whole lane set,
        // each lane seeded with its previous iterate (mirrors the scalar
        // driver's warm start, so per-lane results stay identical).
        let warm: Vec<&[f64]> = lanes.iter().filter_map(|l| l.u_prev.as_deref()).collect();
        let warm_opt = (warm.len() == lanes.len()).then_some(&warm[..]);
        let (us, iters) = problem.solve_state_batch_session(&mut session, &kbatch, warm_opt)?;
        for ((lane, cfg), (u, its)) in lanes.iter_mut().zip(cfgs).zip(us.into_iter().zip(iters)) {
            lane.advance(&problem, cfg, u, its, it);
        }
    }
    sw.stop();

    let (setup_s, loop_s) = (sw.total("setup"), sw.total("loop"));
    Ok(lanes.into_iter().map(|lane| lane.into_result(setup_s, loop_s)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(optimizer: &str, iters: usize) -> TopOptConfig {
        TopOptConfig {
            simp: SimpConfig {
                nx: 16,
                ny: 8,
                lx: 16.0,
                ly: 8.0,
                ..SimpConfig::default()
            },
            iters,
            optimizer: optimizer.into(),
            ..TopOptConfig::default()
        }
    }

    #[test]
    fn compliance_decreases_oc() {
        let r = run_topopt(&small_cfg("oc", 12)).unwrap();
        let first = r.compliance_history[0];
        let last = r.final_compliance();
        assert!(last < first, "no improvement: {first} → {last}");
        // Volume constraint approximately satisfied.
        let mean: f64 = r.rho.iter().sum::<f64>() / r.rho.len() as f64;
        assert!(mean <= 0.55, "volume violated: {mean}");
    }

    #[test]
    fn compliance_decreases_mma() {
        let r = run_topopt(&small_cfg("mma", 12)).unwrap();
        assert!(r.final_compliance() < r.compliance_history[0]);
        let mean: f64 = r.rho.iter().sum::<f64>() / r.rho.len() as f64;
        assert!(mean <= 0.55, "volume violated: {mean}");
    }

    #[test]
    fn mma_and_oc_reach_similar_designs() {
        // Paper §B.4.2: frameworks converge to near-identical compliance
        // (<0.33% there); our two optimizers should land within a few %.
        let a = run_topopt(&small_cfg("oc", 25)).unwrap();
        let b = run_topopt(&small_cfg("mma", 25)).unwrap();
        let (ca, cb) = (a.final_compliance(), b.final_compliance());
        let rel = (ca - cb).abs() / ca.min(cb);
        assert!(rel < 0.10, "OC {ca} vs MMA {cb} ({rel:.3})");
    }

    #[test]
    fn batched_lockstep_matches_individual_runs() {
        let cfg_a = small_cfg("oc", 6);
        let mut cfg_b = small_cfg("mma", 6);
        cfg_b.vol_frac = 0.4;
        let batch = run_topopt_batch(&[cfg_a.clone(), cfg_b.clone()]).unwrap();
        assert_eq!(batch.len(), 2);
        let solo_a = run_topopt(&cfg_a).unwrap();
        let solo_b = run_topopt(&cfg_b).unwrap();
        for (lane, solo) in batch.iter().zip([&solo_a, &solo_b]) {
            assert_eq!(lane.compliance_history.len(), solo.compliance_history.len());
            for (x, y) in lane.compliance_history.iter().zip(&solo.compliance_history) {
                assert!((x - y).abs() <= 1e-9 * x.abs().max(1.0), "{x} vs {y}");
            }
            assert!(crate::util::rel_l2(&lane.rho, &solo.rho) < 1e-9);
        }
    }

    #[test]
    fn warm_starts_cut_solver_iterations() {
        let r = run_topopt(&small_cfg("oc", 6)).unwrap();
        assert_eq!(r.solver_iters_history.len(), 6);
        assert_eq!(r.solver_iters_history.iter().sum::<usize>(), r.total_solver_iters);
        let cold = r.solver_iters_history[0];
        let warm_avg = r.solver_iters_history[1..].iter().sum::<usize>() as f64 / 5.0;
        assert!(
            warm_avg < cold as f64,
            "warm-started iterations should average below the cold start: {:?}",
            r.solver_iters_history
        );
        // The blocked driver warm-starts identically: per-iteration counts
        // must match the scalar driver lane for lane.
        let batch = run_topopt_batch(&[small_cfg("oc", 6)]).unwrap();
        assert_eq!(batch[0].solver_iters_history, r.solver_iters_history);
    }

    #[test]
    fn batched_topopt_rejects_mismatched_meshes() {
        let cfg_a = small_cfg("oc", 4);
        let mut cfg_b = small_cfg("oc", 4);
        cfg_b.simp.nx = 12;
        assert!(run_topopt_batch(&[cfg_a, cfg_b]).is_err());
    }

    #[test]
    fn amg_preconditioned_topopt_matches_jacobi_design() {
        // Same physics, different preconditioner: the optimized designs
        // must agree to solver tolerance (states solved to rel_tol 1e-7).
        let jac = small_cfg("oc", 8);
        let mut amg = small_cfg("oc", 8);
        amg.precond = PrecondKind::amg();
        let r_jac = run_topopt(&jac).unwrap();
        let r_amg = run_topopt(&amg).unwrap();
        assert_eq!(r_amg.compliance_history.len(), r_jac.compliance_history.len());
        // States are solved to rel_tol 1e-7; small per-iteration solver
        // differences can amplify through the density update, so the
        // trajectories are compared loosely.
        for (a, b) in r_amg.compliance_history.iter().zip(&r_jac.compliance_history) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "{a} vs {b}");
        }
        assert!(crate::util::rel_l2(&r_amg.rho, &r_jac.rho) < 1e-2);
        // And the blocked AMG driver stays consistent with the scalar one.
        let batch = run_topopt_batch(std::slice::from_ref(&amg)).unwrap();
        for (a, b) in batch[0].compliance_history.iter().zip(&r_amg.compliance_history) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "batch {a} vs scalar {b}");
        }
    }

    #[test]
    fn densities_stay_in_bounds_and_structure_forms() {
        let r = run_topopt(&small_cfg("oc", 20)).unwrap();
        assert!(r.rho.iter().all(|&x| (1e-3..=1.0).contains(&x)));
        // Penalization should push a meaningful fraction toward 0/1.
        let extreme = r
            .rho
            .iter()
            .filter(|&&x| !(0.2..=0.8).contains(&x))
            .count() as f64
            / r.rho.len() as f64;
        assert!(extreme > 0.3, "design not binarizing: {extreme}");
    }
}
