//! The TensorOpt driver: SIMP compliance minimization with MMA (or OC),
//! instrumented with the Table-3 stage split (setup vs optimization loop).

use anyhow::Result;

use crate::util::timer::Stopwatch;

use super::adjoint;
use super::filter::SensitivityFilter;
use super::mma::{Mma, OcUpdate};
use super::simp::{SimpConfig, SimpProblem};

/// Driver configuration.
#[derive(Clone, Debug)]
pub struct TopOptConfig {
    pub simp: SimpConfig,
    pub vol_frac: f64,
    pub iters: usize,
    pub move_limit: f64,
    /// "mma" or "oc".
    pub optimizer: String,
    /// Filter radius in units of element size h.
    pub rmin_h: f64,
    /// Baseline mode: rebuild the assembly context (routing, tabulation,
    /// K0 locals, facet context) every iteration — the JIT/recompile-style
    /// archetype that Table 3 compares against.
    pub rebuild_setup_each_iter: bool,
}

impl Default for TopOptConfig {
    fn default() -> Self {
        TopOptConfig {
            simp: SimpConfig::default(),
            vol_frac: 0.5,
            iters: 51,
            move_limit: 0.1,
            optimizer: "mma".into(),
            rmin_h: 1.5,
            rebuild_setup_each_iter: false,
        }
    }
}

/// Outcome with the Table-3 numbers.
pub struct TopOptResult {
    pub rho: Vec<f64>,
    pub compliance_history: Vec<f64>,
    pub setup_s: f64,
    pub loop_s: f64,
    pub total_solver_iters: usize,
    /// Snapshots of the density field at selected iterations (Fig 5).
    pub snapshots: Vec<(usize, Vec<f64>)>,
}

impl TopOptResult {
    pub fn final_compliance(&self) -> f64 {
        *self.compliance_history.last().unwrap()
    }
}

/// Run SIMP topology optimization.
pub fn run_topopt(cfg: &TopOptConfig) -> Result<TopOptResult> {
    let mut sw = Stopwatch::new();
    sw.start("setup");
    let mut problem = SimpProblem::new(cfg.simp.clone());
    let h = cfg.simp.lx / cfg.simp.nx as f64;
    let mut filt = SensitivityFilter::new(&problem.mesh, cfg.rmin_h * h);
    sw.stop();

    let ne = problem.n_elems();
    let mut rho = vec![cfg.vol_frac; ne];
    let mut mma = Mma::new(ne, cfg.move_limit);
    let oc = OcUpdate {
        move_limit: cfg.move_limit.max(0.1),
        ..OcUpdate::default()
    };
    let mut history = Vec::with_capacity(cfg.iters);
    let mut snapshots = Vec::new();
    let mut total_solver_iters = 0;

    sw.start("loop");
    for it in 0..cfg.iters {
        if cfg.rebuild_setup_each_iter {
            // Baseline archetype: everything recomputed per iteration.
            problem = SimpProblem::new(cfg.simp.clone());
            filt = SensitivityFilter::new(&problem.mesh, cfg.rmin_h * h);
        }
        let k = problem.assemble_k(&rho);
        let (u, iters) = problem.solve_state(&k, None)?;
        total_solver_iters += iters;
        let c = problem.compliance(&u);
        history.push(c);

        let dc = adjoint::sensitivity_closed_form(&problem, &rho, &u);
        let dc_f = filt.apply(&rho, &dc);

        rho = if cfg.optimizer == "oc" {
            oc.update(&rho, &dc_f, cfg.vol_frac, 1e-3)
        } else {
            let mean: f64 = rho.iter().sum::<f64>() / ne as f64;
            let g = mean / cfg.vol_frac - 1.0;
            let dgdx = vec![1.0 / (cfg.vol_frac * ne as f64); ne];
            mma.update(&rho, &dc_f, g, &dgdx, 1e-3, 1.0)
        };
        if it % (cfg.iters / 4).max(1) == 0 || it + 1 == cfg.iters {
            snapshots.push((it, rho.clone()));
        }
    }
    sw.stop();

    Ok(TopOptResult {
        rho,
        compliance_history: history,
        setup_s: sw.total("setup"),
        loop_s: sw.total("loop"),
        total_solver_iters,
        snapshots,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(optimizer: &str, iters: usize) -> TopOptConfig {
        TopOptConfig {
            simp: SimpConfig {
                nx: 16,
                ny: 8,
                lx: 16.0,
                ly: 8.0,
                ..SimpConfig::default()
            },
            iters,
            optimizer: optimizer.into(),
            ..TopOptConfig::default()
        }
    }

    #[test]
    fn compliance_decreases_oc() {
        let r = run_topopt(&small_cfg("oc", 12)).unwrap();
        let first = r.compliance_history[0];
        let last = r.final_compliance();
        assert!(last < first, "no improvement: {first} → {last}");
        // Volume constraint approximately satisfied.
        let mean: f64 = r.rho.iter().sum::<f64>() / r.rho.len() as f64;
        assert!(mean <= 0.55, "volume violated: {mean}");
    }

    #[test]
    fn compliance_decreases_mma() {
        let r = run_topopt(&small_cfg("mma", 12)).unwrap();
        assert!(r.final_compliance() < r.compliance_history[0]);
        let mean: f64 = r.rho.iter().sum::<f64>() / r.rho.len() as f64;
        assert!(mean <= 0.55, "volume violated: {mean}");
    }

    #[test]
    fn mma_and_oc_reach_similar_designs() {
        // Paper §B.4.2: frameworks converge to near-identical compliance
        // (<0.33% there); our two optimizers should land within a few %.
        let a = run_topopt(&small_cfg("oc", 25)).unwrap();
        let b = run_topopt(&small_cfg("mma", 25)).unwrap();
        let (ca, cb) = (a.final_compliance(), b.final_compliance());
        let rel = (ca - cb).abs() / ca.min(cb);
        assert!(rel < 0.10, "OC {ca} vs MMA {cb} ({rel:.3})");
    }

    #[test]
    fn densities_stay_in_bounds_and_structure_forms() {
        let r = run_topopt(&small_cfg("oc", 20)).unwrap();
        assert!(r.rho.iter().all(|&x| (1e-3..=1.0).contains(&x)));
        // Penalization should push a meaningful fraction toward 0/1.
        let extreme = r
            .rho
            .iter()
            .filter(|&&x| !(0.2..=0.8).contains(&x))
            .count() as f64
            / r.rho.len() as f64;
        assert!(extreme > 0.3, "design not binarizing: {extreme}");
    }
}
