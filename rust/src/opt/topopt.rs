//! The TensorOpt driver: SIMP compliance minimization with MMA (or OC),
//! instrumented with the Table-3 stage split (setup vs optimization loop).

use anyhow::Result;

use crate::util::timer::Stopwatch;

use super::adjoint;
use super::filter::SensitivityFilter;
use super::mma::{Mma, OcUpdate};
use super::simp::{SimpConfig, SimpProblem};

/// Driver configuration.
#[derive(Clone, Debug)]
pub struct TopOptConfig {
    pub simp: SimpConfig,
    pub vol_frac: f64,
    pub iters: usize,
    pub move_limit: f64,
    /// "mma" or "oc".
    pub optimizer: String,
    /// Filter radius in units of element size h.
    pub rmin_h: f64,
    /// Baseline mode: rebuild the assembly context (routing, tabulation,
    /// K0 locals, facet context) every iteration — the JIT/recompile-style
    /// archetype that Table 3 compares against.
    pub rebuild_setup_each_iter: bool,
}

impl Default for TopOptConfig {
    fn default() -> Self {
        TopOptConfig {
            simp: SimpConfig::default(),
            vol_frac: 0.5,
            iters: 51,
            move_limit: 0.1,
            optimizer: "mma".into(),
            rmin_h: 1.5,
            rebuild_setup_each_iter: false,
        }
    }
}

/// Outcome with the Table-3 numbers.
pub struct TopOptResult {
    pub rho: Vec<f64>,
    pub compliance_history: Vec<f64>,
    pub setup_s: f64,
    pub loop_s: f64,
    pub total_solver_iters: usize,
    /// Snapshots of the density field at selected iterations (Fig 5).
    pub snapshots: Vec<(usize, Vec<f64>)>,
}

impl TopOptResult {
    pub fn final_compliance(&self) -> f64 {
        *self.compliance_history.last().unwrap()
    }
}

/// Run SIMP topology optimization.
pub fn run_topopt(cfg: &TopOptConfig) -> Result<TopOptResult> {
    let mut sw = Stopwatch::new();
    sw.start("setup");
    let mut problem = SimpProblem::new(cfg.simp.clone());
    let h = cfg.simp.lx / cfg.simp.nx as f64;
    let mut filt = SensitivityFilter::new(&problem.mesh, cfg.rmin_h * h);
    sw.stop();

    let ne = problem.n_elems();
    let mut rho = vec![cfg.vol_frac; ne];
    let mut mma = Mma::new(ne, cfg.move_limit);
    let oc = OcUpdate {
        move_limit: cfg.move_limit.max(0.1),
        ..OcUpdate::default()
    };
    let mut history = Vec::with_capacity(cfg.iters);
    let mut snapshots = Vec::new();
    let mut total_solver_iters = 0;

    sw.start("loop");
    for it in 0..cfg.iters {
        if cfg.rebuild_setup_each_iter {
            // Baseline archetype: everything recomputed per iteration.
            problem = SimpProblem::new(cfg.simp.clone());
            filt = SensitivityFilter::new(&problem.mesh, cfg.rmin_h * h);
        }
        let k = problem.assemble_k(&rho);
        let (u, iters) = problem.solve_state(&k, None)?;
        total_solver_iters += iters;
        let c = problem.compliance(&u);
        history.push(c);

        let dc = adjoint::sensitivity_closed_form(&problem, &rho, &u);
        let dc_f = filt.apply(&rho, &dc);

        rho = if cfg.optimizer == "oc" {
            oc.update(&rho, &dc_f, cfg.vol_frac, 1e-3)
        } else {
            let mean: f64 = rho.iter().sum::<f64>() / ne as f64;
            let g = mean / cfg.vol_frac - 1.0;
            let dgdx = vec![1.0 / (cfg.vol_frac * ne as f64); ne];
            mma.update(&rho, &dc_f, g, &dgdx, 1e-3, 1.0)
        };
        if it % (cfg.iters / 4).max(1) == 0 || it + 1 == cfg.iters {
            snapshots.push((it, rho.clone()));
        }
    }
    sw.stop();

    Ok(TopOptResult {
        rho,
        compliance_history: history,
        setup_s: sw.total("setup"),
        loop_s: sw.total("loop"),
        total_solver_iters,
        snapshots,
    })
}

/// Run `S` SIMP problems in lockstep on one shared mesh topology: each
/// iteration re-assembles ALL `S` stiffness matrices through one
/// shared-topology batched Map-Reduce ([`SimpProblem::assemble_k_batch`])
/// instead of `S` scalar assemblies, and solves ALL `S` state equations
/// through one batched condensation (symbolic mapping built once at setup)
/// plus one lockstep CG — every Krylov iteration performs a single fused
/// SpMV over the shared pattern for the whole design set instead of `S`
/// scalar solves. The multi-start / sweep workload (varying volume
/// fraction, optimizer, filter radius, move limit) served at batch cost.
/// Configs must share `simp` and `iters`; results are identical to running
/// [`run_topopt`] per config (setup/loop timings are shared across the
/// batch).
pub fn run_topopt_batch(cfgs: &[TopOptConfig]) -> Result<Vec<TopOptResult>> {
    anyhow::ensure!(!cfgs.is_empty(), "empty topopt batch");
    let base = &cfgs[0];
    for cfg in cfgs {
        anyhow::ensure!(cfg.simp == base.simp, "topopt batch must share the SIMP problem");
        anyhow::ensure!(cfg.iters == base.iters, "topopt batch must share the iteration count");
        anyhow::ensure!(
            !cfg.rebuild_setup_each_iter,
            "the rebuild baseline is a per-problem archetype"
        );
    }

    struct Lane {
        rho: Vec<f64>,
        mma: Mma,
        oc: OcUpdate,
        filt: SensitivityFilter,
        history: Vec<f64>,
        snapshots: Vec<(usize, Vec<f64>)>,
        solver_iters: usize,
    }

    let mut sw = Stopwatch::new();
    sw.start("setup");
    let problem = SimpProblem::new(base.simp.clone());
    // Gather weights built once; every iteration's S-instance re-assembly
    // is then a weighted gather over the shared pattern. Likewise the
    // Dirichlet symbolic mapping: condensation bookkeeping is a function
    // of pattern + clamp only, so it is built once here and reused by
    // every iteration's blocked solve.
    let plan = problem.batched_plan();
    let cplan = problem.condense_plan();
    let ne = problem.n_elems();
    let h = base.simp.lx / base.simp.nx as f64;
    let mut lanes: Vec<Lane> = cfgs
        .iter()
        .map(|cfg| Lane {
            rho: vec![cfg.vol_frac; ne],
            mma: Mma::new(ne, cfg.move_limit),
            oc: OcUpdate {
                move_limit: cfg.move_limit.max(0.1),
                ..OcUpdate::default()
            },
            filt: SensitivityFilter::new(&problem.mesh, cfg.rmin_h * h),
            history: Vec::with_capacity(cfg.iters),
            snapshots: Vec::new(),
            solver_iters: 0,
        })
        .collect();
    sw.stop();

    sw.start("loop");
    for it in 0..base.iters {
        // One shared-topology batched assembly for the whole lane set.
        let mut moduli = Vec::with_capacity(lanes.len() * ne);
        for lane in &lanes {
            moduli.extend(problem.e_of_rho(&lane.rho));
        }
        let kbatch = plan.assemble_scaled(&moduli);
        // One blocked condensation + lockstep CG for the whole lane set.
        let (us, iters) = problem.solve_state_batch_with(&cplan, &kbatch)?;
        for (s, (lane, cfg)) in lanes.iter_mut().zip(cfgs).enumerate() {
            let u = &us[s];
            lane.solver_iters += iters[s];
            let c = problem.compliance(u);
            lane.history.push(c);

            let dc = adjoint::sensitivity_closed_form(&problem, &lane.rho, u);
            let dc_f = lane.filt.apply(&lane.rho, &dc);

            lane.rho = if cfg.optimizer == "oc" {
                lane.oc.update(&lane.rho, &dc_f, cfg.vol_frac, 1e-3)
            } else {
                let mean: f64 = lane.rho.iter().sum::<f64>() / ne as f64;
                let g = mean / cfg.vol_frac - 1.0;
                let dgdx = vec![1.0 / (cfg.vol_frac * ne as f64); ne];
                lane.mma.update(&lane.rho, &dc_f, g, &dgdx, 1e-3, 1.0)
            };
            if it % (cfg.iters / 4).max(1) == 0 || it + 1 == cfg.iters {
                lane.snapshots.push((it, lane.rho.clone()));
            }
        }
    }
    sw.stop();

    let (setup_s, loop_s) = (sw.total("setup"), sw.total("loop"));
    Ok(lanes
        .into_iter()
        .map(|lane| TopOptResult {
            rho: lane.rho,
            compliance_history: lane.history,
            setup_s,
            loop_s,
            total_solver_iters: lane.solver_iters,
            snapshots: lane.snapshots,
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(optimizer: &str, iters: usize) -> TopOptConfig {
        TopOptConfig {
            simp: SimpConfig {
                nx: 16,
                ny: 8,
                lx: 16.0,
                ly: 8.0,
                ..SimpConfig::default()
            },
            iters,
            optimizer: optimizer.into(),
            ..TopOptConfig::default()
        }
    }

    #[test]
    fn compliance_decreases_oc() {
        let r = run_topopt(&small_cfg("oc", 12)).unwrap();
        let first = r.compliance_history[0];
        let last = r.final_compliance();
        assert!(last < first, "no improvement: {first} → {last}");
        // Volume constraint approximately satisfied.
        let mean: f64 = r.rho.iter().sum::<f64>() / r.rho.len() as f64;
        assert!(mean <= 0.55, "volume violated: {mean}");
    }

    #[test]
    fn compliance_decreases_mma() {
        let r = run_topopt(&small_cfg("mma", 12)).unwrap();
        assert!(r.final_compliance() < r.compliance_history[0]);
        let mean: f64 = r.rho.iter().sum::<f64>() / r.rho.len() as f64;
        assert!(mean <= 0.55, "volume violated: {mean}");
    }

    #[test]
    fn mma_and_oc_reach_similar_designs() {
        // Paper §B.4.2: frameworks converge to near-identical compliance
        // (<0.33% there); our two optimizers should land within a few %.
        let a = run_topopt(&small_cfg("oc", 25)).unwrap();
        let b = run_topopt(&small_cfg("mma", 25)).unwrap();
        let (ca, cb) = (a.final_compliance(), b.final_compliance());
        let rel = (ca - cb).abs() / ca.min(cb);
        assert!(rel < 0.10, "OC {ca} vs MMA {cb} ({rel:.3})");
    }

    #[test]
    fn batched_lockstep_matches_individual_runs() {
        let cfg_a = small_cfg("oc", 6);
        let mut cfg_b = small_cfg("mma", 6);
        cfg_b.vol_frac = 0.4;
        let batch = run_topopt_batch(&[cfg_a.clone(), cfg_b.clone()]).unwrap();
        assert_eq!(batch.len(), 2);
        let solo_a = run_topopt(&cfg_a).unwrap();
        let solo_b = run_topopt(&cfg_b).unwrap();
        for (lane, solo) in batch.iter().zip([&solo_a, &solo_b]) {
            assert_eq!(lane.compliance_history.len(), solo.compliance_history.len());
            for (x, y) in lane.compliance_history.iter().zip(&solo.compliance_history) {
                assert!((x - y).abs() <= 1e-9 * x.abs().max(1.0), "{x} vs {y}");
            }
            assert!(crate::util::rel_l2(&lane.rho, &solo.rho) < 1e-9);
        }
    }

    #[test]
    fn batched_topopt_rejects_mismatched_meshes() {
        let cfg_a = small_cfg("oc", 4);
        let mut cfg_b = small_cfg("oc", 4);
        cfg_b.simp.nx = 12;
        assert!(run_topopt_batch(&[cfg_a, cfg_b]).is_err());
    }

    #[test]
    fn densities_stay_in_bounds_and_structure_forms() {
        let r = run_topopt(&small_cfg("oc", 20)).unwrap();
        assert!(r.rho.iter().all(|&x| (1e-3..=1.0).contains(&x)));
        // Penalization should push a meaningful fraction toward 0/1.
        let extreme = r
            .rho
            .iter()
            .filter(|&&x| !(0.2..=0.8).contains(&x))
            .count() as f64
            / r.rho.len() as f64;
        assert!(extreme > 0.3, "design not binarizing: {extreme}");
    }
}
