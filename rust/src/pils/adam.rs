//! Adam optimizer (Kingma & Ba) over flat f64 parameter vectors.

/// Adam state.
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: usize,
}

impl Adam {
    pub fn new(n: usize, lr: f64) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }

    /// One update in place.
    pub fn step(&mut self, params: &mut [f64], grad: &[f64]) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }

    /// Cosine learning-rate schedule helper (paper §B.1.2 PINN setup).
    pub fn set_cosine_lr(&mut self, step: usize, total: usize, lr0: f64, lr1: f64) {
        let frac = (step as f64 / total.max(1) as f64).clamp(0.0, 1.0);
        self.lr = lr1 + 0.5 * (lr0 - lr1) * (1.0 + (std::f64::consts::PI * frac).cos());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adam minimizes a convex quadratic.
    #[test]
    fn minimizes_quadratic() {
        let mut params = vec![5.0, -3.0];
        let mut opt = Adam::new(2, 0.05);
        for _ in 0..2000 {
            let grad: Vec<f64> = params.iter().map(|&x| 2.0 * (x - 1.0)).collect();
            opt.step(&mut params, &grad);
        }
        assert!((params[0] - 1.0).abs() < 1e-3);
        assert!((params[1] - 1.0).abs() < 1e-3);
    }

    #[test]
    fn cosine_schedule_endpoints() {
        let mut opt = Adam::new(1, 1.0);
        opt.set_cosine_lr(0, 100, 1e-3, 1e-5);
        assert!((opt.lr - 1e-3).abs() < 1e-12);
        opt.set_cosine_lr(100, 100, 1e-3, 1e-5);
        assert!((opt.lr - 1e-5).abs() < 1e-12);
    }
}
