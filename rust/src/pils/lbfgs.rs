//! L-BFGS with two-loop recursion and Armijo backtracking line search —
//! the second stage of the paper's training schedule (10k Adam + 200
//! L-BFGS, Table 1). Operates on a black-box `params → (loss, grad)`.

use anyhow::Result;

use crate::util::dot;

use super::trainer::LossFn;

/// L-BFGS optimizer state.
pub struct Lbfgs {
    /// History depth.
    pub m: usize,
    /// Armijo parameter.
    pub c1: f64,
    /// Backtracking shrink factor.
    pub shrink: f64,
    /// Max line-search trials per step.
    pub max_ls: usize,
    s_hist: Vec<Vec<f64>>,
    y_hist: Vec<Vec<f64>>,
}

impl Lbfgs {
    pub fn new(m: usize) -> Lbfgs {
        Lbfgs {
            m,
            c1: 1e-4,
            shrink: 0.5,
            max_ls: 20,
            s_hist: Vec::new(),
            y_hist: Vec::new(),
        }
    }

    /// Two-loop recursion: approximate `H·g`.
    fn direction(&self, grad: &[f64]) -> Vec<f64> {
        let mut q = grad.to_vec();
        let k = self.s_hist.len();
        let mut alphas = vec![0.0; k];
        for i in (0..k).rev() {
            let rho = 1.0 / dot(&self.y_hist[i], &self.s_hist[i]).max(1e-300);
            alphas[i] = rho * dot(&self.s_hist[i], &q);
            for (qj, yj) in q.iter_mut().zip(&self.y_hist[i]) {
                *qj -= alphas[i] * yj;
            }
        }
        // Initial Hessian scaling γ = sᵀy / yᵀy.
        if k > 0 {
            let s = &self.s_hist[k - 1];
            let y = &self.y_hist[k - 1];
            let gamma = dot(s, y) / dot(y, y).max(1e-300);
            for qj in q.iter_mut() {
                *qj *= gamma.max(1e-12);
            }
        }
        for i in 0..k {
            let rho = 1.0 / dot(&self.y_hist[i], &self.s_hist[i]).max(1e-300);
            let beta = rho * dot(&self.y_hist[i], &q);
            for (qj, sj) in q.iter_mut().zip(&self.s_hist[i]) {
                *qj += (alphas[i] - beta) * sj;
            }
        }
        q
    }

    /// One L-BFGS step with backtracking. Returns `false` only if even a
    /// restarted steepest-descent line search cannot make progress.
    pub fn step(
        &mut self,
        f: &mut dyn LossFn,
        params: &mut Vec<f64>,
        loss: &mut f64,
        grad: &mut Vec<f64>,
    ) -> Result<bool> {
        let dir: Vec<f64> = self.direction(grad).iter().map(|&d| -d).collect();
        let dg = dot(&dir, grad);
        if dg < 0.0 && self.try_line_search(f, params, loss, grad, &dir, dg)? {
            return Ok(true);
        }
        // Restart: drop the (stale) curvature history, take a gradient
        // step scaled to unit step length.
        self.s_hist.clear();
        self.y_hist.clear();
        let gnorm = dot(grad, grad).sqrt().max(1e-300);
        let sd: Vec<f64> = grad.iter().map(|&g| -g / gnorm).collect();
        let sdg = -gnorm;
        self.try_line_search(f, params, loss, grad, &sd, sdg)
    }

    fn try_line_search(
        &mut self,
        f: &mut dyn LossFn,
        params: &mut Vec<f64>,
        loss: &mut f64,
        grad: &mut Vec<f64>,
        dir: &[f64],
        dg: f64,
    ) -> Result<bool> {
        let mut t = 1.0;
        for _ in 0..self.max_ls {
            let trial: Vec<f64> = params.iter().zip(dir).map(|(&p, &d)| p + t * d).collect();
            let (l_new, g_new) = f.eval(&trial)?;
            if l_new.is_finite() && l_new <= *loss + self.c1 * t * dg {
                // Accept; update history.
                let s: Vec<f64> = trial.iter().zip(params.iter()).map(|(a, b)| a - b).collect();
                let y: Vec<f64> = g_new.iter().zip(grad.iter()).map(|(a, b)| a - b).collect();
                if dot(&s, &y) > 1e-12 {
                    self.s_hist.push(s);
                    self.y_hist.push(y);
                    if self.s_hist.len() > self.m {
                        self.s_hist.remove(0);
                        self.y_hist.remove(0);
                    }
                }
                *params = trial;
                *loss = l_new;
                *grad = g_new;
                return Ok(true);
            }
            t *= self.shrink;
        }
        Ok(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pils::trainer::LossFn;

    struct Rosenbrock;

    impl LossFn for Rosenbrock {
        fn eval(&mut self, p: &[f64]) -> Result<(f64, Vec<f64>)> {
            let (x, y) = (p[0], p[1]);
            let loss = (1.0 - x).powi(2) + 100.0 * (y - x * x).powi(2);
            let gx = -2.0 * (1.0 - x) - 400.0 * x * (y - x * x);
            let gy = 200.0 * (y - x * x);
            Ok((loss, vec![gx, gy]))
        }
    }

    #[test]
    fn minimizes_rosenbrock() {
        let mut f = Rosenbrock;
        let mut params = vec![-1.2, 1.0];
        let (mut loss, mut grad) = f.eval(&params).unwrap();
        let mut opt = Lbfgs::new(10);
        let mut stalls = 0;
        for _ in 0..1000 {
            if !opt.step(&mut f, &mut params, &mut loss, &mut grad).unwrap() {
                stalls += 1;
                if stalls > 3 {
                    break;
                }
            }
        }
        assert!(loss < 1e-8, "loss {loss}, params {params:?}");
        assert!((params[0] - 1.0).abs() < 1e-3);
    }

    struct Quadratic;

    impl LossFn for Quadratic {
        fn eval(&mut self, p: &[f64]) -> Result<(f64, Vec<f64>)> {
            let loss: f64 = p.iter().enumerate().map(|(i, &x)| (i + 1) as f64 * x * x).sum();
            let grad = p
                .iter()
                .enumerate()
                .map(|(i, &x)| 2.0 * (i + 1) as f64 * x)
                .collect();
            Ok((loss, grad))
        }
    }

    #[test]
    fn converges_faster_than_gd_on_illconditioned_quadratic() {
        let mut f = Quadratic;
        let mut params: Vec<f64> = (0..20).map(|i| 1.0 + i as f64 * 0.1).collect();
        let (mut loss, mut grad) = f.eval(&params).unwrap();
        let mut opt = Lbfgs::new(10);
        for _ in 0..60 {
            if !opt.step(&mut f, &mut params, &mut loss, &mut grad).unwrap() {
                break;
            }
        }
        assert!(loss < 1e-12, "loss {loss}");
    }
}
