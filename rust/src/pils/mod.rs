//! TensorPILS — physics-informed neural solvers driven from Rust
//! (downstream application *ii* of the paper).
//!
//! The AOT artifacts expose each method (PINN / VPINN / Deep Ritz /
//! TensorPILS) as a black-box `params → (loss, ∇loss)` HLO program; this
//! module supplies the optimizers ([`adam`], [`lbfgs`]) and the training
//! loop ([`trainer`]), plus SIREN parameter I/O and evaluation ([`siren`]).
//! Python never runs during training — the paper's schedule (Adam then
//! L-BFGS) executes entirely in Rust against PJRT executables.

pub mod adam;
pub mod lbfgs;
pub mod siren;
pub mod trainer;

pub use adam::Adam;
pub use lbfgs::Lbfgs;
pub use trainer::{ArtifactLoss, LossFn, Operand, TrainLog};
