//! SIREN parameter I/O and PJRT-based evaluation.

use anyhow::{Context, Result};

use crate::runtime::Runtime;

/// Load an initial parameter blob (`siren_init_s{seed}.bin`: raw
/// little-endian f32) as f64.
pub fn load_init(runtime: &Runtime, seed: usize) -> Result<Vec<f64>> {
    let info = runtime.manifest.get(&format!("siren_init_s{seed}"))?;
    let bytes = std::fs::read(&info.file)
        .with_context(|| format!("reading init blob {}", info.file.display()))?;
    anyhow::ensure!(bytes.len() % 4 == 0, "blob not f32-aligned");
    let mut out = Vec::with_capacity(bytes.len() / 4);
    for c in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f64);
    }
    let expect = info.meta.get("param_count").copied().unwrap_or(0.0) as usize;
    anyhow::ensure!(out.len() == expect, "blob length {} != {}", out.len(), expect);
    Ok(out)
}

/// Evaluate a trained SIREN at arbitrary points via the `siren_eval`
/// artifact, padding to its point bucket.
pub fn eval(runtime: &Runtime, params: &[f64], points: &[f64]) -> Result<Vec<f64>> {
    let info = runtime.manifest.get("siren_eval")?.clone();
    let bucket = info.inputs[1].shape[0];
    assert_eq!(points.len() % 2, 0);
    let n = points.len() / 2;
    let p32: Vec<f32> = params.iter().map(|&x| x as f32).collect();
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    while start < n {
        let chunk = (n - start).min(bucket);
        let mut pts32 = vec![0.0f32; bucket * 2];
        for (dst, src) in pts32.iter_mut().zip(&points[start * 2..(start + chunk) * 2]) {
            *dst = *src as f32;
        }
        let result = runtime.execute_f32("siren_eval", &[&p32, &pts32])?;
        out.extend(result[0][..chunk].iter().map(|&v| v as f64));
        start += chunk;
    }
    Ok(out)
}
