//! Training loop plumbing: black-box artifact losses + schedule execution.

use anyhow::Result;

use crate::runtime::exec::{Operand as ExecOperand, Runtime};
use crate::util::timer::time_it;

/// Owned operand buffer for the fixed (non-parameter) artifact inputs.
#[derive(Clone, Debug)]
pub enum Operand {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Operand {
    pub fn from_f64(xs: &[f64]) -> Operand {
        Operand::F32(xs.iter().map(|&x| x as f32).collect())
    }

    pub fn from_usize(xs: &[usize]) -> Operand {
        Operand::I32(xs.iter().map(|&x| x as i32).collect())
    }
}

/// A `params → (loss, grad)` function.
pub trait LossFn {
    fn eval(&mut self, params: &[f64]) -> Result<(f64, Vec<f64>)>;
}

/// An artifact-backed loss: input 0 is the flat parameter vector; the
/// remaining inputs are fixed per problem instance (mesh data, sparse K,
/// forcing, frequency...). Output 0 is the scalar loss, output 1 the
/// parameter gradient.
pub struct ArtifactLoss<'rt> {
    pub runtime: &'rt Runtime,
    pub name: String,
    pub fixed: Vec<Operand>,
    /// Count of `eval` calls (for it/s metrics).
    pub calls: usize,
}

impl<'rt> ArtifactLoss<'rt> {
    pub fn new(runtime: &'rt Runtime, name: &str, fixed: Vec<Operand>) -> ArtifactLoss<'rt> {
        ArtifactLoss {
            runtime,
            name: name.to_string(),
            fixed,
            calls: 0,
        }
    }
}

impl LossFn for ArtifactLoss<'_> {
    fn eval(&mut self, params: &[f64]) -> Result<(f64, Vec<f64>)> {
        self.calls += 1;
        let p32: Vec<f32> = params.iter().map(|&x| x as f32).collect();
        let mut inputs: Vec<ExecOperand<'_>> = Vec::with_capacity(1 + self.fixed.len());
        inputs.push(ExecOperand::F32(&p32));
        for op in &self.fixed {
            inputs.push(match op {
                Operand::F32(v) => ExecOperand::F32(v),
                Operand::I32(v) => ExecOperand::I32(v),
            });
        }
        let out = self.runtime.execute(&self.name, &inputs)?;
        anyhow::ensure!(out.len() >= 2, "loss artifact must return (loss, grad)");
        let loss = out[0][0] as f64;
        let grad = out[1].iter().map(|&g| g as f64).collect();
        Ok((loss, grad))
    }
}

/// Record of one training run (Fig B.11-style curves + it/s for Table 1).
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    /// (iteration, loss) samples.
    pub curve: Vec<(usize, f64)>,
    pub adam_iters: usize,
    pub adam_secs: f64,
    pub lbfgs_iters: usize,
    pub lbfgs_secs: f64,
    pub final_loss: f64,
}

impl TrainLog {
    pub fn adam_its_per_sec(&self) -> f64 {
        self.adam_iters as f64 / self.adam_secs.max(1e-12)
    }

    pub fn lbfgs_its_per_sec(&self) -> f64 {
        self.lbfgs_iters as f64 / self.lbfgs_secs.max(1e-12)
    }
}

/// Clip a gradient to a maximum global norm (rollout training through
/// scan can produce exploding gradients early on).
pub fn clip_grad(grad: &mut [f64], max_norm: f64) {
    let norm = crate::util::norm2(grad);
    if norm > max_norm {
        let scale = max_norm / norm;
        for g in grad.iter_mut() {
            *g *= scale;
        }
    }
}

/// The paper's schedule: `adam_iters` of Adam (cosine LR) followed by
/// `lbfgs_iters` of L-BFGS. Returns the trained parameters + log.
pub fn train_schedule(
    f: &mut dyn LossFn,
    params0: Vec<f64>,
    adam_iters: usize,
    lbfgs_iters: usize,
    lr: f64,
) -> Result<(Vec<f64>, TrainLog)> {
    let mut params = params0;
    let mut log = TrainLog::default();
    let log_every = (adam_iters / 50).max(1);

    let mut adam = super::Adam::new(params.len(), lr);
    let ((), secs) = time_it(|| ());
    let _ = secs;
    let t0 = std::time::Instant::now();
    let mut last_loss = f64::NAN;
    for it in 0..adam_iters {
        adam.set_cosine_lr(it, adam_iters, lr, lr * 0.01);
        let (loss, mut grad) = f.eval(&params)?;
        clip_grad(&mut grad, 100.0);
        adam.step(&mut params, &grad);
        last_loss = loss;
        if it % log_every == 0 {
            log.curve.push((it, loss));
        }
    }
    log.adam_iters = adam_iters;
    log.adam_secs = t0.elapsed().as_secs_f64();

    let t1 = std::time::Instant::now();
    if lbfgs_iters > 0 {
        let (mut loss, mut grad) = f.eval(&params)?;
        let mut lbfgs = super::Lbfgs::new(10);
        for it in 0..lbfgs_iters {
            log.lbfgs_iters = it + 1;
            if !lbfgs.step(f, &mut params, &mut loss, &mut grad)? {
                break;
            }
            log.curve.push((adam_iters + it, loss));
        }
        last_loss = loss;
    }
    log.lbfgs_secs = t1.elapsed().as_secs_f64();
    log.final_loss = last_loss;
    Ok((params, log))
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Sphere;

    impl LossFn for Sphere {
        fn eval(&mut self, p: &[f64]) -> Result<(f64, Vec<f64>)> {
            Ok((
                p.iter().map(|x| x * x).sum(),
                p.iter().map(|x| 2.0 * x).collect(),
            ))
        }
    }

    #[test]
    fn schedule_reduces_loss() {
        let mut f = Sphere;
        let (params, log) = train_schedule(&mut f, vec![3.0, -2.0, 1.0], 200, 20, 0.05).unwrap();
        assert!(log.final_loss < 1e-6, "{log:?}");
        assert!(params.iter().all(|x| x.abs() < 1e-3));
        assert!(log.adam_its_per_sec() > 0.0);
        assert!(!log.curve.is_empty());
    }
}
