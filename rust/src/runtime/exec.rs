//! The [`Runtime`]: PJRT CPU client + compiled-executable cache + typed
//! execution helpers.
//!
//! The real implementation needs the vendored `xla` crate and is gated
//! behind the `pjrt` cargo feature. Without the feature (the default,
//! offline build) a stub `Runtime` with the same API surface is compiled:
//! its constructor always returns an error, so every caller that handles
//! missing artifacts (`Runtime::new().ok()` / `runtime_or_skip()`) degrades
//! to the native Map path exactly as if `make artifacts` had not been run.

use std::path::PathBuf;

use anyhow::Result;

use super::manifest::Manifest;

/// A typed input operand (f32 tensors, i32 index arrays).
pub enum Operand<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// f64 → f32 narrowing for the artifact path.
pub fn to_f32(xs: &[f64]) -> Vec<f32> {
    xs.iter().map(|&x| x as f32).collect()
}

/// f32 → f64 widening back to the native path.
pub fn to_f64(xs: &[f32]) -> Vec<f64> {
    xs.iter().map(|&x| x as f64).collect()
}

/// Owns the PJRT client and all compiled executables. Not `Send`/`Sync`
/// (the underlying client is `Rc`-based) — construct once per coordinator
/// thread.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: std::cell::RefCell<
        std::collections::HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>,
    >,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a runtime over the default artifact directory.
    pub fn new() -> Result<Runtime> {
        Self::with_dir(super::artifact_dir())
    }

    /// Create a runtime over an explicit artifact directory.
    pub fn with_dir(dir: PathBuf) -> Result<Runtime> {
        use anyhow::Context as _;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(&dir)?;
        Ok(Runtime {
            client,
            manifest,
            dir,
            cache: std::cell::RefCell::new(std::collections::HashMap::new()),
        })
    }

    /// Artifact directory this runtime reads from.
    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    /// Load + compile an artifact (cached). This is the paper's JIT-free
    /// agility point: compilation happens once per (kind, bucket), never
    /// per mesh.
    pub fn load(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        use anyhow::Context as _;
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let info = self.manifest.get(name)?;
        let proto = xla::HloModuleProto::from_text_file(&info.file)
            .with_context(|| format!("parsing HLO text {}", info.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Drop all cached executables (used by the "recompile mode" baseline
    /// that simulates per-mesh JIT frameworks).
    pub fn clear_cache(&self) {
        self.cache.borrow_mut().clear();
    }

    /// Execute an artifact on f32 inputs; returns all tuple outputs as f32
    /// vectors. Input shapes are validated against the manifest.
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let info = self.manifest.get(name)?.clone();
        anyhow::ensure!(
            inputs.len() == info.inputs.len(),
            "artifact {name}: expected {} inputs, got {}",
            info.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, spec) in inputs.iter().zip(&info.inputs) {
            anyhow::ensure!(
                data.len() == spec.numel(),
                "artifact {name}: input {} expects {} elements, got {}",
                spec.name,
                spec.numel(),
                data.len()
            );
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        let exe = self.load(name)?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }

    /// Execute with mixed f32/i32 inputs (index arrays for the model
    /// artifacts). `inputs` supplies each operand as [`Operand`].
    pub fn execute(&self, name: &str, inputs: &[Operand<'_>]) -> Result<Vec<Vec<f32>>> {
        let info = self.manifest.get(name)?.clone();
        anyhow::ensure!(
            inputs.len() == info.inputs.len(),
            "artifact {name}: expected {} inputs, got {}",
            info.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (op, spec) in inputs.iter().zip(&info.inputs) {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match op {
                Operand::F32(data) => {
                    anyhow::ensure!(
                        data.len() == spec.numel(),
                        "artifact {name}: input {} wrong length",
                        spec.name
                    );
                    xla::Literal::vec1(data).reshape(&dims)?
                }
                Operand::I32(data) => {
                    anyhow::ensure!(
                        data.len() == spec.numel(),
                        "artifact {name}: input {} wrong length",
                        spec.name
                    );
                    xla::Literal::vec1(data).reshape(&dims)?
                }
            };
            literals.push(lit);
        }
        let exe = self.load(name)?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// Stub runtime compiled when the `pjrt` feature is off: construction
/// always fails with an actionable message, so artifact-dependent code
/// paths self-skip. The struct itself exists only so `&Runtime`-taking
/// APIs (mapper, trainers, experiment drivers) compile unchanged.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    pub manifest: Manifest,
    dir: PathBuf,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Create a runtime over the default artifact directory.
    pub fn new() -> Result<Runtime> {
        Self::with_dir(super::artifact_dir())
    }

    /// Create a runtime over an explicit artifact directory. Always errors
    /// in the stub build — with the manifest error when artifacts are
    /// missing (the common case), or a feature hint when they exist.
    pub fn with_dir(dir: PathBuf) -> Result<Runtime> {
        let _manifest = Manifest::load(&dir)?;
        anyhow::bail!(
            "PJRT runtime unavailable: built without the `pjrt` cargo feature \
             (artifacts exist in {}, but no XLA client is linked; rebuild with \
             `--features pjrt` and the vendored `xla` crate)",
            dir.display()
        )
    }

    /// Artifact directory this runtime reads from.
    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    /// Number of compiled executables currently cached (always 0).
    pub fn cached(&self) -> usize {
        0
    }

    /// Drop all cached executables (no-op).
    pub fn clear_cache(&self) {}

    /// Execute an artifact on f32 inputs (always errors in the stub).
    pub fn execute_f32(&self, name: &str, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        anyhow::bail!("PJRT runtime unavailable (`pjrt` feature disabled): artifact {name}")
    }

    /// Execute with mixed f32/i32 inputs (always errors in the stub).
    pub fn execute(&self, name: &str, _inputs: &[Operand<'_>]) -> Result<Vec<Vec<f32>>> {
        anyhow::bail!("PJRT runtime unavailable (`pjrt` feature disabled): artifact {name}")
    }
}
