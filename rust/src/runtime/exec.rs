//! The [`Runtime`]: PJRT CPU client + compiled-executable cache + typed
//! execution helpers.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{Context, Result};

use super::manifest::Manifest;

/// Owns the PJRT client and all compiled executables. Not `Send`/`Sync`
/// (the underlying client is `Rc`-based) — construct once per coordinator
/// thread.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a runtime over the default artifact directory.
    pub fn new() -> Result<Runtime> {
        Self::with_dir(super::artifact_dir())
    }

    /// Create a runtime over an explicit artifact directory.
    pub fn with_dir(dir: PathBuf) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(&dir)?;
        Ok(Runtime {
            client,
            manifest,
            dir,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Artifact directory this runtime reads from.
    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    /// Load + compile an artifact (cached). This is the paper's JIT-free
    /// agility point: compilation happens once per (kind, bucket), never
    /// per mesh.
    pub fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let info = self.manifest.get(name)?;
        let proto = xla::HloModuleProto::from_text_file(&info.file)
            .with_context(|| format!("parsing HLO text {}", info.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Drop all cached executables (used by the "recompile mode" baseline
    /// that simulates per-mesh JIT frameworks).
    pub fn clear_cache(&self) {
        self.cache.borrow_mut().clear();
    }

    /// Execute an artifact on f32 inputs; returns all tuple outputs as f32
    /// vectors. Input shapes are validated against the manifest.
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let info = self.manifest.get(name)?.clone();
        anyhow::ensure!(
            inputs.len() == info.inputs.len(),
            "artifact {name}: expected {} inputs, got {}",
            info.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, spec) in inputs.iter().zip(&info.inputs) {
            anyhow::ensure!(
                data.len() == spec.numel(),
                "artifact {name}: input {} expects {} elements, got {}",
                spec.name,
                spec.numel(),
                data.len()
            );
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        let exe = self.load(name)?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }

    /// Execute with mixed f32/i32 inputs (index arrays for the model
    /// artifacts). `inputs` supplies each operand as [`Operand`].
    pub fn execute(&self, name: &str, inputs: &[Operand<'_>]) -> Result<Vec<Vec<f32>>> {
        let info = self.manifest.get(name)?.clone();
        anyhow::ensure!(
            inputs.len() == info.inputs.len(),
            "artifact {name}: expected {} inputs, got {}",
            info.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (op, spec) in inputs.iter().zip(&info.inputs) {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match op {
                Operand::F32(data) => {
                    anyhow::ensure!(
                        data.len() == spec.numel(),
                        "artifact {name}: input {} wrong length",
                        spec.name
                    );
                    xla::Literal::vec1(data).reshape(&dims)?
                }
                Operand::I32(data) => {
                    anyhow::ensure!(
                        data.len() == spec.numel(),
                        "artifact {name}: input {} wrong length",
                        spec.name
                    );
                    xla::Literal::vec1(data).reshape(&dims)?
                }
            };
            literals.push(lit);
        }
        let exe = self.load(name)?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// A typed input operand.
pub enum Operand<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// f64 → f32 narrowing for the artifact path.
pub fn to_f32(xs: &[f64]) -> Vec<f32> {
    xs.iter().map(|&x| x as f32).collect()
}

/// f32 → f64 widening back to the native path.
pub fn to_f64(xs: &[f32]) -> Vec<f64> {
    xs.iter().map(|&x| x as f64).collect()
}
