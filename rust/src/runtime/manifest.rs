//! Artifact manifest parsing (`artifacts/manifest.json`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Declared tensor signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Artifact family (e.g. `poisson3d_local`).
    pub kind: String,
    /// Element bucket for Map-stage artifacts (0 otherwise).
    pub bucket: usize,
    /// Local matrix size for Map-stage artifacts (0 otherwise).
    pub kl: usize,
    /// All remaining numeric metadata (param counts, λ, μ, mesh sizes...).
    pub meta: BTreeMap<String, f64>,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub buckets: Vec<usize>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;
        let mut buckets = Vec::new();
        for b in v.get("buckets")?.as_arr()? {
            buckets.push(b.as_usize()?);
        }
        buckets.sort_unstable();
        let mut artifacts = BTreeMap::new();
        for (name, entry) in v.get("artifacts")?.as_obj()? {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                let mut out = Vec::new();
                for t in entry.get(key)?.as_arr()? {
                    let shape = t
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|x| x.as_usize())
                        .collect::<Result<Vec<_>>>()?;
                    out.push(TensorSpec {
                        name: t
                            .get("name")
                            .and_then(|n| n.as_str().map(str::to_string))
                            .unwrap_or_default(),
                        shape,
                        dtype: t.get("dtype")?.as_str()?.to_string(),
                    });
                }
                Ok(out)
            };
            let mut meta = BTreeMap::new();
            for (k, val) in entry.as_obj()? {
                if let Json::Num(x) = val {
                    meta.insert(k.clone(), *x);
                }
            }
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    file: dir.join(entry.get("file")?.as_str()?),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                    kind: entry
                        .get("kind")
                        .and_then(|k| k.as_str().map(str::to_string))
                        .unwrap_or_default(),
                    bucket: entry.get("bucket").and_then(|b| b.as_usize()).unwrap_or(0),
                    kl: entry.get("kl").and_then(|b| b.as_usize()).unwrap_or(0),
                    meta,
                },
            );
        }
        Ok(Manifest { buckets, artifacts })
    }

    /// Artifact of `kind` at exactly `bucket`.
    pub fn find(&self, kind: &str, bucket: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .values()
            .find(|a| a.kind == kind && a.bucket == bucket)
    }

    /// Smallest bucket ≥ `n` available for `kind`, or the largest bucket
    /// if `n` exceeds all (the mapper then chunks).
    pub fn bucket_for(&self, kind: &str, n: usize) -> Option<usize> {
        let mut available: Vec<usize> = self
            .artifacts
            .values()
            .filter(|a| a.kind == kind)
            .map(|a| a.bucket)
            .collect();
        available.sort_unstable();
        available.iter().copied().find(|&b| b >= n).or(available.last().copied())
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name:?} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join("tg_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"buckets":[256,2048],"artifacts":{
               "poisson2d_local_E256":{"file":"p.hlo.txt","kind":"poisson2d_local",
                 "bucket":256,"kl":3,
                 "inputs":[{"name":"coords","shape":[256,3,2],"dtype":"float32"}],
                 "outputs":[{"shape":[256,3,3],"dtype":"float32"}]}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.buckets, vec![256, 2048]);
        let a = m.get("poisson2d_local_E256").unwrap();
        assert_eq!(a.inputs[0].shape, vec![256, 3, 2]);
        assert_eq!(a.inputs[0].numel(), 1536);
        assert_eq!(m.bucket_for("poisson2d_local", 100), Some(256));
        assert_eq!(m.bucket_for("poisson2d_local", 10_000), Some(256)); // largest
        assert_eq!(m.bucket_for("missing", 1), None);
    }
}
