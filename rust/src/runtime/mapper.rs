//! Artifact-backed Batch-Map: run the AOT Pallas kernel on the element
//! batch, padding to the bucket ladder.
//!
//! Padding uses *degenerate elements* (all-zero coordinates ⇒ |det J| = 0 ⇒
//! exactly zero contribution — validated in both pytest and the kernel unit
//! tests), so a single compiled executable serves every mesh size up to its
//! bucket: the paper's "zero-compilation agility" reproduced under AOT
//! constraints. Batches larger than the top bucket are chunked.

use anyhow::Result;

use super::exec::Runtime;

/// Map-stage artifact families.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapKind {
    Poisson2d,
    Poisson3d,
    Load2d,
    Load3d,
    Mass2d,
    Mass3d,
    Elasticity3d,
    ElasticityQ4,
}

impl MapKind {
    pub fn kind_str(self) -> &'static str {
        match self {
            MapKind::Poisson2d => "poisson2d_local",
            MapKind::Poisson3d => "poisson3d_local",
            MapKind::Load2d => "load2d_local",
            MapKind::Load3d => "load3d_local",
            MapKind::Mass2d => "mass2d_local",
            MapKind::Mass3d => "mass3d_local",
            MapKind::Elasticity3d => "elasticity3d_local",
            MapKind::ElasticityQ4 => "elasticity2d_q4_local",
        }
    }

    /// (nodes per element, spatial dim, quad points, local output size,
    /// matrix-valued?)
    pub fn dims(self) -> (usize, usize, usize, usize, bool) {
        match self {
            MapKind::Poisson2d => (3, 2, 3, 3, true),
            MapKind::Poisson3d => (4, 3, 4, 4, true),
            MapKind::Load2d => (3, 2, 3, 3, false),
            MapKind::Load3d => (4, 3, 4, 4, false),
            MapKind::Mass2d => (3, 2, 3, 3, true),
            MapKind::Mass3d => (4, 3, 4, 4, true),
            MapKind::Elasticity3d => (4, 3, 4, 12, true),
            MapKind::ElasticityQ4 => (4, 2, 4, 8, true),
        }
    }
}

/// The artifact-backed Map stage.
pub struct PjrtMapper<'rt> {
    pub runtime: &'rt Runtime,
}

impl<'rt> PjrtMapper<'rt> {
    pub fn new(runtime: &'rt Runtime) -> Self {
        PjrtMapper { runtime }
    }

    /// Run the Map kernel: `coords` is `E×k×d` (f64, native layout),
    /// `coeff` is `E×Q`. Returns the local tensor (`E×kl×kl` or `E×kl`)
    /// as f64 for the native Reduce stage.
    pub fn map(&self, kind: MapKind, coords: &[f64], coeff: &[f64]) -> Result<Vec<f64>> {
        let (k, d, q, kl, is_matrix) = kind.dims();
        let per_elem_coords = k * d;
        anyhow::ensure!(coords.len() % per_elem_coords == 0, "coords shape");
        let n_elems = coords.len() / per_elem_coords;
        anyhow::ensure!(coeff.len() == n_elems * q, "coeff shape");
        let out_per_elem = if is_matrix { kl * kl } else { kl };

        let bucket = self
            .runtime
            .manifest
            .bucket_for(kind.kind_str(), n_elems)
            .ok_or_else(|| anyhow::anyhow!("no artifact for kind {:?}", kind))?;
        let name = format!("{}_E{}", kind.kind_str(), bucket);

        let mut out = Vec::with_capacity(n_elems * out_per_elem);
        let mut start = 0;
        while start < n_elems {
            let chunk = (n_elems - start).min(bucket);
            // Pad chunk to the bucket with zero (degenerate) elements.
            let mut c32 = vec![0.0f32; bucket * per_elem_coords];
            for (dst, src) in c32
                .iter_mut()
                .zip(&coords[start * per_elem_coords..(start + chunk) * per_elem_coords])
            {
                *dst = *src as f32;
            }
            let mut q32 = vec![0.0f32; bucket * q];
            for (dst, src) in q32.iter_mut().zip(&coeff[start * q..(start + chunk) * q]) {
                *dst = *src as f32;
            }
            let results = self.runtime.execute_f32(&name, &[&c32, &q32])?;
            let local = &results[0];
            out.extend(local[..chunk * out_per_elem].iter().map(|&v| v as f64));
            start += chunk;
        }
        Ok(out)
    }

    /// Convenience: Map via PJRT + Reduce via the context routing — the
    /// full TensorGalerkin assembly with the Pallas kernel on the hot path.
    pub fn assemble_matrix(
        &self,
        ctx: &crate::assembly::AssemblyContext,
        kind: MapKind,
        coeff: &[f64],
    ) -> Result<crate::sparse::Csr> {
        let coords = crate::fem::geometry::gather_coords(&ctx.mesh);
        let local = self.map(kind, &coords, coeff)?;
        Ok(ctx.reduce_matrix(&local))
    }

    /// Map + Reduce for load vectors.
    pub fn assemble_vector(
        &self,
        ctx: &crate::assembly::AssemblyContext,
        kind: MapKind,
        coeff: &[f64],
    ) -> Result<Vec<f64>> {
        let coords = crate::fem::geometry::gather_coords(&ctx.mesh);
        let local = self.map(kind, &coords, coeff)?;
        Ok(ctx.reduce_vector(&local))
    }
}

/// Quadrature-point coefficient buffer (`E×Q`) from a constant.
pub fn const_coeff(n_elems: usize, q: usize, value: f64) -> Vec<f64> {
    vec![value; n_elems * q]
}
