//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! Rust request path.
//!
//! * [`manifest`] — parse `artifacts/manifest.json` written by
//!   `python/compile/aot.py`.
//! * [`exec`] — the [`Runtime`]: a PJRT CPU client plus a compile cache
//!   (one `PjRtLoadedExecutable` per artifact, compiled on first use).
//! * [`mapper`] — the artifact-backed Batch-Map stage with element-bucket
//!   padding and chunking, feeding Stage II's routing reduce.
//!
//! The `xla` crate's client wraps an `Rc`, so a [`Runtime`] is deliberately
//! *not* `Send`/`Sync`: create it on the coordinator thread (experiments
//! and benches are single-threaded through the runtime; the thread pool is
//! used inside the native compute stages only).

pub mod exec;
pub mod manifest;
pub mod mapper;

pub use exec::Runtime;
pub use manifest::{ArtifactInfo, Manifest};
pub use mapper::{MapKind, PjrtMapper};

/// Default artifact directory, overridable via `TG_ARTIFACTS`.
pub fn artifact_dir() -> std::path::PathBuf {
    std::env::var("TG_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string())
        .into()
}
