//! Per-mesh health tracking and the serving circuit breaker.
//!
//! The escalation ladder in [`MeshSession`](crate::session::MeshSession)
//! is memoryless: every request on a chronically failing mesh burns the
//! full ladder again. This module makes failure *history* a first-class
//! serving input. A [`MeshHealth`] tracker per registry entry folds each
//! observed lane outcome (ok / rescued-by-ladder / exhausted) into EWMAs,
//! a consecutive-failure streak, and per-rung attempt/rescue counts, and
//! drives a three-state circuit breaker:
//!
//! - **Closed** — normal serving. A failure observation that pushes the
//!   exhausted-EWMA past `open_failure_rate` (after `min_observations`)
//!   or the streak past `open_streak` trips the breaker Open. Only a
//!   *failure* can trip it: a success with a still-hot EWMA never
//!   re-opens a freshly closed breaker.
//! - **Open** — requests are shed synchronously (the caller answers
//!   `SolveError::Unhealthy` with a `retry_after_ms` hint) without
//!   touching the drain budget of healthy meshes, and stragglers that
//!   were already queued when the breaker tripped are answered the same
//!   way at drain ([`HealthRegistry::shed_at_drain`]) instead of
//!   occupying dispatch slots. After `open_ms` the next admission
//!   becomes a probe.
//! - **HalfOpen** — exactly one probe group is admitted; everything else
//!   sheds until the probe settles. A successful probe closes the
//!   breaker; a failed one re-opens it. A probe that is never observed
//!   (lost, expired, rejected) times out after `open_ms` and a fresh
//!   probe is allowed.
//!
//! Time comes from an injectable [`ClockSource`]: wall time in
//! production, a manually advanced millisecond counter under test, so
//! `fault-inject` breaker tests are deterministic.
//!
//! The [`HealthRegistry`] aggregates per-mesh trackers plus a *global*
//! sick-traffic EWMA used for adaptive load shedding: when rescued or
//! exhausted lanes dominate recent traffic the coordinator tightens its
//! admission bound (`max_queue / tighten_divisor`) and relaxes it again
//! on recovery (hysteresis via [`HealthRegistry::update_tightened`]).
//!
//! Everything here is inert unless [`HealthConfig::enabled`] is set; the
//! default config keeps every serving path bitwise identical to the
//! tracker-free stack.

use std::collections::HashMap;
use std::time::Instant;

use crate::solver::{EscalationReport, EscalationStage};

/// Circuit-breaker state of one mesh.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Normal serving; failures are being counted.
    Closed,
    /// Chronically failing; requests are shed until the open window ends.
    Open,
    /// One probe group is admitted to test recovery.
    HalfOpen,
}

/// Health classification of one served lane outcome.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneOutcome {
    /// Converged on the first attempt.
    Ok,
    /// Converged, but only after the escalation ladder intervened.
    Rescued,
    /// Failed even after (or without) the ladder.
    Exhausted,
}

/// Admission verdict for a request group on one mesh.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitDecision {
    /// Serve it.
    Admit,
    /// Breaker is open (or a probe is already in flight): answer
    /// `Unhealthy` synchronously and retry after the hinted delay.
    Shed {
        /// Milliseconds until the breaker will consider a probe.
        retry_after_ms: u64,
    },
}

/// Tuning knobs for health tracking, the breaker, and adaptive shedding.
///
/// The `Default` (== [`HealthConfig::disabled`]) turns the whole
/// subsystem off; [`HealthConfig::breaker`] is the enabled preset.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HealthConfig {
    /// Master switch; `false` makes every tracker call a no-op.
    pub enabled: bool,
    /// EWMA smoothing factor in `(0, 1]` for all health averages.
    pub alpha: f64,
    /// Observations required before EWMA thresholds may trip anything.
    pub min_observations: u64,
    /// Exhausted-EWMA level at which a failure observation trips Open.
    pub open_failure_rate: f64,
    /// Consecutive exhausted outcomes that trip Open regardless of EWMA
    /// (0 disables the streak trigger).
    pub open_streak: u32,
    /// Milliseconds a breaker stays Open before admitting a probe; also
    /// the timeout after which an unobserved probe is retried.
    pub open_ms: u64,
    /// Global sick-traffic EWMA level that tightens the admission bound.
    pub tighten_threshold: f64,
    /// Divisor applied to the base `max_queue` while tightened.
    pub tighten_divisor: usize,
    /// Use a manually advanced clock instead of wall time (tests).
    pub manual_clock: bool,
}

impl HealthConfig {
    /// Health tracking off — the default; serving is bitwise identical
    /// to the tracker-free stack.
    pub fn disabled() -> Self {
        HealthConfig {
            enabled: false,
            alpha: 0.2,
            min_observations: 8,
            open_failure_rate: 0.6,
            open_streak: 4,
            open_ms: 250,
            tighten_threshold: 0.5,
            tighten_divisor: 4,
            manual_clock: false,
        }
    }

    /// The enabled preset with the same tuning as [`disabled`].
    ///
    /// [`disabled`]: HealthConfig::disabled
    pub fn breaker() -> Self {
        HealthConfig { enabled: true, ..HealthConfig::disabled() }
    }
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig::disabled()
    }
}

/// Injectable time source: wall time in production, a manually advanced
/// counter under test.
#[derive(Clone, Copy, Debug)]
enum ClockSource {
    /// Milliseconds elapsed since the registry was created.
    Wall(Instant),
    /// Milliseconds advanced explicitly via `advance`.
    Manual(u64),
}

impl ClockSource {
    fn now_ms(&self) -> u64 {
        match self {
            ClockSource::Wall(origin) => origin.elapsed().as_millis() as u64,
            ClockSource::Manual(ms) => *ms,
        }
    }
}

/// Breaker transition produced by one admit/observe call (registry-level
/// counters are bumped from these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Transition {
    None,
    Opened,
    HalfOpened,
    Closed,
}

/// Health history of one mesh: outcome EWMAs, the failure streak,
/// rung-level ladder statistics, and the breaker state machine.
#[derive(Clone, Debug)]
pub struct MeshHealth {
    state: BreakerState,
    ewma_failed: f64,
    ewma_rescued: f64,
    ewma_exhausted: f64,
    streak: u32,
    observations: u64,
    opened_at_ms: u64,
    probe_at_ms: u64,
    probe_inflight: bool,
    rung_attempts: [u64; EscalationStage::COUNT],
    rung_rescues: [u64; EscalationStage::COUNT],
    rungs_skipped: u64,
}

impl Default for MeshHealth {
    fn default() -> Self {
        MeshHealth {
            state: BreakerState::Closed,
            ewma_failed: 0.0,
            ewma_rescued: 0.0,
            ewma_exhausted: 0.0,
            streak: 0,
            observations: 0,
            opened_at_ms: 0,
            probe_at_ms: 0,
            probe_inflight: false,
            rung_attempts: [0; EscalationStage::COUNT],
            rung_rescues: [0; EscalationStage::COUNT],
            rungs_skipped: 0,
        }
    }
}

impl MeshHealth {
    /// Admission decision for a request group arriving now.
    fn admit(&mut self, now_ms: u64, cfg: &HealthConfig) -> (AdmitDecision, Transition) {
        match self.state {
            BreakerState::Closed => (AdmitDecision::Admit, Transition::None),
            BreakerState::Open => {
                let due = self.opened_at_ms.saturating_add(cfg.open_ms);
                if now_ms >= due {
                    self.state = BreakerState::HalfOpen;
                    self.probe_inflight = true;
                    self.probe_at_ms = now_ms;
                    (AdmitDecision::Admit, Transition::HalfOpened)
                } else {
                    (AdmitDecision::Shed { retry_after_ms: due - now_ms }, Transition::None)
                }
            }
            BreakerState::HalfOpen => {
                let timeout = self.probe_at_ms.saturating_add(cfg.open_ms);
                if self.probe_inflight && now_ms < timeout {
                    // One probe at a time: everything else sheds until
                    // the in-flight probe settles or times out.
                    let wait = timeout.saturating_sub(now_ms).max(1);
                    (AdmitDecision::Shed { retry_after_ms: wait }, Transition::None)
                } else {
                    // The previous probe was lost (expired, rejected,
                    // never observed) or timed out: admit a fresh one.
                    self.probe_inflight = true;
                    self.probe_at_ms = now_ms;
                    (AdmitDecision::Admit, Transition::None)
                }
            }
        }
    }

    /// Fold one observed outcome (plus its ladder report, if any) into
    /// the history and run the breaker transitions.
    fn observe(
        &mut self,
        outcome: LaneOutcome,
        report: Option<&EscalationReport>,
        now_ms: u64,
        cfg: &HealthConfig,
    ) -> Transition {
        self.observations += 1;
        let (failed, rescued, exhausted) = match outcome {
            LaneOutcome::Ok => (0.0, 0.0, 0.0),
            LaneOutcome::Rescued => (1.0, 1.0, 0.0),
            LaneOutcome::Exhausted => (1.0, 0.0, 1.0),
        };
        let a = cfg.alpha.clamp(0.0, 1.0);
        self.ewma_failed += a * (failed - self.ewma_failed);
        self.ewma_rescued += a * (rescued - self.ewma_rescued);
        self.ewma_exhausted += a * (exhausted - self.ewma_exhausted);
        if outcome == LaneOutcome::Exhausted {
            self.streak = self.streak.saturating_add(1);
        } else {
            self.streak = 0;
        }
        if let Some(rep) = report {
            for att in &rep.attempts {
                self.rung_attempts[att.stage.index()] += 1;
            }
            if let Some(stage) = rep.resolved_by {
                self.rung_rescues[stage.index()] += 1;
            }
            self.rungs_skipped += rep.skipped.len() as u64;
        }
        match self.state {
            BreakerState::Closed => {
                let chronic = self.observations >= cfg.min_observations
                    && self.ewma_exhausted >= cfg.open_failure_rate;
                let streaky = cfg.open_streak > 0 && self.streak >= cfg.open_streak;
                // Trip only on a failure observation: a success while
                // the EWMA is still hot must not re-open the breaker.
                if outcome == LaneOutcome::Exhausted && (chronic || streaky) {
                    self.state = BreakerState::Open;
                    self.opened_at_ms = now_ms;
                    return Transition::Opened;
                }
            }
            BreakerState::HalfOpen => {
                self.probe_inflight = false;
                if outcome == LaneOutcome::Exhausted {
                    self.state = BreakerState::Open;
                    self.opened_at_ms = now_ms;
                    return Transition::Opened;
                }
                self.state = BreakerState::Closed;
                // A closing probe resets the streak; the EWMAs keep
                // their memory so renewed failures re-open quickly.
                self.streak = 0;
                return Transition::Closed;
            }
            BreakerState::Open => {}
        }
        Transition::None
    }

    /// The admitted probe never made it to a solve (overload-rejected
    /// alongside its group): allow the next admission to probe afresh.
    fn cancel_probe(&mut self) {
        self.probe_inflight = false;
    }

    /// Drain-time shed check: `Some(retry_after_ms)` while the breaker
    /// is Open and the open window has not elapsed. No transition: an
    /// Open-but-due mesh serves normally (its observations make no
    /// transition in the Open state, and the next *submission* becomes
    /// the probe), and a HalfOpen probe group is never drain-shed.
    fn shed_at_drain(&self, now_ms: u64, cfg: &HealthConfig) -> Option<u64> {
        if self.state != BreakerState::Open {
            return None;
        }
        let due = self.opened_at_ms.saturating_add(cfg.open_ms);
        if now_ms >= due {
            return None;
        }
        Some(due - now_ms)
    }

    fn snapshot(&self) -> HealthSnapshot {
        HealthSnapshot {
            state: self.state,
            ewma_failed: self.ewma_failed,
            ewma_rescued: self.ewma_rescued,
            ewma_exhausted: self.ewma_exhausted,
            streak: self.streak,
            observations: self.observations,
            rung_attempts: self.rung_attempts,
            rung_rescues: self.rung_rescues,
            rungs_skipped: self.rungs_skipped,
        }
    }
}

/// Read-only view of one mesh's health, returned by
/// `BatchServer::health`.
#[derive(Clone, Debug)]
pub struct HealthSnapshot {
    /// Current breaker state.
    pub state: BreakerState,
    /// EWMA fraction of lanes that did not converge on the first try.
    pub ewma_failed: f64,
    /// EWMA fraction of lanes rescued by the escalation ladder.
    pub ewma_rescued: f64,
    /// EWMA fraction of lanes that failed even after the ladder.
    pub ewma_exhausted: f64,
    /// Consecutive exhausted outcomes.
    pub streak: u32,
    /// Total outcomes folded into this tracker.
    pub observations: u64,
    /// Ladder attempts per rung, indexed by `EscalationStage::index`.
    pub rung_attempts: [u64; EscalationStage::COUNT],
    /// Ladder rescues per rung, indexed by `EscalationStage::index`.
    pub rung_rescues: [u64; EscalationStage::COUNT],
    /// Rungs skipped as unaffordable by budget-aware escalation.
    pub rungs_skipped: u64,
}

/// All per-mesh trackers plus the global sick-traffic EWMA that drives
/// adaptive admission tightening. One lives behind a mutex in the
/// `BatchServer`; unit tests drive it directly.
#[derive(Debug)]
pub struct HealthRegistry {
    cfg: HealthConfig,
    clock: ClockSource,
    meshes: HashMap<u64, MeshHealth>,
    sick_ewma: f64,
    sick_obs: u64,
    opens: u64,
    half_opens: u64,
    closes: u64,
    shed: u64,
    tightenings: u64,
    tightened: bool,
}

impl HealthRegistry {
    /// Fresh registry (fresh clock, no history) under `cfg`.
    pub fn new(cfg: HealthConfig) -> Self {
        let clock = if cfg.manual_clock {
            ClockSource::Manual(0)
        } else {
            ClockSource::Wall(Instant::now())
        };
        HealthRegistry {
            cfg,
            clock,
            meshes: HashMap::new(),
            sick_ewma: 0.0,
            sick_obs: 0,
            opens: 0,
            half_opens: 0,
            closes: 0,
            shed: 0,
            tightenings: 0,
            tightened: false,
        }
    }

    /// Replace the config and drop all history (fresh clock included).
    pub fn reconfigure(&mut self, cfg: HealthConfig) {
        *self = HealthRegistry::new(cfg);
    }

    /// The active config.
    pub fn config(&self) -> &HealthConfig {
        &self.cfg
    }

    /// Advance the manual clock by `ms`; no-op on the wall clock.
    pub fn advance_clock(&mut self, ms: u64) {
        if let ClockSource::Manual(t) = &mut self.clock {
            *t = t.saturating_add(ms);
        }
    }

    /// Current clock reading in milliseconds.
    pub fn now_ms(&self) -> u64 {
        self.clock.now_ms()
    }

    /// Admission decision for a request group on `mesh_id`. A Shed
    /// decision is *not* counted here — the caller sheds once per
    /// request via [`note_shed`](HealthRegistry::note_shed).
    pub fn admit(&mut self, mesh_id: u64) -> AdmitDecision {
        if !self.cfg.enabled {
            return AdmitDecision::Admit;
        }
        let now = self.clock.now_ms();
        let cfg = self.cfg;
        let (decision, transition) = self.meshes.entry(mesh_id).or_default().admit(now, &cfg);
        if transition == Transition::HalfOpened {
            self.half_opens += 1;
        }
        decision
    }

    /// Count `n` requests shed on an Open breaker.
    pub fn note_shed(&mut self, n: u64) {
        self.shed += n;
    }

    /// Fold one served outcome for `mesh_id` into its tracker and the
    /// global sick-traffic EWMA.
    pub fn observe(
        &mut self,
        mesh_id: u64,
        outcome: LaneOutcome,
        report: Option<&EscalationReport>,
    ) {
        if !self.cfg.enabled {
            return;
        }
        let sick = if outcome == LaneOutcome::Ok { 0.0 } else { 1.0 };
        let a = self.cfg.alpha.clamp(0.0, 1.0);
        self.sick_ewma += a * (sick - self.sick_ewma);
        self.sick_obs += 1;
        let now = self.clock.now_ms();
        let cfg = self.cfg;
        match self.meshes.entry(mesh_id).or_default().observe(outcome, report, now, &cfg) {
            Transition::Opened => self.opens += 1,
            Transition::Closed => self.closes += 1,
            Transition::HalfOpened | Transition::None => {}
        }
    }

    /// Drain-time breaker check for stragglers already queued when the
    /// breaker tripped: `Some(retry_after_ms)` when `mesh_id`'s breaker
    /// is (still) Open with its open window not yet elapsed — the caller
    /// answers the chunk `Unhealthy` without dispatching it, counting
    /// the sheds via [`note_shed`](HealthRegistry::note_shed). Makes no
    /// state transition, so HalfOpen probe groups always drain normally
    /// and an Open-but-due mesh's stragglers are served (their
    /// observations cannot transition an Open breaker; the next
    /// submission becomes the probe).
    pub fn shed_at_drain(&self, mesh_id: u64) -> Option<u64> {
        if !self.cfg.enabled {
            return None;
        }
        let mh = self.meshes.get(&mesh_id)?;
        mh.shed_at_drain(self.clock.now_ms(), &self.cfg)
    }

    /// An admitted probe group was dropped before serving — the whole
    /// burst was overload-rejected, or the shard worker holding the probe
    /// crashed and the salvaged probe requests were answered instead of
    /// requeued: let the next admission probe. Without this a breaker
    /// whose probe died with its worker would wedge in HalfOpen until
    /// the probe timeout. No-op for untracked meshes.
    pub fn cancel_probe(&mut self, mesh_id: u64) {
        if let Some(mh) = self.meshes.get_mut(&mesh_id) {
            mh.cancel_probe();
        }
    }

    /// Health snapshot of `mesh_id`, if it has been tracked.
    pub fn snapshot(&self, mesh_id: u64) -> Option<HealthSnapshot> {
        self.meshes.get(&mesh_id).map(MeshHealth::snapshot)
    }

    /// Re-evaluate adaptive tightening from the global sick-traffic
    /// EWMA; returns whether the admission bound is currently tightened.
    /// Entering the tightened state is counted once per episode
    /// (hysteresis: staying sick does not re-count).
    pub fn update_tightened(&mut self) -> bool {
        if !self.cfg.enabled {
            self.tightened = false;
            return false;
        }
        let sick = self.sick_obs >= self.cfg.min_observations
            && self.sick_ewma >= self.cfg.tighten_threshold;
        if sick && !self.tightened {
            self.tightenings += 1;
        }
        self.tightened = sick;
        self.tightened
    }

    /// Total requests shed on Open breakers.
    pub fn shed(&self) -> u64 {
        self.shed
    }

    /// Closed → Open and HalfOpen → Open transitions.
    pub fn opens(&self) -> u64 {
        self.opens
    }

    /// Open → HalfOpen probe admissions.
    pub fn half_opens(&self) -> u64 {
        self.half_opens
    }

    /// HalfOpen → Closed recoveries.
    pub fn closes(&self) -> u64 {
        self.closes
    }

    /// Episodes in which the admission bound was tightened.
    pub fn tightenings(&self) -> u64 {
        self.tightenings
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manual_cfg() -> HealthConfig {
        HealthConfig {
            alpha: 1.0,
            min_observations: 1,
            open_failure_rate: 2.0, // unreachable: isolate the streak trigger
            open_streak: 2,
            open_ms: 100,
            manual_clock: true,
            ..HealthConfig::breaker()
        }
    }

    #[test]
    fn disabled_registry_is_inert() {
        let mut reg = HealthRegistry::new(HealthConfig::disabled());
        for _ in 0..20 {
            reg.observe(7, LaneOutcome::Exhausted, None);
            assert_eq!(reg.admit(7), AdmitDecision::Admit);
        }
        assert!(!reg.update_tightened());
        assert_eq!(reg.opens(), 0);
        assert!(reg.snapshot(7).is_none(), "disabled tracking must record nothing");
    }

    #[test]
    fn streak_opens_then_probe_closes() {
        let mut reg = HealthRegistry::new(manual_cfg());
        reg.observe(1, LaneOutcome::Exhausted, None);
        assert_eq!(reg.snapshot(1).unwrap().state, BreakerState::Closed);
        reg.observe(1, LaneOutcome::Exhausted, None);
        assert_eq!(reg.snapshot(1).unwrap().state, BreakerState::Open);
        assert_eq!(reg.opens(), 1);

        // Open window: shed with a countdown hint.
        match reg.admit(1) {
            AdmitDecision::Shed { retry_after_ms } => assert!(retry_after_ms <= 100),
            other => panic!("open breaker must shed, got {other:?}"),
        }

        // After open_ms the next admission is the probe; while it is in
        // flight every further admission sheds (one-probe semantics).
        reg.advance_clock(100);
        assert_eq!(reg.admit(1), AdmitDecision::Admit);
        assert_eq!(reg.half_opens(), 1);
        assert!(matches!(reg.admit(1), AdmitDecision::Shed { .. }));

        // Probe succeeds → Closed; streak cleared, so the next single
        // failure does not instantly re-open.
        reg.observe(1, LaneOutcome::Ok, None);
        assert_eq!(reg.snapshot(1).unwrap().state, BreakerState::Closed);
        assert_eq!(reg.closes(), 1);
        reg.observe(1, LaneOutcome::Exhausted, None);
        assert_eq!(reg.snapshot(1).unwrap().state, BreakerState::Closed);
    }

    #[test]
    fn failed_probe_reopens() {
        let mut reg = HealthRegistry::new(manual_cfg());
        reg.observe(1, LaneOutcome::Exhausted, None);
        reg.observe(1, LaneOutcome::Exhausted, None);
        reg.advance_clock(100);
        assert_eq!(reg.admit(1), AdmitDecision::Admit);
        reg.observe(1, LaneOutcome::Exhausted, None);
        assert_eq!(reg.snapshot(1).unwrap().state, BreakerState::Open);
        assert_eq!(reg.opens(), 2, "a failed probe re-opens");
        assert!(matches!(reg.admit(1), AdmitDecision::Shed { .. }));
    }

    #[test]
    fn lost_probe_times_out_and_cancel_allows_fresh_probe() {
        let mut reg = HealthRegistry::new(manual_cfg());
        reg.observe(1, LaneOutcome::Exhausted, None);
        reg.observe(1, LaneOutcome::Exhausted, None);
        reg.advance_clock(100);
        assert_eq!(reg.admit(1), AdmitDecision::Admit);
        // Probe never observed: after open_ms a fresh probe is allowed.
        reg.advance_clock(100);
        assert_eq!(reg.admit(1), AdmitDecision::Admit);
        assert_eq!(reg.half_opens(), 1, "timeout retry is not a new half-open transition");
        // An explicitly cancelled probe (overload-rejected group) frees
        // the slot immediately.
        reg.cancel_probe(1);
        assert_eq!(reg.admit(1), AdmitDecision::Admit);
    }

    #[test]
    fn success_with_hot_ewma_never_trips() {
        let cfg = HealthConfig {
            alpha: 1.0,
            min_observations: 1,
            open_failure_rate: 0.5,
            open_streak: 0, // EWMA trigger only
            manual_clock: true,
            ..HealthConfig::breaker()
        };
        let mut reg = HealthRegistry::new(cfg);
        reg.observe(3, LaneOutcome::Exhausted, None);
        assert_eq!(reg.snapshot(3).unwrap().state, BreakerState::Open);
        reg.advance_clock(300);
        assert_eq!(reg.admit(3), AdmitDecision::Admit);
        reg.observe(3, LaneOutcome::Ok, None);
        assert_eq!(reg.snapshot(3).unwrap().state, BreakerState::Closed);
        // Rescued outcome is sick for the EWMA but is not a failure
        // observation, so the breaker stays Closed.
        reg.observe(3, LaneOutcome::Rescued, None);
        assert_eq!(reg.snapshot(3).unwrap().state, BreakerState::Closed);
        assert!(reg.snapshot(3).unwrap().ewma_failed >= 0.5);
    }

    #[test]
    fn tighten_hysteresis_counts_episodes_once() {
        let mut reg = HealthRegistry::new(manual_cfg());
        assert!(!reg.update_tightened());
        reg.observe(9, LaneOutcome::Rescued, None); // alpha = 1 → sick EWMA jumps to 1
        assert!(reg.update_tightened());
        assert!(reg.update_tightened(), "staying sick keeps the bound tight");
        assert_eq!(reg.tightenings(), 1, "one episode, one count");
        reg.observe(9, LaneOutcome::Ok, None);
        assert!(!reg.update_tightened(), "recovery relaxes the bound");
        reg.observe(9, LaneOutcome::Rescued, None);
        assert!(reg.update_tightened());
        assert_eq!(reg.tightenings(), 2, "a new episode counts again");
    }

    #[test]
    fn rung_counters_fold_from_reports() {
        use crate::solver::{FailureKind, SkippedRung, SolveStats, StageAttempt};
        let mut rep = EscalationReport {
            first: Some(SolveStats::fail(3, 1.0, FailureKind::MaxIters)),
            ..EscalationReport::default()
        };
        rep.attempts.push(StageAttempt {
            stage: EscalationStage::DirectLu,
            stats: SolveStats::ok(0, 0.0),
        });
        rep.resolved_by = Some(EscalationStage::DirectLu);
        rep.skipped.push(SkippedRung {
            stage: EscalationStage::IterBump,
            est_ms: 1e4,
            budget_ms: 5.0,
        });
        let mut reg = HealthRegistry::new(manual_cfg());
        reg.observe(2, LaneOutcome::Rescued, Some(&rep));
        let snap = reg.snapshot(2).unwrap();
        assert_eq!(snap.rung_attempts[EscalationStage::DirectLu.index()], 1);
        assert_eq!(snap.rung_rescues[EscalationStage::DirectLu.index()], 1);
        assert_eq!(snap.rungs_skipped, 1);
        assert!(snap.ewma_rescued >= 1.0 - 1e-12);
    }
}
