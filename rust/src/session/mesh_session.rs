//! The [`MeshSession`] type: one owner for the per-mesh solve stack.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::assembly::{AssemblyContext, BilinearForm, Coefficient};
use crate::bc::{condense, CondensePlan, DirichletBc, ReducedBatch, ReducedSystem};
use crate::mesh::Mesh;
use crate::solver::{
    cg, cg_batch, cg_batch_warm, cg_batch_warm_with, lu_cost_units, rel_residual, rung_cost_ms,
    AmgBatch, AmgConfig, AmgHierarchy, AmgPrecond, EscalationReport, EscalationStage, FailureKind,
    JacobiPrecond, LockstepOp, MultiRhs, PrecondEngine, PrecondKind, SkippedRung, SolveStats,
    SolverConfig, StageAttempt, AMG_SETUP_ITER_EQUIV,
};
use crate::sparse::{Csr, CsrBatch, Dense};

/// EWMA smoothing for the observed milliseconds-per-iteration samples.
const COST_ALPHA: f64 = 0.3;

/// Budget left after spending `spent_ms` of an optional deadline budget.
fn remaining_after(budget_ms: Option<f64>, spent_ms: f64) -> Option<f64> {
    budget_ms.map(|b| (b - spent_ms).max(0.0))
}

/// Milliseconds remaining to the escalation ladder (`None` = unbounded).
struct LadderBudget {
    remaining: Option<f64>,
}

impl LadderBudget {
    fn new(budget_ms: Option<f64>) -> LadderBudget {
        LadderBudget { remaining: budget_ms.map(|b| b.max(0.0)) }
    }

    fn fits(&self, est_ms: f64) -> bool {
        match self.remaining {
            Some(r) => est_ms <= r,
            None => true,
        }
    }

    fn charge(&mut self, spent_ms: f64) {
        if let Some(r) = &mut self.remaining {
            *r = (*r - spent_ms).max(0.0);
        }
    }

    fn left(&self) -> f64 {
        self.remaining.unwrap_or(f64::INFINITY)
    }
}

/// The complete per-mesh solve stack, built once per (mesh, BC, form):
/// Dirichlet condensation plan, persistent reduced system, preconditioner
/// engine, optional warm-start state, and (for self-assembling sessions)
/// the assembly context. See the [module docs](super) for the
/// symbolic-once / numeric-refill lifecycle and ownership rules.
pub struct MeshSession {
    /// Assembly context, owned when the session assembled its own
    /// operator ([`MeshSession::poisson`]); sessions wrapping an
    /// externally assembled matrix leave it to the caller.
    ctx: Option<AssemblyContext>,
    /// Dirichlet symbolic mapping on the session pattern — built once.
    cplan: CondensePlan,
    /// Persistent condensed system; [`MeshSession::refill`] renumerates
    /// it in place (value gather + lift, zero allocation).
    sys: ReducedSystem,
    /// Preconditioner over the condensed session operator. `None` until
    /// the first [`MeshSession::sync_engine`] on pattern-only sessions:
    /// AMG aggregation reads VALUES, so building from a zeroed pattern
    /// would not match a build from the first real operator.
    engine: Option<PrecondEngine>,
    /// Separate AMG slot for [`MeshSession::solve_refit_batch`], whose
    /// hierarchy is built from the *condensed batch* representative (not
    /// the session operator) — built on first use, refilled afterwards.
    batch_amg: Option<AmgHierarchy>,
    /// Stored warm-start seed (full DoF field) for
    /// [`MeshSession::solve_current`].
    warm: Option<Vec<f64>>,
    /// Lazily built AMG hierarchy for the preconditioner-escalation
    /// ladder stage (only used when the engine is Jacobi): built from the
    /// session operator on the first rescue, cached for every later one.
    rescue_amg: OnceLock<AmgHierarchy>,
    /// Observed EWMA of milliseconds per Krylov iteration (f64 bits in
    /// an atomic so `&self` solve paths can calibrate). `0.0` means
    /// uncalibrated, which zeroes every rung cost estimate and leaves
    /// the budget gate inert.
    cost_ms_per_iter: AtomicU64,
    /// Per-rung observed rates (f64 bits), indexed by
    /// [`EscalationStage::index`]; each rung's EWMA is in THAT rung's
    /// work units (`ms/iteration` for the plain-CG rungs,
    /// `ms/(setup-equivalent + iteration)` for the AMG rescue,
    /// `ms/LU-unit` for dense LU — see [`lu_cost_units`]), so the
    /// dense-LU and AMG-rescue gates stop inheriting the CG rate. `0.0`
    /// slots are uncalibrated: their rung estimates stay zero and the
    /// gate stays inert for them.
    rung_rates: [AtomicU64; EscalationStage::COUNT],
    /// Explicit calibration override (tests, external calibrators);
    /// `0.0` = none, fall back to the observed EWMAs. A set override
    /// pins EVERY rung's rate.
    cost_override: AtomicU64,
    config: SolverConfig,
}

impl MeshSession {
    /// Fixed-operator Poisson session over a mesh: assemble the unit
    /// diffusion operator once, clamp the whole boundary homogeneously,
    /// condense and precondition. The coordinator's per-mesh state.
    pub fn poisson(mesh: &Mesh, config: SolverConfig) -> MeshSession {
        let ctx = AssemblyContext::new(mesh, 1);
        let proto = BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        };
        let k = ctx.assemble_matrix(&proto);
        let zero = vec![0.0; ctx.n_dofs()];
        let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
        let cplan = CondensePlan::new(k.nrows, &k.indptr, &k.indices, &bc);
        // One symbolic traversal serves both the cached plan and the
        // fixed-operator reduced system.
        let sys = cplan.apply(&k.data, &zero);
        let engine = PrecondEngine::build(&sys.k, config.precond);
        MeshSession {
            ctx: Some(ctx),
            cplan,
            sys,
            engine: Some(engine),
            batch_amg: None,
            warm: None,
            rescue_amg: OnceLock::new(),
            cost_ms_per_iter: AtomicU64::new(0),
            rung_rates: Default::default(),
            cost_override: AtomicU64::new(0),
            config,
        }
    }

    /// Session over an externally assembled operator: condense `K U = F`
    /// once and build the configured engine from the condensed values.
    pub fn from_matrix(
        k: &Csr,
        f_full: &[f64],
        bc: &DirichletBc,
        config: SolverConfig,
    ) -> MeshSession {
        let cplan = CondensePlan::new(k.nrows, &k.indptr, &k.indices, bc);
        let sys = cplan.apply(&k.data, f_full);
        let engine = PrecondEngine::build(&sys.k, config.precond);
        MeshSession {
            ctx: None,
            cplan,
            sys,
            engine: Some(engine),
            batch_amg: None,
            warm: None,
            rescue_amg: OnceLock::new(),
            cost_ms_per_iter: AtomicU64::new(0),
            rung_rates: Default::default(),
            cost_override: AtomicU64::new(0),
            config,
        }
    }

    /// Session over a bare sparsity pattern (values all zero), for
    /// drivers that refill the operator per iteration before solving.
    /// The engine is deferred to the first [`MeshSession::sync_engine`]:
    /// AMG aggregation depends on values, so it must see the first real
    /// operator, not the zeroed pattern.
    pub fn from_pattern(
        pattern: &Csr,
        f_full: &[f64],
        bc: &DirichletBc,
        config: SolverConfig,
    ) -> MeshSession {
        let cplan = CondensePlan::new(pattern.nrows, &pattern.indptr, &pattern.indices, bc);
        let sys = cplan.apply(&pattern.data, f_full);
        MeshSession {
            ctx: None,
            cplan,
            sys,
            engine: None,
            batch_amg: None,
            warm: None,
            rescue_amg: OnceLock::new(),
            cost_ms_per_iter: AtomicU64::new(0),
            rung_rates: Default::default(),
            cost_override: AtomicU64::new(0),
            config,
        }
    }

    /// Renumerate the session system for new operator values (and load)
    /// on the unchanged pattern: value gather + restriction + boundary
    /// lift, zero allocation, bitwise identical to a fresh condensation.
    /// Call [`MeshSession::sync_engine`] before solving so the
    /// preconditioner tracks the new values.
    pub fn refill(&mut self, values: &[f64], f_full: &[f64]) {
        self.cplan.reapply_into(values, f_full, &mut self.sys);
        // The rescue hierarchy aggregated the old values; rebuild lazily.
        let _ = self.rescue_amg.take();
    }

    /// Bring the engine up to date with the current session values:
    /// refill in place when built (Jacobi re-extracts its diagonal —
    /// bitwise the historical per-solve build; AMG refills the hierarchy
    /// through its cached symbolic plans), build it on first call.
    pub fn sync_engine(&mut self) {
        match &mut self.engine {
            Some(e) => e.refill(&self.sys.k),
            None => self.engine = Some(PrecondEngine::build(&self.sys.k, self.config.precond)),
        }
    }

    /// Stash a full-DoF iterate as the warm-start seed for the next
    /// [`MeshSession::solve_current`] (iteration loops seed with the
    /// previous state).
    pub fn seed_warm(&mut self, u_full: &[f64]) {
        match &mut self.warm {
            Some(w) => w.copy_from_slice(u_full),
            None => self.warm = Some(u_full.to_vec()),
        }
    }

    /// Drop the stored warm-start seed (next solve cold-starts).
    pub fn clear_warm(&mut self) {
        self.warm = None;
    }

    fn engine_ref(&self) -> &PrecondEngine {
        self.engine
            .as_ref()
            .expect("session engine not built: call sync_engine() after the first refill")
    }

    /// Pin the ladder's cost model to an explicit milliseconds-per-
    /// work-unit value (tests, external calibrators) — the override pins
    /// the base Krylov rate AND every per-rung rate. Non-positive or
    /// non-finite values clear the override, reverting to the observed
    /// EWMAs.
    pub fn set_cost_ms_per_iter(&self, ms: f64) {
        let v = if ms.is_finite() && ms > 0.0 { ms } else { 0.0 };
        self.cost_override.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Effective milliseconds-per-iteration of the BASE Krylov cost
    /// model: the explicit override when set, otherwise the EWMA
    /// recorded from converged solves (`0.0` until the first calibration
    /// — which makes every rung estimate zero, so nothing is skipped).
    /// Rung gates use the stage-specific [`MeshSession::rung_rate`].
    pub fn cost_ms_per_iter(&self) -> f64 {
        let over = f64::from_bits(self.cost_override.load(Ordering::Relaxed));
        if over > 0.0 {
            return over;
        }
        f64::from_bits(self.cost_ms_per_iter.load(Ordering::Relaxed))
    }

    /// Effective per-work-unit rate for one ladder rung: the explicit
    /// override when set, otherwise that rung's own observed EWMA. The
    /// plain-CG rungs (cold restart, iteration bump) are pre-calibrated
    /// by ordinary converged solves; the AMG-rescue and dense-LU rungs
    /// calibrate only from their own completed rescues and stay at the
    /// inert `0.0` (estimate zero, never skipped) until then.
    pub fn rung_rate(&self, stage: EscalationStage) -> f64 {
        let over = f64::from_bits(self.cost_override.load(Ordering::Relaxed));
        if over > 0.0 {
            return over;
        }
        f64::from_bits(self.rung_rates[stage.index()].load(Ordering::Relaxed))
    }

    /// Fold one sample into an EWMA slot. A racing store just loses a
    /// sample — this is calibration, not accounting.
    fn ewma_update(slot: &AtomicU64, sample: f64) {
        let prev = f64::from_bits(slot.load(Ordering::Relaxed));
        let next = if prev > 0.0 { prev + COST_ALPHA * (sample - prev) } else { sample };
        slot.store(next.to_bits(), Ordering::Relaxed);
    }

    /// Record one `ms / iteration` sample from an ordinary converged
    /// Krylov solve: it calibrates the base rate and, because the
    /// cold-restart and iteration-bump rungs are plain CG at that same
    /// rate, those two rung slots — arming their gates before any rescue
    /// has ever run. The AMG-rescue and dense-LU rungs are NOT fed here:
    /// their cost structure differs, which is the point of per-rung
    /// calibration.
    fn record_cost_sample(&self, ms_per_iter: f64) {
        if !(ms_per_iter.is_finite() && ms_per_iter > 0.0) {
            return;
        }
        Self::ewma_update(&self.cost_ms_per_iter, ms_per_iter);
        Self::ewma_update(&self.rung_rates[EscalationStage::ColdRestart.index()], ms_per_iter);
        Self::ewma_update(&self.rung_rates[EscalationStage::IterBump.index()], ms_per_iter);
    }

    /// Record one per-work-unit sample from a completed ladder rung into
    /// that rung's own EWMA slot.
    fn record_rung_sample(&self, stage: EscalationStage, rate: f64) {
        if !(rate.is_finite() && rate > 0.0) {
            return;
        }
        Self::ewma_update(&self.rung_rates[stage.index()], rate);
    }

    /// Run the first (pre-ladder) attempt, timing it only when the
    /// ladder is enabled: the elapsed milliseconds calibrate the rung
    /// cost model and are charged against the caller's deadline budget.
    /// With escalation off (the default) this reads no clocks, keeping
    /// the default path untouched.
    fn timed_attempt<T>(&self, run: impl FnOnce() -> (T, SolveStats)) -> (T, SolveStats, f64) {
        if !self.config.escalation.enabled {
            let (x, st) = run();
            return (x, st, 0.0);
        }
        let t0 = Instant::now();
        let (x, st) = run();
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        if st.converged && st.iterations > 0 {
            self.record_cost_sample(ms / st.iterations as f64);
        }
        (x, st, ms)
    }

    /// Budget gate for one ladder rung: `true` admits it; an
    /// unaffordable rung is recorded in the report and skipped.
    fn rung_gate(
        &self,
        stage: EscalationStage,
        k: &Csr,
        ms_per_iter: f64,
        budget: &LadderBudget,
        rep: &mut EscalationReport,
    ) -> bool {
        let est = rung_cost_ms(stage, k.nrows, k.data.len(), &self.config, ms_per_iter);
        if budget.fits(est) {
            return true;
        }
        rep.skipped.push(SkippedRung { stage, est_ms: est, budget_ms: budget.left() });
        false
    }

    /// Run one ladder rung, charging its actual elapsed time against the
    /// budget and folding a converged rung into ITS OWN rate EWMA, in
    /// the same work units its cost estimate is computed in: iterations
    /// for the plain-CG rungs, setup-equivalent + iterations for the AMG
    /// rescue, LU units ([`lu_cost_units`] — dense LU reports
    /// `iterations == 0`) for the direct fallback.
    fn timed_rung<T>(
        &self,
        stage: EscalationStage,
        k: &Csr,
        budget: &mut LadderBudget,
        run: impl FnOnce() -> (T, SolveStats),
    ) -> (T, SolveStats) {
        let t0 = Instant::now();
        let (x, st) = run();
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        budget.charge(ms);
        if st.converged {
            let units = match stage {
                EscalationStage::ColdRestart | EscalationStage::IterBump => st.iterations as f64,
                EscalationStage::PrecondEscalation => {
                    AMG_SETUP_ITER_EQUIV + st.iterations as f64
                }
                EscalationStage::DirectLu => lu_cost_units(k.nrows, k.data.len()),
            };
            if units > 0.0 {
                self.record_rung_sample(stage, ms / units);
            }
        }
        (x, st)
    }

    /// Scalar PCG on the current session system. `warm` (full DoF field)
    /// overrides the stored [`MeshSession::seed_warm`] seed; with
    /// neither, the cold start is bitwise the historical trajectory.
    /// Returns the expanded full-DoF solution.
    pub fn solve_current(&self, warm: Option<&[f64]>) -> (Vec<f64>, SolveStats) {
        let seed = warm.or(self.warm.as_deref());
        let x0 = seed.map(|w| self.sys.restrict(w));
        let (u_free, stats) =
            self.engine_ref().cg_warm(&self.sys.k, &self.sys.rhs, x0.as_deref(), &self.config);
        (self.sys.expand(&u_free), stats)
    }

    /// Scalar PCG against the session operator with a caller-supplied
    /// full-DoF load (the fixed-operator serving path): restrict, solve
    /// cold, expand.
    pub fn solve_with_load(&self, f_full: &[f64]) -> (Vec<f64>, SolveStats) {
        let rhs = self.sys.restrict(f_full);
        let (u_free, stats) = self.engine_ref().cg_warm(&self.sys.k, &rhs, None, &self.config);
        (self.sys.expand(&u_free), stats)
    }

    /// Scalar PCG on the session operator with an already-reduced RHS
    /// (free DoFs) — time steppers form their own reduced loads. No
    /// expansion; the caller owns the free-DoF state.
    pub fn solve_reduced(&self, rhs: &[f64], x0: Option<&[f64]>) -> (Vec<f64>, SolveStats) {
        self.engine_ref().cg_warm(&self.sys.k, rhs, x0, &self.config)
    }

    /// Scalar BiCGSTAB on the session operator with a reduced RHS (the
    /// Allen-Cahn semi-implicit step).
    pub fn bicgstab_reduced(&self, rhs: &[f64]) -> (Vec<f64>, SolveStats) {
        self.engine_ref().bicgstab(&self.sys.k, rhs, &self.config)
    }

    /// Full per-instance pipeline for a *foreign* operator on the session
    /// topology (per-request varcoeff solves): condense with the session
    /// constraints, precondition — Jacobi extracts the request diagonal
    /// (the historical per-request numbers, bitwise); AMG reuses the
    /// session hierarchy, a valid SPD preconditioner for same-topology
    /// positive-coefficient operators, so no request pays a hierarchy
    /// construction — and solve. Returns the expanded solution.
    pub fn solve_foreign(&self, k: &Csr, f_full: &[f64]) -> (Vec<f64>, SolveStats) {
        let sys = condense(k, f_full, &self.sys.bc);
        let (u_free, stats) = match self.engine_ref() {
            PrecondEngine::Jacobi(_) => {
                let pc = JacobiPrecond::new(&sys.k);
                cg(&sys.k, &sys.rhs, &pc, &self.config)
            }
            PrecondEngine::Amg(h, ws) => {
                cg(&sys.k, &sys.rhs, &AmgPrecond::with_scratch(h, ws), &self.config)
            }
        };
        (sys.expand(&u_free), stats)
    }

    /// Foreign-operator pipeline with the escalation ladder: bitwise
    /// [`MeshSession::solve_foreign`] when the solve converges or the
    /// policy is off; otherwise the failed request retries through
    /// [`MeshSession::escalate_lane`](crate::solver::EscalationPolicy).
    pub fn solve_foreign_resilient(
        &self,
        k: &Csr,
        f_full: &[f64],
    ) -> (Vec<f64>, SolveStats, Option<EscalationReport>) {
        self.solve_foreign_resilient_budgeted(k, f_full, None)
    }

    /// [`MeshSession::solve_foreign_resilient`] with an optional
    /// deadline budget in milliseconds: ladder rungs whose cost estimate
    /// exceeds the remaining budget are skipped and recorded in the
    /// report. `None` is bitwise the unbudgeted call.
    pub fn solve_foreign_resilient_budgeted(
        &self,
        k: &Csr,
        f_full: &[f64],
        budget_ms: Option<f64>,
    ) -> (Vec<f64>, SolveStats, Option<EscalationReport>) {
        let sys = condense(k, f_full, &self.sys.bc);
        let (u_free, stats, spent) = self.timed_attempt(|| match self.engine_ref() {
            PrecondEngine::Jacobi(_) => {
                let pc = JacobiPrecond::new(&sys.k);
                cg(&sys.k, &sys.rhs, &pc, &self.config)
            }
            PrecondEngine::Amg(h, ws) => {
                cg(&sys.k, &sys.rhs, &AmgPrecond::with_scratch(h, ws), &self.config)
            }
        });
        if stats.converged || !self.config.escalation.enabled {
            return (sys.expand(&u_free), stats, None);
        }
        let (rescued, rep) =
            self.escalate_lane(&sys.k, &sys.rhs, stats, false, remaining_after(budget_ms, spent));
        match rescued {
            Some(x) => {
                let st = rep.final_stats().unwrap_or(stats);
                (sys.expand(&x), st, Some(rep))
            }
            None => (sys.expand(&u_free), stats, Some(rep)),
        }
    }

    /// The escalation-stage AMG hierarchy, built from the session operator
    /// on first use. Like [`MeshSession::solve_foreign`] under AMG, it is
    /// a valid SPD preconditioner for same-topology positive-coefficient
    /// foreign operators, so one hierarchy serves every rescued lane.
    fn rescue_hierarchy(&self) -> &AmgHierarchy {
        self.rescue_amg.get_or_init(|| AmgHierarchy::build(&self.sys.k, AmgConfig::default()))
    }

    /// One scalar rescue solve of `(k, rhs)`. `amg = false`: per-operator
    /// Jacobi; `amg = true`: the session's AMG hierarchy (engine-owned
    /// when the engine is AMG, the cached rescue hierarchy otherwise).
    fn rescue_solve(
        &self,
        k: &Csr,
        rhs: &[f64],
        amg: bool,
        cfg: &SolverConfig,
    ) -> (Vec<f64>, SolveStats) {
        if amg {
            match self.engine.as_ref() {
                Some(PrecondEngine::Amg(h, ws)) => {
                    cg(k, rhs, &AmgPrecond::with_scratch(h, ws), cfg)
                }
                _ => cg(k, rhs, &AmgPrecond::new(self.rescue_hierarchy()), cfg),
            }
        } else {
            cg(k, rhs, &JacobiPrecond::new(k), cfg)
        }
    }

    /// Dense-LU direct fallback — the ladder's last rung. Accepts the
    /// factored answer only when its true relative residual meets the
    /// (slightly relaxed) solve tolerance.
    fn direct_solve(&self, k: &Csr, rhs: &[f64]) -> (Option<Vec<f64>>, SolveStats) {
        let dense = Dense { nrows: k.nrows, ncols: k.ncols, data: k.to_dense() };
        match dense.factor() {
            Ok(lu) => {
                let mut x = vec![0.0; k.nrows];
                lu.solve_into(rhs, &mut x);
                let rel = rel_residual(k, &x, rhs);
                if rel.is_finite() && rel <= self.config.rel_tol.max(1e-8) {
                    (Some(x), SolveStats::ok(0, rel))
                } else if rel.is_finite() {
                    (None, SolveStats::fail(0, rel, FailureKind::Stagnated))
                } else {
                    (None, SolveStats::fail(0, rel, FailureKind::NonFinite))
                }
            }
            Err(_) => (None, SolveStats::fail(0, f64::INFINITY, FailureKind::Breakdown)),
        }
    }

    /// Run the escalation ladder on one failed lane: `k`/`rhs` are the
    /// lane's reduced operator and load, `first` the failing stats,
    /// `was_warm` whether the failed attempt was warm-started (gates the
    /// cold-restart stage — a cold failure retried cold is the same
    /// solve). `budget_ms` is the deadline budget left for rescue: rungs
    /// whose [`rung_cost_ms`] estimate exceeds it are skipped (recorded
    /// in the report) and every attempted rung charges its actual
    /// elapsed time. Returns the rescued free-DoF solution (`None` when
    /// every configured stage failed or was skipped) and the per-stage
    /// accounting.
    fn escalate_lane(
        &self,
        k: &Csr,
        rhs: &[f64],
        first: SolveStats,
        was_warm: bool,
        budget_ms: Option<f64>,
    ) -> (Option<Vec<f64>>, EscalationReport) {
        let pol = self.config.escalation;
        let mut rep = EscalationReport {
            first: Some(first),
            attempts: Vec::new(),
            skipped: Vec::new(),
            resolved_by: None,
        };
        let mut budget = LadderBudget::new(budget_ms);
        let engine_amg = matches!(self.engine.as_ref(), Some(PrecondEngine::Amg(..)));
        // Tracks the strongest preconditioner reached so far; later stages
        // keep it rather than regressing to the one that already failed.
        let mut amg = engine_amg;
        // Each gate runs at its rung's own calibrated rate (the plain-CG
        // rungs share the base Krylov rate; AMG rescue and dense LU use
        // their own observed EWMAs, inert zero until first calibrated).
        if pol.cold_restart
            && was_warm
            && self.rung_gate(
                EscalationStage::ColdRestart,
                k,
                self.rung_rate(EscalationStage::ColdRestart),
                &budget,
                &mut rep,
            )
        {
            let (x, st) = self.timed_rung(EscalationStage::ColdRestart, k, &mut budget, || {
                self.rescue_solve(k, rhs, amg, &self.config)
            });
            rep.attempts.push(StageAttempt { stage: EscalationStage::ColdRestart, stats: st });
            if st.converged {
                rep.resolved_by = Some(EscalationStage::ColdRestart);
                return (Some(x), rep);
            }
        }
        if pol.escalate_precond && !engine_amg {
            amg = true;
            if self.rung_gate(
                EscalationStage::PrecondEscalation,
                k,
                self.rung_rate(EscalationStage::PrecondEscalation),
                &budget,
                &mut rep,
            ) {
                let (x, st) =
                    self.timed_rung(EscalationStage::PrecondEscalation, k, &mut budget, || {
                        self.rescue_solve(k, rhs, true, &self.config)
                    });
                rep.attempts
                    .push(StageAttempt { stage: EscalationStage::PrecondEscalation, stats: st });
                if st.converged {
                    rep.resolved_by = Some(EscalationStage::PrecondEscalation);
                    return (Some(x), rep);
                }
            }
        }
        if pol.iter_bump > 1
            && self.rung_gate(
                EscalationStage::IterBump,
                k,
                self.rung_rate(EscalationStage::IterBump),
                &budget,
                &mut rep,
            )
        {
            let mut cfg = self.config;
            cfg.max_iter = cfg.max_iter.saturating_mul(pol.iter_bump);
            let (x, st) = self.timed_rung(EscalationStage::IterBump, k, &mut budget, || {
                self.rescue_solve(k, rhs, amg, &cfg)
            });
            rep.attempts.push(StageAttempt { stage: EscalationStage::IterBump, stats: st });
            if st.converged {
                rep.resolved_by = Some(EscalationStage::IterBump);
                return (Some(x), rep);
            }
        }
        if pol.direct_fallback
            && k.nrows <= pol.direct_max
            && self.rung_gate(
                EscalationStage::DirectLu,
                k,
                self.rung_rate(EscalationStage::DirectLu),
                &budget,
                &mut rep,
            )
        {
            let (x, st) = self.timed_rung(EscalationStage::DirectLu, k, &mut budget, || {
                self.direct_solve(k, rhs)
            });
            rep.attempts.push(StageAttempt { stage: EscalationStage::DirectLu, stats: st });
            if st.converged {
                rep.resolved_by = Some(EscalationStage::DirectLu);
                return (x, rep);
            }
        }
        (None, rep)
    }

    /// [`MeshSession::solve_with_load`] plus the escalation ladder on
    /// failure. With the policy off (the default) or a converged first
    /// attempt, the result is bitwise `solve_with_load` and no report is
    /// produced — serving paths call this unconditionally.
    pub fn solve_with_load_resilient(
        &self,
        f_full: &[f64],
    ) -> (Vec<f64>, SolveStats, Option<EscalationReport>) {
        self.solve_with_load_resilient_budgeted(f_full, None)
    }

    /// [`MeshSession::solve_with_load_resilient`] with an optional
    /// deadline budget in milliseconds for the ladder (skipped rungs are
    /// recorded in the report). `None` is bitwise the unbudgeted call.
    pub fn solve_with_load_resilient_budgeted(
        &self,
        f_full: &[f64],
        budget_ms: Option<f64>,
    ) -> (Vec<f64>, SolveStats, Option<EscalationReport>) {
        let rhs = self.sys.restrict(f_full);
        let (u_free, stats, spent) =
            self.timed_attempt(|| self.engine_ref().cg_warm(&self.sys.k, &rhs, None, &self.config));
        if stats.converged || !self.config.escalation.enabled {
            return (self.sys.expand(&u_free), stats, None);
        }
        let (rescued, rep) =
            self.escalate_lane(&self.sys.k, &rhs, stats, false, remaining_after(budget_ms, spent));
        match rescued {
            Some(x) => {
                let st = rep.final_stats().unwrap_or(stats);
                (self.sys.expand(&x), st, Some(rep))
            }
            None => (self.sys.expand(&u_free), stats, Some(rep)),
        }
    }

    /// [`MeshSession::solve_reduced`] plus the escalation ladder on
    /// failure (`x0.is_some()` arms the cold-restart stage). Bitwise
    /// `solve_reduced` when converged or with the policy off. Always
    /// unbudgeted: time steppers own their step budget, not the ladder.
    pub fn solve_reduced_resilient(
        &self,
        rhs: &[f64],
        x0: Option<&[f64]>,
    ) -> (Vec<f64>, SolveStats, Option<EscalationReport>) {
        let (x, stats, _spent) =
            self.timed_attempt(|| self.engine_ref().cg_warm(&self.sys.k, rhs, x0, &self.config));
        if stats.converged || !self.config.escalation.enabled {
            return (x, stats, None);
        }
        let (rescued, rep) = self.escalate_lane(&self.sys.k, rhs, stats, x0.is_some(), None);
        match rescued {
            Some(xr) => {
                let st = rep.final_stats().unwrap_or(stats);
                (xr, st, Some(rep))
            }
            None => (x, stats, Some(rep)),
        }
    }

    /// [`MeshSession::solve_load_batch`] plus per-lane escalation: only
    /// failed lanes re-solve, and a rescued lane overwrites exactly its
    /// own instance-major slice — healthy neighbors are untouched (their
    /// lockstep trajectories are never re-run). Bitwise `solve_load_batch`
    /// when every lane converges or with the policy off.
    pub fn solve_load_batch_resilient(
        &self,
        rhs: &[f64],
    ) -> (Vec<f64>, Vec<SolveStats>, Vec<Option<EscalationReport>>) {
        self.solve_load_batch_resilient_budgeted(rhs, None)
    }

    /// [`MeshSession::solve_load_batch_resilient`] with optional
    /// per-lane deadline budgets in milliseconds (one slot per lane;
    /// `None` slots are unbounded). The lockstep first attempt is
    /// charged against every lane's budget; skipped rungs land in that
    /// lane's report. `budgets: None` is bitwise the unbudgeted call.
    pub fn solve_load_batch_resilient_budgeted(
        &self,
        rhs: &[f64],
        budgets: Option<&[Option<f64>]>,
    ) -> (Vec<f64>, Vec<SolveStats>, Vec<Option<EscalationReport>>) {
        let ladder = self.config.escalation.enabled;
        let t0 = if ladder { Some(Instant::now()) } else { None };
        let (mut u, mut stats) = self.solve_load_batch(rhs);
        let mut reports = vec![None; stats.len()];
        if ladder {
            let spent = t0.map_or(0.0, |t| t.elapsed().as_secs_f64() * 1e3);
            // Lockstep advances every lane together, so one scalar lane
            // iteration costs about batch_ms / (max iterations × lanes).
            let lanes = stats.len();
            let max_it = stats.iter().map(|s| s.iterations).max().unwrap_or(0);
            if lanes > 0 && max_it > 0 {
                self.record_cost_sample(spent / (max_it * lanes) as f64);
            }
            if let Some(b) = budgets {
                assert_eq!(b.len(), stats.len(), "one budget slot per lane");
            }
            let nf = self.n_free();
            for s in 0..stats.len() {
                if stats[s].converged {
                    continue;
                }
                let lane = s * nf..(s + 1) * nf;
                let left = remaining_after(budgets.and_then(|b| b[s]), spent);
                let (rescued, rep) =
                    self.escalate_lane(&self.sys.k, &rhs[lane.clone()], stats[s], false, left);
                if let Some(x) = rescued {
                    stats[s] = rep.final_stats().unwrap_or(stats[s]);
                    u[lane].copy_from_slice(&x);
                }
                reports[s] = Some(rep);
            }
        }
        (u, stats, reports)
    }

    /// [`MeshSession::solve_varcoeff_batch`] plus per-lane escalation on
    /// the lane's own condensed operator (`red.k` instance `s`). Only
    /// failed lanes re-solve; healthy neighbors are untouched. Bitwise
    /// `solve_varcoeff_batch` when every lane converges or with the
    /// policy off.
    pub fn solve_varcoeff_batch_resilient(
        &self,
        kbatch: &CsrBatch,
        f: &[f64],
    ) -> (ReducedBatch, Vec<f64>, Vec<SolveStats>, Vec<Option<EscalationReport>>) {
        self.solve_varcoeff_batch_resilient_budgeted(kbatch, f, None)
    }

    /// [`MeshSession::solve_varcoeff_batch_resilient`] with optional
    /// per-lane deadline budgets in milliseconds (one slot per lane;
    /// `None` slots are unbounded). `budgets: None` is bitwise the
    /// unbudgeted call.
    pub fn solve_varcoeff_batch_resilient_budgeted(
        &self,
        kbatch: &CsrBatch,
        f: &[f64],
        budgets: Option<&[Option<f64>]>,
    ) -> (ReducedBatch, Vec<f64>, Vec<SolveStats>, Vec<Option<EscalationReport>>) {
        let ladder = self.config.escalation.enabled;
        let t0 = if ladder { Some(Instant::now()) } else { None };
        let (red, mut u, mut stats) = self.solve_varcoeff_batch(kbatch, f);
        let mut reports = vec![None; stats.len()];
        if ladder {
            let spent = t0.map_or(0.0, |t| t.elapsed().as_secs_f64() * 1e3);
            let lanes = stats.len();
            let max_it = stats.iter().map(|s| s.iterations).max().unwrap_or(0);
            if lanes > 0 && max_it > 0 {
                self.record_cost_sample(spent / (max_it * lanes) as f64);
            }
            if let Some(b) = budgets {
                assert_eq!(b.len(), stats.len(), "one budget slot per lane");
            }
            let nf = red.n_free();
            for s in 0..stats.len() {
                if stats[s].converged {
                    continue;
                }
                let ks = red.k.instance(s);
                let left = remaining_after(budgets.and_then(|b| b[s]), spent);
                let (rescued, rep) =
                    self.escalate_lane(&ks, red.rhs_of(s), stats[s], false, left);
                if let Some(x) = rescued {
                    stats[s] = rep.final_stats().unwrap_or(stats[s]);
                    u[s * nf..(s + 1) * nf].copy_from_slice(&x);
                }
                reports[s] = Some(rep);
            }
        }
        (red, u, stats, reports)
    }

    /// Lockstep multi-RHS operator over the session matrix, carrying the
    /// engine's setup-time Jacobi diagonal when available (bitwise the
    /// per-lane scalar preconditioning).
    pub fn multi_op(&self, s_n: usize) -> MultiRhs<'_> {
        match self.engine_ref().inv_diag() {
            Some(inv) => MultiRhs::with_inv_diag(&self.sys.k, s_n, inv.to_vec()),
            None => MultiRhs::new(&self.sys.k, s_n),
        }
    }

    /// Lockstep PCG through the session engine on a caller-built op
    /// (cold start): Jacobi lanes use the op's own diagonals; AMG applies
    /// the session hierarchy to every lane per iteration.
    pub fn solve_multi<Op: LockstepOp>(&self, op: &Op, rhs: &[f64]) -> (Vec<f64>, Vec<SolveStats>) {
        self.engine_ref().cg_batch_warm(op, rhs, None, &self.config)
    }

    /// `S` solves against the session operator with instance-major
    /// reduced loads (`S × n_free`) — the fixed-operator batched serving
    /// path, one fused SpMV per Krylov iteration for the whole set.
    pub fn solve_load_batch(&self, rhs: &[f64]) -> (Vec<f64>, Vec<SolveStats>) {
        let nf = self.n_free();
        assert_eq!(rhs.len() % nf.max(1), 0, "rhs must be S × n_free");
        let op = self.multi_op(rhs.len() / nf.max(1));
        self.solve_multi(&op, rhs)
    }

    /// `S` foreign operators on the session pattern, condensed through
    /// the session plan and solved in lockstep (the batched varcoeff
    /// pipeline). `f` is one broadcast load (`n_full`) or `S` instance-
    /// major loads. Jacobi lanes match the scalar per-request pipeline
    /// bitwise; AMG applies the session hierarchy to every lane. Returns
    /// the reduced batch (for expansion) with solutions and stats.
    pub fn solve_varcoeff_batch(
        &self,
        kbatch: &CsrBatch,
        f: &[f64],
    ) -> (ReducedBatch, Vec<f64>, Vec<SolveStats>) {
        let red = self.cplan.apply_batch(kbatch, f);
        let (u, stats) = match self.engine_ref() {
            PrecondEngine::Jacobi(_) => cg_batch(&red.k, &red.rhs, &self.config),
            PrecondEngine::Amg(h, ws) => {
                let pc = AmgBatch::with_scratch(h, red.n_instances(), ws);
                cg_batch_warm_with(&red.k, &red.rhs, None, &pc, &self.config)
            }
        };
        (red, u, stats)
    }

    /// `S` refitted session operators (same pattern, new values per
    /// design — the lockstep topology-optimization state solve), with
    /// optional per-design full-DoF warm seeds. Under Jacobi each lane
    /// uses its own diagonal (bitwise the historical blocked path);
    /// under AMG one hierarchy — built from design 0's condensed
    /// stiffness on first call, refilled from it afterwards — serves
    /// every lane. Returns the reduced batch with solutions and stats.
    pub fn solve_refit_batch(
        &mut self,
        kbatch: &CsrBatch,
        f: &[f64],
        warm: Option<&[&[f64]]>,
    ) -> (ReducedBatch, Vec<f64>, Vec<SolveStats>) {
        let red = self.cplan.apply_batch(kbatch, f);
        let x0: Option<Vec<f64>> = warm.map(|ws| {
            assert_eq!(ws.len(), kbatch.n_instances, "one warm seed per design");
            let mut flat = Vec::with_capacity(kbatch.n_instances * red.n_free());
            for w in ws {
                flat.extend(red.restrict(w));
            }
            flat
        });
        let (u, stats) = match self.config.precond {
            PrecondKind::Jacobi => cg_batch_warm(&red.k, &red.rhs, x0.as_deref(), &self.config),
            PrecondKind::Amg(acfg) => {
                match &mut self.batch_amg {
                    Some(h) => h.refill(red.k.values(0)),
                    None => self.batch_amg = Some(AmgHierarchy::build(&red.k.instance(0), acfg)),
                }
                let h = self.batch_amg.as_ref().expect("hierarchy just ensured");
                let pc = AmgBatch::new(h, red.n_instances());
                cg_batch_warm_with(&red.k, &red.rhs, x0.as_deref(), &pc, &self.config)
            }
        };
        (red, u, stats)
    }

    /// The owned assembly context of a self-assembling session.
    pub fn ctx(&self) -> &AssemblyContext {
        self.ctx.as_ref().expect("session does not own an assembly context")
    }

    /// The condensed session operator.
    pub fn matrix(&self) -> &Csr {
        &self.sys.k
    }

    /// The condensed session right-hand side.
    pub fn reduced_rhs(&self) -> &[f64] {
        &self.sys.rhs
    }

    /// Sorted free (unconstrained) DoF indices.
    pub fn free(&self) -> &[usize] {
        &self.sys.free
    }

    /// The session constraints.
    pub fn bc(&self) -> &DirichletBc {
        &self.sys.bc
    }

    /// The Dirichlet symbolic mapping (for same-pattern auxiliary
    /// condensations — e.g. a time stepper's stiffness next to its mass).
    pub fn plan(&self) -> &CondensePlan {
        &self.cplan
    }

    pub fn n_free(&self) -> usize {
        self.sys.free.len()
    }

    pub fn n_full(&self) -> usize {
        self.sys.n_full()
    }

    /// Restrict a full vector to free DoFs.
    pub fn restrict(&self, full: &[f64]) -> Vec<f64> {
        self.sys.restrict(full)
    }

    /// Expand a free-DoF solution to the full DoF vector (inserting the
    /// prescribed boundary values).
    pub fn expand(&self, u_free: &[f64]) -> Vec<f64> {
        self.sys.expand(u_free)
    }

    pub fn config(&self) -> &SolverConfig {
        &self.config
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembly::LinearForm;
    use crate::mesh::structured::unit_square_tri;

    fn poisson_pieces(n: usize) -> (Csr, Vec<f64>, DirichletBc) {
        let m = unit_square_tri(n);
        let ctx = AssemblyContext::new(&m, 1);
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        let f = ctx.assemble_vector(&LinearForm::Source { f: Coefficient::Const(1.0) });
        let bc = DirichletBc::homogeneous(m.boundary_nodes());
        (k, f, bc)
    }

    #[test]
    fn from_matrix_solves_and_matches_manual_stack() {
        let (k, f, bc) = poisson_pieces(6);
        let session = MeshSession::from_matrix(&k, &f, &bc, SolverConfig::default());
        let (u, stats) = session.solve_current(None);
        assert!(stats.converged);
        // Manual pre-session stack: condense + engine + cg, bitwise.
        let sys = condense(&k, &f, &bc);
        let engine = PrecondEngine::build(&sys.k, PrecondKind::Jacobi);
        let (uf, st) = engine.cg_warm(&sys.k, &sys.rhs, None, &SolverConfig::default());
        assert_eq!(u, sys.expand(&uf));
        assert_eq!(stats.iterations, st.iterations);
    }

    #[test]
    fn pattern_session_refill_matches_direct_build() {
        let (k, f, bc) = poisson_pieces(5);
        let pattern = Csr {
            data: vec![0.0; k.data.len()],
            ..k.clone()
        };
        let mut session = MeshSession::from_pattern(&pattern, &f, &bc, SolverConfig::default());
        session.refill(&k.data, &f);
        session.sync_engine();
        let (u, _) = session.solve_current(None);
        let direct = MeshSession::from_matrix(&k, &f, &bc, SolverConfig::default());
        let (u2, _) = direct.solve_current(None);
        assert_eq!(u, u2);
    }

    #[test]
    fn warm_seed_is_used_and_clearable() {
        let (k, f, bc) = poisson_pieces(6);
        let mut session = MeshSession::from_matrix(&k, &f, &bc, SolverConfig::default());
        let (u, cold) = session.solve_current(None);
        session.seed_warm(&u);
        let (_, warm) = session.solve_current(None);
        assert!(warm.iterations < cold.iterations, "{warm:?} vs {cold:?}");
        session.clear_warm();
        let (_, cold2) = session.solve_current(None);
        assert_eq!(cold2.iterations, cold.iterations);
    }

    #[test]
    fn load_batch_lane_matches_scalar_solve() {
        let (k, f, bc) = poisson_pieces(5);
        let session = MeshSession::from_matrix(&k, &f, &bc, SolverConfig::default());
        let nf = session.n_free();
        let mut rhs = Vec::with_capacity(2 * nf);
        rhs.extend(session.reduced_rhs());
        rhs.extend(session.reduced_rhs().iter().map(|v| 2.0 * v));
        let (u, stats) = session.solve_load_batch(&rhs);
        assert!(stats.iter().all(|s| s.converged));
        let (u0, st0) = session.solve_reduced(&rhs[..nf], None);
        assert_eq!(&u[..nf], &u0[..]);
        assert_eq!(stats[0].iterations, st0.iterations);
    }
}
