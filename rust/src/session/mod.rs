//! The per-mesh solver session: ONE owner for the solve stack every
//! downstream path shares.
//!
//! The paper's central claim is that a single Galerkin assembly + solve
//! core serves solving, PDE-constrained optimization and operator
//! learning. This module is that core's runtime embodiment: a
//! [`MeshSession`] is built once per (mesh, boundary conditions, form)
//! and owns the complete per-mesh stack —
//!
//! * the Dirichlet symbolic mapping ([`crate::bc::CondensePlan`]),
//! * the persistent condensed system ([`crate::bc::ReducedSystem`]),
//! * the preconditioner engine ([`crate::solver::PrecondEngine`]:
//!   Jacobi diagonal or smoothed-aggregation AMG hierarchy), and
//! * optional warm-start state for iteration loops.
//!
//! # Symbolic-once / numeric-refill lifecycle
//!
//! Everything that depends only on the sparsity *pattern* — the free-DoF
//! mapping, the condensed pattern, the AMG aggregation and symbolic
//! triple-product plans — is computed exactly once, at session build.
//! Everything that depends on *values* flows through refill entry points
//! that reuse the symbolic plans without reallocating:
//!
//! 1. **Build** ([`MeshSession::from_matrix`] /
//!    [`MeshSession::from_pattern`]): condense the operator (or its bare
//!    pattern) once, build the engine (deferred for pattern-only builds,
//!    because AMG aggregation reads values).
//! 2. **Refill** ([`MeshSession::refill`] +
//!    [`MeshSession::sync_engine`]): push new values through
//!    [`crate::bc::CondensePlan::reapply_into`] and
//!    [`crate::solver::PrecondEngine::refill`] — zero allocation, bitwise
//!    identical to a fresh condense + build-from-values.
//! 3. **Solve** ([`MeshSession::solve_current`],
//!    [`MeshSession::solve_with_load`], [`MeshSession::solve_load_batch`],
//!    [`MeshSession::solve_varcoeff_batch`],
//!    [`MeshSession::solve_refit_batch`], …): scalar or lockstep, against
//!    the session operator or per-request foreign operators on the same
//!    pattern, each path bitwise identical to the hand-wired stack it
//!    replaced.
//! 4. **Seed** ([`MeshSession::seed_warm`]): stash a full-DoF iterate so
//!    the next [`MeshSession::solve_current`] warm-starts from it.
//!
//! # Ownership rules
//!
//! Outside this module (and `bc`/`solver`, which define the types), no
//! code constructs a [`crate::bc::CondensePlan`] or a
//! [`crate::solver::PrecondEngine`] directly — CI greps for it. Consumers
//! hold a `MeshSession` (the coordinator's registry holds
//! `Arc<BatchSolver>`-wrapped sessions, the designed seam for sharded
//! multi-worker serving) and go through its lifecycle API, so the next
//! capabilities (sharded workers, AMR re-registration, predict-then-
//! correct seeding) are one-call-site changes instead of five.
//!
//! All interior scratch (`ReducedSystem` storage, AMG cycle workspace
//! behind a `Mutex`) lives inside the session, so repeated calls on any
//! path stay allocation-free and the session is `Sync`: one instance can
//! serve scalar and blocked rollouts concurrently behind an `Arc`.
//!
//! # Escalation ladder
//!
//! When [`crate::solver::EscalationPolicy`] is enabled on the session
//! config, the `*_resilient` solve methods
//! ([`MeshSession::solve_with_load_resilient`],
//! [`MeshSession::solve_load_batch_resilient`],
//! [`MeshSession::solve_varcoeff_batch_resilient`],
//! [`MeshSession::solve_foreign_resilient`],
//! [`MeshSession::solve_reduced_resilient`]) retry *only the failed
//! lanes* through a fixed recovery sequence — cold restart (drop the warm
//! seed), preconditioner escalation (Jacobi → AMG with a session-cached
//! rescue hierarchy), iteration-budget bump, dense-LU direct fallback —
//! recording per-stage [`crate::solver::SolveStats`] in an
//! [`crate::solver::EscalationReport`]. Healthy lanes of a lockstep batch
//! are never re-run: a rescue overwrites exactly the failed lane's
//! instance-major slice. With the policy off (the default) the resilient
//! methods are bitwise their plain counterparts, so serving paths call
//! them unconditionally.
//!
//! # Budget-aware escalation
//!
//! Each resilient entry point has a `*_budgeted` variant that accepts an
//! optional milliseconds budget (derived by the coordinator from the
//! request deadline). Every ladder rung carries a cost estimate from
//! [`crate::solver::rung_cost_ms`], scaled by that rung's OWN calibrated
//! rate ([`MeshSession::rung_rate`]): the plain-CG rungs (cold restart,
//! iteration bump) are pre-calibrated at the base Krylov rate by every
//! converged solve, while the AMG-rescue and dense-LU rungs calibrate
//! only from their own completed rescues — in their own work units
//! (setup-equivalent iterations, LU units) — so they no longer inherit
//! the CG rate. An explicit [`MeshSession::set_cost_ms_per_iter`]
//! override pins every rung's rate. Rungs whose estimate exceeds the
//! remaining budget are skipped — recorded as
//! [`crate::solver::SkippedRung`]s in the report — so a
//! deadline-constrained request jumps straight to the cheapest viable
//! rescue instead of burning its deadline on a hopeless one. With no
//! budget the ladder runs exactly as before, and an uncalibrated rung
//! (rate zero, estimate zero) is never skipped.
//!
//! # Health tracking
//!
//! The [`health`] submodule turns the ladder's *outcomes* into serving
//! inputs: per-mesh EWMAs, failure streaks and rung statistics drive a
//! three-state circuit breaker plus adaptive admission tightening in the
//! coordinator. See [`health`] for the state machine; the session layer
//! itself stays stateless about health.

mod mesh_session;
pub mod health;

pub use mesh_session::MeshSession;
