//! Smoothed-aggregation algebraic multigrid (SA-AMG) preconditioning.
//!
//! Jacobi-PCG — the paper's Table B.1 configuration — needs `O(h⁻¹)` Krylov
//! iterations on the Poisson/elasticity families of Fig. 2, so on fine
//! meshes the *solve*, not assembly, dominates wall-clock. A multigrid
//! V-cycle preconditioner makes the iteration count (near) mesh-independent:
//! every PCG iteration then costs a few SpMVs more, but the iteration count
//! stops growing with refinement.
//!
//! # Symbolic-once / numeric-refill design
//!
//! Mirroring [`crate::bc::CondensePlan`] (and the shared-topology discipline
//! of the whole assembly layer), the hierarchy is split into a symbolic part
//! that depends only on the sparsity pattern + one strength snapshot, and a
//! numeric part that is a pure function of the operator values:
//!
//! * **Symbolic (built once per mesh/pattern):** greedy strength-of-
//!   connection aggregation of the CSR graph, the pattern of the smoothed
//!   prolongation `P = (I − ω D⁻¹A) T`, the pattern of `W = A·P` and of the
//!   Galerkin coarse operator `Aᶜ = Pᵀ·W`, with flat gather lists (pair
//!   lists of data positions) for every product nonzero.
//! * **Numeric ([`AmgHierarchy::refill`]):** given new values on the same
//!   fine pattern — a topology-optimization re-assembly, a varying
//!   coefficient field — the inverse diagonals, `P`, `W`, every coarse
//!   level and the coarsest dense LU are recomputed *in place* through the
//!   stored plans. [`AmgHierarchy::build`] itself runs exactly this numeric
//!   pass after the symbolic setup, so a refill is bitwise identical to a
//!   rebuild with the same aggregation.
//!
//! # Determinism
//!
//! Aggregation and all symbolic passes are sequential. The numeric passes
//! parallelize over disjoint output targets with a fixed per-target
//! accumulation order (the same argument as `Routing`), and the V-cycle is
//! composed of deterministic kernels ([`Csr::spmv_multi`], elementwise
//! sweeps, a sequential dense back-solve) — results are bitwise identical
//! at any `TG_THREADS`.
//!
//! # Batched application
//!
//! [`AmgBatch`] applies ONE hierarchy to `S` residual lanes at once: every
//! level traversal reads the level operators a single time through the
//! fused instance-major kernels (`spmv_multi`), the smoothing sweeps run
//! lane-major, and the coarse LU back-solves per lane — the preconditioner
//! analogue of [`crate::sparse::CsrBatch::spmv_batch`]. Per lane the
//! arithmetic order is exactly the scalar V-cycle's, so each lane of a
//! lockstep AMG-PCG solve is bitwise identical to a scalar AMG-PCG run
//! sharing the same hierarchy.
//!
//! Scope note: the tentative prolongation uses the constant vector as the
//! near-null-space candidate, which is exact for scalar diffusion and an
//! approximation for elasticity (rigid-body modes are a recorded follow-up)
//! — for vector problems the hierarchy is still SPD and symmetric, just
//! less optimal.

use std::sync::Mutex;

use crate::sparse::{Csr, Dense, LuFactor};
use crate::util::threadpool::{self, SyncPtr};

use super::precond::{jacobi_inverse, Preconditioner};

/// SA-AMG construction parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AmgConfig {
    /// Strength-of-connection threshold: `j` is strongly connected to `i`
    /// iff `|a_ij| ≥ theta·√(|a_ii·a_jj|)`.
    pub theta: f64,
    /// Base damping weight for the prolongation smoother and the V-cycle
    /// Jacobi sweeps; rescaled per level by a Gershgorin bound on
    /// `ρ(D⁻¹A)` so the effective `ω·ρ` stays below 2 (keeps the smoother
    /// convergent and the V-cycle SPD on elasticity-like operators).
    pub omega: f64,
    /// Stop coarsening once a level has at most this many DoFs; that level
    /// is LU-factorized and solved directly.
    pub coarse_max: usize,
    /// Hard cap on the number of coarsening steps.
    pub max_levels: usize,
}

impl Default for AmgConfig {
    fn default() -> Self {
        AmgConfig {
            theta: 0.08,
            omega: 2.0 / 3.0,
            coarse_max: 200,
            max_levels: 12,
        }
    }
}

/// Greedy (Vaněk-style) aggregation of the strength graph. Returns the
/// aggregate id of every node and the aggregate count. Fully sequential and
/// a function of `(pattern, values, theta)` alone — independent of thread
/// count by construction.
fn aggregate(a: &Csr, theta: f64) -> (Vec<usize>, usize) {
    let n = a.nrows;
    let diag = a.diagonal();
    let strong = |i: usize, j: usize, v: f64| -> bool {
        j != i && v.abs() >= theta * (diag[i].abs() * diag[j].abs()).sqrt() && v != 0.0
    };
    let mut agg = vec![usize::MAX; n];
    let mut n_agg = 0usize;
    // Pass 1: a node whose strong neighborhood is entirely unaggregated
    // seeds a new aggregate of itself plus that neighborhood.
    for i in 0..n {
        if agg[i] != usize::MAX {
            continue;
        }
        let (cols, vals) = a.row(i);
        let free = cols
            .iter()
            .zip(vals)
            .all(|(&j, &v)| !strong(i, j, v) || agg[j] == usize::MAX);
        if !free {
            continue;
        }
        agg[i] = n_agg;
        for (&j, &v) in cols.iter().zip(vals) {
            if strong(i, j, v) {
                agg[j] = n_agg;
            }
        }
        n_agg += 1;
    }
    // Pass 2: leftover nodes join the pass-1 aggregate of their strongest
    // connection (decided against the pass-1 snapshot so chains cannot
    // form; first-in-row-order wins ties deterministically).
    let snapshot = agg.clone();
    for i in 0..n {
        if agg[i] != usize::MAX {
            continue;
        }
        let (cols, vals) = a.row(i);
        let mut best: Option<(f64, usize)> = None;
        for (&j, &v) in cols.iter().zip(vals) {
            if strong(i, j, v) && snapshot[j] != usize::MAX {
                let w = v.abs();
                if best.map_or(true, |(bw, _)| w > bw) {
                    best = Some((w, snapshot[j]));
                }
            }
        }
        if let Some((_, g)) = best {
            agg[i] = g;
        }
    }
    // Pass 3: whatever is left seeds aggregates from the still-unaggregated
    // strong remainder (isolated nodes become singletons).
    for i in 0..n {
        if agg[i] != usize::MAX {
            continue;
        }
        agg[i] = n_agg;
        let (cols, vals) = a.row(i);
        for (&j, &v) in cols.iter().zip(vals) {
            if strong(i, j, v) && agg[j] == usize::MAX {
                agg[j] = n_agg;
            }
        }
        n_agg += 1;
    }
    (agg, n_agg)
}

/// Symbolic transpose of a CSR pattern: returns `(t_indptr, t_indices,
/// perm)` with `t_data[k] = data[perm[k]]` for any value array on the
/// source pattern (counting sort — deterministic).
fn transpose_pattern(
    nrows: usize,
    ncols: usize,
    indptr: &[usize],
    indices: &[usize],
) -> (Vec<usize>, Vec<usize>, Vec<usize>) {
    let nnz = indices.len();
    let mut counts = vec![0usize; ncols + 1];
    for &c in indices {
        counts[c + 1] += 1;
    }
    for i in 0..ncols {
        counts[i + 1] += counts[i];
    }
    let t_indptr = counts.clone();
    let mut t_indices = vec![0usize; nnz];
    let mut perm = vec![0usize; nnz];
    let mut next = counts;
    for r in 0..nrows {
        for pos in indptr[r]..indptr[r + 1] {
            let c = indices[pos];
            let slot = next[c];
            t_indices[slot] = r;
            perm[slot] = pos;
            next[c] += 1;
        }
    }
    (t_indptr, t_indices, perm)
}

/// Symbolic sparse product `C = A·B`: the pattern of `C` plus, per `C`
/// nonzero, the flat list of `(A-data, B-data)` position pairs whose
/// products it sums — in a canonical order (A row order, then B row order)
/// so the numeric refill is deterministic and identical across rebuilds.
#[derive(Clone, Debug)]
struct ProductPlan {
    nrows: usize,
    ncols: usize,
    indptr: Vec<usize>,
    indices: Vec<usize>,
    pair_ptr: Vec<usize>,
    left: Vec<u32>,
    right: Vec<u32>,
}

impl ProductPlan {
    fn build(
        a_nrows: usize,
        a_indptr: &[usize],
        a_indices: &[usize],
        b_ncols: usize,
        b_indptr: &[usize],
        b_indices: &[usize],
    ) -> ProductPlan {
        let mut indptr = Vec::with_capacity(a_nrows + 1);
        indptr.push(0);
        let mut indices = Vec::new();
        let mut pair_ptr = vec![0usize];
        let mut left = Vec::new();
        let mut right = Vec::new();
        let mut row: Vec<(usize, u32, u32)> = Vec::new();
        for i in 0..a_nrows {
            row.clear();
            for apos in a_indptr[i]..a_indptr[i + 1] {
                let j = a_indices[apos];
                for bpos in b_indptr[j]..b_indptr[j + 1] {
                    row.push((b_indices[bpos], apos as u32, bpos as u32));
                }
            }
            // Stable sort keeps the canonical generation order within each
            // output column.
            row.sort_by_key(|t| t.0);
            let mut p = 0;
            while p < row.len() {
                let k = row[p].0;
                indices.push(k);
                while p < row.len() && row[p].0 == k {
                    left.push(row[p].1);
                    right.push(row[p].2);
                    p += 1;
                }
                pair_ptr.push(left.len());
            }
            indptr.push(indices.len());
        }
        ProductPlan {
            nrows: a_nrows,
            ncols: b_ncols,
            indptr,
            indices,
            pair_ptr,
            left,
            right,
        }
    }

    fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Numeric product through the stored pair lists. Each output nonzero
    /// is owned by one task and summed in the canonical stored order —
    /// deterministic at any thread count.
    fn apply(&self, a_data: &[f64], b_data: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.nnz(), "product output length");
        let threads = threadpool::default_threads();
        threadpool::for_each_row_mut(out, 1, threads, |p, slot| {
            let mut acc = 0.0;
            for t in self.pair_ptr[p]..self.pair_ptr[p + 1] {
                acc += a_data[self.left[t] as usize] * b_data[self.right[t] as usize];
            }
            slot[0] = acc;
        });
    }
}

/// Numeric refill plan for the smoothed prolongation values: per `P`
/// nonzero, the A-data positions feeding the `−ω D⁻¹(A T)` part plus the
/// tentative 0/1 contribution.
#[derive(Clone, Debug)]
struct ProlongPlan {
    ptr: Vec<usize>,
    src: Vec<u32>,
    tent: Vec<f64>,
}

/// One coarsening step: the fine operator, the transfer operators and every
/// numeric-refill plan tied to this level.
#[derive(Clone, Debug)]
struct AmgLevel {
    /// Fine operator of this level (level 0 holds the caller's matrix).
    a: Csr,
    /// Position of each diagonal entry in `a.data` (`usize::MAX` if the
    /// pattern lacks one).
    diag_pos: Vec<usize>,
    inv_diag: Vec<f64>,
    /// Per-level damping `ω_eff = ω·2/λ̂` with `λ̂` the Gershgorin bound on
    /// `ρ(D⁻¹A)` — recomputed on every refill.
    omega: f64,
    /// Smoothed prolongation `n × n_agg`.
    p: Csr,
    pplan: ProlongPlan,
    /// Restriction `Pᵀ` (pattern transposed once; values gathered through
    /// `rperm` on refill).
    r: Csr,
    rperm: Vec<usize>,
    /// `W = A·P` (values only live here; pattern inside the plan).
    wplan: ProductPlan,
    wvals: Vec<f64>,
    /// `Aᶜ = Pᵀ·W` — writes the next level's (or the coarsest) values.
    cplan: ProductPlan,
}

impl AmgLevel {
    /// Symbolic construction from an owned fine operator + aggregation.
    fn symbolic(a: Csr, agg: &[usize], n_agg: usize) -> AmgLevel {
        let n = a.nrows;
        let mut diag_pos = vec![usize::MAX; n];
        for (i, dp) in diag_pos.iter_mut().enumerate() {
            if let Some(pos) = a.pos(i, i) {
                *dp = pos;
            }
        }
        // Pattern of P: row p couples to every aggregate its A-row touches,
        // plus its own aggregate (tentative identity).
        let mut p_indptr = Vec::with_capacity(n + 1);
        p_indptr.push(0);
        let mut p_indices = Vec::new();
        let mut ptr = vec![0usize];
        let mut src = Vec::new();
        let mut tent = Vec::new();
        let mut ents: Vec<(usize, u32)> = Vec::new();
        for row in 0..n {
            ents.clear();
            for pos in a.indptr[row]..a.indptr[row + 1] {
                ents.push((agg[a.indices[pos]], pos as u32));
            }
            ents.sort_by_key(|e| e.0);
            let jt = agg[row];
            let mut seen_t = false;
            let mut i = 0;
            while i < ents.len() {
                let j = ents[i].0;
                if !seen_t && jt < j {
                    p_indices.push(jt);
                    tent.push(1.0);
                    ptr.push(src.len());
                    seen_t = true;
                    continue;
                }
                p_indices.push(j);
                tent.push(if j == jt { 1.0 } else { 0.0 });
                if j == jt {
                    seen_t = true;
                }
                while i < ents.len() && ents[i].0 == j {
                    src.push(ents[i].1);
                    i += 1;
                }
                ptr.push(src.len());
            }
            if !seen_t {
                p_indices.push(jt);
                tent.push(1.0);
                ptr.push(src.len());
            }
            p_indptr.push(p_indices.len());
        }
        let p = Csr {
            nrows: n,
            ncols: n_agg,
            data: vec![0.0; p_indices.len()],
            indptr: p_indptr,
            indices: p_indices,
        };
        let (r_indptr, r_indices, rperm) =
            transpose_pattern(p.nrows, p.ncols, &p.indptr, &p.indices);
        let r = Csr {
            nrows: n_agg,
            ncols: n,
            data: vec![0.0; r_indices.len()],
            indptr: r_indptr,
            indices: r_indices,
        };
        let wplan = ProductPlan::build(n, &a.indptr, &a.indices, n_agg, &p.indptr, &p.indices);
        let cplan = ProductPlan::build(
            n_agg,
            &r.indptr,
            &r.indices,
            n_agg,
            &wplan.indptr,
            &wplan.indices,
        );
        let wvals = vec![0.0; wplan.nnz()];
        AmgLevel {
            inv_diag: vec![0.0; n],
            omega: 0.0,
            pplan: ProlongPlan { ptr, src, tent },
            a,
            diag_pos,
            p,
            rperm,
            r,
            wplan,
            wvals,
            cplan,
        }
    }

    /// Numeric pass for this level: inverse diagonal, damping bound,
    /// smoothed `P`, `R` gather and `W = A·P`, leaving the Galerkin product
    /// for the hierarchy driver (it writes the next level's storage).
    fn update_numeric(&mut self, omega_base: f64) {
        let n = self.a.nrows;
        for i in 0..n {
            let d = match self.diag_pos[i] {
                usize::MAX => 0.0,
                pos => self.a.data[pos],
            };
            self.inv_diag[i] = if d.abs() > 1e-300 { 1.0 / d } else { 1.0 };
        }
        // Gershgorin bound on ρ(D⁻¹A): max_i |d_i|⁻¹·Σ_j |a_ij| (exact max,
        // order-independent). Rescale ω so ω_eff·ρ ≤ 2·ω_base < 2.
        let mut lam = 0.0f64;
        for i in 0..n {
            let (_, vals) = self.a.row(i);
            let rowsum: f64 = vals.iter().map(|v| v.abs()).sum();
            lam = lam.max(rowsum * self.inv_diag[i].abs());
        }
        self.omega = omega_base * 2.0 / lam.max(1.0);
        // Smoothed prolongation values: P = T − ω D⁻¹(A T), rows disjoint.
        let omega = self.omega;
        let (a_data, inv_diag) = (&self.a.data, &self.inv_diag);
        let (p_indptr, pplan) = (&self.p.indptr, &self.pplan);
        let pdata = SyncPtr::new(&mut self.p.data);
        let threads = threadpool::default_threads();
        threadpool::parallel_ranges(n, threads, |r0, r1| {
            for row in r0..r1 {
                for k in p_indptr[row]..p_indptr[row + 1] {
                    let mut acc = 0.0;
                    for t in pplan.ptr[k]..pplan.ptr[k + 1] {
                        acc += a_data[pplan.src[t] as usize];
                    }
                    let v = pplan.tent[k] - omega * inv_diag[row] * acc;
                    // SAFETY: tasks own disjoint row ranges of P's data.
                    unsafe { *pdata.get().add(k) = v };
                }
            }
        });
        for (k, &pos) in self.rperm.iter().enumerate() {
            self.r.data[k] = self.p.data[pos];
        }
        self.wplan.apply(&self.a.data, &self.p.data, &mut self.wvals);
    }
}

/// A full SA-AMG hierarchy: coarsening levels plus an LU-factorized
/// coarsest operator. Build once per mesh/pattern; [`AmgHierarchy::refill`]
/// renumerates it for new values on the same pattern.
#[derive(Clone, Debug)]
pub struct AmgHierarchy {
    cfg: AmgConfig,
    levels: Vec<AmgLevel>,
    /// Coarsest operator (the caller's matrix itself when it is already at
    /// or below `coarse_max`).
    coarse_a: Csr,
    coarse_inv_diag: Vec<f64>,
    /// Dense LU of the coarsest operator; `None` falls back to a Jacobi
    /// sweep (numerically singular coarse level).
    lu: Option<LuFactor>,
}

impl AmgHierarchy {
    /// Build the hierarchy for an SPD operator. Symbolic structure
    /// (aggregation, transfer patterns, product pair lists) is computed
    /// here once; the numeric tail is the same pass [`AmgHierarchy::refill`]
    /// runs, so refilling with these values reproduces this hierarchy
    /// bitwise.
    pub fn build(a: &Csr, cfg: AmgConfig) -> AmgHierarchy {
        assert_eq!(a.nrows, a.ncols, "AMG needs a square operator");
        let mut levels = Vec::new();
        let mut cur = a.clone();
        while cur.nrows > cfg.coarse_max && levels.len() < cfg.max_levels {
            let (agg, n_agg) = aggregate(&cur, cfg.theta);
            if n_agg == 0 || n_agg >= cur.nrows {
                break; // no coarsening progress — stop here
            }
            let level = AmgLevel::symbolic(cur, &agg, n_agg);
            cur = Csr {
                nrows: level.cplan.nrows,
                ncols: level.cplan.ncols,
                indptr: level.cplan.indptr.clone(),
                indices: level.cplan.indices.clone(),
                data: vec![0.0; level.cplan.nnz()],
            };
            levels.push(level);
        }
        let n_c = cur.nrows;
        let mut h = AmgHierarchy {
            cfg,
            levels,
            coarse_a: cur,
            coarse_inv_diag: vec![0.0; n_c],
            lu: None,
        };
        h.renumeric();
        h
    }

    /// Renumerate the whole hierarchy for new values on the finest pattern
    /// (same length as the original matrix's data). Aggregation, transfer
    /// patterns and product plans are reused — only values flow: the trick
    /// [`crate::bc::CondensePlan::reapply_into`] applies to condensation,
    /// extended through the Galerkin triple product.
    pub fn refill(&mut self, values: &[f64]) {
        let fine = self
            .levels
            .first_mut()
            .map(|l| &mut l.a)
            .unwrap_or(&mut self.coarse_a);
        assert_eq!(values.len(), fine.data.len(), "refill value length");
        fine.data.copy_from_slice(values);
        self.renumeric();
        #[cfg(feature = "fault-inject")]
        if crate::util::faults::fire(crate::util::faults::AMG_REFILL_POISON, 0, 0) {
            // Corrupt one smoother entry AFTER renumeric (which would
            // otherwise recompute it away); coarse-only hierarchies poison
            // the coarse smoother and drop the exact LU so the corruption
            // is actually exercised.
            match self.levels.first_mut() {
                Some(lev) => lev.inv_diag[0] = f64::NAN,
                None => {
                    self.coarse_inv_diag[0] = f64::NAN;
                    self.lu = None;
                }
            }
        }
    }

    /// The shared numeric pass of [`AmgHierarchy::build`] and
    /// [`AmgHierarchy::refill`].
    fn renumeric(&mut self) {
        let nl = self.levels.len();
        for l in 0..nl {
            let (head, tail) = self.levels.split_at_mut(l + 1);
            let lev = &mut head[l];
            lev.update_numeric(self.cfg.omega);
            let next_data: &mut [f64] = match tail.first_mut() {
                Some(next) => &mut next.a.data,
                None => &mut self.coarse_a.data,
            };
            lev.cplan.apply(&lev.r.data, &lev.wvals, next_data);
        }
        self.coarse_inv_diag = jacobi_inverse(self.coarse_a.diagonal());
        let n_c = self.coarse_a.nrows;
        // Guard against stalled coarsening (e.g. a near-diagonal operator
        // with no strong connections): never densify a large coarse level —
        // the Jacobi-sweep fallback keeps the cycle valid at O(n) cost.
        if n_c > 4 * self.cfg.coarse_max.max(1) {
            self.lu = None;
            return;
        }
        let dense = Dense {
            nrows: n_c,
            ncols: n_c,
            data: self.coarse_a.to_dense(),
        };
        self.lu = dense.factor().ok();
    }

    /// Number of operator levels including the coarsest.
    pub fn n_levels(&self) -> usize {
        self.levels.len() + 1
    }

    /// DoF count per level, finest first.
    pub fn level_dims(&self) -> Vec<usize> {
        let mut dims: Vec<usize> = self.levels.iter().map(|l| l.a.nrows).collect();
        dims.push(self.coarse_a.nrows);
        dims
    }

    /// Operator complexity `Σ_l nnz(A_l) / nnz(A_0)` — the classic AMG
    /// memory/work figure of merit.
    pub fn operator_complexity(&self) -> f64 {
        let fine_nnz = self.levels.first().map(|l| l.a.nnz()).unwrap_or(self.coarse_a.nnz());
        let total: usize =
            self.levels.iter().map(|l| l.a.nnz()).sum::<usize>() + self.coarse_a.nnz();
        total as f64 / fine_nnz.max(1) as f64
    }

    /// Finest-level dimension.
    pub fn nrows(&self) -> usize {
        self.levels.first().map(|l| l.a.nrows).unwrap_or(self.coarse_a.nrows)
    }

    /// Allocate cycle scratch for `lanes` simultaneous residual lanes.
    pub fn scratch(&self, lanes: usize) -> CycleScratch {
        let dims = self.level_dims();
        CycleScratch {
            lanes,
            r: dims.iter().map(|&n| vec![0.0; lanes * n]).collect(),
            z: dims.iter().map(|&n| vec![0.0; lanes * n]).collect(),
            t: dims[..dims.len() - 1].iter().map(|&n| vec![0.0; lanes * n]).collect(),
        }
    }

    /// One symmetric V(1,1)-cycle applied to `s_n` instance-major residual
    /// lanes: `Z_s ← B R_s` with `B ≈ A⁻¹`. All level traversals are fused
    /// across lanes (`spmv_multi` reads each level pattern once per batch);
    /// per lane the arithmetic order equals a 1-lane call, so batched and
    /// scalar applications agree bitwise lane for lane.
    pub fn vcycle_into(&self, s_n: usize, r_in: &[f64], z_out: &mut [f64], ws: &mut CycleScratch) {
        let nl = self.levels.len();
        assert_eq!(ws.lanes, s_n, "scratch sized for a different lane count");
        let n0 = self.nrows();
        assert_eq!(r_in.len(), s_n * n0, "residual must be S × n");
        assert_eq!(z_out.len(), s_n * n0, "output must be S × n");
        ws.r[0].copy_from_slice(r_in);
        // Down-sweep: pre-smooth from zero, restrict the residual.
        for l in 0..nl {
            let lev = &self.levels[l];
            let n = lev.a.nrows;
            let (rhead, rtail) = ws.r.split_at_mut(l + 1);
            let rcur = &rhead[l];
            let rnext = &mut rtail[0];
            let z = &mut ws.z[l];
            let t = &mut ws.t[l];
            // One damped-Jacobi sweep from the zero guess: z = ω D⁻¹ r.
            for s in 0..s_n {
                let base = s * n;
                for i in 0..n {
                    z[base + i] = lev.omega * lev.inv_diag[i] * rcur[base + i];
                }
            }
            // Restrict the smoothed residual: r_{l+1} = Pᵀ (r − A z).
            lev.a.spmv_multi(z, t, s_n);
            for (ti, &ri) in t.iter_mut().zip(rcur.iter()) {
                *ti = ri - *ti;
            }
            lev.r.spmv_multi(t, rnext, s_n);
        }
        // Coarsest solve (direct LU per lane; Jacobi-sweep fallback when
        // the coarse operator failed to factorize).
        {
            let n_c = self.coarse_a.nrows;
            let rc = &ws.r[nl];
            let zc = &mut ws.z[nl];
            match &self.lu {
                Some(lu) => {
                    for s in 0..s_n {
                        let lane = s * n_c..(s + 1) * n_c;
                        lu.solve_into(&rc[lane.clone()], &mut zc[lane]);
                    }
                }
                None => {
                    for s in 0..s_n {
                        let base = s * n_c;
                        for i in 0..n_c {
                            zc[base + i] = self.coarse_inv_diag[i] * rc[base + i];
                        }
                    }
                }
            }
        }
        // Up-sweep: prolong the correction, post-smooth.
        for l in (0..nl).rev() {
            let lev = &self.levels[l];
            let n = lev.a.nrows;
            let (zhead, ztail) = ws.z.split_at_mut(l + 1);
            let z = &mut zhead[l];
            let znext = &ztail[0];
            let t = &mut ws.t[l];
            let rcur = &ws.r[l];
            lev.p.spmv_multi(znext, t, s_n);
            for (zi, &ti) in z.iter_mut().zip(t.iter()) {
                *zi += ti;
            }
            // Post-smooth: z += ω D⁻¹ (r − A z) — symmetric with the
            // pre-sweep, keeping the cycle SPD for CG.
            lev.a.spmv_multi(z, t, s_n);
            for s in 0..s_n {
                let base = s * n;
                for i in 0..n {
                    z[base + i] += lev.omega * lev.inv_diag[i] * (rcur[base + i] - t[base + i]);
                }
            }
        }
        #[cfg(feature = "fault-inject")]
        for s in 0..s_n {
            if crate::util::faults::fire(crate::util::faults::AMG_POISON, s, 0) {
                ws.z[0][s * n0..(s + 1) * n0].fill(f64::NAN);
            }
        }
        z_out.copy_from_slice(&ws.z[0]);
        // Guard: a lane whose smoothed correction went non-finite from a
        // finite residual falls back to the identity preconditioner for
        // this application — one poisoned lane cannot leak NaN into the
        // outer Krylov state of its neighbors, and CG on the lane keeps a
        // valid (if unaccelerated) direction.
        for s in 0..s_n {
            let lane = s * n0..(s + 1) * n0;
            if z_out[lane.clone()].iter().any(|v| !v.is_finite())
                && r_in[lane.clone()].iter().all(|v| v.is_finite())
            {
                let (dst, src) = (&mut z_out[lane.clone()], &r_in[lane]);
                dst.copy_from_slice(src);
            }
        }
    }
}

/// Reusable V-cycle workspace (per-level residual/correction/temp buffers
/// for a fixed lane count) — grow-once per configuration, so repeated
/// applications allocate nothing.
#[derive(Clone, Debug)]
pub struct CycleScratch {
    lanes: usize,
    r: Vec<Vec<f64>>,
    z: Vec<Vec<f64>>,
    t: Vec<Vec<f64>>,
}

impl CycleScratch {
    /// An unsized scratch — [`CycleScratch::ensure`] shapes it on first
    /// use. Long-lived owners ([`super::PrecondEngine`]) start here so one
    /// slot serves every later solve without per-call allocation.
    pub fn empty() -> CycleScratch {
        CycleScratch {
            lanes: 0,
            r: Vec::new(),
            z: Vec::new(),
            t: Vec::new(),
        }
    }

    /// Resize for a hierarchy + lane count; a no-op when already shaped
    /// (the steady state of every repeated-solve driver).
    pub fn ensure(&mut self, h: &AmgHierarchy, lanes: usize) {
        let dims = h.level_dims();
        let ok = self.lanes == lanes
            && self.r.len() == dims.len()
            && self.r.iter().zip(&dims).all(|(b, &n)| b.len() == lanes * n);
        if !ok {
            *self = h.scratch(lanes);
        }
    }
}

/// Scratch storage of the V-cycle wrappers: owned (one-shot constructions)
/// or borrowed from a long-lived holder like [`super::PrecondEngine`], so
/// repeated solves reuse one allocation. The slot is a `Mutex` (not a
/// `RefCell`) so engine-holding drivers stay `Sync` and can sit behind an
/// `Arc` — the lock is uncontended on every current path (one solve at a
/// time per engine) and costs one atomic per preconditioner application.
enum ScratchSlot<'a> {
    Owned(Mutex<CycleScratch>),
    Shared(&'a Mutex<CycleScratch>),
}

impl ScratchSlot<'_> {
    fn cell(&self) -> &Mutex<CycleScratch> {
        match self {
            ScratchSlot::Owned(c) => c,
            ScratchSlot::Shared(c) => c,
        }
    }
}

/// Scalar V-cycle preconditioner over a borrowed hierarchy — plugs into
/// [`super::cg`]/[`super::cg_warm`]/[`super::bicgstab`] through the
/// [`Preconditioner`] trait exactly like [`super::JacobiPrecond`].
pub struct AmgPrecond<'h> {
    h: &'h AmgHierarchy,
    scratch: ScratchSlot<'h>,
}

impl<'h> AmgPrecond<'h> {
    pub fn new(h: &'h AmgHierarchy) -> AmgPrecond<'h> {
        AmgPrecond {
            h,
            scratch: ScratchSlot::Owned(Mutex::new(h.scratch(1))),
        }
    }

    /// Borrow a caller-held scratch instead of allocating one — the
    /// engine-owned slot that makes repeated AMG solves allocation-free.
    pub fn with_scratch(
        h: &'h AmgHierarchy,
        scratch: &'h Mutex<CycleScratch>,
    ) -> AmgPrecond<'h> {
        AmgPrecond { h, scratch: ScratchSlot::Shared(scratch) }
    }
}

impl Preconditioner for AmgPrecond<'_> {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        let mut ws = self.scratch.cell().lock().unwrap();
        ws.ensure(self.h, 1);
        self.h.vcycle_into(1, r, z, &mut ws);
    }
}

/// Lockstep V-cycle preconditioner: ONE hierarchy applied to `S`
/// instance-major residual lanes per call, with every level operator read
/// once per batch ([`Csr::spmv_multi`] inner loops). Each lane is bitwise
/// identical to [`AmgPrecond`] on that lane.
pub struct AmgBatch<'h> {
    h: &'h AmgHierarchy,
    s_n: usize,
    scratch: ScratchSlot<'h>,
}

impl<'h> AmgBatch<'h> {
    pub fn new(h: &'h AmgHierarchy, s_n: usize) -> AmgBatch<'h> {
        AmgBatch {
            h,
            s_n,
            scratch: ScratchSlot::Owned(Mutex::new(h.scratch(s_n))),
        }
    }

    /// Borrow a caller-held scratch (see [`AmgPrecond::with_scratch`]).
    pub fn with_scratch(
        h: &'h AmgHierarchy,
        s_n: usize,
        scratch: &'h Mutex<CycleScratch>,
    ) -> AmgBatch<'h> {
        AmgBatch { h, s_n, scratch: ScratchSlot::Shared(scratch) }
    }
}

impl super::cg_batch::LockstepPrecond for AmgBatch<'_> {
    fn apply_batch(&self, r: &[f64], z: &mut [f64]) {
        let mut ws = self.scratch.cell().lock().unwrap();
        ws.ensure(self.h, self.s_n);
        self.h.vcycle_into(self.s_n, r, z, &mut ws);
    }
}

#[cfg(test)]
mod tests {
    use super::super::{cg, cg_warm, JacobiPrecond, SolverConfig};
    use super::*;
    use crate::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
    use crate::bc::{condense, DirichletBc};
    use crate::mesh::structured::unit_square_tri;

    fn poisson(n: usize, rho: Option<fn(&[f64]) -> f64>) -> (Csr, Vec<f64>) {
        let m = unit_square_tri(n);
        let ctx = AssemblyContext::new(&m, 1);
        let coeff = match rho {
            Some(f) => ctx.coeff_fn(f),
            None => Coefficient::Const(1.0),
        };
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion { rho: coeff });
        let f = ctx.assemble_vector(&LinearForm::Source { f: Coefficient::Const(1.0) });
        let sys = condense(&k, &f, &DirichletBc::homogeneous(m.boundary_nodes()));
        (sys.k, sys.rhs)
    }

    #[test]
    fn aggregation_covers_every_node_once() {
        let (a, _) = poisson(10, None);
        let (agg, n_agg) = aggregate(&a, 0.08);
        assert!(n_agg > 0 && n_agg < a.nrows);
        let mut seen = vec![false; n_agg];
        for &g in &agg {
            assert!(g < n_agg);
            seen[g] = true;
        }
        assert!(seen.iter().all(|&s| s), "empty aggregate");
        // Deterministic: a second pass reproduces the assignment exactly.
        let (agg2, n2) = aggregate(&a, 0.08);
        assert_eq!(agg, agg2);
        assert_eq!(n_agg, n2);
    }

    #[test]
    fn hierarchy_coarsens_and_is_deterministic() {
        let (a, _) = poisson(16, None);
        let cfg = AmgConfig { coarse_max: 20, ..AmgConfig::default() };
        let h1 = AmgHierarchy::build(&a, cfg);
        assert!(h1.n_levels() >= 2, "levels: {:?}", h1.level_dims());
        let dims = h1.level_dims();
        assert!(dims.windows(2).all(|w| w[1] < w[0]), "dims must shrink: {dims:?}");
        assert!(h1.operator_complexity() < 3.0, "complexity {}", h1.operator_complexity());
        // Rebuild bitwise-equals (threaded numeric passes are deterministic).
        let h2 = AmgHierarchy::build(&a, cfg);
        for (l1, l2) in h1.levels.iter().zip(&h2.levels) {
            assert_eq!(l1.a.data, l2.a.data);
            assert_eq!(l1.p.data, l2.p.data);
        }
        assert_eq!(h1.coarse_a.data, h2.coarse_a.data);
    }

    #[test]
    fn galerkin_coarse_operators_stay_spd() {
        let (a, _) = poisson(12, Some(|p: &[f64]| 1.0 + 3.0 * p[0] * p[1]));
        let h = AmgHierarchy::build(&a, AmgConfig { coarse_max: 10, ..AmgConfig::default() });
        let mut ops: Vec<&Csr> = h.levels.iter().map(|l| &l.a).collect();
        ops.push(&h.coarse_a);
        for (l, op) in ops.iter().enumerate() {
            // Symmetry (up to roundoff of the two summation orders).
            for i in 0..op.nrows {
                let (cols, vals) = op.row(i);
                for (&j, &v) in cols.iter().zip(vals) {
                    let vt = op.get(j, i).unwrap_or(0.0);
                    assert!(
                        (v - vt).abs() <= 1e-12 * v.abs().max(1.0),
                        "level {l}: asymmetry at ({i},{j}): {v} vs {vt}"
                    );
                }
            }
            // Positive definiteness on a few deterministic probes.
            for probe in 0..3u64 {
                let x: Vec<f64> = (0..op.nrows)
                    .map(|i| 0.1 + ((i as u64 * 2654435761 + probe * 97) % 1000) as f64 / 1000.0)
                    .collect();
                let ax = op.dot(&x);
                let xax: f64 = x.iter().zip(&ax).map(|(a, b)| a * b).sum();
                assert!(xax > 0.0, "level {l}: xᵀAx = {xax}");
            }
        }
    }

    #[test]
    fn refill_bitwise_matches_rebuild() {
        let (a, _) = poisson(12, None);
        // Scaled values keep every strength decision identical, so rebuild
        // and refill share the aggregation — they must agree bitwise.
        let mut a2 = a.clone();
        a2.scale(3.5);
        let cfg = AmgConfig { coarse_max: 15, ..AmgConfig::default() };
        let mut h = AmgHierarchy::build(&a, cfg);
        let fresh = AmgHierarchy::build(&a2, cfg);
        h.refill(&a2.data);
        for (lr, lf) in h.levels.iter().zip(&fresh.levels) {
            assert_eq!(lr.a.data, lf.a.data, "refilled level operator");
            assert_eq!(lr.p.data, lf.p.data, "refilled prolongation");
            assert_eq!(lr.omega, lf.omega, "refilled damping");
        }
        assert_eq!(h.coarse_a.data, fresh.coarse_a.data);
        // And the applications agree bitwise too.
        let n = a.nrows;
        let r: Vec<f64> = (0..n).map(|i| ((i * 37 + 11) % 17) as f64 - 8.0).collect();
        let (mut z1, mut z2) = (vec![0.0; n], vec![0.0; n]);
        AmgPrecond::new(&h).apply(&r, &mut z1);
        AmgPrecond::new(&fresh).apply(&r, &mut z2);
        assert_eq!(z1, z2);
    }

    #[test]
    fn vcycle_application_is_repeatable() {
        let (a, _) = poisson(10, None);
        let h = AmgHierarchy::build(&a, AmgConfig::default());
        let pc = AmgPrecond::new(&h);
        let n = a.nrows;
        let r: Vec<f64> = (0..n).map(|i| (i % 5) as f64 - 2.0).collect();
        let (mut z1, mut z2) = (vec![0.0; n], vec![0.0; n]);
        pc.apply(&r, &mut z1);
        pc.apply(&r, &mut z2);
        assert_eq!(z1, z2, "scratch reuse must not perturb the cycle");
    }

    #[test]
    fn amg_pcg_converges_and_beats_jacobi_iterations() {
        let (a, b) = poisson(24, None);
        let cfg = SolverConfig::default();
        let h = AmgHierarchy::build(&a, AmgConfig::default());
        let amg = AmgPrecond::new(&h);
        let (x_amg, st_amg) = cg(&a, &b, &amg, &cfg);
        assert!(st_amg.converged, "{st_amg:?}");
        let jac = JacobiPrecond::new(&a);
        let (x_jac, st_jac) = cg(&a, &b, &jac, &cfg);
        assert!(st_jac.converged);
        assert!(
            st_amg.iterations < st_jac.iterations,
            "AMG {} vs Jacobi {}",
            st_amg.iterations,
            st_jac.iterations
        );
        assert!(crate::util::rel_l2(&x_amg, &x_jac) < 1e-8);
    }

    #[test]
    fn tiny_operator_degenerates_to_direct_solve() {
        // At or below coarse_max the hierarchy is a pure dense solve: the
        // preconditioner is (numerically) A⁻¹ and CG converges immediately.
        let (a, b) = poisson(4, None);
        let h = AmgHierarchy::build(&a, AmgConfig::default());
        assert_eq!(h.n_levels(), 1);
        let pc = AmgPrecond::new(&h);
        let (x, st) = cg_warm(&a, &b, None, &pc, &SolverConfig::default());
        assert!(st.converged);
        assert!(st.iterations <= 2, "direct-solve preconditioner: {st:?}");
        let jac = JacobiPrecond::new(&a);
        let (x_ref, _) = cg(&a, &b, &jac, &SolverConfig::default());
        assert!(crate::util::rel_l2(&x, &x_ref) < 1e-8);
    }
}
